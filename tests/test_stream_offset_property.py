"""Property tests: solve_stream_offset is SAFE and TIGHT for random
read/write frontiers, proven against the SegmentPool byte oracle.

Safety: replaying the schedule with In placed ``delta`` bytes above Out
never clobbers.  Tightness: ``delta - 1`` always clobbers (when
``delta > 0``) — the solver returns the exact optimum, not a bound.

Two layers of coverage:

  * generic random frontiers (hypothesis),
  * the ``conv_k2d`` k x k halo/stride/padding frontiers — a
    deterministic exhaustive sweep over k in {1, 3, 5} x stride in
    {1, 2} x padding in {same, valid} that runs even without
    hypothesis, plus a randomized hypothesis version over arbitrary
    geometries.
"""
import numpy as np
import pytest

from repro.core.graph_planner import solve_stream_offset
from repro.core.pool import PoolClobberError, SegmentPool
from repro.core.rowsched import (RowSchedule, conv_k2d_out,
                                 conv_k2d_schedule)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(not HAVE_HYPOTHESIS,
                                      reason="hypothesis not installed")


# ---------------------------------------------------------------------------
# Generic random frontiers.
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @st.composite
    def _schedules(draw):
        """A random streaming schedule: per step, a set of input bytes
        read (monotone-ish frontier with halo re-reads) and bytes
        written."""
        steps = draw(st.integers(2, 12))
        in_size = draw(st.integers(steps, 40))
        halo = draw(st.integers(0, 3))
        stride = draw(st.integers(1, 3))
        out_per_step = draw(st.integers(1, 5))
        reads = []
        for t in range(steps):
            base = min(t * stride, in_size - 1)
            lo = max(0, base - halo)
            hi = min(in_size - 1, base + halo)
            reads.append(list(range(lo, hi + 1)))
        return reads, in_size, out_per_step


def _frontiers(reads, in_size, out_per_step):
    steps = len(reads)
    last_read = {}
    for t, rs in enumerate(reads):
        for r in rs:
            last_read[r] = t
    read_start = np.empty(steps, dtype=np.int64)
    for t in range(steps):
        needed = [r for r, lr in last_read.items() if lr >= t]
        read_start[t] = min(needed) if needed else in_size
    write_end = (np.arange(steps, dtype=np.int64) + 1) * out_per_step
    return read_start, write_end, last_read


def _replay(reads, in_size, out_per_step, last_read, delta):
    """Drive the byte schedule through the clobber oracle at offset
    ``delta``: Out at 0, In at ``delta``; rows below the frontier are
    freed exactly as Eq. (2) models their death."""
    steps = len(reads)
    out_size = steps * out_per_step
    n = max(in_size + max(delta, 0), out_size)
    pool = SegmentPool(n, segment_bytes=1)
    for b in range(in_size):
        pool.write(delta + b, owner=("in", b))
    written = 0
    for t in range(steps):
        for b in reads[t]:
            pool.read(delta + b, owner=("in", b))
        # free every byte the frontier has passed after this step's reads
        needed = [r for r, lr in last_read.items() if lr >= t + 1]
        frontier = min(needed) if needed else in_size
        for b in range(in_size):
            if b < frontier and pool.live and \
                    pool._slots.get((delta + b) % n) is not None and \
                    pool._slots[(delta + b) % n].owner == ("in", b):
                pool.free(delta + b, owner=("in", b))
        for b in range(written, (t + 1) * out_per_step):
            pool.write(b, owner=("out", b))
        written = (t + 1) * out_per_step
    for b in range(out_size):
        pool.read(b, owner=("out", b))


if HAVE_HYPOTHESIS:
    @needs_hypothesis
    @given(_schedules())
    @settings(max_examples=60, deadline=None)
    def test_solved_delta_is_clobber_free_and_tight(sched):
        reads, in_size, out_per_step = sched
        read_start, write_end, last_read = _frontiers(reads, in_size,
                                                      out_per_step)
        delta = solve_stream_offset(write_end, read_start)
        assert delta >= 0
        _replay(reads, in_size, out_per_step, last_read, delta)
        if delta > 0:
            with pytest.raises(PoolClobberError):
                _replay(reads, in_size, out_per_step, last_read,
                        delta - 1)


def test_known_gemm_case_matches_closed_form():
    """m=1 GEMM in byte units: delta = N - 1 (Eq. 1)."""
    K, N = 7, 4
    read_start = np.zeros(N, dtype=np.int64)      # whole row needed
    write_end = (np.arange(N, dtype=np.int64) + 1)
    assert solve_stream_offset(write_end, read_start) == N - 1


# ---------------------------------------------------------------------------
# conv_k2d halo/stride/padding frontiers.
# ---------------------------------------------------------------------------

def _replay_rowsched(sched: RowSchedule, delta: int) -> None:
    """Drive a RowSchedule through the oracle exactly the way the sim
    executor does (``executors._sim_rowsched_op``): reads, then
    Eq.-(2) frees, then writes, per step; In at ``delta`` chunks above
    Out."""
    ic, oc = sched.in_chunk, sched.out_chunk
    in_tot, out_tot = sched.in_rows * ic, sched.out_rows * oc
    n = max(in_tot + max(delta, 0), out_tot, 1)
    pool = SegmentPool(n, segment_bytes=1)
    for s in range(in_tot):
        pool.write(delta + s, owner=("in", s))
    frees = sched.frees()
    for t in range(sched.steps):
        for r in sched.reads[t]:
            for s in range(ic):
                pool.read(delta + r * ic + s, owner=("in", r * ic + s))
        for r in frees[t]:
            for s in range(ic):
                pool.free(delta + r * ic + s, owner=("in", r * ic + s))
        for r in sched.writes[t]:
            for s in range(oc):
                pool.write(r * oc + s, owner=("out", r * oc + s))
    for s in range(out_tot):
        pool.read(s, owner=("out", s))


def _check_safe_and_tight(sched: RowSchedule) -> int:
    delta = sched.solve_delta()
    assert delta >= 0
    _replay_rowsched(sched, delta)            # safe: must not clobber
    if delta > 0:
        with pytest.raises(PoolClobberError):  # tight: exact optimum
            _replay_rowsched(sched, delta - 1)
    return delta


@pytest.mark.parametrize("k", (1, 3, 5))
@pytest.mark.parametrize("stride", (1, 2))
@pytest.mark.parametrize("padding", ("same", "valid"))
@pytest.mark.parametrize("h_in,in_chunk,out_chunk",
                         ((7, 3, 2), (12, 4, 4), (9, 2, 5)))
def test_conv_k2d_frontier_safe_and_tight(k, stride, padding, h_in,
                                          in_chunk, out_chunk):
    """Deterministic sweep (runs without hypothesis): the k-row halo
    widens the safe-offset frontier and the solved delta stays exact
    for every (k, stride, padding) geometry."""
    h_out = conv_k2d_out(h_in, k, stride, padding)
    sched = conv_k2d_schedule(h_in, h_out, in_chunk, out_chunk, k=k,
                              stride=stride, padding=padding)
    delta = _check_safe_and_tight(sched)
    if padding == "same" and stride == 1 and out_chunk >= in_chunk:
        # the trailing (k-1)//2 halo rows alone force delta > 0
        assert delta >= (k - 1) // 2 * in_chunk


if HAVE_HYPOTHESIS:
    @needs_hypothesis
    @given(k=st.sampled_from((1, 3, 5)),
           stride=st.sampled_from((1, 2)),
           padding=st.sampled_from(("same", "valid")),
           h_in=st.integers(5, 24),
           in_chunk=st.integers(1, 6),
           out_chunk=st.integers(1, 6))
    @settings(max_examples=80, deadline=None)
    def test_conv_k2d_frontier_random_geometry(k, stride, padding, h_in,
                                               in_chunk, out_chunk):
        h_out = conv_k2d_out(h_in, k, stride, padding)
        sched = conv_k2d_schedule(h_in, h_out, in_chunk, out_chunk, k=k,
                                  stride=stride, padding=padding)
        _check_safe_and_tight(sched)
