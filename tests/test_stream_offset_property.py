"""Property test: solve_stream_offset is SAFE and TIGHT for random
read/write frontiers, proven against the SegmentPool byte oracle.

Safety: replaying the schedule with In placed ``delta`` bytes above Out
never clobbers.  Tightness: ``delta - 1`` always clobbers (when
``delta > 0``) — the solver returns the exact optimum, not a bound.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.graph_planner import solve_stream_offset
from repro.core.pool import PoolClobberError, SegmentPool


@st.composite
def _schedules(draw):
    """A random streaming schedule: per step, a set of input bytes read
    (monotone-ish frontier with halo re-reads) and bytes written."""
    steps = draw(st.integers(2, 12))
    in_size = draw(st.integers(steps, 40))
    halo = draw(st.integers(0, 3))
    stride = draw(st.integers(1, 3))
    out_per_step = draw(st.integers(1, 5))
    reads = []
    for t in range(steps):
        base = min(t * stride, in_size - 1)
        lo = max(0, base - halo)
        hi = min(in_size - 1, base + halo)
        reads.append(list(range(lo, hi + 1)))
    return reads, in_size, out_per_step


def _frontiers(reads, in_size, out_per_step):
    steps = len(reads)
    last_read = {}
    for t, rs in enumerate(reads):
        for r in rs:
            last_read[r] = t
    read_start = np.empty(steps, dtype=np.int64)
    for t in range(steps):
        needed = [r for r, lr in last_read.items() if lr >= t]
        read_start[t] = min(needed) if needed else in_size
    write_end = (np.arange(steps, dtype=np.int64) + 1) * out_per_step
    return read_start, write_end, last_read


def _replay(reads, in_size, out_per_step, last_read, delta):
    """Drive the byte schedule through the clobber oracle at offset
    ``delta``: Out at 0, In at ``delta``; rows below the frontier are
    freed exactly as Eq. (2) models their death."""
    steps = len(reads)
    out_size = steps * out_per_step
    n = max(in_size + max(delta, 0), out_size)
    pool = SegmentPool(n, segment_bytes=1)
    for b in range(in_size):
        pool.write(delta + b, owner=("in", b))
    written = 0
    for t in range(steps):
        for b in reads[t]:
            pool.read(delta + b, owner=("in", b))
        # free every byte the frontier has passed after this step's reads
        needed = [r for r, lr in last_read.items() if lr >= t + 1]
        frontier = min(needed) if needed else in_size
        for b in range(in_size):
            if b < frontier and pool.live and \
                    pool._slots.get((delta + b) % n) is not None and \
                    pool._slots[(delta + b) % n].owner == ("in", b):
                pool.free(delta + b, owner=("in", b))
        for b in range(written, (t + 1) * out_per_step):
            pool.write(b, owner=("out", b))
        written = (t + 1) * out_per_step
    for b in range(out_size):
        pool.read(b, owner=("out", b))


@given(_schedules())
@settings(max_examples=60, deadline=None)
def test_solved_delta_is_clobber_free_and_tight(sched):
    reads, in_size, out_per_step = sched
    read_start, write_end, last_read = _frontiers(reads, in_size,
                                                  out_per_step)
    delta = solve_stream_offset(write_end, read_start)
    assert delta >= 0
    _replay(reads, in_size, out_per_step, last_read, delta)  # must pass
    if delta > 0:
        with pytest.raises(PoolClobberError):
            _replay(reads, in_size, out_per_step, last_read, delta - 1)


def test_known_gemm_case_matches_closed_form():
    """m=1 GEMM in byte units: delta = N - 1 (Eq. 1)."""
    K, N = 7, 4
    read_start = np.zeros(N, dtype=np.int64)      # whole row needed
    write_end = (np.arange(N, dtype=np.int64) + 1)
    assert solve_stream_offset(write_end, read_start) == N - 1
