"""Partial execution (DESIGN.md §13): the spatial-slicing subsystem.

Covers the three proof obligations of the subsystem:

  * geometry — slice windows tile the output, halos chain backward
    through the conv chain exactly as :class:`ChainStep.in_window`
    demands, and the Pareto frontier is monotone (more slices, less
    ring),
  * safety — the sliced program carries the SAME static certificate the
    sim clobber oracle computes (differential static-vs-sim),
  * numerics — sliced execution is bit-identical to unsliced execution
    (fp32 and int8, jnp + pallas; the slow lane).

Plus the driver-facing policy (``plan_partial`` auto/force), the
compile-pipeline knob (``partial="auto"|N``), the VMCU301/VMCU303 lint
findings, and the artifact roundtrip.
"""
import numpy as np
import pytest

import repro
from repro.analysis import lint_program, verify_program
from repro.compile.driver import _resolve_net
from repro.graph import certify_net, init_net_params
from repro.graph.netplan import _plan_net as plan_net
from repro.graph.run import (QuantizedNet, _quantize_net, run_net_quantized)
from repro.partial import (PartialPlanError, apply_partial, candidate,
                           chain_range, chain_steps, estimate_slices,
                           pareto, plan_partial, program_macs,
                           recompute_spans, slice_layout)
from repro.partial.slicer import even_bounds

M4 = repro.get_target("cortex-m4")


def _byte_plan(net):
    graph = _resolve_net(net)
    return plan_net(graph, dtype="int8", fused_exec=False,
                    **M4.byte_ring_kwargs)


def _ranges(plan):
    return [(g.op_lo, g.op_hi) for g in plan.groups]


@pytest.fixture(scope="module")
def vww_byte():
    return _byte_plan("mcunet-5fps-vww")


@pytest.fixture(scope="module")
def imagenet_byte():
    return _byte_plan("mcunet-320kb-imagenet")


# ---------------------------------------------------------------------------
# Geometry: windows, halos, frontier.
# ---------------------------------------------------------------------------

def test_even_bounds_tile_monotonically():
    for h, n in ((32, 4), (17, 3), (7, 7)):
        b = even_bounds(h, n)
        assert b[0] == 0 and b[-1] == h and len(b) == n + 1
        assert all(b[i] < b[i + 1] for i in range(n))


def _sliceable_chains(plan):
    out = []
    for lo, hi in _ranges(plan):
        rng = chain_range(plan.program, lo, hi)
        if not isinstance(rng, str):
            out.append(((lo, hi), rng))
    return out


def test_imagenet_has_sliceable_groups(imagenet_byte):
    chains = _sliceable_chains(imagenet_byte)
    assert len(chains) >= 3  # the pw/dw/pw interior of the net


def test_chain_range_rejects_first_group(vww_byte, imagenet_byte):
    for plan in (vww_byte, imagenet_byte):
        lo, hi = _ranges(plan)[0]
        why = chain_range(plan.program, lo, hi)
        assert isinstance(why, str) and "first group" in why


def test_chain_range_excludes_trailing_residual_add(imagenet_byte):
    ops = imagenet_byte.program.ops
    trimmed = 0
    for (glo, ghi), (lo, hi) in _sliceable_chains(imagenet_byte):
        assert lo == glo
        assert all(o.kind in ("conv_pw", "conv_dw", "conv_k2d")
                   for o in ops[lo:hi])
        if ops[ghi - 1].kind == "add":
            assert hi == ghi - 1  # the add consumes, it is not sliced
            trimmed += 1
    assert trimmed >= 1


def test_chain_range_is_idempotent_on_chain_ranges(imagenet_byte):
    for _, (lo, hi) in _sliceable_chains(imagenet_byte):
        assert chain_range(imagenet_byte.program, lo, hi) == (lo, hi)


def test_slice_windows_tile_output_and_chain_halos(imagenet_byte):
    (glo, ghi), (lo, hi) = _sliceable_chains(imagenet_byte)[0]
    steps = chain_steps(imagenet_byte.program.ops[lo:hi])
    layout = slice_layout(steps, 4)
    assert layout is not None and layout.n_slices == 4
    L = len(steps)
    for j, st in enumerate(steps):
        bands = [(w[j].out_lo, w[j].out_hi) for w in layout.windows]
        assert bands[0][0] == 0 and bands[-1][1] == st.h_out
        if j == L - 1:
            # final output bands tile [0, h_out) exactly, no gaps
            assert all(a[1] == b[0] for a, b in zip(bands, bands[1:]))
        else:
            # interior bands overlap by the recomputed halo rows
            assert all(a[1] >= b[0] for a, b in zip(bands, bands[1:]))
        for w in layout.windows:
            win = w[j]
            # each input window is exactly what in_window demands
            assert (win.in_lo, win.in_hi) == \
                st.in_window(win.out_lo, win.out_hi)
            # first slice keeps the op's padding; interior slices use a
            # local mode (never a partial top halo)
            if win.out_lo == 0:
                assert win.padding == st.padding
            else:
                assert win.padding in ("same_mid", "valid")
        # position j's input windows are position j-1's output bands
        if j > 0:
            for w in layout.windows:
                assert (w[j].in_lo, w[j].in_hi) == \
                    (w[j - 1].out_lo, w[j - 1].out_hi)
        # the shared scratch band covers every slice's window there
        if j >= 1:
            assert layout.band_rows[j] == \
                max(w[j].h_in for w in layout.windows)
    # halo rows are recomputed, so the trade has a strictly positive
    # latency price on a k x k chain
    assert layout.extra_macs > 0
    assert all(r >= 0 for r in layout.extra_in_rows)
    assert L == hi - lo


def test_pareto_frontier_is_monotone(imagenet_byte):
    prog = imagenet_byte.program
    (glo, ghi), (lo, hi) = _sliceable_chains(imagenet_byte)[0]
    # group range and chain range resolve to the same frontier
    front = pareto(prog, glo, ghi)
    assert [c.as_dict() for c in front] == \
        [c.as_dict() for c in pareto(prog, lo, hi)]
    assert len(front) >= 2
    for a, b in zip(front, front[1:]):
        assert b.n_slices > a.n_slices
        assert b.region_segments < a.region_segments  # strictly improving
    two = candidate(prog, glo, ghi, front[0].n_slices)
    assert two is not None and two.as_dict() == front[0].as_dict()
    assert candidate(prog, glo, ghi, 10 ** 6) is None  # > h_out rows


def test_recompute_spans_match_planner(vww_byte, imagenet_byte):
    # the surgery's span accounting reproduces the planner's ring
    for plan in (vww_byte, imagenet_byte):
        assert recompute_spans(plan.program.ops) == \
            plan.program.pool_segments


# ---------------------------------------------------------------------------
# Policy: plan_partial auto / force, and the sliced-program certificate.
# ---------------------------------------------------------------------------

def _assert_static_equals_sim(program):
    res = verify_program(program)
    assert res.safe is True, [str(d) for d in res.diagnostics]
    sim = certify_net(program)
    want = {"peak_live": sim.peak_live, "reads": sim.reads,
            "writes": sim.writes}
    assert {k: res.stats[k] for k in want} == want


def test_plan_partial_none_when_net_fits(vww_byte):
    assert plan_partial(vww_byte.program, _ranges(vww_byte),
                        M4.sram_bytes) is None


def test_plan_partial_auto_fits_imagenet_on_m4(imagenet_byte):
    prog = imagenet_byte.program
    assert prog.pool_bytes > M4.sram_bytes  # the overflow being resolved
    pp = plan_partial(prog, _ranges(imagenet_byte), M4.sram_bytes)
    assert pp is not None
    assert pp.ring_bytes_before == prog.pool_bytes
    assert pp.ring_bytes_after == pp.program.pool_bytes <= M4.sram_bytes
    assert pp.net_macs == program_macs(prog)
    assert 0 < pp.mac_overhead < 0.15  # the latency price is bounded
    s = pp.summary()
    assert s["total_slices"] == sum(pp.choices.values()) >= 2
    assert s["n_sliced_groups"] == len(pp.choices) >= 1
    assert len(pp.parents) == len(pp.program.ops)
    # every slice points back into its unsliced group
    for i, par in enumerate(pp.parents):
        assert pp.program.ops[i].kind == prog.ops[par].kind


@pytest.mark.slow
def test_sliced_imagenet_static_certificate_equals_sim(imagenet_byte):
    pp = plan_partial(imagenet_byte.program, _ranges(imagenet_byte),
                      M4.sram_bytes)
    _assert_static_equals_sim(pp.program)


def test_plan_partial_force_slices_pinning_group(vww_byte):
    # VWW fits — force=N still slices the most-pinning sliceable group
    pp = plan_partial(vww_byte.program, _ranges(vww_byte), M4.sram_bytes,
                      force=4)
    assert list(pp.choices.values()) == [4]
    assert len(pp.program.ops) > len(vww_byte.program.ops)
    _assert_static_equals_sim(pp.program)  # differential static-vs-sim


def test_plan_partial_force_infeasible_raises(vww_byte):
    with pytest.raises(PartialPlanError, match="cannot slice any group"):
        plan_partial(vww_byte.program, _ranges(vww_byte), M4.sram_bytes,
                     force=10 ** 6)


def test_estimate_slices_advisory(vww_byte, imagenet_byte):
    # byte geometry: one segment is one byte
    est = estimate_slices(imagenet_byte.program, _ranges(imagenet_byte),
                          M4.sram_bytes)
    assert isinstance(est, int) and est >= 2
    assert estimate_slices(vww_byte.program, _ranges(vww_byte),
                           M4.sram_bytes) is None  # nothing over budget


# ---------------------------------------------------------------------------
# Lint: VMCU301 names the group, VMCU303 advertises the resolution.
# ---------------------------------------------------------------------------

def test_lint_vmcu301_names_group_and_vmcu303_advises(vww_byte):
    diags = lint_program(vww_byte.program, "cortex-m4",
                         deploy_bytes=200_000,
                         bottleneck_group="mb5",
                         partial_slices=7)
    by_code = {d.code: d for d in diags}
    assert "VMCU301" in by_code
    assert "fusion group 'mb5'" in by_code["VMCU301"].message
    assert "VMCU303" in by_code
    assert by_code["VMCU303"].severity == "warning"
    assert "est. 7 slice(s)" in by_code["VMCU303"].message
    assert "partial='auto'" in by_code["VMCU303"].message
    # no advisory without a slice estimate
    diags = lint_program(vww_byte.program, "cortex-m4",
                         deploy_bytes=200_000)
    assert "VMCU303" not in {d.code for d in diags}


# ---------------------------------------------------------------------------
# Compile pipeline: the partial="auto"|N knob.
# ---------------------------------------------------------------------------

def test_compile_rejects_bad_partial_values():
    with pytest.raises(ValueError, match="partial must be"):
        repro.compile("ds-cnn", "cortex-m4", dtype="int8",
                      quantize=False, partial="sideways")


def test_compile_partial_requires_unfused():
    with pytest.raises(repro.CompileError, match="unfused"):
        repro.compile("ds-cnn", "cortex-m4", dtype="float32",
                      fused_exec=True, partial="auto")


def test_compile_partial_not_needed_when_net_fits():
    cn = repro.compile("mcunet-5fps-vww", "cortex-m4", dtype="int8",
                       quantize=False, certify=False, partial="auto")
    note = next(p.note for p in cn.passes if p.name == "partial")
    assert "not needed" in note
    assert cn.partial is None
    rep = cn.report()
    assert rep["partial"] is None
    assert rep["byte_ring_bytes"] == rep["deploy_bytes"] > 0
    assert rep["fits_sram"] is True


def test_cli_partial_flag_rejects_garbage(capsys):
    from repro.cli import main as cli_main

    assert cli_main(["--partial", "sideways"]) == 2
    assert "--partial" in capsys.readouterr().err


@pytest.mark.slow
def test_compile_imagenet_partial_auto_artifact_roundtrip(tmp_path):
    # the acceptance case: the net that used to raise SRAMBudgetError
    with pytest.raises(repro.SRAMBudgetError, match="partial='auto'"):
        repro.compile("mcunet-320kb-imagenet", "cortex-m4", dtype="int8",
                      quantize=False, certify=False)
    cn = repro.compile("mcunet-320kb-imagenet", "cortex-m4", dtype="int8",
                       quantize=False, certify="static", partial="auto")
    rep = cn.report()
    assert rep["fits_sram"] is True
    assert rep["deploy_bytes"] <= M4.sram_bytes
    p = cn.mcu["partial"]
    assert p["total_slices"] >= 2
    assert p["ring_bytes_after"] <= M4.sram_bytes < p["ring_bytes_before"]
    # the acceptance bound: post-slice ring within 1.5x of the per-group
    # Eq.-(2) bottleneck
    assert p["ring_bytes_after"] / cn.mcu_bottleneck_bytes < 1.5
    assert cn.certificate["clobbers"] == 0
    note = next(q.note for q in cn.passes if q.name == "partial")
    assert "slices; ring" in note

    from repro.analysis import lint_artifact

    path = str(tmp_path / "sliced.json")
    cn.save(path)
    lrep = lint_artifact(path)
    assert lrep.clean and lrep.result.safe is True, \
        [str(d) for d in lrep.result.diagnostics]
    rt = repro.load(path)
    assert rt.partial == cn.partial
    assert rt.certificate == cn.certificate
    assert rt.report()["deploy_bytes"] == rep["deploy_bytes"]


# ---------------------------------------------------------------------------
# Numerics: sliced == unsliced (the conformance rows).
# ---------------------------------------------------------------------------

def _input_for(program, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((program.in_rows, program.in_dim),
                               dtype=np.float32)


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_sliced_fp32_matches_unsliced_resnet8(backend):
    # resnet-8 is the k x k chain case: slice halos must reproduce the
    # conv_k2d boundary rows exactly
    kw = dict(dtype="float32", fused_exec=False, certify=False,
              check_budget=False)
    u = repro.compile("resnet-8", "cortex-m4", **kw)
    s = repro.compile("resnet-8", "cortex-m4", partial=4, **kw)
    assert s.partial is not None
    assert s.partial["total_slices"] == 4
    x = _input_for(u.program)
    yu = np.asarray(u.run(x, backend=backend))
    ys = np.asarray(s.run(x, backend=backend))
    np.testing.assert_allclose(ys, yu, rtol=0, atol=0)  # bit-exact


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_sliced_int8_bitexact_vww(vww_byte, backend):
    # quantize ONCE, then share every op's qparams across its slices:
    # requant constants are identical, so execution stays bit-exact
    graph = _resolve_net("mcunet-5fps-vww")
    plan = plan_net(graph, dtype="int8", fused_exec=False)
    params = init_net_params(plan)
    q = _quantize_net(plan, params, n_calib=2)
    pp = plan_partial(vww_byte.program, _ranges(vww_byte), M4.sram_bytes,
                      force=4)
    sprog, spar = apply_partial(q.program, pp.choices)
    assert certify_net(sprog).peak_live > 0  # sim oracle: no clobbers
    sq = QuantizedNet(plan=q.plan, program=sprog,
                      params=[q.params[p] for p in spar],
                      qparams=[q.qparams[p] for p in spar],
                      act_scales=q.act_scales)
    x = _input_for(plan.program, seed=7)
    yu = np.asarray(run_net_quantized(q, x, backend=backend))
    ys = np.asarray(run_net_quantized(sq, x, backend=backend))
    assert np.array_equal(ys, yu)
