"""Ring telemetry tests (repro.obs): tracer, counters, timeline, spans.

The load-bearing invariants:

  * traced byte counts equal the static verifier certificate's
    reads/writes BIT-EXACTLY on every zoo net, fp32 and int8 — three
    independent derivations (closed form, schedule counters, measured
    SegmentPool counts) of one number,
  * the occupancy-timeline watermark equals the plan's ``pool_bytes``
    (the ring is tight), checked differentially per net,
  * ``trace=True`` changes nothing about the computed outputs, and
    ``trace=False`` never constructs a tracer (zero-cost path),
  * the trace artifact round-trips, diffs, exports to Chrome JSON, and
    its canonical form is pinned by a golden file.
"""
import json
import pathlib

import jax
import numpy as np
import pytest

import repro
from repro.compile.driver import compile as vcompile
from repro.core import (ConvDWSpec, ConvPWSpec, GemmSpec, execute,
                        plan_program)
from repro.graph.run import init_net_params, run_net
from repro.obs import (TRACE_SCHEMA, RingTracer, TraceArtifact, build_trace,
                       collect, diff_traces, op_counters, pool_timeline,
                       program_totals, set_attr, span)

GOLDEN = pathlib.Path(__file__).parent / "golden" / "mini.trace.json"

_ZOO = [("ds-cnn", "cortex-m4"), ("resnet-8", "cortex-m4"),
        ("mcunet-5fps-vww", "cortex-m4"),
        ("mobilenetv1-0.25", "cortex-m4"),
        ("mcunet-320kb-imagenet", "cortex-m7")]


def _trace_program():
    """The golden 3-op net: pw conv -> dw conv -> gemm head, one ring."""
    H, C = 4, 8
    return plan_program(H * H, C,
                        [ConvPWSpec(H, H, C, 16, activation="relu"),
                         ConvDWSpec(H, H, 16, rs=3, activation="relu"),
                         GemmSpec(4)],
                        block_rows=1)


def _sim_trace(program, **kw):
    tracer = RingTracer()
    execute(program, backend="sim", tracer=tracer)
    return build_trace(program, tracer=tracer, **kw)


def golden_trace_payload() -> dict:
    """What tests/golden/mini.trace.json pins (regen.py writes this)."""
    return _sim_trace(_trace_program(), net="mini").canonical()


# ---------------------------------------------------------------------------
# Golden + determinism.
# ---------------------------------------------------------------------------

def test_golden_trace_fresh():
    assert GOLDEN.exists(), "run: PYTHONPATH=src python tests/golden/regen.py"
    assert json.loads(GOLDEN.read_text()) == golden_trace_payload(), \
        "mini trace drifted — regen tests/golden if intentional"


def test_trace_deterministic_across_runs():
    prog = _trace_program()
    a = _sim_trace(prog, net="mini")
    b = _sim_trace(prog, net="mini")
    assert a.canonical() == b.canonical()
    # measured sim counts are part of the canonical form
    assert any("sim" in e for e in a.canonical()["events"])

    params = init_net_params(prog)
    x = jax.random.normal(jax.random.PRNGKey(3), (prog.m_rows, prog.in_dim))
    tr1, tr2 = RingTracer(), RingTracer()
    y1 = run_net(prog, x, params, backend="jnp", tracer=tr1)
    y2 = run_net(prog, x, params, backend="jnp", tracer=tr2)
    assert np.array_equal(np.asarray(y1), np.asarray(y2))
    assert build_trace(prog, tracer=tr1).canonical() == \
        build_trace(prog, tracer=tr2).canonical()


def test_traced_run_matches_untraced():
    prog = _trace_program()
    params = init_net_params(prog)
    x = jax.random.normal(jax.random.PRNGKey(5), (prog.m_rows, prog.in_dim))
    y_plain = np.asarray(run_net(prog, x, params, backend="jnp"))
    tracer = RingTracer()
    y_traced = np.asarray(run_net(prog, x, params, backend="jnp",
                                  tracer=tracer))
    # float path: per-op jit vs whole-program jit may fuse differently
    np.testing.assert_allclose(y_traced, y_plain, rtol=1e-5, atol=1e-5)
    assert len(tracer.wall_s) == len(prog.ops)
    assert all(v >= 0.0 for v in tracer.wall_s.values())


def test_traced_run_bit_identical_int8():
    cn = vcompile("ds-cnn", "cortex-m4", dtype="int8", quantize=True,
                  certify=False, n_calib=1)
    x = jax.random.normal(jax.random.PRNGKey(7),
                          (cn.program.in_rows, cn.program.in_dim))
    y_plain = np.asarray(cn.run(x))
    y_traced, art = cn.run(x, trace=True)
    # integer ring math: tracing must not move a single bit
    assert np.array_equal(np.asarray(y_traced), y_plain)
    assert isinstance(art, TraceArtifact)
    assert art.backend == "jnp" and art.net == "ds-cnn"
    assert art.totals["requants"] > 0


def test_batched_trace_counters_equal_certificate_times_batch():
    """Batched ``run(trace=True)`` returns ONE artifact whose traffic
    counters are the per-sample certificate scaled by exactly the
    batch size (wall times sum across lanes)."""
    from repro.analysis import verify_program

    cn = vcompile("ds-cnn", "cortex-m4", quantize=True, certify=False,
                  n_calib=1)
    batch = 3
    x = jax.random.normal(
        jax.random.PRNGKey(11),
        (batch, cn.program.in_rows, cn.program.in_dim))
    y1, art1 = cn.run(x[0], trace=True)
    yb, artb = cn.run(x, trace=True)
    assert yb.shape[0] == batch
    assert np.array_equal(np.asarray(yb[0]), np.asarray(y1))
    assert artb.totals["batch"] == batch

    cert = verify_program(cn.program).certificate()
    seg_bytes = cn.program.seg_width * cn.program.elem_bytes
    assert artb.totals["bytes_loaded"] == \
        batch * cert["reads"] * seg_bytes
    assert artb.totals["bytes_stored"] == \
        batch * cert["writes"] * seg_bytes
    for k in ("segs_read", "segs_written", "macs", "requants"):
        assert artb.totals[k] == batch * art1.totals[k], k
    for e1, eb in zip(art1.events, artb.events):
        for k in ("segs_read", "segs_written", "bytes_loaded",
                  "bytes_stored"):
            if k in e1:
                assert eb[k] == batch * e1[k], (e1["name"], k)
    assert artb.totals["wall_us"] > 0


# ---------------------------------------------------------------------------
# The bit-exact traffic invariant, per zoo net, fp32 + int8.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", ["float32", "int8"])
@pytest.mark.parametrize("net,target", _ZOO)
def test_traffic_equals_certificate(net, target, dtype):
    from repro.analysis import verify_program

    cn = vcompile(net, target, dtype=dtype, quantize=False, certify=False)
    prog = cn.program
    cert = verify_program(prog).certificate()
    tot = program_totals(prog)
    assert tot["segs_read"] == cert["reads"]
    assert tot["segs_written"] == cert["writes"]

    tracer = RingTracer()
    sim = execute(prog, backend="sim", tracer=tracer)
    assert sim.reads == cert["reads"] and sim.writes == cert["writes"]
    for c in op_counters(prog):   # per-op: measured == schedule-derived
        got = tracer.sim_counts[c.index]
        assert got["reads"] == c.segs_read, (net, dtype, c.index)
        assert got["writes"] == c.segs_written, (net, dtype, c.index)

    art = build_trace(prog, tracer=tracer, net=net)
    seg_bytes = prog.seg_width * prog.elem_bytes
    assert art.totals["bytes_loaded"] == cert["reads"] * seg_bytes
    assert art.totals["bytes_stored"] == cert["writes"] * seg_bytes


@pytest.mark.parametrize("net,target", _ZOO)
def test_watermark_equals_pool_bytes(net, target):
    """Differential: the timeline watermark must equal pool_bytes — a
    looser timeline (or looser plan) breaks one side of the equality."""
    cn = vcompile(net, target, quantize=False, certify=False)
    tl = pool_timeline(cn.program)
    assert tl.watermark_bytes == cn.program.pool_bytes
    assert tl.watermark_segments == cn.program.pool_segments
    assert max(tl.live_curve()) <= tl.watermark_segments
    # every tensor gets exactly one residency interval
    assert len(tl.residencies) == len(cn.program.ops) + 1
    assert all(r.died > r.born for r in tl.residencies)


def test_closed_form_traffic_cross_check():
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).parents[1]))
    from benchmarks.energy_proxy import net_traffic

    for net, target in _ZOO[:2]:
        cn = vcompile(net, target, quantize=False, certify=False)
        tot = program_totals(cn.program)
        cf = net_traffic(cn.program)
        assert cf["segs_read"] == tot["segs_read"], net
        assert cf["segs_written"] == tot["segs_written"], net


# ---------------------------------------------------------------------------
# Artifact surfaces: round-trip, schema, Chrome export, ASCII, diff.
# ---------------------------------------------------------------------------

def test_artifact_roundtrip_and_schema(tmp_path):
    art = _sim_trace(_trace_program(), net="mini")
    p = tmp_path / "mini.trace.json"
    art.save(str(p))
    back = TraceArtifact.load(str(p))
    assert back.to_dict() == art.to_dict()

    payload = json.loads(p.read_text())
    payload["schema"] = "vmcu-trace/999"
    p.write_text(json.dumps(payload))
    with pytest.raises(ValueError, match="schema"):
        TraceArtifact.load(str(p))


def test_chrome_trace_structure():
    art = _sim_trace(_trace_program(), net="mini")
    chrome = json.loads(json.dumps(art.to_chrome_trace()))
    evs = chrome["traceEvents"]
    xs = [e for e in evs if e["ph"] == "X"]
    # stage + 3 ops + fetch as complete events, monotone timebase
    assert len(xs) == len(art.events)
    assert all(e["dur"] > 0 and e["ts"] >= 0 for e in xs)
    assert [e["ts"] for e in xs] == sorted(e["ts"] for e in xs)
    assert any(e["ph"] == "C" and e["name"] == "pool_live_segments"
               for e in evs)
    assert any(e["ph"] == "M" for e in evs)


def test_ascii_timeline_watermark_line():
    art = _sim_trace(_trace_program(), net="mini")
    text = art.ascii_timeline(width=40)
    assert text.splitlines()[-1].startswith("watermark:")
    assert str(art.geometry["pool_bytes"]) in text.splitlines()[-1]
    # one row per op between the header and the watermark line
    assert len(text.splitlines()) == len(art.timeline["ops"]) + 2


def test_diff_traces():
    prog = _trace_program()
    a = _sim_trace(prog, net="mini")
    b = _sim_trace(prog, net="mini")
    d = diff_traces(a, b)
    assert d["structural"] == []
    b.events[1]["bytes_loaded"] += 1   # a mutated counter must surface
    d = diff_traces(a, b)
    assert any("bytes_loaded" in line for line in d["structural"])


# ---------------------------------------------------------------------------
# Compile-pipeline spans.
# ---------------------------------------------------------------------------

def test_compile_records_pass_spans():
    cn = vcompile("ds-cnn", "cortex-m4", quantize=False, certify="static")
    names = [s["name"] for s in cn.spans]
    # int8 target: the budget gate also solves the deployable byte ring
    assert names == ["build", "schedule", "plan", "byte_plan", "budget",
                     "lint", "certify"]
    sched = cn.spans[names.index("schedule")]
    assert sched["attrs"]["states_expanded"] >= 1
    assert all(s["seconds"] >= 0.0 for s in cn.spans)


def test_quantize_decomposed_into_subspans():
    cn = vcompile("ds-cnn", "cortex-m4", dtype="int8", quantize=True,
                  certify=False, n_calib=1)
    q = next(s for s in cn.spans if s["name"] == "quantize")
    child_names = [c["name"] for c in q["children"]]
    assert {"calibrate", "act_scales", "quantize_ops"} <= set(child_names)
    cal = next(c for c in q["children"] if c["name"] == "calibrate")
    assert cal["attrs"]["batches"] == 1
    # sub-spans nest inside (and so sum to less than) the quantize pass
    assert sum(c["seconds"] for c in q["children"]) <= q["seconds"]


def test_spans_survive_save_load(tmp_path):
    cn = vcompile("ds-cnn", "cortex-m4", dtype="int8", quantize=True,
                  certify="static", n_calib=1)
    p = tmp_path / "ds.plan.json"
    cn.save(str(p))
    back = repro.load(str(p))
    assert back.spans == cn.spans
    # a loaded artifact still profiles (sim path: no plan/graph needed)
    art = _sim_trace(back.program, net=back.net_name, spans=back.spans)
    assert [s["name"] for s in art.spans][:2] == ["build", "schedule"]


def test_span_noop_without_collector():
    with span("nothing", k=1) as s:
        assert s is None
    set_attr(ignored=True)   # must not raise

    with collect() as col:
        with span("outer", a=1):
            with span("inner"):
                set_attr(b=2)
    assert len(col.spans) == 1
    out = col.spans[0]
    assert out.name == "outer" and out.attrs == {"a": 1}
    assert out.children[0].name == "inner"
    assert out.children[0].attrs == {"b": 2}
    assert out.seconds >= out.children[0].seconds >= 0.0


def test_profile_returns_trace():
    cn = vcompile("ds-cnn", "cortex-m4", dtype="float32",
                  quantize=False, certify=False)
    art = cn.profile(backend="jnp")
    assert isinstance(art, TraceArtifact)
    assert art.backend == "jnp"
    assert "wall_us" in art.totals and art.totals["wall_us"] > 0
    assert art.watermark_bytes == cn.program.pool_bytes
    # planner-only int8 compiles profile through the sim oracle
    cn8 = vcompile("ds-cnn", "cortex-m4", dtype="int8", quantize=False,
                   certify=False)
    art8 = cn8.profile()
    assert art8.backend == "sim" and art8.totals["sim"]["reads"] > 0


# ---------------------------------------------------------------------------
# CLI.
# ---------------------------------------------------------------------------

def test_cli_render_save_diff(tmp_path, capsys, monkeypatch):
    from repro.obs.cli import main

    monkeypatch.chdir(tmp_path)
    t1, t2 = str(tmp_path / "a.trace.json"), str(tmp_path / "b.trace.json")
    assert main(["ds-cnn", "--save", t1]) == 0
    out = capsys.readouterr().out
    assert "watermark:" in out and "compile pipeline:" in out
    assert main([t1, "--chrome", str(tmp_path / "c.json")]) == 0
    chrome = json.loads((tmp_path / "c.json").read_text())
    assert any(e.get("ph") == "X" for e in chrome["traceEvents"])

    assert main(["ds-cnn", "--save", t2]) == 0
    capsys.readouterr()
    assert main(["--diff", t1, t2]) == 0   # same plan, same trace

    payload = json.loads(pathlib.Path(t2).read_text())
    payload["events"][1]["segs_read"] += 1
    pathlib.Path(t2).write_text(json.dumps(payload))
    assert main(["--diff", t1, t2]) == 1   # structural drift gates
    assert main([]) == 2                   # usage error
