"""Regenerate the codegen golden files.

Run after an intentional change to the emitted intrinsic skeletons or
the planner's solved offsets:

    PYTHONPATH=src python tests/golden/regen.py

Three golden sets:

  * ``*.c``       — the mini/fused/qmini unit-test programs
                    (tests/test_codegen.py),
  * ``vww/*.c``   — the whole MCUNet-5fps-VWW int8 deployment plan's
                    ring-geometry units (byte-typed pool header, target
                    idiom banner, no requant tables — fully determined
                    by the planner's solved integer offsets).  This is
                    what ``vmcu-compile --smoke`` diffs in CI.
  * ``mini.trace.json`` — the canonical (wall-time-stripped) telemetry
                    trace of the 3-op mini net (tests/test_trace.py):
                    per-op byte/MAC counters, measured sim access
                    counts and the occupancy timeline.
"""
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parents[1]))

from test_codegen import (_fused_program, _mini_net_program,  # noqa: E402
                          _quantized_program_and_qparams)
from test_trace import golden_trace_payload  # noqa: E402

from repro.core.codegen import emit_program  # noqa: E402


def _net_geometry_units(net: str, name: str) -> dict[str, str]:
    """Ring-geometry goldens of a registered net's int8 cortex-m4 plan
    (byte-typed pool header, target idiom banner, no requant tables —
    fully determined by the planner's solved integer offsets)."""
    import repro

    cn = repro.compile(net, target="cortex-m4",
                       quantize=False, certify=False)
    return cn.emit_c(geometry_only=True, name=name)


def _vww_geometry_units() -> dict[str, str]:
    """The CLI smoke-gate goldens: MCUNet-VWW's int8 deployment ring.

    Emitted through the SAME facade path ``vmcu-compile --smoke`` uses,
    so the cortex-m4 Target descriptor (geometry, dtype, idiom) stays
    the one definition site for both sides of the diff."""
    import repro

    return _net_geometry_units("mcunet-5fps-vww", "vww")


def _write(out: pathlib.Path, units: dict[str, str]) -> None:
    out.mkdir(parents=True, exist_ok=True)
    for stale in out.glob("*.c"):       # goldens no longer emitted must
        if stale.name not in units:     # not linger as if still covered
            stale.unlink()
            print("removed stale", stale)
    for name, src in units.items():
        (out / name).write_text(src)
        print("wrote", out / name)


def main() -> None:
    out = pathlib.Path(__file__).parent
    units = emit_program(_mini_net_program(), "mini")
    units.update(emit_program(_fused_program(), "fused"))
    qprog, qparams = _quantized_program_and_qparams()
    units.update(emit_program(qprog, "qmini", quant=qparams))
    _write(out, units)
    _write(out / "vww", _vww_geometry_units())
    # ResNet-8 (conv_k2d ops incl. the shortcut-projection branch):
    # pinned by tests/test_codegen.py and the CI freshness gate
    _write(out / "resnet8", _net_geometry_units("resnet-8", "resnet8"))
    trace = out / "mini.trace.json"
    trace.write_text(json.dumps(golden_trace_payload(), indent=1,
                                sort_keys=True) + "\n")
    print("wrote", trace)


if __name__ == "__main__":
    main()
