"""Regenerate the codegen golden files.

Run after an intentional change to the emitted intrinsic skeletons or
the planner's solved offsets:

    PYTHONPATH=src python tests/golden/regen.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parents[1]))

from test_codegen import (_fused_program, _mini_net_program,  # noqa: E402
                          _quantized_program_and_qparams)

from repro.core.codegen import emit_program  # noqa: E402


def main() -> None:
    out = pathlib.Path(__file__).parent
    units = emit_program(_mini_net_program(), "mini")
    units.update(emit_program(_fused_program(), "fused"))
    qprog, qparams = _quantized_program_and_qparams()
    units.update(emit_program(qprog, "qmini", quant=qparams))
    for stale in out.glob("*.c"):       # goldens no longer emitted must
        if stale.name not in units:     # not linger as if still covered
            stale.unlink()
            print("removed stale", stale)
    for name, src in units.items():
        (out / name).write_text(src)
        print("wrote", out / name)


if __name__ == "__main__":
    main()
