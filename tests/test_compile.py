"""The one-call deployment API: target registry, pass pipeline,
CompiledNet surface, budget gating, and the deprecation shims over the
legacy plan_net/quantize_net entry points."""
import pathlib

import jax
import numpy as np
import pytest

import repro
from repro.compile.targets import Target, get_target, register_target
from repro.core.graph_planner import MCUNET_5FPS_VWW
from repro.graph import build_mcunet, plan_net, quantize_net
from repro.graph.ir import Graph
from repro.graph.netplan import _plan_net
from repro.graph.run import (_quantize_net, init_net_params,
                             reference_forward)

GOLDEN_VWW = pathlib.Path(__file__).parent / "golden" / "vww"


def _s7_graph() -> Graph:
    """One unfused residual module — the small compile fixture."""
    return build_mcunet(MCUNET_5FPS_VWW[6:7], "s7", include_head=False)


# ---------------------------------------------------------------------------
# Target registry.
# ---------------------------------------------------------------------------

def test_registry_ships_the_stock_targets():
    assert {"cortex-m4", "cortex-m7", "host-sim"} <= set(
        repro.list_targets())
    m4 = get_target("cortex-m4")
    assert m4.requant_idiom == "smlad" and m4.default_dtype == "int8"
    assert get_target("cortex-m55").requant_idiom == "mve"
    assert get_target("host-sim").default_dtype == "float32"
    # descriptors pass through unchanged
    assert get_target(m4) is m4


def test_unknown_target_and_idiom_rejected():
    with pytest.raises(ValueError, match="unknown target"):
        get_target("cortex-m999")
    with pytest.raises(ValueError, match="idiom"):
        Target(name="x", cpu="x", sram_bytes=1, flash_bytes=1,
               requant_idiom="avx512")


def test_register_custom_target():
    t = Target(name="test-board", cpu="test", sram_bytes=64_000,
               flash_bytes=256_000)
    register_target(t, "tb", overwrite=True)
    assert get_target("tb") is t
    with pytest.raises(ValueError, match="already registered"):
        register_target(t)


def test_target_knobs_are_the_single_definition_site():
    m4 = get_target("cortex-m4")
    assert m4.plan_kwargs == {"seg_width": 128, "block_rows": 1}
    assert m4.byte_ring_kwargs == {"seg_width": 1, "block_rows": None}
    assert m4.fits_sram(128_000) and not m4.fits_sram(128_001)


# ---------------------------------------------------------------------------
# The pipeline.
# ---------------------------------------------------------------------------

def test_float_compile_equals_manual_plan():
    g = _s7_graph()
    cn = repro.compile(g, target="host-sim")
    manual = _plan_net(g)
    assert cn.program == manual.program
    assert cn.mcu_bottleneck_bytes == manual.mcu_bottleneck_bytes
    assert [p.name for p in cn.passes] == ["build", "schedule", "plan",
                                           "budget", "lint", "certify"]


def test_int8_compile_runs_all_passes():
    cn = repro.compile(_s7_graph(), target="cortex-m4")
    assert cn.quantized and cn.dtype == "int8"
    assert [p.name for p in cn.passes] == ["build", "schedule", "plan",
                                           "budget", "quantize", "lint",
                                           "certify"]
    assert cn.certificate["clobbers"] == 0
    assert cn.program.quantized  # executed program is the int8-typed one


def test_compile_run_matches_reference():
    cn = repro.compile(_s7_graph(), target="host-sim")
    x = jax.random.normal(jax.random.PRNGKey(2),
                          (cn.program.in_rows, cn.program.in_dim))
    y = np.asarray(cn.run(x))
    ref = np.asarray(reference_forward(cn.program, x, cn.ensure_params()))
    np.testing.assert_allclose(y, ref, atol=1e-4)


def test_planner_only_int8_run_raises_clearly():
    cn = repro.compile(_s7_graph(), target="cortex-m4", quantize=False,
                       certify=False)
    assert cn.qnet is None and cn.program.quantized
    x = jax.numpy.zeros((cn.program.in_rows, cn.program.in_dim))
    with pytest.raises(repro.CompileError, match="quantize=True"):
        cn.run(x)
    with pytest.raises(repro.CompileError, match="geometry_only"):
        cn.emit_c()
    assert len(cn.emit_c(geometry_only=True)) == len(cn.program.ops)


def test_planner_only_compile_never_materializes_params():
    """Benchmark-grade compiles stay planner-fast: no init_net_params
    until .run()/.save() actually needs parameters."""
    cn = repro.compile(_s7_graph(), target="host-sim", certify=False)
    assert cn.params is None
    analytic = cn.report()["flash_bytes_used"]   # analytic, no init
    assert analytic > 0 and cn.params is None
    cn.ensure_params()
    assert cn.params is not None
    assert cn.flash_bytes_used == analytic       # exact == analytic


def test_compile_by_registered_name_and_errors():
    cn = repro.compile("mcunet-vww", target="host-sim", certify=False)
    assert cn.net_name == "mcunet-5fps-vww"
    assert "mcunet-5fps-vww" in repro.available_nets()
    with pytest.raises(ValueError, match="unknown net"):
        repro.compile("mcunet-nope", target="host-sim")
    with pytest.raises(TypeError, match="Graph or a registered name"):
        repro.compile(42, target="host-sim")
    with pytest.raises(repro.CompileError, match="unfused"):
        repro.compile(_s7_graph(), target="cortex-m4", fused_exec=True)


def test_sram_budget_gate():
    tiny = Target(name="tiny-board", cpu="t", sram_bytes=1_000,
                  flash_bytes=1_000_000)
    with pytest.raises(repro.SRAMBudgetError, match="OVER|over by"):
        repro.compile(_s7_graph(), target=tiny, quantize=False,
                      certify=False)
    # check_budget=False records the verdict without raising
    cn = repro.compile(_s7_graph(), target=tiny, quantize=False,
                       certify=False, check_budget=False)
    rep = cn.report()
    assert rep["fits_sram"] is False and rep["sram_margin_bytes"] < 0


def test_report_accounts_against_the_target():
    cn = repro.compile(_s7_graph(), target="cortex-m4")
    rep = cn.report()
    for key in ("net", "target", "dtype", "n_ops", "pool_bytes",
                "mcu_bottleneck_bytes", "sram_margin_bytes", "fits_sram",
                "flash_bytes_used", "certificate", "passes"):
        assert key in rep, key
    assert rep["dtype"] == "int8"
    assert rep["sram_bytes"] == 128_000
    assert rep["flash_bytes_used"] > 0
    assert rep["pool_bytes"] == cn.program.pool_bytes


def test_emit_c_bakes_target_idiom_banner():
    cn = repro.compile(_s7_graph(), target="cortex-m4")
    units = cn.emit_c()
    assert all(src.startswith("// target idiom: __SMLAD")
               for src in units.values())
    assert any("_requant" in src for src in units.values())
    mve = cn.emit_c(idiom="mve")
    assert all("VMLADAVA.S8" in src.splitlines()[0]
               for src in mve.values())
    geom = cn.emit_c(geometry_only=True)
    assert all("_mult[" not in src for src in geom.values())


def test_vww_geometry_emission_matches_cli_goldens():
    """The tier-1 pin of the ``vmcu-compile --smoke`` golden gate: the
    compiled VWW deployment plan's ring-geometry units are byte-stable."""
    cn = repro.compile("mcunet-5fps-vww", target="cortex-m4",
                       quantize=False, certify=False)
    units = cn.emit_c(geometry_only=True, name="vww")
    assert len(units) == len(list(GOLDEN_VWW.glob("*.c")))
    for name, src in units.items():
        golden = GOLDEN_VWW / name
        assert golden.exists(), f"missing golden {name}; regenerate with " \
            "tests/golden/regen.py"
        assert src == golden.read_text(), f"{name} drifted from golden"


# ---------------------------------------------------------------------------
# Deprecation shims (direct legacy entry keeps working, with a warning).
# ---------------------------------------------------------------------------

def test_plan_net_shim_warns_and_matches_internal():
    g = _s7_graph()
    with pytest.warns(DeprecationWarning, match="repro.compile"):
        via_shim = plan_net(g, fused_exec=False, dtype="int8")
    direct = _plan_net(g, fused_exec=False, dtype="int8")
    assert via_shim.program == direct.program
    assert via_shim.mcu_bottleneck_bytes == direct.mcu_bottleneck_bytes


def test_quantize_net_shim_warns_and_matches_internal():
    plan = _plan_net(_s7_graph(), fused_exec=False, dtype="int8")
    params = init_net_params(plan)
    with pytest.warns(DeprecationWarning, match="repro.compile"):
        via_shim = quantize_net(plan, params)
    direct = _quantize_net(plan, params)
    assert via_shim.act_scales == direct.act_scales
    assert via_shim.program == direct.program
    for a, b in zip(via_shim.qparams, direct.qparams):
        for xa, xb in zip(a, b):
            np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


# ---------------------------------------------------------------------------
# CLI.
# ---------------------------------------------------------------------------

def test_cli_smoke_gate_passes():
    from repro.cli import main

    # no --target/--dtype: --smoke pins the int8 cortex-m4 configuration
    rc = main(["--smoke", "--golden-dir", str(GOLDEN_VWW)])
    assert rc == 0


def test_cli_list_targets():
    from repro.cli import main

    assert main(["--list-targets"]) == 0
    assert main(["--list-nets"]) == 0
