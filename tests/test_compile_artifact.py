"""Plan artifacts: the codec is bit-exact, and save()/load() reproduces
identical pool_bytes, identical emitted C and bit-identical int8
execution on both MCUNet nets — without re-running the scheduler.

Also the acceptance equivalence: ``repro.compile(net, target, int8)``
is byte-identical to the manual ``plan_net + quantize_net +
emit_program`` wiring it replaced.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.compile import artifact
from repro.core.codegen import emit_program
from repro.core.graph_planner import (MCUNET_5FPS_VWW,
                                      MCUNET_320KB_IMAGENET)
from repro.core.program import PoolProgram
from repro.graph import build_mcunet
from repro.graph.netplan import _plan_net
from repro.graph.run import (_quantize_net, init_net_params,
                             run_net_quantized)


# ---------------------------------------------------------------------------
# Codec.
# ---------------------------------------------------------------------------

def test_codec_roundtrips_arrays_bit_exactly():
    entries = [
        (jnp.arange(-7, 5, dtype=jnp.int8).reshape(3, 4),
         jnp.asarray([1 << 30, -5], jnp.int32)),
        (jax.random.normal(jax.random.PRNGKey(0), (4, 3)), None),
        None,
        ((1 << 30) + 7, -1, (1 << 30) + 11, -2),
    ]
    back = artifact.decode(artifact.encode(entries))
    assert isinstance(back, list) and isinstance(back[0], tuple)
    assert back[2] is None and back[3] == entries[3]
    np.testing.assert_array_equal(np.asarray(back[0][0]),
                                  np.asarray(entries[0][0]))
    assert np.asarray(back[1][0]).tobytes() \
        == np.asarray(entries[1][0]).tobytes()  # bit-exact floats


def test_codec_roundtrips_bfloat16():
    x = jax.random.normal(jax.random.PRNGKey(1), (5,)).astype(jnp.bfloat16)
    y = artifact.decode(artifact.encode(x))
    assert y.dtype == jnp.bfloat16
    assert np.asarray(y).tobytes() == np.asarray(x).tobytes()


def test_program_json_roundtrip():
    prog = _plan_net(build_mcunet(MCUNET_5FPS_VWW[6:7], "s7",
                                  include_head=False),
                     fused_exec=False, dtype="int8").program
    back = PoolProgram.from_json_dict(prog.to_json_dict())
    assert back == prog


def test_artifact_rejects_foreign_payloads(tmp_path):
    import json

    p = tmp_path / "x.json"
    p.write_text(json.dumps({"kind": "something-else", "schema": 1}))
    with pytest.raises(ValueError, match="not a vmcu"):
        artifact.load(str(p))
    p.write_text(json.dumps({"kind": artifact.KIND, "schema": 99}))
    with pytest.raises(ValueError, match="schema"):
        artifact.load(str(p))


# ---------------------------------------------------------------------------
# Whole-net acceptance: facade == manual wiring, and save/load == facade.
# ---------------------------------------------------------------------------

NETS = (("mcunet-5fps-vww", MCUNET_5FPS_VWW, 2, "cortex-m4"),
        ("mcunet-320kb-imagenet", MCUNET_320KB_IMAGENET, 1000,
         "cortex-m7"))


def _roundtrip_net(tmp_path, name, modules, classes, target):
    # the facade (certify elsewhere; this test pins artifacts + parity)
    cn = repro.compile(name, target=target, dtype="int8", certify=False)

    # the manual wiring it replaced
    g = build_mcunet(modules, name, num_classes=classes)
    plan = _plan_net(g, fused_exec=False, dtype="int8")
    params = init_net_params(plan)
    qnet = _quantize_net(plan, params)

    x = jax.random.normal(jax.random.PRNGKey(5),
                          (plan.program.in_rows, plan.program.in_dim))

    # byte-identical pool accounting + golden C + int8 execution
    assert cn.pool_bytes == qnet.pool_bytes
    assert cn.program == qnet.program
    idiom = cn.target.requant_idiom
    manual_units = emit_program(qnet.program, name, quant=qnet.qparams,
                                idiom=idiom)
    assert cn.emit_c() == manual_units
    y_facade = np.asarray(cn.run(x))
    y_manual = np.asarray(run_net_quantized(qnet, x))
    np.testing.assert_array_equal(y_facade, y_manual)

    # save -> load -> run: identical without re-solving the schedule
    path = cn.save(str(tmp_path / f"{name}.plan.json"))
    loaded = repro.load(path)
    assert loaded.plan is None          # nothing to re-solve with
    assert loaded.pool_bytes == cn.pool_bytes
    assert loaded.program == cn.program
    assert loaded.mcu == cn.mcu
    assert loaded.emit_c() == manual_units
    np.testing.assert_array_equal(np.asarray(loaded.run(x)), y_facade)
    assert loaded.report()["fits_sram"] == cn.report()["fits_sram"]


def test_vww_artifact_roundtrip_and_manual_parity(tmp_path):
    _roundtrip_net(tmp_path, *NETS[0])


def test_imagenet_artifact_roundtrip_and_manual_parity(tmp_path):
    _roundtrip_net(tmp_path, *NETS[1])


def test_float_artifact_roundtrip(tmp_path):
    cn = repro.compile(build_mcunet(MCUNET_5FPS_VWW[6:7], "s7",
                                    include_head=False),
                       target="host-sim", certify=False)
    x = jax.random.normal(jax.random.PRNGKey(6),
                          (cn.program.in_rows, cn.program.in_dim))
    y = np.asarray(cn.run(x))
    loaded = repro.load(cn.save(str(tmp_path / "s7.plan.json")))
    assert not loaded.quantized
    assert loaded.program == cn.program
    np.testing.assert_array_equal(np.asarray(loaded.run(x)), y)
