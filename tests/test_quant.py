"""Int8 quantization primitives: fixed-point requant exactness
(hypothesis property vs the exact Fraction reference), multiplier
encoding, calibration/quantize round trips."""
import numpy as np
import pytest

from repro.quant import (QParams, SHIFT_MAX, SHIFT_MIN, calibrate,
                         dequantize, quantize, quantize_bias,
                         quantize_multiplier, requant_pair, requantize,
                         requantize_i32)

INT32_MIN, INT32_MAX = -(1 << 31), (1 << 31) - 1


def _ref_requant(acc: int, mult: int, shift: int) -> int:
    """Exact reference: round-half-even of ``acc * mult * 2**(shift-31)``
    (``round()`` on Fraction is banker's rounding), saturated to int8."""
    from fractions import Fraction

    q = round(Fraction(acc * mult, 1 << (31 - shift)))
    return max(-128, min(127, q))


# ---------------------------------------------------------------------------
# Fixed cases: int32 edges and exact ties.
# ---------------------------------------------------------------------------

EDGE_ACCS = [INT32_MIN, INT32_MAX, 0, 1, -1, 127, -128, 255, -255,
             1 << 30, -(1 << 30)]


@pytest.mark.parametrize("mult,shift", [
    (1 << 30, 0),            # exact x0.5: odd accs are ties
    ((1 << 31) - 1, 0),
    (1 << 30, SHIFT_MAX),    # extreme left shift
    (1 << 30, SHIFT_MIN),    # extreme right shift
    (-(1 << 31), 5),         # most negative multiplier
    (3, -7),
])
def test_requantize_int32_edges(mult, shift):
    accs = np.array(EDGE_ACCS, np.int32)
    got = np.asarray(requantize(accs, mult, shift))
    want = np.array([_ref_requant(int(a), mult, shift) for a in EDGE_ACCS],
                    np.int8)
    np.testing.assert_array_equal(got, want)


def test_requantize_ties_round_to_even():
    # acc * 2^30 / 2^31 = acc/2: every odd acc is an exact tie
    accs = np.array([1, 3, 5, -1, -3, -5, 7, -7], np.int32)
    got = np.asarray(requantize(accs, 1 << 30, 0))
    np.testing.assert_array_equal(got, [0, 2, 2, 0, -2, -2, 4, -4])


def test_requantize_saturates():
    assert requantize(np.int32(INT32_MAX), INT32_MAX, SHIFT_MAX) == 127
    assert requantize(np.int32(INT32_MIN), INT32_MAX, SHIFT_MAX) == -128


def test_requantize_per_channel_broadcast():
    acc = np.arange(-6, 6, dtype=np.int32).reshape(4, 3) * 1000
    mult = np.array([1 << 30, 1 << 29, (1 << 31) - 1], np.int32)
    shift = np.array([0, 3, -4], np.int32)
    got = np.asarray(requantize(acc, mult[None, :], shift[None, :]))
    for r in range(4):
        for c in range(3):
            assert got[r, c] == _ref_requant(int(acc[r, c]), int(mult[c]),
                                             int(shift[c]))


# ---------------------------------------------------------------------------
# Hypothesis property: exactness over random multipliers/shifts/edges.
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def test_requantize_random_sweep_matches_float_reference():
    """Deterministic fallback sweep (hypothesis covers this ground much
    more densely when installed): random (acc, mult, shift) triples plus
    the int32 edges, bit-exact against the Fraction reference."""
    rng = np.random.default_rng(0)
    accs = np.concatenate([
        rng.integers(INT32_MIN, INT32_MAX + 1, 500),
        np.array(EDGE_ACCS, np.int64),
        rng.integers(-512, 512, 200),
    ]).astype(np.int32)
    for _ in range(20):
        mult = int(rng.integers(INT32_MIN, INT32_MAX + 1))
        shift = int(rng.integers(SHIFT_MIN, SHIFT_MAX + 1))
        got = np.asarray(requantize(accs, mult, shift))
        want = np.array([_ref_requant(int(a), mult, shift) for a in accs],
                        np.int8)
        np.testing.assert_array_equal(got, want, err_msg=f"mult={mult} "
                                      f"shift={shift}")


if HAVE_HYPOTHESIS:
    acc_st = st.one_of(
        st.integers(INT32_MIN, INT32_MAX),
        st.sampled_from(EDGE_ACCS),
        # dense tie region: small accs hit exact .5 cases often
        st.integers(-512, 512),
    )

    @given(acc=acc_st, mult=st.integers(INT32_MIN, INT32_MAX),
           shift=st.integers(SHIFT_MIN, SHIFT_MAX))
    @settings(max_examples=300, deadline=None)
    def test_requantize_matches_float_reference(acc, mult, shift):
        """The single-rounding fixed-point path equals
        round-to-nearest-even of the REAL product for every int32
        accumulator."""
        got = int(np.asarray(requantize(np.int32(acc), mult, shift)))
        assert got == _ref_requant(acc, mult, shift)

    @given(acc=acc_st, mult=st.integers(1, INT32_MAX),
           shift=st.integers(SHIFT_MIN, SHIFT_MAX))
    @settings(max_examples=100, deadline=None)
    def test_requantize_i32_matches_unsaturated_reference(acc, mult,
                                                          shift):
        from fractions import Fraction

        got = int(np.asarray(requantize_i32(np.int32(acc), mult, shift)))
        want = round(Fraction(acc * mult, 1 << (31 - shift)))
        assert got == max(-(1 << 24), min(1 << 24, want))

    @given(real=st.floats(2.0 ** -30, 2.0 ** 30, allow_nan=False,
                          allow_infinity=False))
    @settings(max_examples=200, deadline=None)
    def test_quantize_multiplier_encoding(real):
        m, shift = quantize_multiplier(real)
        assert (1 << 30) <= m < (1 << 31)
        assert SHIFT_MIN <= shift <= SHIFT_MAX
        # the Q31 encoding is within half an ulp of the real multiplier
        assert abs(m * 2.0 ** (shift - 31) - real) <= 2.0 ** (shift - 31)


def test_quantize_multiplier_rejects_bad_scales():
    assert quantize_multiplier(0.0) == (0, 0)
    with pytest.raises(ValueError):
        quantize_multiplier(-1.0)
    with pytest.raises(ValueError):
        quantize_multiplier(2.0 ** 40)


# ---------------------------------------------------------------------------
# Calibration / quantize round trips.
# ---------------------------------------------------------------------------

def test_per_tensor_roundtrip():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(7, 13)).astype(np.float32)
    qp = calibrate(x)
    assert not qp.per_channel and qp.zero_point == 0
    q = np.asarray(quantize(x, qp))
    assert q.dtype == np.int8 and q.min() >= -127 and q.max() <= 127
    err = np.abs(np.asarray(dequantize(q, qp)) - x)
    assert err.max() <= qp.scale / 2 + 1e-9


def test_per_channel_scales_one_per_output_channel():
    rng = np.random.default_rng(1)
    w = rng.normal(size=(5, 4)).astype(np.float32) * \
        np.array([1.0, 10.0, 0.1, 100.0], np.float32)
    qp = calibrate(w, axis=1)
    assert np.asarray(qp.scale).shape == (4,)
    q = np.asarray(quantize(w, qp))
    # every channel uses its full int8 range despite 1000x scale spread
    assert (np.abs(q).max(axis=0) == 127).all()


def test_all_zero_channel_gets_floor_scale():
    w = np.zeros((3, 2), np.float32)
    qp = calibrate(w, axis=1)
    assert (np.asarray(qp.scale) > 0).all()
    assert np.asarray(quantize(w, qp)).max() == 0


def test_quantize_bias_uses_accumulator_scale():
    w_qp = QParams(scale=np.array([0.5, 0.25]), axis=1)
    b = np.array([1.0, 1.0])
    bq = np.asarray(quantize_bias(b, 0.1, w_qp))
    np.testing.assert_array_equal(bq, [20, 40])   # 1/(0.5*0.1), 1/(0.25*0.1)


def test_requant_pair_encodes_scale_ratio():
    w_qp = QParams(scale=np.array([0.02, 0.004]), axis=1)
    mult, shift = requant_pair(0.05, w_qp, 0.01)
    real = np.asarray(mult, np.float64) * 2.0 ** (np.asarray(shift) - 31)
    np.testing.assert_allclose(real, [0.1, 0.02], rtol=1e-9)
