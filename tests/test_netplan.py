"""Global network planner: one ring, chained offsets, baseline report."""
import pytest

from repro.core import PoolClobberError, concat_programs, execute, \
    plan_program, GemmSpec
from repro.core.graph_planner import (MCUNET_5FPS_VWW,
                                      MCUNET_320KB_IMAGENET,
                                      tinyengine_module_bytes,
                                      vmcu_module_bytes)
from repro.graph import build_mcunet, build_mlp_tower, certify_net
from repro.graph.netplan import _plan_net as plan_net


def test_vww_whole_network_bottleneck_reproduces_paper_reduction():
    """Acceptance: >= the paper's 61.5% bottleneck reduction vs
    TinyEngine, computed from the NetPlan (not the closed forms)."""
    plan = plan_net(build_mcunet(MCUNET_5FPS_VWW, "vww", num_classes=2))
    assert plan.reduction_vs_tinyengine >= 0.615
    # cross-check against the legacy per-module byte formulas
    assert plan.mcu_bottleneck_bytes == max(
        vmcu_module_bytes(c) for c in MCUNET_5FPS_VWW)
    assert plan.tinyengine_bottleneck_bytes == max(
        tinyengine_module_bytes(c) for c in MCUNET_5FPS_VWW)
    assert plan.deployable(128_000)


def test_imagenet_whole_network_bottleneck():
    plan = plan_net(build_mcunet(MCUNET_320KB_IMAGENET, "imagenet",
                                 num_classes=1000))
    assert plan.reduction_vs_tinyengine >= 0.58   # paper: 58.6%
    assert plan.mcu_bottleneck_bytes == max(
        vmcu_module_bytes(c) for c in MCUNET_320KB_IMAGENET)
    # the paper's deployment story: vMCU fits a 128 KB device on the
    # whole-network bottleneck, TinyEngine (247.8 KB) does not
    assert plan.deployable(128_000)
    assert plan.tinyengine_bottleneck_bytes > 128_000


def test_cross_group_chaining_shares_one_ring():
    """Consecutive groups overlap in ONE pool: the merged ring is the
    max single-group span, far below the sum of per-group pools."""
    plan = plan_net(build_mcunet(MCUNET_5FPS_VWW, "vww"))
    prog = plan.program
    assert len(plan.groups) > 10
    # group boundaries chain: next group's first op reads where the
    # previous group's last op wrote
    for a, b in zip(plan.groups[:-1], plan.groups[1:]):
        assert prog.ops[b.op_lo].in_ptr == prog.ops[a.op_hi - 1].out_ptr
    # byte-granular offsets chain the same way
    for a, b in zip(plan.groups[:-1], plan.groups[1:]):
        assert b.mcu_in_off == a.mcu_out_off
    # one ring, not a sum of rings
    per_group_spans = [
        max(prog.ops[i].span_segments for i in range(g.op_lo, g.op_hi))
        for g in plan.groups]
    assert prog.pool_segments == max(per_group_spans)
    assert prog.pool_segments < sum(per_group_spans)


def test_netplan_tight_geometry_is_exact():
    """delta_slack=1 on the tight whole-net plan must clobber in the
    oracle — the cross-layer chaining has zero slack."""
    g = build_mcunet(MCUNET_5FPS_VWW[:3], "vww3", include_head=False)
    safe = plan_net(g, block_rows=None)
    certify_net(safe)   # must not raise
    tight = plan_net(g, block_rows=None, delta_slack=1)
    with pytest.raises(PoolClobberError):
        certify_net(tight)


def test_netplan_aligned_geometry_checks():
    plan = plan_net(build_mcunet(MCUNET_5FPS_VWW, "vww"))
    plan.program.check_alignment()
    assert plan.program.executable
    # tight footprint never exceeds the aligned allocation
    assert plan.program.pool_segments <= plan.program.n_segments


def test_mlp_tower_plans_for_every_config():
    from repro.configs import ALL_ARCHS, get_config
    for name in ALL_ARCHS:
        cfg = get_config(name)
        plan = plan_net(build_mlp_tower(cfg, m_rows=4, n_layers=2),
                        block_rows=None)
        assert plan.program.executable
        # in-place MLP chain: the ring is exactly the resident rows
        from repro.core.vpool import segments_for
        assert plan.program.pool_segments == 4 * segments_for(cfg.d_model)


def test_concat_programs_chains_pointers():
    a = plan_program(8, 64, [GemmSpec(96), GemmSpec(32)], seg_width=16,
                     block_rows=None)
    b = plan_program(8, 32, [GemmSpec(64)], seg_width=16, block_rows=None)
    merged = concat_programs([a, b])
    assert len(merged.ops) == 3
    assert merged.ops[2].in_ptr == merged.ops[1].out_ptr
    assert merged.pool_segments == max(a.pool_segments, b.pool_segments)
    execute(merged, backend="sim")   # chained offsets are clobber-free


def test_concat_programs_rejects_shape_mismatch():
    a = plan_program(8, 64, [GemmSpec(96)], seg_width=16)
    b = plan_program(8, 32, [GemmSpec(64)], seg_width=16)
    with pytest.raises(ValueError, match="boundary mismatch"):
        concat_programs([a, b])
