"""Eq. (2) multi-layer plans vs the paper's published anchors."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.graph_planner import (MCUNET_5FPS_VWW, MCUNET_320KB_IMAGENET,
                                      ModuleConfig, hmcos_module_bytes,
                                      plan_fc_chain,
                                      plan_inverted_bottleneck,
                                      plan_module_fallback,
                                      solve_stream_offset,
                                      tinyengine_module_bytes,
                                      vmcu_module_bytes)


def test_tinyengine_b2_anchor():
    """Paper §7.3 quotes TinyEngine's ImageNet bottleneck as 247.8 KB (B2).
    Our tensor-level model reproduces it to the byte (KB = 1000 B)."""
    b2 = MCUNET_320KB_IMAGENET[1]
    assert tinyengine_module_bytes(b2) == 247_808


def test_vmcu_beats_baselines_everywhere():
    """Fused where it wins, per-layer fallback otherwise (paper's own
    rule for modules where the DW kernel exceeds the image)."""
    for cfg in MCUNET_5FPS_VWW + MCUNET_320KB_IMAGENET:
        v = vmcu_module_bytes(cfg)
        assert v < tinyengine_module_bytes(cfg), cfg.name
        assert v < hmcos_module_bytes(cfg), cfg.name


def test_fallback_engages_only_on_tiny_spatial_dims():
    fused_losers = [c.name for c in MCUNET_5FPS_VWW + MCUNET_320KB_IMAGENET
                    if plan_module_fallback(c)
                    < plan_inverted_bottleneck(c).pool_bytes]
    # S7/S8 (3x3 images) and B16 (7x7 kernel on 6x6) — the paper's cases
    assert set(fused_losers) <= {"S7", "S8", "B16"}


def test_network_bottleneck_reduction_vww():
    """Paper: vMCU reduces the VWW memory bottleneck by 61.5% vs TinyEngine.
    Our analytic lower-bound plan must reduce it by at least that much."""
    te = max(tinyengine_module_bytes(c) for c in MCUNET_5FPS_VWW)
    v = max(vmcu_module_bytes(c) for c in MCUNET_5FPS_VWW)
    assert 1 - v / te >= 0.615


def test_imagenet_fits_128kb_device():
    """Paper: vMCU deploys MCUNet-320KB-ImageNet on a 128 KB MCU (B1
    bottleneck 102.7 KB measured; our plan is a lower bound of that)."""
    worst = max(vmcu_module_bytes(c) for c in MCUNET_320KB_IMAGENET)
    assert worst <= 102_700
    # ... while TinyEngine (247.8 KB) and HMCOS cannot fit
    assert max(tinyengine_module_bytes(c)
               for c in MCUNET_320KB_IMAGENET) > 128_000


def test_workspace_is_paper_11_segments():
    s1 = MCUNET_5FPS_VWW[0]
    plan = plan_inverted_bottleneck(s1, workspace="paper_11seg")
    # 3x3 B segments (c_mid each) + 1 C (c_mid) + 1 D (c_out)
    assert plan.workspace_bytes == (9 * s1.c_mid + s1.c_mid + s1.c_out)


@given(st.integers(4, 40), st.integers(1, 32), st.integers(8, 64),
       st.integers(1, 32), st.sampled_from([1, 2]))
@settings(max_examples=30, deadline=None)
def test_fused_plan_never_worse_than_tensor_level(hw, cin, cmid, cout, s1):
    cfg = ModuleConfig("x", hw, cin, cmid, cout, 3, (s1, 1, 1))
    v = vmcu_module_bytes(cfg)
    assert v <= tinyengine_module_bytes(cfg)


def test_stream_offset_monotone_writes():
    # writes strictly behind reads -> zero offset
    we = np.arange(1, 11) * 4
    rs = np.arange(10) * 8
    assert solve_stream_offset(we, rs) == 0


def test_fc_chain_is_inplace_when_dims_equal():
    """Transformer MLP (d -> f -> d): Eq. 2 says zero extra segments —
    the fused kernel runs in place (paper §5.2's >50% case)."""
    plan = plan_fc_chain(64, [256, 1024, 256], elem_bytes=2)
    assert plan.delta_bytes == 0
    naive_two_layers = 64 * (256 + 1024) * 2
    assert plan.pool_bytes < naive_two_layers


@given(st.integers(2, 64), st.integers(1, 8), st.integers(1, 8))
@settings(max_examples=30, deadline=None)
def test_fc_chain_delta_matches_row_growth(m, din, dout):
    plan = plan_fc_chain(m, [din * 16, dout * 16], elem_bytes=1)
    # growth rate (dout-din) per row bounds the offset
    assert plan.delta_bytes >= 0
    assert plan.pool_bytes <= (m * max(din, dout) * 16
                               + min(din, dout) * 16 * m)
