"""MLPerf-Tiny model-zoo acceptance: DS-CNN, ResNet-8 and
MobileNetV1-0.25 compile through ``repro.compile(net, "cortex-m4")``
and run end-to-end on every backend in fp32 AND int8 — sim certifies
zero clobbers, jnp/pallas match the plain-XLA reference (int8
bitwise across backends)."""
import jax
import numpy as np
import pytest

import jax.numpy as jnp

import repro
from repro.core.executors import run_program
from repro.graph import (build_ad_autoencoder, build_ds_cnn,
                         build_mobilenet_v1, build_resnet8,
                         reference_forward)
from repro.quant import QParams, quantize

KEY = jax.random.PRNGKey(0)
ZOO = ("ds-cnn", "resnet-8", "mobilenetv1-0.25")


def _tol(ref):
    scale = float(np.abs(np.asarray(ref)).max()) or 1.0
    return dict(rtol=3e-4, atol=3e-5 * scale)


def test_zoo_builders_validate():
    for build, n_convs in ((build_ds_cnn, 9), (build_resnet8, 9),
                           (build_mobilenet_v1, 27)):
        g = build()
        g.validate()
        convs = [n for n in g.nodes.values()
                 if n.kind.startswith("conv")]
        assert len(convs) == n_convs
        # every zoo net exercises a real k x k spatial conv
        assert any(n.kind == "conv_k2d" for n in g.nodes.values())


def test_zoo_fits_cortex_m4_sram():
    """Deployability: every zoo net's byte-granular bottleneck fits the
    paper's 128 KB board, well under the tensor-level baseline."""
    for net in ZOO:
        cn = repro.compile(net, "cortex-m4", quantize=False,
                           certify=False)
        rep = cn.report()
        assert rep["fits_sram"], rep
        assert rep["mcu_bottleneck_bytes"] \
            < rep["tinyengine_bottleneck_bytes"]


@pytest.mark.slow
@pytest.mark.parametrize("net", ZOO)
def test_zoo_fp32_all_backends(net):
    """host-sim fp32 compile: certify (sim), then jnp and pallas match
    the plain-XLA reference forward."""
    cn = repro.compile(net, "host-sim")          # certify pass included
    assert cn.certificate["clobbers"] == 0
    cn.program.check_alignment()
    params = cn.ensure_params()
    x = jax.random.normal(KEY, (cn.program.in_rows, cn.program.in_dim))
    ref = reference_forward(cn.program, x, params)
    tol = _tol(ref)
    for backend in ("jnp", "pallas"):
        y = cn.run(x, backend=backend)
        assert y.shape == (cn.program.out_rows, cn.program.out_dim)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), **tol)


@pytest.mark.slow
@pytest.mark.parametrize("net", ZOO)
def test_zoo_int8_all_backends_bitwise(net):
    """cortex-m4 int8 compile: sim-certified, jnp == pallas BITWISE on
    the whole ring state, and the dequantized output tracks the float
    reference (cosine + argmax agreement)."""
    from repro.graph.run import quantized_agreement

    cn = repro.compile(net, "cortex-m4")         # int8 + quantize + certify
    assert cn.quantized and cn.certificate["clobbers"] == 0
    qnet = cn.qnet
    x = jax.random.normal(KEY, (cn.program.in_rows, cn.program.in_dim))
    x_q = quantize(x, QParams(scale=qnet.in_scale))
    y_j, pool_j = run_program(qnet.program, x_q, qnet.qparams,
                              backend="jnp")
    y_p, pool_p = run_program(qnet.program, x_q, qnet.qparams,
                              backend="pallas")
    assert y_j.dtype == np.int8 and y_p.dtype == np.int8
    np.testing.assert_array_equal(np.asarray(y_j), np.asarray(y_p))
    np.testing.assert_array_equal(np.asarray(pool_j.array),
                                  np.asarray(pool_p.array))
    rep = quantized_agreement(qnet, n=4)
    assert rep["cosine"] >= 0.99, rep
    assert rep["argmax_agreement"] >= 0.75, rep


# ---------------------------------------------------------------------------
# MLPerf-Tiny anomaly detection: the ToyADMOS FC autoencoder.
# ---------------------------------------------------------------------------

def test_ad_toyadmos_builder_validates():
    g = build_ad_autoencoder()
    g.validate()
    fcs = [n for n in g.nodes.values() if n.kind == "fc"]
    assert len(fcs) == 10                    # 4 enc + latent + 4 dec + head
    assert fcs[-1].out.d == 640 and fcs[-1].activation is None
    assert all(n.activation == "relu" for n in fcs[:-1])


def test_ad_toyadmos_fp32_all_backends():
    cn = repro.compile("ad-toyadmos", "host-sim")
    assert cn.certificate["clobbers"] == 0
    params = cn.ensure_params()
    x = jax.random.normal(KEY, (cn.program.in_rows, cn.program.in_dim))
    ref = reference_forward(cn.program, x, params)
    tol = _tol(ref)
    for backend in ("jnp", "pallas"):
        y = cn.run(x, backend=backend)
        assert y.shape == (1, 640)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), **tol)


def test_ad_toyadmos_int8_cortex_m4_bitwise():
    from repro.graph.run import quantized_agreement

    cn = repro.compile("ad-toyadmos", "cortex-m4")
    assert cn.quantized and cn.certificate["clobbers"] == 0
    assert cn.report()["fits_sram"]
    qnet = cn.qnet
    x = jax.random.normal(KEY, (cn.program.in_rows, cn.program.in_dim))
    x_q = quantize(x, QParams(scale=qnet.in_scale))
    y_j, _ = run_program(qnet.program, x_q, qnet.qparams, backend="jnp")
    y_p, _ = run_program(qnet.program, x_q, qnet.qparams,
                         backend="pallas")
    np.testing.assert_array_equal(np.asarray(y_j), np.asarray(y_p))
    rep = quantized_agreement(qnet, n=4)
    assert rep["cosine"] >= 0.99, rep


def test_ad_toyadmos_alias_resolves():
    cn = repro.compile("toyadmos", "host-sim", certify=False)
    assert cn.net_name == "ad-toyadmos"


# ---------------------------------------------------------------------------
# Batched CompiledNet.run: one shared plan vmapped over a leading dim.
# ---------------------------------------------------------------------------

def test_batched_run_int8_bitwise_matches_loop():
    """A leading batch dim vmaps ONE shared plan; the int8 path stays
    bitwise identical to the per-sample loop."""
    cn = repro.compile("ad-toyadmos", "cortex-m4")
    x = jax.random.normal(KEY, (3, cn.program.in_rows, cn.program.in_dim))
    y_b = cn.run(x)
    assert y_b.shape == (3, 1, 640)
    y_l = jnp.stack([cn.run(xi) for xi in x])
    np.testing.assert_array_equal(np.asarray(y_b), np.asarray(y_l))
    # pallas batches via the per-sample loop — same bitwise surface
    y_p = cn.run(x, backend="pallas")
    np.testing.assert_array_equal(np.asarray(y_p), np.asarray(y_l))


def test_batched_run_fp32_matches_loop():
    cn = repro.compile("ds-cnn", "host-sim")
    x = jax.random.normal(KEY, (2, cn.program.in_rows, cn.program.in_dim))
    y_b = cn.run(x)
    y_l = jnp.stack([cn.run(xi) for xi in x])
    assert y_b.shape == y_l.shape
    np.testing.assert_allclose(np.asarray(y_b), np.asarray(y_l),
                               **_tol(y_l))


def test_resnet8_shortcut_projection_plan_shape():
    """The downsampling stacks lower to the branch pattern: main-path
    k2d convs with the block input held, a shortcut projection reading
    the held tensor (in_op), and a post-add relu."""
    cn = repro.compile("resnet-8", "host-sim", certify=False)
    ops = cn.program.ops
    kinds = [op.kind for op in ops]
    assert kinds.count("conv_k2d") == 7          # stem + 3 stacks x 2
    assert kinds.count("add") == 3
    branch = [op for op in ops if op.in_op >= 0]
    assert len(branch) == 2                      # R1.sc, R2.sc
    for op in branch:
        assert op.kind == "conv_pw" and op.stride == 2
        # the held source op must not free the shared block input
        assert ops[op.in_op].hold_input
    for op in ops:
        if op.kind == "add":
            assert op.activation == "relu" and op.aux_op >= 0
