"""AxisRules logical→physical resolution and param-spec pattern rules."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ARCH_REGISTRY
from repro.configs.base import DECODE_32K, LONG_500K, PREFILL_32K, TRAIN_4K
from repro.parallel.sharding import AxisRules, no_sharding


def _mesh2():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def test_no_mesh_is_noop():
    rules = no_sharding()
    x = jax.numpy.ones((4, 4))
    assert rules.act(x, "batch", None) is x
    assert rules.sharding("batch") is None


def test_tp_mode_resolution():
    r = AxisRules(mesh=_mesh2(), mode="tp")
    assert r.spec("batch", "seq", "heads") == P("data", None, "model")
    assert r.spec("fsdp", "ff") == P("data", "model")
    assert r.spec("vocab") == P("model")


def test_fsdp_sp_mode_resolution():
    r = AxisRules(mesh=_mesh2(), mode="fsdp_sp")
    assert r.spec("batch", "seq", "heads") == P("data", "model", None)
    assert r.spec("fsdp", "ff") == P("data", None)
    assert r.spec("vocab") == P("model")  # vocab always TP


def test_decode_never_shards_seq():
    r = AxisRules(mesh=_mesh2(), mode="fsdp_sp", decode=True)
    assert r.spec("batch", "seq", None) == P("data", None, None)


def test_long_context_shards_cache_not_batch():
    r = AxisRules(mesh=_mesh2(), mode="fsdp_sp", decode=True,
                  long_context=True, kv_shardable=False)
    assert r.spec("batch") == P(None)
    assert r.spec("kv_seq") == P(("data", "model"))


def test_kv_seq_fallback_when_heads_unshardable():
    r = AxisRules(mesh=_mesh2(), mode="tp", decode=True, kv_shardable=False)
    assert r.spec("kv_seq") == P("model")
    assert r.spec("kv_heads") == P(None)


def test_param_rules_cover_all_archs():
    """Every parameter of every arch matches a rule that shards the big
    dims and replicates norms."""
    from repro.models.registry import build_model
    r = AxisRules(mesh=_mesh2(), mode="tp")
    for name, full in ARCH_REGISTRY.items():
        cfg = full.reduced()
        model = build_model(cfg)
        shapes = jax.eval_shape(lambda m=model: m.init(jax.random.PRNGKey(0)))
        specs = r.params_shardings(shapes)
        for (path, s), ns in zip(
                jax.tree_util.tree_flatten_with_path(shapes)[0],
                jax.tree.leaves(specs)):
            pathstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                               for k in path)
            assert ns is not None, (name, pathstr)
            assert len(ns.spec) <= len(s.shape), (name, pathstr, ns.spec)
            if "embed" in pathstr:
                assert "model" in jax.tree.leaves(tuple(ns.spec)), pathstr


def test_make_rules_flags():
    from repro.launch.specs import make_rules
    cfg = ARCH_REGISTRY["gemma2-2b"]
    mesh = _mesh2()
    assert make_rules(cfg, mesh, TRAIN_4K).decode is False
    assert make_rules(cfg, mesh, DECODE_32K).decode is True
    assert make_rules(cfg, mesh, LONG_500K).long_context is True
    assert make_rules(cfg, mesh, PREFILL_32K).long_context is False
