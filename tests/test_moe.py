"""MoE dispatch: routing invariants + dispatch-variant equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import ARCH_REGISTRY
from repro.models.moe import init_moe, moe_forward
from repro.models.registry import build_model
from repro.parallel.sharding import no_sharding

KEY = jax.random.PRNGKey(7)


def _cfg(**kw):
    base = ARCH_REGISTRY["granite-moe-1b-a400m"].reduced()
    return dataclasses.replace(base, **kw) if kw else base


def test_scan_dispatch_equals_cumsum_dispatch():
    """§Perf iteration C1 must be a pure lowering change: identical math."""
    cfg_c = _cfg(moe_dispatch="cumsum")
    cfg_s = _cfg(moe_dispatch="scan")
    p = init_moe(KEY, cfg_c)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg_c.d_model),
                          jnp.float32)
    y_c, aux_c = moe_forward(p, x, cfg_c, no_sharding())
    y_s, aux_s = moe_forward(p, x, cfg_s, no_sharding())
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s), atol=1e-6)
    np.testing.assert_allclose(float(aux_c), float(aux_s), atol=1e-6)


def test_moe_output_is_gate_weighted():
    """With one expert and top-1 routing, MoE == dense expert + shared."""
    cfg = _cfg(n_experts=1, top_k=1, n_shared_experts=0)
    p = init_moe(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 4, cfg.d_model),
                          jnp.float32)
    y, _ = moe_forward(p, x, cfg, no_sharding())
    # manual dense expert
    from repro.models.common import apply_norm
    h = apply_norm(p["ln"], x, cfg).reshape(-1, cfg.d_model)
    g = jax.nn.silu(h @ p["moe_gate"][0]) * (h @ p["moe_up"][0])
    want = (g @ p["moe_down"][0]).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=1e-4,
                               atol=1e-5)


@given(st.integers(1, 3), st.integers(0, 1))
@settings(max_examples=8, deadline=None)
def test_moe_finite_and_shaped(seed, shared):
    cfg = _cfg(n_shared_experts=shared)
    p = init_moe(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 10),
                          (2, 6, cfg.d_model), jnp.float32)
    y, aux = moe_forward(p, x, cfg, no_sharding())
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) >= 0.0


def test_capacity_drops_tokens_when_tight():
    """cf -> tiny forces drops: output for dropped tokens comes only from
    shared experts / zero — never NaN."""
    cfg = _cfg(capacity_factor=0.01, n_shared_experts=0)
    p = init_moe(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, cfg.d_model),
                          jnp.float32)
    y, _ = moe_forward(p, x, cfg, no_sharding())
    assert bool(jnp.all(jnp.isfinite(y)))
    # at least one token zeroed by the capacity drop
    norms = jnp.linalg.norm(y.reshape(-1, cfg.d_model), axis=-1)
    assert float(jnp.min(norms)) == 0.0


def test_deepseek_lead_dense_layer_present():
    cfg = ARCH_REGISTRY["deepseek-moe-16b"].reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    assert len(params["lead"]) == cfg.first_dense_layers
    assert "router" not in params["lead"][0]["ffn"]       # dense
    g0 = jax.tree.leaves(params["groups"][0])[0]
    assert "router" in params["groups"][0]["ffn"]         # MoE in scan
