"""VirtualPool geometry + staging: alignment edge cases, mid-block wrap,
and the single shared stage/fetch + ceil-div helpers."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FusedMLPSpec, GemmSpec, PoolSpec, VirtualPool,
                        ceil_div, plan_program, segments_for)
from repro.core.vpool import fetch_rows, stage_rows
from repro.kernels.segment_matmul import SEG_WIDTH, aligned_pool_geometry

KEY = jax.random.PRNGKey(0)


# -- the one ceil-div segment helper ----------------------------------------

def test_segments_for_matches_all_legacy_spellings():
    for d in [1, 31, 32, 33, 127, 128, 129, 300, 4096]:
        for w in [1, 16, 32, 128]:
            assert segments_for(d, w) == -(-d // w) == math.ceil(d / w)
    assert ceil_div(0, 8) == 0
    from repro.core.ring_buffer import _segs as rb_segs
    from repro.kernels.segment_matmul import _segs as km_segs
    assert rb_segs(300, 128) == km_segs(300) == segments_for(300)


# -- aligned_pool_geometry edge cases ---------------------------------------

def test_aligned_geometry_delta_zero_is_in_place():
    """delta == 0 (square in-place plans): both pointers collapse to 0."""
    n, in_ptr, out_ptr = aligned_pool_geometry(16, 128, 128, 0, 4)
    assert in_ptr == 0 and out_ptr == 0
    assert n >= 16 and n % 4 == 0


def test_aligned_geometry_ragged_dims():
    """Dims not divisible by SEG_WIDTH still produce safe aligned plans."""
    m, d_in, d_out, br = 24, 300, 130, 8
    k_segs, n_segs = segments_for(d_in), segments_for(d_out)
    bk, bn = br * k_segs, br * n_segs
    n, in_ptr, out_ptr = aligned_pool_geometry(m, d_in, d_out, 1, br)
    assert in_ptr % bk == 0 and out_ptr % bn == 0
    assert in_ptr - out_ptr >= 1  # never rounded below the solved delta
    assert n % math.lcm(bk, bn) == 0


@pytest.mark.parametrize("m,d_in,d_out,delta,br", [
    (8, 128, 128, 0, 4), (24, 300, 130, 1, 8), (32, 64, 640, 128, 8),
    (16, 96, 64, 5, 2), (512, 256, 256, 1, 8),
])
def test_aligned_geometry_never_wraps_mid_block(m, d_in, d_out, delta, br):
    """Every contiguous DMA block must fit before the pool's end."""
    k_segs, n_segs = segments_for(d_in), segments_for(d_out)
    bk, bn = br * k_segs, br * n_segs
    n, in_ptr, out_ptr = aligned_pool_geometry(m, d_in, d_out, delta, br)
    for i in range(m // br):
        assert (in_ptr + i * bk) % n + bk <= n, "mid-block wrap (in)"
        assert (out_ptr + i * bn) % n + bn <= n, "mid-block wrap (out)"


def test_program_alignment_never_wraps_mid_block():
    """Same invariant for whole aligned programs (chain + fused MLP)."""
    program = plan_program(16, 256,
                           [GemmSpec(384, "gelu"), GemmSpec(256),
                            FusedMLPSpec(512, ff_tile=256)],
                           block_rows=8)
    program.check_alignment()  # raises on any mid-block wrap
    with pytest.raises(ValueError, match="block_rows=None"):
        plan_program(16, 256, [GemmSpec(384)],
                     block_rows=None).check_alignment()


def test_aligned_delta_never_below_solved_delta():
    """Alignment may only round the offset UP (safety preserved)."""
    for delta in [0, 1, 5, 17, 64, 129]:
        for br in [1, 2, 8]:
            _, in_ptr, out_ptr = aligned_pool_geometry(16, 256, 384,
                                                       delta, br)
            assert in_ptr - out_ptr >= delta


# -- the one stage/fetch implementation -------------------------------------

@pytest.mark.parametrize("d", [128, 64, 300])
def test_stage_fetch_roundtrip(d):
    m, n_seg = 4, 64
    x = jax.random.normal(KEY, (m, d))
    pool = jnp.zeros((n_seg, SEG_WIDTH))
    pool = stage_rows(pool, x, 7 * segments_for(d))
    got = fetch_rows(pool, 7 * segments_for(d), m, d)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))


def test_stage_fetch_wraps_modulo():
    """Staging past the end of the ring wraps — the paper's bounds check."""
    m, d, n_seg = 4, 128, 8
    x = jax.random.normal(KEY, (m, d))
    pool = jnp.zeros((n_seg, SEG_WIDTH))
    pool = stage_rows(pool, x, n_seg - 2)  # wraps after two segments
    got = fetch_rows(pool, n_seg - 2, m, d)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(pool[0]), np.asarray(x[2]))


def test_virtual_pool_handle():
    spec = PoolSpec(32, 128, jnp.float32)
    vp = VirtualPool.alloc(spec)
    assert vp.spec == spec and vp.nbytes == 32 * 128 * 4
    x = jax.random.normal(KEY, (2, 200))
    got = vp.stage_rows(x, 3).fetch_rows(3, 2, 200)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))
    with pytest.raises(ValueError):
        PoolSpec(0, 128)


def test_legacy_aliases_are_the_shared_impl():
    from repro.core import ring_buffer
    from repro.kernels import segment_matmul
    x = jax.random.normal(KEY, (3, 96))
    pool = jnp.zeros((16, SEG_WIDTH))
    a = ring_buffer.write_rows(pool, x, 2, 16)
    b = segment_matmul.stage_rows(pool, x, 2)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(
        np.asarray(ring_buffer.read_rows(a, 2, 3, 96, 16)),
        np.asarray(segment_matmul.fetch_rows(b, 2, 3, 96)))
