"""Checkpoint manager: atomicity, restore, async, retention, elasticity."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (4, 8)),
        "nested": {"b": jnp.arange(6).reshape(2, 3).astype(jnp.float32)},
        "groups": (jnp.ones((2, 3)), {"c": jnp.zeros((5,))}),
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(10, tree)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        tree)
    out = mgr.restore(like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.latest_step() == 4
    assert mgr.steps() == [3, 4]  # older GC'd


def test_atomic_no_partial_checkpoint(tmp_path):
    """A stale .tmp dir must never be listed as a valid checkpoint."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, _tree())
    os.makedirs(os.path.join(str(tmp_path), "step_0000000009.tmp"))
    assert mgr.latest_step() == 5


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_async(7, _tree())
    mgr.wait()
    assert mgr.latest_step() == 7


def test_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore(_tree())


def test_elastic_restore_dtype_and_structure(tmp_path):
    """Restore targets a like-tree; structure must match even when the
    restoring job builds it fresh (different mesh/session)."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, _tree(1))
    fresh_like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), _tree(99))
    out = mgr.restore(fresh_like)
    want = _tree(1)
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(want["a"]))
