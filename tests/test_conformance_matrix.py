"""THE backend x dtype conformance matrix.

One cell for EVERY (executable op kind, backend, dtype) combination —
no silent skips: unsupported cells are explicit ``xfail(strict=True)``
entries in :data:`UNSUPPORTED`, so the support surface is
machine-readable.  Cells assert

  * ``jnp`` / ``pallas`` fp32  — allclose against ``kernels/ref.py``,
  * ``jnp`` / ``pallas`` int8  — BITWISE equality against the
    ``kernels/ref.py`` ``*_q_ref`` oracles (integer math is exact),
  * ``sim``                    — the clobber-oracle certificate (the sim
    backend replays the schedule; it has no numeric output).

This file subsumes the previous ad-hoc per-op backend-equivalence
copies (``test_program.test_cross_backend_equivalence``,
``test_program.test_elementwise_op_runs_on_all_backends``,
``test_quant_execution.test_int8_gemm_scan_blocks_match_pallas``).

A second grid pins the new ``conv_k2d`` kind across its whole envelope:
k in {3, 5} x stride in {1, 2} x padding in {same, valid}.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.executors import execute, run_program
from repro.core.graph_planner import ModuleConfig
from repro.core.program import (AvgPoolSpec, ConvDWSpec, ConvK2DSpec,
                                ConvPWSpec, ConvStreamSpec,
                                ElementwiseSpec, EXECUTABLE_KINDS,
                                FusedMLPSpec, GemmSpec, GRUCellSpec,
                                IBModuleSpec, ResidualAddSpec,
                                plan_program)
from repro.graph.run import _quantize_net
from repro.kernels import ref
from repro.quant import QParams, quantize

KEY = jax.random.PRNGKey(0)
BACKENDS = ("sim", "jnp", "pallas")
DTYPES = ("float32", "int8")

# The machine-readable unsupported surface.  A cell listed here MUST
# fail (strict xfail) — if an int8 path is ever added, the entry has to
# be removed, keeping this table honest.
UNSUPPORTED = {
    ("fused_mlp", "int8"):
        "no int8 fused-MLP path — d_ff tiles accumulate in fp32 only",
    ("elementwise", "int8"):
        "gelu/silu have no single-multiplier int8 form "
        "(relu rides on the producing op instead)",
    ("ib_fused", "int8"):
        "int8 requires unfused module lowering (fused_exec=False)",
}


@dataclasses.dataclass
class Cell:
    program: object
    params: list
    x: jax.Array
    ref_fp32: object          # (x, params) -> [out_rows, d_out]
    ref_int8: object          # (x_q, qparams, ops) -> int8 array


def _rand(key, *shape):
    return jax.random.normal(key, shape)


def _cell_gemm() -> Cell:
    m, d_in, d_out = 8, 160, 96
    prog = plan_program(m, d_in, [GemmSpec(d_out, activation="relu")],
                        block_rows=4)
    k1, k2, k3 = jax.random.split(KEY, 3)
    w = _rand(k1, d_in, d_out) / d_in ** 0.5
    b = _rand(k2, d_out) / 8
    return Cell(
        prog, [(w, b)], _rand(k3, m, d_in),
        lambda x, p: ref.elementwise_ref(
            ref.gemm_ref(x, p[0][0], p[0][1]), "relu"),
        lambda x_q, qp, ops: ref.gemm_q_ref(x_q, *qp[0],
                                            activation="relu"))


def _cell_conv_pw() -> Cell:
    h, w_, c_in, c_out, s = 6, 5, 160, 64, 2
    prog = plan_program(h * w_, c_in,
                        [ConvPWSpec(h, w_, c_in, c_out, stride=s,
                                    activation="relu")], block_rows=1)
    k1, k2, k3 = jax.random.split(KEY, 3)
    w = _rand(k1, c_in, c_out) / c_in ** 0.5
    b = _rand(k2, c_out) / 8

    def fp32(x, p):
        y = ref.conv_pw_ref(x.reshape(h, w_, c_in), p[0][0], p[0][1],
                            stride=s, activation="relu")
        return y.reshape(-1, c_out)

    def int8(x_q, qp, ops):
        y = ref.conv_pw_q_ref(x_q.reshape(h, w_, c_in), *qp[0], stride=s,
                              activation="relu")
        return y.reshape(-1, c_out)

    return Cell(prog, [(w, b)], _rand(k3, h * w_, c_in), fp32, int8)


def _cell_conv_dw() -> Cell:
    h, w_, c, rs, s = 6, 6, 48, 3, 2
    prog = plan_program(h * w_, c,
                        [ConvDWSpec(h, w_, c, rs=rs, stride=s,
                                    activation="relu")], block_rows=1)
    k1, k2, k3 = jax.random.split(KEY, 3)
    w = _rand(k1, rs, rs, c) / rs
    b = _rand(k2, c) / 8

    def fp32(x, p):
        y = ref.conv_dw_ref(x.reshape(h, w_, c), p[0][0], p[0][1],
                            stride=s, activation="relu")
        return y.reshape(-1, c)

    def int8(x_q, qp, ops):
        y = ref.conv_dw_q_ref(x_q.reshape(h, w_, c), *qp[0], stride=s,
                              activation="relu")
        return y.reshape(-1, c)

    return Cell(prog, [(w, b)], _rand(k3, h * w_, c), fp32, int8)


def _cell_conv_k2d() -> Cell:
    h, w_, c_in, c_out, k, s = 7, 6, 24, 40, 3, 2
    prog = plan_program(h * w_, c_in,
                        [ConvK2DSpec(h, w_, c_in, c_out, k=k, stride=s,
                                     activation="relu")], block_rows=1)
    k1, k2, k3 = jax.random.split(KEY, 3)
    w = _rand(k1, k, k, c_in, c_out) / (k * k * c_in) ** 0.5
    b = _rand(k2, c_out) / 8

    def fp32(x, p):
        y = ref.conv_k2d_ref(x.reshape(h, w_, c_in), p[0][0], p[0][1],
                             stride=s, activation="relu")
        return y.reshape(-1, c_out)

    def int8(x_q, qp, ops):
        y = ref.conv_k2d_q_ref(x_q.reshape(h, w_, c_in), *qp[0],
                               stride=s, activation="relu")
        return y.reshape(-1, c_out)

    return Cell(prog, [(w, b)], _rand(k3, h * w_, c_in), fp32, int8)


def _cell_add() -> Cell:
    h, w_, c = 4, 4, 32
    prog = plan_program(h * w_, c,
                        [ConvPWSpec(h, w_, c, c, activation=None),
                         ResidualAddSpec(1, activation="relu")],
                        block_rows=1)
    k1, k2 = jax.random.split(KEY)
    w = _rand(k1, c, c) / c ** 0.5
    zb = jnp.zeros((c,))

    def fp32(x, p):
        y = ref.conv_pw_ref(x.reshape(h, w_, c), p[0][0], zb)
        return ref.add_ref(y.reshape(-1, c), x, activation="relu")

    def int8(x_q, qp, ops):
        y = ref.conv_pw_q_ref(x_q.reshape(h, w_, c), *qp[0])
        return ref.add_q_ref(y.reshape(-1, c), x_q, *qp[1],
                             activation="relu")

    return Cell(prog, [(w, None), None], _rand(k2, h * w_, c), fp32,
                int8)


def _cell_pool_avg() -> Cell:
    h, w_, c = 5, 4, 32
    prog = plan_program(h * w_, c, [AvgPoolSpec(h, w_, c)], block_rows=1)
    return Cell(
        prog, [None], _rand(KEY, h * w_, c),
        lambda x, p: ref.avgpool_ref(x.reshape(h, w_, c)),
        lambda x_q, qp, ops: ref.avgpool_q_ref(x_q.reshape(h, w_, c),
                                               *qp[0]))


def _cell_fused_mlp() -> Cell:
    m, d, f = 8, 256, 512
    prog = plan_program(m, d,
                        [FusedMLPSpec(f, gated=True, residual=True,
                                      activation="gelu", ff_tile=256)],
                        block_rows=8)
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    wg = _rand(k1, d, f) / d ** 0.5
    wu = _rand(k2, d, f) / d ** 0.5
    wd = _rand(k3, f, d) / f
    return Cell(
        prog, [(wg, wu, wd)], _rand(k4, m, d),
        lambda x, p: ref.fused_mlp_ref(x, *p[0], gated=True,
                                       residual=True, activation="gelu"),
        None)


def _cell_elementwise() -> Cell:
    m, d = 8, 256
    prog = plan_program(m, d, [ElementwiseSpec("gelu")], block_rows=8)
    return Cell(prog, [None], _rand(KEY, m, d),
                lambda x, p: ref.elementwise_ref(x, "gelu"), None)


def _cell_ib_fused() -> Cell:
    cfg = ModuleConfig(name="cell", hw=6, c_in=16, c_mid=24, c_out=16,
                       rs=3, strides=(1, 1, 1))
    prog = plan_program(cfg.hw * cfg.hw, cfg.c_in, [IBModuleSpec(cfg)],
                        block_rows=1)
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    w1 = _rand(k1, cfg.c_in, cfg.c_mid) / cfg.c_in ** 0.5
    wd = _rand(k2, cfg.rs, cfg.rs, cfg.c_mid) / cfg.rs
    w2 = _rand(k3, cfg.c_mid, cfg.c_out) / cfg.c_mid ** 0.5

    def fp32(x, p):
        y = ref.ib_fused_ref(x.reshape(cfg.hw, cfg.hw, cfg.c_in), *p[0],
                             residual=True)
        return y.reshape(-1, cfg.c_out)

    return Cell(prog, [(w1, wd, w2)],
                _rand(k4, cfg.hw * cfg.hw, cfg.c_in), fp32, None)


def _cell_conv_stream() -> Cell:
    """One stream step from the zero (reset) state: the fresh pool's
    zero-initialized window IS the reference conv's zero padding, so a
    single ``run_program`` call is a well-defined matrix cell."""
    h_win, w_, c_in, c_out, hop = 6, 5, 24, 32, 2
    prog = plan_program(hop * w_, c_in,
                        [ConvStreamSpec(h_win, w_, c_in, c_out, k=3,
                                        stride=1, hop=hop,
                                        activation="relu")], block_rows=1)
    k1, k2, k3 = jax.random.split(KEY, 3)
    w = _rand(k1, 3, 3, c_in, c_out) / (9 * c_in) ** 0.5
    b = _rand(k2, c_out) / 8

    def fp32(x, p):
        state = jnp.zeros((h_win, w_, c_in))
        y, _ = ref.conv_stream_ref(state, x.reshape(hop, w_, c_in),
                                   p[0][0], p[0][1], activation="relu")
        return y.reshape(-1, c_out)

    def int8(x_q, qp, ops):
        state_q = jnp.zeros((h_win, w_, c_in), jnp.int8)
        y, _ = ref.conv_stream_q_ref(state_q,
                                     x_q.reshape(hop, w_, c_in),
                                     *qp[0], activation="relu")
        return y.reshape(-1, c_out)

    return Cell(prog, [(w, b)], _rand(k3, hop * w_, c_in), fp32, int8)


def _cell_gru_cell() -> Cell:
    """One recurrence step from the zero hidden state (Q7 zero-point is
    0, so int8 zero state == float zero state)."""
    d_in, d_h = 40, 32
    prog = plan_program(1, d_in, [GRUCellSpec(d_h)], block_rows=1)
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    w = _rand(k1, d_in, 3 * d_h) / d_in ** 0.5
    u = _rand(k2, d_h, 3 * d_h) / d_h ** 0.5
    b = _rand(k3, 3 * d_h) / 8

    def fp32(x, p):
        h = jnp.zeros((1, d_h))
        return ref.gru_cell_ref(x, h, *p[0])

    def int8(x_q, qp, ops):
        h_q7 = jnp.zeros((1, d_h), jnp.int8)
        return ref.gru_cell_q_ref(x_q, h_q7, *qp[0])

    return Cell(prog, [(w, u, b)], _rand(k4, 1, d_in), fp32, int8)


CELL_BUILDERS = {
    "gemm": _cell_gemm,
    "conv_pw": _cell_conv_pw,
    "conv_dw": _cell_conv_dw,
    "conv_k2d": _cell_conv_k2d,
    "add": _cell_add,
    "pool_avg": _cell_pool_avg,
    "fused_mlp": _cell_fused_mlp,
    "elementwise": _cell_elementwise,
    "ib_fused": _cell_ib_fused,
    "conv_stream": _cell_conv_stream,
    "gru_cell": _cell_gru_cell,
}


def test_matrix_covers_every_executable_kind():
    """Adding an executable op kind without a matrix cell is an error —
    the conformance surface may never silently shrink."""
    assert set(CELL_BUILDERS) == set(EXECUTABLE_KINDS)
    assert set(k for k, _ in UNSUPPORTED) <= set(EXECUTABLE_KINDS)


def _grid():
    cells = []
    for kind in EXECUTABLE_KINDS:
        for backend in BACKENDS:
            for dtype in DTYPES:
                marks = ()
                reason = UNSUPPORTED.get((kind, dtype))
                if reason is not None:
                    marks = pytest.mark.xfail(reason=reason, strict=True)
                cells.append(pytest.param(kind, backend, dtype,
                                          marks=marks,
                                          id=f"{kind}-{backend}-{dtype}"))
    return cells


def _tol(expected):
    scale = float(np.abs(np.asarray(expected)).max()) or 1.0
    return dict(rtol=3e-4, atol=3e-5 * scale)


@pytest.mark.parametrize("kind,backend,dtype", _grid())
def test_conformance_cell(kind, backend, dtype):
    cell = CELL_BUILDERS[kind]()
    if dtype == "int8":
        # unsupported kinds raise here — the strict-xfail contract
        qnet = _quantize_net(cell.program, cell.params)
        if backend == "sim":
            sim = execute(qnet.program, backend="sim")
            assert sim.peak_live <= qnet.program.n_segments
            return
        x_q = quantize(cell.x, QParams(scale=qnet.in_scale))
        y, _ = run_program(qnet.program, x_q, qnet.qparams,
                           backend=backend)
        expected = cell.ref_int8(x_q, qnet.qparams, qnet.program.ops)
        assert y.dtype == np.int8
        np.testing.assert_array_equal(np.asarray(y), np.asarray(expected))
    else:
        if backend == "sim":
            sim = execute(cell.program, backend="sim")
            assert sim.peak_live <= cell.program.n_segments
            return
        y, _ = run_program(cell.program, cell.x, cell.params,
                           backend=backend)
        expected = cell.ref_fp32(cell.x, cell.params)
        np.testing.assert_allclose(np.asarray(y), np.asarray(expected),
                                   **_tol(expected))


# ---------------------------------------------------------------------------
# Execution-granularity blocking (kernel_block_rows): every kind x
# block x dtype on the pallas backend — blocking must not move a bit.
# ---------------------------------------------------------------------------

#: 1 = the fine-grained certified schedule; 8 = the Target default
#: (``Target.kernel_block_rows``).
KERNEL_BLOCKS = (1, 8)


def _blocked_grid():
    cells = []
    for kind in EXECUTABLE_KINDS:
        for block in KERNEL_BLOCKS:
            for dtype in DTYPES:
                marks = ()
                reason = UNSUPPORTED.get((kind, dtype))
                if reason is not None:
                    marks = pytest.mark.xfail(reason=reason, strict=True)
                cells.append(pytest.param(
                    kind, block, dtype, marks=marks,
                    id=f"{kind}-rb{block}-{dtype}"))
    return cells


@pytest.mark.parametrize("kind,block,dtype", _blocked_grid())
def test_blocked_pallas_cell(kind, block, dtype):
    """The pallas backend at execution granularity 1 and the target
    default 8 both agree with the ref oracle (bitwise for int8) —
    kernel blocking is invisible to the numbers."""
    cell = CELL_BUILDERS[kind]()
    if dtype == "int8":
        qnet = _quantize_net(cell.program, cell.params)
        x_q = quantize(cell.x, QParams(scale=qnet.in_scale))
        y, _ = run_program(qnet.program, x_q, qnet.qparams,
                           backend="pallas", kernel_block_rows=block)
        expected = cell.ref_int8(x_q, qnet.qparams, qnet.program.ops)
        assert y.dtype == np.int8
        np.testing.assert_array_equal(np.asarray(y), np.asarray(expected))
    else:
        y, _ = run_program(cell.program, cell.x, cell.params,
                           backend="pallas", kernel_block_rows=block)
        expected = cell.ref_fp32(cell.x, cell.params)
        np.testing.assert_allclose(np.asarray(y), np.asarray(expected),
                                   **_tol(expected))


def test_blocked_conv_pw_multi_row_engages():
    """A stride-1 pointwise conv whose geometry satisfies the driver's
    divisor rule: the multi-row path (row_block > 1) must actually
    engage AND stay bitwise-identical to the ref oracle for int8."""
    from repro.core.executors import _pw_row_block

    h, w_, c_in, c_out = 8, 4, 96, 64
    prog = plan_program(h * w_, c_in,
                        [ConvPWSpec(h, w_, c_in, c_out,
                                    activation="relu")], block_rows=1)
    op = next(o for o in prog.ops if o.kind == "conv_pw")
    rb = _pw_row_block(op, prog.n_segments, op.in_ptr, prog.seg_width, 8)
    assert rb > 1, "geometry was chosen so blocking engages"

    k1, k2, k3 = jax.random.split(KEY, 3)
    w = _rand(k1, c_in, c_out) / c_in ** 0.5
    b = _rand(k2, c_out) / 8
    x = _rand(k3, h * w_, c_in)
    expected = ref.conv_pw_ref(x.reshape(h, w_, c_in), w, b,
                               activation="relu").reshape(-1, c_out)
    y, _ = run_program(prog, x, [(w, b)], backend="pallas",
                       kernel_block_rows=8)
    np.testing.assert_allclose(np.asarray(y), np.asarray(expected),
                               **_tol(expected))

    qnet = _quantize_net(prog, [(w, b)])
    x_q = quantize(x, QParams(scale=qnet.in_scale))
    expected_q = ref.conv_pw_q_ref(x_q.reshape(h, w_, c_in),
                                   *qnet.qparams[0], activation="relu") \
        .reshape(-1, c_out)
    for block in KERNEL_BLOCKS:
        y_q, _ = run_program(qnet.program, x_q, qnet.qparams,
                             backend="pallas", kernel_block_rows=block)
        np.testing.assert_array_equal(np.asarray(y_q),
                                      np.asarray(expected_q))


def test_batched_vmap_pallas_cell():
    """A leading batch dimension vmapped straight over the blocked
    pallas path: every lane equals the single-sample run."""
    cell = CELL_BUILDERS["gemm"]()

    def run_one(xi):
        y, _ = run_program(cell.program, xi, cell.params,
                           backend="pallas", kernel_block_rows=8)
        return y

    xb = jnp.stack([cell.x, cell.x * 0.5, -cell.x])
    yb = jax.vmap(run_one)(xb)
    assert yb.shape[0] == 3
    for i in range(3):
        np.testing.assert_allclose(np.asarray(yb[i]),
                                   np.asarray(run_one(xb[i])),
                                   rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# conv_k2d envelope: k x stride x padding across backends and dtypes.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", (3, 5))
@pytest.mark.parametrize("stride", (1, 2))
@pytest.mark.parametrize("padding", ("same", "valid"))
@pytest.mark.parametrize("dtype", DTYPES)
def test_conv_k2d_envelope(k, stride, padding, dtype):
    """Every (k, stride, padding) geometry: sim certifies, jnp and
    pallas agree with the ref oracle (bitwise for int8)."""
    h, w_, c_in, c_out = 9, 8, 24, 32
    prog = plan_program(h * w_, c_in,
                        [ConvK2DSpec(h, w_, c_in, c_out, k=k,
                                     stride=stride, padding=padding,
                                     activation="relu")], block_rows=1)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(k * 10 + stride), 3)
    w = _rand(k1, k, k, c_in, c_out) / (k * k * c_in) ** 0.5
    b = _rand(k2, c_out) / 8
    x = _rand(k3, h * w_, c_in)
    sim = execute(prog, backend="sim")
    assert sim.peak_live <= prog.n_segments
    if dtype == "float32":
        expected = ref.conv_k2d_ref(x.reshape(h, w_, c_in), w, b,
                                    stride=stride, padding=padding,
                                    activation="relu") \
            .reshape(-1, c_out)
        for backend in ("jnp", "pallas"):
            y, _ = run_program(prog, x, [(w, b)], backend=backend)
            np.testing.assert_allclose(np.asarray(y),
                                       np.asarray(expected),
                                       **_tol(expected))
    else:
        qnet = _quantize_net(prog, [(w, b)])
        x_q = quantize(x, QParams(scale=qnet.in_scale))
        expected = ref.conv_k2d_q_ref(x_q.reshape(h, w_, c_in),
                                      *qnet.qparams[0], stride=stride,
                                      padding=padding,
                                      activation="relu") \
            .reshape(-1, c_out)
        for backend in ("jnp", "pallas"):
            y, _ = run_program(qnet.program, x_q, qnet.qparams,
                               backend=backend)
            np.testing.assert_array_equal(np.asarray(y),
                                          np.asarray(expected))


def test_conv_k2d_tight_delta_clobbers_at_minus_one():
    """The k-halo frontier widens Eq. (1): the solved offset is exact —
    shrinking it by one segment must clobber in the oracle."""
    from repro.core.pool import PoolClobberError

    spec = ConvK2DSpec(9, 8, 24, 32, k=5, stride=1, padding="same")
    safe = plan_program(72, 24, [spec], block_rows=None)
    execute(safe, backend="sim")
    tight = plan_program(72, 24, [spec], block_rows=None, delta_slack=1)
    with pytest.raises(PoolClobberError):
        execute(tight, backend="sim")
