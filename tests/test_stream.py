"""Streaming inference subsystem acceptance (DESIGN.md §14).

Persistent temporal state on the segment ring — the fourth lifetime
class.  Pinned here:

  * graph conversion round-trip (``to_streaming`` / ``to_full``),
  * >= 8 consecutive DS-CNN frames on sim (zero clobbers), jnp and
    pallas, with int8 BITWISE jnp == pallas agreement per step,
  * streaming-vs-full-recompute equivalence: once the window has
    filled, every stream step reproduces the one-shot net on the
    current window (bitwise in int8, exact in fp32) when the twin
    shares the stream's weights and quantization,
  * the static certificate's per-step counters times N equal the sim
    oracle's N-step counters (the multi-step horizon proof is not
    advisory — it predicts the byte traffic exactly),
  * the state liveness diagnostics VMCU211/212/213 fire on hand-broken
    plans, in agreement with the sim oracle where it can see the bug,
  * multi-state chains (conv_stream window + GRU hidden vector) track
    the kernels/ref.py oracles step by step in fp32 and bitwise int8,
  * the streaming DS-CNN state + frame ring fits the 128 KB
    cortex-m4 budget.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.analysis.verifier import verify_program
from repro.core.executors import execute, run_program
from repro.core.pool import PoolClobberError
from repro.core.program import (AvgPoolSpec, ConvStreamSpec, GRUCellSpec,
                                plan_program)
from repro.core.vpool import VirtualPool
from repro.graph import QuantizedNet, build_ad_autoencoder, build_ds_cnn
from repro.graph.run import _quantize_net
from repro.kernels import ref
from repro.quant import QParams, quantize
from repro.stream import to_full, to_streaming

KEY = jax.random.PRNGKey(7)
N_FRAMES = 8
SRAM_CORTEX_M4 = 128 * 1024


# ---------------------------------------------------------------------------
# Graph conversion.
# ---------------------------------------------------------------------------

def test_to_streaming_round_trip():
    g = build_ds_cnn()
    gs = to_streaming(g)
    assert gs.name == "ds-cnn-stream"
    stems = [n for n in gs.nodes.values() if n.kind == "conv_stream"]
    assert len(stems) == 1
    win = g.nodes[g.input_id()].out
    assert stems[0].h_win == win.h and stems[0].hop == 1
    frame = gs.nodes[gs.input_id()].out
    assert (frame.h, frame.w, frame.d) == (1, win.w, win.d)
    assert to_streaming(gs) is gs                    # idempotent
    gf = to_full(gs)
    assert gf.name == g.name
    assert [n.kind for n in gf.nodes.values()] \
        == [n.kind for n in g.nodes.values()]
    assert gf.nodes[gf.input_id()].out == win


def test_to_streaming_rejects_non_conv_stem():
    with pytest.raises(ValueError, match="conv_k2d stem"):
        to_streaming(build_ad_autoencoder())


def test_to_full_requires_single_stream_stem():
    with pytest.raises(ValueError, match="conv_stream"):
        to_full(build_ds_cnn())


# ---------------------------------------------------------------------------
# DS-CNN streaming compile: >= 8 consecutive frames on every backend.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def ds_int8():
    return repro.compile("ds-cnn", "cortex-m4", dtype="int8",
                         streaming=True)


@pytest.fixture(scope="module")
def ds_fp32():
    return repro.compile("ds-cnn", "host-sim", streaming=True)


def _frames(program, n, key=KEY):
    return jax.random.normal(
        key, (n, program.ops[0].rows_in, program.in_dim))


def test_stream_sim_n_frames_zero_clobbers(ds_int8):
    """Eight consecutive frames through the clobber oracle on ONE
    persistent pool — state survives every step or the sim raises."""
    sess = ds_int8.stream(backend="sim")
    for _ in range(N_FRAMES):
        counters = sess.step()
    assert counters["steps"] == N_FRAMES
    assert counters["peak_live"] <= ds_int8.qnet.program.n_segments


def test_stream_int8_jnp_pallas_bitwise(ds_int8):
    prog = ds_int8.qnet.program
    frames_q = quantize(_frames(prog, N_FRAMES),
                        QParams(scale=ds_int8.qnet.in_scale))
    sj = ds_int8.stream(backend="jnp")
    sp = ds_int8.stream(backend="pallas")
    for f in frames_q:
        y_j, y_p = sj.step(f), sp.step(f)
        assert y_j.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(y_j), np.asarray(y_p))
    # ... and the whole ring (frame extent AND state) agrees bitwise
    np.testing.assert_array_equal(np.asarray(sj._pool.array),
                                  np.asarray(sp._pool.array))


def test_stream_fp32_jnp_pallas_allclose(ds_fp32):
    frames = _frames(ds_fp32.program, N_FRAMES)
    sj = ds_fp32.stream(backend="jnp")
    sp = ds_fp32.stream(backend="pallas")
    for f in frames:
        y_j, y_p = sj.step(f), sp.step(f)
        np.testing.assert_allclose(np.asarray(y_j), np.asarray(y_p),
                                   rtol=3e-4, atol=1e-5)


def test_stream_reset_restarts_from_zero_state(ds_int8):
    prog = ds_int8.qnet.program
    frames_q = quantize(_frames(prog, 3),
                        QParams(scale=ds_int8.qnet.in_scale))
    sess = ds_int8.stream(backend="jnp")
    first = [np.asarray(sess.step(f)) for f in frames_q]
    assert sess.steps == 3
    sess.reset()
    assert sess.steps == 0
    again = [np.asarray(sess.step(f)) for f in frames_q]
    for a, b in zip(first, again):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# Streaming == full recompute, once the window has filled.
# ---------------------------------------------------------------------------

def _window_frames(x, prog):
    """Split the full one-shot input into per-step frames."""
    rows = prog.ops[0].rows_in
    return x.reshape(-1, rows, prog.in_dim)


def test_stream_matches_one_shot_int8_bitwise(ds_int8):
    """After ``h_win`` frames the stream output equals the one-shot
    DS-CNN on the same window BITWISE — provided the twin shares the
    stream's weights AND quantization (calibration sees frames, not
    windows, so the qparams are copied, not re-derived)."""
    cf = repro.compile("ds-cnn", "cortex-m4", dtype="int8",
                       certify=False)
    qs = ds_int8.qnet
    twin = QuantizedNet(plan=None, program=cf.qnet.program,
                        params=qs.params, qparams=qs.qparams,
                        act_scales=qs.act_scales)
    h_win = ds_int8.program.ops[0].h_in
    x = jax.random.normal(KEY, (twin.program.in_rows,
                                twin.program.in_dim))
    x_q = quantize(x, QParams(scale=qs.in_scale))
    y_full, _ = run_program(twin.program, x_q, twin.qparams,
                            backend="jnp")
    sess = ds_int8.stream(backend="jnp")
    y_stream = sess.run(_window_frames(x_q, qs.program))
    assert sess.steps == h_win
    np.testing.assert_array_equal(np.asarray(y_stream),
                                  np.asarray(y_full))


def test_stream_matches_one_shot_fp32(ds_fp32):
    cf = repro.compile("ds-cnn", "host-sim", certify=False)
    params = ds_fp32.ensure_params()   # shared weights, aligned op lists
    h_win = ds_fp32.program.ops[0].h_in
    x = jax.random.normal(KEY, (cf.program.in_rows, cf.program.in_dim))
    y_full, _ = run_program(cf.program, x, params, backend="jnp")
    sess = ds_fp32.stream(backend="jnp")
    y_stream = sess.run(_window_frames(x, ds_fp32.program))
    assert sess.steps == h_win
    np.testing.assert_allclose(np.asarray(y_stream),
                               np.asarray(y_full), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# The multi-step certificate: static counters x N == sim counters(N).
# ---------------------------------------------------------------------------

def test_static_certificate_predicts_n_step_sim_counters(ds_int8):
    prog = ds_int8.qnet.program
    res = verify_program(prog)
    assert res.safe is True
    st = res.stats
    assert st["stream_horizon"] == "unbounded"
    assert st["n_states"] == 1
    state = st["state_segments"]
    assert state == sum(op.state_segments for op in prog.ops)
    sess = ds_int8.stream(backend="sim")
    for k in range(1, N_FRAMES + 1):
        c = sess.step()
        # the state is pre-written once; every step then re-reads and
        # rewrites it, so the per-step static stats add linearly
        assert c["reads"] == k * st["reads"]
        assert c["writes"] == state + k * (st["writes"] - state)
        assert c["peak_live"] == st["peak_live"]


def test_compile_certificate_carries_stream_horizon(ds_int8):
    cert = ds_int8.certificate
    assert cert["clobbers"] == 0
    assert cert["stream_horizon"] == "unbounded"
    assert cert["n_states"] == 1 and cert["state_segments"] > 0


def test_stream_state_fits_cortex_m4_budget(ds_int8):
    """Acceptance: frame ring + persistent state together fit the
    paper's 128 KB board, and the state is wrap-free above the frame
    program's linear extent."""
    prog = ds_int8.qnet.program
    assert prog.physical_pool_bytes <= SRAM_CORTEX_M4
    sess = ds_int8.stream(backend="sim")
    assert 0 < sess.state_bytes < prog.physical_pool_bytes
    for op in prog.ops:
        if op.state_segments:
            assert op.state_ptr + op.state_segments <= prog.n_segments
            for other in prog.ops:
                # frame traffic lives strictly below every state region
                assert other.in_ptr + other.in_segments <= op.state_ptr
                assert other.out_ptr + other.out_segments <= op.state_ptr


# ---------------------------------------------------------------------------
# State liveness diagnostics: VMCU211 / 212 / 213.
# ---------------------------------------------------------------------------

def _stream_prog():
    return plan_program(10, 24,
                        [ConvStreamSpec(6, 5, 24, 32, k=3, hop=2,
                                        activation="relu")], block_rows=1)


def _mutate_op0(prog, **kw):
    ops = list(prog.ops)
    ops[0] = dataclasses.replace(ops[0], **kw)
    return dataclasses.replace(prog, ops=tuple(ops))


def test_vmcu211_state_clobbered_by_frame_traffic():
    prog = _stream_prog()
    bad = _mutate_op0(prog, state_ptr=prog.ops[0].out_ptr)
    res = verify_program(bad)
    assert res.safe is False
    assert res.errors[0].code == "VMCU211"
    # agreement: the sim oracle sees the same clobber
    with pytest.raises(PoolClobberError):
        execute(bad, backend="sim")


def test_vmcu212_wrong_state_extent():
    prog = _stream_prog()
    bad = _mutate_op0(prog, state_segments=prog.ops[0].state_segments - 1)
    res = verify_program(bad)
    assert res.safe is False
    assert res.errors[0].code == "VMCU212"


def test_vmcu213_state_wraps_ring():
    prog = _stream_prog()
    bad = _mutate_op0(prog, state_ptr=prog.n_segments - 1)
    res = verify_program(bad)
    assert res.safe is False
    assert res.errors[0].code == "VMCU213"


def test_stream_prog_static_stats_match_sim_exactly():
    """The small synthetic stream program, adversarially: static stats
    equal the sim pool counters bit for bit (the verifier's agreement
    contract extends to the state lifetime class)."""
    prog = _stream_prog()
    res = verify_program(prog)
    assert res.safe is True
    sim = execute(prog, backend="sim")
    assert res.stats["reads"] == sim.reads
    assert res.stats["writes"] == sim.writes
    assert res.stats["peak_live"] == sim.peak_live


# ---------------------------------------------------------------------------
# Multi-state chain: conv_stream window + GRU hidden vector, vs oracle.
# ---------------------------------------------------------------------------

H_WIN, W_, C_IN, C_OUT, HOP, D_H = 6, 5, 8, 16, 2, 24


def _chain_prog():
    return plan_program(HOP * W_, C_IN, [
        ConvStreamSpec(H_WIN, W_, C_IN, C_OUT, k=3, hop=HOP,
                       activation="relu"),
        AvgPoolSpec(H_WIN, W_, C_OUT),
        GRUCellSpec(D_H)], block_rows=1)


def _chain_params():
    k1, k2, k3, k4, k5 = jax.random.split(KEY, 5)
    w = jax.random.normal(k1, (3, 3, C_IN, C_OUT)) / (9 * C_IN) ** 0.5
    b = jax.random.normal(k2, (C_OUT,)) / 8
    wg = jax.random.normal(k3, (C_OUT, 3 * D_H)) / C_OUT ** 0.5
    ug = jax.random.normal(k4, (D_H, 3 * D_H)) / D_H ** 0.5
    bg = jax.random.normal(k5, (3 * D_H,)) / 8
    return [(w, b), None, (wg, ug, bg)]


def test_chain_two_states_certified():
    res = verify_program(_chain_prog())
    assert res.safe is True
    assert res.stats["n_states"] == 2
    assert res.stats["stream_horizon"] == "unbounded"


def test_chain_fp32_tracks_oracle_step_by_step():
    prog, params = _chain_prog(), _chain_params()
    (w, b), _, (wg, ug, bg) = params
    pool = VirtualPool.alloc(prog.spec(jnp.float32))
    state = jnp.zeros((H_WIN, W_, C_IN))
    h = jnp.zeros((1, D_H))
    frames = jax.random.normal(KEY, (5, HOP * W_, C_IN))
    for frame in frames:
        pool = pool.stage_rows(frame, prog.input_ptr)
        pool = execute(prog, pool, params, backend="jnp")
        y = pool.fetch_rows(prog.output_ptr, prog.out_rows, prog.out_dim)
        yc, state = ref.conv_stream_ref(state,
                                        frame.reshape(HOP, W_, C_IN),
                                        w, b, activation="relu")
        h = ref.gru_cell_ref(ref.avgpool_ref(yc), h, wg, ug, bg)
        np.testing.assert_allclose(np.asarray(y), np.asarray(h),
                                   rtol=3e-4, atol=1e-5)


@pytest.mark.parametrize("backend", ("jnp", "pallas"))
def test_chain_int8_bitwise_tracks_q_oracle(backend):
    """Both persistent state classes through the fixed-point pipeline:
    the ring execution stays BITWISE equal to the q-oracles for every
    step — the Q7 hidden state and int8 window survive exactly."""
    prog, params = _chain_prog(), _chain_params()
    qnet = _quantize_net(prog, params)
    qprog = qnet.program
    pool = VirtualPool.alloc(qprog.spec(jnp.int8))
    state_q = jnp.zeros((H_WIN, W_, C_IN), jnp.int8)
    h_q7 = jnp.zeros((1, D_H), jnp.int8)
    frames = jax.random.normal(KEY, (5, HOP * W_, C_IN))
    frames_q = quantize(frames, QParams(scale=qnet.in_scale))
    for frame_q in frames_q:
        pool = pool.stage_rows(frame_q, qprog.input_ptr)
        pool = execute(qprog, pool, qnet.qparams, backend=backend)
        y = pool.fetch_rows(qprog.output_ptr, qprog.out_rows,
                            qprog.out_dim)
        yc, state_q = ref.conv_stream_q_ref(
            state_q, frame_q.reshape(HOP, W_, C_IN), *qnet.qparams[0],
            activation="relu")
        ya = ref.avgpool_q_ref(yc, *qnet.qparams[1])
        h_q7 = ref.gru_cell_q_ref(ya, h_q7, *qnet.qparams[2])
        assert y.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(y), np.asarray(h_q7))


# ---------------------------------------------------------------------------
# Session API edges.
# ---------------------------------------------------------------------------

def test_session_requires_streaming_compile():
    cn = repro.compile("ds-cnn", "host-sim", certify=False)
    with pytest.raises(ValueError, match="streaming=True"):
        cn.stream()


def test_session_array_backend_needs_frames(ds_fp32):
    sess = ds_fp32.stream(backend="jnp")
    with pytest.raises(ValueError, match="frame"):
        sess.step()


def test_session_trace_collects_per_step_artifacts(ds_fp32):
    sess = ds_fp32.stream(backend="jnp", trace=True)
    frames = _frames(ds_fp32.program, 2)
    for f in frames:
        sess.step(f)
    assert len(sess.traces) == 2
    for tr in sess.traces:
        assert tr.events, "trace artifact must carry per-op events"
