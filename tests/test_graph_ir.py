"""Graph IR, builders, lifetime analysis, reordering, fusion selection."""
import pytest

from repro.core.graph_planner import (MCUNET_5FPS_VWW,
                                      MCUNET_320KB_IMAGENET,
                                      plan_inverted_bottleneck,
                                      plan_module_fallback,
                                      vmcu_module_bytes)
from repro.graph import (Graph, Tensor, build_mcunet, build_mlp_tower,
                         peak_live_bytes, reorder, select_groups)


def test_build_mcunet_chains_and_validates():
    for modules, classes in ((MCUNET_5FPS_VWW, 2),
                             (MCUNET_320KB_IMAGENET, 1000)):
        g = build_mcunet(modules, "net", num_classes=classes)
        g.validate()
        order = g.topo_order()
        assert order[0] == "in"
        assert g.nodes[g.output_id()].out.d == classes
        # every module appears with its full node run
        for cfg in modules:
            assert f"{cfg.name}.pw1" in g.nodes
            assert f"{cfg.name}.dw" in g.nodes
            assert f"{cfg.name}.pw2" in g.nodes
            assert (f"{cfg.name}.add" in g.nodes) == cfg.has_residual
        # adapters appear exactly where consecutive rows do not chain
        cur = g.nodes["in"].out
        for cfg in modules:
            if (cur.h, cur.d) != (cfg.hw, cfg.c_in):
                tid = next(i for i in g.nodes
                           if i.startswith("T")
                           and g.nodes[i].out.h == cfg.hw
                           and g.nodes[i].out.d == cfg.c_in)
                assert g.nodes[tid].kind == "conv_pw"
            last = (f"{cfg.name}.add" if cfg.has_residual
                    else f"{cfg.name}.pw2")
            cur = g.nodes[last].out


def test_build_mlp_tower_covers_every_registered_config():
    from repro.configs import ALL_ARCHS, get_config
    assert len(ALL_ARCHS) >= 5
    for name in ALL_ARCHS:
        cfg = get_config(name)
        g = build_mlp_tower(cfg, m_rows=4, n_layers=2)
        g.validate()
        kinds = [n.kind for n in g.nodes.values()]
        assert kinds == ["input"] + ["mlp"] * 2


def test_residual_add_shape_mismatch_rejected():
    g = Graph("bad")
    g.add("in", "input", [], Tensor(4, 8))
    g.add("a", "fc", ["in"], Tensor(4, 16))
    g.add("s", "add", ["a", "in"], Tensor(4, 16))
    with pytest.raises(ValueError, match="add shape mismatch"):
        g.validate()


def _diamond() -> Graph:
    """Residual diamond where the branch order changes the peak: the big
    chain's peak occurs mid-branch, so consuming the shared input with
    the SMALL branch first (Liberis & Lane reordering) wins."""
    g = Graph("diamond")
    g.add("in", "input", [], Tensor(1, 200))
    g.add("a1", "fc", ["in"], Tensor(1, 50))
    g.add("a2", "fc", ["a1"], Tensor(1, 400))
    g.add("a3", "fc", ["a2"], Tensor(1, 100))
    g.add("b1", "fc", ["in"], Tensor(1, 100))
    g.add("j", "add", ["a3", "b1"], Tensor(1, 100))
    return g


def test_reorder_beats_naive_topo_order_on_branches():
    g = _diamond()
    naive = ["in", "a1", "a2", "a3", "b1", "j"]
    assert peak_live_bytes(g, naive) == 700   # in held through A's peak
    order, peak = reorder(g)
    assert peak == 600                        # b1 first frees `in` early
    assert order.index("b1") < order.index("a2")
    assert peak == peak_live_bytes(g, order)


def test_standalone_add_rejected_at_grouping():
    """Free-form skip connections outside module groups fail loudly at
    fusion selection (the planner can only hold module-residual
    sources), not deep inside spec lowering."""
    g = _diamond()
    order, _ = reorder(g)
    with pytest.raises(ValueError, match="standalone residual adds"):
        select_groups(g, order)


def test_reorder_is_topological():
    g = build_mcunet(MCUNET_5FPS_VWW, "vww")
    order, peak = reorder(g)
    pos = {i: t for t, i in enumerate(order)}
    for n in g.nodes.values():
        for src in n.inputs:
            assert pos[src] < pos[n.id]
    assert peak > 0


def test_fusion_selection_matches_paper_exclusion_rule():
    """Per module: group mcu_bytes == vmcu_module_bytes (the byte
    formulas are now cross-checks of the graph path, not the source of
    truth); fused execution additionally requires the Fig.-6 kernel
    envelope (stride 1)."""
    for modules in (MCUNET_5FPS_VWW, MCUNET_320KB_IMAGENET):
        g = build_mcunet(modules, "net")
        order, _ = reorder(g)
        groups = {gr.name: gr for gr in select_groups(g, order)}
        for cfg in modules:
            gr = groups[cfg.name]
            assert gr.kind == "module"
            assert gr.mcu_bytes == vmcu_module_bytes(cfg)
            fused_wins = (plan_inverted_bottleneck(cfg).pool_bytes
                          <= plan_module_fallback(cfg))
            assert gr.fused_bytes_win == fused_wins
            if any(s != 1 for s in cfg.strides):
                assert not gr.fused_exec
            else:
                assert gr.fused_exec == fused_wins


def test_mlp_chain_grouping():
    from repro.configs import get_config
    cfg = get_config("gemma2-2b")
    g = build_mlp_tower(cfg, m_rows=4, n_layers=3)
    order, _ = reorder(g)
    groups = select_groups(g, order)
    assert len(groups) == 1
    assert groups[0].kind == "mlp_chain"
    assert len(groups[0].node_ids) == 3
