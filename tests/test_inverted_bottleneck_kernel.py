"""Fused inverted-bottleneck Pallas kernel (paper Fig. 6) vs oracle —
including the in-ring overlap (E overwrites consumed A rows)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.inverted_bottleneck import (inverted_bottleneck_ref,
                                               ring_inverted_bottleneck)

KEY = jax.random.PRNGKey(0)


def _run(H, Cin, Cmid, Cout, res, RS=3, halo_rows=3):
    W = H
    ks = jax.random.split(KEY, 4)
    a = jax.random.normal(ks[0], (H, W, Cin), jnp.float32)
    w1 = jax.random.normal(ks[1], (Cin, Cmid), jnp.float32) / np.sqrt(Cin)
    wd = jax.random.normal(ks[2], (RS, RS, Cmid), jnp.float32) * 0.3
    w2 = jax.random.normal(ks[3], (Cmid, Cout), jnp.float32) / np.sqrt(Cmid)
    seg_w = 128
    in_ptr = halo_rows * W           # Eq.-2 offset, row-aligned
    n_seg = in_ptr + H * W + W
    pool = jnp.zeros((n_seg, seg_w), jnp.float32)
    flat = jnp.pad(a.reshape(H * W, Cin), ((0, 0), (0, seg_w - Cin)))
    pool = pool.at[in_ptr:in_ptr + H * W].set(flat)
    pool = ring_inverted_bottleneck(pool, w1, wd, w2, H=H, W=W, C_in=Cin,
                                    C_mid=Cmid, C_out=Cout, RS=RS,
                                    in_ptr=in_ptr, out_ptr=0,
                                    residual=res, interpret=True)
    got = pool[:H * W, :Cout].reshape(H, W, Cout)
    want = inverted_bottleneck_ref(a, w1, wd, w2, residual=res)
    return got, want


@pytest.mark.parametrize("H,Cin,Cmid,Cout,res", [
    (8, 16, 48, 16, True),     # paper S1 shape family
    (6, 8, 24, 12, False),     # no residual (channel change)
    (10, 16, 32, 16, True),
    (5, 8, 16, 8, True),       # tiny image (paper S5-like)
])
def test_matches_oracle_with_ring_overlap(H, Cin, Cmid, Cout, res):
    got, want = _run(H, Cin, Cmid, Cout, res)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_intermediate_never_materialized():
    """The C_mid-wide tensor B exists only as an RS-row VMEM workspace —
    structurally guaranteed: the pool never holds a C_mid-wide row."""
    H, Cin, Cmid, Cout = 8, 16, 48, 16
    got, want = _run(H, Cin, Cmid, Cout, True)
    # pool segment width (128) < W * Cmid bytes per row proves B>pool rows;
    # the assertion of interest is simply numerical correctness above plus
    # the workspace shape in the kernel (RS rows), checked here statically.
    from repro.kernels import inverted_bottleneck as ib
    import inspect
    src = inspect.getsource(ib.ring_inverted_bottleneck)
    assert "pltpu.VMEM((RS, W, C_mid)" in src
