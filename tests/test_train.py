"""Training integration: loss goes down, restart determinism, microbatch
equivalence, straggler accounting."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_REGISTRY
from repro.launch.train import train_loop
from repro.models.registry import build_model
from repro.train.data import synthetic_batch
from repro.train.optimizer import AdamWConfig, lr_at
from repro.train.train_step import init_train_state, make_train_step

CFG = ARCH_REGISTRY["gemma3-1b"].reduced()


def test_loss_decreases_on_fixed_batch():
    model = build_model(CFG)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(
        model, opt=AdamWConfig(peak_lr=3e-3, warmup_steps=3,
                               total_steps=40)))
    batch = synthetic_batch(CFG, 4, 32, step=0)
    first = last = None
    for _ in range(25):
        state, m = step(state, batch)
        last = float(m["loss"])
        first = first if first is not None else last
    assert last < first * 0.8, (first, last)


def test_microbatch_grad_accum_matches_full_batch():
    model = build_model(CFG)
    state = init_train_state(model, jax.random.PRNGKey(1))
    batch = synthetic_batch(CFG, 8, 16, step=0)
    s_full, m_full = jax.jit(make_train_step(model))(state, batch)
    s_mb, m_mb = jax.jit(make_train_step(model, microbatches=4))(state, batch)
    for a, b in zip(jax.tree.leaves(s_full.params),
                    jax.tree.leaves(s_mb.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


def test_checkpoint_restart_is_bit_deterministic(tmp_path):
    """Kill after 6 steps, resume, and land on the same state as an
    uninterrupted run (checkpoint/restart fault tolerance)."""
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    r_full = train_loop(CFG, steps=10, batch=2, seq=16, ckpt_dir=d1,
                        ckpt_every=100, log_every=100)
    train_loop(CFG, steps=6, batch=2, seq=16, ckpt_dir=d2,
               ckpt_every=3, log_every=100)
    r_resumed = train_loop(CFG, steps=10, batch=2, seq=16, ckpt_dir=d2,
                           ckpt_every=100, log_every=100)
    assert np.isclose(r_full["final_loss"], r_resumed["final_loss"],
                      rtol=1e-5), (r_full, r_resumed)


def test_lr_schedule_shape():
    opt = AdamWConfig(peak_lr=1.0, warmup_steps=10, total_steps=100)
    assert float(lr_at(opt, jnp.asarray(0))) < 0.2
    assert np.isclose(float(lr_at(opt, jnp.asarray(10))), 1.0, atol=0.05)
    assert float(lr_at(opt, jnp.asarray(99))) < 0.01


def test_data_determinism_across_restarts():
    b1 = synthetic_batch(CFG, 4, 32, step=17)
    b2 = synthetic_batch(CFG, 4, 32, step=17)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = synthetic_batch(CFG, 4, 32, step=18)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
