"""End-to-end behaviour of the whole system (the paper's deployment story
plus the TPU framework wrapped around it)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (MCUNET_320KB_IMAGENET, motivational_example,
                        plan_gemm)
from repro.core.graph_planner import (hmcos_module_bytes,
                                      tinyengine_module_bytes,
                                      vmcu_module_bytes)
from repro.configs import ARCH_REGISTRY, cells_for
from repro.configs.base import LONG_500K


def test_paper_deployment_story_end_to_end():
    """The headline claim: MCUNet-320KB-ImageNet deploys on a 128 KB
    device under vMCU and under no tensor-level baseline."""
    ram = 128_000
    vmcu = max(vmcu_module_bytes(c) for c in MCUNET_320KB_IMAGENET)
    te = max(tinyengine_module_bytes(c) for c in MCUNET_320KB_IMAGENET)
    hm = max(hmcos_module_bytes(c) for c in MCUNET_320KB_IMAGENET)
    assert vmcu <= ram < te and ram < hm


def test_planner_to_kernel_pipeline():
    """Eq. (1) plan → ring pool → Pallas kernel → same numerics as BLAS."""
    from repro.kernels import ops
    from repro.kernels import ref
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 256))
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 128)) / 16
    y, info = ops.segment_gemm(x, w)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(ref.gemm_ref(x, w, jnp.zeros(128))),
        rtol=2e-5, atol=2e-5)
    assert info["pool_bytes"] < info["naive_bytes"]


def test_every_assigned_arch_registered_with_cells():
    assert len(ARCH_REGISTRY) == 10
    for name, cfg in ARCH_REGISTRY.items():
        cells = cells_for(cfg)
        assert 3 <= len(cells) <= 4, name
        assert (LONG_500K in cells) == cfg.sub_quadratic, name


def test_motivational_example_is_the_paper_figure():
    assert motivational_example() == (7, 10)


def test_single_layer_bound_is_respected():
    """Paper §5.2: single-layer saving is bounded by 50%."""
    for mnk in [(4, 4, 4), (16, 3, 9), (7, 11, 2)]:
        plan = plan_gemm(*mnk, segment_bytes=1)
        assert plan.pool_segments >= plan.naive_segments / 2


def test_train_then_serve_round_trip(tmp_path):
    """Train a tiny model, checkpoint it, reload it, serve with it."""
    from repro.checkpoint.manager import CheckpointManager
    from repro.launch.train import train_loop
    from repro.models.registry import build_model
    from repro.serve.engine import ServingEngine
    from repro.train.train_step import init_train_state

    cfg = ARCH_REGISTRY["gemma3-1b"].reduced()
    d = str(tmp_path / "ck")
    train_loop(cfg, steps=4, batch=2, seq=16, ckpt_dir=d, ckpt_every=2,
               log_every=100)
    model = build_model(cfg)
    like = jax.eval_shape(
        lambda: init_train_state(model, jax.random.PRNGKey(0)))
    state = CheckpointManager(d).restore(like)
    engine = ServingEngine(model, state.params, cache_len=48)
    out = engine.generate([[1, 2, 3, 4]], max_new=4)
    assert len(out[0]) == 4 and all(0 <= t < cfg.vocab for t in out[0])
