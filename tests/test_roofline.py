"""Roofline analysis: HLO collective parsing + term arithmetic."""
import numpy as np

from repro.roofline.analysis import (HBM_BW, ICI_BW, PEAK_FLOPS, Roofline,
                                     model_flops, parse_collectives)

HLO = """
HloModule jit_train_step
ENTRY main {
  %p0 = f32[16,128]{1,0} parameter(0)
  %ag = bf16[256,4096]{1,0} all-gather(%p0), dimensions={0}
  %ar = f32[1024]{0} all-reduce(%ag), to_apply=%add
  %rs = bf16[64,64]{1,0} reduce-scatter(%ar), dimensions={0}
  %a2a = bf16[32,32,8]{2,1,0} all-to-all(%rs), dimensions={0}
  %cp = f32[8]{0} collective-permute(%a2a), source_target_pairs={{0,1}}
  ROOT %ar2 = (f32[512]{0}, f32[256]{0}) all-reduce(%cp, %cp), to_apply=%add
}
"""


def test_parse_collectives_kinds_and_bytes():
    c = parse_collectives(HLO)
    assert c["all-gather"]["count"] == 1
    assert c["all-gather"]["bytes"] == 256 * 4096 * 2
    assert c["all-reduce"]["count"] == 2
    assert c["all-reduce"]["bytes"] == 1024 * 4 + (512 + 256) * 4
    assert c["reduce-scatter"]["bytes"] == 64 * 64 * 2
    assert c["all-to-all"]["bytes"] == 32 * 32 * 8 * 2
    assert c["collective-permute"]["bytes"] == 8 * 4


def test_roofline_terms_and_dominance():
    r = Roofline(flops_per_chip=PEAK_FLOPS,          # 1 s of compute
                 hbm_bytes_per_chip=HBM_BW / 2,      # 0.5 s of memory
                 collective_bytes_per_chip=0.0,
                 collectives={"all-reduce": {"count": 1,
                                             "bytes": ICI_BW / 4}})
    assert np.isclose(r.t_compute, 1.0)
    assert np.isclose(r.t_memory, 0.5)
    assert np.isclose(r.t_collective, 0.5)  # all-reduce factor 2x
    assert r.dominant == "compute"
    assert np.isclose(r.fraction_of_roofline(PEAK_FLOPS / 2), 0.5)


def test_model_flops_conventions():
    assert model_flops(10, 10, 100, "train") == 6 * 10 * 100
    assert model_flops(10, 4, 100, "prefill") == 2 * 4 * 100  # MoE active
