"""The static ring-safety verifier, proven against the sim clobber
oracle (DESIGN.md §11).

Four layers:

  * interval algebra unit tests (the modular clash primitives),
  * fast-path vs generic frontier extraction — identical ``_SchedInfo``
    for every op the zoo plans,
  * the differential fault-injection matrix: every deterministic
    mutation of solved plans (``repro.analysis.mutate``) must get the
    SAME verdict from ``verify_program`` and from replaying the
    schedule through the byte-accurate ``SegmentPool`` — no false-safe,
    no false-unsafe — plus a hypothesis layer of randomized corruption,
  * the certificate: stats identical to the sim pool's counters, inert
    under the fields execution never reads (``delta``, pool dtype), and
    a measured ≥x speedup over the replay on MCUNet-VWW.
"""
import dataclasses

import numpy as np
import pytest

from repro.analysis import (Diagnostic, VerifyResult, break_plan,
                            mutations, verify_program)
from repro.analysis.intervals import (first_static_clash,
                                      first_stream_clash, overlap)
from repro.analysis.verifier import (_SCHED_CACHE, _sched_info_build,
                                     _sched_info_build_generic)
from repro.core.executors import run_program_sim
from repro.core.pool import PoolClobberError
from repro.core.program import plan_module_program
from repro.core.rowsched import schedule_for_op
from repro.graph.ir import build_ds_cnn, build_resnet8
from repro.graph.netplan import _plan_net

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(not HAVE_HYPOTHESIS,
                                      reason="hypothesis not installed")


def _program(builder, dtype="float32"):
    return _plan_net(builder(), dtype=dtype).program


def _sim_verdict(program) -> bool:
    try:
        run_program_sim(program)
        return True
    except PoolClobberError:
        return False


# ---------------------------------------------------------------------------
# Interval algebra.
# ---------------------------------------------------------------------------

def test_overlap_modular():
    assert overlap(0, 3, 2, 3, 10)          # [0,3) x [2,5)
    assert not overlap(0, 3, 3, 3, 10)      # [0,3) x [3,6)
    assert overlap(8, 4, 0, 2, 10)          # [8,12) wraps onto [0,2)
    assert not overlap(8, 2, 0, 2, 10)
    assert overlap(0, 10, 5, 1, 10)         # full ring hits everything
    assert not overlap(0, 0, 0, 5, 10)      # empty run hits nothing


def test_first_static_clash_exact():
    # sweep [0,8) over a 3-long victim based 5 above, ring 16: the first
    # clash is write 5 on victim segment 0
    assert first_static_clash(8, 3, 5, 16) == (5, 0)
    # victim entirely above the sweep: no clash
    assert first_static_clash(8, 3, 9, 16) is None
    # wrap: delta 14, ring 16 — write 0 lands on victim segment 2
    assert first_static_clash(8, 3, 14, 16) == (0, 2)


def test_first_stream_clash_respects_frees():
    # two write steps, victim shrinks under the sweep: we=[2,4],
    # lo=[0,3], hi=4, delta=3, n=32.  Step 0 writes [0,2) with victim
    # live [3,7): no clash.  Step 1 writes [2,4) with victim [6,7):
    # no clash either (6 < 4 is false) -> None.
    we, lo = np.array([2, 4]), np.array([0, 3])
    assert first_stream_clash(we, lo, 4, 3, 32) is None
    # without the Eq.-(2) free (lo stuck at 0) step 1 clashes: first
    # write >= delta is w=3 on victim segment 0
    assert first_stream_clash(we, np.array([0, 0]), 4, 3, 32) == (1, 3, 0)


# ---------------------------------------------------------------------------
# Fast-path frontier extraction == generic event replay.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("builder", [build_ds_cnn, build_resnet8])
@pytest.mark.parametrize("dtype", ["float32", "int8"])
def test_fast_path_matches_generic(builder, dtype):
    program = _program(builder, dtype)
    for op in program.ops:
        rows = op.rows_in or program.m_rows
        fast = _sched_info_build(op, program.seg_width, program.m_rows)
        gen = _sched_info_build_generic(
            schedule_for_op(op, program.seg_width, m_rows=rows))
        assert fast.monotone_error is None
        for f in dataclasses.fields(fast):
            a, b = getattr(fast, f.name), getattr(gen, f.name)
            if isinstance(a, np.ndarray):
                assert np.array_equal(a, b), (op.kind, f.name)
            else:
                assert a == b, (op.kind, f.name)


# ---------------------------------------------------------------------------
# Solved plans verify; certificates mirror the sim counters.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("builder", [build_ds_cnn, build_resnet8])
@pytest.mark.parametrize("dtype", ["float32", "int8"])
def test_solved_plans_prove_safe_with_sim_stats(builder, dtype):
    program = _program(builder, dtype)
    res = verify_program(program)
    assert res.safe is True and not res.diagnostics
    sim = run_program_sim(program)
    assert res.stats == {"peak_live": sim.peak_live, "reads": sim.reads,
                         "writes": sim.writes,
                         "n_segments": program.n_segments}
    cert = res.certificate("ab" * 32)
    assert cert["clobbers"] == 0 and cert["program_sha256"] == "ab" * 32


def test_verdict_inert_fields():
    """delta and the pool dtype are never read by execution; neither may
    flip the verdict (the VMCU401/402 *lint* owns dtype consistency)."""
    program = _program(build_ds_cnn)
    ops = tuple(dataclasses.replace(op, delta=op.delta + 3)
                for op in program.ops)
    assert verify_program(
        dataclasses.replace(program, ops=ops)).safe is True
    assert verify_program(program.with_dtype("int8")).safe is True
    assert verify_program(program.with_dtype("bfloat16")).safe is True


def test_plan_only_program_is_inconclusive():
    from repro.core.graph_planner import MCUNET_5FPS_VWW

    res = verify_program(plan_module_program(MCUNET_5FPS_VWW[1]))
    assert res.safe is None
    assert [d.code for d in res.diagnostics] == ["VMCU105"]
    assert res.diagnostics[0].severity == "warning"
    with pytest.raises(ValueError):
        res.certificate()


def test_break_plan_is_unsafe_both_ways():
    program = _program(build_ds_cnn)
    mut = break_plan(program)
    res = verify_program(mut.program)
    assert res.safe is False and not _sim_verdict(mut.program)
    d = res.diagnostics[0]
    assert d.code in ("VMCU101", "VMCU102", "VMCU103", "VMCU104")
    assert d.code in str(d)


def test_unsafe_diagnostic_pinpoints_first_clobbered_byte():
    """The derived (step, slot, byte) must be the sim oracle's actual
    first failure site."""
    program = _program(build_ds_cnn)
    mut = break_plan(program)
    d = verify_program(mut.program).diagnostics[0]
    assert d.byte == d.segment * program.seg_width * program.elem_bytes
    try:
        run_program_sim(mut.program)
        pytest.fail("sim accepted a plan the verifier rejected")
    except PoolClobberError as e:
        assert f"pool[{d.segment}]" in str(e)


# ---------------------------------------------------------------------------
# The differential fault-injection matrix (>= 200 mutants).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("builder", [build_ds_cnn, build_resnet8])
def test_differential_mutation_matrix(builder):
    program = _program(builder)
    n_checked = n_unsafe = 0
    for mut in mutations(program):
        res = verify_program(mut.program)
        assert res.safe is not None, f"{mut.tag}: verifier gave up"
        sim_safe = _sim_verdict(mut.program)
        assert res.safe == sim_safe, (
            f"{mut.tag}: static={res.safe} sim={sim_safe}")
        n_checked += 1
        n_unsafe += not sim_safe
    # the deterministic matrix alone covers >= 200 corrupted plans
    # (158 on ds-cnn + 148 on resnet-8), a healthy mix of both verdicts
    assert n_checked >= 100
    assert 0 < n_unsafe < n_checked


if HAVE_HYPOTHESIS:
    @needs_hypothesis
    @settings(max_examples=60, deadline=None)
    @given(st.data())
    def test_differential_random_corruption(data):
        program = _program(build_ds_cnn)
        i = data.draw(st.integers(0, len(program.ops) - 1), label="op")
        field = data.draw(st.sampled_from(
            ["in_ptr", "out_ptr", "aux_ptr", "hold_input",
             "n_segments"]), label="field")
        shift = data.draw(st.integers(-2 * program.n_segments,
                                      2 * program.n_segments),
                          label="shift")
        op = program.ops[i]
        if field == "n_segments":
            n = max(1, program.n_segments + shift)
            mutant = dataclasses.replace(program, n_segments=n)
        elif field == "hold_input":
            mutant = _replace_op(program, i,
                                 hold_input=not op.hold_input)
        elif field == "aux_ptr" and op.aux_op < 0:
            mutant = program
        else:
            mutant = _replace_op(program, i,
                                 **{field: getattr(op, field) + shift})
        res = verify_program(mutant)
        assert res.safe is not None
        assert res.safe == _sim_verdict(mutant)


def _replace_op(program, i, **changes):
    ops = list(program.ops)
    ops[i] = dataclasses.replace(ops[i], **changes)
    return dataclasses.replace(program, ops=tuple(ops))


# ---------------------------------------------------------------------------
# Diagnostics & structure.
# ---------------------------------------------------------------------------

def test_diagnostic_str_carries_location():
    d = Diagnostic(code="VMCU101", message="m", op_index=3, step=7,
                   segment=11, byte=1408)
    assert str(d) == "VMCU101 [op 3, step 7, slot 11, byte 1408]: m"


def test_verify_result_error_filter():
    r = VerifyResult(safe=None, diagnostics=[
        Diagnostic(code="VMCU105", message="w", severity="warning")])
    assert r.errors == []


# ---------------------------------------------------------------------------
# The point of the static path: it is much faster than the replay.
# ---------------------------------------------------------------------------

def test_static_proof_beats_sim_replay_on_vww():
    import time

    from repro.graph.ir import build_mcunet
    from repro.core.graph_planner import MCUNET_5FPS_VWW

    g = build_mcunet(MCUNET_5FPS_VWW, "mcunet-5fps-vww", num_classes=2)
    program = _plan_net(g, dtype="int8").program

    def best_of(fn, n=3):
        times = []
        for _ in range(n):
            t0 = time.perf_counter()
            fn()
            times.append(time.perf_counter() - t0)
        return min(times)

    t_sim = best_of(lambda: run_program_sim(program))
    verify_program(program)  # geometry cache warm, as after compile()
    t_static = best_of(lambda: verify_program(program))
    assert verify_program(program).safe is True
    # acceptance: >= 10x on MCUNet-VWW; assert 5x here to keep the
    # gate robust on noisy CI runners (the benchmark records the ratio)
    assert t_static * 5 <= t_sim, (t_static, t_sim)


def test_sched_cache_is_geometry_keyed():
    _SCHED_CACHE.clear()
    program = _program(build_ds_cnn)
    verify_program(program)
    n1 = len(_SCHED_CACHE)
    assert 0 < n1 <= len(program.ops)
    verify_program(_program(build_ds_cnn, "int8"))  # same geometry
    assert len(_SCHED_CACHE) == n1
