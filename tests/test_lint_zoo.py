"""Zoo-wide lint conformance: every model-zoo artifact (fp32 + int8)
is accepted by ``vmcu-lint``, the ``certify="static"`` certificate
roundtrips bit-identically through save/load, and a static-certified
artifact is byte-identical to a sim-certified one (modulo pass
timings).  Plus the VMCU4xx/5xx rejection paths on a real artifact.
"""
import json

import pytest

import repro
from repro.analysis import lint_artifact, lint_c_dir
from repro.analysis.cli import main as lint_main

#: (net, target, dtype) — the conformance matrix the zoo ships.
COMBOS = [(net, tgt, dt)
          for net, tgt in (("mcunet-5fps-vww", "cortex-m4"),
                           ("mcunet-320kb-imagenet", "cortex-m7"),
                           ("ds-cnn", "cortex-m4"),
                           ("ds-cnn-stream", "cortex-m4"),
                           ("ad-toyadmos", "cortex-m4"),
                           ("resnet-8", "cortex-m4"),
                           ("mobilenetv1-0.25", "cortex-m4"))
          for dt in ("float32", "int8")]
_IDS = [f"{n}-{d}" for n, _, d in COMBOS]


def _compile(net, target, dtype, certify):
    # fp32 artifacts compile against host-sim (the zoo's fp32 lane);
    # int8 against the real MCU target.  quantize=False keeps the
    # matrix affordable — the ring, certificate and artifact layout are
    # what's under test, and the full-quantization path is covered by
    # the dedicated VWW test below.
    if dtype == "float32":
        return repro.compile(net, "host-sim", dtype=dtype,
                             certify=certify)
    return repro.compile(net, target, dtype=dtype, quantize=False,
                         certify=certify)


@pytest.mark.slow
@pytest.mark.parametrize("net,target,dtype", COMBOS, ids=_IDS)
def test_zoo_artifact_lints_clean_and_cert_roundtrips(net, target, dtype,
                                                      tmp_path):
    cn = _compile(net, target, dtype, certify="static")
    cert = cn.certificate
    assert cert["clobbers"] == 0 and len(cert["program_sha256"]) == 64
    note = next(p.note for p in cn.passes if p.name == "certify")
    assert note.startswith("static proof"), note
    assert "lint" in [p.name for p in cn.passes]

    path = str(tmp_path / "plan.json")
    cn.save(path)
    rep = lint_artifact(path)
    assert rep.clean and rep.result.safe is True, \
        [str(d) for d in rep.result.diagnostics]
    assert lint_main([path]) == 0

    rt = repro.load(path)
    assert rt.certificate == cert  # bit-identical through save/load


@pytest.mark.slow
@pytest.mark.parametrize("net,target,dtype", COMBOS, ids=_IDS)
def test_static_and_sim_artifacts_byte_identical(net, target, dtype,
                                                 tmp_path):
    p_sim = str(tmp_path / "sim.json")
    p_static = str(tmp_path / "static.json")
    _compile(net, target, dtype, certify="sim").save(p_sim)
    _compile(net, target, dtype, certify="static").save(p_static)
    a, b = (json.load(open(p)) for p in (p_sim, p_static))
    for d in (a, b):  # only the pass/span timings may differ
        d.pop("passes"), d.pop("spans")
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


# ---------------------------------------------------------------------------
# Full-quantization VWW artifact: the rejection paths, end to end.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def vww_int8(tmp_path_factory):
    cn = repro.compile("mcunet-5fps-vww", "cortex-m4", certify="static")
    path = str(tmp_path_factory.mktemp("vww") / "vww.plan.json")
    cn.save(path)
    return cn, path


def test_quantized_vww_artifact_lints_clean(vww_int8):
    cn, path = vww_int8
    rep = lint_artifact(path)
    assert rep.clean and rep.result.safe is True
    assert rep.dtype == "int8" and rep.net == "mcunet-5fps-vww"


def test_tampered_artifact_rejected_with_code(vww_int8, tmp_path):
    _, path = vww_int8
    payload = json.load(open(path))
    payload["program"]["ops"][2]["out_ptr"] += 1
    bad = str(tmp_path / "tampered.json")
    json.dump(payload, open(bad, "w"))
    rep = lint_artifact(bad)
    codes = {d.code for d in rep.result.errors}
    assert not rep.clean and "VMCU403" in codes  # hash catches the edit
    assert lint_main([bad]) == 1
    with pytest.raises(repro.CompileError, match="VMCU403"):
        repro.load(bad)


def test_quant_payload_dtype_mismatch_vmcu404(vww_int8, tmp_path):
    _, path = vww_int8
    payload = json.load(open(path))
    payload["dtype"] = "float32"
    payload["program"]["dtype"] = "float32"
    payload["program"]["elem_bytes"] = 4
    for op in payload["program"]["ops"]:
        op["segment_bytes"] = 4 * payload["program"]["seg_width"]
    payload.pop("certificate")  # sidestep the hash check on purpose
    payload["certificate"] = None
    bad = str(tmp_path / "retyped.json")
    json.dump(payload, open(bad, "w"))
    rep = lint_artifact(bad)
    assert "VMCU404" in {d.code for d in rep.result.errors}


def test_emitted_c_staleness_vmcu5xx(vww_int8, tmp_path):
    cn, path = vww_int8
    cdir = tmp_path / "c"
    cn.emit_c(str(cdir), geometry_only=True)
    assert lint_c_dir(cn.program, cdir, name=cn.net_name) == []
    # full requant emission of the SAME plan also lints clean
    cn.emit_c(str(cdir))
    assert lint_c_dir(cn.program, cdir, name=cn.net_name) == []
    assert lint_main([path, "--c-dir", str(cdir)]) == 0

    units = sorted(cdir.glob("*.c"))
    drifted = units[0].read_text().replace("POOL_SEGS 900",
                                           "POOL_SEGS 896")
    units[0].write_text(drifted)            # VMCU501: re-solved ring
    units[1].unlink()                       # VMCU502: missing unit
    (cdir / "stale_extra_op.c").write_text("// leftover\n")  # VMCU503
    diags = lint_c_dir(cn.program, cdir, name=cn.net_name)
    codes = [d.code for d in diags]
    assert sorted(set(codes)) == ["VMCU501", "VMCU502", "VMCU503"]
    assert lint_main([path, "--c-dir", str(cdir)]) == 1
