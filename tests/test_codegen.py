"""Paper §6 compiler layer: intrinsic codegen from plans."""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.codegen import (INTRINSICS, emit_fc_kernel,
                                validate_kernel_source)
from repro.core.planner import plan_gemm


def test_emitted_kernel_structure():
    plan = plan_gemm(4, 2, 3, segment_bytes=16)
    src = emit_fc_kernel(plan, 4, 2, 3)
    assert validate_kernel_source(src)
    for name in INTRINSICS:
        assert name in src
    # the solved Eq.(1) pointers are baked in
    assert f"In@{plan.delta}" in src
    assert "Out@0" in src
    assert f"#define POOL_SEGS {plan.pool_segments}" in src


@given(st.integers(1, 6), st.integers(1, 6), st.integers(1, 6))
@settings(max_examples=20, deadline=None)
def test_codegen_valid_for_any_plan(m, n, k):
    plan = plan_gemm(m, n, k, segment_bytes=8)
    assert validate_kernel_source(emit_fc_kernel(plan, m, n, k))


def test_plan_dim_mismatch_rejected():
    plan = plan_gemm(4, 2, 3, segment_bytes=16)
    with pytest.raises(ValueError):
        emit_fc_kernel(plan, 5, 2, 3)
