"""Paper §6 compiler layer: intrinsic codegen from plans and programs."""
import pathlib

import pytest

from repro.core.codegen import (INTRINSICS, emit_fc_kernel, emit_program,
                                validate_kernel_source)
from repro.core.graph_planner import MCUNET_5FPS_VWW
from repro.core.planner import plan_gemm
from repro.core.program import (AvgPoolSpec, ConvDWSpec, ConvPWSpec,
                                ElementwiseSpec, FusedMLPSpec, GemmSpec,
                                IBModuleSpec, ResidualAddSpec,
                                plan_program)

GOLDEN = pathlib.Path(__file__).parent / "golden"


def test_emitted_kernel_structure():
    plan = plan_gemm(4, 2, 3, segment_bytes=16)
    src = emit_fc_kernel(plan, 4, 2, 3)
    assert validate_kernel_source(src)
    for name in INTRINSICS:
        assert name in src
    # the solved Eq.(1) pointers are baked in
    assert f"In@{plan.delta}" in src
    assert "Out@0" in src
    assert f"#define POOL_SEGS {plan.pool_segments}" in src


def test_plan_dim_mismatch_rejected():
    plan = plan_gemm(4, 2, 3, segment_bytes=16)
    with pytest.raises(ValueError):
        emit_fc_kernel(plan, 5, 2, 3)


# ---------------------------------------------------------------------------
# emit_program: one translation unit per op, golden-file pinned.
# ---------------------------------------------------------------------------

def _mini_net_program():
    """Unfused residual module + head: covers conv_pw / conv_dw / add /
    pool_avg / gemm units with nontrivial solved offsets."""
    H, C, CM = 6, 32, 48
    return plan_program(H * H, C,
                        [ConvPWSpec(H, H, C, CM, activation="relu"),
                         ConvDWSpec(H, H, CM, rs=3, activation="relu"),
                         ConvPWSpec(H, H, CM, C),
                         ResidualAddSpec(3),
                         AvgPoolSpec(H, H, C),
                         GemmSpec(4)],
                        block_rows=1)


def _fused_program():
    """ib_fused + fused_mlp + elementwise units."""
    cfg = MCUNET_5FPS_VWW[0]
    return plan_program(400, 16, [IBModuleSpec(cfg)], block_rows=1)


def _quantized_program_and_qparams():
    """The mini net re-typed int8 with fixed (RNG-free) requant
    constants — pins the requant-table emission byte-for-byte."""
    import numpy as np

    prog = _mini_net_program().with_dtype("int8")
    qparams = []
    for i, op in enumerate(prog.ops):
        if op.kind in ("gemm", "conv_pw", "conv_dw"):
            mult = np.arange(op.d_out, dtype=np.int32) + (1 << 30) + i
            shift = np.full(op.d_out, -3 + i, np.int32)
            qparams.append((None, None, mult, shift))
        elif op.kind == "add":
            qparams.append(((1 << 30) + 7, -1, (1 << 30) + 11, -2))
        elif op.kind == "pool_avg":
            qparams.append(((1 << 30) + 13, -5))
    return prog, qparams


def test_emit_program_structure():
    units = emit_program(_mini_net_program(), "mini")
    assert len(units) == 6
    kinds = [name.split("_", 2)[2][:-2] for name in units]
    assert kinds == ["conv_pw", "conv_dw", "conv_pw", "add", "pool_avg",
                     "gemm"]
    for src in units.values():
        assert "WRAP(" in src and "#define POOL_SEGS" in src
        assert "RAMLoad" in src and "RAMStore" in src
        assert "RAMFree" in src
    # the residual unit reads the held source and frees it there
    add_src = units["mini_op03_add.c"]
    assert "Res@" in add_src and "residual source dies here" in add_src


def test_emit_program_matches_golden_files():
    """The emitted translation units are pinned byte-for-byte: any change
    to the solved offsets or the intrinsic skeletons must be reviewed by
    regenerating tests/golden/ (see test docstring)."""
    units = emit_program(_mini_net_program(), "mini")
    units.update(emit_program(_fused_program(), "fused"))
    for name, src in units.items():
        golden = GOLDEN / name
        assert golden.exists(), f"missing golden file {name}; regenerate " \
            "with tests/golden/regen.py"
        assert src == golden.read_text(), f"{name} drifted from golden"


def test_resnet8_geometry_units_match_golden_files():
    """The ResNet-8 int8 deployment plan's ring-geometry units (conv_k2d
    halo loops, branch shortcut conv, post-add relu) are pinned
    byte-for-byte under tests/golden/resnet8/ — the CI freshness gate
    (regen.py + git diff) keeps them honest."""
    import repro

    cn = repro.compile("resnet-8", target="cortex-m4", quantize=False,
                       certify=False)
    units = cn.emit_c(geometry_only=True, name="resnet8")
    assert sum("conv_k2d" in n for n in units) == 7
    assert sum("add" in n for n in units) == 3
    golden_dir = GOLDEN / "resnet8"
    for name, src in units.items():
        golden = golden_dir / name
        assert golden.exists(), f"missing golden file {name}; regenerate " \
            "with tests/golden/regen.py"
        assert src == golden.read_text(), f"{name} drifted from golden"
    # no stale goldens lingering as if still covered
    assert {p.name for p in golden_dir.glob("*.c")} == set(units)


def test_emit_quantized_program_bakes_requant_constants():
    prog, qparams = _quantized_program_and_qparams()
    units = emit_program(prog, "qmini", quant=qparams)
    assert len(units) == 6
    pw = units["qmini_op00_conv_pw.c"]
    assert "static const int32_t op00_conv_pw_mult[48]" in pw
    assert "static const int32_t op00_conv_pw_shift[48]" in pw
    assert "Requant(acc" in pw and "op00_conv_pw_requant" in pw
    assert "VQRDMULH" in pw            # the MVE/Helium idiom note
    add = units["qmini_op03_add.c"]    # scalar pair per operand
    assert "op03_add_mult[2]" in add
    pool = units["qmini_op04_pool_avg.c"]
    assert "op04_pool_avg_mult[1]" in pool
    # the shared intrinsic structure is untouched by the quant prologue
    for src in units.values():
        assert "WRAP(" in src and "RAMStore" in src


def test_emit_quantized_program_requires_qparams():
    prog, qparams = _quantized_program_and_qparams()
    with pytest.raises(ValueError, match="qparams"):
        emit_program(prog, "qmini")
    with pytest.raises(ValueError, match="entries"):
        emit_program(prog, "qmini", quant=qparams[:-1])


def test_quantized_units_match_golden_files():
    prog, qparams = _quantized_program_and_qparams()
    units = emit_program(prog, "qmini", quant=qparams)
    for name, src in units.items():
        golden = GOLDEN / name
        assert golden.exists(), f"missing golden file {name}; regenerate " \
            "with tests/golden/regen.py"
        assert src == golden.read_text(), f"{name} drifted from golden"


def test_emit_program_rejects_plan_only():
    from repro.core.program import plan_module_program
    with pytest.raises(ValueError, match="executable"):
        emit_program(plan_module_program(MCUNET_5FPS_VWW[0]))


def test_fused_mlp_and_elementwise_units():
    prog = plan_program(8, 256, [FusedMLPSpec(512, ff_tile=256),
                                 ElementwiseSpec("relu")], block_rows=8)
    units = emit_program(prog, "mlp")
    assert "d_ff=512" in units["mlp_op00_fused_mlp.c"]
    assert "elementwise relu" in units["mlp_op01_elementwise.c"]


# ---------------------------------------------------------------------------
# Property test (requires hypothesis).
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @given(st.integers(1, 6), st.integers(1, 6), st.integers(1, 6))
    @settings(max_examples=20, deadline=None)
    def test_codegen_valid_for_any_plan(m, n, k):
        plan = plan_gemm(m, n, k, segment_bytes=8)
        assert validate_kernel_source(emit_fc_kernel(plan, m, n, k))
