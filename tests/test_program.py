"""The unified PoolProgram API: one plan object, three backends.

Covers the redesign's acceptance criteria: a multi-op program (gemm chain +
fused MLP) executes on ``sim``/``jnp``/``pallas`` from the same plan
object, jnp and pallas agree, sim is clobber-free at the solved deltas and
clobbers at delta-1, and footprints match the legacy planners bit-for-bit.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ElementwiseSpec, FusedMLPSpec, GemmSpec,
                        PoolClobberError, execute, executor_names,
                        plan_chain, plan_gemm, plan_module_program,
                        plan_program, plan_stream_chain_program,
                        register_executor, run_program, segments_for)
from repro.core.executors import _EXECUTORS
from repro.core.graph_planner import (MCUNET_5FPS_VWW, plan_fc_chain,
                                      plan_inverted_bottleneck)
from repro.core.planner import gemm_offset_closed_form
from repro.kernels import ref

KEY = jax.random.PRNGKey(0)
M, D = 16, 256
DIMS = [256, 384, 256]
D_FF = 512


def _three_op_program(block_rows=8, **kw):
    """gemm(gelu) -> gemm -> fused MLP: the acceptance-criteria program."""
    return plan_program(M, DIMS[0],
                        [GemmSpec(DIMS[1], activation="gelu"),
                         GemmSpec(DIMS[2]),
                         FusedMLPSpec(D_FF, ff_tile=256)],
                        block_rows=block_rows, **kw)


def _three_op_params():
    ks = jax.random.split(KEY, 8)
    w1 = jax.random.normal(ks[0], (DIMS[0], DIMS[1])) / 16
    b1 = jax.random.normal(ks[1], (DIMS[1],))
    w2 = jax.random.normal(ks[2], (DIMS[1], DIMS[2])) / 19
    b2 = jax.random.normal(ks[3], (DIMS[2],))
    wg = jax.random.normal(ks[4], (DIMS[2], D_FF)) / 16
    wu = jax.random.normal(ks[5], (DIMS[2], D_FF)) / 16
    wd = jax.random.normal(ks[6], (D_FF, DIMS[2])) / 22
    x = jax.random.normal(ks[7], (M, DIMS[0]))
    return x, [(w1, b1), (w2, b2), (wg, wu, wd)]


def _three_op_reference(x, params):
    (w1, b1), (w2, b2), (wg, wu, wd) = params
    h = jax.nn.gelu(ref.gemm_ref(x, w1, b1))
    h = ref.gemm_ref(h, w2, b2)
    return ref.fused_mlp_ref(h, wg, wu, wd)


# (Per-op cross-backend equivalence now lives in ONE place — the
# exhaustive tests/test_conformance_matrix.py grid.  This file keeps the
# multi-op chain below because it additionally pins the CHAINED offsets
# of one plan object across backends.)
def test_cross_backend_equivalence_of_chained_plan():
    """Same >=3-op plan object on sim, jnp AND pallas — cross-op offset
    chaining, not per-op math (that's the conformance matrix's job)."""
    program = _three_op_program()
    x, params = _three_op_params()

    sim = execute(program, backend="sim")  # must NOT raise PoolClobberError
    assert sim.peak_live <= program.n_segments

    y_jnp, _ = run_program(program, x, params, backend="jnp")
    y_pal, _ = run_program(program, x, params, backend="pallas")
    np.testing.assert_allclose(np.asarray(y_jnp), np.asarray(y_pal),
                               rtol=1e-5, atol=1e-5)
    want = _three_op_reference(x, params)
    np.testing.assert_allclose(np.asarray(y_jnp), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_program_footprint_matches_legacy_planners():
    """Acceptance: no footprint regression — pool_bytes equals the legacy
    planners' values for the same shapes."""
    program = _three_op_program()
    legacy_chain = plan_chain(M, DIMS)  # the gemm part, legacy API
    mlp_span = M * segments_for(DIMS[2])  # in-place fused MLP, delta == 0
    expected_segments = max(legacy_chain.n_segments, mlp_span)
    assert program.pool_segments == expected_segments
    assert program.pool_bytes == expected_segments * 128 * 4
    # the tight metric is block_rows-invariant
    assert _three_op_program(block_rows=None).pool_segments \
        == program.pool_segments


def test_single_gemm_program_matches_plan_gemm():
    """plan_program subsumes plan_gemm (Eq. 1 closed form, segment units)."""
    for m, n, k in [(2, 2, 3), (8, 4, 6), (7, 11, 2), (16, 3, 9)]:
        prog = plan_program(m, k, [GemmSpec(n)], seg_width=1,
                            block_rows=None)
        plan = plan_gemm(m, n, k, segment_bytes=1, validate=True)
        assert prog.ops[0].delta == plan.delta
        assert prog.pool_segments == plan.pool_segments
        assert prog.naive_bytes // 4 == plan.naive_segments


def test_plan_chain_adapter_reproduces_legacy_loop():
    """The ChainPlan adapter must chain pointers exactly as the original
    per-layer loop did (verbatim reimplementation below)."""
    for m, dims, sw in [(8, [96, 384, 96, 64], 32),
                        (16, [64, 256, 64], 32),
                        (64, [256, 1024, 256], 128),
                        (3, [40, 40, 40], 16)]:
        ptrs, in_ptr, max_span = [], 0, 0
        for d_in, d_out in zip(dims[:-1], dims[1:]):
            k_segs = segments_for(d_in, sw)
            n_segs = segments_for(d_out, sw)
            delta = gemm_offset_closed_form(m, n_segs, k_segs)
            out_ptr = in_ptr - delta
            span = (max(in_ptr + m * k_segs, out_ptr + m * n_segs)
                    - min(in_ptr, out_ptr))
            max_span = max(max_span, span)
            ptrs.append((in_ptr, out_ptr))
            in_ptr = out_ptr
        plan = plan_chain(m, dims, seg_width=sw)
        assert plan.layer_ptrs == tuple(ptrs)
        assert plan.n_segments == max_span


def test_sim_clobbers_at_delta_minus_one():
    """Tightness: the solved deltas are exact optima — shrinking every op's
    offset by one segment must clobber a live segment in the oracle."""
    layers = [GemmSpec(64, activation="gelu"), GemmSpec(32)]
    safe = plan_program(8, 48, layers, seg_width=16, block_rows=None)
    execute(safe, backend="sim")  # exact plan: no clobber
    tight = plan_program(8, 48, layers, seg_width=16, block_rows=None,
                         delta_slack=1)
    with pytest.raises(PoolClobberError):
        execute(tight, backend="sim")


def test_sim_clobbers_at_delta_minus_one_with_inplace_op():
    """Same, for a program ending in an in-place (delta == 0) op."""
    layers = [GemmSpec(64), ElementwiseSpec("relu")]
    execute(plan_program(8, 48, layers, seg_width=16), backend="sim")
    tight = plan_program(8, 48, layers, seg_width=16, delta_slack=1)
    with pytest.raises(PoolClobberError):
        execute(tight, backend="sim")


# (test_elementwise_op_runs_on_all_backends retired: subsumed by the
# elementwise row of tests/test_conformance_matrix.py.)


def test_plan_only_programs_match_legacy_eq2_planners():
    """plan_program subsumes plan_inverted_bottleneck and plan_fc_chain."""
    for cfg in MCUNET_5FPS_VWW[:3]:
        prog = plan_module_program(cfg)
        assert prog.pool_bytes == plan_inverted_bottleneck(cfg).pool_bytes
        assert not prog.executable
        with pytest.raises(NotImplementedError):
            execute(prog, backend="sim")
    dims = [64, 256, 64]
    prog = plan_stream_chain_program(32, dims)
    assert prog.pool_bytes == plan_fc_chain(32, dims).pool_bytes


def test_executor_registry_is_pluggable():
    assert set(executor_names()) >= {"sim", "jnp", "pallas"}
    with pytest.raises(ValueError, match="unknown backend"):
        execute(_three_op_program(), backend="nope")

    @register_executor("_counting")
    def _count(program, pool, params, **kw):
        return len(program.ops)

    try:
        assert execute(_three_op_program(), backend="_counting") == 3
    finally:
        del _EXECUTORS["_counting"]


def test_jnp_backend_works_unaligned_and_any_seg_width():
    """block_rows=None programs (tight geometry) run on jnp/sim; the pallas
    backend refuses them with a helpful error."""
    program = plan_program(6, 48, [GemmSpec(64, "gelu"), GemmSpec(32)],
                           seg_width=16, block_rows=None)
    x = jax.random.normal(KEY, (6, 48))
    ks = jax.random.split(KEY, 2)
    params = [(jax.random.normal(ks[0], (48, 64)) / 7, None),
              (jax.random.normal(ks[1], (64, 32)) / 8, None)]
    execute(program, backend="sim")
    y, _ = run_program(program, x, params, backend="jnp")
    want = ref.gemm_ref(jax.nn.gelu(ref.gemm_ref(x, params[0][0],
                                                 jnp.zeros(64))),
                        params[1][0], jnp.zeros(32))
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
    with pytest.raises(ValueError, match="aligned"):
        run_program(program, x, params, backend="pallas")


def test_coalesced_schedule_preserves_aggregate_counters():
    """RowSchedule.coalesced(b) groups b consecutive steps into one
    super-step without changing any aggregate counter — the invariant
    that keeps block-granular execution under the same certificate."""
    from repro.core.rowsched import conv_pw_schedule, gemm_fine_schedule

    for sched, block in ((conv_pw_schedule(12, 12, 3, 2, stride=1), 4),
                         (conv_pw_schedule(12, 6, 3, 2, stride=2), 3),
                         (gemm_fine_schedule(8, 2, 1), 2)):
        co = sched.coalesced(block)
        assert co.steps == -(-sched.steps // block)
        flat = lambda seq: [r for rows in seq for r in rows]
        assert flat(co.reads) == flat(sched.reads)
        assert flat(co.writes) == flat(sched.writes)
        assert (co.in_chunk, co.out_chunk) == (sched.in_chunk,
                                               sched.out_chunk)
    assert sched.coalesced(1) is sched
    with pytest.raises(ValueError):
        sched.coalesced(0)


def test_op_grid_steps_divisor_rule():
    from repro.core.program import op_grid_steps

    program = plan_program(8, 32, [GemmSpec(32)])
    op = program.ops[0]
    assert op_grid_steps(op) == 8
    assert op_grid_steps(op, 4) == 2
    with pytest.raises(ValueError):
        op_grid_steps(op, 3)
    with pytest.raises(ValueError):
        op_grid_steps(op, 0)
