"""Gradient-compression collectives + int8 codec."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.parallel.collectives import (bucketed_psum, compressed_psum,
                                        dequantize_int8, quantize_int8)


def test_int8_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (128,)) * 3.0
    q, s = quantize_int8(x)
    err = jnp.max(jnp.abs(dequantize_int8(q, s) - x))
    assert float(err) <= float(s) * 0.5 + 1e-6


def _one_device_mesh():
    return Mesh(np.array(jax.devices()[:1]), ("dp",))


def test_compressed_psum_single_participant_identity():
    mesh = _one_device_mesh()
    x = jax.random.normal(jax.random.PRNGKey(1), (64,))
    f = shard_map(functools.partial(compressed_psum, axis_name="dp"),
                  mesh=mesh, in_specs=P(), out_specs=P())
    y = f(x)
    # single participant: the only error is quantization (<= scale/2)
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    np.testing.assert_allclose(np.asarray(y), np.asarray(x),
                               atol=scale * 0.51 + 1e-7)


def test_bucketed_psum_preserves_tree():
    mesh = _one_device_mesh()
    tree = {"w": jnp.ones((130,)), "b": jnp.arange(7, dtype=jnp.float32)}
    f = shard_map(
        functools.partial(bucketed_psum, axis_name="dp", bucket_bytes=256),
        mesh=mesh, in_specs=P(), out_specs=P())
    out = f(tree)
    np.testing.assert_allclose(np.asarray(out["w"]), np.ones(130), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out["b"]),
                               np.arange(7, dtype=np.float32), rtol=1e-6)


def test_compression_wire_bytes():
    """int8 payload is 4x smaller than fp32 (8x vs bf16 grads upcast)."""
    x = jnp.ones((1024,), jnp.float32)
    q, _ = quantize_int8(x)
    assert q.dtype == jnp.int8 and q.nbytes * 4 == x.nbytes
