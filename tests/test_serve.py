"""Serving engine: batched generation, ring-cache equivalence, greedy
determinism."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_REGISTRY
from repro.models.registry import build_model
from repro.serve.engine import ServingEngine

KEY = jax.random.PRNGKey(3)


def _engine(arch="gemma2-2b", cache_len=64):
    cfg = ARCH_REGISTRY[arch].reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    return cfg, model, params, ServingEngine(model, params,
                                             cache_len=cache_len)


def test_batched_generation_runs():
    cfg, model, params, eng = _engine()
    prompts = [[1, 2, 3, 4], [5, 6, 7, 8, 9, 10]]
    outs = eng.generate(prompts, max_new=6)
    assert len(outs) == 2 and all(len(o) == 6 for o in outs)
    assert all(0 <= t < cfg.vocab for o in outs for t in o)


def test_generation_matches_teacher_forced_forward():
    """Greedy decode == argmax over the full forward on the generated
    sequence (same right-aligned prompt, no padding)."""
    cfg, model, params, eng = _engine()
    prompt = [3, 1, 4, 1, 5, 9, 2, 6]
    out = eng.generate([prompt], max_new=5)[0]
    seq = jnp.asarray([prompt + out], jnp.int32)
    logits, _ = model.forward(params, seq)
    for i in range(5):
        pos = len(prompt) - 1 + i
        want = int(jnp.argmax(logits[0, pos]))
        assert out[i] == want, (i, out, want)


def test_generation_deterministic():
    _, _, _, eng = _engine()
    a = eng.generate([[1, 2, 3]], max_new=4)
    b = eng.generate([[1, 2, 3]], max_new=4)
    assert a == b


def test_ssm_engine_generation():
    cfg, model, params, eng = _engine("mamba2-780m")
    outs = eng.generate([[1, 2, 3, 4, 5]], max_new=4)
    assert len(outs[0]) == 4


def test_fp8_kv_cache_decode_accuracy():
    """fp8(e4m3) KV caches: rel. logit error bounded — the memory-halving
    serving mode used for the llama-90b decode cell (§Perf X5)."""
    import jax.numpy as jnp
    cfg, model, params, _ = _engine("gemma2-2b")
    B, S = 2, 16
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    lf, _ = model.forward(params, tokens)
    _, caches, cur = model.prefill(params, tokens[:, :S - 1],
                                   cache_len=S + 4)
    caches8 = jax.tree.map(
        lambda a: (a.astype(jnp.float8_e4m3fn)
                   if a.dtype == jnp.bfloat16 else a), caches)
    dl, _, _ = model.decode_step(params, caches8, tokens[:, S - 1], cur)
    rel = float(jnp.max(jnp.abs(dl - lf[:, S - 1]))
                / (jnp.max(jnp.abs(lf[:, S - 1])) + 1e-9))
    assert rel < 0.15
