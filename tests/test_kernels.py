"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref
from repro.kernels.ring_decode import ring_cache_update

KEY = jax.random.PRNGKey(42)


def _rand(shape, dtype, key):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


@pytest.mark.parametrize("m,d_in,d_out", [
    (8, 128, 128), (16, 96, 64), (8, 256, 512), (24, 300, 130),
    (32, 64, 640),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ring_gemm_matches_oracle(m, d_in, d_out, dtype):
    ks = jax.random.split(KEY, 3)
    x = _rand((m, d_in), dtype, ks[0])
    w = (_rand((d_in, d_out), dtype, ks[1]) / np.sqrt(d_in)).astype(dtype)
    b = _rand((d_out,), dtype, ks[2])
    y, info = ops.segment_gemm(x, w, b, block_rows=8)
    want = ref.gemm_ref(x, w, b)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)
    assert info["delta"] >= 0


def test_ring_gemm_pool_saving_on_large_m():
    """For M >> block, the ring saves ≈ min(N,K)/(N+K) of the naive pool."""
    m, d = 512, 256
    x = _rand((m, d), jnp.float32, KEY)
    w = _rand((d, d), jnp.float32, KEY) / 16.0
    y, info = ops.segment_gemm(x, w, None, block_rows=8)
    saving = 1 - info["pool_bytes"] / info["naive_bytes"]
    assert saving > 0.45  # paper's ~50% single-layer bound, minus alignment


@pytest.mark.parametrize("m,d,f,ff_tile", [
    (8, 128, 512, 128), (16, 256, 1024, 256), (8, 384, 768, 384),
])
@pytest.mark.parametrize("gated,act", [(True, "gelu"), (True, "silu"),
                                       (False, "gelu")])
def test_fused_mlp_matches_oracle(m, d, f, ff_tile, gated, act):
    ks = jax.random.split(KEY, 4)
    x = _rand((m, d), jnp.float32, ks[0])
    wg = _rand((d, f), jnp.float32, ks[1]) / np.sqrt(d)
    wu = _rand((d, f), jnp.float32, ks[2]) / np.sqrt(d)
    wd = _rand((f, d), jnp.float32, ks[3]) / np.sqrt(f)
    y = ops.fused_mlp(x, wg, wu, wd, ff_tile=ff_tile, gated=gated,
                      activation=act)
    want = ref.fused_mlp_ref(x, wg, wu, wd, gated=gated, activation=act)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("qh,kvh,dh,window,block", [
    (8, 2, 64, 256, 64), (4, 4, 128, 128, 128), (16, 1, 64, 512, 128),
])
@pytest.mark.parametrize("T", [7, 100, 256, 512, 5000])
def test_ring_decode_matches_oracle(qh, kvh, dh, window, block, T):
    if T > window and T % window == 0:
        T += 1  # exercise unaligned wrap
    ks = jax.random.split(KEY, 3)
    q = _rand((qh, dh), jnp.float32, ks[0])
    k = _rand((window, kvh, dh), jnp.float32, ks[1])
    v = _rand((window, kvh, dh), jnp.float32, ks[2])
    o = ops.decode_attention(q, k, v, T, window=window, block=block)
    want = ref.ring_decode_ref(q, k, v, T, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_ring_decode_softcap():
    ks = jax.random.split(KEY, 3)
    q = _rand((4, 64), jnp.float32, ks[0]) * 10
    k = _rand((128, 2, 64), jnp.float32, ks[1])
    v = _rand((128, 2, 64), jnp.float32, ks[2])
    o = ops.decode_attention(q, k, v, 1000, window=128, block=64,
                             softcap=50.0)
    want = ref.ring_decode_ref(q, k, v, 1000, window=128, softcap=50.0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


def test_ring_cache_update_is_modular():
    """RAMStore-with-modulo: slot t % window, the paper's boundary check."""
    window, kvh, dh = 8, 2, 4
    k_ring = jnp.zeros((window, kvh, dh))
    v_ring = jnp.zeros((window, kvh, dh))
    for t in range(19):
        kn = jnp.full((kvh, dh), float(t))
        k_ring, v_ring = ring_cache_update(k_ring, v_ring, kn, kn,
                                           jnp.asarray(t))
    # after 19 writes, slot s holds token  (largest t<19 with t%8==s)
    for s in range(window):
        expect = s + 16 if s + 16 < 19 else s + 8
        assert float(k_ring[s, 0, 0]) == float(expect)


def test_chained_ring_gemm_layers():
    """Two GEMMs through one persistent pool — output of layer 1 consumed
    in place by layer 2 (the vMCU whole-network mode)."""
    from repro.kernels.segment_matmul import (aligned_pool_geometry,
                                              fetch_rows, ring_gemm,
                                              stage_rows, SEG_WIDTH)
    from repro.core.planner import gemm_offset_closed_form
    m, d0, d1, d2 = 16, 256, 512, 128
    ks = jax.random.split(KEY, 3)
    x = _rand((m, d0), jnp.float32, ks[0])
    w1 = _rand((d0, d1), jnp.float32, ks[1]) / 16
    w2 = _rand((d1, d2), jnp.float32, ks[2]) / 23

    br = 8
    segs = lambda d: -(-d // SEG_WIDTH)  # noqa: E731
    d1_delta = gemm_offset_closed_form(m, segs(d1), segs(d0))
    n_seg1, in1, out1 = aligned_pool_geometry(m, d0, d1, d1_delta, br)
    # layer 2 writes d2_delta below its input (= layer 1's output at out1),
    # block-aligned; the ring wraps negative pointers.
    d2_delta = gemm_offset_closed_form(m, segs(d2), segs(d1))
    out2 = out1 - (-(-d2_delta // (br * segs(d2)))) * (br * segs(d2))
    align = br * segs(d0) * segs(d1) * segs(d2)
    span = max(n_seg1, (out1 - out2) + m * segs(d1), m * segs(d2))
    n_seg = -(-span // align) * align
    shift = -(-max(0, -out2) // align) * align  # make all pointers >= 0
    in1, out1, out2 = in1 + shift, out1 + shift, out2 + shift
    pool = jnp.zeros((n_seg, SEG_WIDTH), jnp.float32)
    pool = stage_rows(pool, x, in1)
    zb1 = jnp.zeros((d1,), jnp.float32)
    zb2 = jnp.zeros((d2,), jnp.float32)
    pool = ring_gemm(pool, w1, zb1, m_rows=m, d_in=d0, d_out=d1,
                     in_ptr=in1, out_ptr=out1, block_rows=br, interpret=True)
    pool = ring_gemm(pool, w2, zb2, m_rows=m, d_in=d1, d_out=d2,
                     in_ptr=out1, out_ptr=out2, block_rows=br,
                     interpret=True)
    got = fetch_rows(pool, out2, m, d2)
    want = ref.gemm_ref(ref.gemm_ref(x, w1, zb1), w2, zb2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
