"""Per-arch smoke tests (deliverable f): reduced config of the same family,
one forward + one train step on CPU; output shapes + no NaNs; prefill/decode
consistency against the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_REGISTRY
from repro.models.registry import build_model
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step

ARCHS = list(ARCH_REGISTRY)
KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family in ("vlm", "audio"):
        L = cfg.n_image_tokens if cfg.family == "vlm" else cfg.encoder_seq
        batch["memory"] = jax.random.normal(KEY, (B, L, cfg.d_model),
                                            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = ARCH_REGISTRY[arch].reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    logits, aux = model.forward(params, batch["tokens"],
                                memory=batch.get("memory"))
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_no_nans(arch):
    cfg = ARCH_REGISTRY[arch].reduced()
    model = build_model(cfg)
    state = init_train_state(model, KEY)
    step = jax.jit(make_train_step(
        model, opt=AdamWConfig(peak_lr=1e-3, warmup_steps=2, total_steps=10)))
    state, metrics = step(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state.step) == 1
    # params actually moved
    p0 = jax.tree.leaves(state.params)[0]
    assert bool(jnp.all(jnp.isfinite(p0)))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = ARCH_REGISTRY[arch].reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    tokens = batch["tokens"]
    memory = batch.get("memory")
    logits_full, _ = model.forward(params, tokens, memory=memory)
    last, caches, cur = model.prefill(params, tokens[:, :S - 1],
                                      memory=memory, cache_len=S + 4)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(logits_full[:, S - 2]),
                               rtol=2e-2, atol=2e-2)
    d_logits, caches, cur = model.decode_step(params, caches,
                                              tokens[:, S - 1], cur)
    np.testing.assert_allclose(np.asarray(d_logits),
                               np.asarray(logits_full[:, S - 1]),
                               rtol=2e-2, atol=2e-2)
    assert int(cur) == S


@pytest.mark.parametrize("arch", ["gemma2-2b", "recurrentgemma-2b",
                                  "gemma3-1b"])
def test_ring_kv_wraps_beyond_window(arch):
    """Decode far past the sliding window: ring slots recycle (vMCU modulo
    check) and logits stay finite and consistent with a fresh prefill."""
    cfg = ARCH_REGISTRY[arch].reduced()  # window=32
    model = build_model(cfg)
    params = model.init(KEY)
    S = cfg.window + 9
    tokens = jax.random.randint(KEY, (1, S + 1), 0, cfg.vocab)
    _, caches, cur = model.prefill(params, tokens[:, :S], cache_len=S + 8)
    step_logits, _, _ = model.decode_step(params, caches, tokens[:, S], cur)
    logits_full, _ = model.forward(params, tokens)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(logits_full[:, S]),
                               rtol=3e-2, atol=3e-2)


def test_multi_step_decode_consistency():
    cfg = ARCH_REGISTRY["gemma2-2b"].reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    S, extra = 12, 4
    tokens = jax.random.randint(KEY, (2, S + extra), 0, cfg.vocab)
    logits_full, _ = model.forward(params, tokens)
    _, caches, cur = model.prefill(params, tokens[:, :S],
                                   cache_len=S + extra + 2)
    for t in range(extra):
        lg, caches, cur = model.decode_step(params, caches, tokens[:, S + t],
                                            cur)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(logits_full[:, S + t]),
                                   rtol=2e-2, atol=2e-2)
