"""Eq. (1) solver: paper closed forms, exact scans, pool tightness."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.affine import (AccessFn, IterDomain, gemm_domain,
                               gemm_read_access, gemm_write_access)
from repro.core.planner import (gemm_min_footprint_segments,
                                gemm_offset_closed_form,
                                motivational_example, plan_gemm,
                                plan_pointwise_conv, solve_offset_bruteforce,
                                solve_offset_scan)
from repro.core.pool import PoolClobberError, SegmentPool, run_gemm_schedule

dims = st.integers(min_value=1, max_value=7)


def test_motivational_example_fig1c():
    """Paper Fig. 1(c): segment-level needs 7 slots, tensor-level 10."""
    assert motivational_example() == (7, 10)


def test_paper_gemm_closed_form_cases():
    # K=3, N=2 (the Fig. 1 example): one empty segment (N-1)
    assert gemm_offset_closed_form(2, 2, 3) == 1
    # N <= K: footprint = MK + N - 1
    assert gemm_min_footprint_segments(4, 2, 5) == 4 * 5 + 2 - 1
    # N > K: footprint = MN + K - 1
    assert gemm_min_footprint_segments(4, 5, 2) == 4 * 5 + 2 - 1


@given(dims, dims, dims)
@settings(max_examples=60, deadline=None)
def test_closed_form_matches_exact_scan(m, n, k):
    d, r, w = gemm_domain(m, n, k), gemm_read_access(m, k), \
        gemm_write_access(m, n)
    assert gemm_offset_closed_form(m, n, k) == solve_offset_scan(d, r, w)


@given(st.integers(1, 4), st.integers(1, 4), st.integers(1, 4))
@settings(max_examples=30, deadline=None)
def test_scan_matches_bruteforce(m, n, k):
    d, r, w = gemm_domain(m, n, k), gemm_read_access(m, k), \
        gemm_write_access(m, n)
    assert solve_offset_scan(d, r, w) == solve_offset_bruteforce(d, r, w)


@given(dims, dims, dims)
@settings(max_examples=40, deadline=None)
def test_plan_is_safe_and_tight(m, n, k):
    """The solved delta executes cleanly; delta-1 must clobber (tightness —
    the paper's 'silent error' case)."""
    plan = plan_gemm(m, n, k, segment_bytes=1, validate=True)
    pool = SegmentPool(plan.pool_segments)
    run_gemm_schedule(pool, m, n, k, b_out=0, b_in=plan.delta)
    assert pool.peak_live <= plan.pool_segments
    if plan.delta > 0:
        with pytest.raises(PoolClobberError):
            run_gemm_schedule(SegmentPool(plan.pool_segments), m, n, k,
                              b_out=0, b_in=plan.delta - 1)


@given(dims, dims, dims)
@settings(max_examples=40, deadline=None)
def test_footprint_beats_or_equals_naive(m, n, k):
    plan = plan_gemm(m, n, k, segment_bytes=1)
    assert plan.pool_segments <= plan.naive_segments
    # paper's bound: single-layer saving is at most 50%
    assert plan.pool_segments >= plan.naive_segments / 2


def test_numerics_survive_the_ring():
    """Payloads written through the ring are the payloads read back."""
    m, n, k = 3, 2, 4
    plan = plan_gemm(m, n, k, segment_bytes=1)
    pool = SegmentPool(plan.pool_segments)
    payload = np.arange(m * k).reshape(m, k)
    run_gemm_schedule(pool, m, n, k, b_out=0, b_in=plan.delta,
                      in_payload=payload)
    for mm in range(m):
        for nn in range(n):
            got = pool.read(mm * n + nn, owner="out")
            assert got[0] == mm and got[1] == nn
            assert got[2] == tuple(payload[mm])


@given(st.integers(2, 10), st.integers(1, 6), st.integers(1, 6),
       st.sampled_from([1, 2]))
@settings(max_examples=30, deadline=None)
def test_pointwise_conv_plan_bounds(h, c, kk, stride):
    plan = plan_pointwise_conv(h, h, c, kk, stride=stride)
    naive = plan.in_segments + plan.out_segments
    assert plan.pool_segments <= naive + 2  # alignment slack
    assert plan.delta >= 0


def test_affine_access_linearization():
    a = AccessFn(A=((1, 0), (0, 1)), V=(2, 3), shape=(5, 7))
    pts = IterDomain((2, 2)).points_lex()
    addrs = a.addresses(pts)
    assert addrs[0] == 2 * 7 + 3
    assert addrs[-1] == 3 * 7 + 4
