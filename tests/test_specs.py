"""Dry-run spec machinery: cache classification, batch/state specs.

Runs on a 1x1 ("data","model") mesh — shardings resolve without needing
512 fake devices (the full-mesh path is exercised by the dry-run itself).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs import ARCH_REGISTRY
from repro.configs.base import DECODE_32K, LONG_500K, TRAIN_4K
from repro.launch.specs import (batch_specs, cache_specs, input_specs,
                                make_rules, params_specs, state_specs)
from repro.models.registry import build_model


def _mesh():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))


@pytest.mark.parametrize("arch", list(ARCH_REGISTRY))
def test_cache_specs_cover_every_leaf(arch):
    cfg = ARCH_REGISTRY[arch].reduced()
    model = build_model(cfg)
    rules = make_rules(cfg, _mesh(), DECODE_32K)
    specs = cache_specs(model, cfg, rules, batch=4, cache_len=64)
    for leaf in jax.tree.leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct)
        assert leaf.sharding is not None


@pytest.mark.parametrize("arch", ["gemma2-2b", "mamba2-780m",
                                  "whisper-tiny"])
def test_input_specs_kinds(arch):
    cfg = ARCH_REGISTRY[arch].reduced()
    model = build_model(cfg)
    mesh = _mesh()
    for cell in (TRAIN_4K, DECODE_32K):
        rules = make_rules(cfg, mesh, cell)
        specs = input_specs(model, cfg, cell, rules)
        assert specs.kind == cell.kind
        assert len(specs.args) >= 2
        if cell.kind == "train":
            assert specs.donate == (0,)
        else:
            assert specs.donate == (1,)


def test_state_specs_two_copy_dtype():
    cfg = ARCH_REGISTRY["gemma2-2b"].reduced()
    model = build_model(cfg)
    rules = make_rules(cfg, _mesh(), TRAIN_4K)
    st = state_specs(model, rules, two_copy=True)
    masters = jax.tree.leaves(st.params)
    casts = jax.tree.leaves(st.cast)
    assert all(x.dtype == jnp.float32 for x in masters
               if jnp.issubdtype(x.dtype, jnp.floating))
    assert all(x.dtype == jnp.bfloat16 for x in casts
               if jnp.issubdtype(x.dtype, jnp.floating))
    assert len(masters) == len(casts)


def test_serve_dtype_override():
    cfg = ARCH_REGISTRY["granite-8b"].reduced()
    model = build_model(cfg)
    rules = make_rules(cfg, _mesh(), DECODE_32K)
    specs = params_specs(model, rules, dtype=jnp.bfloat16)
    for leaf in jax.tree.leaves(specs):
        assert leaf.dtype != jnp.float32


def test_batch_specs_match_family():
    mesh = _mesh()
    for arch, has_memory in (("gemma2-2b", False),
                             ("llama-3.2-vision-90b", True),
                             ("whisper-tiny", True)):
        cfg = ARCH_REGISTRY[arch]
        rules = make_rules(cfg, mesh, TRAIN_4K)
        bs = batch_specs(cfg, TRAIN_4K, rules)
        assert ("memory" in bs) == has_memory
        assert bs["tokens"].shape == (TRAIN_4K.global_batch,
                                      TRAIN_4K.seq_len)


def test_long_context_rules():
    cfg = ARCH_REGISTRY["mamba2-780m"]
    rules = make_rules(cfg, _mesh(), LONG_500K)
    assert rules.long_context and rules.decode
