"""JAX HBM ring pool: numerics identical to the naive chain, footprint
below the tensor-level chain, plan properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.ring_buffer import (init_chain_params, naive_chain_apply,
                                    plan_chain, run_chain_via_ring)

KEY = jax.random.PRNGKey(0)


def test_chain_numerics_match_naive():
    dims = [96, 384, 96, 64]
    m = 8
    plan = plan_chain(m, dims, seg_width=32)
    params = init_chain_params(KEY, dims)
    x = jax.random.normal(jax.random.PRNGKey(1), (m, dims[0]))
    y_ring = run_chain_via_ring(x, params, plan)
    y_ref = naive_chain_apply(x, params)
    np.testing.assert_allclose(np.asarray(y_ring), np.asarray(y_ref),
                               rtol=3e-5, atol=3e-5)


def test_block_rows_invariance():
    dims = [64, 256, 64]
    m = 16
    plan = plan_chain(m, dims, seg_width=32)
    params = init_chain_params(KEY, dims)
    x = jax.random.normal(jax.random.PRNGKey(2), (m, dims[0]))
    y1 = run_chain_via_ring(x, params, plan, block_rows=1)
    y4 = run_chain_via_ring(x, params, plan, block_rows=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4), rtol=1e-5,
                               atol=1e-5)


@given(st.integers(2, 24),
       st.lists(st.integers(1, 6), min_size=2, max_size=5))
@settings(max_examples=25, deadline=None)
def test_plan_pool_never_exceeds_naive(m, dim_units):
    dims = [u * 32 for u in dim_units]
    plan = plan_chain(m, dims, seg_width=32)
    assert plan.pool_bytes <= plan.naive_bytes
    assert plan.n_segments > 0


def test_pool_saving_grows_with_chain_balance():
    """Equal-width chains overlap best (the paper's ≈50% case)."""
    plan = plan_chain(64, [256, 256, 256], seg_width=128)
    assert 1 - plan.pool_bytes / plan.naive_bytes > 0.45
