"""Fault tolerance: preemption, straggler accounting, elastic restore."""
import os
import signal
import threading
import time

import jax
import numpy as np

from repro.configs import ARCH_REGISTRY
from repro.checkpoint.manager import CheckpointManager
from repro.launch import train as train_mod
from repro.launch.train import train_loop

CFG = ARCH_REGISTRY["gemma3-1b"].reduced()


def test_preemption_checkpoints_and_exits(tmp_path):
    """SIGTERM mid-run → clean checkpoint at the step boundary, resumable."""
    d = str(tmp_path / "pre")

    def fire():
        time.sleep(1.5)
        train_mod._on_sigterm(signal.SIGTERM, None)  # simulate delivery

    train_mod._PREEMPTED = False
    t = threading.Thread(target=fire)
    t.start()
    train_loop(CFG, steps=400, batch=2, seq=16, ckpt_dir=d, ckpt_every=50,
               log_every=1000)
    t.join()
    train_mod._PREEMPTED = False
    mgr = CheckpointManager(d)
    stopped_at = mgr.latest_step()
    assert stopped_at is not None and stopped_at < 400
    # resume and run a few more steps
    out = train_loop(CFG, steps=stopped_at + 3, batch=2, seq=16, ckpt_dir=d,
                     ckpt_every=50, log_every=1000)
    assert np.isfinite(out["final_loss"])


def test_straggler_accounting(tmp_path):
    out = train_loop(CFG, steps=12, batch=2, seq=16,
                     ckpt_dir=str(tmp_path / "s"), ckpt_every=100,
                     log_every=1000, straggler_factor=1e9)
    assert out["stragglers"] == 0
    assert out["median_step_s"] > 0


def test_elastic_restore_across_state_layouts(tmp_path):
    """A checkpoint written by one job restores into a freshly-built state
    (different session, same logical structure) — the pod-count-change
    scenario at CPU scale."""
    from repro.models.registry import build_model
    from repro.train.train_step import init_train_state
    model = build_model(CFG)
    state = init_train_state(model, jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path / "e"))
    mgr.save(7, state)
    like = jax.eval_shape(
        lambda: init_train_state(model, jax.random.PRNGKey(123)))
    restored = mgr.restore(like)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
