"""Int8 quantized execution: dtype-aware geometry, backend agreement
(jnp == pallas bitwise), sim certification of the int8-typed programs,
and whole-MCUNet int8 runs matching the float reference."""
import jax
import numpy as np
import pytest

from repro.core.graph_planner import (MCUNET_5FPS_VWW,
                                      MCUNET_320KB_IMAGENET)
from repro.core.program import GemmSpec, plan_program
from repro.graph import (build_mcunet, certify_net, init_net_params,
                         quantized_agreement, run_net_quantized)
from repro.graph.netplan import _plan_net as plan_net
from repro.graph.run import _quantize_net as quantize_net

KEY = jax.random.PRNGKey(0)


def _s7_plan(**kw):
    """One unfused residual module: conv_pw / conv_dw / conv_pw / add."""
    return plan_net(build_mcunet(MCUNET_5FPS_VWW[6:7], "s7",
                                 include_head=False),
                    fused_exec=False, **kw)


# ---------------------------------------------------------------------------
# Dtype-aware geometry.
# ---------------------------------------------------------------------------

def test_with_dtype_float32_is_identity():
    prog = plan_net(build_mcunet(MCUNET_5FPS_VWW[:2], "m2",
                                 include_head=False)).program
    assert prog.with_dtype("float32") is prog


def test_int8_pool_bytes_are_byte_denominated():
    plan = _s7_plan()
    prog = plan.program
    q = prog.with_dtype("int8")
    # identical segment geometry, 4x smaller byte footprint
    assert q.n_segments == prog.n_segments
    assert q.pool_segments == prog.pool_segments
    assert [(op.in_ptr, op.out_ptr, op.delta) for op in q.ops] \
        == [(op.in_ptr, op.out_ptr, op.delta) for op in prog.ops]
    assert q.pool_bytes * 4 == prog.pool_bytes
    assert q.pool_bytes == q.pool_segments * q.seg_width
    assert all(op.segment_bytes == q.seg_width for op in q.ops)
    assert q.spec().dtype == np.int8


def test_plan_dtype_param_equals_with_dtype():
    g = build_mcunet(MCUNET_5FPS_VWW[6:7], "s7", include_head=False)
    a = plan_net(g, fused_exec=False, dtype="int8").program
    b = plan_net(g, fused_exec=False).program.with_dtype("int8")
    assert a == b and a.quantized


def test_elem_bytes_dtype_conflict_rejected():
    with pytest.raises(ValueError, match="contradicts"):
        plan_program(4, 128, [GemmSpec(128)], elem_bytes=4, dtype="int8")
    with pytest.raises(ValueError, match="unknown pool dtype"):
        plan_program(4, 128, [GemmSpec(128)], dtype="int4")


def test_legacy_elem_bytes_1_keeps_float_execution():
    """Quantized execution is opt-in via dtype="int8" ONLY: a 1-byte
    elem_bytes (e.g. ops.segment_gemm over an int8 array) must keep the
    byte accounting but stay on the float executor path."""
    import jax.numpy as jnp
    from repro.kernels import ops

    prog = plan_program(8, 128, [GemmSpec(128)], elem_bytes=1,
                        block_rows=8)
    assert prog.dtype == "byte" and not prog.quantized
    assert prog.pool_bytes == prog.pool_segments * prog.seg_width
    x = (jax.random.normal(KEY, (8, 128)) * 10).astype(jnp.int8)
    w = jnp.eye(128, dtype=jnp.int8)
    y, info = ops.segment_gemm(x, w, None, block_rows=8)  # float path
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_fused_exec_false_lowers_modules_unfused():
    plan = _s7_plan()
    kinds = [op.kind for op in plan.program.ops]
    assert kinds == ["conv_pw", "conv_dw", "conv_pw", "add"]
    fused = plan_net(build_mcunet(MCUNET_5FPS_VWW[6:7], "s7",
                                  include_head=False))
    # the byte-granular REPORTED footprints follow the exclusion rule
    # either way — only execution lowering changes
    assert plan.mcu_bottleneck_bytes == fused.mcu_bottleneck_bytes


# ---------------------------------------------------------------------------
# Backend agreement: int8 is exact integer math, so jnp and pallas must
# agree BITWISE (not allclose) on every kernel.
# ---------------------------------------------------------------------------

def _quantized_mini_net():
    """Unfused module + avgpool/fc head: covers all five int8 kernels
    (conv_pw, conv_dw, add, pool_avg, gemm)."""
    plan = plan_net(build_mcunet(MCUNET_5FPS_VWW[6:7], "mini",
                                 num_classes=4), fused_exec=False)
    kinds = [op.kind for op in plan.program.ops]
    assert kinds == ["conv_pw", "conv_dw", "conv_pw", "add", "pool_avg",
                     "gemm"]
    params = init_net_params(plan, KEY)
    return plan, quantize_net(plan, params)


def test_int8_jnp_and_pallas_agree_bitwise():
    plan, qnet = _quantized_mini_net()
    from repro.core.executors import run_program
    from repro.quant import QParams, quantize

    x = jax.random.normal(KEY, (plan.program.in_rows, plan.program.in_dim))
    x_q = quantize(x, QParams(scale=qnet.in_scale))
    y_jnp, pool_jnp = run_program(qnet.program, x_q, qnet.qparams,
                                  backend="jnp")
    y_pal, pool_pal = run_program(qnet.program, x_q, qnet.qparams,
                                  backend="pallas")
    assert y_jnp.dtype == np.int8 and y_pal.dtype == np.int8
    np.testing.assert_array_equal(np.asarray(y_jnp), np.asarray(y_pal))
    # the ENTIRE ring state agrees, not just the fetched output
    np.testing.assert_array_equal(np.asarray(pool_jnp.array),
                                  np.asarray(pool_pal.array))


# (test_int8_gemm_scan_blocks_match_pallas retired: the gemm-int8 rows
# of tests/test_conformance_matrix.py pin the multi-row-block scan path
# bitwise against kernels/ref.py on both backends.)


def test_quantize_net_rejects_fused_plans():
    plan = plan_net(build_mcunet(MCUNET_5FPS_VWW[:1], "f1",
                                 include_head=False))   # ib_fused op
    params = init_net_params(plan, KEY)
    with pytest.raises(ValueError, match="fused_exec=False"):
        quantize_net(plan, params)


# ---------------------------------------------------------------------------
# Whole-network int8 acceptance.
# ---------------------------------------------------------------------------

def _acceptance(name, modules, classes, *, backend="jnp", n=8):
    plan = plan_net(build_mcunet(modules, name, num_classes=classes),
                    fused_exec=False, dtype="int8")
    params = init_net_params(plan, KEY)
    qnet = quantize_net(plan, params)
    # sim-oracle certificate of the int8-typed program: zero clobbers
    sim = certify_net(qnet.program)
    assert sim.peak_live <= qnet.program.n_segments
    # executed int8 ring is byte-denominated and 4x under fp32
    fp32 = plan_net(build_mcunet(modules, name, num_classes=classes),
                    fused_exec=False)
    assert qnet.pool_bytes * 4 == fp32.program.pool_bytes
    rep = quantized_agreement(qnet, n=n, backend=backend)
    assert rep["cosine"] >= 0.99, rep
    assert rep["argmax_agreement"] >= 0.95, rep
    return qnet, rep


@pytest.mark.slow
def test_mcunet_vww_int8_end_to_end():
    """MCUNet-5fps-VWW runs int8 end-to-end: zero sim clobbers, >=95%
    argmax agreement with the float reference."""
    _acceptance("vww", MCUNET_5FPS_VWW, 2)


@pytest.mark.slow
def test_mcunet_imagenet_int8_end_to_end():
    """MCUNet-320KB-ImageNet (strided modules, resampling adapters,
    1000-way head) int8 end-to-end."""
    _acceptance("imagenet", MCUNET_320KB_IMAGENET, 1000)


def test_int8_output_dequantizes_to_float():
    plan, qnet = _quantized_mini_net()
    x = jax.random.normal(jax.random.PRNGKey(3),
                          (plan.program.in_rows, plan.program.in_dim))
    y = run_net_quantized(qnet, x)
    assert y.dtype == np.float32
    assert y.shape == (plan.program.out_rows, plan.program.out_dim)
