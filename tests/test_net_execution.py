"""Acceptance: full MCUNet NetPrograms execute end-to-end on every
backend — sim certifies zero clobbers, jnp and pallas match the
plain-XLA reference forward pass to float tolerance."""
import jax
import numpy as np
import pytest

pytestmark = pytest.mark.slow   # whole-network execution: full lane only

from repro.core.graph_planner import (MCUNET_5FPS_VWW,
                                      MCUNET_320KB_IMAGENET)
from repro.graph import (build_mcunet, build_mlp_tower, certify_net,
                         init_net_params, reference_forward, run_net)
from repro.graph.netplan import _plan_net as plan_net

KEY = jax.random.PRNGKey(0)


def _tolerances(ref):
    scale = float(np.abs(np.asarray(ref)).max()) or 1.0
    return dict(rtol=3e-4, atol=3e-5 * scale)


def _run_all_backends(plan, backends):
    sim = certify_net(plan)             # zero clobbers or PoolClobberError
    assert sim.peak_live <= plan.program.n_segments
    params = init_net_params(plan, KEY)
    x = jax.random.normal(KEY, (plan.program.in_rows, plan.program.in_dim))
    ref = reference_forward(plan, x, params)
    tol = _tolerances(ref)
    for backend in backends:
        y = run_net(plan, x, params, backend=backend)
        assert y.shape == (plan.program.out_rows, plan.program.out_dim)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), **tol)


def test_mcunet_vww_full_network_all_backends():
    """MCUNet-5fps-VWW: 8 modules + adapters + head through ONE ring on
    sim, jnp AND pallas."""
    plan = plan_net(build_mcunet(MCUNET_5FPS_VWW, "vww", num_classes=2))
    plan.program.check_alignment()
    _run_all_backends(plan, ("jnp", "pallas"))


def test_mcunet_imagenet_full_network_all_backends():
    """MCUNet-320KB-ImageNet: 17 modules (strided, resampling adapters,
    unfused residuals) end-to-end on every backend."""
    plan = plan_net(build_mcunet(MCUNET_320KB_IMAGENET, "imagenet",
                                 num_classes=1000))
    plan.program.check_alignment()
    _run_all_backends(plan, ("jnp", "pallas"))


def test_mlp_tower_executes_and_matches_reference():
    """A configs/ model's FFN stack through the same bridge."""
    from repro.configs import get_config
    cfg = get_config("gemma2-2b").reduced()
    plan = plan_net(build_mlp_tower(cfg, m_rows=8, n_layers=2),
                    block_rows=8)
    _run_all_backends(plan, ("jnp", "pallas"))


def test_unfused_residual_module_holds_source_across_ops():
    """S7 (exclusion rule: fallback wins) must execute unfused with the
    module input held live until its residual add — certified by the
    oracle AND numerically equal to the fused reference math."""
    plan = plan_net(build_mcunet(MCUNET_5FPS_VWW[6:7], "s7",
                                 include_head=False))
    kinds = [op.kind for op in plan.program.ops]
    assert kinds == ["conv_pw", "conv_dw", "conv_pw", "add"]
    assert plan.program.ops[0].hold_input
    assert plan.program.ops[3].aux_op == 0
    _run_all_backends(plan, ("jnp", "pallas"))
