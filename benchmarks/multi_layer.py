"""Paper Fig. 9 / Fig. 10 — inverted-bottleneck RAM usage for
MCUNet-5fps-VWW (S1–S8) and MCUNet-320KB-ImageNet (B1–B17).

Rows now come from the whole-network graph compiler (``repro.graph``):
build the net IR, schedule + fuse by the paper's exclusion rule, and
read each module's byte footprint off its fusion group — the legacy
closed-form module formulas are asserted as a CROSS-CHECK of the graph
path, not reimplemented.
"""
from __future__ import annotations

from repro.core.graph_planner import (MCUNET_5FPS_VWW,
                                      MCUNET_320KB_IMAGENET,
                                      hmcos_module_bytes,
                                      tinyengine_module_bytes,
                                      vmcu_module_bytes)
import repro
from repro.core.program import plan_module_program
from repro.graph import build_mcunet


def run(net) -> list[dict]:
    graph = build_mcunet(net, "bench", include_head=False)
    # tight geometry (block_rows=None) overrides the host-sim default
    plan = repro.compile(graph, target="host-sim", block_rows=None,
                         certify=False).plan
    by_name = {g.name: g.group for g in plan.groups
               if g.group.kind == "module"}
    rows = []
    for cfg in net:
        group = by_name[cfg.name]
        # the old closed-form numbers are cross-checks now
        assert group.mcu_bytes == vmcu_module_bytes(cfg), cfg.name
        assert group.te_bytes == tinyengine_module_bytes(cfg), cfg.name
        assert group.hmcos_bytes == hmcos_module_bytes(cfg), cfg.name
        fused = plan_module_program(cfg)  # one-op PoolProgram (Eq. 2 plan)
        rows.append({
            "module": cfg.name,
            "vmcu_kb": group.mcu_bytes / 1000,
            "vmcu_fused_kb": fused.pool_bytes / 1000,
            "fused_exec": group.fused_exec,
            "tinyengine_kb": group.te_bytes / 1000,
            "hmcos_kb": group.hmcos_bytes / 1000,
        })
    return rows


def main(rows_by_net: dict[str, list[dict]] | None = None) -> None:
    for name, key, net in (("MCUNet-5fps-VWW", "vww", MCUNET_5FPS_VWW),
                           ("MCUNet-320KB-ImageNet", "imagenet",
                            MCUNET_320KB_IMAGENET)):
        rows = run(net) if rows_by_net is None else rows_by_net[key]
        print(f"# {name}")
        print("module,vmcu_kb,tinyengine_kb,hmcos_kb,red_vs_te,red_vs_hmcos")
        for r in rows:
            print(f"{r['module']},{r['vmcu_kb']:.1f},"
                  f"{r['tinyengine_kb']:.1f},{r['hmcos_kb']:.1f},"
                  f"{100 * (1 - r['vmcu_kb'] / r['tinyengine_kb']):.1f}%,"
                  f"{100 * (1 - r['vmcu_kb'] / r['hmcos_kb']):.1f}%")
        bot_v = max(r["vmcu_kb"] for r in rows)
        bot_te = max(r["tinyengine_kb"] for r in rows)
        bot_hm = max(r["hmcos_kb"] for r in rows)
        print(f"# bottleneck: vMCU={bot_v:.1f}KB TinyEngine={bot_te:.1f}KB "
              f"HMCOS={bot_hm:.1f}KB  reduction vs TE="
              f"{100 * (1 - bot_v / bot_te):.1f}% "
              f"(paper: 61.5% VWW / 58.6% ImageNet)")
        print(f"# fits 128KB device: vMCU={bot_v <= 128} "
              f"TinyEngine={bot_te <= 128} HMCOS={bot_hm <= 128}")


if __name__ == "__main__":
    main()
