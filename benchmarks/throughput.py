"""Throughput — end-to-end inferences/sec through the compiled ring.

Times the batched ``CompiledNet.run`` fast path (ONE solved plan,
vmapped over the batch lanes on the ``jnp`` executor; quantize /
dequantize batched outside the traced region) at batch 1 / 32 / 256.
The section answers the question Table 3 cannot: whether the ring's
per-op mechanics amortize when the deployment actually streams inputs.
Wall-times are CPU-relative indicators, not TPU numbers.
"""
from __future__ import annotations

import jax

from .timing import bench_us

#: (net, target) — a small zoo net so the section stays smoke-fast.
NET, TARGET = "ds-cnn", "cortex-m4"
BATCHES = (1, 32, 256)


def run() -> list[dict]:
    import repro

    cn = repro.compile(NET, target=TARGET)
    rows = []
    for bs in BATCHES:
        x = jax.random.normal(
            jax.random.PRNGKey(0),
            (bs, cn.program.in_rows, cn.program.in_dim))
        us = bench_us(cn.run, x, iters=5)
        rows.append({"net": NET, "target": TARGET, "batch": bs,
                     "wall_us": us, "inf_per_sec": bs / (us * 1e-6)})
    return rows


def main(rows: list[dict] | None = None) -> None:
    rows = run() if rows is None else rows
    print("net,target,batch,wall_us,inf_per_sec")
    for r in rows:
        print(f"{r['net']},{r['target']},{r['batch']},"
              f"{r['wall_us']:.0f},{r['inf_per_sec']:.1f}")
    print("# batched CompiledNet.run: one plan, vmapped pool lanes")


if __name__ == "__main__":
    main()
