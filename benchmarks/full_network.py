"""Whole-network bottleneck benchmark — the paper's headline full-DNN
metric (61.5% memory-bottleneck reduction), via the compile facade.

Per network: ``repro.compile(net, target="host-sim")`` schedules, fuses
and plans the net, and the row reads the byte-granular bottleneck vs
the TinyEngine / HMCOS baselines plus the executed segment-granular
ring footprint (fp32 TPU adaptation) and op/fusion statistics off the
CompiledNet.  Ring geometry (seg rows / DMA alignment) comes from the
Target registry — ONE definition site shared with int8_network.
"""
from __future__ import annotations

import repro

NETS = ("mcunet-5fps-vww", "mcunet-320kb-imagenet")
TARGET = repro.get_target("host-sim")


def run() -> list[dict]:
    rows = []
    for name in NETS:
        cn = repro.compile(name, target=TARGET, certify=False)
        plan = cn.plan
        fused = sum(1 for g in plan.groups if g.group.fused_exec)
        modules_n = sum(1 for g in plan.groups if g.group.kind == "module")
        rows.append({
            "net": name,
            "n_ops": len(plan.program.ops),
            "n_groups": len(plan.groups),
            "modules_fused_exec": fused,
            "modules_total": modules_n,
            "vmcu_bottleneck_kb": plan.mcu_bottleneck_bytes / 1000,
            "tinyengine_bottleneck_kb":
                plan.tinyengine_bottleneck_bytes / 1000,
            "hmcos_bottleneck_kb": plan.hmcos_bottleneck_bytes / 1000,
            "reduction_vs_tinyengine": plan.reduction_vs_tinyengine,
            "reduction_vs_hmcos": plan.reduction_vs_hmcos,
            "exec_pool_kb": plan.program.pool_bytes / 1000,
            "exec_physical_pool_kb":
                plan.program.physical_pool_bytes / 1000,
            "fits_128kb": plan.deployable(128_000),
        })
    return rows


def main(rows: list[dict] | None = None) -> None:
    rows = run() if rows is None else rows
    print("net,vmcu_kb,tinyengine_kb,hmcos_kb,red_vs_te,fused/modules,"
          "exec_pool_kb")
    for r in rows:
        print(f"{r['net']},{r['vmcu_bottleneck_kb']:.1f},"
              f"{r['tinyengine_bottleneck_kb']:.1f},"
              f"{r['hmcos_bottleneck_kb']:.1f},"
              f"{100 * r['reduction_vs_tinyengine']:.1f}%,"
              f"{r['modules_fused_exec']}/{r['modules_total']},"
              f"{r['exec_pool_kb']:.1f}")
    print("# paper: 61.5% (VWW) / 58.6% (ImageNet) bottleneck reduction "
          "vs TinyEngine")


if __name__ == "__main__":
    main()
