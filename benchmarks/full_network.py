"""Whole-network bottleneck benchmark — the paper's headline full-DNN
metric (61.5% memory-bottleneck reduction), from the graph compiler.

Per network: the scheduled + fused NetPlan's byte-granular bottleneck vs
the TinyEngine / HMCOS baselines, plus the executed segment-granular
ring footprint (fp32 TPU adaptation) and op/fusion statistics.
"""
from __future__ import annotations

from repro.core.graph_planner import (MCUNET_5FPS_VWW,
                                      MCUNET_320KB_IMAGENET)
from repro.graph import build_mcunet, plan_net

NETS = (("mcunet-5fps-vww", MCUNET_5FPS_VWW, 2),
        ("mcunet-320kb-imagenet", MCUNET_320KB_IMAGENET, 1000))


def run() -> list[dict]:
    rows = []
    for name, modules, classes in NETS:
        plan = plan_net(build_mcunet(modules, name, num_classes=classes))
        fused = sum(1 for g in plan.groups if g.group.fused_exec)
        modules_n = sum(1 for g in plan.groups if g.group.kind == "module")
        rows.append({
            "net": name,
            "n_ops": len(plan.program.ops),
            "n_groups": len(plan.groups),
            "modules_fused_exec": fused,
            "modules_total": modules_n,
            "vmcu_bottleneck_kb": plan.mcu_bottleneck_bytes / 1000,
            "tinyengine_bottleneck_kb":
                plan.tinyengine_bottleneck_bytes / 1000,
            "hmcos_bottleneck_kb": plan.hmcos_bottleneck_bytes / 1000,
            "reduction_vs_tinyengine": plan.reduction_vs_tinyengine,
            "reduction_vs_hmcos": plan.reduction_vs_hmcos,
            "exec_pool_kb": plan.program.pool_bytes / 1000,
            "exec_physical_pool_kb":
                plan.program.physical_pool_bytes / 1000,
            "fits_128kb": plan.deployable(128_000),
        })
    return rows


def main(rows: list[dict] | None = None) -> None:
    rows = run() if rows is None else rows
    print("net,vmcu_kb,tinyengine_kb,hmcos_kb,red_vs_te,fused/modules,"
          "exec_pool_kb")
    for r in rows:
        print(f"{r['net']},{r['vmcu_bottleneck_kb']:.1f},"
              f"{r['tinyengine_bottleneck_kb']:.1f},"
              f"{r['hmcos_bottleneck_kb']:.1f},"
              f"{100 * r['reduction_vs_tinyengine']:.1f}%,"
              f"{r['modules_fused_exec']}/{r['modules_total']},"
              f"{r['exec_pool_kb']:.1f}")
    print("# paper: 61.5% (VWW) / 58.6% (ImageNet) bottleneck reduction "
          "vs TinyEngine")


if __name__ == "__main__":
    main()
