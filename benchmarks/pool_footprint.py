"""TPU-level footprint proof: ``memory_analysis()`` of the compiled ring
chain vs the naive chain — XLA's buffer assignment itself confirms the
pool reuse (the HBM analogue of the paper's RAM measurements)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.ring_buffer import (init_chain_params, naive_chain_apply,
                                    plan_chain, ring_chain_apply)


def measure(m: int, dims: list[int]) -> dict:
    params = init_chain_params(jax.random.PRNGKey(0), dims)
    plan = plan_chain(m, dims)

    naive = jax.jit(lambda x: naive_chain_apply(x, params))
    c_naive = naive.lower(
        jax.ShapeDtypeStruct((m, dims[0]), jnp.float32)).compile()
    ring = jax.jit(lambda p: ring_chain_apply(p, params, plan, 8))
    c_ring = ring.lower(jax.ShapeDtypeStruct(
        (plan.n_segments, plan.seg_width), jnp.float32)).compile()

    def peak(c, arg_is_donated):
        ma = c.memory_analysis()
        t = ma.temp_size_in_bytes
        a = ma.argument_size_in_bytes
        return t + (a if arg_is_donated else a)

    m_naive = c_naive.memory_analysis()
    m_ring = c_ring.memory_analysis()
    # activation footprint: temps + (pool for ring; input+temps for naive;
    # weights counted equally on both sides so subtract nothing)
    w_bytes = sum(x.size * 4 for x in jax.tree.leaves(params))
    naive_act = (m_naive.temp_size_in_bytes
                 + m_naive.argument_size_in_bytes - w_bytes
                 + m_naive.output_size_in_bytes)
    ring_act = (m_ring.temp_size_in_bytes
                + m_ring.argument_size_in_bytes - w_bytes)  # pool donated
    return {
        "case": f"M{m}x{'x'.join(map(str, dims))}",
        "naive_activation_bytes": int(naive_act),
        "ring_activation_bytes": int(ring_act),
        "xla_measured_saving": 1 - ring_act / max(naive_act, 1),
        "planner_predicted_saving": 1 - plan.pool_bytes / plan.naive_bytes,
    }


def run() -> list[dict]:
    return [measure(64, [256, 1024, 256]),
            measure(256, [512, 512, 512]),
            measure(128, [1024, 4096, 1024])]


def main() -> None:
    print("case,naive_act_kb,ring_act_kb,xla_saving,planner_saving")
    for r in run():
        print(f"{r['case']},{r['naive_activation_bytes']/1000:.0f},"
              f"{r['ring_activation_bytes']/1000:.0f},"
              f"{100*r['xla_measured_saving']:.1f}%,"
              f"{100*r['planner_predicted_saving']:.1f}%")


if __name__ == "__main__":
    main()
