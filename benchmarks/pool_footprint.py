"""TPU-level footprint proof: ``memory_analysis()`` of the compiled
``jnp``-backend PoolProgram vs the naive chain — XLA's buffer assignment
itself confirms the pool reuse (the HBM analogue of the paper's RAM
measurements)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import GemmSpec, plan_program
from repro.core.executors import _run_jnp
from repro.core.ring_buffer import init_chain_params, naive_chain_apply


def measure(m: int, dims: list[int]) -> dict:
    params = init_chain_params(jax.random.PRNGKey(0), dims)
    specs = [GemmSpec(d, activation="gelu") for d in dims[1:-1]] + \
        [GemmSpec(dims[-1])]
    # Tight (unaligned) geometry: the compiled pool then equals the
    # planner's pool_bytes, so prediction and XLA measurement compare the
    # same buffer (the jnp executor needs no DMA block alignment).
    program = plan_program(m, dims[0], specs, block_rows=None)

    # Params are real jit arguments (not closure constants) on both sides
    # so argument_size_in_bytes accounts weights identically.
    c_naive = jax.jit(naive_chain_apply).lower(
        jax.ShapeDtypeStruct((m, dims[0]), jnp.float32), params).compile()
    # _run_jnp is the jit'd executor body (donated pool, static program).
    c_ring = _run_jnp.lower(
        jax.ShapeDtypeStruct((program.n_segments, program.seg_width),
                             jnp.float32),
        [(w, b) for w, b in params], program).compile()

    m_naive = c_naive.memory_analysis()
    m_ring = c_ring.memory_analysis()
    # activation footprint: temps + (pool for ring; input+temps for naive;
    # weights counted equally on both sides so subtract nothing)
    w_bytes = sum(x.size * 4 for x in jax.tree.leaves(params))
    naive_act = (m_naive.temp_size_in_bytes
                 + m_naive.argument_size_in_bytes - w_bytes
                 + m_naive.output_size_in_bytes)
    ring_act = (m_ring.temp_size_in_bytes
                + m_ring.argument_size_in_bytes - w_bytes)  # pool donated
    return {
        "case": f"M{m}x{'x'.join(map(str, dims))}",
        "naive_activation_bytes": int(naive_act),
        "ring_activation_bytes": int(ring_act),
        "pool_bytes": program.pool_bytes,
        "naive_bytes": program.naive_bytes,
        "xla_measured_saving": 1 - ring_act / max(naive_act, 1),
        "planner_predicted_saving": program.saving_fraction,
    }


def run() -> list[dict]:
    return [measure(64, [256, 1024, 256]),
            measure(256, [512, 512, 512]),
            measure(128, [1024, 4096, 1024])]


def main(rows: list[dict] | None = None) -> None:
    rows = run() if rows is None else rows
    print("case,naive_act_kb,ring_act_kb,xla_saving,planner_saving")
    for r in rows:
        print(f"{r['case']},{r['naive_activation_bytes']/1000:.0f},"
              f"{r['ring_activation_bytes']/1000:.0f},"
              f"{100*r['xla_measured_saving']:.1f}%,"
              f"{100*r['planner_predicted_saving']:.1f}%")


if __name__ == "__main__":
    main()
