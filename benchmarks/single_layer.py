"""Paper Fig. 7 — single-layer RAM usage, 9 pointwise convolutions.

vMCU (segment plan) vs TinyEngine-style (disjoint in+out, im2col
preprocessing per §7.2) vs plain tensor-level.  Byte-exact analytic
footprints; KB = 1000 B as the paper uses.  The paper reports 12.0–49.5%
reduction — our planner's reductions per case are printed alongside.
"""
from __future__ import annotations

from repro.core.baselines import (FIG7_CASES, hmcos_bytes,
                                  pointwise_conv_layer, tinyengine_bytes)
from repro.core.planner import plan_pointwise_conv


def run() -> list[dict]:
    rows = []
    for h, c, k in FIG7_CASES:
        layer = pointwise_conv_layer(h, c, k, im2col=True)
        vmcu = plan_pointwise_conv(h, h, c, k).pool_bytes
        te = tinyengine_bytes(layer)
        hm = hmcos_bytes(pointwise_conv_layer(h, c, k, im2col=False))
        rows.append({
            "case": f"H/W{h},C{c},K{k}",
            "vmcu_kb": vmcu / 1000,
            "tinyengine_kb": te / 1000,
            "tensor_level_kb": hm / 1000,
            "reduction_vs_te": 1 - vmcu / te,
            "fits_128kb": vmcu <= 128_000,
            "te_fits_128kb": te <= 128_000,
        })
    return rows


def main(rows: list[dict] | None = None) -> None:
    rows = run() if rows is None else rows
    print("case,vmcu_kb,tinyengine_kb,reduction_vs_te,fits128,te_fits128")
    for r in rows:
        print(f"{r['case']},{r['vmcu_kb']:.1f},{r['tinyengine_kb']:.1f},"
              f"{100 * r['reduction_vs_te']:.1f}%,{r['fits_128kb']},"
              f"{r['te_fits_128kb']}")
    reds = [r["reduction_vs_te"] for r in rows]
    print(f"# reduction range: {100 * min(reds):.1f}%..{100 * max(reds):.1f}%"
          f"  (paper: 12.0%..49.5%)")


if __name__ == "__main__":
    main()
