"""Partial-execution benchmark — the SRAMBudgetError -> latency trade.

MCUNet-320KB-ImageNet's unsliced deployable byte ring (196.4 KB) does
not fit a 128 KB cortex-m4: before this subsystem that was a hard
:class:`repro.SRAMBudgetError`.  ``partial="auto"`` slices the
over-budget fusion groups spatially (recomputing halo rows) until the
ring fits, and this section records what that trade costs:

  * ``byte_ring_kb`` / ``byte_ring_sliced_kb`` — deployable ring before
    and after slicing (the budget being missed / met),
  * ``n_sliced_groups`` / ``total_slices``     — the chosen schedule,
  * ``mac_overhead``                           — recomputed MACs as a
    fraction of the whole net (the latency price),
  * ``byte_ring_over_mcu``                     — post-slice ring over
    the per-group Eq.-(2) bottleneck (1.0 = the merged multi-group ring
    costs nothing over the paper's per-group bound).

Planner-only (``quantize=False``) and fully deterministic, so the
section runs in ``--smoke`` and regressions fail CI.
"""
from __future__ import annotations

import repro

#: (net, target) — ImageNet on cortex-m4 is the genuine overflow; VWW
#: rides along as the fits-without-slicing control.
CASES = (("mcunet-320kb-imagenet", "cortex-m4"),
         ("mcunet-5fps-vww", "cortex-m4"))


def run() -> list[dict]:
    rows = []
    for net, target in CASES:
        cn = repro.compile(net, target=target, dtype="int8",
                           quantize=False, certify=False,
                           partial="auto")
        t = cn.target
        mcu = cn.mcu_bottleneck_bytes
        ring_before = cn.mcu["byte_ring_bytes"]
        p = cn.mcu.get("partial")
        ring_after = p["ring_bytes_after"] if p else ring_before
        rows.append({
            "net": net,
            "target": t.name,
            "sram_kb": t.sram_bytes / 1000,
            "mcu_bottleneck_kb": mcu / 1000,
            "byte_ring_kb": ring_before / 1000,
            "byte_ring_sliced_kb": ring_after / 1000,
            "n_sliced_groups": p["n_sliced_groups"] if p else 0,
            "total_slices": p["total_slices"] if p else 0,
            "mac_overhead": round(p["mac_overhead"], 6) if p else 0.0,
            "extra_macs": p["extra_macs"] if p else 0,
            "byte_ring_over_mcu": ring_after / mcu,
            "fits_sram_deployable": ring_after <= t.sram_bytes,
        })
    return rows


def main(rows: list[dict] | None = None) -> None:
    rows = run() if rows is None else rows
    print("net,sram_kb,ring_kb,ring_sliced_kb,slices,mac_overhead,"
          "ring_over_mcu,fits")
    for r in rows:
        print(f"{r['net']},{r['sram_kb']:.0f},{r['byte_ring_kb']:.1f},"
              f"{r['byte_ring_sliced_kb']:.1f},{r['total_slices']},"
              f"{100 * r['mac_overhead']:.2f}%,"
              f"{r['byte_ring_over_mcu']:.3f},"
              f"{r['fits_sram_deployable']}")
    print("# partial execution turns the 128KB overflow into a "
          "recompute trade: the deployable ring fits and the latency "
          "price is the mac_overhead column")


if __name__ == "__main__":
    main()
