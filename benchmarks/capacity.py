"""Paper Fig. 11 / Fig. 12 — capacity scaling at equal RAM.

At TinyEngine's per-module RAM budget, how much larger an image (Fig. 11)
or channel width (Fig. 12) can vMCU run?  Binary search per VWW module.
Paper: image 1.29x–2.58x, channels 1.26x–3.17x.
"""
from __future__ import annotations

import dataclasses

from repro.core.graph_planner import (MCUNET_5FPS_VWW,
                                      tinyengine_module_bytes,
                                      vmcu_module_bytes)


def _max_scale(cfg, budget: int, grow) -> float:
    lo, hi = 1.0, 8.0
    for _ in range(24):
        mid = (lo + hi) / 2
        if vmcu_module_bytes(grow(cfg, mid)) <= budget:
            lo = mid
        else:
            hi = mid
    return lo


def grow_image(cfg, s: float):
    return dataclasses.replace(cfg, hw=max(1, int(cfg.hw * s)))


def grow_channels(cfg, s: float):
    return dataclasses.replace(cfg, c_in=max(1, int(cfg.c_in * s)),
                               c_out=max(1, int(cfg.c_out * s)))


def run() -> list[dict]:
    rows = []
    for cfg in MCUNET_5FPS_VWW:
        budget = tinyengine_module_bytes(cfg)
        rows.append({
            "module": cfg.name,
            "image_scale": _max_scale(cfg, budget, grow_image),
            "channel_scale": _max_scale(cfg, budget, grow_channels),
        })
    return rows


def main(rows: list[dict] | None = None) -> None:
    rows = run() if rows is None else rows
    print("module,image_scale,channel_scale")
    for r in rows:
        print(f"{r['module']},{r['image_scale']:.2f},"
              f"{r['channel_scale']:.2f}")
    im = [r["image_scale"] for r in rows]
    ch = [r["channel_scale"] for r in rows]
    print(f"# image {min(im):.2f}x..{max(im):.2f}x (paper 1.29–2.58); "
          f"channels {min(ch):.2f}x..{max(ch):.2f}x (paper 1.26–3.17)")


if __name__ == "__main__":
    main()
