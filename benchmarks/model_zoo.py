"""MLPerf-Tiny model-zoo footprints — the conv_k2d workloads.

Per net (DS-CNN keyword spotting, ResNet-8 image classification,
MobileNetV1-0.25 visual wake words) the row records the byte-granular
vMCU bottleneck vs the tensor-level baseline, the executed int8 ring and
the cortex-m4 SRAM margin, all deterministic planner outputs
(``quantize=False``: no calibration, no execution) so the section runs
under ``--smoke`` and footprint regressions fail CI.
"""
from __future__ import annotations

import repro

NETS = ("ds-cnn", "resnet-8", "mobilenetv1-0.25")
TARGET = repro.get_target("cortex-m4")


def run() -> list[dict]:
    rows = []
    for name in NETS:
        cn = repro.compile(name, target=TARGET, dtype="int8",
                           quantize=False, certify=False)
        rep = cn.report()
        k2d = sum(1 for op in cn.program.ops if op.kind == "conv_k2d")
        rows.append({
            "net": name,
            "n_ops": len(cn.program.ops),
            "n_conv_k2d": k2d,
            "int8_pool_kb": cn.pool_bytes / 1000,
            "mcu_bottleneck_kb": cn.mcu_bottleneck_bytes / 1000,
            "naive_bottleneck_kb":
                rep["tinyengine_bottleneck_bytes"] / 1000,
            "saving_vs_naive": rep["reduction_vs_tinyengine"],
            "sram_margin_kb": rep["sram_margin_bytes"] / 1000,
            "fits_cortex_m4": rep["fits_sram"],
        })
    return rows


def main(rows: list[dict] | None = None) -> None:
    rows = run() if rows is None else rows
    print("net,k2d_ops,int8_pool_kb,mcu_kb,naive_kb,saving,m4_margin_kb")
    for r in rows:
        print(f"{r['net']},{r['n_conv_k2d']},{r['int8_pool_kb']:.1f},"
              f"{r['mcu_bottleneck_kb']:.1f},"
              f"{r['naive_bottleneck_kb']:.1f},"
              f"{100 * r['saving_vs_naive']:.1f}%,"
              f"{r['sram_margin_kb']:.1f}")
    print("# general k x k convs (halo frontiers) through the same "
          "one-ring planner; all three fit the paper's 128 KB board")


if __name__ == "__main__":
    main()
