"""Traffic — measured ring byte/MAC counters per zoo net.

Fig. 8's energy argument rests on RAM traffic, so this section reports
the traffic the executed schedules actually generate, not a model of it:
every net is compiled (planner-only) and traced through the SegmentPool
sim oracle.  Three independent derivations must agree BIT-EXACTLY per
net — the run asserts it:

  * closed-form clamp-span arithmetic (``energy_proxy.net_traffic``),
  * the schedule-derived static counters (``repro.obs.program_totals``,
    which also equal the safety certificate's reads/writes),
  * the sim-measured SegmentPool access counts.

Rows carry bytes loaded/stored, MACs, arithmetic intensity, the
roofline verdict at MCU machine balance, and the occupancy watermark
(== the plan's pool_bytes, also asserted).
"""
from __future__ import annotations

_ZOO = [("mcunet-5fps-vww", "cortex-m4"),
        ("mcunet-320kb-imagenet", "cortex-m7"),
        ("ds-cnn", "cortex-m4"),
        ("resnet-8", "cortex-m4"),
        ("mobilenetv1-0.25", "cortex-m4")]


def run() -> list[dict]:
    import repro
    from repro.core.executors import execute
    from repro.obs import RingTracer, build_trace, program_totals
    from repro.roofline.analysis import ring_traffic_summary

    from .energy_proxy import net_traffic

    rows = []
    for net, target in _ZOO:
        cn = repro.compile(net, target, quantize=False, certify="static")
        program = cn.program
        tracer = RingTracer()
        execute(program, backend="sim", tracer=tracer)
        art = build_trace(program, tracer=tracer, net=cn.net_name,
                          target=cn.target.name)

        static = program_totals(program)
        closed = net_traffic(program)
        measured = {"segs_read": tracer.sim_summary["reads"],
                    "segs_written": tracer.sim_summary["writes"]}
        for key, want in measured.items():
            assert static[key] == want, \
                f"{net}: static {key} {static[key]} != measured {want}"
            assert closed[key] == want, \
                f"{net}: closed-form {key} {closed[key]} != measured {want}"
        assert art.watermark_bytes == program.pool_bytes, \
            (f"{net}: watermark {art.watermark_bytes} != pool_bytes "
             f"{program.pool_bytes}")

        roof = ring_traffic_summary(art)
        rows.append({
            "net": cn.net_name,
            "target": cn.target.name,
            "dtype": cn.dtype,
            "n_ops": len(program.ops),
            "bytes_loaded": static["bytes_loaded"],
            "bytes_stored": static["bytes_stored"],
            "bytes_moved_kb": (static["bytes_loaded"]
                               + static["bytes_stored"]) / 1000,
            "macs_m": static["macs"] / 1e6,
            "arithmetic_intensity": round(
                static["arithmetic_intensity"], 3),
            "bound": roof["bound"],
            "watermark_kb": art.watermark_bytes / 1000,
            "agreement": "closed==static==measured",
        })
    return rows


def main(rows: "list[dict] | None" = None) -> None:
    rows = run() if rows is None else rows
    print("net,bytes_moved_kb,macs_m,mac_per_byte,bound,watermark_kb")
    for r in rows:
        print(f"{r['net']},{r['bytes_moved_kb']:.1f},{r['macs_m']:.2f},"
              f"{r['arithmetic_intensity']:.2f},{r['bound']},"
              f"{r['watermark_kb']:.1f}")
    print("# measured (sim oracle) == static counters == closed form, "
          "bit-exact; watermark == plan pool_bytes on every net")


if __name__ == "__main__":
    main()
