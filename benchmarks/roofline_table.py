"""§Roofline — read the dry-run JSONs and print the per-(arch × shape)
three-term table (single-pod, per the brief)."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.environ.get("DRYRUN_DIR", "results/dryrun")


def load(mesh: str = "16x16") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, f"*__{mesh}.json"))):
        rec = json.load(open(path))
        rows.append(rec)
    return rows


def main() -> None:
    rows = load()
    if not rows:
        print(f"# no dry-run results under {RESULTS} — run "
              "`python -m repro.launch.dryrun --all` first")
        return
    print("arch,cell,status,peak_GB,fits16G,t_compute_s,t_memory_s,"
          "t_collective_s,dominant,model_flops_ratio,roofline_fraction")
    for rec in rows:
        if rec["status"] != "ok":
            print(f"{rec['arch']},{rec['cell']},ERROR,,,,,,,,")
            continue
        r, m = rec["roofline"], rec["memory"]
        print(f"{rec['arch']},{rec['cell']},ok,"
              f"{m['peak_bytes']/1e9:.2f},{m['fits_16g']},"
              f"{r['t_compute_s']:.4f},{r['t_memory_s']:.4f},"
              f"{r['t_collective_s']:.4f},{r['dominant']},"
              f"{r['model_flops_ratio']:.3f},"
              f"{r['roofline_fraction']:.4f}")


if __name__ == "__main__":
    main()
