"""Shared wall-clock helper for the benchmark sections."""
from __future__ import annotations

import time

import jax


def time_us(fn, *args) -> float:
    """One blocked wall-time measurement of ``fn(*args)`` in μs.

    The result is ``jax.block_until_ready``-ed before the clock stops —
    a bare ``perf_counter`` around an async-dispatching call times the
    dispatch, not the work.
    """
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) * 1e6


def bench_us(fn, *args, iters: int = 20) -> float:
    """Mean wall-time of ``fn(*args)`` in microseconds.

    The warmup call is blocked on before the clock starts so compile and
    async dispatch never bleed into the timed region.
    """
    jax.block_until_ready(fn(*args))  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6
