"""Shared wall-clock helper for the benchmark sections."""
from __future__ import annotations

import time

import jax


def bench_us(fn, *args, iters: int = 20) -> float:
    """Mean wall-time of ``fn(*args)`` in microseconds.

    The warmup call is blocked on before the clock starts so compile and
    async dispatch never bleed into the timed region.
    """
    jax.block_until_ready(fn(*args))  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6
