"""Benchmark harness — one section per paper table/figure.

  single_layer   — Fig. 7  (RAM, 9 pointwise convs)
  energy_proxy   — Fig. 8  (memory-traffic proxy for energy)
  latency        — Table 3 (ring vs naive kernel cost, CPU-relative)
  multi_layer    — Fig. 9/10 (inverted bottlenecks, S1–S8 / B1–B17)
  capacity       — Fig. 11/12 (image/channel scaling at equal RAM)
  pool_footprint — XLA-measured ring-pool footprint (TPU adaptation)
  roofline_table — §Roofline from dry-run artifacts (if present)
"""
from __future__ import annotations

import time

from . import (capacity, energy_proxy, latency, multi_layer,
               pool_footprint, roofline_table, single_layer)

SECTIONS = [
    ("Fig7_single_layer_ram", single_layer.main),
    ("Fig8_energy_proxy", energy_proxy.main),
    ("Table3_latency", latency.main),
    ("Fig9_10_multi_layer_ram", multi_layer.main),
    ("Fig11_12_capacity", capacity.main),
    ("TPU_pool_footprint", pool_footprint.main),
    ("TPU_roofline_table", roofline_table.main),
]


def main() -> None:
    for name, fn in SECTIONS:
        print(f"\n=== {name} ===")
        t0 = time.time()
        fn()
        print(f"# section time: {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
