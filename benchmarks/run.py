"""Benchmark harness — one section per paper table/figure.

  single_layer   — Fig. 7  (RAM, 9 pointwise convs)
  energy_proxy   — Fig. 8  (memory-traffic proxy for energy)
  latency        — Table 3 (ring vs naive kernel cost, CPU-relative)
  throughput     — inferences/sec through the batched CompiledNet.run
                   fast path at batch 1/32/256
  multi_layer    — Fig. 9/10 (inverted bottlenecks, S1–S8 / B1–B17)
  full_network   — whole-DNN bottleneck via the compile facade (§7/§9):
                   the paper's 61.5% headline metric
  partial_execution — spatial slicing of over-budget fusion groups
                   (DESIGN.md §13): ring-fits-SRAM vs recompute-MAC trade
  compile_pipeline — repro.compile() pass timings + plan-artifact size
                   for the MCUNet-VWW int8 deployment (§9)
  streaming      — per-frame latency + state-resident ring bytes of the
                   streaming DS-CNN vs full recompute (DESIGN.md §14)
  capacity       — Fig. 11/12 (image/channel scaling at equal RAM)
  pool_footprint — XLA-measured ring-pool footprint (TPU adaptation)
  roofline_table — §Roofline from dry-run artifacts (if present)

Besides the human-readable stdout, the harness writes ``BENCH_vmcu.json``
(machine-readable: per-op pool_bytes / naive_bytes / saving_fraction /
wall-time records via the unified PoolProgram API, plus every section's
row dump and wall-time) so the perf trajectory is tracked across PRs.

``--smoke`` runs the fast, deterministic planner sections only (CI);
whenever a committed ``BENCH_vmcu.json`` exists, the new planner
footprints are compared against it and the run FAILS if any regressed
(``--no-check`` to skip).  Wall-time sections are gated too: every
Table 3 ring/naive ratio must stay under ``VMCU_BENCH_LATENCY_TOL``
(default 1.5) and neither latency ratios nor throughput rates may
worsen beyond ``VMCU_BENCH_REGRESS_TOL``× (default 2.0) the committed
numbers — loosen either env knob on noisy CI, or set
``VMCU_BENCH_REGRESS_TOL=0`` to disable the relative wall gates.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import jax

from . import (capacity, energy_proxy, full_network, int8_network, latency,
               model_zoo, multi_layer, partial_execution, pool_footprint,
               roofline_table, single_layer, streaming, throughput, traffic)
from .timing import bench_us, time_us

BENCH_JSON = "BENCH_vmcu.json"

#: Wall-time gate knobs.  The bench runs on a noisy shared CPU, so both
#: carry deliberate headroom; loosen them via env on noisier CI:
#:   VMCU_BENCH_LATENCY_TOL — absolute cap on every Table3 ring/naive
#:                            ratio (default 1.5; the acceptance target
#:                            is <= 1.2 under quiet conditions)
#:   VMCU_BENCH_REGRESS_TOL — relative worsening factor allowed vs the
#:                            committed BENCH_vmcu.json wall numbers
#:                            (default 2.0; <= 0 disables the relative
#:                            wall gates entirely)
LATENCY_RATIO_CAP = float(os.environ.get("VMCU_BENCH_LATENCY_TOL", "1.5"))
REGRESS_TOL = float(os.environ.get("VMCU_BENCH_REGRESS_TOL", "2.0"))


def _multi_layer_rows():
    from repro.core.graph_planner import (MCUNET_5FPS_VWW,
                                          MCUNET_320KB_IMAGENET)
    return {"vww": multi_layer.run(MCUNET_5FPS_VWW),
            "imagenet": multi_layer.run(MCUNET_320KB_IMAGENET)}


#: (net, target, full) — full=True additionally quantizes + saves the
#: artifact; the rest are planner-only (ring + certificate only).
_PIPELINE_ZOO = [("mcunet-5fps-vww", "cortex-m4", True),
                 ("mcunet-320kb-imagenet", "cortex-m7", False),
                 ("ds-cnn", "cortex-m4", False),
                 ("resnet-8", "cortex-m4", False),
                 ("mobilenetv1-0.25", "cortex-m4", False)]


def _best_of(fn, n=3):
    """Best-of-n wall seconds; every call is blocked on its JAX result
    (``timing.time_us``) — a bare perf_counter around async dispatch
    times the dispatch, not the work."""
    return min(time_us(fn) for _ in range(n)) / 1e6


def _compile_pipeline_rows():
    """One-call deployment trajectory: per-pass seconds + artifact size
    for the MCUNet-VWW int8 flow, plus certify-mode timings (static
    proof vs sim replay, best-of-3) for every zoo net (DESIGN.md §9/§11).
    """
    import tempfile

    import repro
    from repro.analysis import verify_program
    from repro.graph.run import certify_net

    rows = []
    for net, target, full in _PIPELINE_ZOO:
        cn = repro.compile(net, target=target, quantize=full,
                           certify="static")
        program = cn.program
        t_sim = _best_of(lambda: certify_net(program))
        assert verify_program(program).safe is True
        t_static = _best_of(lambda: verify_program(program))
        row = {
            "net": cn.net_name,
            "target": cn.target.name,
            "passes": {p.name: round(p.seconds, 4) for p in cn.passes},
            "int8_pool_kb": cn.pool_bytes / 1000,
            "mcu_bottleneck_kb": cn.mcu_bottleneck_bytes / 1000,
            "sram_margin_kb": cn.target.sram_margin(
                cn.mcu_bottleneck_bytes) / 1000,
            "flash_used_kb": cn.flash_bytes_used / 1000,
            "certify_sim_s": round(t_sim, 6),
            "certify_static_s": round(t_static, 6),
            "certify_speedup": round(t_sim / t_static, 1),
        }
        if full:
            with tempfile.NamedTemporaryFile(suffix=".plan.json") as f:
                cn.save(f.name)
                row["artifact_kb"] = os.path.getsize(f.name) / 1000
            row["n_c_units"] = len(cn.emit_c())
            # the 15s hotspot, decomposed (obs.spans sub-spans)
            q = next((s for s in cn.spans or []
                      if s["name"] == "quantize"), None)
            if q is not None:
                row["quantize_spans"] = {
                    c["name"]: round(c["seconds"], 4)
                    for c in q["children"]}
        rows.append(row)
    return rows


def _compile_pipeline_show(rows):
    for r in rows:
        extra = ""
        if "artifact_kb" in r:
            extra = (f" artifact={r['artifact_kb']:.0f}KB "
                     f"c_units={r['n_c_units']}")
        print(f"{r['net']} -> {r['target']}: int8_pool={r['int8_pool_kb']:.1f}KB "
              f"mcu_bottleneck={r['mcu_bottleneck_kb']:.1f}KB" + extra)
        print("  passes: " + ", ".join(f"{k}={v:.2f}s"
                                       for k, v in r["passes"].items()))
        if "quantize_spans" in r:
            print("  quantize: " + ", ".join(
                f"{k}={v:.2f}s" for k, v in r["quantize_spans"].items()))
        print(f"  certify: sim={r['certify_sim_s'] * 1e3:.2f}ms "
              f"static={r['certify_static_s'] * 1e3:.2f}ms "
              f"({r['certify_speedup']:.0f}x)")


def check_latency_gate(rows, old_rows=None) -> list[str]:
    """Wall-time gate on Table 3: every ring/naive ratio must stay
    under the absolute cap, and must not worsen beyond REGRESS_TOL×
    the committed ratio (wall-times were previously exempt from the
    regression check — a real slowdown could land silently)."""
    bad = []
    old = {r["case"]: r for r in (old_rows or [])}
    for r in rows:
        if r["ratio"] > LATENCY_RATIO_CAP:
            bad.append(
                f"latency gate: {r['case']} ring/naive ratio "
                f"{r['ratio']:.2f} > cap {LATENCY_RATIO_CAP:.2f} "
                f"(VMCU_BENCH_LATENCY_TOL to loosen)")
        prev = old.get(r["case"])
        if prev and REGRESS_TOL > 0 \
                and r["ratio"] > prev["ratio"] * REGRESS_TOL:
            bad.append(
                f"latency gate: {r['case']} ratio {r['ratio']:.2f} > "
                f"{REGRESS_TOL:.1f}x committed {prev['ratio']:.2f} "
                f"(VMCU_BENCH_REGRESS_TOL to loosen)")
    return bad


def check_throughput_gate(rows, old_rows=None) -> list[str]:
    """The Throughput section must be populated with positive rates and
    must not collapse beyond REGRESS_TOL× vs the committed numbers."""
    if not rows:
        return ["throughput gate: Throughput section empty"]
    bad = []
    old = {(r["net"], r["batch"]): r for r in (old_rows or [])}
    for r in rows:
        if not r["inf_per_sec"] > 0:
            bad.append(f"throughput gate: {r['net']} batch {r['batch']} "
                       f"rate {r['inf_per_sec']} not positive")
            continue
        prev = old.get((r["net"], r["batch"]))
        if prev and REGRESS_TOL > 0 \
                and r["inf_per_sec"] < prev["inf_per_sec"] / REGRESS_TOL:
            bad.append(
                f"throughput gate: {r['net']} batch {r['batch']} "
                f"{r['inf_per_sec']:.1f} inf/s < committed "
                f"{prev['inf_per_sec']:.1f} / {REGRESS_TOL:.1f} "
                f"(VMCU_BENCH_REGRESS_TOL to loosen)")
    return bad


def check_certify_gate(rows) -> list[str]:
    """--smoke gate: the static proof must cost <10% of the sim replay
    on MCUNet-VWW (the acceptance headline; other nets are recorded
    but not gated — their replay is too quick for a stable ratio)."""
    bad = []
    for r in rows:
        if r["net"] != "mcunet-5fps-vww":
            continue
        if r["certify_static_s"] >= 0.1 * r["certify_sim_s"]:
            bad.append(
                f"certify gate: static {r['certify_static_s'] * 1e3:.2f}ms"
                f" >= 10% of sim {r['certify_sim_s'] * 1e3:.2f}ms on "
                f"{r['net']}")
    return bad


# (name, collector-or-None, printer, in_smoke).  Collectors run once;
# printers reuse the collected rows where the section supports it.
SECTIONS = [
    ("Fig7_single_layer_ram", single_layer.run, single_layer.main, True),
    ("Fig8_energy_proxy", energy_proxy.run, energy_proxy.main, True),
    ("Table3_latency", latency.run, latency.main, True),
    ("Throughput", throughput.run, throughput.main, True),
    ("Fig9_10_multi_layer_ram", _multi_layer_rows, multi_layer.main, True),
    ("Net_full_network", full_network.run, full_network.main, True),
    ("Int8_full_network", int8_network.run, int8_network.main, True),
    ("Partial_execution", partial_execution.run, partial_execution.main,
     True),
    ("Zoo_k2d", model_zoo.run, model_zoo.main, True),
    ("Traffic", traffic.run, traffic.main, True),
    ("Compile_pipeline", _compile_pipeline_rows, _compile_pipeline_show,
     True),
    ("Streaming", streaming.run, streaming.main, True),
    ("Fig11_12_capacity", capacity.run, capacity.main, True),
    ("TPU_pool_footprint", pool_footprint.run, pool_footprint.main, False),
    ("TPU_roofline_table", None, lambda rows: roofline_table.main(), False),
]


def bench_ops(smoke: bool = False) -> list[dict]:
    """Per-PoolOp trajectory records via the unified program API.

    Besides the whole-program ``wall_us_jnp`` best, each record carries
    tracer-measured per-op wall times for the jnp executor (and for
    pallas outside ``--smoke`` — interpret mode on CPU is too slow for
    the fast lane)."""
    import jax.numpy as jnp
    from repro.core import (FusedMLPSpec, GemmSpec, VirtualPool, execute,
                            plan_program)
    from repro.obs import RingTracer

    key = jax.random.PRNGKey(0)
    cases = [
        ("gemm_128x384x256", 128, 384, [GemmSpec(256)]),
        ("fused_mlp_64x512x2048", 64, 512,
         [FusedMLPSpec(2048, ff_tile=512)]),
        ("chain3_64x256x1024x256", 64, 256,
         [GemmSpec(1024, "gelu"), GemmSpec(256)]),
    ]
    records = []
    for name, m, d_in, specs in cases:
        program = plan_program(m, d_in, specs, block_rows=8)
        params = []
        for op in program.ops:
            key, k1, k2, k3 = jax.random.split(key, 4)
            if op.kind == "gemm":
                params.append(
                    (jax.random.normal(k1, (op.d_in, op.d_out)) / 16,
                     jnp.zeros((op.d_out,))))
            else:
                params.append(
                    (jax.random.normal(k1, (op.d_in, op.d_ff)) / 16,
                     jax.random.normal(k2, (op.d_in, op.d_ff)) / 16,
                     jax.random.normal(k3, (op.d_ff, op.d_in)) / 32))
        x = jax.random.normal(key, (m, d_in))
        pool0 = VirtualPool.alloc(program.spec(x.dtype)) \
            .stage_rows(x, program.input_ptr)
        wall_us = bench_us(
            lambda: execute(program, VirtualPool(pool0.array.copy()),
                            params, backend="jnp").array, iters=10)

        def _op_walls(backend: str) -> list[float]:
            tracer = RingTracer()
            execute(program, VirtualPool(pool0.array.copy()), params,
                    backend=backend, tracer=tracer)   # warm the jits
            tracer = RingTracer()
            execute(program, VirtualPool(pool0.array.copy()), params,
                    backend=backend, tracer=tracer)
            return [round(tracer.wall_s[i] * 1e6, 1)
                    for i in range(len(program.ops))]

        rec = {
            "name": name,
            "ops": [op.kind for op in program.ops],
            "m_rows": m,
            "pool_bytes": program.pool_bytes,
            "physical_pool_bytes": program.physical_pool_bytes,
            "naive_bytes": program.naive_bytes,
            "saving_fraction": program.saving_fraction,
            "wall_us_jnp": wall_us,
            "wall_us_per_op": wall_us / len(program.ops),
            "op_wall_us_jnp": _op_walls("jnp"),
        }
        if not smoke:  # pallas interprets on CPU — full lane only
            rec["op_wall_us_pallas"] = _op_walls("pallas")
        records.append(rec)
    return records


# ---------------------------------------------------------------------------
# Footprint-regression check (wall-times are excluded by design).
# ---------------------------------------------------------------------------

def _footprints(payload: dict) -> dict[str, float]:
    """Flatten every deterministic planner footprint in a payload."""
    out: dict[str, float] = {}
    for rec in payload.get("ops", []):
        for fld in ("pool_bytes", "physical_pool_bytes"):
            if fld in rec:
                out[f"ops/{rec['name']}/{fld}"] = rec[fld]
    sections = payload.get("sections", {})
    for r in sections.get("Net_full_network", []):
        out[f"net/{r['net']}/vmcu_bottleneck_kb"] = \
            r["vmcu_bottleneck_kb"]
        out[f"net/{r['net']}/exec_pool_kb"] = r["exec_pool_kb"]
    for r in sections.get("Int8_full_network", []):
        out[f"int8/{r['net']}/int8_pool_kb"] = r["int8_pool_kb"]
        out[f"int8/{r['net']}/int8_byte_ring_kb"] = r["int8_byte_ring_kb"]
        out[f"int8/{r['net']}/mcu_bottleneck_kb"] = r["mcu_bottleneck_kb"]
    for r in sections.get("Partial_execution", []):
        out[f"partial/{r['net']}/byte_ring_sliced_kb"] = \
            r["byte_ring_sliced_kb"]
        out[f"partial/{r['net']}/mac_overhead"] = r["mac_overhead"]
    for r in sections.get("Zoo_k2d", []):
        out[f"zoo/{r['net']}/int8_pool_kb"] = r["int8_pool_kb"]
        out[f"zoo/{r['net']}/mcu_bottleneck_kb"] = r["mcu_bottleneck_kb"]
    for r in sections.get("Compile_pipeline", []):
        out[f"compile/{r['net']}/int8_pool_kb"] = r["int8_pool_kb"]
        out[f"compile/{r['net']}/mcu_bottleneck_kb"] = \
            r["mcu_bottleneck_kb"]
    for r in sections.get("Streaming", []):
        out[f"stream/{r['net']}/state_kb"] = r["state_kb"]
        out[f"stream/{r['net']}/ring_kb"] = r["ring_kb"]
        out[f"stream/{r['net']}/step_bytes_kb"] = r["step_bytes_kb"]
    for r in sections.get("Traffic", []):
        out[f"traffic/{r['net']}/bytes_moved_kb"] = r["bytes_moved_kb"]
        out[f"traffic/{r['net']}/watermark_kb"] = r["watermark_kb"]
    ml = sections.get("Fig9_10_multi_layer_ram", {})
    for net_key, rows in (ml.items() if isinstance(ml, dict) else []):
        for r in rows:
            out[f"module/{net_key}/{r['module']}/vmcu_kb"] = r["vmcu_kb"]
    return out


def check_regressions(old_payload: dict, new_payload: dict) -> list[str]:
    """Return messages for every footprint that got WORSE (larger)."""
    old = _footprints(old_payload)
    new = _footprints(new_payload)
    bad = []
    for key, new_val in new.items():
        old_val = old.get(key)
        if old_val is not None and new_val > old_val * (1 + 1e-9):
            bad.append(f"{key}: {old_val} -> {new_val}")
    return bad


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast deterministic planner sections only")
    ap.add_argument("--no-check", action="store_true",
                    help="skip the footprint-regression comparison")
    args = ap.parse_args(argv)

    old_payload = None
    if not args.no_check and os.path.exists(BENCH_JSON):
        with open(BENCH_JSON) as f:
            old_payload = json.load(f)

    # one span per section (perf_counter under the hood) — the old
    # time.time() + round(.., 2) pipeline reported 0.0 for every
    # sub-10ms section
    from repro.obs.spans import SpanCollector, collect, span

    collector = SpanCollector()
    section_times = {}
    section_rows = {}
    for name, collect_rows, show, in_smoke in SECTIONS:
        if args.smoke and not in_smoke:
            continue
        print(f"\n=== {name} ===")
        with collect(collector), span(name):
            rows = collect_rows() if collect_rows is not None else None
            show(rows)
        section_times[name] = round(collector.spans[-1].seconds, 6)
        if rows is not None:
            section_rows[name] = rows
        print(f"# section time: {section_times[name]:.3f}s")

    ops = bench_ops(smoke=args.smoke)
    payload = {
        "schema": 2,
        "backend": jax.default_backend(),
        "smoke": args.smoke,
        "ops": ops,
        "section_time_s": section_times,
        "sections": section_rows,
    }

    if args.smoke and "Compile_pipeline" in section_rows:
        bad = check_certify_gate(section_rows["Compile_pipeline"])
        if bad:
            print("\n# STATIC-CERTIFY GATE FAILED:")
            for msg in bad:
                print(f"#   {msg}")
            sys.exit(1)

    old_sections = (old_payload or {}).get("sections", {})
    wall_bad = []
    if "Table3_latency" in section_rows:
        wall_bad += check_latency_gate(
            section_rows["Table3_latency"],
            old_sections.get("Table3_latency"))
    if "Throughput" in section_rows:
        wall_bad += check_throughput_gate(
            section_rows["Throughput"], old_sections.get("Throughput"))
    if wall_bad:
        print("\n# WALL-TIME GATE FAILED:")
        for msg in wall_bad:
            print(f"#   {msg}")
        sys.exit(1)

    if old_payload is not None:
        bad = check_regressions(old_payload, payload)
        if bad:
            print("\n# PLANNER FOOTPRINT REGRESSIONS vs recorded "
                  f"{BENCH_JSON}:")
            for msg in bad:
                print(f"#   {msg}")
            sys.exit(1)
        print(f"\n# no footprint regressions vs recorded {BENCH_JSON}")

    with open(BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"\n# wrote {BENCH_JSON} ({len(ops)} op records)")


if __name__ == "__main__":
    main()
