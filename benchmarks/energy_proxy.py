"""Paper Fig. 8 — energy proxy.

Energy on MCU "is highly related to the total number of memory accesses and
execution latency" (§7.2).  No energy rail exists on this container, so we
report the mechanism the paper identifies: RAM/HBM traffic per inference.
TinyEngine pays (a) an im2col round-trip per pixel and (b) separate
write-out; vMCU streams segments once.  Counted analytically per Fig.-7
case, bytes moved per output pixel.
"""
from __future__ import annotations

from repro.core.baselines import FIG7_CASES


def traffic(h: int, c: int, k: int, *, im2col: bool) -> int:
    px = h * h
    read_in = px * c              # read activation once
    im2col_rt = 2 * px * c if im2col else 0  # write + reread patch buffer
    write_out = px * k
    reread_out = px * k if im2col else 0     # TinyEngine post-process pass
    return read_in + im2col_rt + write_out + reread_out


def run() -> list[dict]:
    rows = []
    for h, c, k in FIG7_CASES:
        v = traffic(h, c, k, im2col=False)
        t = traffic(h, c, k, im2col=True)
        rows.append({"case": f"H/W{h},C{c},K{k}", "vmcu_bytes": v,
                     "tinyengine_bytes": t, "saving": 1 - v / t})
    return rows


def main(rows: list[dict] | None = None) -> None:
    rows = run() if rows is None else rows
    print("case,vmcu_traffic_kb,tinyengine_traffic_kb,energy_proxy_saving")
    for r in rows:
        print(f"{r['case']},{r['vmcu_bytes']/1000:.1f},"
              f"{r['tinyengine_bytes']/1000:.1f},{100*r['saving']:.1f}%")
    ss = [r["saving"] for r in rows]
    print(f"# traffic-proxy saving range {100*min(ss):.1f}%.."
          f"{100*max(ss):.1f}% (paper energy: 20.6%..53.0%)")


if __name__ == "__main__":
    main()
