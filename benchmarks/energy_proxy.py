"""Paper Fig. 8 — energy proxy.

Energy on MCU "is highly related to the total number of memory accesses and
execution latency" (§7.2).  No energy rail exists on this container, so we
report the mechanism the paper identifies: RAM/HBM traffic per inference.
TinyEngine pays (a) an im2col round-trip per pixel and (b) separate
write-out; vMCU streams segments once.  Counted analytically per Fig.-7
case, bytes moved per output pixel.
"""
from __future__ import annotations

from repro.core.baselines import FIG7_CASES


def traffic(h: int, c: int, k: int, *, im2col: bool) -> int:
    px = h * h
    read_in = px * c              # read activation once
    im2col_rt = 2 * px * c if im2col else 0  # write + reread patch buffer
    write_out = px * k
    reread_out = px * k if im2col else 0     # TinyEngine post-process pass
    return read_in + im2col_rt + write_out + reread_out


# ---------------------------------------------------------------------------
# Closed-form per-net traffic — asserted against the measured trace.
# ---------------------------------------------------------------------------

def _halo_rows(h_in: int, h_out: int, k: int, stride: int, pad: int) -> int:
    """Total in-image halo rows read by a k-row spatial conv: per output
    row ``p`` the window ``[p*stride - pad, p*stride - pad + k)`` clipped
    to the image."""
    total = 0
    for p in range(h_out):
        lo = max(0, p * stride - pad)
        hi = min(h_in, p * stride - pad + k)
        total += max(0, hi - lo)
    return total


def net_traffic(program) -> dict:
    """Independent closed-form segment traffic of one planned program.

    Pure clamp-span arithmetic per op kind — it never enumerates the
    ``core.rowsched`` schedules — yet it must equal BOTH the
    schedule-derived static counters (``repro.obs.program_totals``) and
    the tracer-measured SegmentPool counts bit-exactly (asserted per zoo
    net by ``benchmarks/traffic.py``); Fig. 8's energy proxy is thereby
    demoted from a trusted model to a cross-checked one.  The counting
    convention is the safety certificate's: staging writes and output
    survival reads included.
    """
    from repro.core.rowsched import conv_k2d_pad
    from repro.core.vpool import segments_for

    sw = program.seg_width
    segs_read, segs_written = 0, 0
    for op in program.ops:
        ci = segments_for(op.d_in, sw)
        co = segments_for(op.d_out, sw)
        m = op.rows_in or program.m_rows
        if op.kind == "gemm":
            segs_read += m * co * ci       # row m re-read per out segment
            segs_written += m * co
        elif op.kind == "conv_pw":
            segs_read += op.h_out * op.w_in * ci
            segs_written += op.h_out * op.w_out * co
        elif op.kind == "conv_dw":
            segs_read += _halo_rows(op.h_in, op.h_out, op.rs, op.stride,
                                    (op.rs - 1) // 2) * op.w_in * ci
            segs_written += op.h_out * op.w_out * co
        elif op.kind == "conv_k2d":
            segs_read += _halo_rows(op.h_in, op.h_out, op.rs, op.stride,
                                    conv_k2d_pad(op.rs, op.padding)) \
                * op.w_in * ci
            segs_written += op.h_out * op.w_out * co
        elif op.kind == "ib_fused":
            h, pad = op.h_in, (op.rs - 1) // 2
            rows = min(pad + 1, h) + (h - 1)   # primed halo + 1/step
            if op.residual and pad > 0:        # re-read of row p, except
                rows += max(h - 2, 0)          # where it IS the halo row
            segs_read += rows * op.w_in * ci
            segs_written += h * op.w_out * co
        elif op.kind == "add":
            segs_read += 2 * op.rows_in * ci   # chained + held residual
            segs_written += op.rows_in * ci
        elif op.kind == "pool_avg":
            segs_read += op.h_in * op.w_in * ci
            segs_written += co
        elif op.kind in ("fused_mlp", "elementwise"):
            segs_read += m * ci
            segs_written += m * ci
        else:
            raise NotImplementedError(
                f"no closed-form traffic for op kind {op.kind!r}")
    segs_read += program.ops[-1].out_segments     # output survival reads
    segs_written += program.ops[0].in_segments    # input staging writes
    seg_bytes = program.seg_width * program.elem_bytes
    return {"segs_read": segs_read, "segs_written": segs_written,
            "bytes_loaded": segs_read * seg_bytes,
            "bytes_stored": segs_written * seg_bytes,
            "bytes_moved": (segs_read + segs_written) * seg_bytes}


def run() -> list[dict]:
    rows = []
    for h, c, k in FIG7_CASES:
        v = traffic(h, c, k, im2col=False)
        t = traffic(h, c, k, im2col=True)
        rows.append({"case": f"H/W{h},C{c},K{k}", "vmcu_bytes": v,
                     "tinyengine_bytes": t, "saving": 1 - v / t})
    return rows


def main(rows: list[dict] | None = None) -> None:
    rows = run() if rows is None else rows
    print("case,vmcu_traffic_kb,tinyengine_traffic_kb,energy_proxy_saving")
    for r in rows:
        print(f"{r['case']},{r['vmcu_bytes']/1000:.1f},"
              f"{r['tinyengine_bytes']/1000:.1f},{100*r['saving']:.1f}%")
    ss = [r["saving"] for r in rows]
    print(f"# traffic-proxy saving range {100*min(ss):.1f}%.."
          f"{100*max(ss):.1f}% (paper energy: 20.6%..53.0%)")


if __name__ == "__main__":
    main()
