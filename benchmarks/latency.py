"""Paper Table 3 — latency: the ring kernels must cost ≈ the plain kernels.

The paper's claim is that segment-level management adds only modular
addressing (vMCU = 1.03x TinyEngine).  We time the jit'd ``jnp``-backend
execution of a planned ``PoolProgram`` vs the naive chain on CPU (relative
cost of the ring mechanics).  Wall-times here are CPU-relative indicators,
not TPU numbers.
"""
from __future__ import annotations

import jax

from repro.core import GemmSpec, VirtualPool, execute, plan_program
from repro.core.ring_buffer import init_chain_params, naive_chain_apply

from .timing import bench_us


def _chain_specs(dims: list[int]) -> list[GemmSpec]:
    return [GemmSpec(d, activation="gelu") for d in dims[1:-1]] + \
        [GemmSpec(dims[-1])]


def run() -> list[dict]:
    rows = []
    for m, dims in ((64, [256, 1024, 256]), (128, [512, 512, 512]),
                    (32, [384, 1536, 384])):
        params = init_chain_params(jax.random.PRNGKey(0), dims)
        x = jax.random.normal(jax.random.PRNGKey(1), (m, dims[0]))
        program = plan_program(m, dims[0], _chain_specs(dims), block_rows=8)
        naive_us = bench_us(jax.jit(lambda x: naive_chain_apply(x, params)),
                            x)

        pool0 = VirtualPool.alloc(program.spec(x.dtype)) \
            .stage_rows(x, program.input_ptr)

        # Non-donating jit: the staged pool is read-only per call (one
        # dispatch per iteration, like the naive closure), so the ring's
        # cost is execution + modular addressing, not a host-side copy.
        ring_jit = jax.jit(lambda arr: execute(
            program, VirtualPool(arr), params, backend="jnp").array)
        ring_us = bench_us(ring_jit, pool0.array)
        rows.append({"case": f"M{m}x{'x'.join(map(str, dims))}",
                     "naive_us": naive_us, "ring_us": ring_us,
                     "ratio": ring_us / naive_us,
                     "pool_bytes": program.pool_bytes,
                     "naive_bytes": program.naive_bytes,
                     "pool_saving": program.saving_fraction})
    return rows


def main(rows: list[dict] | None = None) -> None:
    rows = run() if rows is None else rows
    print("case,naive_us,ring_us,ratio,pool_saving")
    for r in rows:
        print(f"{r['case']},{r['naive_us']:.0f},{r['ring_us']:.0f},"
              f"{r['ratio']:.2f},{100*r['pool_saving']:.1f}%")
    print("# paper: vMCU latency ~= 1.03x TinyEngine at 13-61% RAM saving")


if __name__ == "__main__":
    main()
