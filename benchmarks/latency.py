"""Paper Table 3 — latency: the ring kernels must cost ≈ the plain kernels.

The paper's claim is that segment-level management adds only modular
addressing (vMCU = 1.03x TinyEngine).  We time the jit'd ring-pool chain vs
the naive chain on CPU (relative cost of the ring mechanics), plus the
interpret-mode Pallas kernel vs its oracle at small shapes.
Wall-times here are CPU-relative indicators, not TPU numbers.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.ring_buffer import (init_chain_params, naive_chain_apply,
                                    plan_chain, ring_chain_apply,
                                    write_rows)


def _bench(fn, *args, iters=20) -> float:
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> list[dict]:
    rows = []
    for m, dims in ((64, [256, 1024, 256]), (128, [512, 512, 512]),
                    (32, [384, 1536, 384])):
        params = init_chain_params(jax.random.PRNGKey(0), dims)
        x = jax.random.normal(jax.random.PRNGKey(1), (m, dims[0]))
        plan = plan_chain(m, dims)
        naive_us = _bench(jax.jit(lambda x: naive_chain_apply(x, params)), x)

        pool0 = write_rows(jnp.zeros((plan.n_segments, plan.seg_width)),
                           x, plan.layer_ptrs[0][0] - plan.layer_ptrs[-1][1],
                           plan.n_segments)

        def ring_fn(p):
            return ring_chain_apply(p, params, plan, 8)
        ring_us = _bench(lambda: ring_fn(pool0.copy()), iters=20)
        rows.append({"case": f"M{m}x{'x'.join(map(str, dims))}",
                     "naive_us": naive_us, "ring_us": ring_us,
                     "ratio": ring_us / naive_us,
                     "pool_saving": 1 - plan.pool_bytes / plan.naive_bytes})
    return rows


def main() -> None:
    rows = run()
    print("case,naive_us,ring_us,ratio,pool_saving")
    for r in rows:
        print(f"{r['case']},{r['naive_us']:.0f},{r['ring_us']:.0f},"
              f"{r['ratio']:.2f},{100*r['pool_saving']:.1f}%")
    print("# paper: vMCU latency ~= 1.03x TinyEngine at 13-61% RAM saving")


if __name__ == "__main__":
    main()
