"""Int8 whole-network benchmark — the executed quantized ring next to
the paper's byte-granular MCU bottleneck, via the compile facade.

With the int8 execution subsystem the *executed* ring and the *reported*
MCU footprint are finally in the same unit (bytes of int8 state).  Per
network this section records:

  * ``int8_pool_kb``        — the executed int8 ring (the MCU target's
                              registry geometry: seg_width=128 segment
                              rows, DMA-block aligned; pallas-grade),
  * ``int8_byte_ring_kb``   — the same unfused plan solved at the
                              target's byte-ring granularity
                              (seg_width=1, tight; sim/jnp-grade) — the
                              executed number comparable to
                              ``mcu_bottleneck_kb`` at the paper's
                              granularity,
  * ``mcu_bottleneck_kb``   — the byte-granular Eq.-(2) bottleneck
                              (paper Fig. 9/10 metric),
  * ``fp32_to_int8_saving`` — the exact pool saving of quantized
                              execution (4x: same segment geometry, 1
                              byte per element).

Both geometries come from the :class:`repro.compile.targets.Target`
registry — one definition site, shared with full_network — and all
numbers are deterministic planner outputs (``quantize=False``: no
calibration, no execution), so the section runs in ``--smoke`` and
regressions fail CI.
"""
from __future__ import annotations

import repro

NETS = ("mcunet-5fps-vww", "mcunet-320kb-imagenet", "ds-cnn",
        "resnet-8", "mobilenetv1-0.25")
TARGET = repro.get_target("cortex-m4")


def run() -> list[dict]:
    rows = []
    for name in NETS:
        # check_budget=False: this section REPORTS footprints (ImageNet's
        # unsliced byte ring legitimately overflows cortex-m4 — the
        # Partial_execution section shows the slicing that resolves it)
        cn = repro.compile(name, target=TARGET, dtype="int8",
                           quantize=False, certify=False,
                           check_budget=False)
        int8 = cn.program
        fp32 = int8.with_dtype("float32")
        byte_ring = repro.compile(name, target=TARGET, dtype="int8",
                                  quantize=False, certify=False,
                                  check_budget=False,
                                  **TARGET.byte_ring_kwargs)
        mcu = cn.mcu_bottleneck_bytes
        rows.append({
            "net": name,
            "n_ops": len(int8.ops),
            "int8_pool_kb": int8.pool_bytes / 1000,
            "int8_byte_ring_kb": byte_ring.pool_bytes / 1000,
            "fp32_pool_kb": fp32.pool_bytes / 1000,
            "mcu_bottleneck_kb": mcu / 1000,
            "fp32_to_int8_saving":
                1.0 - int8.pool_bytes / fp32.pool_bytes,
            "byte_ring_over_mcu":
                byte_ring.pool_bytes / mcu,
            # the executed host-side ring is NOT what lands on the MCU;
            # the deployable verdict judges the byte-granular ring
            "fits_256kb_executed": int8.pool_bytes <= 256_000,
            "fits_256kb_deployable": byte_ring.pool_bytes <= 256_000,
        })
    return rows


def main(rows: list[dict] | None = None) -> None:
    rows = run() if rows is None else rows
    print("net,int8_pool_kb,byte_ring_kb,mcu_kb,fp32_kb,saving")
    for r in rows:
        print(f"{r['net']},{r['int8_pool_kb']:.1f},"
              f"{r['int8_byte_ring_kb']:.1f},{r['mcu_bottleneck_kb']:.1f},"
              f"{r['fp32_pool_kb']:.1f},"
              f"{100 * r['fp32_to_int8_saving']:.1f}%")
    print("# int8 execution shrinks the executed ring exactly 4x; the "
          "byte-granular ring is the number comparable to the paper's "
          "mcu_bottleneck (remaining gap = unfused execution + held "
          "residual sources)")


if __name__ == "__main__":
    main()
