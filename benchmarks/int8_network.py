"""Int8 whole-network benchmark — the executed quantized ring next to
the paper's byte-granular MCU bottleneck.

With the int8 execution subsystem the *executed* ring and the *reported*
MCU footprint are finally in the same unit (bytes of int8 state).  Per
network this section records:

  * ``int8_pool_kb``        — the executed int8 ring (seg_width=128,
                              pallas-grade geometry; one 128-byte segment
                              per pixel row chunk),
  * ``int8_byte_ring_kb``   — the same unfused plan solved at byte
                              granularity (seg_width=1; sim/jnp-grade) —
                              the executed number comparable to
                              ``mcu_bottleneck_kb`` at the paper's
                              granularity,
  * ``mcu_bottleneck_kb``   — the byte-granular Eq.-(2) bottleneck
                              (paper Fig. 9/10 metric),
  * ``fp32_to_int8_saving`` — the exact pool saving of quantized
                              execution (4x: same segment geometry, 1
                              byte per element).

All numbers are deterministic planner outputs (no execution), so the
section runs in ``--smoke`` and regressions fail CI.
"""
from __future__ import annotations

from repro.core.graph_planner import (MCUNET_5FPS_VWW,
                                      MCUNET_320KB_IMAGENET)
from repro.graph import build_mcunet, plan_net

NETS = (("mcunet-5fps-vww", MCUNET_5FPS_VWW, 2),
        ("mcunet-320kb-imagenet", MCUNET_320KB_IMAGENET, 1000))


def run() -> list[dict]:
    rows = []
    for name, modules, classes in NETS:
        graph = build_mcunet(modules, name, num_classes=classes)
        fp32 = plan_net(graph, fused_exec=False)
        int8 = fp32.program.with_dtype("int8")
        byte_ring = plan_net(graph, fused_exec=False, dtype="int8",
                             seg_width=1, block_rows=None)
        mcu = fp32.mcu_bottleneck_bytes
        rows.append({
            "net": name,
            "n_ops": len(int8.ops),
            "int8_pool_kb": int8.pool_bytes / 1000,
            "int8_byte_ring_kb": byte_ring.program.pool_bytes / 1000,
            "fp32_pool_kb": fp32.program.pool_bytes / 1000,
            "mcu_bottleneck_kb": mcu / 1000,
            "fp32_to_int8_saving":
                1.0 - int8.pool_bytes / fp32.program.pool_bytes,
            "byte_ring_over_mcu":
                byte_ring.program.pool_bytes / mcu,
            "fits_256kb_int8": int8.pool_bytes <= 256_000,
        })
    return rows


def main(rows: list[dict] | None = None) -> None:
    rows = run() if rows is None else rows
    print("net,int8_pool_kb,byte_ring_kb,mcu_kb,fp32_kb,saving")
    for r in rows:
        print(f"{r['net']},{r['int8_pool_kb']:.1f},"
              f"{r['int8_byte_ring_kb']:.1f},{r['mcu_bottleneck_kb']:.1f},"
              f"{r['fp32_pool_kb']:.1f},"
              f"{100 * r['fp32_to_int8_saving']:.1f}%")
    print("# int8 execution shrinks the executed ring exactly 4x; the "
          "byte-granular ring is the number comparable to the paper's "
          "mcu_bottleneck (remaining gap = unfused execution + held "
          "residual sources)")


if __name__ == "__main__":
    main()
