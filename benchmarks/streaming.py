"""Streaming — per-frame cost of the persistent-state subsystem.

The streaming DS-CNN keeps its stem window ring-resident (DESIGN.md
§14) and touches only the new MFCC frame per step; the full-recompute
baseline re-runs the one-shot net on the whole window every frame.
Rows report, per net:

  * ``state_kb`` / ``ring_kb`` — state-resident ring bytes and the
    whole physical ring (frame extent + state), vs the one-shot ring,
  * ``step_bytes_kb`` vs ``full_bytes_kb`` — steady-state segment
    traffic per new frame, from the *static certificate* counters (the
    sim oracle equals them bit-exactly; ``tests/test_stream.py`` pins
    the N-step arithmetic),
  * ``wall_us_step`` vs ``wall_us_full`` — measured jnp per-frame
    latency for one stream step vs one full recompute.

Byte metrics are deterministic planner outputs and regression-gated by
the harness; wall times are recorded but never gated.
"""
from __future__ import annotations

#: (net, target, dtype) — the streaming lane of the zoo.
_NETS = [("ds-cnn", "cortex-m4", "int8")]


def run() -> list[dict]:
    import jax

    import repro
    from repro.analysis import verify_program
    from repro.quant import QParams, quantize

    from .timing import bench_us

    rows = []
    for net, target, dtype in _NETS:
        cs = repro.compile(net, target, dtype=dtype, streaming=True)
        cf = repro.compile(net, target, dtype=dtype, certify=False)
        sprog = cs.qnet.program if cs.quantized else cs.program
        fprog = cf.qnet.program if cf.quantized else cf.program
        cert = cs.certificate
        assert cert["clobbers"] == 0
        assert cert["stream_horizon"] == "unbounded"
        full = verify_program(fprog)
        assert full.safe is True

        seg_bytes = sprog.seg_width * sprog.elem_bytes
        state_segs = cert["state_segments"]
        # steady-state per-frame traffic: every step re-reads/rewrites
        # the state and moves the frame program; the one-time state
        # pre-write is excluded (tests pin counters(N) = init + N*step)
        step_segs = cert["reads"] + cert["writes"] - state_segs
        full_segs = full.stats["reads"] + full.stats["writes"]

        sess = cs.stream(backend="jnp")
        key = jax.random.PRNGKey(0)
        frame = jax.random.normal(
            key, (sprog.ops[0].rows_in, sprog.in_dim))
        x = jax.random.normal(key, (fprog.in_rows, fprog.in_dim))
        if cs.quantized:
            frame = quantize(frame, QParams(scale=cs.qnet.in_scale))
        sess.step(frame)                         # warm the jit
        wall_step = bench_us(lambda: sess.step(frame), iters=10)
        cf.run(x)                                # warm the jit
        wall_full = bench_us(lambda: cf.run(x), iters=10)

        assert sprog.physical_pool_bytes <= cs.target.sram_bytes
        rows.append({
            "net": cs.net_name,
            "target": cs.target.name,
            "dtype": cs.dtype,
            "horizon": cert["stream_horizon"],
            "n_states": cert["n_states"],
            "state_kb": state_segs * seg_bytes / 1000,
            "ring_kb": sprog.physical_pool_bytes / 1000,
            "full_ring_kb": fprog.physical_pool_bytes / 1000,
            "step_bytes_kb": step_segs * seg_bytes / 1000,
            "full_bytes_kb": full_segs * seg_bytes / 1000,
            "traffic_saving": round(1 - step_segs / full_segs, 4),
            "wall_us_step": wall_step,
            "wall_us_full": wall_full,
        })
    return rows


def main(rows: "list[dict] | None" = None) -> None:
    rows = run() if rows is None else rows
    print("net,dtype,state_kb,ring_kb,full_ring_kb,step_bytes_kb,"
          "full_bytes_kb,traffic_saving,wall_us_step,wall_us_full")
    for r in rows:
        print(f"{r['net']},{r['dtype']},{r['state_kb']:.1f},"
              f"{r['ring_kb']:.1f},{r['full_ring_kb']:.1f},"
              f"{r['step_bytes_kb']:.1f},{r['full_bytes_kb']:.1f},"
              f"{r['traffic_saving']:.2%},{r['wall_us_step']:.0f},"
              f"{r['wall_us_full']:.0f}")
    print("# per-frame byte traffic from the static certificate "
          "(sim-exact); horizon certified unbounded on every net")


if __name__ == "__main__":
    main()
