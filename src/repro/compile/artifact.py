"""Serializable plan artifacts (DESIGN.md §9).

A compiled net is a *closed* deployment artifact: the solved
:class:`PoolProgram` (pure ints — the Eq.-(1)/(2) offsets, so loading
never re-runs the branch-and-bound scheduler), the parameter payloads
(float weights, int8 weights + int32 biases, requant multiplier/shift
tables) and the byte-granular MCU accounting.  This module is the
JSON codec for those payloads:

  * arrays  -> ``{"__array__": <base64 raw bytes>, dtype, shape}`` —
    bit-exact roundtrips for every dtype (int8/int32/float32/bfloat16),
  * tuples  -> ``{"__tuple__": [...]}`` (parameter entries are tuples;
    executors index them positionally),
  * ints / floats / strings / None / lists / dicts pass through as JSON
    scalars (Python's JSON float codec is repr-based, so activation
    scales roundtrip bit-exactly too).
"""
from __future__ import annotations

import base64
import hashlib
import json

import numpy as np

SCHEMA = 1
KIND = "vmcu-compiled-net"


def program_sha256(program) -> str:
    """Canonical content hash of a :class:`PoolProgram`.

    Hashes the sorted-key compact JSON of the program's own dict form,
    so it is stable across processes and identical for a program and its
    save/load roundtrip.  Certificates embed it (``vmcu-lint`` flags a
    mismatch as VMCU403: the plan changed after it was certified)."""
    blob = json.dumps(program.to_json_dict(), sort_keys=True,
                      separators=(",", ":"))
    return hashlib.sha256(blob.encode("utf-8")).hexdigest()


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # jax's extended dtypes (bfloat16 et al.)

        return np.dtype(getattr(ml_dtypes, name))


def encode(obj):
    """Recursively encode params/qparams into JSON-safe structures."""
    if obj is None or isinstance(obj, (bool, str)):
        return obj
    if isinstance(obj, (int, np.integer)):
        return int(obj)
    if isinstance(obj, (float, np.floating)):
        return float(obj)
    if isinstance(obj, tuple):
        return {"__tuple__": [encode(v) for v in obj]}
    if isinstance(obj, list):
        return [encode(v) for v in obj]
    if isinstance(obj, dict):
        return {k: encode(v) for k, v in obj.items()}
    arr = np.asarray(obj)  # jax arrays land here (device -> host copy)
    return {"__array__": base64.b64encode(arr.tobytes()).decode("ascii"),
            "dtype": arr.dtype.name, "shape": list(arr.shape)}


def decode(obj):
    """Inverse of :func:`encode`; arrays come back as jnp arrays so the
    executors treat loaded and freshly-compiled params identically."""
    import jax.numpy as jnp

    if isinstance(obj, dict):
        if "__tuple__" in obj:
            return tuple(decode(v) for v in obj["__tuple__"])
        if "__array__" in obj:
            dt = _np_dtype(obj["dtype"])
            raw = np.frombuffer(base64.b64decode(obj["__array__"]),
                                dtype=dt)
            return jnp.asarray(raw.reshape(obj["shape"]))
        return {k: decode(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [decode(v) for v in obj]
    return obj


def dump(payload: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(payload, f)


def load(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    if payload.get("kind") != KIND:
        raise ValueError(f"{path} is not a {KIND} artifact")
    if payload.get("schema") != SCHEMA:
        raise ValueError(f"artifact schema {payload.get('schema')} != "
                         f"supported {SCHEMA}")
    return payload
