"""Hardware target descriptors for the one-call deployment driver.

A :class:`Target` captures everything the compile pipeline previously
asked the caller to hand-wire per call site: the SRAM/flash budgets the
plan is gated against, the executed ring geometry (segment width + DMA
block alignment), the SIMD width and requantization idiom the emitted C
is annotated for (``__SMLAD`` on Cortex-M4/M7 vs Helium MVE
``VMLADAVA.S8``/``VQRDMULH`` on M55/M85), and the default pool dtype.

The registry ships the three descriptors the reproduction is measured
on (``cortex-m4``, ``cortex-m7``, ``host-sim``) plus an MVE-class part
(``cortex-m55``); :func:`register_target` adds new boards without
touching the driver.  Benchmarks and examples read their seg-rows /
alignment knobs from here — ONE definition site (DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses

from ..core.vpool import SEG_WIDTH

#: Requantization idioms the codegen annotates (DESIGN.md §8).
REQUANT_IDIOMS = ("smlad", "mve", "none")


@dataclasses.dataclass(frozen=True)
class Target:
    """One deployment target's hardware envelope + planning defaults.

    ``seg_width``/``block_rows`` are the *executed* ring geometry (the
    TPU-adapted segment pool every backend runs); the paper's
    byte-granular MCU accounting is target-independent and exposed as
    :attr:`byte_ring_kwargs`.  ``sram_bytes`` gates the byte-granular
    deployable bottleneck in the driver's ``budget`` pass.
    """

    name: str
    cpu: str
    sram_bytes: int
    flash_bytes: int
    seg_width: int = SEG_WIDTH
    block_rows: int | None = 1    # DMA block alignment (None = tight)
    kernel_block_rows: int = 8    # pallas execution granularity cap
                                  # (rows fused per grid step; NOT plan
                                  # geometry — certificates are unchanged)
    simd_bits: int = 32
    requant_idiom: str = "smlad"  # one of REQUANT_IDIOMS
    default_dtype: str = "int8"
    default_backend: str = "jnp"  # executor the CompiledNet runs on

    def __post_init__(self):
        if self.requant_idiom not in REQUANT_IDIOMS:
            raise ValueError(f"unknown requant idiom "
                             f"{self.requant_idiom!r}; known: "
                             f"{REQUANT_IDIOMS}")
        if self.sram_bytes <= 0 or self.flash_bytes <= 0:
            raise ValueError(f"target {self.name!r} needs positive "
                             "sram/flash budgets")

    # -- planner knobs (ONE definition site) ------------------------------
    @property
    def plan_kwargs(self) -> dict:
        """The executed-ring ``plan_net`` geometry of this target."""
        return {"seg_width": self.seg_width, "block_rows": self.block_rows}

    @property
    def byte_ring_kwargs(self) -> dict:
        """The paper's byte-granular geometry (Fig. 9/10 metric): one
        byte per segment, tight Eq.-(1)/(2) pointers.  Shared by every
        target — int8 bytes are int8 bytes on any MCU."""
        return {"seg_width": 1, "block_rows": None}

    # -- budgets -----------------------------------------------------------
    def fits_sram(self, bytes_: int) -> bool:
        return bytes_ <= self.sram_bytes

    def sram_margin(self, bytes_: int) -> int:
        return self.sram_bytes - bytes_


_REGISTRY: dict[str, Target] = {}
_ALIASES: dict[str, str] = {}


def register_target(target: Target, *aliases: str,
                    overwrite: bool = False) -> Target:
    """Add ``target`` (and optional alias names) to the registry."""
    if target.name in _REGISTRY and not overwrite:
        raise ValueError(f"target {target.name!r} already registered")
    _REGISTRY[target.name] = target
    for a in aliases:
        _ALIASES[a] = target.name
    return target


def get_target(target: str | Target) -> Target:
    """Resolve a target name (or pass a Target descriptor through)."""
    if isinstance(target, Target):
        return target
    name = _ALIASES.get(target, target)
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown target {target!r}; known: "
                         f"{list_targets()}") from None


def list_targets() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# The stock descriptors.
# ---------------------------------------------------------------------------

# The paper's evaluation boards: STM32F446RE (Cortex-M4, 128 KB SRAM —
# the deployment story of examples/mcu_plan.py) and an M7-class part
# with the larger 320 KB SRAM tier.  Both requantize via the dual-MAC
# __SMLAD idiom; int8 is the deployment dtype.
register_target(Target(
    name="cortex-m4", cpu="Arm Cortex-M4 (STM32F446RE)",
    sram_bytes=128_000, flash_bytes=512_000,
    simd_bits=32, requant_idiom="smlad", default_dtype="int8"))

register_target(Target(
    name="cortex-m7", cpu="Arm Cortex-M7 (STM32F746ZG)",
    sram_bytes=320_000, flash_bytes=1_024_000,
    simd_bits=64, requant_idiom="smlad", default_dtype="int8"))

# Helium/MVE-class part: 128-bit vector requant (VMLADAVA.S8 + VQRDMULH).
register_target(Target(
    name="cortex-m55", cpu="Arm Cortex-M55 (Helium MVE)",
    sram_bytes=256_000, flash_bytes=2_048_000,
    simd_bits=128, requant_idiom="mve", default_dtype="int8"))

# Development target: the TPU-adapted float ring with an effectively
# unbounded budget — every pass runs, nothing gates.
register_target(Target(
    name="host-sim", cpu="host (XLA cpu/tpu; Pallas interpret)",
    sram_bytes=1 << 40, flash_bytes=1 << 40,
    simd_bits=128 * 32, requant_idiom="none",
    default_dtype="float32"))
