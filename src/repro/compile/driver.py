"""The one-call deployment driver: ``repro.compile(net, target)``.

The paper's value proposition is an end-to-end flow — model in,
segment-ring plan + MCU kernels out.  This driver packages the repo's
previously hand-wired steps (``build_* -> reorder -> plan_net ->
quantize_net -> sim certify -> emit_program``) as a named pass pipeline
over a :class:`repro.compile.targets.Target` descriptor, DORY /
TinyEngine-style:

  ``build``     resolve the net (Graph or registered name) and validate,
  ``schedule``  operator reordering (branch-and-bound over topo orders),
  ``plan``      solve ONE segment ring for the whole net (Eq. 1/2),
  ``budget``    gate the byte-granular bottleneck on the target's SRAM
                (pure arithmetic — runs BEFORE the expensive passes so
                an over-budget net fails in milliseconds),
  ``quantize``  int8 calibration + requant tables (int8 targets),
  ``lint``      budget/consistency findings (``repro.analysis.lint``:
                VMCU3xx/4xx — errors abort, warnings ride in the note),
  ``certify``   prove the plan clobber-free.  ``certify="static"`` runs
                the abstract interpreter (``repro.analysis``) instead of
                replaying the schedule through the SegmentPool sim —
                same certificate, orders of magnitude faster — and falls
                back to the sim replay (recording why) on the rare
                program outside the decidable fragment.

The result is a :class:`CompiledNet`: ``.run(x)`` on any executor
backend, ``.emit_c(dir)`` for the intrinsic-C units, ``.report()`` for
footprint-vs-budget accounting, and ``.save()``/``.load()`` JSON plan
artifacts — deployment never re-runs the scheduler (DESIGN.md §9).

``plan_net`` / ``quantize_net`` remain importable as deprecated shims
over the same internals this driver calls.
"""
from __future__ import annotations

import dataclasses
import time

from ..core.codegen import emit_program
from ..core.program import PoolProgram, dtype_itemsize
from ..graph.ir import (Graph, build_ad_autoencoder, build_ds_cnn,
                        build_mcunet, build_mobilenet_v1, build_resnet8)
from ..graph.netplan import NetPlan, _plan_net
from ..graph.run import (QuantizedNet, _quantize_net, certify_net,
                         init_net_params, run_net, run_net_quantized)
from ..graph.schedule import reorder
from ..obs.spans import SpanCollector, collect, span
from . import artifact
from .targets import Target, get_target

PASS_NAMES = ("build", "schedule", "plan", "budget", "partial",
              "quantize", "lint", "certify")

_UNSET = object()


class CompileError(Exception):
    """A pass of the compile pipeline failed."""


class SRAMBudgetError(CompileError):
    """The planned net does not fit the target's SRAM budget."""


# ---------------------------------------------------------------------------
# Net registry — names the CLI / benchmarks compile by.
# ---------------------------------------------------------------------------

def _vww() -> Graph:
    from ..core.graph_planner import MCUNET_5FPS_VWW

    return build_mcunet(MCUNET_5FPS_VWW, "mcunet-5fps-vww", num_classes=2)


def _imagenet() -> Graph:
    from ..core.graph_planner import MCUNET_320KB_IMAGENET

    return build_mcunet(MCUNET_320KB_IMAGENET, "mcunet-320kb-imagenet",
                        num_classes=1000)


def _ds_cnn_stream() -> Graph:
    from ..stream import to_streaming

    return to_streaming(build_ds_cnn())


# MLPerf-Tiny-class model zoo: real k x k spatial convs (conv_k2d)
# through the same one-ring planner as the MCUNet tables, plus the
# FC-heavy ToyADMOS anomaly-detection autoencoder and the per-frame
# streaming form of DS-CNN (persistent window state on the ring).
_NET_BUILDERS = {"mcunet-5fps-vww": _vww, "mcunet-320kb-imagenet": _imagenet,
                 "ds-cnn": build_ds_cnn, "resnet-8": build_resnet8,
                 "mobilenetv1-0.25": build_mobilenet_v1,
                 "ad-toyadmos": build_ad_autoencoder,
                 "ds-cnn-stream": _ds_cnn_stream}
_NET_ALIASES = {"mcunet-vww": "mcunet-5fps-vww",
                "mcunet-imagenet": "mcunet-320kb-imagenet",
                "dscnn": "ds-cnn", "resnet8": "resnet-8",
                "mobilenet-v1": "mobilenetv1-0.25",
                "toyadmos": "ad-toyadmos", "ad-ae": "ad-toyadmos",
                "dscnn-stream": "ds-cnn-stream"}


def available_nets() -> tuple[str, ...]:
    return tuple(sorted(_NET_BUILDERS))


def _resolve_net(net) -> Graph:
    if isinstance(net, Graph):
        return net
    if isinstance(net, str):
        name = _NET_ALIASES.get(net, net)
        try:
            return _NET_BUILDERS[name]()
        except KeyError:
            raise ValueError(f"unknown net {net!r}; known: "
                             f"{available_nets()}") from None
    raise TypeError(f"net must be a Graph or a registered name, got "
                    f"{type(net).__name__}")


# ---------------------------------------------------------------------------
# CompiledNet.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PassRecord:
    name: str
    seconds: float
    note: str = ""


def _nbytes(obj) -> int:
    """Total array bytes in a params/qparams structure (flash estimate)."""
    import numpy as np

    if obj is None or isinstance(obj, (bool, int, float, str)):
        return 0
    if isinstance(obj, (list, tuple)):
        return sum(_nbytes(v) for v in obj)
    return np.asarray(obj).nbytes


def _flash_param_bytes(program: PoolProgram,
                       parents: list[int] | None = None) -> int:
    """Analytic float-parameter storage (4 B/element, the init_net_params
    shapes) — lets ``report()`` account flash without materializing
    parameters on planner-only compiles.  ``parents`` (sliced programs)
    counts each unsliced op's parameters once across its slices."""
    total = 0
    seen: set[int] = set()
    for i, op in enumerate(program.ops):
        if parents is not None:
            if parents[i] in seen:
                continue
            seen.add(parents[i])
        if op.kind in ("gemm", "conv_pw"):
            total += op.d_in * op.d_out
        elif op.kind in ("conv_k2d", "conv_stream"):
            total += op.rs * op.rs * op.d_in * op.d_out
        elif op.kind == "gru_cell":
            total += (op.d_in + op.d_out) * 3 * op.d_out
        elif op.kind == "conv_dw":
            total += op.rs * op.rs * op.d_in
        elif op.kind == "ib_fused":
            total += (op.d_in * op.d_mid + op.rs * op.rs * op.d_mid
                      + op.d_mid * op.d_out)
        elif op.kind == "fused_mlp":
            total += 3 * op.d_in * op.d_ff
    return total * 4


@dataclasses.dataclass
class CompiledNet:
    """A deployed network: one solved ring + everything needed to run,
    emit, report and serialize it.

    ``program`` is the *executed* program (int8-typed for quantized
    targets); ``plan``/``graph`` carry the full NetPlan and IR when the
    net was compiled in-process and are ``None`` after :meth:`load`
    (the artifact is self-contained — ``mcu`` snapshots the
    byte-granular accounting)."""

    net_name: str
    target: Target
    dtype: str
    program: PoolProgram
    params: list | None        # lazily He-initialized (planner-only
                               # compiles never materialize parameters)
    qnet: QuantizedNet | None
    mcu: dict
    certificate: dict | None
    passes: list
    plan: NetPlan | None = None
    graph: Graph | None = None
    init_key: object = None    # PRNG key for lazy parameter init
    spans: list | None = None  # nested timed pipeline spans (obs.spans)
    partial: dict | None = None  # partial-execution accounting + parents

    # -- classification ----------------------------------------------------
    @property
    def quantized(self) -> bool:
        return self.qnet is not None

    @property
    def partial_parents(self) -> list[int] | None:
        """Sliced-op -> unsliced-op index map (``None`` when unsliced)."""
        if self.partial is None:
            return None
        return self.partial.get("parents")

    def ensure_params(self) -> list:
        """Materialize the float parameters on first need (run/save of a
        planner-only compile); quantized compiles already carry them."""
        if self.params is None:
            if self.plan is None:
                raise CompileError("no parameters in this CompiledNet "
                                   "and no plan to initialize them from")
            base = init_net_params(self.plan, self.init_key)
            parents = self.partial_parents
            self.params = (base if parents is None
                           else [base[p] for p in parents])
        return self.params

    # -- footprints --------------------------------------------------------
    @property
    def pool_bytes(self) -> int:
        """The executed ring footprint (bytes of pool state)."""
        return self.program.pool_bytes

    @property
    def mcu_bottleneck_bytes(self) -> int:
        """The byte-granular deployable bottleneck (paper Fig. 9/10)."""
        return self.mcu["mcu_bottleneck_bytes"]

    def _dedup_by_parent(self, entries: list) -> list:
        """Slices of one op share its parameters — count flash once."""
        parents = self.partial_parents
        if parents is None:
            return entries
        seen: set[int] = set()
        kept = []
        for p, e in zip(parents, entries):
            if p not in seen:
                seen.add(p)
                kept.append(e)
        return kept

    @property
    def flash_bytes_used(self) -> int:
        """Parameter storage the target's flash must hold (exact for
        materialized params/qparams, analytic otherwise)."""
        if self.quantized:
            return _nbytes(self._dedup_by_parent(self.qnet.qparams))
        if self.params is not None:
            return _nbytes(self._dedup_by_parent(self.params))
        return _flash_param_bytes(self.program, self.partial_parents)

    def fits(self) -> bool:
        return self.target.fits_sram(self.mcu_bottleneck_bytes)

    # -- execution ---------------------------------------------------------
    def run(self, x, *, backend: str | None = None, trace: bool = False,
            **kwargs):
        """Run the compiled net on ``x`` (float in / float out; int8
        targets quantize on entry and dequantize on exit).

        ``trace=True`` threads a :class:`repro.obs.RingTracer` through
        the executor (per-op synchronized wall times) and returns
        ``(y, TraceArtifact)`` instead of ``y``.  ``trace=False`` is the
        zero-cost path: no tracer reaches the executor and the ``jnp``
        backend keeps its whole-program jit (bit-identical output).

        A leading batch dimension (``x.ndim == 3``) runs every sample
        through the ONE solved plan: vmapped on the ``jnp`` backend
        (one pool per lane, shared program/params), a device loop on
        ``pallas`` (the kernels alias the pool in place per sample).
        Batched ``trace=True`` traces each sample and returns one
        artifact whose counters are the certificate scaled by exactly
        the batch size (wall times sum across lanes).
        """
        backend = backend or self.target.default_backend
        import jax
        import jax.numpy as jnp

        xa = jnp.asarray(x)
        if xa.ndim == 3:
            if trace:
                return self._run_batch_traced(xa, backend, **kwargs)
            if backend != "jnp":
                return jnp.stack([self.run(xi, backend=backend, **kwargs)
                                  for xi in xa])
            from ..core.executors import run_program

            if self.quantized:
                # quantize/dequantize are host-side numpy (deliberately
                # un-traced) — batch them OUTSIDE the vmapped ring run
                from ..quant import QParams, dequantize, quantize

                qn = self.qnet
                xq = quantize(xa, QParams(scale=qn.in_scale))
                yq = jax.vmap(lambda s: run_program(
                    qn.program, s, qn.qparams, backend="jnp")[0])(xq)
                return dequantize(yq, QParams(scale=qn.out_scale))
            params = self.ensure_params()
            return jax.vmap(lambda s: run_program(
                self.program, s, params, backend="jnp")[0])(xa)
        tracer = None
        if trace:
            from ..obs import RingTracer

            tracer = kwargs["tracer"] = RingTracer()
        if backend == "pallas":
            # Execution granularity only (rows fused per Pallas grid
            # step) — the plan and its certificates are untouched.
            kwargs.setdefault("kernel_block_rows",
                              self.target.kernel_block_rows)
        if self.quantized:
            y = run_net_quantized(self.qnet, x, backend=backend,
                                  **kwargs)
        elif self.program.quantized:
            raise CompileError(
                "this is a planner-only int8 compile (quantize=False): "
                "the ring geometry exists but no calibrated qparams — "
                "recompile with quantize=True to execute")
        else:
            y = run_net(self.program, x, self.ensure_params(),
                        backend=backend, **kwargs)
        if tracer is None:
            return y
        from ..obs import build_trace

        art = build_trace(self.program, tracer=tracer, backend=backend,
                          net=self.net_name, target=self.target.name,
                          spans=self.spans)
        return y, art

    def _run_batch_traced(self, xa, backend: str, **kwargs):
        """Batched ``trace=True``: every sample runs through the ONE
        solved plan with its own tracer; wall times sum across lanes
        and the schedule-derived counters scale by exactly the batch —
        the certificate × batch invariant the tests pin.  (The
        occupancy timeline and watermark stay per-sample: each lane
        runs its own pool.)"""
        import jax.numpy as jnp

        from ..obs import RingTracer, build_trace

        agg = RingTracer()
        agg.backend = backend
        ys = []
        for xi in xa:
            t = RingTracer()
            ys.append(self.run(xi, backend=backend, tracer=t, **kwargs))
            for i, s in t.wall_s.items():
                agg.wall_s[i] = agg.wall_s.get(i, 0.0) + s
        art = build_trace(self.program, tracer=agg, backend=backend,
                          net=self.net_name, target=self.target.name,
                          spans=self.spans)
        batch = int(xa.shape[0])
        scaled = ("steps", "segs_read", "segs_written", "bytes_loaded",
                  "bytes_stored", "macs", "requants")
        for ev in art.events:
            for k in scaled:
                if k in ev:
                    ev[k] = ev[k] * batch
        for k in scaled:
            if k in art.totals:
                art.totals[k] = art.totals[k] * batch
        art.totals["batch"] = batch
        return jnp.stack(ys), art

    def stream(self, *, backend: str | None = None, trace: bool = False):
        """Open a :class:`repro.stream.StreamSession` on this net — the
        per-frame reset/step driver over the persistent-state ring.
        Requires a streaming compile (``streaming=True`` or a graph
        with ``conv_stream``/``gru_cell`` nodes)."""
        from ..stream import StreamSession

        return StreamSession(
            self, backend=backend or self.target.default_backend,
            trace=trace)

    def profile(self, x=None, *, backend: str | None = None):
        """One traced run on a deterministic input; returns the
        :class:`repro.obs.TraceArtifact` (geometry, per-op byte/MAC
        counters + wall times, occupancy timeline, compile spans).

        Planner-only int8 compiles (no qparams) profile through the sim
        oracle instead — measured segment traffic, no numerics."""
        if self.program.quantized and not self.quantized:
            from ..core.executors import execute
            from ..obs import RingTracer, build_trace

            tracer = RingTracer()
            execute(self.program, backend="sim", tracer=tracer)
            return build_trace(self.program, tracer=tracer,
                               net=self.net_name, target=self.target.name,
                               spans=self.spans)
        if x is None:
            import jax

            x = jax.random.normal(
                jax.random.PRNGKey(0),
                (self.program.in_rows, self.program.in_dim))
        _y, art = self.run(x, backend=backend, trace=True)
        return art

    # -- C emission --------------------------------------------------------
    def emit_c(self, outdir=None, *, name: str | None = None,
               geometry_only: bool = False,
               idiom: str | None = _UNSET) -> dict[str, str]:
        """Emit one intrinsic-C unit per op (``{filename: source}``).

        Quantized nets bake their requant tables in; ``geometry_only``
        emits just the solved ring skeleton (byte-typed pool header, no
        requant constants — the deterministic form the CLI smoke gate
        diffs against goldens).  ``idiom`` defaults to the target's
        requant idiom banner.  ``outdir`` additionally writes the files.
        """
        if idiom is _UNSET:
            idiom = (self.target.requant_idiom
                     if self.target.requant_idiom != "none" else None)
        name = name or self.net_name
        if geometry_only or not self.quantized:
            if not geometry_only and self.program.quantized:
                raise CompileError(
                    "this is a planner-only int8 compile (quantize="
                    "False): no requant tables to bake — recompile with "
                    "quantize=True, or pass geometry_only=True for the "
                    "ring skeleton")
            prog = (self.program.with_dtype("byte") if geometry_only
                    else self.program)
            units = emit_program(prog, name, idiom=idiom)
        else:
            units = emit_program(self.qnet.program, name,
                                 quant=self.qnet.qparams, idiom=idiom)
        if outdir is not None:
            import pathlib

            out = pathlib.Path(outdir)
            out.mkdir(parents=True, exist_ok=True)
            for fname, src in units.items():
                (out / fname).write_text(src)
        return units

    # -- reporting ---------------------------------------------------------
    def report(self) -> dict:
        """Footprint / bottleneck accounting against the target budget."""
        t = self.target
        bot = self.mcu_bottleneck_bytes
        deploy = self.mcu.get("deploy_bytes") or bot
        flash = self.flash_bytes_used
        rep = {
            "net": self.net_name,
            "target": t.name,
            "cpu": t.cpu,
            "dtype": self.dtype,
            "n_ops": len(self.program.ops),
            "pool_bytes": self.pool_bytes,
            "physical_pool_bytes": self.program.physical_pool_bytes,
            "mcu_bottleneck_bytes": bot,
            "tinyengine_bottleneck_bytes":
                self.mcu.get("tinyengine_bottleneck_bytes"),
            "hmcos_bottleneck_bytes":
                self.mcu.get("hmcos_bottleneck_bytes"),
            "reduction_vs_tinyengine":
                self.mcu.get("reduction_vs_tinyengine"),
            "reduction_vs_hmcos": self.mcu.get("reduction_vs_hmcos"),
            "bottleneck_group": self.mcu.get("bottleneck_group"),
            "byte_ring_bytes": self.mcu.get("byte_ring_bytes"),
            "deploy_bytes": self.mcu.get("deploy_bytes"),
            "partial": self.mcu.get("partial"),
            "sram_bytes": t.sram_bytes,
            "sram_margin_bytes": t.sram_margin(deploy),
            "fits_sram": t.fits_sram(deploy),
            "flash_bytes": t.flash_bytes,
            "flash_bytes_used": flash,
            "fits_flash": flash <= t.flash_bytes,
            "certificate": self.certificate,
            "passes": [[p.name, round(p.seconds, 4), p.note]
                       for p in self.passes],
        }
        return rep

    # -- plan artifacts ----------------------------------------------------
    def save(self, path: str) -> str:
        """Write the solved plan + payloads as a JSON artifact.

        Loading it back (:meth:`load`) reproduces ``pool_bytes``, the
        emitted C and bit-identical execution without ever re-running
        the branch-and-bound scheduler."""
        payload = {
            "schema": artifact.SCHEMA,
            "kind": artifact.KIND,
            "net": self.net_name,
            "target": dataclasses.asdict(self.target),
            "dtype": self.dtype,
            "program": self.program.to_json_dict(),
            "params": artifact.encode(self.ensure_params()),
            "quant": None if not self.quantized else {
                "act_scales": list(self.qnet.act_scales),
                "qparams": artifact.encode(self.qnet.qparams),
            },
            "mcu": self.mcu,
            "certificate": self.certificate,
            "passes": [[p.name, p.seconds, p.note] for p in self.passes],
            "spans": self.spans,
            "partial": self.partial,
        }
        artifact.dump(payload, path)
        return path

    @classmethod
    def load(cls, path: str) -> "CompiledNet":
        payload = artifact.load(path)
        target = Target(**payload["target"])
        program = PoolProgram.from_json_dict(payload["program"])
        cert = payload.get("certificate")
        if cert is not None and "program_sha256" in cert:
            have = artifact.program_sha256(program)
            if cert["program_sha256"] != have:
                raise CompileError(
                    f"VMCU403: {path} certificate does not match its "
                    f"program (certified {cert['program_sha256'][:12]}"
                    f"..., stored {have[:12]}...) — the plan changed "
                    "after it was certified")
        params = artifact.decode(payload["params"])
        qnet = None
        if payload["quant"] is not None:
            qnet = QuantizedNet(
                plan=None, program=program, params=params,
                qparams=artifact.decode(payload["quant"]["qparams"]),
                act_scales=tuple(payload["quant"]["act_scales"]))
        return cls(net_name=payload["net"], target=target,
                   dtype=payload["dtype"], program=program, params=params,
                   qnet=qnet, mcu=payload["mcu"],
                   certificate=payload["certificate"],
                   passes=[PassRecord(n, s, note)
                           for n, s, note in payload["passes"]],
                   spans=payload.get("spans"),
                   partial=payload.get("partial"))


def load(path: str) -> CompiledNet:
    """Load a saved plan artifact (module-level alias)."""
    return CompiledNet.load(path)


# ---------------------------------------------------------------------------
# The pipeline.
# ---------------------------------------------------------------------------

def _mcu_summary(plan: NetPlan) -> dict:
    """Snapshot the byte-granular accounting so it survives save/load."""
    return {
        "mcu_bottleneck_bytes": plan.mcu_bottleneck_bytes,
        "tinyengine_bottleneck_bytes": plan.tinyengine_bottleneck_bytes,
        "hmcos_bottleneck_bytes": plan.hmcos_bottleneck_bytes,
        "reduction_vs_tinyengine": plan.reduction_vs_tinyengine,
        "reduction_vs_hmcos": plan.reduction_vs_hmcos,
        "mcu_pool_bytes": plan.mcu_pool_bytes,
        "bottleneck_group": plan.bottleneck_group().name,
        "n_groups": len(plan.groups),
        "groups": [{"name": g.name, "kind": g.group.kind,
                    "fused_exec": g.group.fused_exec,
                    "mcu_bytes": g.group.mcu_bytes,
                    "te_bytes": g.group.te_bytes,
                    "hmcos_bytes": g.group.hmcos_bytes}
                   for g in plan.groups],
    }


def compile(net, target: str | Target = "host-sim", *, dtype=None,
            fused_exec: bool | None = None, seg_width: int | None = None,
            block_rows=_UNSET, order=None, params=None, key=None,
            calib=None, n_calib: int = 2, quantize: bool = True,
            certify: bool | str = True, lint: bool = True,
            check_budget: bool = True, partial: str | int = "off",
            streaming: bool = False) -> CompiledNet:
    """Compile ``net`` for ``target`` — the repo's deployment front door.

    ``net`` is a :class:`repro.graph.Graph` or a registered net name
    (:func:`available_nets`); ``target`` a :class:`Target` or registry
    name.  Every knob defaults from the target descriptor: ``dtype``
    (``target.default_dtype``), ring geometry (``seg_width`` /
    ``block_rows``), and ``fused_exec`` (unfused for int8 — the
    CMSIS-NN deployment form quantization requires).  ``params`` /
    ``key`` seed the float parameters (He-init with PRNGKey(0) when
    omitted — deterministic, and materialized lazily so planner-only
    compiles never pay for init); ``calib``/``n_calib`` feed int8
    calibration.  ``quantize=False`` plans an int8 ring without
    calibrating (planner-only, ``.run`` unavailable); ``certify`` is
    ``True``/``"sim"`` (replay the SegmentPool clobber oracle),
    ``"static"`` (prove it with :func:`repro.analysis.verify_program`,
    sim fallback outside the decidable fragment) or ``False`` (skip);
    ``lint=False`` skips the VMCU3xx/4xx lint pass;
    ``check_budget=False`` records the SRAM verdict without raising
    :class:`SRAMBudgetError`.

    ``partial`` enables partial execution (DESIGN.md §13): ``"auto"``
    slices over-budget fusion groups spatially until the deployable
    ring fits the target SRAM (demoting :class:`SRAMBudgetError` into
    a scheduled latency/memory trade), an ``int`` forces that many
    slices on the ring-pinning group, ``"off"`` (default) keeps the
    hard budget gate.

    ``streaming=True`` converts the resolved feed-forward graph to its
    per-frame streaming form (:func:`repro.stream.to_streaming`) before
    planning, then re-certifies the streaming plan — state liveness
    included.  Run it with :meth:`CompiledNet.stream`.
    """
    if certify not in (True, False, "sim", "static"):
        raise ValueError(f"certify must be True/False/'sim'/'static', "
                         f"got {certify!r}")
    if not (partial in ("off", "auto") or isinstance(partial, int)):
        raise ValueError(f"partial must be 'off', 'auto' or an int "
                         f"slice count, got {partial!r}")
    t = get_target(target)
    dtype = dtype or t.default_dtype
    dtype_itemsize(dtype)  # fail fast on unknown dtypes
    if fused_exec is None:
        # partial execution slices the unfused pw/dw/pw chain — the
        # same deployment form int8 quantization requires
        fused_exec = dtype != "int8" and partial == "off"
    elif fused_exec and dtype == "int8":
        raise CompileError(
            "int8 compilation requires unfused module lowering "
            "(fused_exec=False): quantized execution requantizes "
            "between the pw/dw/pw ops")
    elif fused_exec and partial != "off":
        raise CompileError(
            "partial execution requires unfused module lowering "
            "(fused_exec=False): the slice surgery rewrites the "
            "pw/dw/pw chain ops individually")
    seg_width = t.seg_width if seg_width is None else seg_width
    block_rows = t.block_rows if block_rows is _UNSET else block_rows

    passes: list[PassRecord] = []
    collector = SpanCollector()

    def run_pass(name, fn):
        t0 = time.perf_counter()
        with collect(collector), span(name):
            out, note = fn()
        passes.append(PassRecord(name, time.perf_counter() - t0, note))
        return out

    # build ----------------------------------------------------------------
    def _build():
        g = _resolve_net(net)
        note = ""
        if streaming:
            from ..stream import to_streaming

            g = to_streaming(g)
            note = " (streaming form)"
        g.validate()
        return g, f"{len(g.nodes)} nodes, {len(g.modules)} modules{note}"
    graph = run_pass("build", _build)

    # schedule -------------------------------------------------------------
    def _schedule():
        if order is not None:
            return list(order), f"caller order ({len(order)} nodes)"
        o, peak = reorder(graph)
        return o, f"peak live {peak} B over {len(o)} nodes"
    sched_order = run_pass("schedule", _schedule)

    # plan -----------------------------------------------------------------
    def _plan():
        p = _plan_net(graph, order=sched_order, seg_width=seg_width,
                      block_rows=block_rows, dtype=dtype,
                      fused_exec=fused_exec)
        return p, (f"{len(p.program.ops)} ops in one ring, "
                   f"pool {p.program.pool_bytes} B")
    plan = run_pass("plan", _plan)

    # budget ---------------------------------------------------------------
    # Pure arithmetic on the solved plans: gate BEFORE the expensive
    # quantize/certify passes so an over-budget net fails in ms.  For
    # int8 (the deployment dtype) the gate covers BOTH the analytic
    # per-group bottleneck and the deployable byte ring (seg_width=1 /
    # tight rows — the footprint an MCU build actually allocates), which
    # a merged multi-group ring can exceed the per-group bound on.
    # Float compiles keep the analytic gate: their byte ring is a 4x
    # host-development artifact, not what ships.
    byte_geometry = seg_width == 1 and block_rows is None
    real_mcu = t.sram_bytes < (1 << 38)     # host-sim never gates
    ring_gate = dtype == "int8" or partial != "off"
    byte_plan = None
    if real_mcu and ring_gate and (check_budget or partial != "off") \
            and not byte_geometry:
        def _byte_plan():
            return _plan_net(graph, order=sched_order, dtype=dtype,
                             fused_exec=fused_exec,
                             **t.byte_ring_kwargs)
        try:
            with collect(collector), span("byte_plan"):
                byte_plan = _byte_plan()
        except Exception:
            byte_plan = None        # fall back to the analytic gate only

    def _budget():
        bot = plan.mcu_bottleneck_bytes
        ring = (byte_plan.program.pool_bytes if byte_plan is not None
                else plan.program.pool_bytes
                if byte_geometry and ring_gate else bot)
        deploy = max(bot, ring)
        margin = t.sram_margin(deploy)
        verdict = "fits" if margin >= 0 else "OVER"
        note = (f"bottleneck {bot} B, deployable ring {ring} B vs "
                f"{t.sram_bytes} B SRAM ({verdict}, margin {margin} B)")
        if margin < 0 and partial != "off":
            return (deploy, margin), note + " — deferred to partial pass"
        if check_budget and margin < 0:
            raise SRAMBudgetError(
                f"{graph.name} needs {deploy} B (deployable "
                f"bottleneck) but target {t.name!r} has {t.sram_bytes} "
                f"B SRAM (over by {-margin} B); pass partial='auto' to "
                "slice the over-budget groups, or check_budget=False "
                "to record the verdict without gating")
        return (deploy, margin), note
    run_pass("budget", _budget)

    # partial --------------------------------------------------------------
    # Slice over-budget fusion groups spatially (DESIGN.md §13).  The
    # slicing is CHOSEN on the deployable byte ring (that is the budget
    # being missed) and APPLIED to the executed geometry too.
    partial_plan = None
    exec_parents = None
    exec_program = plan.program
    if partial != "off":
        def _partial():
            nonlocal exec_parents, exec_program
            from ..partial import (PartialPlanError, apply_partial,
                                   plan_partial)

            policy_prog = (byte_plan.program if byte_plan is not None
                           else plan.program)
            policy_groups = (byte_plan.groups if byte_plan is not None
                             else plan.groups)
            ranges = [(gp.op_lo, gp.op_hi) for gp in policy_groups]
            force = partial if isinstance(partial, int) else None
            try:
                pp = plan_partial(policy_prog, ranges, t.sram_bytes,
                                  force=force)
            except PartialPlanError as e:
                raise SRAMBudgetError(
                    f"partial execution cannot fit {graph.name} in "
                    f"{t.sram_bytes} B SRAM on {t.name!r}: {e}") from e
            if pp is None:
                return None, "not needed (deployable ring fits SRAM)"
            exec_program, exec_parents = apply_partial(plan.program,
                                                       pp.choices)
            return pp, (f"{len(pp.groups)} group(s) -> "
                        f"{sum(g['n_slices'] for g in pp.groups)} "
                        f"slices; ring {pp.ring_bytes_before} -> "
                        f"{pp.ring_bytes_after} B, "
                        f"+{pp.mac_overhead:.1%} MACs")
        partial_plan = run_pass("partial", _partial)

    # quantize -------------------------------------------------------------
    # (parameters materialize lazily: planner-only compiles — the
    # benchmark sections — never pay for init_net_params.  Sliced
    # compiles calibrate the UNSLICED plan — the reference forward runs
    # whole tensors — then share each op's qparams across its slices,
    # so requant constants are identical and execution stays bit-exact.)
    qnet = None
    if dtype == "int8" and quantize:
        def _quant():
            nonlocal params
            if params is None:
                with span("init_params", ops=len(plan.program.ops)):
                    params = init_net_params(plan, key)
            q = _quantize_net(plan, params, calib=calib, n_calib=n_calib)
            note = (f"{len(q.qparams)} q-ops, requant tables for "
                    f"{sum(1 for op in q.program.ops if op.kind != 'add')}"
                    " stores")
            if partial_plan is not None:
                from ..partial import apply_partial

                qprog, qpar = apply_partial(q.program,
                                            partial_plan.choices)
                q = QuantizedNet(
                    plan=q.plan, program=qprog,
                    params=[q.params[p] for p in qpar],
                    qparams=[q.qparams[p] for p in qpar],
                    act_scales=q.act_scales)
                note += f"; shared across {len(qpar)} sliced ops"
            return q, note
        qnet = run_pass("quantize", _quant)

    program = qnet.program if qnet is not None else exec_program

    # deployable accounting shared by lint / mcu snapshot / report ---------
    ring_unsliced = (byte_plan.program.pool_bytes
                     if byte_plan is not None
                     else plan.program.pool_bytes
                     if byte_geometry and ring_gate else None)
    deploy_ring = (partial_plan.ring_bytes_after
                   if partial_plan is not None else ring_unsliced)
    deploy_bytes = max(plan.mcu_bottleneck_bytes, deploy_ring or 0)

    # lint -----------------------------------------------------------------
    # (lazy import: repro.analysis is pure inspection, but keep the
    # driver importable without it in minimal deployments)
    if lint:
        def _lint():
            from ..analysis.lint import lint_program

            est = None
            if t.sram_margin(deploy_bytes) < 0 and partial_plan is None:
                # the overflow stood — can partial execution resolve it?
                from ..partial import estimate_slices

                policy = (byte_plan if byte_plan is not None else plan)
                pprog = policy.program
                est = estimate_slices(
                    pprog, [(gp.op_lo, gp.op_hi) for gp in policy.groups],
                    t.sram_bytes // (pprog.seg_width * pprog.elem_bytes))
            diags = lint_program(
                program, t, deploy_bytes=deploy_bytes,
                bottleneck_group=plan.bottleneck_group().name,
                partial_slices=est)
            # check_budget=False means "record, don't gate" — that
            # covers the lint pass's SRAM finding too
            errors = [d for d in diags if d.severity == "error"
                      and (check_budget or d.code != "VMCU301")]
            if errors:
                raise CompileError(f"lint: {errors[0]}")
            if diags:
                return None, (f"{len(diags)} warning(s): "
                              + "; ".join(str(d) for d in diags))
            return None, "clean"
        run_pass("lint", _lint)

    # certify --------------------------------------------------------------
    certificate = None
    if certify:
        def _certify():
            mode = "static" if certify == "static" else "sim"
            note = ""
            if mode == "static":
                from ..analysis import verify_program

                res = verify_program(program)
                if res.safe is False:
                    raise CompileError(f"certify: {res.diagnostics[0]}")
                if res.safe:
                    cert = res.certificate(
                        artifact.program_sha256(program))
                    return cert, (f"static proof: zero clobbers; peak "
                                  f"{cert['peak_live']}/"
                                  f"{program.n_segments} segments live")
                note = f"sim fallback ({res.diagnostics[0].code}); "
            sim = certify_net(program)
            cert = {"clobbers": 0, "peak_live": sim.peak_live,
                    "reads": sim.reads, "writes": sim.writes,
                    "n_segments": program.n_segments,
                    "program_sha256": artifact.program_sha256(program)}
            state_total = sum(op.state_segments for op in program.ops)
            if state_total:
                # the sim observes the end-live invariant the static
                # horizon proof relies on: only the state regions and
                # the final output survive the step
                cert["n_states"] = sum(1 for op in program.ops
                                       if op.state_segments)
                cert["state_segments"] = state_total
                cert["stream_horizon"] = (
                    "unbounded" if sim.live == state_total
                    + program.ops[-1].out_segments else 1)
            return cert, (f"{note}zero clobbers; peak {sim.peak_live}/"
                          f"{program.n_segments} segments live")
        certificate = run_pass("certify", _certify)

    mcu = _mcu_summary(plan)
    mcu["byte_ring_bytes"] = ring_unsliced
    mcu["deploy_bytes"] = deploy_bytes
    partial_info = None
    if partial_plan is not None:
        partial_info = dict(partial_plan.summary())
        partial_info["parents"] = list(exec_parents)
        mcu["partial"] = {k: v for k, v in partial_info.items()
                          if k != "parents"}
        if params is not None:     # re-align materialized float params
            params = [params[p] for p in exec_parents]

    return CompiledNet(net_name=graph.name, target=t, dtype=dtype,
                       program=program, params=params, qnet=qnet,
                       mcu=mcu, certificate=certificate,
                       passes=passes, plan=plan, graph=graph,
                       init_key=key, spans=collector.to_dicts(),
                       partial=partial_info)
