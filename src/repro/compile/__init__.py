"""The compile pipeline: target descriptors, pass driver, artifacts.

``repro.compile(net, target)`` (the function re-exported at the package
root) is the one-call deployment front door; this package holds its
parts:

  * ``targets``  — the :class:`Target` descriptor registry (SRAM/flash
                   budgets, ring geometry, SIMD width, requant idiom),
  * ``driver``   — the named pass pipeline (build -> schedule -> plan ->
                   budget -> quantize -> lint -> certify) and
                   :class:`CompiledNet`,
  * ``artifact`` — the JSON plan-artifact codec (bit-exact payloads).

See DESIGN.md §9.
"""
from .targets import (REQUANT_IDIOMS, Target, get_target, list_targets,
                      register_target)
from .driver import (PASS_NAMES, CompileError, CompiledNet, PassRecord,
                     SRAMBudgetError, available_nets, compile, load)

__all__ = [
    "REQUANT_IDIOMS", "Target", "get_target", "list_targets",
    "register_target",
    "PASS_NAMES", "CompileError", "CompiledNet", "PassRecord",
    "SRAMBudgetError", "available_nets", "compile", "load",
]
