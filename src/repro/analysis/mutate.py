"""Plan mutators — adversarial inputs for the differential fault-
injection tests.

Each mutation corrupts ONE solved quantity of a clobber-free
:class:`~repro.core.program.PoolProgram` the way a planner bug, a stale
artifact, or a hand-edited plan would: a stream offset nudged, a hold
flag flipped, the ring shrunk, a dtype/delta field rewritten.  The
differential property (``tests/test_verifier.py``) then asserts that
:func:`repro.analysis.verify_program` and the sim clobber-oracle return
the SAME verdict on every mutant — no false-safe, no false-unsafe.

The enumeration is deterministic (no RNG) so the ≥200-plan matrix is
reproducible; hypothesis layers extra randomized shifts on top when
installed.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Iterator

from ..core.program import PoolProgram

#: offset nudges applied to in/out/aux pointers (n/2 and n added per-plan)
_SHIFTS = (1, -1, 2, 7)


def _with_op(program: PoolProgram, i: int, **changes) -> PoolProgram:
    ops = list(program.ops)
    ops[i] = dataclasses.replace(ops[i], **changes)
    return dataclasses.replace(program, ops=tuple(ops))


@dataclasses.dataclass(frozen=True)
class Mutation:
    """One corrupted plan plus a human-readable provenance tag."""

    tag: str
    program: PoolProgram


def mutations(program: PoolProgram, *, ops_stride: int = 1
              ) -> Iterator[Mutation]:
    """Deterministically enumerate corrupted variants of ``program``.

    ``ops_stride`` subsamples the op axis (every op is O(ops) mutants —
    stride keeps the matrix affordable on deep nets).  Covers: solved
    in/out/aux segment offsets (±small, ±n/2, ±n), ``hold_input`` flips,
    ``in_op``/``aux_op`` chain rewires, ring size changes, and the
    verdict-inert fields (``delta``, dtype) the verifier must NOT judge
    by."""
    n = program.n_segments
    shifts = _SHIFTS + (n // 2, n) if n > 4 else _SHIFTS
    for i in range(0, len(program.ops), max(1, ops_stride)):
        op = program.ops[i]
        for s in shifts:
            if s == 0:
                continue
            yield Mutation(f"op{i}.in_ptr{s:+d}",
                           _with_op(program, i, in_ptr=op.in_ptr + s))
            yield Mutation(f"op{i}.out_ptr{s:+d}",
                           _with_op(program, i, out_ptr=op.out_ptr + s))
            if op.aux_op >= 0:
                yield Mutation(
                    f"op{i}.aux_ptr{s:+d}",
                    _with_op(program, i, aux_ptr=op.aux_ptr + s))
        yield Mutation(f"op{i}.hold_input={not op.hold_input}",
                       _with_op(program, i,
                                hold_input=not op.hold_input))
        if op.in_op >= 0:
            yield Mutation(f"op{i}.in_op={op.in_op - 1}",
                           _with_op(program, i, in_op=op.in_op - 1))
        # verdict-inert corruption: delta is documentation of the solved
        # offset, not an input to execution — flipping it must not flip
        # the verdict (the sim never reads it; nor may the verifier).
        yield Mutation(f"op{i}.delta{+3:+d}",
                       _with_op(program, i, delta=op.delta + 3))
    for dn in (-1, -2, -(n // 2)):
        if n + dn >= 1:
            yield Mutation(
                f"n_segments{dn:+d}",
                dataclasses.replace(program, n_segments=n + dn))
    yield Mutation("n_segments+1",
                   dataclasses.replace(program, n_segments=n + 1))


def break_plan(program: PoolProgram) -> Mutation:
    """One canonical deliberately-broken plan (for docs / --smoke): nudge
    an op's solved output offset until the verifier derives a clobber —
    the exact failure the Eq. (1)/(2) offsets exist to prevent."""
    from .verifier import verify_program

    for i, op in enumerate(program.ops):
        for s in (1, -1, 2):
            broken = _with_op(program, i, out_ptr=op.out_ptr + s)
            if verify_program(broken).safe is False:
                return Mutation(f"op{i}.out_ptr{s:+d}", broken)
    # tight plans always break above; a fully-slack plan still breaks
    # when the ring shrinks below its peak footprint
    m = dataclasses.replace(program,
                            n_segments=max(1, program.n_segments // 2))
    return Mutation(f"n_segments={m.n_segments}", m)


__all__ = ["Mutation", "mutations", "break_plan"]
