"""``vmcu-lint`` — static ring-safety verification as a console script.

    vmcu-lint vww.plan.json other.plan.json     # lint saved artifacts
    vmcu-lint vww.plan.json --c-dir out/        # + emitted-C staleness
    vmcu-lint --smoke                           # self-contained CI gate

Per artifact: the certificate content hash (VMCU403), the quantization
payload (VMCU404), the full static clobber-freedom proof (VMCU1xx/2xx
with the exact first clobbered byte and step), and the target budgets
(VMCU3xx).  Exit 0 iff every artifact is clean (warnings don't gate),
1 on any error finding, 2 on usage errors.

``--smoke`` needs no inputs: it compiles MCUNet-VWW for cortex-m4 with
``certify="static"``, asserts the saved artifact lints clean, then
corrupts the plan two ways — a :func:`repro.analysis.break_plan` offset
nudge (asserting the static verdict matches the sim clobber oracle) and
a tampered artifact (asserting lint rejects it with a VMCU code) — so
an unsound verifier fails CI loudly.
"""
from __future__ import annotations

import argparse
import sys
from typing import TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .lint import ArtifactReport


def _print_report(rep: "ArtifactReport") -> None:
    verdict = ("CLEAN" if rep.clean
               else "UNSAFE" if rep.result.safe is False else "UNPROVEN")
    print(f"{rep.path}: {verdict}  ({rep.net}, {rep.dtype}, "
          f"{rep.target})")
    if rep.result.stats:
        s = rep.result.stats
        print(f"  proof: zero clobbers; peak {s['peak_live']}/"
              f"{s['n_segments']} segments live, {s['reads']} reads / "
              f"{s['writes']} writes")
    for d in rep.result.diagnostics:
        print(f"  {'WARN ' if d.severity == 'warning' else 'ERROR'} {d}")


def _smoke() -> int:
    """The CI gate: prove a clean plan, catch two corrupted ones."""
    import json
    import tempfile
    from pathlib import Path

    from ..compile.driver import compile as _compile
    from ..core.executors import run_program_sim
    from ..core.pool import PoolClobberError
    from .lint import lint_artifact
    from .mutate import break_plan
    from .verifier import verify_program

    cn = _compile("mcunet-5fps-vww", "cortex-m4", quantize=False,
                  certify="static")
    cert = dict(cn.certificate)
    if cert.get("clobbers") != 0 or "program_sha256" not in cert:
        print(f"smoke FAILED: bad static certificate {cert}",
              file=sys.stderr)
        return 1
    note = next(p.note for p in cn.passes if p.name == "certify")
    if "static proof" not in note:
        print(f"smoke FAILED: certify pass fell back to sim ({note})",
              file=sys.stderr)
        return 1

    with tempfile.TemporaryDirectory() as td:
        path = str(Path(td) / "vww.plan.json")
        cn.save(path)
        rep = lint_artifact(path)
        if not rep.clean:
            print("smoke FAILED: clean artifact lints dirty:",
                  file=sys.stderr)
            _print_report(rep)
            return 1
        print(f"clean plan: static proof OK ({cert['peak_live']}/"
              f"{cert['n_segments']} segments peak live)")

        # corruption 1: a planner-bug-shaped offset nudge — the static
        # verdict must agree with the sim clobber oracle
        mut = break_plan(cn.program)
        res = verify_program(mut.program)
        try:
            run_program_sim(mut.program)
            sim_safe = True
        except PoolClobberError:
            sim_safe = False
        if res.safe is not False or sim_safe:
            print(f"smoke FAILED: {mut.tag}: static={res.safe} "
                  f"sim_safe={sim_safe} (must both be unsafe)",
                  file=sys.stderr)
            return 1
        print(f"broken plan ({mut.tag}): static and sim agree UNSAFE — "
              f"{res.diagnostics[0]}")

        # corruption 2: a tampered artifact must fail lint with a code
        payload = json.loads(Path(path).read_text())
        payload["program"]["ops"][0]["out_ptr"] += 1
        Path(path).write_text(json.dumps(payload))
        rep = lint_artifact(path)
        codes = sorted({d.code for d in rep.result.errors})
        if rep.clean or not codes:
            print("smoke FAILED: tampered artifact lints clean",
                  file=sys.stderr)
            return 1
        print(f"tampered artifact rejected: {', '.join(codes)}")
    print("vmcu-lint smoke OK")
    return 0


def main(argv: "list[str] | None" = None) -> int:
    ap = argparse.ArgumentParser(
        prog="vmcu-lint",
        description="Statically verify vMCU plan artifacts: prove "
                    "clobber-freedom, check certificates, budgets and "
                    "emitted C — without executing anything.")
    ap.add_argument("artifacts", nargs="*",
                    help="saved plan artifacts (CompiledNet.save JSON)")
    ap.add_argument("--c-dir", metavar="DIR",
                    help="also diff DIR's emitted C units against each "
                         "artifact's solved ring (VMCU5xx)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: prove a fresh MCUNet-VWW plan, then "
                         "assert two corrupted variants are rejected")
    args = ap.parse_args(argv)

    if args.smoke:
        if args.artifacts:
            print("--smoke is self-contained; drop the artifact "
                  "arguments", file=sys.stderr)
            return 2
        return _smoke()
    if not args.artifacts:
        ap.print_usage(file=sys.stderr)
        print("vmcu-lint: need at least one artifact (or --smoke)",
              file=sys.stderr)
        return 2

    from ..core.program import PoolProgram
    from .lint import lint_artifact, lint_c_dir

    bad = 0
    for path in args.artifacts:
        try:
            rep = lint_artifact(path)
        except (OSError, ValueError, KeyError) as e:
            print(f"{path}: ERROR not a readable plan artifact: {e}",
                  file=sys.stderr)
            bad += 1
            continue
        if args.c_dir:
            import json

            with open(path) as f:
                payload = json.load(f)
            program = PoolProgram.from_json_dict(payload["program"])
            rep.result.diagnostics.extend(
                lint_c_dir(program, args.c_dir, name=rep.net))
        _print_report(rep)
        if not rep.clean or rep.result.errors:
            bad += 1
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
