"""Plan linter — budget/consistency checks over programs, artifacts and
emitted C (the ``VMCU3xx``/``VMCU4xx``/``VMCU5xx`` half of the table).

:func:`verify_program` proves the *ring* safe; this module checks
everything around the ring that can still sink a deployment:

  * :func:`lint_program` — the target envelope (SRAM/flash budgets,
    ``VMCU301``/``VMCU302``) and the program's own byte accounting
    (``elem_bytes`` vs dtype, per-op ``segment_bytes`` vs geometry,
    ``VMCU401``/``VMCU402``),
  * :func:`lint_artifact` — a saved ``.save()`` plan artifact: the
    embedded safety certificate's content hash (``VMCU403`` — the plan
    changed after it was certified), the quantization payload vs the
    program dtype (``VMCU404``), then the full static ring proof and
    budget lint of the loaded program,
  * :func:`lint_c_dir` — previously emitted C units vs a fresh
    geometry-only emission of the same plan (``VMCU501`` drift /
    ``VMCU502`` missing / ``VMCU503`` stray unit): catches the
    "re-planned the net, forgot to re-emit" staleness class.

Everything here is pure inspection — no execution, no parameter decode
(flash accounting reads array byte sizes straight off the encoded
``{"__array__", dtype, shape}`` envelopes).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

from ..core.program import (PLAN_ONLY_KINDS, PoolProgram, dtype_itemsize)
from .verifier import CODES, Diagnostic, VerifyResult, verify_program


def _diag(code: str, detail: str, *, severity: str = "error",
          op_index: int | None = None) -> Diagnostic:
    return Diagnostic(code=code, message=f"{CODES[code]}: {detail}",
                      severity=severity, op_index=op_index)


# ---------------------------------------------------------------------------
# Program-level lint (budgets + byte-accounting consistency).
# ---------------------------------------------------------------------------

def lint_program(program: PoolProgram, target: Any = None, *,
                 deploy_bytes: int | None = None,
                 bottleneck_group: str | None = None,
                 partial_slices: int | None = None) -> list[Diagnostic]:
    """Budget + byte-accounting findings for one program.

    ``target`` (a :class:`repro.compile.targets.Target`, a registry
    name, or ``None`` to skip the budget checks) supplies the SRAM and
    flash envelopes.  ``deploy_bytes`` is the byte-granular deployable
    bottleneck the SRAM gate judges (the paper's Fig.-9/10 metric — the
    executed ring is a host-side float/int8 structure, deliberately NOT
    what lands on the MCU); without it the SRAM check is skipped.  SRAM
    overrun is an error; flash overrun is a *warning* — without the
    artifact payload the parameter size is an analytic estimate.

    ``bottleneck_group`` names the fusion group pinning the overflow in
    the VMCU301 finding; ``partial_slices`` (the driver's
    :func:`repro.partial.estimate_slices` result) adds a VMCU303
    advisory: the overflow is resolvable by partial execution.
    """
    diags: list[Diagnostic] = []
    plan_only = program.ops and program.ops[0].kind in PLAN_ONLY_KINDS

    try:
        eb = dtype_itemsize(program.dtype)
    except ValueError:
        diags.append(_diag("VMCU401",
                           f"unknown pool dtype {program.dtype!r}"))
        eb = None
    if eb is not None and program.elem_bytes != eb:
        diags.append(_diag(
            "VMCU401", f"elem_bytes={program.elem_bytes} but dtype "
            f"{program.dtype!r} is {eb} B/element"))
    if not plan_only and eb is not None:
        want = program.seg_width * program.elem_bytes
        for i, op in enumerate(program.ops):
            if op.segment_bytes != want:
                diags.append(_diag(
                    "VMCU402",
                    f"segment_bytes={op.segment_bytes} but seg_width="
                    f"{program.seg_width} x elem_bytes="
                    f"{program.elem_bytes} = {want}", op_index=i))
                break  # one geometry finding per program is enough

    if target is not None:
        from ..compile.targets import get_target

        t = get_target(target)
        if deploy_bytes is not None and deploy_bytes > t.sram_bytes:
            who = (f" (pinned by fusion group {bottleneck_group!r})"
                   if bottleneck_group else "")
            diags.append(_diag(
                "VMCU301", f"deployable bottleneck {deploy_bytes} B > "
                f"{t.sram_bytes} B SRAM on {t.name!r}{who}"))
            if partial_slices is not None:
                diags.append(_diag(
                    "VMCU303", f"overflow is resolvable by partial "
                    f"execution: est. {partial_slices} slice(s) — "
                    "recompile with partial='auto'",
                    severity="warning"))
        flash = _flash_estimate(program)
        if flash > t.flash_bytes:
            diags.append(_diag(
                "VMCU302", f"~{flash} B parameters (analytic estimate) "
                f"> {t.flash_bytes} B flash on {t.name!r}",
                severity="warning"))
    return diags


def _flash_estimate(program: PoolProgram) -> int:
    """Analytic parameter bytes (the driver's fp32 shapes, scaled by the
    program dtype's itemsize for quantized plans)."""
    from ..compile.driver import _flash_param_bytes

    est = _flash_param_bytes(program)
    if program.quantized:
        est //= 4  # int8 weights; biases/tables add back a little
    return est


# ---------------------------------------------------------------------------
# Artifact lint (certificate hash, quant payload, then the ring proof).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ArtifactReport:
    """One linted artifact: identity + the merged verdict."""

    path: str
    net: str
    dtype: str
    target: str
    result: VerifyResult

    @property
    def clean(self) -> bool:
        return self.result.safe is not False and not self.result.errors


def _encoded_nbytes(obj: Any) -> int:
    """Array bytes of an :mod:`repro.compile.artifact` encoded payload,
    read off the envelopes without decoding (no jax import)."""
    if isinstance(obj, dict):
        if "__array__" in obj:
            n = math.prod(obj["shape"]) if obj["shape"] else 1
            return n * _itemsize(obj["dtype"])
        if "__tuple__" in obj:
            return sum(_encoded_nbytes(v) for v in obj["__tuple__"])
        return sum(_encoded_nbytes(v) for v in obj.values())
    if isinstance(obj, list):
        return sum(_encoded_nbytes(v) for v in obj)
    return 0


def _itemsize(dtype_name: str) -> int:
    import numpy as np

    try:
        return np.dtype(dtype_name).itemsize
    except TypeError:
        return 2 if "16" in dtype_name else 4


def lint_artifact(path: str) -> ArtifactReport:
    """Lint one saved plan artifact (``CompiledNet.save`` JSON).

    Checks, in order: the certificate's embedded ``program_sha256``
    against a fresh hash of the stored program (``VMCU403``), the
    quantization payload against the program dtype (``VMCU404``), the
    static ring proof (``verify_program`` — the full ``VMCU1xx``/
    ``VMCU2xx`` surface), and the target budgets with *exact* flash
    accounting from the encoded parameter payload.
    """
    from ..compile import artifact
    from ..compile.targets import Target

    payload = artifact.load(path)
    program = PoolProgram.from_json_dict(payload["program"])
    target = Target(**payload["target"])
    diags: list[Diagnostic] = []

    cert = payload.get("certificate")
    if cert is not None and "program_sha256" in cert:
        have = artifact.program_sha256(program)
        if cert["program_sha256"] != have:
            diags.append(_diag(
                "VMCU403", f"certificate hashes "
                f"{cert['program_sha256'][:12]}..., stored program "
                f"hashes {have[:12]}..."))

    quant = payload.get("quant")
    if quant is not None and program.dtype != "int8":
        diags.append(_diag(
            "VMCU404", f"artifact carries requant tables but the "
            f"program dtype is {program.dtype!r}"))
    if quant is not None and cert is not None:
        n_cert = cert.get("n_segments")
        if n_cert is not None and n_cert != program.n_segments:
            diags.append(_diag(
                "VMCU403", f"certificate ring n_segments={n_cert} != "
                f"program n_segments={program.n_segments}"))

    res = verify_program(program)
    diags.extend(res.diagnostics)

    diags.extend(lint_program(program))  # byte accounting, no budgets
    mcu = payload.get("mcu") or {}
    deploy = mcu.get("deploy_bytes", mcu.get("mcu_bottleneck_bytes"))
    if deploy is not None and deploy > target.sram_bytes:
        who = mcu.get("bottleneck_group")
        who = f" (pinned by fusion group {who!r})" if who else ""
        diags.append(_diag(
            "VMCU301", f"deployable bottleneck {deploy} B > "
            f"{target.sram_bytes} B SRAM on {target.name!r}{who}"))
    flash = (_encoded_nbytes(quant["qparams"]) if quant is not None
             else _encoded_nbytes(payload.get("params")))
    if flash > target.flash_bytes:
        diags.append(_diag(
            "VMCU302", f"{flash} B parameter payload > "
            f"{target.flash_bytes} B flash on {target.name!r}",
            severity="warning"))

    safe = False if any(d.severity == "error" for d in diags) else res.safe
    return ArtifactReport(
        path=path, net=payload.get("net", "?"), dtype=payload["dtype"],
        target=target.name,
        result=VerifyResult(safe=safe, diagnostics=diags,
                            stats=res.stats))


# ---------------------------------------------------------------------------
# Emitted-C staleness lint.
# ---------------------------------------------------------------------------

def lint_c_dir(program: PoolProgram, c_dir: Any, name: str = "net",
               idiom: str | None = None) -> list[Diagnostic]:
    """Diff previously emitted C units against a fresh geometry-only
    emission of ``program`` — the deterministic ring skeleton, so the
    comparison is idiom/dtype/requant-independent.

    ``VMCU501``: a unit exists but its ring geometry diverged (the plan
    was re-solved after emission).  ``VMCU502``: a planned op's unit is
    missing.  ``VMCU503``: a ``.c``/``.h`` file in ``c_dir`` corresponds
    to no planned op (a stale unit a linker could still pick up).

    A unit passes if it is byte-identical to the geometry-only emission
    (``emit_c(geometry_only=True)`` goldens) OR carries the same *ring
    signature* — POOL_SEGS plus every solved ``WRAP(...)`` pointer
    expression, in order — so full quantized/idiom-bannered emissions of
    the SAME plan lint clean while a re-solved ring is always caught.
    """
    import pathlib

    from ..core.codegen import emit_program

    if program.ops and program.ops[0].kind in PLAN_ONLY_KINDS:
        return [_diag("VMCU105", "plan-only program has no emitted C",
                      severity="warning")]
    want = emit_program(program.with_dtype("byte"), name, idiom=idiom)
    d = pathlib.Path(c_dir)
    have = {p.name for p in d.glob("*.c")} | {p.name for p in d.glob("*.h")}
    diags: list[Diagnostic] = []
    for fname, src in sorted(want.items()):
        if fname not in have:
            diags.append(_diag("VMCU502", f"{fname} not found in {d}"))
            continue
        text = (d / fname).read_text()
        if text != src and _ring_signature(text) != _ring_signature(src):
            diags.append(_diag(
                "VMCU501", f"{fname} solved ring geometry differs from "
                f"the plan (stale — re-run emit_c)"))
    for fname in sorted(have - set(want)):
        diags.append(_diag(
            "VMCU503", f"{fname} matches no op of this plan",
            severity="warning"))
    return diags


def _ring_signature(src: str) -> tuple:
    """The solved ring baked into one C unit: POOL_SEGS + every
    ``WRAP(...)`` pointer expression, in emission order.  Deliberately
    excludes SEG_BYTES (dtype-scaled) and requant constants."""
    import re

    pool = re.search(r"#define POOL_SEGS (\d+)", src)
    wraps = tuple(dict.fromkeys(re.findall(r"WRAP\(([^)]*)\)", src)))
    return (pool.group(1) if pool else None, wraps)


__all__ = ["ArtifactReport", "lint_artifact", "lint_c_dir",
           "lint_program"]
