"""Modular byte/segment-interval arithmetic for the ring verifier.

The abstract domain of :mod:`repro.analysis.verifier` is the *live
record*: a contiguous run of pool segments ``(base + s) % n`` for
``s in [lo, hi)``.  Everything the clobber oracle can detect reduces to
two questions about such runs:

  * do two modular runs share a slot (``overlap``), and
  * which is the FIRST write of a streaming sweep that lands on a live
    run (``first_static_clash`` / ``first_stream_clash``)?

Both are answered exactly.  A write stream covers absolute output
segments ``w in [0, out_tot)`` at slot ``(out_base + w) % n``; a live
segment ``r`` of a record based ``delta = (rec_base - out_base) % n``
above the output occupies slot ``(out_base + delta + r) % n``.  The two
collide iff ``w ≡ delta + r (mod n)``, i.e. ``w - r = delta + j*n`` for
some integer ``j`` — enumerating the (at most a handful of) feasible
``j`` turns every modular clash query into a linear one.
"""
from __future__ import annotations

import numpy as np


def overlap(a0: int, la: int, b0: int, lb: int, n: int) -> bool:
    """Do ``[a0, a0+la)`` and ``[b0, b0+lb)`` intersect modulo ``n``?"""
    if la <= 0 or lb <= 0:
        return False
    if la >= n or lb >= n:
        return True
    return ((b0 - a0) % n) < la or ((a0 - b0) % n) < lb


def _j_range(delta: int, hi: int, out_tot: int, n: int) -> range:
    """Integers ``j`` with ``delta + j*n`` in ``[-(hi-1), out_tot-1]``."""
    if hi <= 0 or out_tot <= 0:
        return range(0)
    j_min = -((hi - 1 + delta) // n)
    j_max = (out_tot - 1 - delta) // n
    return range(j_min, j_max + 1)


def first_static_clash(out_tot: int, victim_len: int, delta: int,
                       n: int) -> tuple[int, int] | None:
    """First write of a ``[0, out_tot)`` sweep that lands on a live run
    of ``victim_len`` segments based ``delta`` slots above the sweep.

    Returns ``(w, r)`` — the clashing write segment and victim segment —
    or ``None``.  The victim is live for the whole sweep (a held input,
    a residual source, any tensor the op does not consume)."""
    best: tuple[int, int] | None = None
    for j in _j_range(delta, victim_len, out_tot, n):
        d = delta + j * n
        w = max(0, d)
        if w < out_tot and w - d < victim_len:
            if best is None or w < best[0]:
                best = (w, w - d)
    return best


def first_stream_clash(we: np.ndarray, lo: np.ndarray, hi: int,
                       delta: int, n: int
                       ) -> tuple[int, int, int] | None:
    """First write that lands on the *shrinking* live suffix of the
    record the op is streaming over.

    ``we[t]`` is the cumulative output-segment high-water mark after
    step ``t``'s writes; ``lo[t]`` the first still-live victim segment
    at step ``t``'s writes (Eq.-(2) frees have already run); ``hi`` the
    victim's live top.  Returns ``(t, w, r)`` — step, write segment,
    victim segment — of the earliest clash, or ``None``."""
    steps = len(we)
    if steps == 0 or hi <= 0:
        return None
    we_prev = np.empty_like(we)
    we_prev[0] = 0
    we_prev[1:] = we[:-1]
    out_tot = int(we[-1])
    best: tuple[int, int, int] | None = None
    for j in _j_range(delta, hi, out_tot, n):
        d = delta + j * n
        # a clash at step t needs a write w in [we_prev[t], we[t]) and a
        # live victim segment r in [lo[t], hi) with w = d + r
        mask = (we > we_prev) & (lo < hi) & (we_prev < d + hi) \
            & (we > d + lo)
        if not mask.any():
            continue
        t = int(np.argmax(mask))
        w = int(max(we_prev[t], d + lo[t]))
        cand = (t, w, w - d)
        if best is None or (cand[0], cand[1]) < (best[0], best[1]):
            best = cand
    return best
