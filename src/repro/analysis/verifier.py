"""Static ring-safety verifier — proves clobber-freedom without executing.

``verify_program`` is an abstract interpreter over a
:class:`~repro.core.program.PoolProgram` and the SAME
:mod:`repro.core.rowsched` row schedules the sim oracle replays.  Its
abstract state is a set of **live records** — one per resident tensor,
each a contiguous modular run of pool segments (``repro.analysis
.intervals``).  Per op it checks, symbolically and per step, exactly the
three ways ``run_program_sim`` can raise :class:`PoolClobberError`:

  * a read that misses its tensor (broken chain pointer, dead record,
    branch/residual alias to a tensor that is not live) — ``VMCU2xx``,
  * a write that lands on a live segment of another tensor (the solved
    offset is too small, the output wraps the ring onto itself, a held
    residual source is overrun) — ``VMCU1xx`` with the exact first
    clobbered byte and step,
  * the final outputs failing to survive the ring.

Streaming programs (``repro.stream``) add a fourth lifetime class:
persistent state regions (``conv_stream`` windows, ``gru_cell`` hidden
vectors) that live across invocations.  They are registered as live
records up front and NEVER freed, so the same write sweeps prove frame
traffic can never touch them — ``VMCU211``/``VMCU212``/``VMCU213`` —
and one verified step certifies an unbounded step horizon (see
``stream_horizon`` in the stats).

Soundness against the byte oracle (DESIGN.md §11): for the monotone
schedules the planner emits, the live part of the tensor being streamed
over is always a contiguous suffix ``[needed_min(t+1), in_rows)`` at
write time, frees can never be the oracle's *first* error (a clobbering
write or a failed read always precedes), and every read/aux/other-record
hazard reduces to a congruence or modular-interval question answered
exactly.  When a program falls outside that proof fragment (plan-only
kinds, non-monotone schedules, producer/consumer geometry divergence)
the verifier returns ``safe=None`` with a ``VMCU105`` diagnostic and the
caller falls back to the sim oracle — it never guesses.

When the proof succeeds the result carries the same access statistics
the sim pool would have counted (``reads`` / ``writes`` / ``peak_live``),
so a ``certify="static"`` certificate is byte-identical to the replayed
one.  Row schedules and their derived frontiers are memoized per op
*geometry* (nets repeat module shapes heavily), which is what makes the
static path O(ops) in practice where the replay is O(rows executed).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.program import EXECUTABLE_KINDS, PoolOp, PoolProgram
from ..core.rowsched import RowSchedule, schedule_for_op
from ..core.vpool import segments_for
from .intervals import first_static_clash, first_stream_clash

_ROWSCHED_KINDS = ("conv_pw", "conv_dw", "conv_k2d", "ib_fused", "add",
                   "pool_avg", "conv_stream", "gru_cell")

#: Streaming op kinds whose ``state_ptr``/``state_segments`` region holds
#: persistent cross-invocation state (the fourth lifetime class).
_STREAM_KINDS = ("conv_stream", "gru_cell")

#: Stable diagnostic codes (DESIGN.md §11 carries the full table).
CODES = {
    "VMCU101": "write clobbers the op's own streaming input "
               "(solved offset too small)",
    "VMCU102": "write clobbers a live segment of another tensor "
               "(held input / residual source / survivor)",
    "VMCU103": "tensor wraps the ring onto itself "
               "(span exceeds n_segments)",
    "VMCU104": "final outputs do not survive the ring",
    "VMCU105": "static proof unavailable for this program "
               "(fall back to the sim oracle)",
    "VMCU201": "chained input pointer does not reach the producer's "
               "live record",
    "VMCU202": "input tensor is not live "
               "(freed too early, or a bad branch/hold index)",
    "VMCU203": "residual pointer does not reach the residual source's "
               "live record",
    "VMCU204": "residual source tensor is not live",
    "VMCU211": "persistent stream state clobbered by frame traffic "
               "(staged input or an op's output overwrites live state)",
    "VMCU212": "stream state extent wrong — the step cannot write the "
               "full state back",
    "VMCU213": "stale-state read (state region wraps the ring or "
               "overlaps another op's state)",
    "VMCU301": "pool exceeds the target's SRAM budget",
    "VMCU302": "parameter payload exceeds the target's flash budget",
    "VMCU303": "SRAM overflow resolvable by partial execution "
               "(re-compile with partial='auto')",
    "VMCU401": "program elem_bytes inconsistent with its dtype",
    "VMCU402": "op segment_bytes inconsistent with the program geometry",
    "VMCU403": "artifact certificate does not match the program "
               "(stale or tampered plan)",
    "VMCU404": "artifact quantization payload inconsistent with the "
               "program dtype",
    "VMCU501": "emitted C unit diverges from the plan's ring geometry",
    "VMCU502": "emitted C unit missing for a planned op",
    "VMCU503": "emitted C unit does not correspond to any planned op",
}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One structured finding, with a stable ``VMCUxxx`` code."""

    code: str
    message: str
    severity: str = "error"          # "error" | "warning"
    op_index: int | None = None
    step: int | None = None
    segment: int | None = None       # pool slot (mod n_segments)
    byte: int | None = None          # first affected pool byte

    def __str__(self) -> str:
        loc = []
        if self.op_index is not None:
            loc.append(f"op {self.op_index}")
        if self.step is not None:
            loc.append(f"step {self.step}")
        if self.segment is not None:
            loc.append(f"slot {self.segment}")
        if self.byte is not None:
            loc.append(f"byte {self.byte}")
        where = f" [{', '.join(loc)}]" if loc else ""
        return f"{self.code}{where}: {self.message}"


@dataclasses.dataclass
class VerifyResult:
    """Outcome of :func:`verify_program`.

    ``safe`` is ``True`` (proven clobber-free), ``False`` (a concrete
    first clobber/read failure was derived) or ``None`` (the program is
    outside the decidable fragment — fall back to the sim oracle).
    ``stats`` mirrors the sim pool counters exactly when ``safe``."""

    safe: bool | None
    diagnostics: list[Diagnostic]
    stats: dict | None = None

    @property
    def errors(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "error"]

    def certificate(self, program_sha256: str | None = None) -> dict:
        """The machine-checkable safety certificate (requires safe)."""
        if not self.safe or self.stats is None:
            raise ValueError("no certificate: program not proven safe")
        cert = {"clobbers": 0, **self.stats}
        if program_sha256 is not None:
            cert["program_sha256"] = program_sha256
        return cert


@dataclasses.dataclass
class _Record:
    """A live tensor: segments ``(base + s) % n`` for ``s in [0, length)``,
    tagged with the sim's ownership id (input tensor of op ``rid``)."""

    rid: int
    base: int
    length: int


@dataclasses.dataclass(frozen=True)
class _SchedInfo:
    """A row schedule plus every derived frontier the verifier needs,
    memoized per op *geometry* (nets repeat module shapes heavily)."""

    sched: RowSchedule
    monotone_error: str | None
    in_tot: int
    out_tot: int
    t_read: int                 # step of the first input read
    t_aux: int                  # step of the first aux read (aux only)
    aux_tot: int                # 0 when the schedule has no aux reads
    n_read_events: int
    n_aux_events: int
    we: np.ndarray              # cumulative output segs after step t
    lo: np.ndarray              # first live input seg at step t's writes
    aux_lo: np.ndarray | None   # same for the residual source
    # max over write steps of (we - lo - aux_freed) / (we - aux_freed):
    # peak_live contribution of the op on top of the resident records.
    stream_peak: int
    stream_peak_hold: int
    # max over write steps of (we - lo) / (we - aux_lo): the O(1)
    # no-wrap safety precheck (delta >= stream_max => no j=0 clash).
    stream_max: int
    aux_stream_max: int


def _flatten(rows_per_step: tuple[tuple[int, ...], ...],
             steps: int) -> tuple[np.ndarray, np.ndarray]:
    """One pass over a per-step row list: (flat row indices, per-step
    counts)."""
    cnt = np.fromiter((len(rows) for rows in rows_per_step),
                      dtype=np.int64, count=steps)
    flat = np.fromiter((r for rows in rows_per_step for r in rows),
                       dtype=np.int64, count=int(cnt.sum()))
    return flat, cnt


def _is_sweep(flat: np.ndarray, rows: int) -> bool:
    """Is ``flat`` exactly ``0, 1, ..., rows-1`` (the in-order sweep)?"""
    return len(flat) == rows and (np.array_equal(
        flat, np.arange(rows, dtype=np.int64)) if rows else True)


def _sched_key(op: PoolOp, seg_width: int,
               m_rows: int) -> tuple:
    rows = op.rows_in or m_rows
    return (op.kind, rows, op.h_in, op.h_out, op.w_in, op.w_out,
            op.d_in, op.d_out, op.stride, op.rs, op.padding,
            op.resample, op.residual, op.hop, seg_width)


_SCHED_CACHE: dict[tuple, _SchedInfo] = {}


def _inconclusive_info(sched: RowSchedule, err: str) -> _SchedInfo:
    empty = np.zeros(0, dtype=np.int64)
    return _SchedInfo(
        sched=sched, monotone_error=err, in_tot=0, out_tot=0, t_read=0,
        t_aux=0, aux_tot=0, n_read_events=0, n_aux_events=0, we=empty,
        lo=empty, aux_lo=None, stream_peak=0, stream_peak_hold=0,
        stream_max=0, aux_stream_max=0)


def _window(rows: tuple[int, ...]) -> tuple[int, int] | None:
    """``(start, end)`` if ``rows`` is a strictly-increasing contiguous
    window, else ``None``.  Single rows are the overwhelmingly common
    case; multi-row windows are the k x k halos."""
    k = len(rows)
    if k == 1:
        return rows[0], rows[0]
    if rows[-1] - rows[0] + 1 != k:
        return None
    prev = rows[0]
    for r in rows[1:]:
        if r != prev + 1:
            return None
        prev = r
    return rows[0], rows[-1]


def _sched_info_build(op: PoolOp, seg_width: int,
                      m_rows: int) -> _SchedInfo:
    """Fast path: all builders emit contiguous monotone read windows and
    in-order write sweeps, so the decidable-fragment check and every
    frontier reduce to O(steps) scans with no per-event work.  Any
    schedule outside that shape falls back to the event-exact
    :func:`_sched_info_build_generic` (the two are pinned equal by
    ``tests/test_verifier.py``)."""
    sched = schedule_for_op(op, seg_width, m_rows=m_rows)
    steps = sched.steps
    ic, oc = sched.in_chunk, sched.out_chunk
    in_tot = sched.in_rows * ic
    out_tot = sched.out_rows * oc

    w_steps = sched.writes
    r_steps = sched.reads
    a_steps = sched.aux_reads
    have_aux = a_steps is not None and any(a_steps)
    aux_chunk = sched.aux_chunk

    # forward pass: writes must be the exact in-order row sweep, reads
    # contiguous windows with monotone starts AND ends (then a freed row
    # can never be re-read and the live input is always a contiguous
    # suffix — the decidable fragment), aux reads an in-order sweep.
    we_list = [0] * steps
    starts = [-1] * steps          # -1: no read at this step
    a_freed = [0] * steps
    n_read_events = n_aux = 0
    t_read = t_aux = -1
    pos = apos = 0
    prev_s = prev_e = -1
    for t in range(steps):
        rows = w_steps[t]
        if rows:
            if len(rows) == 1:
                s = e = rows[0]
            else:
                w = _window(rows)
                if w is None:
                    return _sched_info_build_generic(sched)
                s, e = w
            if s != pos:
                return _sched_info_build_generic(sched)
            pos = e + 1
        we_list[t] = pos
        rows = r_steps[t]
        if rows:
            if len(rows) == 1:
                s = e = rows[0]
            else:
                w = _window(rows)
                if w is None:
                    return _sched_info_build_generic(sched)
                s, e = w
            if s < prev_s or e < prev_e:
                return _sched_info_build_generic(sched)
            prev_s, prev_e = s, e
            starts[t] = s
            n_read_events += len(rows)
            if t_read < 0:
                t_read = t
        if have_aux:
            rows = a_steps[t]
            if rows:
                if len(rows) == 1:
                    s = e = rows[0]
                else:
                    w = _window(rows)
                    if w is None:
                        return _sched_info_build_generic(sched)
                    s, e = w
                if s != apos:
                    return _sched_info_build_generic(sched)
                apos = e + 1
                n_aux += len(rows)
                if t_aux < 0:
                    t_aux = t
            a_freed[t] = apos * aux_chunk
    if pos != sched.out_rows:
        return _sched_info_build_generic(sched)
    if have_aux and apos != sched.aux_rows:
        return _sched_info_build_generic(sched)

    # backward pass: lo[t] = (lowest row still read strictly after step
    # t) * ic — with monotone window starts that is simply the NEXT
    # reading step's start — fused with the stream peak maxima (which
    # can be negative when frees outrun writes, hence the None floor).
    nxt = sched.in_rows            # clamped +inf: everything is freed
    lo = [0] * steps
    peak = peak_hold = stream_max = None
    for t in range(steps - 1, -1, -1):
        lo_t = nxt * ic
        lo[t] = lo_t
        s0 = starts[t]
        if s0 >= 0:
            nxt = s0
        w = we_list[t] * oc
        if w > (we_list[t - 1] * oc if t else 0):   # a step that writes
            s_hold = w - a_freed[t]
            if peak_hold is None or s_hold > peak_hold:
                peak_hold = s_hold
            s = s_hold - lo_t
            if peak is None or s > peak:
                peak = s
            sm = w - lo_t
            if stream_max is None or sm > stream_max:
                stream_max = sm
    if peak is None:
        peak = peak_hold = stream_max = 0

    aux_lo = None
    aux_tot = 0
    if have_aux:
        aux_tot = sched.aux_rows * aux_chunk
        aux_lo = np.asarray(a_freed, dtype=np.int64)

    return _SchedInfo(
        sched=sched, monotone_error=None, in_tot=in_tot, out_tot=out_tot,
        t_read=max(t_read, 0), t_aux=max(t_aux, 0), aux_tot=aux_tot,
        n_read_events=n_read_events, n_aux_events=n_aux,
        we=np.asarray(we_list, dtype=np.int64) * oc,
        lo=np.asarray(lo, dtype=np.int64), aux_lo=aux_lo,
        stream_peak=peak, stream_peak_hold=peak_hold,
        stream_max=stream_max, aux_stream_max=peak_hold)


def _sched_info_build_generic(sched: RowSchedule) -> _SchedInfo:
    """Event-exact fallback: derives the same frontiers from the flat
    read/write event streams, for schedules outside the contiguous-
    window shape the fast path handles."""
    steps = sched.steps
    ic, oc = sched.in_chunk, sched.out_chunk
    in_tot = sched.in_rows * ic
    out_tot = sched.out_rows * oc

    # Decidable-fragment gate first (see _SchedInfo / DESIGN.md §11):
    # writes must be the in-order row sweep, reads must never resurrect
    # a freed row, aux reads must sweep once in order.  Everything else
    # below RELIES on these facts (e.g. we = cumsum of write counts).
    flat_w, w_cnt = _flatten(sched.writes, steps)
    if not _is_sweep(flat_w, sched.out_rows):
        return _inconclusive_info(
            sched, "writes are not the in-order row sweep")
    flat_r, r_cnt = _flatten(sched.reads, steps)
    lr = np.full(sched.in_rows, -1, dtype=np.int64)
    if len(flat_r):
        np.maximum.at(lr, flat_r,
                      np.repeat(np.arange(steps, dtype=np.int64), r_cnt))
    nm = sched.needed_min(lr)
    rows = np.nonzero(lr >= 0)[0]
    if rows.size and not (nm[lr[rows] + 1] > rows).all():
        return _inconclusive_info(
            sched, "read frontier is not monotone (freed rows re-read)")

    we = np.cumsum(w_cnt) * oc          # exact: writes are the sweep
    lo = np.minimum(nm[1:], sched.in_rows) * ic
    aux_lo = None
    aux_tot = n_aux = 0
    t_aux = 0
    if sched.aux_reads is not None and any(sched.aux_reads):
        flat_a, a_cnt = _flatten(sched.aux_reads, steps)
        if not _is_sweep(flat_a, sched.aux_rows):
            return _inconclusive_info(
                sched, "aux reads are not the in-order row sweep")
        t_aux = int(np.argmax(a_cnt > 0))
        aux_tot = sched.aux_rows * sched.aux_chunk
        n_aux = len(flat_a)
        aux_lo = np.cumsum(a_cnt) * sched.aux_chunk
    has_write = w_cnt > 0
    a_freed = aux_lo if aux_lo is not None else 0
    stream = we - lo - a_freed
    stream_hold = we - a_freed
    any_write = bool(has_write.any())
    peak = int(stream[has_write].max()) if any_write else 0
    peak_hold = int(stream_hold[has_write].max()) if any_write else 0
    stream_max = int((we - lo)[has_write].max()) if any_write else 0
    return _SchedInfo(
        sched=sched, monotone_error=None, in_tot=in_tot, out_tot=out_tot,
        t_read=int(np.argmax(r_cnt > 0)) if len(flat_r) else 0,
        t_aux=t_aux, aux_tot=aux_tot, n_read_events=len(flat_r),
        n_aux_events=n_aux, we=we, lo=lo, aux_lo=aux_lo,
        stream_peak=peak, stream_peak_hold=peak_hold,
        stream_max=stream_max, aux_stream_max=peak_hold)


def _sched_info(op: PoolOp, seg_width: int, m_rows: int) -> _SchedInfo:
    key = _sched_key(op, seg_width, m_rows)
    info = _SCHED_CACHE.get(key)
    if info is None:
        if len(_SCHED_CACHE) >= 4096:       # unbounded-growth backstop
            _SCHED_CACHE.clear()
        info = _SCHED_CACHE[key] = _sched_info_build(op, seg_width,
                                                     m_rows)
    return info


def _inconclusive(reason: str, op_index: int | None = None
                  ) -> VerifyResult:
    return VerifyResult(safe=None, diagnostics=[Diagnostic(
        "VMCU105", reason + " — fall back to certify='sim'",
        severity="warning", op_index=op_index)])


def verify_program(program: PoolProgram) -> VerifyResult:
    """Statically prove (or refute) that ``program`` replays through the
    :class:`~repro.core.pool.SegmentPool` clobber oracle without error.

    Agreement contract: whenever the result is ``safe=True`` /
    ``safe=False`` it matches the sim oracle's verdict on the same
    program, and on ``safe=True`` the ``stats`` equal the sim pool's
    counters (``tests/test_verifier.py`` pins both, adversarially)."""
    n = program.n_segments
    if n <= 0:
        return _inconclusive(f"invalid pool size n_segments={n}")
    if not program.ops:
        return _inconclusive("empty program")
    for i, op in enumerate(program.ops):
        if op.kind not in EXECUTABLE_KINDS:
            return _inconclusive(
                f"plan-only op kind {op.kind!r} has no executable "
                "schedule", op_index=i)

    seg_bytes = program.seg_width * program.elem_bytes
    first = program.ops[0]

    # -- staging: the net input tensor becomes record 0 ------------------
    if first.in_segments > n:
        d = Diagnostic(
            "VMCU103",
            f"staged input ({first.in_segments} segments) wraps the "
            f"{n}-segment ring onto itself; first self-clobber at "
            f"segment {n}",
            op_index=0, step=0,
            segment=(first.in_ptr + n) % n,
            byte=((first.in_ptr + n) % n) * seg_bytes)
        return VerifyResult(safe=False, diagnostics=[d])
    records: dict[int, _Record] = {
        0: _Record(0, first.in_ptr, first.in_segments)}
    peak = first.in_segments
    reads_total = 0
    writes_total = first.in_segments

    # -- persistent stream state: pre-registered live records -------------
    # State regions (repro.stream) outlive every frame tensor: the sim
    # pre-writes them under ("state", i, j) owners before staging, so the
    # verifier registers them as live records that are NEVER freed — the
    # static-clash sweep (f) below then proves every frame write misses
    # them, which is exactly the VMCU211 obligation.  Records get rid
    # -(100 + i) so they can never collide with tensor ids (>= 0).
    state_rids: list[int] = []
    state_total = 0
    for i, op in enumerate(program.ops):
        if not op.state_segments:
            continue
        if op.kind not in _STREAM_KINDS:
            return _inconclusive(
                f"op kind {op.kind!r} carries state_segments but has no "
                "streaming semantics", op_index=i)
        expect = (op.h_in * op.w_in
                  * segments_for(op.d_in, program.seg_width)
                  if op.kind == "conv_stream"
                  else segments_for(op.d_out, program.seg_width))
        if op.state_segments != expect:
            d = Diagnostic(
                "VMCU212",
                f"{op.kind} op {i} carries {op.state_segments} state "
                f"segments but its geometry needs {expect} — the step "
                "cannot write the full state back",
                op_index=i)
            return VerifyResult(safe=False, diagnostics=[d])
        base = op.state_ptr % n
        if base + op.state_segments > n:
            d = Diagnostic(
                "VMCU213",
                f"{op.kind} op {i} state wraps the ring (base {base} + "
                f"{op.state_segments} segments > n={n}); the next step "
                "would read re-staged frame bytes as state",
                op_index=i, segment=base, byte=base * seg_bytes)
            return VerifyResult(safe=False, diagnostics=[d])
        for rid in state_rids:
            other = records[rid]
            clash = first_static_clash(
                op.state_segments, other.length,
                (other.base - op.state_ptr) % n, n)
            if clash is not None:
                slot = (op.state_ptr + clash[0]) % n
                d = Diagnostic(
                    "VMCU213",
                    f"state of op {i} overlaps state of op "
                    f"{-(rid + 100)} at pool slot {slot} — each step "
                    "reads the other's bytes as its own stale state",
                    op_index=i, segment=slot, byte=slot * seg_bytes)
                return VerifyResult(safe=False, diagnostics=[d])
        rid = -(100 + i)
        records[rid] = _Record(rid, op.state_ptr, op.state_segments)
        state_rids.append(rid)
        state_total += op.state_segments
    if state_total:
        for rid in state_rids:   # staging must not overwrite live state
            other = records[rid]
            clash = first_static_clash(
                first.in_segments, other.length,
                (other.base - first.in_ptr) % n, n)
            if clash is not None:
                slot = (first.in_ptr + clash[0]) % n
                d = Diagnostic(
                    "VMCU211",
                    f"staged frame input clobbers live stream state of "
                    f"op {-(rid + 100)} at pool slot {slot}",
                    op_index=0, step=0, segment=slot,
                    byte=slot * seg_bytes)
                return VerifyResult(safe=False, diagnostics=[d])
        peak += state_total
        writes_total += state_total

    for i, op in enumerate(program.ops):
        info = _sched_info(op, program.seg_width, program.m_rows)
        if info.monotone_error is not None:
            return _inconclusive(f"{op.kind} schedule: "
                                 f"{info.monotone_error}", op_index=i)
        sched = info.sched
        oc = sched.out_chunk
        in_tot, out_tot = info.in_tot, info.out_tot
        iown = op.in_op if (op.in_op >= 0 and op.kind in _ROWSCHED_KINDS) \
            else i

        # sliced ops (repro.partial) read a row WINDOW of a longer held
        # source record; the proof treats the whole record as static,
        # which requires the op to hold it and the window to fit.
        src_tot = op.h_src * sched.in_chunk if op.h_src else in_tot
        if op.h_src:
            if not op.hold_input:
                return _inconclusive(
                    f"op {i} windows its source (h_src={op.h_src}) "
                    "without holding it", op_index=i)
            if (op.in_row0 + sched.in_rows) * sched.in_chunk > src_tot:
                return _inconclusive(
                    f"op {i} reads rows [{op.in_row0}, "
                    f"{op.in_row0 + sched.in_rows}) beyond its "
                    f"{op.h_src}-row source", op_index=i)

        # candidate first errors within this op: key (step, phase, seg)
        # with phases read=0, aux=1, write=3 — the sim's in-step order.
        candidates: list[tuple[tuple[int, int, int], Diagnostic]] = []

        rec = records.get(iown)
        if rec is None:
            candidates.append(((info.t_read, 0, 0), Diagnostic(
                "VMCU202",
                f"{op.kind} op {i} reads tensor {iown} which is not "
                "live (freed by an earlier consumer, or in_op/hold_input "
                "is wrong)", op_index=i, step=info.t_read)))
        elif (rec.base - op.in_ptr) % n != 0:
            candidates.append(((info.t_read, 0, 0), Diagnostic(
                "VMCU201",
                f"{op.kind} op {i} reads its input at segment "
                f"{op.in_ptr} but tensor {iown} is live at segment "
                f"{rec.base} (offset {(rec.base - op.in_ptr) % n} mod "
                f"{n})", op_index=i, step=info.t_read,
                segment=op.in_ptr % n, byte=(op.in_ptr % n) * seg_bytes)))
        elif rec.length != src_tot:
            return _inconclusive(
                f"{op.kind} op {i} expects {src_tot} input segments but "
                f"tensor {iown} is live with {rec.length}", op_index=i)

        aux_rec = None
        if info.aux_tot:
            if op.aux_op == iown:
                return _inconclusive(
                    f"op {i} aliases its residual source to its own "
                    "input tensor", op_index=i)
            aux_rec = records.get(op.aux_op)
            if aux_rec is None:
                candidates.append(((info.t_aux, 1, 0), Diagnostic(
                    "VMCU204",
                    f"{op.kind} op {i} reads residual tensor "
                    f"{op.aux_op} which is not live", op_index=i,
                    step=info.t_aux)))
            elif (aux_rec.base - op.aux_ptr) % n != 0:
                candidates.append(((info.t_aux, 1, 0), Diagnostic(
                    "VMCU203",
                    f"{op.kind} op {i} reads its residual at segment "
                    f"{op.aux_ptr} but tensor {op.aux_op} is live at "
                    f"segment {aux_rec.base}", op_index=i,
                    step=info.t_aux, segment=op.aux_ptr % n,
                    byte=(op.aux_ptr % n) * seg_bytes)))
            elif aux_rec.length != info.aux_tot:
                return _inconclusive(
                    f"op {i} expects {info.aux_tot} residual segments "
                    f"but tensor {op.aux_op} is live with "
                    f"{aux_rec.length}", op_index=i)

        def _write_diag(code: str, w: int, victim_rid: int,
                        victim_seg: int, step: int | None = None
                        ) -> tuple[tuple[int, int, int], Diagnostic]:
            if step is None:
                ev_t = [t for t, rows in enumerate(sched.writes)
                        for _ in rows]
                step = ev_t[min(w // oc, len(ev_t) - 1)]
            slot = (op.out_ptr + w) % n
            victim = (f"stream state of op {-(victim_rid + 100)}"
                      if victim_rid < 0 else f"tensor {victim_rid}")
            return ((step, 3, w), Diagnostic(
                code,
                f"{op.kind} op {i} writes output segment {w} over live "
                f"segment {victim_seg} of {victim} at pool "
                f"slot {slot}", op_index=i, step=step, segment=slot,
                byte=slot * seg_bytes))

        # (c) the output wrapping the ring onto itself
        if out_tot > n:
            candidates.append(_write_diag("VMCU103", n, i + 1, 0))

        # (d) writes vs the shrinking live suffix of the streamed input
        if rec is not None and not any(k[1] == 0 for k, _ in candidates):
            delta = (rec.base - op.out_ptr) % n
            if op.hold_input:
                clash = first_static_clash(out_tot, rec.length, delta, n)
                if clash is not None:
                    candidates.append(_write_diag(
                        "VMCU102", clash[0], iown, clash[1]))
            elif (delta < info.stream_max or delta + in_tot > n
                  or out_tot > n):
                # O(1) precheck failed — run the exact modular scan
                clash3 = first_stream_clash(info.we, info.lo, in_tot,
                                            delta, n)
                if clash3 is not None:
                    t, w, r = clash3
                    candidates.append(_write_diag(
                        "VMCU101", w, iown, r, step=t))

        # (e) writes vs the shrinking residual source
        if aux_rec is not None and not any(
                k[1] == 1 for k, _ in candidates):
            a_delta = (aux_rec.base - op.out_ptr) % n
            if (a_delta < info.aux_stream_max
                    or a_delta + info.aux_tot > n or out_tot > n):
                clash3 = first_stream_clash(
                    info.we, info.aux_lo, info.aux_tot, a_delta, n)
                if clash3 is not None:
                    t, w, r = clash3
                    candidates.append(_write_diag(
                        "VMCU102", w, op.aux_op, r, step=t))

        # (f) writes vs every other live tensor (constant intervals)
        for rid, other in records.items():
            if rid == iown or (aux_rec is not None and rid == op.aux_op):
                continue
            clash = first_static_clash(
                out_tot, other.length, (other.base - op.out_ptr) % n, n)
            if clash is not None:
                candidates.append(_write_diag(
                    "VMCU211" if rid < 0 else "VMCU102",
                    clash[0], rid, clash[1]))

        if candidates:
            _, diag = min(candidates, key=lambda c: c[0])
            return VerifyResult(safe=False, diagnostics=[diag])

        # -- clean: update exact sim-pool statistics ----------------------
        reads_total += info.n_read_events * sched.in_chunk \
            + info.n_aux_events * sched.aux_chunk
        writes_total += out_tot
        if op.state_segments:
            # whole-state read then same-owner whole-state rewrite (the
            # window shift / hidden-state update) — mirrors _sim_stream_op
            reads_total += op.state_segments
            writes_total += op.state_segments
        live_before = sum(r.length for r in records.values())
        stream = info.stream_peak_hold if op.hold_input \
            else info.stream_peak
        peak = max(peak, live_before + stream)

        # -- records after the op -----------------------------------------
        if not op.hold_input or op.free_src:
            records.pop(iown, None)
        if aux_rec is not None:
            records.pop(op.aux_op, None)
        if op.out_op >= 0:
            # deferred-owner write (repro.partial): this op contributes a
            # row band of the SHARED tensor consumed by op out_op — the
            # record grows contiguously slice by slice.
            dst = records.get(op.out_op)
            if dst is None:
                if op.out_row0:
                    return _inconclusive(
                        f"op {i} writes rows at offset {op.out_row0} of "
                        f"tensor {op.out_op} before its first rows exist",
                        op_index=i)
                records[op.out_op] = _Record(op.out_op, op.out_ptr,
                                             out_tot)
            elif (op.out_row0 * oc != dst.length
                  or (op.out_ptr - dst.base) % n != dst.length):
                return _inconclusive(
                    f"op {i} extends tensor {op.out_op} non-contiguously "
                    f"(record length {dst.length}, write row offset "
                    f"{op.out_row0})", op_index=i)
            else:
                dst.length += out_tot
        else:
            records[i + 1] = _Record(i + 1, op.out_ptr, out_tot)

    # -- the final outputs must survive the ring --------------------------
    last = program.ops[-1]
    final = records.get(len(program.ops))
    if final is None:
        return _inconclusive("last op defers its output to a consumer "
                             "beyond the program",
                             op_index=len(program.ops) - 1)
    if last.out_segments > final.length:
        d = Diagnostic(
            "VMCU104",
            f"program promises {last.out_segments} output segments but "
            f"only {final.length} were produced",
            op_index=len(program.ops) - 1)
        return VerifyResult(safe=False, diagnostics=[d])
    reads_total += last.out_segments
    if state_total:
        reads_total += state_total   # ...and so must persistent state

    stats = {"peak_live": peak, "reads": reads_total,
             "writes": writes_total, "n_segments": n}
    if state_total:
        # Multi-step horizon: one verified step plus the invariant that
        # the only records alive at end-of-step are the state regions and
        # the final output (which the stream session frees after fetching
        # it) means step k+1 starts from the SAME abstract state as step
        # k — the per-step proof lifts to an unbounded horizon.
        stats["n_states"] = len(state_rids)
        stats["state_segments"] = state_total
        leftover = set(records) - {len(program.ops)} - set(state_rids)
        stats["stream_horizon"] = "unbounded" if not leftover else 1
    return VerifyResult(safe=True, diagnostics=[], stats=stats)
