"""repro.analysis — static ring-safety verification and plan linting.

The vMCU planner *solves* the Eq.-(1)/(2) segment-ring offsets; this
package *proves* them, without executing anything:

  * :mod:`repro.analysis.verifier` — the abstract interpreter
    (:func:`verify_program`): live-record domain over the same row
    schedules the sim oracle replays; emits a machine-checkable safety
    certificate or a ``VMCU1xx``/``VMCU2xx`` diagnostic with the exact
    first clobbered byte and step,
  * :mod:`repro.analysis.lint` — budget / byte-accounting / artifact /
    emitted-C findings (``VMCU3xx``–``VMCU5xx``),
  * :mod:`repro.analysis.mutate` — deterministic plan corruptions for
    the differential fault-injection tests,
  * :mod:`repro.analysis.cli` — the ``vmcu-lint`` console entry point.

``repro.compile`` surfaces all of this as the ``lint`` pass and the
``certify="static"`` mode (DESIGN.md §11).
"""
from .lint import (ArtifactReport, lint_artifact, lint_c_dir,
                   lint_program)
from .mutate import Mutation, break_plan, mutations
from .verifier import (CODES, Diagnostic, VerifyResult, verify_program)

__all__ = [
    "ArtifactReport",
    "CODES",
    "Diagnostic",
    "Mutation",
    "VerifyResult",
    "break_plan",
    "lint_artifact",
    "lint_c_dir",
    "lint_program",
    "mutations",
    "verify_program",
]
