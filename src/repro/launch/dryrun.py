import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST precede every other import (jax locks the device
count at first init).  For each cell this script:

  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. synthesizes ShapeDtypeStruct inputs with shardings (no allocation),
  3. ``jit(step).lower(...)`` then ``.compile()`` — sharding mismatches,
     unsupported collectives or compile-time OOM fail HERE,
  4. records memory_analysis / cost_analysis / collective schedule to JSON
     for §Dry-run and §Roofline of EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch gemma2-2b --cell train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--out results/dryrun]
"""
import argparse
import json
import time
import traceback

import jax

from ..configs import ARCH_REGISTRY, cells_for, get_config
from ..configs.base import ALL_SHAPES
from ..models.registry import build_model
from ..roofline.analysis import analyze, model_flops
from ..train.train_step import make_train_step
from .mesh import make_production_mesh
from .specs import input_specs, make_rules


def build_step_fn(model, cfg, cell, rules, *, microbatches: int = 1,
                  remat: str | None = None, cast_bf16: bool = False,
                  rs_grads: bool = False, two_copy: bool = False):
    if cell.kind == "train":
        step = make_train_step(model, rules, microbatches=microbatches,
                               remat_policy=remat,
                               cast_params_bf16=cast_bf16,
                               constrain_grads=rs_grads, two_copy=two_copy)
        return step
    if cell.kind == "prefill":
        if cfg.family in ("vlm", "audio"):
            def prefill(params, tokens, memory):
                return model.prefill(params, tokens, rules, memory=memory,
                                     cache_len=cell.seq_len)
        else:
            def prefill(params, tokens):
                return model.prefill(params, tokens, rules,
                                     cache_len=cell.seq_len)
        return prefill

    def decode(params, caches, token, cur_len):
        return model.decode_step(params, caches, token, cur_len, rules)
    return decode


def _compile_cell(cfg, cell, mesh, rules, *, microbatches, remat,
                  cast_bf16=False, rs_grads=False, serve_dtype=None,
                  two_copy=False):
    model = build_model(cfg)
    specs = input_specs(model, cfg, cell, rules, serve_dtype=serve_dtype,
                        two_copy=two_copy)
    step = build_step_fn(model, cfg, cell, rules, microbatches=microbatches,
                         remat=remat, cast_bf16=cast_bf16,
                         rs_grads=rs_grads, two_copy=two_copy)
    with mesh:
        lowered = jax.jit(step, donate_argnums=specs.donate).lower(
            *specs.args)
        compiled = lowered.compile()
    return compiled


def _probe_costs(cfg, cell, mesh, rules, *, microbatches, remat,
                 cast_bf16=False, rs_grads=False, two_copy=False):
    """Exact per-group cost via two shallow UNROLLED probes.

    XLA's cost_analysis counts a while-loop (scan) body once, so the full
    scan compile under-reports FLOPs by ~n_groups×.  Probes at 1 and 2
    unrolled groups give the per-group increment; the cell's true cost is
    ``c1 + (G-1)·(c2 - c1)`` — exact because every per-group cost
    (fwd/bwd/optimizer/collectives) is linear in depth."""
    import dataclasses as _dc
    p = len(cfg.pattern)
    lead = cfg.first_dense_layers
    rem = (cfg.n_layers - lead) % p
    G = (cfg.n_layers - lead) // p
    enc = cfg.encoder_layers

    def probe(k_groups: int, k_enc: int):
        pc = _dc.replace(cfg, scan_layers=False,
                         n_layers=lead + k_groups * p + rem,
                         encoder_layers=k_enc)
        compiled = _compile_cell(pc, cell, mesh, rules,
                                 microbatches=microbatches, remat=remat,
                                 cast_bf16=cast_bf16, rs_grads=rs_grads,
                                 two_copy=two_copy)
        return analyze(compiled)

    r1 = probe(1, min(enc, 1))
    r2 = probe(2, min(enc, 2))

    def lerp(a, b):
        return a + (G - 1) * (b - a) if not enc else a + (G - 1) * (b - a)

    flops = lerp(r1.flops_per_chip, r2.flops_per_chip)
    byts = lerp(r1.hbm_bytes_per_chip, r2.hbm_bytes_per_chip)
    colls = {}
    for kind in set(r1.collectives) | set(r2.collectives):
        c1 = r1.collectives.get(kind, {"count": 0, "bytes": 0.0})
        c2 = r2.collectives.get(kind, {"count": 0, "bytes": 0.0})
        colls[kind] = {
            "count": int(lerp(c1["count"], c2["count"])),
            "bytes": lerp(c1["bytes"], c2["bytes"]),
        }
    from ..roofline.analysis import Roofline
    return Roofline(flops_per_chip=flops, hbm_bytes_per_chip=byts,
                    collective_bytes_per_chip=sum(v["bytes"]
                                                  for v in colls.values()),
                    collectives=colls)


def run_cell(arch: str, cell_name: str, multi_pod: bool, *,
             microbatches: int = 1, remat: str | None = None,
             unroll: bool = False, probe: bool = True,
             cast_bf16: bool = False, rs_grads: bool = False,
             moe_dispatch: str | None = None, serve_bf16: bool = False,
             bf16_einsum: bool = False, two_copy: bool = False,
             sp_residual: bool = False, kv_fp8: bool = False,
             save_hlo: str | None = None) -> dict:
    import dataclasses as _dc
    cfg = get_config(arch)
    if unroll:
        cfg = _dc.replace(cfg, scan_layers=False)
    if moe_dispatch:
        cfg = _dc.replace(cfg, moe_dispatch=moe_dispatch)
    if bf16_einsum:
        cfg = _dc.replace(cfg, bf16_einsum=True)
    cell = next(c for c in ALL_SHAPES if c.name == cell_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(cfg, mesh, cell, multi_pod=multi_pod)
    import jax.numpy as _jnp
    sdt = _jnp.bfloat16 if serve_bf16 else None
    kdt = _jnp.float8_e4m3fn if kv_fp8 else _jnp.bfloat16
    model = build_model(cfg)
    specs = input_specs(model, cfg, cell, rules, serve_dtype=sdt,
                        kv_dtype=kdt, two_copy=two_copy)
    step = build_step_fn(model, cfg, cell, rules, microbatches=microbatches,
                         remat=remat, cast_bf16=cast_bf16, rs_grads=rs_grads,
                         two_copy=two_copy)

    t0 = time.time()
    with mesh:
        lowered = jax.jit(step, donate_argnums=specs.donate).lower(
            *specs.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    if probe and not multi_pod:
        # Roofline table is single-pod: probe-extrapolated exact costs.
        # Probes always run microbatches=1 — the grad-accumulation scan is
        # a while loop whose body cost_analysis counts once, but per-step
        # totals are microbatch-invariant (only peak memory changes).
        roof = _probe_costs(cfg, cell, mesh, rules,
                            microbatches=1, remat=remat,
                            cast_bf16=cast_bf16, rs_grads=rs_grads,
                            two_copy=two_copy)
    else:
        roof = analyze(compiled, hlo)
    n_chips = mesh.devices.size
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode"
                                  else 1)
    useful = model_flops(cfg.param_count(), cfg.active_param_count(),
                         tokens, cell.kind) / n_chips
    rec = {
        "arch": arch,
        "cell": cell_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": cfg.shard_mode,
        "microbatches": microbatches,
        "cast_bf16": cast_bf16,
        "rs_grads": rs_grads,
        "moe_dispatch": cfg.moe_dispatch,
        "serve_bf16": serve_bf16,
        "bf16_einsum": cfg.bf16_einsum,
        "two_copy": two_copy,
        "sp_residual": sp_residual,
        "kv_fp8": kv_fp8,
        "unrolled": unroll,
        "remat": remat or cfg.remat_policy,
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": (mem.argument_size_in_bytes
                           + mem.temp_size_in_bytes),
            "fits_16g": (mem.argument_size_in_bytes
                         + mem.temp_size_in_bytes) < 16e9,
        },
        "roofline": {
            "flops_per_chip": roof.flops_per_chip,
            "hbm_bytes_per_chip": roof.hbm_bytes_per_chip,
            "t_compute_s": roof.t_compute,
            "t_memory_s": roof.t_memory,
            "t_collective_s": roof.t_collective,
            "dominant": roof.dominant,
            "collectives": roof.collectives,
            "useful_flops_per_chip": useful,
            "model_flops_ratio": (useful / roof.flops_per_chip
                                  if roof.flops_per_chip else 0.0),
            "roofline_fraction": roof.fraction_of_roofline(useful),
        },
    }
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str)
    ap.add_argument("--cell", type=str)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer groups for exact cost_analysis")
    ap.add_argument("--cast-bf16", action="store_true",
                    help="hillclimb: bf16 shard-local param casting")
    ap.add_argument("--rs-grads", action="store_true",
                    help="hillclimb: reduce-scatter gradient constraint")
    ap.add_argument("--moe-dispatch", type=str, default=None,
                    help="hillclimb: MoE dispatch variant (scan)")
    ap.add_argument("--serve-bf16", action="store_true",
                    help="hillclimb: bf16 weights for prefill/decode")
    ap.add_argument("--two-copy", action="store_true",
                    help="hillclimb: bf16 param copy in TrainState")
    ap.add_argument("--sp-residual", action="store_true",
                    help="hillclimb: Megatron-SP residual sharding (tp)")
    ap.add_argument("--kv-fp8", action="store_true",
                    help="hillclimb: fp8(e4m3) KV caches for decode")
    ap.add_argument("--remat", type=str, default=None)
    ap.add_argument("--out", type=str, default="results/dryrun")
    ap.add_argument("--save-hlo", type=str, default=None)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    jobs: list[tuple[str, str, bool]] = []
    if args.all:
        for arch, cfg in ARCH_REGISTRY.items():
            for cell in cells_for(cfg):
                jobs.append((arch, cell.name, False))
                jobs.append((arch, cell.name, True))
    else:
        jobs.append((args.arch, args.cell, args.multi_pod))

    for arch, cell, mp in jobs:
        tag = f"{arch}__{cell}__{'2x16x16' if mp else '16x16'}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path) and args.all:
            print(f"[skip] {tag}")
            continue
        print(f"[run ] {tag}", flush=True)
        try:
            rec = run_cell(arch, cell, mp, microbatches=args.microbatches,
                           remat=args.remat, unroll=args.unroll,
                           cast_bf16=args.cast_bf16, rs_grads=args.rs_grads,
                           moe_dispatch=args.moe_dispatch,
                           serve_bf16=args.serve_bf16,
                           two_copy=args.two_copy,
                           sp_residual=args.sp_residual,
                           kv_fp8=args.kv_fp8,
                           save_hlo=args.save_hlo)
        except Exception as e:  # record failures — they are findings
            rec = {"arch": arch, "cell": cell,
                   "mesh": "2x16x16" if mp else "16x16",
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        status = rec["status"]
        extra = ""
        if status == "ok":
            m = rec["memory"]
            r = rec["roofline"]
            extra = (f"peak={m['peak_bytes']/1e9:.2f}GB "
                     f"dom={r['dominant']} "
                     f"frac={r['roofline_fraction']:.3f} "
                     f"compile={rec['compile_s']:.0f}s")
        print(f"[done] {tag}: {status} {extra}", flush=True)


if __name__ == "__main__":
    main()
