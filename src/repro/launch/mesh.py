"""Production meshes.

Functions, not module constants — importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod stacks 2 pods (512 chips).

    Axes: ``data`` (DP/FSDP), ``model`` (TP/experts/vocab), ``pod`` (pure DP
    across the DCN — gradients cross it once per step)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"))
