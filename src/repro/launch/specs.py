"""ShapeDtypeStruct stand-ins (+ shardings) for every (arch × shape) cell.

Nothing here allocates: params/state/caches come from ``jax.eval_shape`` and
inputs are synthesized structs.  ``input_specs`` is the single entry point
the dry-run, roofline and launch scripts share.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeCell
from ..models.transformer import Model
from ..parallel.sharding import AxisRules
from ..train.data import batch_spec
from ..train.optimizer import TrainState
from ..train.train_step import init_train_state


def make_rules(cfg: ModelConfig, mesh: Mesh | None, cell: ShapeCell,
               multi_pod: bool = False) -> AxisRules:
    model_size = mesh.shape.get("model", 1) if mesh is not None else 1
    return AxisRules(
        mesh=mesh,
        mode=cfg.shard_mode,
        multi_pod=multi_pod,
        decode=(cell.kind == "decode"),
        long_context=(cell.kind == "decode" and cell.global_batch == 1),
        kv_shardable=(model_size > 0
                      and cfg.n_kv_heads % max(model_size, 1) == 0),
    )


def _with_sharding(shapes: Any, shardings: Any) -> Any:
    def attach(s, ns):
        if ns is None:
            return jax.ShapeDtypeStruct(s.shape, s.dtype)
        return jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=ns)
    return jax.tree.map(attach, shapes, shardings)


def state_specs(model: Model, rules: AxisRules, *,
                two_copy: bool = False) -> TrainState:
    shapes = jax.eval_shape(
        lambda: init_train_state(model, jax.random.PRNGKey(0),
                                 two_copy=two_copy))
    shardings = TrainState(
        step=rules.sharding() and NamedSharding(rules.mesh, P()),
        params=rules.params_shardings(shapes.params),
        mu=rules.params_shardings(shapes.mu),
        nu=rules.params_shardings(shapes.nu),
        cast=(rules.params_shardings(shapes.cast) if two_copy else None),
    )
    return _with_sharding(shapes, shardings)


def params_specs(model: Model, rules: AxisRules, *,
                 dtype=None) -> Any:
    """Param ShapeDtypeStructs; ``dtype`` overrides float leaves (serving
    runs bf16 weights — §Perf global improvement)."""
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    if dtype is not None:
        shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, dtype if s.dtype == jnp.float32 else s.dtype),
            shapes)
    return _with_sharding(shapes, rules.params_shardings(shapes))


def batch_specs(cfg: ModelConfig, cell: ShapeCell, rules: AxisRules) -> dict:
    spec = batch_spec(cfg, cell)
    out = {}
    for name, s in spec.items():
        dims = ("batch",) + (None,) * (len(s.shape) - 1)
        ns = rules.sharding(*dims)
        out[name] = (jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=ns)
                     if ns is not None else s)
    return out


def cache_specs(model: Model, cfg: ModelConfig, rules: AxisRules,
                batch: int, cache_len: int, dtype=jnp.bfloat16) -> Any:
    shapes = jax.eval_shape(
        lambda: model.init_caches(batch, cache_len, dtype))

    def classify(s: jax.ShapeDtypeStruct):
        shp = s.shape
        nd = len(shp)
        kv_sig = (cfg.n_kv_heads, cfg.head_dim)
        if nd >= 4 and shp[-2:] == kv_sig:
            seq = shp[-3]
            lead = (None,) * (nd - 4)
            if seq == cache_len and cache_len != cfg.window:
                dims = lead + ("batch", "kv_seq", "kv_heads", None)
            else:  # ring window or cross-memory KV — small, seq-replicated
                dims = lead + ("batch", None, "kv_heads", None)
            return rules.sharding(*dims)
        if cfg.ssm_state and nd >= 4 and shp[-2:] == (cfg.ssm_head_dim,
                                                      cfg.ssm_state):
            lead = (None,) * (nd - 4)
            return rules.sharding(*(lead + ("batch", "heads", None, None)))
        if shp[-1] == (cfg.lru_width or -1):
            if nd >= 3 and shp[-2] == cfg.ssm_conv - 1:   # conv [..,B,K-1,W]
                lead = (None,) * (nd - 3)
                return rules.sharding(*(lead + ("batch", None, "tp")))
            if nd >= 2 and shp[-2] == batch:              # h state [..,B,W]
                lead = (None,) * (nd - 2)
                return rules.sharding(*(lead + ("batch", "tp")))
        # conv states & misc: batch-shard only
        lead = (None,) * (len(shp) - 1)
        bdim = next((i for i, d in enumerate(shp) if d == batch), None)
        dims = tuple("batch" if i == bdim else None for i in range(nd))
        return rules.sharding(*dims)

    shardings = jax.tree.map(classify, shapes)
    return _with_sharding(shapes, shardings)


@dataclasses.dataclass(frozen=True)
class CellSpecs:
    """Everything needed to lower one (arch × shape × mesh) cell."""
    kind: str
    args: tuple            # positional ShapeDtypeStructs for the step fn
    donate: tuple[int, ...]


def input_specs(model: Model, cfg: ModelConfig, cell: ShapeCell,
                rules: AxisRules, *, serve_dtype=None,
                kv_dtype=jnp.bfloat16,
                two_copy: bool = False) -> CellSpecs:
    if cell.kind == "train":
        return CellSpecs(
            kind="train",
            args=(state_specs(model, rules, two_copy=two_copy),
                  batch_specs(cfg, cell, rules)),
            donate=(0,),
        )
    if cell.kind == "prefill":
        params = params_specs(model, rules, dtype=serve_dtype)
        toks = jax.ShapeDtypeStruct(
            (cell.global_batch, cell.seq_len), jnp.int32,
            sharding=rules.sharding("batch", None))
        args = [params, toks]
        if cfg.family in ("vlm", "audio"):
            L = (cfg.n_image_tokens if cfg.family == "vlm"
                 else cfg.encoder_seq)
            args.append(jax.ShapeDtypeStruct(
                (cell.global_batch, L, cfg.d_model), jnp.bfloat16,
                sharding=rules.sharding("batch", None, None)))
        return CellSpecs(kind="prefill", args=tuple(args), donate=())
    # decode
    params = params_specs(model, rules, dtype=serve_dtype)
    caches = cache_specs(model, cfg, rules, cell.global_batch,
                         cell.seq_len, dtype=kv_dtype)
    token = jax.ShapeDtypeStruct((cell.global_batch,), jnp.int32,
                                 sharding=rules.sharding("batch"))
    cur = jax.ShapeDtypeStruct((), jnp.int32, sharding=rules.sharding())
    return CellSpecs(kind="decode", args=(params, caches, token, cur),
                     donate=(1,))
