"""End-to-end training driver with fault tolerance.

Runs at reduced scale on this CPU container (examples/train_lm.py drives a
~100M model for a few hundred steps) and at production scale unchanged —
the mesh/shardings come from the same code path the dry-run validates.

Fault-tolerance features exercised here:
  * checkpoint/restart — atomic CheckpointManager, resume from latest step;
  * deterministic data  — batches are a pure function of step, so a restart
    replays exactly (tests/test_train.py kills and resumes mid-run);
  * preemption handling — SIGTERM sets a flag, the loop checkpoints and
    exits cleanly at the next step boundary;
  * elastic restore     — checkpoints are logical; restore re-shards onto
    the current mesh (pods may come and go between runs);
  * async checkpointing — the save thread overlaps the next train steps;
  * straggler guard     — per-step wall-time watermark is logged; steps
    slower than ``straggler_factor`` × median are counted and reported
    (on real fleets this feeds the scheduler's replacement policy).
"""
from __future__ import annotations

import argparse
import signal
import statistics
import time

import jax
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..configs import get_config
from ..models.registry import build_model
from ..parallel.sharding import AxisRules, no_sharding
from ..train.data import synthetic_batch
from ..train.optimizer import AdamWConfig
from ..train.train_step import init_train_state, make_train_step

_PREEMPTED = False


def _on_sigterm(signum, frame):  # noqa: ANN001
    global _PREEMPTED
    _PREEMPTED = True


def train_loop(cfg, *, steps: int, batch: int, seq: int, ckpt_dir: str,
               ckpt_every: int = 50, rules: AxisRules | None = None,
               microbatches: int = 1, log_every: int = 10,
               straggler_factor: float = 3.0) -> dict:
    rules = rules or no_sharding()
    model = build_model(cfg)
    opt = AdamWConfig(peak_lr=3e-4, warmup_steps=max(10, steps // 20),
                      total_steps=steps)
    step_fn = jax.jit(make_train_step(model, rules, opt=opt,
                                      microbatches=microbatches),
                      donate_argnums=(0,))
    mgr = CheckpointManager(ckpt_dir)

    start = mgr.latest_step()
    if start is None:
        state = init_train_state(model, jax.random.PRNGKey(0))
        start = 0
    else:
        like = jax.eval_shape(
            lambda: init_train_state(model, jax.random.PRNGKey(0)))
        state = mgr.restore(like)
        print(f"[restore] resumed from step {start}")

    signal.signal(signal.SIGTERM, _on_sigterm)
    losses, times, stragglers = [], [], 0
    for step in range(start, steps):
        b = synthetic_batch(cfg, batch, seq, step)
        t0 = time.time()
        state, metrics = step_fn(state, b)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        losses.append(loss)
        times.append(dt)
        if len(times) > 8 and dt > straggler_factor * statistics.median(times):
            stragglers += 1
        if step % log_every == 0:
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):8.3f} {dt*1e3:7.1f}ms",
                  flush=True)
        if (step + 1) % ckpt_every == 0 or _PREEMPTED:
            mgr.save_async(step + 1, state, {"loss": loss})
        if _PREEMPTED:
            mgr.wait()
            print(f"[preempt] checkpointed at {step + 1}, exiting")
            break
    mgr.wait()
    mgr.save(steps if not _PREEMPTED else step + 1, state,
             {"loss": losses[-1] if losses else float("nan")})
    return {"final_loss": losses[-1] if losses else float("nan"),
            "first_loss": losses[0] if losses else float("nan"),
            "stragglers": stragglers,
            "median_step_s": statistics.median(times) if times else 0.0}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    out = train_loop(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                     ckpt_dir=args.ckpt_dir, microbatches=args.microbatches)
    print(out)


if __name__ == "__main__":
    main()
