"""Serving driver: batched generation through the ring-KV engine."""
from __future__ import annotations

import argparse
import time

import jax

from ..configs import get_config
from ..models.registry import build_model
from ..serve.engine import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params,
                           cache_len=args.prompt_len + args.max_new + 8)
    prompts = [[(7 * i + j) % cfg.vocab for j in range(args.prompt_len)]
               for i in range(args.batch)]
    t0 = time.time()
    outs = engine.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    toks = args.batch * args.max_new
    print(f"generated {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s batch={args.batch})")
    for i, o in enumerate(outs[:2]):
        print(f"  req{i}: {o[:12]}...")


if __name__ == "__main__":
    main()
