"""Fault-tolerant checkpointing.

Design for 1000+ nodes (DESIGN.md §6):

* **Atomic**: write to ``step_N.tmp/`` then ``os.rename`` — a crash mid-write
  never corrupts the latest-good checkpoint; ``latest`` is resolved by
  scanning committed directories, not a mutable symlink.
* **Elastic**: arrays are saved with their *logical* pytree paths and full
  (unsharded) shapes; ``restore`` re-shards onto whatever mesh the restarted
  job has — pod counts can change between runs.
* **Async**: ``save_async`` snapshots device arrays to host then flushes on a
  background thread so the train loop resumes immediately.
* **Data-parallel dedup**: on a real cluster each host writes only the
  shards it owns (``process_index`` prefix); on this single-process CPU
  container that degenerates to one writer, same layout.
* **Retention**: ``keep`` newest checkpoints are preserved, older ones GC'd.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

_SEP = "|"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
                        for k in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- paths ---------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "MANIFEST.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree: Any, metadata: dict | None = None) -> str:
        flat = _flatten(tree)
        tmp = self._step_dir(step) + ".tmp"
        final = self._step_dir(step)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, f"shard_{jax.process_index():05d}.npz"),
                 **flat)
        manifest = {
            "step": step,
            "time": time.time(),
            "n_arrays": len(flat),
            "keys": sorted(flat),
            "treedef": str(jax.tree.structure(tree)),
            "metadata": metadata or {},
        }
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)   # commit point — atomic on POSIX
        self._gc()
        return final

    def save_async(self, step: int, tree: Any,
                   metadata: dict | None = None) -> None:
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before return
        self.wait()
        self._thread = threading.Thread(
            target=self.save, args=(step, host_tree, metadata), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        for s in self.steps()[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def restore(self, like: Any, step: int | None = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``like``; if ``shardings`` is given
        (pytree of NamedSharding, possibly for a *different* mesh than the
        checkpoint was written under) arrays are placed shard-by-shard —
        elastic rescaling."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self._step_dir(step)
        data: dict[str, np.ndarray] = {}
        for name in sorted(os.listdir(d)):
            if name.endswith(".npz"):
                with np.load(os.path.join(d, name)) as z:
                    data.update({k: z[k] for k in z.files})

        flat_like = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        shard_leaves = (jax.tree.leaves(shardings)
                        if shardings is not None else None)
        for i, (path, leaf) in enumerate(flat_like[0]):
            key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
                            for k in path)
            arr = data[key]
            if shard_leaves is not None and shard_leaves[i] is not None:
                arr = jax.device_put(arr, shard_leaves[i])
            else:
                arr = jax.numpy.asarray(arr, dtype=leaf.dtype) \
                    if hasattr(leaf, "dtype") else arr
            leaves.append(arr)
        return jax.tree.unflatten(flat_like[1], leaves)
