from .manager import CheckpointManager
