"""Row-granular access schedules for whole-network PoolOps.

The single-layer Eq.-(1) closed form covers GEMM; the conv/pool/residual
ops a whole DNN needs have richer read frontiers (halos, strided reads,
resampled rows, a residual source read late).  This module is the ONE
source of truth for those schedules: for each op kind it enumerates, per
execution step, which input *rows* (contiguous segment chunks) are read
and which output rows are written.  From that one description both

  * the planner derives the byte/segment frontiers fed to
    :func:`repro.core.graph_planner.solve_stream_offset` (Eq. 2), and
  * the ``sim`` executor replays the exact read/free/write sequence in
    the :class:`repro.core.pool.SegmentPool` clobber oracle,

so the solved offset and the certified schedule can never drift apart.

A "row" here is one contiguous chunk of pool segments: one image row
(``W * segs(C)`` segments) for conv kinds, one matrix/pixel row for
``add``, one image row in / one channel row out for ``pool_avg``.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from .graph_planner import solve_stream_offset

_INF = np.iinfo(np.int64).max // 4


def resample_src(p: int, n_in: int, n_out: int) -> int:
    """Nearest-grid row map for resampling adapters: monotone, exact
    ``p * s`` when ``n_in == s * n_out``."""
    return (p * n_in) // n_out


@dataclasses.dataclass(frozen=True)
class RowSchedule:
    """Per-step row access schedule of one op, at chunk granularity.

    ``reads[t]``/``writes[t]`` are input/output row indices touched at
    step ``t`` (reads happen before writes within a step, matching the
    kernels); ``aux_reads`` are rows of a second, non-chained source
    tensor (the residual operand of ``add``).  ``in_chunk``/``out_chunk``
    are the chunk sizes in pool segments.
    """

    steps: int
    in_rows: int
    out_rows: int
    in_chunk: int
    out_chunk: int
    reads: tuple[tuple[int, ...], ...]
    writes: tuple[tuple[int, ...], ...]
    aux_reads: tuple[tuple[int, ...], ...] | None = None
    aux_rows: int = 0
    aux_chunk: int = 0

    # -- derived frontiers -------------------------------------------------
    def last_read(self) -> np.ndarray:
        """Per input row: the last step that reads it (-1 if never read)."""
        lr = np.full(self.in_rows, -1, dtype=np.int64)
        counts = np.fromiter((len(rows) for rows in self.reads),
                             dtype=np.int64, count=self.steps)
        flat = [r for rows in self.reads for r in rows]
        if flat:
            steps = np.repeat(np.arange(self.steps, dtype=np.int64),
                              counts)
            np.maximum.at(lr, np.asarray(flat, dtype=np.int64), steps)
        return lr

    def needed_min(self, lr: np.ndarray | None = None) -> np.ndarray:
        """``needed_min[t]`` — lowest input row still read at step >= t
        (length steps + 1; trailing entry is +inf).  Pass a precomputed
        ``last_read()`` array to avoid recomputing it."""
        if lr is None:
            lr = self.last_read()
        per_t = np.full(self.steps, _INF, dtype=np.int64)
        rows = np.nonzero(lr >= 0)[0]
        np.minimum.at(per_t, lr[rows], rows)
        out = np.full(self.steps + 1, _INF, dtype=np.int64)
        out[: self.steps] = per_t
        return np.minimum.accumulate(out[::-1])[::-1]

    def frees(self) -> list[list[int]]:
        """Per step: input rows that die after that step's reads.

        A read row dies at its last read; a row skipped by the access
        pattern (strided convs) dies as soon as the read frontier passes
        it — exactly the Eq.-(2) lifetime model.
        """
        lr = self.last_read()
        nm = self.needed_min()
        dead: list[list[int]] = [[] for _ in range(self.steps)]
        for r in range(self.in_rows):
            if lr[r] >= 0:
                dead[lr[r]].append(r)
            else:
                # first step t with needed_min[t + 1] > r
                t = int(np.searchsorted(nm[1:], r, side="right"))
                dead[min(t, self.steps - 1)].append(r)
        return dead

    def read_start_segments(self) -> np.ndarray:
        # clamp the _INF sentinel (steps with no remaining reads) to
        # in_rows BEFORE scaling by in_chunk — the product overflows
        # int64 for in_chunk >= 5 otherwise
        nm = np.minimum(self.needed_min()[: self.steps], self.in_rows)
        return nm * self.in_chunk

    def write_end_segments(self) -> np.ndarray:
        hi = np.fromiter(((max(rows) + 1) if rows else 0
                          for rows in self.writes),
                         dtype=np.int64, count=self.steps)
        return np.maximum.accumulate(hi) * self.out_chunk

    def solve_delta(self) -> int:
        """Minimal segment offset ``b_In - b_Out`` for this schedule."""
        return solve_stream_offset(self.write_end_segments(),
                                   self.read_start_segments())

    # -- execution-granularity view ---------------------------------------
    def coalesced(self, block: int) -> "RowSchedule":
        """The block-granular view: ``block`` consecutive steps fused
        into one super-step — the schedule the blocked Pallas kernels
        execute (DESIGN.md §15).

        A super-step's reads/writes are the concatenation (order kept,
        duplicates kept) of its member steps', so every aggregate
        counter — total row reads, total row writes, rows freed — is
        invariant under coalescing; only the step axis changes.  The
        planner, sim oracle and static verifier keep replaying the
        fine-grained schedule (certificates stay byte-identical); this
        view exists to state and test the superblock-coalescing
        property: a certified plan's stores only land on segments
        already freed at that step, so hoisting a block's reads above
        its stores cannot read a clobbered row.
        """
        if block < 1:
            raise ValueError("block must be >= 1")
        if block == 1:
            return self

        def group(seq):
            return tuple(tuple(r for step in seq[i:i + block]
                               for r in step)
                         for i in range(0, len(seq), block))

        aux = None if self.aux_reads is None else group(self.aux_reads)
        return dataclasses.replace(
            self, steps=-(-self.steps // block), reads=group(self.reads),
            writes=group(self.writes), aux_reads=aux)


# ---------------------------------------------------------------------------
# Schedule builders, one per op kind.
#
# All builders are pure functions of scalar geometry returning a frozen
# RowSchedule, and nets repeat module shapes heavily — so they memoize.
# Planning, sim replay and static verification of the same op thereby
# share one schedule INSTANCE, not just one derivation.
# ---------------------------------------------------------------------------

_memo = functools.lru_cache(maxsize=1024)


@_memo
def conv_pw_schedule(h_in: int, h_out: int, in_chunk: int, out_chunk: int,
                     *, stride: int = 1, resample: bool = False
                     ) -> RowSchedule:
    """Pointwise conv: output image row ``p`` reads input image row
    ``p * stride`` (or the resampled source row)."""
    reads, writes = [], []
    for p in range(h_out):
        src = resample_src(p, h_in, h_out) if resample else p * stride
        reads.append((src,))
        writes.append((p,))
    return RowSchedule(steps=h_out, in_rows=h_in, out_rows=h_out,
                       in_chunk=in_chunk, out_chunk=out_chunk,
                       reads=tuple(reads), writes=tuple(writes))


@_memo
def conv_dw_schedule(h_in: int, h_out: int, in_chunk: int, out_chunk: int,
                     *, rs: int, stride: int = 1,
                     padding: str = "same") -> RowSchedule:
    """Depthwise RSxRS conv: output row ``p`` reads the clamped halo rows
    ``p*stride - pad .. p*stride - pad + rs - 1``."""
    pad = conv_k2d_pad(rs, padding)
    reads, writes = [], []
    for p in range(h_out):
        win = sorted({min(max(p * stride - pad + r, 0), h_in - 1)
                      for r in range(rs)
                      if 0 <= p * stride - pad + r < h_in})
        reads.append(tuple(win))
        writes.append((p,))
    return RowSchedule(steps=h_out, in_rows=h_in, out_rows=h_out,
                       in_chunk=in_chunk, out_chunk=out_chunk,
                       reads=tuple(reads), writes=tuple(writes))


def conv_k2d_pad(k: int, padding: str) -> int:
    """Low-side ROW padding of a k x k conv (the one definition the
    planner, executors and codegen share).

    Besides ``same`` / ``valid``, the partial-execution slicer uses two
    vertical-split modes: ``same_top`` (a top slice of a 'same' conv —
    keeps the top pad) and ``same_mid`` (an interior/bottom slice — the
    halo rows above are real data, so no top pad)."""
    if padding in ("same", "same_top"):
        return (k - 1) // 2
    if padding in ("valid", "same_mid"):
        return 0
    raise ValueError(f"unknown padding {padding!r} "
                     "(same/valid/same_top/same_mid)")


def conv_k2d_pad_w(k: int, padding: str) -> int:
    """Low-side COLUMN padding of a k x k conv.  The slicer splits rows
    only, so every 'same'-family mode keeps the full horizontal pad."""
    return 0 if padding == "valid" else (k - 1) // 2


def conv_k2d_out(h_in: int, k: int, stride: int, padding: str) -> int:
    """Output extent of a k x k conv along one spatial axis."""
    if padding == "same":
        return -(-h_in // stride)
    if padding == "same_top":
        return (h_in + (k - 1) // 2 - k) // stride + 1
    if padding == "same_mid":
        return (h_in - k) // stride + 1
    if h_in < k:
        raise ValueError(f"valid conv needs h_in >= k ({h_in} < {k})")
    return (h_in - k) // stride + 1


@_memo
def conv_k2d_schedule(h_in: int, h_out: int, in_chunk: int, out_chunk: int,
                      *, k: int, stride: int = 1,
                      padding: str = "same") -> RowSchedule:
    """General k x k spatial conv: output row ``p`` reads the input halo
    rows ``p*stride - pad .. p*stride - pad + k - 1`` (rows outside the
    image are padding and never read) — the k-row read frontier that
    widens the Eq.-(1) safe offset vs the pointwise case."""
    pad = conv_k2d_pad(k, padding)
    reads, writes = [], []
    for p in range(h_out):
        win = sorted({p * stride - pad + r for r in range(k)
                      if 0 <= p * stride - pad + r < h_in})
        reads.append(tuple(win))
        writes.append((p,))
    return RowSchedule(steps=h_out, in_rows=h_in, out_rows=h_out,
                       in_chunk=in_chunk, out_chunk=out_chunk,
                       reads=tuple(reads), writes=tuple(writes))


@_memo
def ib_fused_schedule(h: int, in_chunk: int, out_chunk: int, *, rs: int,
                      residual: bool) -> RowSchedule:
    """The Fig.-6 fused kernel's row schedule (``ring_inverted_bottleneck``):
    step 0 primes the PW1 halo rows ``0..pad``; each later step ``p``
    expands exactly one new input row ``clip(p + pad)``; residual modules
    re-read input row ``p`` at step ``p``."""
    pad = (rs - 1) // 2
    reads, writes = [], []
    for p in range(h):
        if p == 0:
            rows = {min(r, h - 1) for r in range(pad + 1)}
        else:
            rows = {min(max(p + pad, 0), h - 1)}
        if residual:
            rows.add(p)
        reads.append(tuple(sorted(rows)))
        writes.append((p,))
    return RowSchedule(steps=h, in_rows=h, out_rows=h,
                       in_chunk=in_chunk, out_chunk=out_chunk,
                       reads=tuple(reads), writes=tuple(writes))


@_memo
def add_schedule(rows: int, chunk: int, *, aux_chunk: int | None = None
                 ) -> RowSchedule:
    """Residual add: step ``t`` reads row ``t`` of the chained operand AND
    row ``t`` of the held residual source, then writes row ``t``."""
    idx = tuple((t,) for t in range(rows))
    return RowSchedule(steps=rows, in_rows=rows, out_rows=rows,
                       in_chunk=chunk, out_chunk=chunk,
                       reads=idx, writes=idx, aux_reads=idx,
                       aux_rows=rows,
                       aux_chunk=chunk if aux_chunk is None else aux_chunk)


@_memo
def avgpool_schedule(h: int, in_chunk: int, out_chunk: int) -> RowSchedule:
    """Global average pool: reads one image row per step, emits the single
    output row at the last step (after its read)."""
    reads = tuple((t,) for t in range(h))
    writes = tuple(() for _ in range(h - 1)) + ((0,),)
    return RowSchedule(steps=h, in_rows=h, out_rows=1,
                       in_chunk=in_chunk, out_chunk=out_chunk,
                       reads=reads, writes=writes)


@_memo
def conv_stream_schedule(hop: int, h_out: int, in_chunk: int,
                         out_chunk: int) -> RowSchedule:
    """Streaming temporal conv: step 0 consumes the whole ``hop``-row
    frame (shift-append into the ring-resident window state, which is
    tracked as a separate lifetime class, not as chained input); steps
    ``1..h_out`` then write one output row each from the window.  The
    frame is dead before any output write, so delta solves to the
    non-overlap minimum."""
    reads = (tuple(range(hop)),) + ((),) * h_out
    writes = ((),) + tuple((p,) for p in range(h_out))
    return RowSchedule(steps=1 + h_out, in_rows=hop, out_rows=h_out,
                       in_chunk=in_chunk, out_chunk=out_chunk,
                       reads=reads, writes=writes)


@_memo
def gru_cell_schedule(in_chunk: int, out_chunk: int) -> RowSchedule:
    """GRU cell: step 0 reads the single input row (plus the pool-resident
    hidden state, tracked separately); step 1 writes the new hidden row
    to the chained output."""
    return RowSchedule(steps=2, in_rows=1, out_rows=1,
                       in_chunk=in_chunk, out_chunk=out_chunk,
                       reads=((0,), ()), writes=((), (0,)))


@_memo
def gemm_fine_schedule(m: int, k_segs: int, n_segs: int) -> RowSchedule:
    """The paper's Fig.-4 fine-grained FC schedule at row granularity:
    step ``t = r * n_segs + n`` re-reads input row ``r`` (all ``k_segs``
    segments) and writes output segment ``t``; row ``r`` dies at its last
    read ``n == n_segs - 1`` — exactly the order ``run_program_sim``
    replays, so the static verifier shares one source of truth with it."""
    steps = m * n_segs
    reads = tuple((t // n_segs,) for t in range(steps))
    writes = tuple((t,) for t in range(steps))
    return RowSchedule(steps=steps, in_rows=m, out_rows=steps,
                       in_chunk=k_segs, out_chunk=1,
                       reads=reads, writes=writes)


@_memo
def rowwise_schedule(rows: int, d_segs: int) -> RowSchedule:
    """In-place per-row ops (``fused_mlp`` / ``elementwise``): step ``t``
    reads row ``t``, frees it, then writes row ``t`` at delta == 0."""
    idx = tuple((t,) for t in range(rows))
    return RowSchedule(steps=rows, in_rows=rows, out_rows=rows,
                       in_chunk=d_segs, out_chunk=d_segs,
                       reads=idx, writes=idx)


def schedule_for_op(op, seg_width: int, m_rows: int | None = None
                    ) -> RowSchedule:
    """Rebuild the row schedule of a planned :class:`PoolOp` (sim replay).

    ``m_rows`` supplies the program row count for the kinds whose row
    extent defaults to it (``gemm`` / ``fused_mlp`` / ``elementwise``
    with ``rows_in == 0``)."""
    from .vpool import segments_for

    ci = segments_for(op.d_in, seg_width)
    co = segments_for(op.d_out, seg_width)
    if op.kind == "gemm":
        m = op.rows_in or m_rows
        if m is None:
            raise ValueError("gemm schedule needs m_rows")
        return gemm_fine_schedule(m, ci, co)
    if op.kind in ("fused_mlp", "elementwise"):
        m = op.rows_in or m_rows
        if m is None:
            raise ValueError(f"{op.kind} schedule needs m_rows")
        return rowwise_schedule(m, ci)
    if op.kind == "conv_pw":
        return conv_pw_schedule(op.h_in, op.h_out, op.w_in * ci,
                                op.w_out * co, stride=op.stride,
                                resample=op.resample)
    if op.kind == "conv_dw":
        return conv_dw_schedule(op.h_in, op.h_out, op.w_in * ci,
                                op.w_out * co, rs=op.rs, stride=op.stride,
                                padding=op.padding)
    if op.kind == "conv_k2d":
        return conv_k2d_schedule(op.h_in, op.h_out, op.w_in * ci,
                                 op.w_out * co, k=op.rs, stride=op.stride,
                                 padding=op.padding)
    if op.kind == "ib_fused":
        return ib_fused_schedule(op.h_in, op.w_in * ci, op.w_out * co,
                                 rs=op.rs, residual=op.residual)
    if op.kind == "add":
        return add_schedule(op.rows_in, ci)
    if op.kind == "pool_avg":
        return avgpool_schedule(op.h_in, op.w_in * ci, co)
    if op.kind == "conv_stream":
        return conv_stream_schedule(op.hop, op.h_out, op.w_in * ci,
                                    op.w_out * co)
    if op.kind == "gru_cell":
        return gru_cell_schedule(ci, co)
    raise ValueError(f"no row schedule for op kind {op.kind!r}")
