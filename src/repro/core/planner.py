"""Single-layer segment-offset solver — vMCU Eq. (1).

The optimization problem (paper §4):

    min  b_In − b_Out
    s.t. ∀ j ⪯ i (lexicographic):
         L_In·(A_In·i + V_In) + b_In  ≥  L_Out·(A_Out·j + V_Out) + b_Out

Both sides are linear in the iteration point, so with
``r(i) = L_In·(A_In·i+V_In)`` (read address) and ``w(j)`` (write address):

    b_In − b_Out  =  max_{i}  [ max_{j ⪯ i} w(j) ]  −  r(i)

which a single lexicographic scan computes *exactly* in O(|domain|): iterate
points in lex order, keep the running max of ``w``, subtract ``r``.  This is
the ILP of the paper solved in closed form for box domains (the only domains
its kernels use).  Closed-form fast paths for GEMM and conv are derived below
and property-tested against the scan.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .affine import (AccessFn, IterDomain, gemm_domain, gemm_read_access,
                     gemm_write_access)

# Domains larger than this fall back to closed forms / chunked scans.
_SCAN_LIMIT = 50_000_000


def solve_offset_scan(domain: IterDomain, read: AccessFn,
                      write: AccessFn) -> int:
    """Exact minimal ``b_In − b_Out`` via vectorized lexicographic scan."""
    if domain.size > _SCAN_LIMIT:
        raise ValueError(
            f"domain size {domain.size} too large for the exact scan; "
            "use a closed form")
    pts = domain.points_lex()
    r = read.addresses(pts)
    w = write.addresses(pts)
    w_run = np.maximum.accumulate(w)
    return int(np.max(w_run - r))


def solve_offset_bruteforce(domain: IterDomain, read: AccessFn,
                            write: AccessFn) -> int:
    """O(n^2) reference used only in tests on tiny domains."""
    pts = domain.points_lex()
    r = read.addresses(pts)
    w = write.addresses(pts)
    best = -(1 << 62)
    for idx in range(len(pts)):
        best = max(best, int(np.max(w[: idx + 1]) - r[idx]))
    return best


def gemm_offset_closed_form(M: int, N: int, K: int) -> int:
    """delta = max over (m,n,k) of (N−K)·m + n − k  (writes are lex-monotone,
    so the running max is w(i) itself)."""
    m = M - 1 if N > K else 0
    return (N - K) * m + (N - 1)


def gemm_min_footprint_segments(M: int, N: int, K: int) -> int:
    """Paper closed form: ``max(MN, MK) + min(N, K) − 1``."""
    return max(M * N, M * K) + min(N, K) - 1


@dataclasses.dataclass(frozen=True)
class SegmentPlan:
    """Result of planning one kernel over the ring pool.

    ``delta``           minimal b_In − b_Out, in segments (Eq. 1 optimum).
    ``in_segments``     input tensor size in segments.
    ``out_segments``    output tensor size in segments.
    ``pool_segments``   minimal pool size: the span that In ∪ Out occupy.
    ``segment_bytes``   bytes per segment (kernel-specific, vMCU §5.3).
    """

    delta: int
    in_segments: int
    out_segments: int
    segment_bytes: int

    @property
    def pool_segments(self) -> int:
        # In occupies [delta, delta + in_segments); Out occupies
        # [0, out_segments).  Pool must cover the union span.
        lo = min(0, self.delta)
        hi = max(self.delta + self.in_segments, self.out_segments)
        return hi - lo

    @property
    def pool_bytes(self) -> int:
        return self.pool_segments * self.segment_bytes

    @property
    def naive_segments(self) -> int:
        """Tensor-level (TinyEngine-style, non-overlappable layer) footprint."""
        return self.in_segments + self.out_segments

    @property
    def saving_fraction(self) -> float:
        return 1.0 - self.pool_segments / self.naive_segments


def plan_gemm(M: int, N: int, K: int, *, segment_bytes: int,
              validate: bool = False) -> SegmentPlan:
    """Plan a fully-connected layer ``[M,K] @ [K,N]`` (weights in "Flash" —
    i.e. un-pooled read-only storage — exactly as the paper assumes)."""
    delta = gemm_offset_closed_form(M, N, K)
    if validate:
        scan = solve_offset_scan(gemm_domain(M, N, K),
                                 gemm_read_access(M, K),
                                 gemm_write_access(M, N))
        if scan != delta:
            raise AssertionError(
                f"GEMM closed form {delta} != exact scan {scan} "
                f"for M={M} N={N} K={K}")
    plan = SegmentPlan(delta=delta, in_segments=M * K, out_segments=M * N,
                       segment_bytes=segment_bytes)
    expected = gemm_min_footprint_segments(M, N, K)
    if plan.pool_segments != expected:
        raise AssertionError(
            f"pool size {plan.pool_segments} != paper closed form {expected}")
    return plan


def plan_affine(domain: IterDomain, read: AccessFn, write: AccessFn, *,
                segment_bytes: int) -> SegmentPlan:
    """Plan an arbitrary affine kernel via the exact scan."""
    delta = solve_offset_scan(domain, read, write)
    return SegmentPlan(delta=delta, in_segments=read.size,
                       out_segments=write.size, segment_bytes=segment_bytes)


def plan_pointwise_conv(H: int, W: int, C: int, K: int, *, stride: int = 1,
                        elem_bytes: int = 1) -> SegmentPlan:
    """Plan a 1x1 convolution ``[H,W,C] -> [P,Q,K]``.

    With segment = one channel vector (vMCU §5.3 picks segment size =
    min(C, K) elements; we keep one segment per pixel per tensor and fold the
    channel width into ``segment_bytes`` bookkeeping by planning at pixel
    granularity with the *byte* sizes handled by the caller).  At stride 1 a
    pointwise conv over pixels is exactly GEMM with M = H·W rows, K = 1 input
    segment per row, N = 1 output segment per row — but input and output
    segments differ in byte width (C vs K elements), so we plan in *bytes*
    via the generalized scan below.
    """
    P, Q = (H - 1) // stride + 1, (W - 1) // stride + 1
    seg = min(C, K) * elem_bytes  # vMCU §5.3 segment choice
    in_segs_per_pixel = -(-C * elem_bytes // seg)
    out_segs_per_pixel = -(-K * elem_bytes // seg)
    # Iteration: one step per output pixel (p, q); reads input pixel
    # (p*stride, q*stride) [the *last* tap it needs in row-major order is the
    # same pixel for 1x1 conv]; writes output pixel (p, q).
    domain = IterDomain((P, Q))
    read = AccessFn(A=((stride, 0), (0, stride)), V=(0, 0), shape=(H, W))
    write = AccessFn(A=((1, 0), (0, 1)), V=(0, 0), shape=(P, Q))
    pts = domain.points_lex()
    # Addresses in *bytes*: pixel-granular accesses scaled by per-pixel widths.
    r = read.addresses(pts) * (C * elem_bytes)
    w = write.addresses(pts) * (K * elem_bytes)
    # A read of pixel x means bytes [x*C, (x+1)*C) must still be intact; a
    # write of pixel y covers [y*K, (y+1)*K). Safety: write_end <= read_start
    # + (b_In - b_Out)  for all j <= i  =>  delta >= max(w_end - r_start).
    w_end = w + K * elem_bytes
    w_run = np.maximum.accumulate(w_end)
    delta_bytes = int(np.max(w_run - r))
    return SegmentPlan(delta=-(-delta_bytes // seg),
                       in_segments=H * W * in_segs_per_pixel,
                       out_segments=P * Q * out_segs_per_pixel,
                       segment_bytes=seg)


def motivational_example() -> tuple[int, int]:
    """Paper Fig. 1(c): FC layer with In = 2x3 segments, Out = 2x2 segments.
    Returns (segment_level_pool, tensor_level_pool) = (7, 10)."""
    plan = plan_gemm(2, 2, 3, segment_bytes=1, validate=True)
    return plan.pool_segments, plan.naive_segments
