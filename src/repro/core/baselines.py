"""Tensor-level memory-management baselines the paper compares against.

* TinyEngine-style: in-place overlap ONLY when the whole tensors may legally
  alias (depthwise / elementwise); otherwise disjoint input+output buffers.
* HMCOS/Serenity-style: execution-order scheduling only, never in-place; for
  the linear-structure layers evaluated here scheduling buys nothing, so the
  footprint is always input + output (+ workspace).

Both are deliberately simple — the paper's point is precisely that these
policies leave partial overlap on the table for FC / non-depthwise conv.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class LayerShape:
    """A single layer at byte granularity."""

    name: str
    in_bytes: int
    out_bytes: int
    inplace_legal: bool = False  # depthwise / elementwise
    workspace_bytes: int = 0     # e.g. im2col buffers


def tinyengine_bytes(layer: LayerShape) -> int:
    if layer.inplace_legal:
        return max(layer.in_bytes, layer.out_bytes) + layer.workspace_bytes
    return layer.in_bytes + layer.out_bytes + layer.workspace_bytes


def hmcos_bytes(layer: LayerShape) -> int:
    return layer.in_bytes + layer.out_bytes + layer.workspace_bytes


def pointwise_conv_layer(h: int, c: int, k: int, *, elem_bytes: int = 1,
                         im2col: bool = False) -> LayerShape:
    """Pointwise conv as evaluated in paper Fig. 7 (H/W, C, K named cases).
    TinyEngine runs im2col even for 1x1 convs (paper §7.2) — modeled as a
    one-row patch workspace when ``im2col`` is set."""
    ws = c * elem_bytes * h if im2col else 0
    return LayerShape(
        name=f"H/W{h},C{c},K{k}",
        in_bytes=h * h * c * elem_bytes,
        out_bytes=h * h * k * elem_bytes,
        inplace_legal=False,
        workspace_bytes=ws,
    )


# The nine single-layer cases of paper Fig. 7/8.
FIG7_CASES = [
    (80, 16, 16),
    (40, 32, 32),
    (20, 64, 64),
    (20, 64, 32),
    (20, 32, 64),
    (10, 128, 128),
    (10, 128, 64),
    (10, 64, 128),
    (5, 256, 256),
]
