"""Pluggable executors: run the SAME PoolProgram on interchangeable backends.

  * ``sim``    — drives the byte-exact :class:`SegmentPool` clobber oracle
                 with the paper-faithful fine-grained schedule (Fig. 4);
                 raises :class:`PoolClobberError` iff the plan is unsafe.
  * ``jnp``    — jit-able modular-indexing scans (the ring_buffer path);
                 runs on any backend, any seg_width, aligned or not.
  * ``pallas`` — the TPU ring kernels (segment_matmul / fused_mlp /
                 elementwise); requires an aligned program
                 (``block_rows`` set) and ``seg_width == SEG_WIDTH``.

``jnp`` and ``pallas`` produce allclose results from one plan object; the
``sim`` backend proves the plan clobber-free.  New backends register with
:func:`register_executor` (DESIGN.md §4).
"""
from __future__ import annotations

import functools
import time
from typing import Callable

import jax
import jax.numpy as jnp

from .pool import SegmentPool
from .program import (EXECUTABLE_KINDS, PoolProgram, resolve_activation)
from .vpool import (VirtualPool, fetch_rows, fetch_segments, segments_for,
                    stage_rows, stage_segments)

# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------

_EXECUTORS: dict[str, Callable] = {}


def register_executor(name: str):
    """Register ``fn(program, pool, params, **kw)`` as backend ``name``."""
    def deco(fn):
        _EXECUTORS[name] = fn
        return fn
    return deco


def executor_names() -> tuple[str, ...]:
    return tuple(sorted(_EXECUTORS))


def execute(program: PoolProgram, pool=None, params=None, *,
            backend: str = "jnp", **kwargs):
    """Run ``program`` on ``backend``.

    ``pool`` is a :class:`VirtualPool` (or raw ``[n_segments, seg_width]``
    array) with the program input already staged at ``program.input_ptr``;
    ``params`` is one entry per op — ``(w, b)`` for gemm (``b`` may be
    None), ``(w_gate, w_up, w_down)`` for fused_mlp, ``None`` for
    elementwise.  Returns the updated pool handle (``sim`` ignores
    pool/params and returns the SegmentPool with its access statistics).
    """
    try:
        fn = _EXECUTORS[backend]
    except KeyError:
        raise ValueError(f"unknown backend {backend!r}; registered: "
                         f"{executor_names()}") from None
    if not program.executable:
        raise NotImplementedError(
            f"program contains plan-only ops "
            f"({[op.kind for op in program.ops]}); only kinds "
            f"{EXECUTABLE_KINDS} are executable")
    return fn(program, pool, params, **kwargs)


def run_program(program: PoolProgram, x: jax.Array, params, *,
                backend: str = "jnp", **kwargs):
    """Convenience: alloc a pool, stage ``x``, execute, fetch the output.

    Returns ``(y, pool)``.  Array backends only (use ``execute`` with
    ``backend="sim"`` for the oracle)."""
    pool = VirtualPool.alloc(program.spec(x.dtype))
    pool = pool.stage_rows(x, program.input_ptr)
    pool = execute(program, pool, params, backend=backend, **kwargs)
    y = pool.fetch_rows(program.output_ptr, program.out_rows,
                        program.out_dim)
    return y, pool


def _normalize_qparams(program: PoolProgram, params):
    """Validate int8 param entries — see DESIGN.md §8.

    ``(w_q, b_q, mult, shift)`` for gemm/conv (int8 weight, int32 bias at
    the accumulator scale, per-channel requant pair), ``(mult_in,
    shift_in, mult_aux, shift_aux)`` for add, ``(mult, shift)`` for
    pool_avg.
    """
    if params is None:
        raise ValueError("quantized programs need explicit qparams "
                         "(see graph.run.quantize_net)")
    params = list(params)
    if len(params) != len(program.ops):
        raise ValueError(f"{len(params)} qparam entries for "
                         f"{len(program.ops)} ops")
    out = []
    for op, p in zip(program.ops, params):
        if op.kind in ("gemm", "conv_pw", "conv_dw", "conv_k2d",
                       "conv_stream"):
            w, b, mult, shift = p
            if b is None:
                b = jnp.zeros((op.d_out,), jnp.int32)
            out.append((w, b, mult, shift))
        elif op.kind == "gru_cell":
            # (w_q, u_q, b_q12, mult_x, shift_x, mult_u, shift_u):
            # int8 input/recurrent weights, Q12 bias, per-channel requant
            # pairs taking both accumulators to the Q12 gate domain
            w, u, b, mx, sx, mu, su = p
            if b is None:
                b = jnp.zeros((3 * op.d_out,), jnp.int32)
            out.append((w, u, b, mx, sx, mu, su))
        elif op.kind in ("add", "pool_avg"):
            out.append(tuple(p))
        else:
            raise NotImplementedError(
                f"op kind {op.kind!r} has no int8 execution path — lower "
                "the net with fused_exec=False (repro.compile does for "
                "int8 targets)")
    return out


def _normalize_params(program: PoolProgram, params):
    if program.quantized:
        return _normalize_qparams(program, params)
    if params is None:
        params = [None] * len(program.ops)
    params = list(params)
    if len(params) != len(program.ops):
        raise ValueError(f"{len(params)} param entries for "
                         f"{len(program.ops)} ops")
    out = []
    for op, p in zip(program.ops, params):
        if op.kind in ("gemm", "conv_pw", "conv_dw", "conv_k2d",
                       "conv_stream"):
            w, b = p
            if b is None:
                b = jnp.zeros((op.d_out,), w.dtype)
            out.append((w, b))
        elif op.kind == "gru_cell":
            w, u, b = p
            if b is None:
                b = jnp.zeros((3 * op.d_out,), w.dtype)
            out.append((w, u, b))
        elif op.kind == "fused_mlp":
            wg, wu, wd = p
            if wg is None:  # ungated MLPs may omit the gate projection
                wg = wu
            out.append((wg, wu, wd))
        elif op.kind == "ib_fused":
            w1, wd, w2 = p
            out.append((w1, wd, w2))
        else:
            if p is not None:
                raise ValueError(f"{op.kind} op takes no params")
            out.append(None)
    return out


def _as_array(pool):
    return pool.array if isinstance(pool, VirtualPool) else pool


def _like_input(pool, array):
    return VirtualPool(array) if isinstance(pool, VirtualPool) else array


# ---------------------------------------------------------------------------
# jnp backend — shared with ring_buffer's chain apply.
# ---------------------------------------------------------------------------

def gemm_ring_scan(pool: jax.Array, w: jax.Array, b: jax.Array, *,
                   in_ptr: int, out_ptr: int, m_rows: int, n_segments: int,
                   block_rows: int, activation: str | None) -> jax.Array:
    """One FC layer streamed through the ring as a coalesced superblock.

    The jnp mirror of the Pallas ring-GEMM (paper Fig. 4): gather the
    input segments at the modular index, MXU-dot against the un-pooled
    ("Flash") weight in fp32, scatter the output rows at the solved
    offset.  ``block_rows`` is the plan's DMA alignment (it must divide
    ``m_rows``); execution coalesces all row-blocks into ONE
    gather/compute/scatter, which DESIGN.md §15 proves bit-identical to
    the certified per-step schedule.
    """
    d_in, d_out = w.shape
    if m_rows % block_rows:
        raise ValueError("block_rows must divide m_rows")
    act = resolve_activation(activation)
    # Superblock coalescing: the certified schedule proves a store at step
    # t only lands on segments already freed (never read at any step >= t),
    # so gathering EVERY input row before the first store reads exactly the
    # bytes the per-step scan would have read, and the store targets are
    # pairwise distinct — one fetch/dot/stage replaces the whole scan.
    x = fetch_rows(pool, in_ptr, m_rows, d_in, n_segments)
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    y = act(y + b.astype(jnp.float32)).astype(pool.dtype)
    return stage_rows(pool, y, out_ptr, n_segments)


def mlp_ring_scan(pool: jax.Array, w_gate, w_up, w_down, *, ptr: int,
                  m_rows: int, n_segments: int, block_rows: int,
                  d_model: int, ff_tile: int, gated: bool, residual: bool,
                  activation: str) -> jax.Array:
    """In-place fused MLP, mirroring the Pallas kernel's per-``ff_tile``
    accumulation order so the two backends agree to float tolerance."""
    d_ff = w_up.shape[1]
    act = resolve_activation(activation)
    # In-place op (delta == 0): every row's output depends only on that
    # row's input and lands on the segments it was read from, so the
    # per-row-block scan coalesces into one fetch/compute/stage.
    x = fetch_rows(pool, ptr, m_rows, d_model,
                   n_segments).astype(jnp.float32)
    acc = jnp.zeros((m_rows, d_model), jnp.float32)
    for f in range(d_ff // ff_tile):
        sl = slice(f * ff_tile, (f + 1) * ff_tile)
        up = jnp.dot(x, w_up[:, sl].astype(jnp.float32),
                     preferred_element_type=jnp.float32)
        if gated:
            gate = jnp.dot(x, w_gate[:, sl].astype(jnp.float32),
                           preferred_element_type=jnp.float32)
            h = act(gate) * up
        else:
            h = act(up)
        acc = acc + jnp.dot(h, w_down[sl, :].astype(jnp.float32),
                            preferred_element_type=jnp.float32)
    y = acc + x if residual else acc
    return stage_rows(pool, y.astype(pool.dtype), ptr, n_segments)


def elementwise_ring_scan(pool: jax.Array, *, ptr: int, m_rows: int,
                          n_segments: int, block_rows: int, d: int,
                          fn: str) -> jax.Array:
    """In-place element-wise map over resident rows (applied to the whole
    padded tile — every registered fn maps 0 to 0, preserving padding)."""
    seg_w = pool.shape[1]
    d_segs = segments_for(d, seg_w)
    f = resolve_activation(fn)
    # In-place, row-local (delta == 0): coalesce the whole scan.
    x = fetch_segments(pool, ptr, m_rows * d_segs,
                       n_segments).astype(jnp.float32)
    return stage_segments(pool, f(x).astype(pool.dtype), ptr, n_segments)


# ---------------------------------------------------------------------------
# jnp whole-network ops: gather rows (modular) -> fp32 math -> scatter.
# The interleaved ring schedule is certified by the sim backend; here the
# full gather happens before the scatter, which is numerically identical.
# ---------------------------------------------------------------------------

def _pw_maps(op) -> tuple[list[int], list[int]]:
    """Static source row/col index maps of a conv_pw op (the ONE
    resample map lives in ``core.rowsched``)."""
    from .rowsched import resample_src

    if op.resample:
        ridx = [resample_src(p, op.h_in, op.h_out)
                for p in range(op.h_out)]
        cidx = [resample_src(q, op.w_in, op.w_out)
                for q in range(op.w_out)]
    else:
        ridx = [p * op.stride for p in range(op.h_out)]
        cidx = [q * op.stride for q in range(op.w_out)]
    return ridx, cidx


def _image_ptr(pool, op) -> int:
    """Effective base pointer of the op's input image — the source base
    advanced past the rows below the slice window (``in_row0``; 0 for
    every unsliced op)."""
    if not op.in_row0:
        return op.in_ptr
    return op.in_ptr + op.in_row0 * op.w_in * segments_for(op.d_in,
                                                           pool.shape[1])


def _conv_pads(op) -> tuple[int, int, int, int]:
    """Exact ``(pad_t, pad_b, pad_l, pad_r)`` of a dw / k2d conv — the
    minimal zero border such that every tap's strided slice is in
    bounds.  Identical maths for every padding mode (same / valid /
    same_top / same_mid); for the legacy modes it selects the same
    elements as the previous generous symmetric padding."""
    from .rowsched import conv_k2d_pad, conv_k2d_pad_w

    pad_t = conv_k2d_pad(op.rs, op.padding)
    pad_l = conv_k2d_pad_w(op.rs, op.padding)
    pad_b = max(0, op.stride * (op.h_out - 1) + op.rs - pad_t - op.h_in)
    pad_r = max(0, op.stride * (op.w_out - 1) + op.rs - pad_l - op.w_in)
    return pad_t, pad_b, pad_l, pad_r


def _fetch_image(pool, op, n):
    rows = op.rows_in
    x = fetch_rows(pool, _image_ptr(pool, op), rows, op.d_in, n)
    return x.reshape(op.h_in, op.w_in, op.d_in).astype(jnp.float32)


def _store_image(pool, op, img, n):
    y = img.reshape(op.rows_out, op.d_out).astype(pool.dtype)
    return stage_rows(pool, y, op.out_ptr, n)


def conv_pw_ring(pool, w, b, *, op, n_segments):
    img = _fetch_image(pool, op, n_segments)
    ridx, cidx = _pw_maps(op)
    sub = img[jnp.array(ridx)][:, jnp.array(cidx)]
    y = jnp.einsum("hwc,cd->hwd", sub, w.astype(jnp.float32))
    y = resolve_activation(op.activation)(y + b.astype(jnp.float32))
    return _store_image(pool, op, y, n_segments)


def conv_dw_ring(pool, w, b, *, op, n_segments):
    img = _fetch_image(pool, op, n_segments)
    pad_t, pad_b, pad_l, pad_r = _conv_pads(op)
    s = op.stride
    padded = jnp.pad(img, ((pad_t, pad_b), (pad_l, pad_r), (0, 0)))
    acc = jnp.zeros((op.h_out, op.w_out, op.d_in), jnp.float32)
    for r in range(op.rs):
        for c in range(op.rs):
            tap = padded[r:r + s * (op.h_out - 1) + 1:s,
                         c:c + s * (op.w_out - 1) + 1:s]
            acc = acc + tap * w[r, c].astype(jnp.float32)[None, None]
    y = resolve_activation(op.activation)(acc + b.astype(jnp.float32))
    return _store_image(pool, op, y, n_segments)


def conv_k2d_ring(pool, w, b, *, op, n_segments):
    """General k x k conv: ``w`` is ``[k, k, c_in, c_out]``."""
    img = _fetch_image(pool, op, n_segments)
    pad_t, pad_b, pad_l, pad_r = _conv_pads(op)
    s = op.stride
    padded = jnp.pad(img, ((pad_t, pad_b), (pad_l, pad_r), (0, 0)))
    acc = jnp.zeros((op.h_out, op.w_out, op.d_out), jnp.float32)
    for r in range(op.rs):
        for c in range(op.rs):
            tap = padded[r:r + s * (op.h_out - 1) + 1:s,
                         c:c + s * (op.w_out - 1) + 1:s]
            acc = acc + jnp.einsum("hwc,cd->hwd", tap,
                                   w[r, c].astype(jnp.float32))
    y = resolve_activation(op.activation)(acc + b.astype(jnp.float32))
    return _store_image(pool, op, y, n_segments)


def ib_fused_ring(pool, w1, wd, w2, *, op, n_segments):
    """Fused inverted bottleneck, same math as
    ``kernels.inverted_bottleneck.inverted_bottleneck_ref`` (stride 1,
    'same' padding, ReLU after PW1 and DW)."""
    a = _fetch_image(pool, op, n_segments)
    h, w = op.h_in, op.w_in
    rs, pad = op.rs, (op.rs - 1) // 2
    bexp = jnp.maximum(jnp.einsum("hwc,cm->hwm", a,
                                  w1.astype(jnp.float32)), 0.0)
    bp = jnp.pad(bexp, ((pad, pad), (pad, pad), (0, 0)))
    cacc = sum(bp[r:r + h, s:s + w] * wd[r, s].astype(jnp.float32)[None,
                                                                   None]
               for r in range(rs) for s in range(rs))
    cacc = jnp.maximum(cacc, 0.0)
    e = jnp.einsum("hwm,mo->hwo", cacc, w2.astype(jnp.float32))
    if op.residual:
        e = e + a
    return _store_image(pool, op, e, n_segments)


def add_ring(pool, *, op, n_segments):
    x = fetch_rows(pool, op.in_ptr, op.rows_in, op.d_in, n_segments)
    res = fetch_rows(pool, op.aux_ptr, op.rows_in, op.d_in, n_segments)
    y = resolve_activation(op.activation)(
        x.astype(jnp.float32) + res.astype(jnp.float32)).astype(pool.dtype)
    return stage_rows(pool, y, op.out_ptr, n_segments)


def pool_avg_ring(pool, *, op, n_segments):
    img = _fetch_image(pool, op, n_segments)
    y = jnp.mean(img, axis=(0, 1), keepdims=False)[None, :]
    return stage_rows(pool, y.astype(pool.dtype), op.out_ptr, n_segments)


# -- streaming ops: ring-resident state shifted in place (repro.stream) ----

def _shift_window(pool, op, n):
    """conv_stream state update: fetch the ring-resident ``h_win x w_in``
    window at ``state_ptr``, drop the oldest ``hop`` image rows, append
    the staged frame, and write the shifted window back to the state
    region (same dtype — the writeback is exact for int8 pools).
    Returns ``(pool, window_rows)``."""
    wrows = op.h_in * op.w_in
    state = fetch_rows(pool, op.state_ptr, wrows, op.d_in, n)
    frame = fetch_rows(pool, op.in_ptr, op.rows_in, op.d_in, n)
    win = jnp.concatenate([state[op.hop * op.w_in:], frame], axis=0)
    return stage_rows(pool, win, op.state_ptr, n), win


def conv_stream_ring(pool, w, b, *, op, n_segments):
    """Sliding-window temporal conv: one per-frame step = state shift +
    append + full ``k x k`` conv over the window (``w`` is
    ``[k, k, c_in, c_out]``, exactly a conv_k2d over ``h_win x w_in``)."""
    pool, win = _shift_window(pool, op, n_segments)
    img = win.reshape(op.h_in, op.w_in, op.d_in).astype(jnp.float32)
    pad_t, pad_b, pad_l, pad_r = _conv_pads(op)
    s = op.stride
    padded = jnp.pad(img, ((pad_t, pad_b), (pad_l, pad_r), (0, 0)))
    acc = jnp.zeros((op.h_out, op.w_out, op.d_out), jnp.float32)
    for r in range(op.rs):
        for c in range(op.rs):
            tap = padded[r:r + s * (op.h_out - 1) + 1:s,
                         c:c + s * (op.w_out - 1) + 1:s]
            acc = acc + jnp.einsum("hwc,cd->hwd", tap,
                                   w[r, c].astype(jnp.float32))
    y = resolve_activation(op.activation)(acc + b.astype(jnp.float32))
    return _store_image(pool, op, y, n_segments)


def gru_cell_ring(pool, w, u, b, *, op, n_segments):
    """Gated recurrence: hidden state is the pool-resident row at
    ``state_ptr``; the updated state is written back AND chained at
    ``out_ptr`` (gate math: :func:`repro.quant.requant.gru_update`)."""
    from ..quant.requant import gru_update

    x = fetch_rows(pool, op.in_ptr, 1, op.d_in,
                   n_segments).astype(jnp.float32)
    h = fetch_rows(pool, op.state_ptr, 1, op.d_out,
                   n_segments).astype(jnp.float32)
    gx = jnp.dot(x, w.astype(jnp.float32),
                 preferred_element_type=jnp.float32) + b.astype(jnp.float32)
    gh = jnp.dot(h, u.astype(jnp.float32),
                 preferred_element_type=jnp.float32)
    hp = gru_update(gx, gh, h, op.d_out).astype(pool.dtype)
    pool = stage_rows(pool, hp, op.state_ptr, n_segments)
    return stage_rows(pool, hp, op.out_ptr, n_segments)


# ---------------------------------------------------------------------------
# jnp int8 ops: int8 gather -> int32 accumulate -> fixed-point requantize
# on store.  Geometry (and therefore the sim certificate) is identical to
# the fp32 path; only the element arithmetic changes (DESIGN.md §8).
# ---------------------------------------------------------------------------

def _q_act(acc, activation):
    """Int32-domain activation — the one shared definition
    (:func:`repro.quant.requant.act_i32`)."""
    from ..quant.requant import act_i32

    return act_i32(acc, activation)


def _fetch_image_q(pool, op, n):
    x = fetch_rows(pool, _image_ptr(pool, op), op.rows_in, op.d_in, n)
    return x.reshape(op.h_in, op.w_in, op.d_in).astype(jnp.int32)


def conv_pw_ring_q(pool, w, b, mult, shift, *, op, n_segments):
    from ..quant.requant import requantize

    img = _fetch_image_q(pool, op, n_segments)
    ridx, cidx = _pw_maps(op)
    sub = img[jnp.array(ridx)][:, jnp.array(cidx)]
    acc = jnp.einsum("hwc,cd->hwd", sub, w.astype(jnp.int32))
    acc = _q_act(acc + b.astype(jnp.int32), op.activation)
    q = requantize(acc, mult[None, None, :], shift[None, None, :])
    return _store_image(pool, op, q, n_segments)


def conv_k2d_ring_q(pool, w, b, mult, shift, *, op, n_segments):
    """Int8 k x k conv: int32 accumulate over every tap, per-channel
    requantize on store (zero padding is exact — symmetric quantization
    keeps the zero point at 0)."""
    from ..quant.requant import requantize

    img = _fetch_image_q(pool, op, n_segments)
    pad_t, pad_b, pad_l, pad_r = _conv_pads(op)
    s = op.stride
    padded = jnp.pad(img, ((pad_t, pad_b), (pad_l, pad_r), (0, 0)))
    acc = jnp.zeros((op.h_out, op.w_out, op.d_out), jnp.int32)
    for r in range(op.rs):
        for c in range(op.rs):
            tap = padded[r:r + s * (op.h_out - 1) + 1:s,
                         c:c + s * (op.w_out - 1) + 1:s]
            acc = acc + jnp.einsum("hwc,cd->hwd", tap,
                                   w[r, c].astype(jnp.int32))
    acc = _q_act(acc + b.astype(jnp.int32), op.activation)
    q = requantize(acc, mult[None, None, :], shift[None, None, :])
    return _store_image(pool, op, q, n_segments)


def conv_dw_ring_q(pool, w, b, mult, shift, *, op, n_segments):
    from ..quant.requant import requantize

    img = _fetch_image_q(pool, op, n_segments)
    pad_t, pad_b, pad_l, pad_r = _conv_pads(op)
    s = op.stride
    padded = jnp.pad(img, ((pad_t, pad_b), (pad_l, pad_r), (0, 0)))
    acc = jnp.zeros((op.h_out, op.w_out, op.d_in), jnp.int32)
    for r in range(op.rs):
        for c in range(op.rs):
            tap = padded[r:r + s * (op.h_out - 1) + 1:s,
                         c:c + s * (op.w_out - 1) + 1:s]
            acc = acc + tap * w[r, c].astype(jnp.int32)[None, None]
    acc = _q_act(acc + b.astype(jnp.int32), op.activation)
    q = requantize(acc, mult[None, None, :], shift[None, None, :])
    return _store_image(pool, op, q, n_segments)


def gemm_ring_scan_q(pool, w, b, mult, shift, *, in_ptr, out_ptr, m_rows,
                     n_segments, block_rows, d_in, d_out, activation):
    from ..quant.requant import requantize

    # Coalesced like the fp32 path (DESIGN.md §15); integer math makes
    # the equivalence exact at every element.
    x = fetch_rows(pool, in_ptr, m_rows, d_in,
                   n_segments).astype(jnp.int32)
    acc = jnp.dot(x, w.astype(jnp.int32), preferred_element_type=jnp.int32)
    acc = _q_act(acc + b.astype(jnp.int32), activation)
    y = requantize(acc, mult[None, :], shift[None, :])
    return stage_rows(pool, y, out_ptr, n_segments)


def add_ring_q(pool, mult_in, shift_in, mult_aux, shift_aux, *, op,
               n_segments):
    """Residual add with both operands rescaled to the output scale:
    ``sat8(rq(x, s_x/s_o) + rq(res, s_r/s_o))`` — CMSIS-NN's elementwise
    -add form (each operand requantized once, sum clamped)."""
    from ..quant.requant import requantize_i32

    x = fetch_rows(pool, op.in_ptr, op.rows_in, op.d_in, n_segments)
    res = fetch_rows(pool, op.aux_ptr, op.rows_in, op.d_in, n_segments)
    ya = requantize_i32(x.astype(jnp.int32), mult_in, shift_in)
    yb = requantize_i32(res.astype(jnp.int32), mult_aux, shift_aux)
    acc = _q_act(ya + yb, op.activation)   # post-add relu (int32 domain)
    q = jnp.clip(acc, -128, 127).astype(jnp.int8)
    return stage_rows(pool, q, op.out_ptr, n_segments)


def pool_avg_ring_q(pool, mult, shift, *, op, n_segments):
    """Global average pool: int32 SUM over the window, the ``1/(h*w)``
    folded into the requant multiplier."""
    from ..quant.requant import requantize

    img = _fetch_image_q(pool, op, n_segments)
    acc = jnp.sum(img, axis=(0, 1))[None, :]
    q = requantize(acc, mult, shift)
    return stage_rows(pool, q, op.out_ptr, n_segments)


def conv_stream_ring_q(pool, w, b, mult, shift, *, op, n_segments):
    """Int8 sliding-window conv: the state shift/writeback is a pure int8
    copy (exact), the conv is the conv_k2d int32-accumulate pipeline."""
    from ..quant.requant import requantize

    pool, win = _shift_window(pool, op, n_segments)
    img = win.reshape(op.h_in, op.w_in, op.d_in).astype(jnp.int32)
    pad_t, pad_b, pad_l, pad_r = _conv_pads(op)
    s = op.stride
    padded = jnp.pad(img, ((pad_t, pad_b), (pad_l, pad_r), (0, 0)))
    acc = jnp.zeros((op.h_out, op.w_out, op.d_out), jnp.int32)
    for r in range(op.rs):
        for c in range(op.rs):
            tap = padded[r:r + s * (op.h_out - 1) + 1:s,
                         c:c + s * (op.w_out - 1) + 1:s]
            acc = acc + jnp.einsum("hwc,cd->hwd", tap,
                                   w[r, c].astype(jnp.int32))
    acc = _q_act(acc + b.astype(jnp.int32), op.activation)
    q = requantize(acc, mult[None, None, :], shift[None, None, :])
    return _store_image(pool, op, q, n_segments)


def gru_cell_ring_q(pool, w, u, b, mx, sx, mu, su, *, op, n_segments):
    """Int8 GRU cell, CMSIS-NN discipline: both matmul accumulators are
    requantized to the Q12 gate domain, the update runs the shared
    fixed-point pipeline (:func:`repro.quant.requant.gru_update_q12`),
    and the hidden state stays at the FIXED Q7 scale 1/128 — fully
    integer, so jnp and Pallas agree bitwise."""
    from ..quant.requant import gru_update_q12, requantize_i32

    x = fetch_rows(pool, op.in_ptr, 1, op.d_in, n_segments)
    h = fetch_rows(pool, op.state_ptr, 1, op.d_out, n_segments)
    gx = requantize_i32(
        jnp.dot(x.astype(jnp.int32), w.astype(jnp.int32),
                preferred_element_type=jnp.int32), mx, sx)
    gx = gx + b.astype(jnp.int32)
    gh = requantize_i32(
        jnp.dot(h.astype(jnp.int32), u.astype(jnp.int32),
                preferred_element_type=jnp.int32), mu, su)
    hp = gru_update_q12(gx, gh, h, op.d_out)
    pool = stage_rows(pool, hp, op.state_ptr, n_segments)
    return stage_rows(pool, hp, op.out_ptr, n_segments)


def _apply_op_q(pool: jax.Array, op, p, *, n: int, br: int,
                rows: int) -> jax.Array:
    """Apply ONE int8 op — the loop body shared by the whole-program jit
    and the per-op traced path (same jaxpr either way)."""
    if op.kind == "gemm":
        w, b, mult, shift = p
        return gemm_ring_scan_q(pool, w, b, mult, shift,
                                in_ptr=op.in_ptr, out_ptr=op.out_ptr,
                                m_rows=rows, n_segments=n,
                                block_rows=br, d_in=op.d_in,
                                d_out=op.d_out,
                                activation=op.activation)
    if op.kind == "conv_pw":
        w, b, mult, shift = p
        return conv_pw_ring_q(pool, w, b, mult, shift, op=op,
                              n_segments=n)
    if op.kind == "conv_dw":
        w, b, mult, shift = p
        return conv_dw_ring_q(pool, w, b, mult, shift, op=op,
                              n_segments=n)
    if op.kind == "conv_k2d":
        w, b, mult, shift = p
        return conv_k2d_ring_q(pool, w, b, mult, shift, op=op,
                               n_segments=n)
    if op.kind == "add":
        mi, si, ma, sa = p
        return add_ring_q(pool, mi, si, ma, sa, op=op, n_segments=n)
    if op.kind == "pool_avg":
        mult, shift = p
        return pool_avg_ring_q(pool, mult, shift, op=op, n_segments=n)
    if op.kind == "conv_stream":
        w, b, mult, shift = p
        return conv_stream_ring_q(pool, w, b, mult, shift, op=op,
                                  n_segments=n)
    if op.kind == "gru_cell":
        w, u, b, mx, sx, mu, su = p
        return gru_cell_ring_q(pool, w, u, b, mx, sx, mu, su, op=op,
                               n_segments=n)
    raise NotImplementedError(f"no int8 jnp path for {op.kind}")


def _run_jnp_q(pool: jax.Array, params, program: PoolProgram) -> jax.Array:
    br = program.block_rows or 1
    n = program.n_segments
    for op, p in zip(program.ops, params):
        rows = op.rows_in or program.m_rows
        pool = _apply_op_q(pool, op, p, n=n, br=br, rows=rows)
    return pool


def _apply_op(pool: jax.Array, op, p, *, n: int, br: int,
              rows: int) -> jax.Array:
    """Apply ONE fp32 op — see :func:`_apply_op_q`."""
    if op.kind == "gemm":
        w, b = p
        return gemm_ring_scan(pool, w, b, in_ptr=op.in_ptr,
                              out_ptr=op.out_ptr, m_rows=rows,
                              n_segments=n, block_rows=br,
                              activation=op.activation)
    if op.kind == "fused_mlp":
        wg, wu, wd = p
        return mlp_ring_scan(pool, wg, wu, wd, ptr=op.in_ptr,
                             m_rows=rows, n_segments=n,
                             block_rows=br, d_model=op.d_in,
                             ff_tile=op.ff_tile, gated=op.gated,
                             residual=op.residual,
                             activation=op.activation)
    if op.kind == "elementwise":
        return elementwise_ring_scan(pool, ptr=op.in_ptr, m_rows=rows,
                                     n_segments=n, block_rows=br,
                                     d=op.d_in, fn=op.activation)
    if op.kind == "conv_pw":
        w, b = p
        return conv_pw_ring(pool, w, b, op=op, n_segments=n)
    if op.kind == "conv_dw":
        w, b = p
        return conv_dw_ring(pool, w, b, op=op, n_segments=n)
    if op.kind == "conv_k2d":
        w, b = p
        return conv_k2d_ring(pool, w, b, op=op, n_segments=n)
    if op.kind == "ib_fused":
        w1, wd, w2 = p
        return ib_fused_ring(pool, w1, wd, w2, op=op, n_segments=n)
    if op.kind == "add":
        return add_ring(pool, op=op, n_segments=n)
    if op.kind == "pool_avg":
        return pool_avg_ring(pool, op=op, n_segments=n)
    if op.kind == "conv_stream":
        w, b = p
        return conv_stream_ring(pool, w, b, op=op, n_segments=n)
    if op.kind == "gru_cell":
        w, u, b = p
        return gru_cell_ring(pool, w, u, b, op=op, n_segments=n)
    raise NotImplementedError(op.kind)


@functools.partial(jax.jit, static_argnames=("program",),
                   donate_argnums=(0,))
def _run_jnp(pool: jax.Array, params, program: PoolProgram) -> jax.Array:
    br = program.block_rows or 1
    n = program.n_segments
    if program.quantized:
        return _run_jnp_q(pool, params, program)
    for op, p in zip(program.ops, params):
        rows = op.rows_in or program.m_rows
        pool = _apply_op(pool, op, p, n=n, br=br, rows=rows)
    return pool


@functools.partial(jax.jit, static_argnames=("program", "i"),
                   donate_argnums=(0,))
def _run_jnp_op(pool: jax.Array, p, program: PoolProgram,
                i: int) -> jax.Array:
    """One op of ``program`` as its own jit unit (the traced path)."""
    op = program.ops[i]
    rows = op.rows_in or program.m_rows
    br = program.block_rows or 1
    n = program.n_segments
    if program.quantized:
        return _apply_op_q(pool, op, p, n=n, br=br, rows=rows)
    return _apply_op(pool, op, p, n=n, br=br, rows=rows)


@register_executor("jnp")
def run_program_jnp(program: PoolProgram, pool, params, *, tracer=None,
                    **_kw):
    """``tracer=None`` runs the pre-existing whole-program jit
    (bit-identical, zero tracing cost).  With a RingTracer, ops run as
    separate jit units, each synchronized (``block_until_ready``) so the
    recorded per-op wall times are device time, not dispatch time."""
    params = _normalize_params(program, params)
    arr = _as_array(pool)
    if tracer is None:
        arr = _run_jnp(arr, params, program)
    else:
        tracer.backend = "jnp"
        for i, p in enumerate(params):
            t0 = time.perf_counter()
            arr = _run_jnp_op(arr, p, program, i)
            jax.block_until_ready(arr)
            tracer.record(i, time.perf_counter() - t0)
    return _like_input(pool, arr)


# ---------------------------------------------------------------------------
# pallas backend.
# ---------------------------------------------------------------------------

def _pw_row_block(op, n_seg: int, in_ptr: int, seg_width: int,
                  limit: int) -> int:
    """Largest safe pointwise-conv row block ``<= limit``.

    Blocking needs the identity pixel map (stride 1, no resample) so a
    block's source rows are contiguous, plus DMA no-wrap alignment: the
    pool length and both pointers must be multiples of the block's input
    and output chunk sizes (a mid-block modular wrap would split the
    single async copy).  Execution granularity only — the plan geometry
    and its certificates are untouched (DESIGN.md §15).
    """
    if limit <= 1 or op.stride != 1 or op.resample:
        return 1
    ic = op.w_in * segments_for(op.d_in, seg_width)
    oc = op.w_out * segments_for(op.d_out, seg_width)
    for rb in range(min(limit, op.h_out), 1, -1):
        if op.h_out % rb:
            continue
        if n_seg % (rb * ic) or in_ptr % (rb * ic):
            continue
        if n_seg % (rb * oc) or op.out_ptr % (rb * oc):
            continue
        return rb
    return 1


@register_executor("pallas")
def run_program_pallas(program: PoolProgram, pool, params, *,
                       interpret: bool | None = None, tracer=None,
                       kernel_block_rows: int = 8, **_kw):
    # Lazy import: core must stay importable without the kernels package.
    from ..kernels.conv2d import (ring_add, ring_avgpool, ring_conv_dw,
                                  ring_conv_k2d, ring_conv_pw)
    from ..kernels.elementwise import ring_elementwise
    from ..kernels.fused_mlp import ring_fused_mlp
    from ..kernels.inverted_bottleneck import ring_inverted_bottleneck
    from ..kernels.segment_matmul import SEG_WIDTH as KSEG, ring_gemm
    from ..kernels.stream import ring_conv_stream, ring_gru_cell

    if program.block_rows is None:
        raise ValueError("pallas backend needs an aligned program — plan "
                         "with block_rows=<int>")
    if program.seg_width != KSEG:
        raise ValueError(f"pallas kernels use seg_width={KSEG}, program "
                         f"has {program.seg_width}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    arr = _as_array(pool)
    br = program.block_rows
    if tracer is not None:
        tracer.backend = "pallas"
    if program.quantized:
        return _like_input(pool, _run_pallas_q(
            arr, _normalize_params(program, params), program, br,
            interpret, tracer=tracer,
            kernel_block_rows=kernel_block_rows))
    for i, (op, p) in enumerate(zip(program.ops,
                                    _normalize_params(program, params))):
        rows = op.rows_in or program.m_rows
        t0 = time.perf_counter() if tracer is not None else 0.0
        if op.kind == "gemm":
            w, b = p
            arr = ring_gemm(arr, w, b, m_rows=rows, d_in=op.d_in,
                            d_out=op.d_out, in_ptr=op.in_ptr,
                            out_ptr=op.out_ptr, block_rows=br,
                            activation=op.activation, interpret=interpret)
        elif op.kind == "fused_mlp":
            wg, wu, wd = p
            arr = ring_fused_mlp(arr, wg, wu, wd, m_rows=rows,
                                 d_model=op.d_in, ptr=op.in_ptr,
                                 block_rows=br, ff_tile=op.ff_tile,
                                 gated=op.gated, residual=op.residual,
                                 activation=op.activation,
                                 interpret=interpret)
        elif op.kind == "elementwise":
            arr = ring_elementwise(arr, m_rows=rows, d=op.d_in,
                                   ptr=op.in_ptr, fn=op.activation,
                                   block_rows=br, interpret=interpret)
        elif op.kind == "conv_pw":
            w, b = p
            iptr = _image_ptr(arr, op)
            arr = ring_conv_pw(arr, w, b, h_in=op.h_in, w_in=op.w_in,
                               h_out=op.h_out, w_out=op.w_out,
                               c_in=op.d_in, c_out=op.d_out,
                               stride=op.stride, resample=op.resample,
                               in_ptr=iptr, out_ptr=op.out_ptr,
                               activation=op.activation,
                               row_block=_pw_row_block(
                                   op, arr.shape[0], iptr,
                                   program.seg_width, kernel_block_rows),
                               interpret=interpret)
        elif op.kind == "conv_dw":
            w, b = p
            arr = ring_conv_dw(arr, w, b, h_in=op.h_in, w_in=op.w_in,
                               h_out=op.h_out, w_out=op.w_out, c=op.d_in,
                               rs=op.rs, stride=op.stride,
                               padding=op.padding,
                               in_ptr=_image_ptr(arr, op),
                               out_ptr=op.out_ptr,
                               activation=op.activation,
                               interpret=interpret)
        elif op.kind == "conv_k2d":
            w, b = p
            arr = ring_conv_k2d(arr, w, b, h_in=op.h_in, w_in=op.w_in,
                                h_out=op.h_out, w_out=op.w_out,
                                c_in=op.d_in, c_out=op.d_out, k=op.rs,
                                stride=op.stride, padding=op.padding,
                                in_ptr=_image_ptr(arr, op),
                                out_ptr=op.out_ptr,
                                activation=op.activation,
                                interpret=interpret)
        elif op.kind == "ib_fused":
            w1, wd, w2 = p
            arr = ring_inverted_bottleneck(
                arr, w1, wd, w2, H=op.h_in, W=op.w_in, C_in=op.d_in,
                C_mid=op.d_mid, C_out=op.d_out, RS=op.rs,
                in_ptr=op.in_ptr, out_ptr=op.out_ptr,
                residual=op.residual, interpret=interpret)
        elif op.kind == "add":
            arr = ring_add(arr, rows=rows, d=op.d_in, in_ptr=op.in_ptr,
                           aux_ptr=op.aux_ptr, out_ptr=op.out_ptr,
                           activation=op.activation, interpret=interpret)
        elif op.kind == "pool_avg":
            arr = ring_avgpool(arr, h=op.h_in, w=op.w_in, c=op.d_in,
                               in_ptr=op.in_ptr, out_ptr=op.out_ptr,
                               interpret=interpret)
        elif op.kind == "conv_stream":
            w, b = p
            arr = ring_conv_stream(arr, w, b, h_win=op.h_in, w_in=op.w_in,
                                   h_out=op.h_out, w_out=op.w_out,
                                   c_in=op.d_in, c_out=op.d_out, k=op.rs,
                                   stride=op.stride, padding=op.padding,
                                   hop=op.hop, in_ptr=op.in_ptr,
                                   out_ptr=op.out_ptr,
                                   state_ptr=op.state_ptr,
                                   activation=op.activation,
                                   interpret=interpret)
        elif op.kind == "gru_cell":
            w, u, b = p
            arr = ring_gru_cell(arr, w, u, b, d_in=op.d_in, d_h=op.d_out,
                                in_ptr=op.in_ptr, out_ptr=op.out_ptr,
                                state_ptr=op.state_ptr,
                                interpret=interpret)
        else:
            raise NotImplementedError(op.kind)
        if tracer is not None:
            jax.block_until_ready(arr)
            tracer.record(i, time.perf_counter() - t0)
    return _like_input(pool, arr)


def _run_pallas_q(arr, params, program: PoolProgram, br, interpret,
                  tracer=None, kernel_block_rows: int = 8):
    """Int8 program on the Pallas ring kernels (``kernels.quantized``)."""
    from ..kernels.quantized import (ring_add_q, ring_avgpool_q,
                                     ring_conv_dw_q, ring_conv_k2d_q,
                                     ring_conv_pw_q, ring_gemm_q)
    from ..kernels.stream import ring_conv_stream_q, ring_gru_cell_q

    for i, (op, p) in enumerate(zip(program.ops, params)):
        rows = op.rows_in or program.m_rows
        t0 = time.perf_counter() if tracer is not None else 0.0
        if op.kind == "gemm":
            w, b, mult, shift = p
            arr = ring_gemm_q(arr, w, b, mult, shift, m_rows=rows,
                              d_in=op.d_in, d_out=op.d_out,
                              in_ptr=op.in_ptr, out_ptr=op.out_ptr,
                              block_rows=br, activation=op.activation,
                              interpret=interpret)
        elif op.kind == "conv_pw":
            w, b, mult, shift = p
            iptr = _image_ptr(arr, op)
            arr = ring_conv_pw_q(arr, w, b, mult, shift, h_in=op.h_in,
                                 w_in=op.w_in, h_out=op.h_out,
                                 w_out=op.w_out, c_in=op.d_in,
                                 c_out=op.d_out, stride=op.stride,
                                 resample=op.resample,
                                 in_ptr=iptr, out_ptr=op.out_ptr,
                                 activation=op.activation,
                                 row_block=_pw_row_block(
                                     op, arr.shape[0], iptr,
                                     program.seg_width,
                                     kernel_block_rows),
                                 interpret=interpret)
        elif op.kind == "conv_dw":
            w, b, mult, shift = p
            arr = ring_conv_dw_q(arr, w, b, mult, shift, h_in=op.h_in,
                                 w_in=op.w_in, h_out=op.h_out,
                                 w_out=op.w_out, c=op.d_in, rs=op.rs,
                                 stride=op.stride, padding=op.padding,
                                 in_ptr=_image_ptr(arr, op),
                                 out_ptr=op.out_ptr,
                                 activation=op.activation,
                                 interpret=interpret)
        elif op.kind == "conv_k2d":
            w, b, mult, shift = p
            arr = ring_conv_k2d_q(arr, w, b, mult, shift, h_in=op.h_in,
                                  w_in=op.w_in, h_out=op.h_out,
                                  w_out=op.w_out, c_in=op.d_in,
                                  c_out=op.d_out, k=op.rs,
                                  stride=op.stride, padding=op.padding,
                                  in_ptr=_image_ptr(arr, op),
                                  out_ptr=op.out_ptr,
                                  activation=op.activation,
                                  interpret=interpret)
        elif op.kind == "add":
            mi, si, ma, sa = p
            arr = ring_add_q(arr, rows=rows, d=op.d_in, in_ptr=op.in_ptr,
                             aux_ptr=op.aux_ptr, out_ptr=op.out_ptr,
                             mult_in=mi, shift_in=si, mult_aux=ma,
                             shift_aux=sa, activation=op.activation,
                             interpret=interpret)
        elif op.kind == "pool_avg":
            mult, shift = p
            arr = ring_avgpool_q(arr, h=op.h_in, w=op.w_in, c=op.d_in,
                                 in_ptr=op.in_ptr, out_ptr=op.out_ptr,
                                 mult=mult, shift=shift,
                                 interpret=interpret)
        elif op.kind == "conv_stream":
            w, b, mult, shift = p
            arr = ring_conv_stream_q(arr, w, b, mult, shift,
                                     h_win=op.h_in, w_in=op.w_in,
                                     h_out=op.h_out, w_out=op.w_out,
                                     c_in=op.d_in, c_out=op.d_out,
                                     k=op.rs, stride=op.stride,
                                     padding=op.padding, hop=op.hop,
                                     in_ptr=op.in_ptr,
                                     out_ptr=op.out_ptr,
                                     state_ptr=op.state_ptr,
                                     activation=op.activation,
                                     interpret=interpret)
        elif op.kind == "gru_cell":
            w, u, b, mx, sx, mu, su = p
            arr = ring_gru_cell_q(arr, w, u, b, mx, sx, mu, su,
                                  d_in=op.d_in, d_h=op.d_out,
                                  in_ptr=op.in_ptr, out_ptr=op.out_ptr,
                                  state_ptr=op.state_ptr,
                                  interpret=interpret)
        else:
            raise NotImplementedError(
                f"no int8 pallas kernel for {op.kind}")
        if tracer is not None:
            jax.block_until_ready(arr)
            tracer.record(i, time.perf_counter() - t0)
    return arr


# ---------------------------------------------------------------------------
# sim backend — the clobber oracle.
# ---------------------------------------------------------------------------

def _sim_rowsched_op(sim: SegmentPool, program: PoolProgram, i: int) -> None:
    """Replay one conv-family op through the oracle from the SAME row
    schedule the planner solved its delta with (``core.rowsched``)."""
    from .rowsched import schedule_for_op

    op = program.ops[i]
    sched = schedule_for_op(op, program.seg_width)
    frees = sched.frees()
    ic, oc = sched.in_chunk, sched.out_chunk
    # branch ops (in_op >= 0) read the held INPUT of op in_op — segment
    # ownership tags carry that op's index, exactly like aux reads
    iown = op.in_op if op.in_op >= 0 else i
    # sliced ops (repro.partial): reads window the source record at row
    # offset in_row0; writes land inside the SHARED output tensor owned
    # by op out_op at row offset out_row0
    r0 = op.in_row0
    oown = op.out_op if op.out_op >= 0 else i + 1
    w0 = op.out_row0
    for t in range(sched.steps):
        for r in sched.reads[t]:
            for s in range(ic):
                seg = (r0 + r) * ic + s
                sim.read(op.in_ptr + seg, owner=(iown, seg))
        if sched.aux_reads is not None:
            ac = sched.aux_chunk
            for r in sched.aux_reads[t]:
                for s in range(ac):
                    seg = r * ac + s
                    sim.read(op.aux_ptr + seg, owner=(op.aux_op, seg))
                    sim.free(op.aux_ptr + seg, owner=(op.aux_op, seg))
        if not op.hold_input:
            for r in frees[t]:
                for s in range(ic):
                    seg = (r0 + r) * ic + s
                    sim.free(op.in_ptr + seg, owner=(iown, seg))
        for r in sched.writes[t]:
            for s in range(oc):
                sim.write(op.out_ptr + r * oc + s,
                          owner=(oown, (w0 + r) * oc + s))
    if op.free_src:
        # last slice of a held source: release the WHOLE record (earlier
        # slices held it; re-freeing an already-free segment is benign)
        src_rows = op.h_src or sched.in_rows
        for seg in range(src_rows * ic):
            sim.free(op.in_ptr + seg, owner=(iown, seg))


def _sim_stream_op(sim: SegmentPool, program: PoolProgram, i: int) -> None:
    """conv_stream / gru_cell through the oracle: whole-state read then a
    same-owner whole-state rewrite (the executors fetch the full window /
    hidden vector, shift, and write it back — a FOREIGN write into the
    live state region is exactly the clobber this catches), followed by
    the frame traffic via the op's row schedule."""
    op = program.ops[i]
    for j in range(op.state_segments):
        sim.read(op.state_ptr + j, owner=("state", i, j))
    for j in range(op.state_segments):
        sim.write(op.state_ptr + j, owner=("state", i, j))
    _sim_rowsched_op(sim, program, i)


@register_executor("sim")
def run_program_sim(program: PoolProgram, pool=None, params=None, *,
                    tracer=None, **_kw) -> SegmentPool:
    """Execute the program's schedule in the SegmentPool simulator.

    GEMM ops run the paper's fine-grained Fig.-4 schedule (input segment
    freed after its LAST read) — strictly harder than the block-granular
    TPU schedule, so a clobber-free sim run certifies the kernels.
    Conv-family ops replay the row schedule their delta was solved with
    (``core.rowsched``); residual sources are freed by the consuming add.
    Returns the SegmentPool for access statistics (peak_live etc.).

    A ``tracer`` (:class:`repro.obs.RingTracer`) snapshots the pool's
    read/write/free counters around every op — measured per-op traffic
    from the oracle itself, asserted bit-equal to the schedule-derived
    static counters.
    """
    sw = program.seg_width
    if isinstance(pool, SegmentPool):
        # persistent streaming session (repro.stream): state records from
        # the previous step are still live under their ("state", i, j)
        # owners — the next step must prove it never clobbers them
        sim = pool
    else:
        sim = SegmentPool(program.n_segments,
                          segment_bytes=sw * program.elem_bytes)
        for i, op in enumerate(program.ops):
            for j in range(op.state_segments):
                sim.write(op.state_ptr + j, owner=("state", i, j))
    if tracer is not None:
        tracer.backend = "sim"
    first = program.ops[0]
    for j in range(first.in_segments):
        sim.write(first.in_ptr + j, owner=(0, j))
    for i, op in enumerate(program.ops):
        m = op.rows_in or program.m_rows
        if tracer is not None:
            pre = (sim.reads, sim.writes, sim.frees)
            t0 = time.perf_counter()
        if op.kind == "gemm":
            k_segs = segments_for(op.d_in, sw)
            n_segs = segments_for(op.d_out, sw)
            for r in range(m):
                for n in range(n_segs):
                    for k in range(k_segs):
                        seg = r * k_segs + k
                        sim.read(op.in_ptr + seg, owner=(i, seg))
                        if n == n_segs - 1 and not op.hold_input:
                            sim.free(op.in_ptr + seg, owner=(i, seg))
                    outseg = r * n_segs + n
                    sim.write(op.out_ptr + outseg, owner=(i + 1, outseg))
        elif op.kind in ("fused_mlp", "elementwise"):
            # per-row in-place at delta == 0
            d_segs = segments_for(op.d_in, sw)
            for r in range(m):
                for s in range(d_segs):
                    seg = r * d_segs + s
                    sim.read(op.in_ptr + seg, owner=(i, seg))
                    if not op.hold_input:
                        sim.free(op.in_ptr + seg, owner=(i, seg))
                for s in range(d_segs):
                    seg = r * d_segs + s
                    sim.write(op.out_ptr + seg, owner=(i + 1, seg))
        elif op.kind in ("conv_stream", "gru_cell"):
            _sim_stream_op(sim, program, i)
        else:
            _sim_rowsched_op(sim, program, i)
        if tracer is not None:
            tracer.record(i, time.perf_counter() - t0)
            tracer.record_sim(i, reads=sim.reads - pre[0],
                              writes=sim.writes - pre[1],
                              frees=sim.frees - pre[2], live=sim.live)
    last = program.ops[-1]
    for j in range(last.out_segments):  # outputs must survive the ring
        sim.read(last.out_ptr + j, owner=(len(program.ops), j))
    for i, op in enumerate(program.ops):  # ...and so must persistent state
        for j in range(op.state_segments):
            sim.read(op.state_ptr + j, owner=("state", i, j))
    if tracer is not None:
        tracer.finish_sim(sim)
    return sim
