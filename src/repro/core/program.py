"""PoolProgram — the plan-program IR over one :class:`VirtualPool`.

``plan_program()`` is the single planning front-end (it subsumes the three
previously separate APIs: ``plan_gemm``/``SegmentPlan``,
``plan_chain``/``ChainPlan`` and ``plan_fc_chain``/
``plan_inverted_bottleneck``/``FusedPlan`` — those dataclasses remain as
thin adapters).  A program is an ordered list of :class:`PoolOp` steps,
each carrying the solved Eq.-(1)/(2) geometry ``(in_ptr, out_ptr, delta,
segment_bytes)``; executors (``repro.core.executors``) run the *same*
program on interchangeable backends:

  * ``sim``    — the :class:`repro.core.pool.SegmentPool` clobber oracle,
  * ``jnp``    — the jit-able modular-indexing scan path,
  * ``pallas`` — the TPU ring kernels (``segment_matmul``/``fused_mlp``).

Two geometries per program (DESIGN.md §5):

  * **tight** pointers — the exact Eq.-(1) chaining; ``pool_segments`` /
    ``pool_bytes`` report this footprint and match the legacy planners
    bit-for-bit.
  * **physical** pointers — when ``block_rows`` is set, every pointer is
    rounded to its op's DMA block and ``n_segments`` to the lcm of all
    block sizes, so a contiguous async-copy block never wraps mid-block
    (the alignment adaptation previously private to
    ``segment_matmul.aligned_pool_geometry``).  ``block_rows=None``
    programs keep the tight geometry and run on ``sim``/``jnp`` only.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence, Union

import jax

from .planner import gemm_offset_closed_form
from .vpool import PoolSpec, SEG_WIDTH, ceil_div, segments_for

EXECUTABLE_KINDS = ("gemm", "fused_mlp", "elementwise")
PLAN_ONLY_KINDS = ("fused_chain", "inverted_bottleneck")

# Element-wise maps usable as gemm epilogues / elementwise ops.  Every fn
# must map 0 -> 0 so segment padding columns stay zero through the ring.
ACTIVATIONS = {
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "relu": lambda x: jax.numpy.maximum(x, 0.0),
    "square": lambda x: x * x,
    "identity": lambda x: x,
}


def resolve_activation(name: str | None):
    if name is None:
        return ACTIVATIONS["identity"]
    try:
        return ACTIVATIONS[name]
    except KeyError:
        raise ValueError(f"unknown activation {name!r}; "
                         f"known: {sorted(ACTIVATIONS)}") from None


# ---------------------------------------------------------------------------
# Layer specs — the vocabulary plan_program() accepts.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GemmSpec:
    """FC layer ``[M, d_in] @ [d_in, d_out] (+ bias, + activation)`` with
    weights in "Flash" (un-pooled storage), paper Fig. 4."""

    d_out: int
    activation: str | None = None


@dataclasses.dataclass(frozen=True)
class FusedMLPSpec:
    """In-place fused (gated) MLP, the transformer analogue of the paper's
    Fig.-6 inverted bottleneck: ``d_ff`` never materializes, delta == 0."""

    d_ff: int
    gated: bool = True
    residual: bool = True
    activation: str = "gelu"
    ff_tile: int = 512


@dataclasses.dataclass(frozen=True)
class ElementwiseSpec:
    """In-place element-wise map over the resident rows (delta == 0)."""

    fn: str = "gelu"


@dataclasses.dataclass(frozen=True)
class FusedChainSpec:
    """Whole-FC-chain streaming fusion (Eq. 2, byte-granular, plan-only).

    ``dims`` are the hidden dims *after* the program input dim."""

    dims: tuple[int, ...]
    rows_per_step: int = 1
    elem_bytes: int = 2


@dataclasses.dataclass(frozen=True)
class InvertedBottleneckSpec:
    """Paper Fig.-6 PW->DW->PW(->add) module (byte-granular, plan-only)."""

    cfg: object  # repro.core.graph_planner.ModuleConfig
    workspace: str = "paper_11seg"


LayerSpec = Union[GemmSpec, FusedMLPSpec, ElementwiseSpec, FusedChainSpec,
                  InvertedBottleneckSpec]


# ---------------------------------------------------------------------------
# The IR.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PoolOp:
    """One step of a PoolProgram with its solved pool geometry.

    ``in_ptr``/``out_ptr`` are *physical* segment offsets (block-aligned
    when the program was planned with ``block_rows``); ``delta`` is the
    solved Eq.-(1)/(2) optimum ``b_In - b_Out`` (tight, pre-alignment).
    For plan-only kinds all segment quantities are in bytes
    (``segment_bytes == 1``).
    """

    kind: str
    in_ptr: int
    out_ptr: int
    delta: int
    in_segments: int
    out_segments: int
    segment_bytes: int
    d_in: int = 0
    d_out: int = 0
    activation: str | None = None
    gated: bool = False
    residual: bool = False
    d_ff: int = 0
    ff_tile: int = 0
    workspace_bytes: int = 0

    @property
    def span_segments(self) -> int:
        """Width of the live In ∪ Out window while this op runs."""
        lo = min(self.in_ptr, self.out_ptr)
        hi = max(self.in_ptr + self.in_segments,
                 self.out_ptr + self.out_segments)
        return hi - lo


@dataclasses.dataclass(frozen=True)
class PoolProgram:
    """An ordered list of PoolOps over one VirtualPool.

    ``pool_segments``/``pool_bytes`` — tight Eq.-(1) footprint (equals the
    legacy planners for the same shapes).  ``n_segments`` /
    ``physical_pool_bytes`` — the allocated ring length including DMA
    block-alignment padding (identical to the tight value when
    ``block_rows is None``).  Hashable, so executors jit with the program
    as a static argument.
    """

    m_rows: int
    seg_width: int
    block_rows: int | None
    n_segments: int
    pool_segments: int
    elem_bytes: int
    ops: tuple[PoolOp, ...]

    # -- classification ----------------------------------------------------
    @property
    def executable(self) -> bool:
        return all(op.kind in EXECUTABLE_KINDS for op in self.ops)

    @property
    def aligned(self) -> bool:
        return self.block_rows is not None

    # -- footprint accounting ---------------------------------------------
    @property
    def pool_bytes(self) -> int:
        op = self.ops[0]
        if op.kind in PLAN_ONLY_KINDS:
            return (max(op.in_segments + op.delta, op.out_segments)
                    + op.workspace_bytes) * op.segment_bytes
        return self.pool_segments * self.seg_width * self.elem_bytes

    @property
    def physical_pool_bytes(self) -> int:
        op = self.ops[0]
        if op.kind in PLAN_ONLY_KINDS:
            return self.pool_bytes
        return self.n_segments * self.seg_width * self.elem_bytes

    @property
    def naive_bytes(self) -> int:
        """Tensor-level footprint: worst coexisting in+out pair."""
        worst = max(op.in_segments + op.out_segments for op in self.ops)
        op = self.ops[0]
        if op.kind in PLAN_ONLY_KINDS:
            return worst * op.segment_bytes
        return worst * self.seg_width * self.elem_bytes

    @property
    def saving_fraction(self) -> float:
        return 1.0 - self.pool_bytes / self.naive_bytes

    # -- I/O geometry ------------------------------------------------------
    @property
    def in_dim(self) -> int:
        return self.ops[0].d_in

    @property
    def out_dim(self) -> int:
        return self.ops[-1].d_out

    @property
    def input_ptr(self) -> int:
        return self.ops[0].in_ptr

    @property
    def output_ptr(self) -> int:
        return self.ops[-1].out_ptr

    def spec(self, dtype=None) -> PoolSpec:
        import jax.numpy as jnp
        return PoolSpec(self.n_segments, self.seg_width,
                        jnp.float32 if dtype is None else dtype)

    # -- validation --------------------------------------------------------
    def check_alignment(self) -> None:
        """Assert no contiguous DMA block of any op can wrap mid-block.

        Sufficient condition (DESIGN.md §5): every pointer is a multiple of
        its op's block segment count and ``n_segments`` is a multiple of
        every block size — then ``(ptr + i*b) % n_segments`` is always
        block-aligned and ``off + b <= n_segments``.
        """
        if not self.aligned:
            raise ValueError("program was planned with block_rows=None "
                             "(tight geometry) — not DMA-block aligned")
        br = self.block_rows
        for op in self.ops:
            if op.kind not in EXECUTABLE_KINDS:
                continue
            bk = br * segments_for(op.d_in, self.seg_width)
            bn = br * segments_for(op.d_out, self.seg_width)
            if (op.in_ptr % bk or op.out_ptr % bn
                    or self.n_segments % math.lcm(bk, bn)):
                raise AssertionError(f"misaligned op {op.kind} "
                                     f"({op.in_ptr},{op.out_ptr}) in pool "
                                     f"of {self.n_segments}")
            n_blocks = self.m_rows // br
            for i in range(n_blocks):
                off_in = (op.in_ptr + i * bk) % self.n_segments
                off_out = (op.out_ptr + i * bn) % self.n_segments
                assert off_in + bk <= self.n_segments, "mid-block wrap (in)"
                assert off_out + bn <= self.n_segments, "mid-block wrap (out)"


# ---------------------------------------------------------------------------
# The single planning front-end.
# ---------------------------------------------------------------------------

def _floor_mult(x: int, b: int) -> int:
    return (x // b) * b


def _span(in_ptr: int, out_ptr: int, in_tot: int, out_tot: int) -> int:
    return (max(in_ptr + in_tot, out_ptr + out_tot)
            - min(in_ptr, out_ptr))


def plan_program(m_rows: int, d_in: int, layers: Sequence[LayerSpec], *,
                 seg_width: int = SEG_WIDTH, block_rows: int | None = None,
                 elem_bytes: int = 4, delta_slack: int = 0) -> PoolProgram:
    """Solve segment offsets for a layer sequence over ONE virtual pool.

    ``block_rows=None`` keeps the exact Eq.-(1) geometry (``sim``/``jnp``
    backends); an integer plans DMA-block-aligned geometry executable on
    the ``pallas`` backend too (deltas only ever rounded *up* — safety is
    preserved; ``pool_segments`` still reports the tight footprint).

    ``delta_slack`` exists for tightness testing only: it shrinks every
    solved delta, so ``delta_slack=1`` must make the ``sim`` backend raise
    :class:`repro.core.pool.PoolClobberError` (the plans are exact optima).
    """
    layers = list(layers)
    if not layers:
        raise ValueError("need at least one layer spec")
    if any(isinstance(s, (FusedChainSpec, InvertedBottleneckSpec))
           for s in layers):
        if len(layers) != 1:
            raise ValueError("byte-granular plan-only specs (FusedChainSpec/"
                             "InvertedBottleneckSpec) must be the sole layer")
        return _plan_analytic(m_rows, d_in, layers[0])

    aligned = block_rows is not None
    br = block_rows if aligned else 1
    if br <= 0 or m_rows % br:
        raise ValueError(f"block_rows={block_rows} must divide "
                         f"m_rows={m_rows}")

    ops: list[PoolOp] = []
    cur = d_in
    pt = 0   # tight running pointer
    pa = 0   # aligned running pointer
    spans_a: list[int] = []
    aligns: list[int] = [1]
    for pos, spec in enumerate(layers):
        if isinstance(spec, (GemmSpec, FusedMLPSpec)):
            resolve_activation(spec.activation)  # fail at plan time
        elif isinstance(spec, ElementwiseSpec):
            resolve_activation(spec.fn)
        if isinstance(spec, GemmSpec):
            k_segs = segments_for(cur, seg_width)
            n_segs = segments_for(spec.d_out, seg_width)
            bk, bn = br * k_segs, br * n_segs
            delta = (gemm_offset_closed_form(m_rows, n_segs, k_segs)
                     - delta_slack)
            ot = pt - delta
            if not aligned:
                ia, oa = pa, ot
            elif pos == 0:
                # First op: both tensors are still placeable — pick the
                # cheaper of "shift In up to a bk multiple" (the legacy
                # aligned_pool_geometry choice) and "shift Out down to a
                # bn multiple".
                gap_k = ceil_div(max(delta, 0), bk) * bk
                gap_n = ceil_div(max(delta, 0), bn) * bn
                ia, oa = ((gap_k, 0) if gap_k <= gap_n else (0, -gap_n))
            else:
                ia, oa = pa, _floor_mult(pa - delta, bn)
            in_tot, out_tot = m_rows * k_segs, m_rows * n_segs
            op = PoolOp(kind="gemm", in_ptr=ia, out_ptr=oa, delta=delta,
                        in_segments=in_tot, out_segments=out_tot,
                        segment_bytes=seg_width * elem_bytes,
                        d_in=cur, d_out=spec.d_out,
                        activation=spec.activation)
            aligns.append(math.lcm(bk, bn))
            pt, pa, cur = ot, oa, spec.d_out
        elif isinstance(spec, (FusedMLPSpec, ElementwiseSpec)):
            d_segs = segments_for(cur, seg_width)
            bd = br * d_segs
            delta = -delta_slack  # Eq.-(2) optimum for these chains is 0
            ot = pt - delta
            oa = pa if (not aligned or delta == 0) else pa - delta
            tot = m_rows * d_segs
            if isinstance(spec, FusedMLPSpec):
                if spec.d_ff % spec.ff_tile:
                    raise ValueError(f"ff_tile={spec.ff_tile} must divide "
                                     f"d_ff={spec.d_ff}")
                op = PoolOp(kind="fused_mlp", in_ptr=pa, out_ptr=oa,
                            delta=delta, in_segments=tot, out_segments=tot,
                            segment_bytes=seg_width * elem_bytes,
                            d_in=cur, d_out=cur, activation=spec.activation,
                            gated=spec.gated, residual=spec.residual,
                            d_ff=spec.d_ff, ff_tile=spec.ff_tile)
            else:
                op = PoolOp(kind="elementwise", in_ptr=pa, out_ptr=oa,
                            delta=delta, in_segments=tot, out_segments=tot,
                            segment_bytes=seg_width * elem_bytes,
                            d_in=cur, d_out=cur, activation=spec.fn)
            ia = pa
            in_tot = out_tot = tot
            aligns.append(bd)
            pt, pa = ot, oa
        else:
            raise TypeError(f"unknown layer spec {spec!r}")
        spans_a.append(_span(ia, oa, in_tot, out_tot))
        ops.append(op)

    # Tight spans come from the tight chaining, not the aligned pointers.
    pool_segments = max(_tight_spans(m_rows, d_in, layers, seg_width,
                                     delta_slack))

    if aligned:
        align = math.lcm(*aligns)
        n_segments = ceil_div(max(spans_a), align) * align
        base = min(min(op.in_ptr, op.out_ptr) for op in ops)
        shift = -_floor_mult(base, align) if base < 0 else 0
    else:
        n_segments = pool_segments
        base = min(min(op.in_ptr, op.out_ptr) for op in ops)
        shift = -base
    if shift:
        ops = [dataclasses.replace(op, in_ptr=op.in_ptr + shift,
                                   out_ptr=op.out_ptr + shift)
               for op in ops]

    return PoolProgram(m_rows=m_rows, seg_width=seg_width,
                       block_rows=block_rows, n_segments=n_segments,
                       pool_segments=pool_segments, elem_bytes=elem_bytes,
                       ops=tuple(ops))


def _tight_spans(m_rows, d_in, layers, seg_width, delta_slack) -> list[int]:
    """Exact (unaligned) per-op live spans — the legacy ChainPlan numbers."""
    spans = []
    cur, ptr = d_in, 0
    for spec in layers:
        if isinstance(spec, GemmSpec):
            k_segs = segments_for(cur, seg_width)
            n_segs = segments_for(spec.d_out, seg_width)
            delta = (gemm_offset_closed_form(m_rows, n_segs, k_segs)
                     - delta_slack)
            out = ptr - delta
            spans.append(_span(ptr, out, m_rows * k_segs, m_rows * n_segs))
            ptr, cur = out, spec.d_out
        else:
            d_segs = segments_for(cur, seg_width)
            out = ptr + delta_slack
            tot = m_rows * d_segs
            spans.append(_span(ptr, out, tot, tot))
            ptr = out
    return spans


# ---------------------------------------------------------------------------
# Byte-granular plan-only programs (Eq. 2 analytic plans).
# ---------------------------------------------------------------------------

def _plan_analytic(m_rows: int, d_in: int, spec) -> PoolProgram:
    from .graph_planner import plan_fc_chain, plan_inverted_bottleneck
    if isinstance(spec, FusedChainSpec):
        dims = [d_in, *spec.dims]
        fp = plan_fc_chain(m_rows, dims, elem_bytes=spec.elem_bytes,
                           rows_per_step=spec.rows_per_step)
        op = PoolOp(kind="fused_chain", in_ptr=fp.delta_bytes, out_ptr=0,
                    delta=fp.delta_bytes, in_segments=fp.input_bytes,
                    out_segments=fp.output_bytes, segment_bytes=1,
                    d_in=d_in, d_out=dims[-1],
                    workspace_bytes=fp.workspace_bytes)
    else:
        fp = plan_inverted_bottleneck(spec.cfg, spec.workspace)
        op = PoolOp(kind="inverted_bottleneck", in_ptr=fp.delta_bytes,
                    out_ptr=0, delta=fp.delta_bytes,
                    in_segments=fp.input_bytes,
                    out_segments=fp.output_bytes, segment_bytes=1,
                    d_in=spec.cfg.c_in, d_out=spec.cfg.c_out,
                    workspace_bytes=fp.workspace_bytes)
    pool_bytes = (max(op.in_segments + op.delta, op.out_segments)
                  + op.workspace_bytes)
    return PoolProgram(m_rows=m_rows, seg_width=1, block_rows=None,
                       n_segments=pool_bytes, pool_segments=pool_bytes,
                       elem_bytes=1, ops=(op,))


def plan_module_program(cfg, workspace: str = "paper_11seg") -> PoolProgram:
    """One-op program for a fused inverted-bottleneck module (Fig. 6).

    ``pool_bytes`` equals ``plan_inverted_bottleneck(cfg).pool_bytes``."""
    return plan_program(cfg.hw * cfg.hw, cfg.c_in,
                        [InvertedBottleneckSpec(cfg, workspace)])


def plan_stream_chain_program(m_rows: int, dims: Sequence[int], *,
                              rows_per_step: int = 1,
                              elem_bytes: int = 2) -> PoolProgram:
    """One-op program for a whole-chain streaming fusion (Eq. 2).

    ``pool_bytes`` equals ``plan_fc_chain(m_rows, dims, ...).pool_bytes``."""
    return plan_program(m_rows, dims[0],
                        [FusedChainSpec(tuple(dims[1:]),
                                        rows_per_step=rows_per_step,
                                        elem_bytes=elem_bytes)])
