"""PoolProgram — the plan-program IR over one :class:`VirtualPool`.

``plan_program()`` is the single planning front-end (it subsumes the three
previously separate APIs: ``plan_gemm``/``SegmentPlan``,
``plan_chain``/``ChainPlan`` and ``plan_fc_chain``/
``plan_inverted_bottleneck``/``FusedPlan`` — those dataclasses remain as
thin adapters).  A program is an ordered list of :class:`PoolOp` steps,
each carrying the solved Eq.-(1)/(2) geometry ``(in_ptr, out_ptr, delta,
segment_bytes)``; executors (``repro.core.executors``) run the *same*
program on interchangeable backends:

  * ``sim``    — the :class:`repro.core.pool.SegmentPool` clobber oracle,
  * ``jnp``    — the jit-able modular-indexing scan path,
  * ``pallas`` — the TPU ring kernels (``segment_matmul``/``fused_mlp``).

Two geometries per program (DESIGN.md §5):

  * **tight** pointers — the exact Eq.-(1) chaining; ``pool_segments`` /
    ``pool_bytes`` report this footprint and match the legacy planners
    bit-for-bit.
  * **physical** pointers — when ``block_rows`` is set, every pointer is
    rounded to its op's DMA block and ``n_segments`` to the lcm of all
    block sizes, so a contiguous async-copy block never wraps mid-block
    (the alignment adaptation previously private to
    ``segment_matmul.aligned_pool_geometry``).  ``block_rows=None``
    programs keep the tight geometry and run on ``sim``/``jnp`` only.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence, Union

import jax

from .planner import gemm_offset_closed_form
from .vpool import PoolSpec, SEG_WIDTH, ceil_div, segments_for

EXECUTABLE_KINDS = ("gemm", "fused_mlp", "elementwise", "conv_pw",
                    "conv_dw", "conv_k2d", "ib_fused", "add", "pool_avg",
                    "conv_stream", "gru_cell")
PLAN_ONLY_KINDS = ("fused_chain", "inverted_bottleneck")

# Pool element dtypes a program can be planned for.  The name is the
# program's ``dtype`` field (a plain string so PoolProgram stays hashable
# as a static jit argument); the value is the element itemsize that every
# ``segment_bytes`` derivation uses — nothing in the planner assumes 4
# bytes anymore.  ``"int8"`` additionally selects QUANTIZED execution
# (qparams, int32 accumulate + requantize — DESIGN.md §8); ``"byte"`` is
# the accounting-only 1-byte label (numpy's int8 alias) legacy
# ``elem_bytes=1`` callers get, which keeps the float executor paths.
DTYPE_ITEMSIZE = {"float32": 4, "bfloat16": 2, "float16": 2, "int8": 1,
                  "byte": 1}

# Representative dtype per element width, for legacy callers that pass
# only ``elem_bytes`` (the label matters only for ``PoolProgram.spec()``
# defaults; explicit spec(dtype=...) overrides it).  Deliberately NOT
# "int8" for width 1: quantized execution must be opted into explicitly
# via dtype="int8", never inferred from a byte width.
_DTYPE_FOR_BYTES = {4: "float32", 2: "bfloat16", 1: "byte"}


def dtype_itemsize(dtype: str) -> int:
    try:
        return DTYPE_ITEMSIZE[dtype]
    except KeyError:
        raise ValueError(f"unknown pool dtype {dtype!r}; known: "
                         f"{sorted(DTYPE_ITEMSIZE)}") from None

# Element-wise maps usable as gemm epilogues / elementwise ops.  Every fn
# must map 0 -> 0 so segment padding columns stay zero through the ring.
ACTIVATIONS = {
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "relu": lambda x: jax.numpy.maximum(x, 0.0),
    "square": lambda x: x * x,
    "identity": lambda x: x,
}


def resolve_activation(name: str | None):
    if name is None:
        return ACTIVATIONS["identity"]
    try:
        return ACTIVATIONS[name]
    except KeyError:
        raise ValueError(f"unknown activation {name!r}; "
                         f"known: {sorted(ACTIVATIONS)}") from None


# ---------------------------------------------------------------------------
# Layer specs — the vocabulary plan_program() accepts.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GemmSpec:
    """FC layer ``[M, d_in] @ [d_in, d_out] (+ bias, + activation)`` with
    weights in "Flash" (un-pooled storage), paper Fig. 4."""

    d_out: int
    activation: str | None = None


@dataclasses.dataclass(frozen=True)
class FusedMLPSpec:
    """In-place fused (gated) MLP, the transformer analogue of the paper's
    Fig.-6 inverted bottleneck: ``d_ff`` never materializes, delta == 0."""

    d_ff: int
    gated: bool = True
    residual: bool = True
    activation: str = "gelu"
    ff_tile: int = 512


@dataclasses.dataclass(frozen=True)
class ElementwiseSpec:
    """In-place element-wise map over the resident rows (delta == 0)."""

    fn: str = "gelu"


@dataclasses.dataclass(frozen=True)
class FusedChainSpec:
    """Whole-FC-chain streaming fusion (Eq. 2, byte-granular, plan-only).

    ``dims`` are the hidden dims *after* the program input dim."""

    dims: tuple[int, ...]
    rows_per_step: int = 1
    elem_bytes: int = 2


@dataclasses.dataclass(frozen=True)
class InvertedBottleneckSpec:
    """Paper Fig.-6 PW->DW->PW(->add) module (byte-granular, plan-only)."""

    cfg: object  # repro.core.graph_planner.ModuleConfig
    workspace: str = "paper_11seg"


@dataclasses.dataclass(frozen=True)
class ConvPWSpec:
    """Pointwise (1x1) conv over pixel rows: ``[H,W,c_in] -> [P,Q,c_out]``.

    ``stride`` gives the standard strided conv (source pixel ``(p*s,
    q*s)``); ``resample_to=(P, Q)`` instead maps output pixel ``(p, q)``
    to source ``((p*H)//P, (q*W)//Q)`` — the nearest-grid adapter used
    for transitions between module tables whose shapes do not chain."""

    h_in: int
    w_in: int
    c_in: int
    c_out: int
    stride: int = 1
    resample_to: tuple[int, int] | None = None
    activation: str | None = None
    input_from: int = 0   # > 0: branch conv reading a held tensor
    #                       (see ConvK2DSpec.input_from)

    @property
    def out_hw(self) -> tuple[int, int]:
        if self.resample_to is not None:
            return self.resample_to
        return (ceil_div(self.h_in, self.stride),
                ceil_div(self.w_in, self.stride))


@dataclasses.dataclass(frozen=True)
class ConvDWSpec:
    """Depthwise RSxRS conv ('same' padding) over pixel rows."""

    h_in: int
    w_in: int
    c: int
    rs: int = 3
    stride: int = 1
    activation: str | None = None

    @property
    def out_hw(self) -> tuple[int, int]:
        return (ceil_div(self.h_in, self.stride),
                ceil_div(self.w_in, self.stride))


@dataclasses.dataclass(frozen=True)
class ConvK2DSpec:
    """General k x k spatial conv over pixel rows:
    ``[h_in, w_in, c_in] -> [h_out, w_out, c_out]``.

    ``k`` in {3, 5}, ``stride`` in {1, 2}, ``padding`` 'same' (low pad
    ``(k-1)//2``, out = ceil(in/stride)) or 'valid' (no pad, out =
    ``(in-k)//stride + 1``).  The k-row input halo widens the Eq.-(1)
    safe-offset frontier (``core.rowsched.conv_k2d_schedule``).

    ``input_from=m`` (> 0) makes this a *branch* conv: instead of the
    chained tensor it reads the input tensor of the op ``m`` positions
    back (the planner holds that tensor live, exactly like a
    :class:`ResidualAddSpec` source) while the chained tensor stays
    resident for a later consumer — the ResNet shortcut-projection
    pattern."""

    h_in: int
    w_in: int
    c_in: int
    c_out: int
    k: int = 3
    stride: int = 1
    padding: str = "same"
    activation: str | None = None
    input_from: int = 0

    @property
    def out_hw(self) -> tuple[int, int]:
        from .rowsched import conv_k2d_out
        return (conv_k2d_out(self.h_in, self.k, self.stride, self.padding),
                conv_k2d_out(self.w_in, self.k, self.stride, self.padding))


@dataclasses.dataclass(frozen=True)
class IBModuleSpec:
    """EXECUTABLE fused inverted-bottleneck module (Fig. 6, row-granular).

    Runs as one ``ib_fused`` op via ``kernels.inverted_bottleneck``;
    stride-1 only, one pool segment per pixel (``c_in, c_out <=
    seg_width``).  The byte-granular Eq.-(2) footprint of the same module
    is :class:`InvertedBottleneckSpec` (plan-only)."""

    cfg: object  # repro.core.graph_planner.ModuleConfig


@dataclasses.dataclass(frozen=True)
class ResidualAddSpec:
    """Add the *input tensor of the op ``src`` steps back* (still resident
    in the pool — the planner holds it live) to the current tensor.

    ``activation`` applies after the sum (ResNet's post-add ReLU)."""

    src: int = 3  # pw1 -> dw -> pw2 -> add
    activation: str | None = None


@dataclasses.dataclass(frozen=True)
class AvgPoolSpec:
    """Global average pool ``[H,W,c] -> [1,1,c]`` (one output row)."""

    h_in: int
    w_in: int
    c: int


@dataclasses.dataclass(frozen=True)
class ConvStreamSpec:
    """Streaming temporal k x k conv over a ring-resident sliding window.

    The op owns a persistent state tensor ``[h_win, w_in, c_in]`` in the
    pool — the fourth lifetime class (DESIGN.md §14): it survives program
    end and is re-read at step 0 of the next invocation.  Each step
    shifts the window up by ``hop`` rows, appends the ``hop`` new frame
    rows from the chained input, writes the window back, and runs the
    full k x k conv over the window: ``[hop, w_in, c_in] ->
    [h_out, w_out, c_out]``.  A zero-initialized window makes the
    warm-up steps equal the one-shot conv's zero padding, so a filled
    window reproduces the feed-forward model exactly (bitwise in int8:
    symmetric quantization keeps zero-point 0).
    """

    h_win: int
    w_in: int
    c_in: int
    c_out: int
    k: int = 3
    stride: int = 1
    padding: str = "same"
    hop: int = 1
    activation: str | None = None

    @property
    def out_hw(self) -> tuple[int, int]:
        from .rowsched import conv_k2d_out
        return (conv_k2d_out(self.h_win, self.k, self.stride, self.padding),
                conv_k2d_out(self.w_in, self.k, self.stride, self.padding))


@dataclasses.dataclass(frozen=True)
class GRUCellSpec:
    """GRU recurrence step ``[1, d_in] -> [1, d_h]`` with the hidden
    state pool-resident across invocations (gate order z, r, n; hard
    sigmoid / hard tanh so the int8 path is a pure fixed-point Q12
    pipeline in the CMSIS-NN discipline)."""

    d_h: int


LayerSpec = Union[GemmSpec, FusedMLPSpec, ElementwiseSpec, FusedChainSpec,
                  InvertedBottleneckSpec, ConvPWSpec, ConvDWSpec,
                  ConvK2DSpec, IBModuleSpec, ResidualAddSpec, AvgPoolSpec,
                  ConvStreamSpec, GRUCellSpec]


# ---------------------------------------------------------------------------
# The IR.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PoolOp:
    """One step of a PoolProgram with its solved pool geometry.

    ``in_ptr``/``out_ptr`` are *physical* segment offsets (block-aligned
    when the program was planned with ``block_rows``); ``delta`` is the
    solved Eq.-(1)/(2) optimum ``b_In - b_Out`` (tight, pre-alignment).
    For plan-only kinds all segment quantities are in bytes
    (``segment_bytes == 1``).
    """

    kind: str
    in_ptr: int
    out_ptr: int
    delta: int
    in_segments: int
    out_segments: int
    segment_bytes: int
    d_in: int = 0
    d_out: int = 0
    activation: str | None = None
    gated: bool = False
    residual: bool = False
    d_ff: int = 0
    ff_tile: int = 0
    workspace_bytes: int = 0
    # -- whole-network op geometry (conv / pool / residual kinds) ---------
    rows_in: int = 0          # rows consumed (0 -> program.m_rows)
    rows_out: int = 0         # rows produced (0 -> program.m_rows)
    h_in: int = 0             # image geometry for conv kinds
    w_in: int = 0
    h_out: int = 0
    w_out: int = 0
    stride: int = 1
    rs: int = 0               # depthwise / k2d kernel extent
    padding: str = "same"     # conv_k2d halo convention (same/valid)
    resample: bool = False    # nearest-grid adapter row map
    d_mid: int = 0            # fused module expansion width
    aux_ptr: int = 0          # residual-source pool offset ("add" ops)
    aux_op: int = -1          # op index whose INPUT is the residual source
    in_op: int = -1           # branch convs: op index whose (held) INPUT
                              # this op reads instead of the chained tensor
    hold_input: bool = False  # input is a residual source: op must not
                              # free it; the consuming op frees it
    # -- partial execution (spatial slicing; repro.partial) ---------------
    in_row0: int = 0          # window start row within the source tensor
    h_src: int = 0            # full source image height (0 = not windowed)
    out_op: int = -1          # deferred write owner: op index that will
                              # consume the SHARED output tensor this op
                              # writes a slice of (-1 = ordinary chain)
    out_row0: int = 0         # row offset of this op's output inside that
                              # shared output tensor
    free_src: bool = False    # free the whole source record after this op
                              # (last slice's read of a held source)
    # -- streaming state (repro.stream; conv_stream / gru_cell) -----------
    state_ptr: int = 0        # pool offset of the persistent state tensor
    state_segments: int = 0   # its segment extent (0 = stateless op)
    hop: int = 0              # conv_stream: frame rows appended per step

    @property
    def rows_src(self) -> int:
        """Row extent of the op's SOURCE tensor record — the full image
        for a windowed (sliced) read, ``rows_in`` otherwise."""
        if self.h_src:
            return self.h_src * self.w_in if self.w_in else self.h_src
        return self.rows_in

    @property
    def span_segments(self) -> int:
        """Width of the live In ∪ Out window while this op runs."""
        lo = min(self.in_ptr, self.out_ptr)
        hi = max(self.in_ptr + self.in_segments,
                 self.out_ptr + self.out_segments)
        if self.aux_op >= 0:
            lo = min(lo, self.aux_ptr)
            hi = max(hi, self.aux_ptr + self.in_segments)
        return hi - lo


def op_grid_steps(op: PoolOp, row_block: int = 1) -> int:
    """Kernel grid steps ``op`` executes with ``row_block`` output rows
    fused per step.

    ``row_block == 1`` (the default) is the planner's fine-grained
    schedule — the one the sim oracle and static verifier replay and
    the certificates count.  A larger ``row_block`` is pure execution
    granularity (the blocked Pallas kernels, DESIGN.md §15): the same
    rows move in ``1/row_block`` as many steps, so per-step counters
    group by exactly that factor and every aggregate (rows read, rows
    written, bytes moved) is unchanged.
    """
    if row_block < 1:
        raise ValueError("row_block must be >= 1")
    steps = op.h_out if op.h_out else (op.rows_out or 1)
    if row_block == 1:
        return steps
    if steps % row_block:
        raise ValueError(f"row_block {row_block} does not divide the "
                         f"op's {steps} steps")
    return steps // row_block


@dataclasses.dataclass(frozen=True)
class PoolProgram:
    """An ordered list of PoolOps over one VirtualPool.

    ``pool_segments``/``pool_bytes`` — tight Eq.-(1) footprint (equals the
    legacy planners for the same shapes).  ``n_segments`` /
    ``physical_pool_bytes`` — the allocated ring length including DMA
    block-alignment padding (identical to the tight value when
    ``block_rows is None``).  Hashable, so executors jit with the program
    as a static argument.
    """

    m_rows: int
    seg_width: int
    block_rows: int | None
    n_segments: int
    pool_segments: int
    elem_bytes: int
    ops: tuple[PoolOp, ...]
    dtype: str = "float32"    # pool element dtype (DTYPE_ITEMSIZE key)

    # -- classification ----------------------------------------------------
    @property
    def executable(self) -> bool:
        return all(op.kind in EXECUTABLE_KINDS for op in self.ops)

    @property
    def quantized(self) -> bool:
        return self.dtype == "int8"

    @property
    def aligned(self) -> bool:
        return self.block_rows is not None

    # -- footprint accounting ---------------------------------------------
    @property
    def pool_bytes(self) -> int:
        op = self.ops[0]
        if op.kind in PLAN_ONLY_KINDS:
            return (max(op.in_segments + op.delta, op.out_segments)
                    + op.workspace_bytes) * op.segment_bytes
        return self.pool_segments * self.seg_width * self.elem_bytes

    @property
    def physical_pool_bytes(self) -> int:
        op = self.ops[0]
        if op.kind in PLAN_ONLY_KINDS:
            return self.pool_bytes
        return self.n_segments * self.seg_width * self.elem_bytes

    @property
    def naive_bytes(self) -> int:
        """Tensor-level footprint: worst coexisting in+out(+residual)."""
        worst = max(op.in_segments + op.out_segments
                    + (op.in_segments if op.aux_op >= 0 else 0)
                    + op.state_segments
                    for op in self.ops)
        op = self.ops[0]
        if op.kind in PLAN_ONLY_KINDS:
            return worst * op.segment_bytes
        return worst * self.seg_width * self.elem_bytes

    @property
    def saving_fraction(self) -> float:
        return 1.0 - self.pool_bytes / self.naive_bytes

    # -- I/O geometry ------------------------------------------------------
    @property
    def in_dim(self) -> int:
        return self.ops[0].d_in

    @property
    def out_dim(self) -> int:
        return self.ops[-1].d_out

    @property
    def in_rows(self) -> int:
        """Rows of the program input tensor (net programs vary per op)."""
        return self.ops[0].rows_src or self.m_rows

    @property
    def out_rows(self) -> int:
        """Rows of the program output tensor."""
        return self.ops[-1].rows_out or self.m_rows

    @property
    def input_ptr(self) -> int:
        return self.ops[0].in_ptr

    @property
    def output_ptr(self) -> int:
        return self.ops[-1].out_ptr

    def spec(self, dtype=None) -> PoolSpec:
        import jax.numpy as jnp
        return PoolSpec(self.n_segments, self.seg_width,
                        jnp.dtype(self.dtype) if dtype is None else dtype)

    def with_dtype(self, dtype: str) -> "PoolProgram":
        """The SAME solved plan re-typed for another pool element dtype.

        Segment geometry (offsets, deltas, schedules — and therefore the
        sim-oracle certificate) is dtype-independent; only the byte
        accounting changes: every op's ``segment_bytes`` and the
        program's ``elem_bytes`` are re-derived from the new itemsize.
        ``with_dtype("float32")`` of a default program is the identity,
        so legacy fp32 footprints stay bit-identical.
        """
        eb = dtype_itemsize(dtype)
        if dtype == self.dtype and eb == self.elem_bytes:
            return self
        if not self.executable:
            raise ValueError("plan-only byte-granular programs are already "
                             "int8 (segment_bytes == 1); with_dtype applies "
                             "to executable programs")
        ops = tuple(dataclasses.replace(op,
                                        segment_bytes=self.seg_width * eb)
                    for op in self.ops)
        return dataclasses.replace(self, dtype=dtype, elem_bytes=eb,
                                   ops=ops)

    # -- serialization (plan artifacts, DESIGN.md §9) ----------------------
    def to_json_dict(self) -> dict:
        """The program as a JSON-safe dict (every field is an int/str/
        bool/None) — the solved plan IS the artifact; loading it back
        never re-runs the offset solver."""
        d = dataclasses.asdict(self)     # recurses into ops already
        d["ops"] = list(d["ops"])        # tuple -> JSON array
        return d

    @classmethod
    def from_json_dict(cls, d: dict) -> "PoolProgram":
        ops = tuple(PoolOp(**op) for op in d["ops"])
        return cls(**{**{k: v for k, v in d.items() if k != "ops"},
                      "ops": ops})

    # -- validation --------------------------------------------------------
    def op_blocks(self, op: PoolOp) -> tuple[int, int]:
        """(in, out) contiguous DMA block sizes of ``op``, in segments.

        Conv-family kinds copy one image row per step; gemm/mlp/
        elementwise copy ``block_rows`` matrix rows; ``pool_avg`` reads
        image rows and writes one channel row; ``add`` streams single
        pixel rows from both sources.
        """
        sw = self.seg_width
        br = self.block_rows or 1
        ci = segments_for(op.d_in, sw)
        co = segments_for(op.d_out, sw)
        if op.kind in ("conv_pw", "conv_dw", "conv_k2d", "ib_fused",
                       "conv_stream"):
            return op.w_in * ci, op.w_out * co
        if op.kind == "pool_avg":
            return op.w_in * ci, co
        if op.kind in ("add", "gru_cell"):
            return ci, co
        return br * ci, br * co

    def check_alignment(self) -> None:
        """Assert no contiguous DMA block of any op can wrap mid-block.

        Sufficient condition (DESIGN.md §5): every pointer is a multiple of
        its op's block segment count and ``n_segments`` is a multiple of
        every block size — then ``(ptr + i*b) % n_segments`` is always
        block-aligned and ``off + b <= n_segments``.
        """
        if not self.aligned:
            raise ValueError("program was planned with block_rows=None "
                             "(tight geometry) — not DMA-block aligned")
        for op in self.ops:
            if op.kind not in EXECUTABLE_KINDS:
                continue
            bk, bn = self.op_blocks(op)
            if (op.in_ptr % bk or op.out_ptr % bn
                    or self.n_segments % math.lcm(bk, bn)
                    or (op.aux_op >= 0 and op.aux_ptr % bk)):
                raise AssertionError(f"misaligned op {op.kind} "
                                     f"({op.in_ptr},{op.out_ptr}) in pool "
                                     f"of {self.n_segments}")
            for ptr, blk, tot in ((op.in_ptr, bk, op.in_segments),
                                  (op.out_ptr, bn, op.out_segments)):
                for i in range(tot // blk):
                    off = (ptr + i * blk) % self.n_segments
                    assert off + blk <= self.n_segments, "mid-block wrap"


# ---------------------------------------------------------------------------
# The single planning front-end.
# ---------------------------------------------------------------------------

def _floor_mult(x: int, b: int) -> int:
    return (x // b) * b


def _conv_state(spec, rows: int, dim: int, img, pos: int):
    """Validate that ``spec``'s input geometry matches the running tensor."""
    if img is None:
        if rows != spec.h_in * spec.w_in:
            raise ValueError(f"layer {pos}: conv expects {spec.h_in}x"
                             f"{spec.w_in} pixel rows, program has {rows}")
    elif img != (spec.h_in, spec.w_in):
        raise ValueError(f"layer {pos}: conv image {spec.h_in}x{spec.w_in} "
                         f"!= running image {img[0]}x{img[1]}")
    c_in = spec.c if isinstance(spec, ConvDWSpec) else spec.c_in
    if dim != c_in:
        raise ValueError(f"layer {pos}: conv c_in={c_in} != running "
                         f"dim={dim}")


def plan_program(m_rows: int, d_in: int, layers: Sequence[LayerSpec], *,
                 seg_width: int = SEG_WIDTH, block_rows: int | None = None,
                 elem_bytes: int | None = None, dtype: str | None = None,
                 delta_slack: int = 0) -> PoolProgram:
    """Solve segment offsets for a layer sequence over ONE virtual pool.

    ``block_rows=None`` keeps the exact Eq.-(1) geometry (``sim``/``jnp``
    backends); an integer plans DMA-block-aligned geometry executable on
    the ``pallas`` backend too (deltas only ever rounded *up* — safety is
    preserved; ``pool_segments`` still reports the tight footprint).
    Conv-family specs (whole-network programs) use one image row as their
    DMA block regardless of ``block_rows``.

    ``dtype`` sets the pool element type the byte accounting uses
    (``"int8"`` programs report ``pool_bytes`` at 1 byte/element — the
    deployable MCU footprint); segment geometry itself is
    dtype-independent.  ``elem_bytes`` defaults to the dtype's itemsize
    and may not contradict it.

    Residual modules (:class:`ResidualAddSpec`) make the planner *hold*
    the source tensor: every op between the source and the add places its
    output clear of the held interval, and the add op records the source
    location as ``aux_ptr``.

    ``delta_slack`` exists for tightness testing only: it shrinks every
    solved delta, so ``delta_slack=1`` must make the ``sim`` backend raise
    :class:`repro.core.pool.PoolClobberError` (the plans are exact optima).
    """
    from . import rowsched

    if dtype is None:   # legacy elem_bytes-only callers: derive the label
        dtype = (_DTYPE_FOR_BYTES.get(elem_bytes, "float32")
                 if elem_bytes is not None else "float32")
    if elem_bytes is None:
        elem_bytes = dtype_itemsize(dtype)
    elif elem_bytes != dtype_itemsize(dtype):
        raise ValueError(f"elem_bytes={elem_bytes} contradicts "
                         f"dtype={dtype!r} "
                         f"(itemsize {dtype_itemsize(dtype)})")
    layers = list(layers)
    if not layers:
        raise ValueError("need at least one layer spec")
    if any(isinstance(s, (FusedChainSpec, InvertedBottleneckSpec))
           for s in layers):
        if len(layers) != 1:
            raise ValueError("byte-granular plan-only specs (FusedChainSpec/"
                             "InvertedBottleneckSpec) must be the sole layer")
        return _plan_analytic(m_rows, d_in, layers[0])

    aligned = block_rows is not None
    br = block_rows if aligned else 1
    if br <= 0:
        raise ValueError(f"block_rows={block_rows} must be positive")

    # Pre-scan residual adds AND branch convs (input_from): ops in
    # (src..consumer] must avoid the held tensor; the held interval stays
    # in the live span through its consumer.
    aux_src: dict[int, int] = {}
    in_src: dict[int, int] = {}
    avoid_at: list[set[int]] = [set() for _ in layers]
    hold_at: list[set[int]] = [set() for _ in layers]
    for i, s in enumerate(layers):
        if isinstance(s, ResidualAddSpec):
            j = i - s.src
            if j < 0:
                raise ValueError(f"layer {i}: residual source {s.src} ops "
                                 "back reaches before the program input")
            aux_src[i] = j
            for k in range(j, i):
                avoid_at[k].add(j)
            for k in range(j, i + 1):
                hold_at[k].add(j)
        elif getattr(s, "input_from", 0):
            j = i - s.input_from
            if j < 0:
                raise ValueError(f"layer {i}: input_from {s.input_from} "
                                 "ops back reaches before the program "
                                 "input")
            in_src[i] = j
            for k in range(j, i):
                avoid_at[k].add(j)
            for k in range(j, i + 1):
                hold_at[k].add(j)
    # (consumer, held-record) pairs.  Op ``p`` must not free the tensor
    # it READS — record ``in_src.get(p, p)`` — iff a LATER consumer
    # still needs that record; the consumer frees it itself.
    holders = list(aux_src.items()) + list(in_src.items())

    def _hold_input(p: int) -> bool:
        r = in_src.get(p, p)
        return any(j == r and i > p for i, j in holders)

    ops: list[PoolOp] = []
    rows, cur, img = m_rows, d_in, None
    pt = 0   # tight running pointer
    pa = 0   # aligned running pointer
    spans_t: list[int] = []
    spans_a: list[int] = []
    aligns: list[int] = [1]
    # per-op CHAINED input tensor record (tight ptr, aligned ptr, total
    # segments) — for branch ops (input_from) this stays the chained
    # tensor that remains resident, NOT the held tensor the op reads
    tens: list[tuple[int, int, int]] = []
    # persistent-state demands: (op index, state segments, chunk align)
    state_needs: list[tuple[int, int, int]] = []
    # chain state (rows, dim, image) entering each op
    states: list[tuple[int, int, tuple | None]] = []

    def _avoid(out, out_tot, pos, coord, round_to=None, cur=None):
        """Push ``out`` below every held interval it overlaps.

        ``cur`` is the in-flight record of the op being planned (its own
        input may be the held tensor — it is not in ``tens`` yet)."""
        for _ in range(len(avoid_at[pos]) + 1):
            moved = False
            for j in sorted(avoid_at[pos]):
                rec = cur if j == len(tens) else tens[j]
                lo = rec[coord]
                hi = lo + rec[2]
                if out < hi and out + out_tot > lo:
                    out = lo - out_tot + delta_slack
                    if round_to:
                        out = _floor_mult(out, round_to)
                    moved = True
            if not moved:
                break
        return out

    for pos, spec in enumerate(layers):
        if isinstance(spec, (GemmSpec, FusedMLPSpec)):
            resolve_activation(spec.activation)  # fail at plan time
        elif isinstance(spec, ElementwiseSpec):
            resolve_activation(spec.fn)
        elif isinstance(spec, (ConvPWSpec, ConvDWSpec, ConvK2DSpec,
                               ConvStreamSpec, ResidualAddSpec)):
            resolve_activation(spec.activation)
        states.append((rows, cur, img))
        rows_in = rows
        it, ia = pt, pa
        extra: dict = {}
        src_j = in_src.get(pos)
        if src_j is not None:
            if not isinstance(spec, (ConvPWSpec, ConvK2DSpec)):
                raise TypeError(f"layer {pos}: input_from is only "
                                "supported on ConvPWSpec/ConvK2DSpec")
            # the op reads the HELD input of op src_j; the chained
            # tensor stays resident at (pt, pa) for a later consumer
            it, ia = tens[src_j][0], tens[src_j][1]
        if isinstance(spec, GemmSpec):
            if rows % br:
                raise ValueError(f"block_rows={br} must divide rows={rows}")
            k_segs = segments_for(cur, seg_width)
            n_segs = segments_for(spec.d_out, seg_width)
            bk, bn = br * k_segs, br * n_segs
            delta = (gemm_offset_closed_form(rows, n_segs, k_segs)
                     - delta_slack)
            in_tot, out_tot = rows * k_segs, rows * n_segs
            ot = _avoid(pt - delta, out_tot, pos, 0,
                        cur=(it, ia, in_tot))
            if not aligned:
                oa = ot
            elif pos == 0:
                # First op: both tensors are still placeable — pick the
                # cheaper of "shift In up to a bk multiple" (the legacy
                # aligned_pool_geometry choice) and "shift Out down to a
                # bn multiple".
                gap_k = ceil_div(max(delta, 0), bk) * bk
                gap_n = ceil_div(max(delta, 0), bn) * bn
                ia, oa = ((gap_k, 0) if gap_k <= gap_n else (0, -gap_n))
                oa = _avoid(oa, out_tot, pos, 1, round_to=bn,
                            cur=(it, ia, in_tot))
            else:
                oa = _avoid(_floor_mult(pa - delta, bn), out_tot, pos, 1,
                            round_to=bn, cur=(it, ia, in_tot))
            kind, d_out = "gemm", spec.d_out
            extra = dict(activation=spec.activation, rows_in=rows,
                         rows_out=rows)
            aligns.append(math.lcm(bk, bn))
            new_state = (rows, spec.d_out, None if img is None else img)
        elif isinstance(spec, (FusedMLPSpec, ElementwiseSpec)):
            if rows % br:
                raise ValueError(f"block_rows={br} must divide rows={rows}")
            d_segs = segments_for(cur, seg_width)
            bd = br * d_segs
            delta = -delta_slack  # Eq.-(2) optimum for these chains is 0
            ot = pt - delta
            oa = pa if (not aligned or delta == 0) else pa - delta
            in_tot = out_tot = rows * d_segs
            kind, d_out = ("fused_mlp" if isinstance(spec, FusedMLPSpec)
                           else "elementwise"), cur
            if isinstance(spec, FusedMLPSpec):
                if spec.d_ff % spec.ff_tile:
                    raise ValueError(f"ff_tile={spec.ff_tile} must divide "
                                     f"d_ff={spec.d_ff}")
                extra = dict(activation=spec.activation, gated=spec.gated,
                             residual=spec.residual, d_ff=spec.d_ff,
                             ff_tile=spec.ff_tile, rows_in=rows,
                             rows_out=rows)
            else:
                extra = dict(activation=spec.fn, rows_in=rows,
                             rows_out=rows)
            aligns.append(bd)
            new_state = (rows, cur, img)
        elif isinstance(spec, (ConvPWSpec, ConvDWSpec, ConvK2DSpec)):
            if src_j is not None:   # branch conv: validate vs held state
                v_rows, v_dim, v_img = states[src_j]
            else:
                v_rows, v_dim, v_img = rows, cur, img
            _conv_state(spec, v_rows, v_dim, v_img, pos)
            h_in, w_in = spec.h_in, spec.w_in
            h_out, w_out = spec.out_hw
            c_in = spec.c if isinstance(spec, ConvDWSpec) else spec.c_in
            c_out = spec.c if isinstance(spec, ConvDWSpec) else spec.c_out
            ci = segments_for(c_in, seg_width)
            co = segments_for(c_out, seg_width)
            in_chunk, out_chunk = w_in * ci, w_out * co
            if isinstance(spec, ConvPWSpec):
                sched = rowsched.conv_pw_schedule(
                    h_in, h_out, in_chunk, out_chunk, stride=spec.stride,
                    resample=spec.resample_to is not None)
                kind = "conv_pw"
                extra = dict(activation=spec.activation, stride=spec.stride,
                             resample=spec.resample_to is not None)
            elif isinstance(spec, ConvK2DSpec):
                sched = rowsched.conv_k2d_schedule(
                    h_in, h_out, in_chunk, out_chunk, k=spec.k,
                    stride=spec.stride, padding=spec.padding)
                kind = "conv_k2d"
                extra = dict(activation=spec.activation, stride=spec.stride,
                             rs=spec.k, padding=spec.padding)
            else:
                sched = rowsched.conv_dw_schedule(
                    h_in, h_out, in_chunk, out_chunk, rs=spec.rs,
                    stride=spec.stride)
                kind = "conv_dw"
                extra = dict(activation=spec.activation, stride=spec.stride,
                             rs=spec.rs)
            delta = sched.solve_delta() - delta_slack
            in_tot, out_tot = h_in * w_in * ci, h_out * w_out * co
            if src_j is not None:
                # the in-flight avoid record is the CHAINED tensor (it
                # stays resident for a later consumer, e.g. the add)
                chain_rec = (pt, pa, rows * segments_for(cur, seg_width))
                extra["in_op"] = src_j
            else:
                chain_rec = (it, ia, in_tot)
            ot = _avoid(it - delta, out_tot, pos, 0, cur=chain_rec)
            oa = (ot if not aligned else
                  _avoid(_floor_mult(ia - delta, out_chunk), out_tot, pos,
                         1, round_to=out_chunk, cur=chain_rec))
            d_out = c_out
            extra.update(h_in=h_in, w_in=w_in, h_out=h_out, w_out=w_out,
                         rows_in=v_rows, rows_out=h_out * w_out)
            aligns.append(math.lcm(in_chunk, out_chunk))
            new_state = (h_out * w_out, c_out, (h_out, w_out))
        elif isinstance(spec, ConvStreamSpec):
            if spec.hop <= 0 or spec.h_win % spec.hop:
                raise ValueError(f"layer {pos}: hop={spec.hop} must divide "
                                 f"h_win={spec.h_win}")
            frame_rows = spec.hop * spec.w_in
            if img is None:
                if rows != frame_rows:
                    raise ValueError(f"layer {pos}: conv_stream expects a "
                                     f"{spec.hop}x{spec.w_in} frame, "
                                     f"program has {rows} rows")
            elif img != (spec.hop, spec.w_in):
                raise ValueError(f"layer {pos}: conv_stream frame "
                                 f"{spec.hop}x{spec.w_in} != running image "
                                 f"{img[0]}x{img[1]}")
            if cur != spec.c_in:
                raise ValueError(f"layer {pos}: conv_stream c_in="
                                 f"{spec.c_in} != running dim={cur}")
            h_out, w_out = spec.out_hw
            ci = segments_for(spec.c_in, seg_width)
            co = segments_for(spec.c_out, seg_width)
            in_chunk, out_chunk = spec.w_in * ci, w_out * co
            sched = rowsched.conv_stream_schedule(spec.hop, h_out, in_chunk,
                                                  out_chunk)
            delta = sched.solve_delta() - delta_slack
            in_tot, out_tot = frame_rows * ci, h_out * w_out * co
            ot = _avoid(it - delta, out_tot, pos, 0, cur=(it, ia, in_tot))
            oa = (ot if not aligned else
                  _avoid(_floor_mult(ia - delta, out_chunk), out_tot, pos,
                         1, round_to=out_chunk, cur=(it, ia, in_tot)))
            kind, d_out = "conv_stream", spec.c_out
            extra = dict(activation=spec.activation, stride=spec.stride,
                         rs=spec.k, padding=spec.padding, hop=spec.hop,
                         h_in=spec.h_win, w_in=spec.w_in, h_out=h_out,
                         w_out=w_out, rows_in=frame_rows,
                         rows_out=h_out * w_out)
            state_needs.append((pos, spec.h_win * spec.w_in * ci, in_chunk))
            aligns.append(math.lcm(in_chunk, out_chunk))
            new_state = (h_out * w_out, spec.c_out, (h_out, w_out))
        elif isinstance(spec, GRUCellSpec):
            if rows != 1:
                raise ValueError(f"layer {pos}: gru_cell expects a single "
                                 f"row, program has {rows}")
            ci = segments_for(cur, seg_width)
            co = segments_for(spec.d_h, seg_width)
            sched = rowsched.gru_cell_schedule(ci, co)
            delta = sched.solve_delta() - delta_slack
            in_tot, out_tot = ci, co
            ot = _avoid(it - delta, out_tot, pos, 0, cur=(it, ia, in_tot))
            oa = (ot if not aligned else
                  _avoid(_floor_mult(ia - delta, co), out_tot, pos, 1,
                         round_to=co, cur=(it, ia, in_tot)))
            kind, d_out = "gru_cell", spec.d_h
            extra = dict(rows_in=1, rows_out=1)
            state_needs.append((pos, co, co))
            aligns.append(math.lcm(ci, co))
            new_state = (1, spec.d_h, None)
        elif isinstance(spec, IBModuleSpec):
            cfg = spec.cfg
            if any(s != 1 for s in cfg.strides):
                raise ValueError("IBModuleSpec (fused execution) is "
                                 "stride-1 only; lower strided modules "
                                 "unfused")
            if (segments_for(cfg.c_in, seg_width) != 1
                    or segments_for(cfg.c_out, seg_width) != 1):
                raise ValueError("ib_fused needs one segment per pixel "
                                 f"(c_in={cfg.c_in}, c_out={cfg.c_out}, "
                                 f"seg_width={seg_width})")
            h = w = cfg.hw
            if img is None:
                if rows != h * w:
                    raise ValueError(f"layer {pos}: module expects {h}x{w} "
                                     f"pixel rows, program has {rows}")
            elif img != (h, w):
                raise ValueError(f"layer {pos}: module image {h}x{w} != "
                                 f"running image {img}")
            if cur != cfg.c_in:
                raise ValueError(f"layer {pos}: module c_in={cfg.c_in} != "
                                 f"running dim={cur}")
            sched = rowsched.ib_fused_schedule(h, w, w, rs=cfg.rs,
                                               residual=cfg.has_residual)
            delta = sched.solve_delta() - delta_slack
            in_tot = out_tot = h * w
            ot = _avoid(pt - delta, out_tot, pos, 0,
                        cur=(it, ia, in_tot))
            oa = (ot if not aligned else
                  _avoid(_floor_mult(pa - delta, w), out_tot, pos, 1,
                         round_to=w, cur=(it, ia, in_tot)))
            kind, d_out = "ib_fused", cfg.c_out
            extra = dict(h_in=h, w_in=w, h_out=h, w_out=w, rs=cfg.rs,
                         residual=cfg.has_residual, d_mid=cfg.c_mid,
                         rows_in=rows, rows_out=rows)
            aligns.append(w)
            new_state = (rows, cfg.c_out, (h, w))
        elif isinstance(spec, ResidualAddSpec):
            j = aux_src[pos]
            src_rows, src_dim, _src_img = states[j]
            if src_rows != rows or src_dim != cur:
                raise ValueError(f"layer {pos}: residual source shape "
                                 f"({src_rows},{src_dim}) != current "
                                 f"({rows},{cur})")
            d_segs = segments_for(cur, seg_width)
            delta = -delta_slack
            ot, oa = pt - delta, pa + delta_slack
            in_tot = out_tot = rows * d_segs
            kind, d_out = "add", cur
            extra = dict(rows_in=rows, rows_out=rows,
                         activation=spec.activation,
                         aux_op=j, aux_ptr=tens[j][0 if not aligned else 1])
            aligns.append(d_segs)
            new_state = (rows, cur, img)
        elif isinstance(spec, AvgPoolSpec):
            _conv_state_pool(spec, rows, cur, img, pos)
            ci = segments_for(spec.c, seg_width)
            in_chunk, out_chunk = spec.w_in * ci, ci
            sched = rowsched.avgpool_schedule(spec.h_in, in_chunk,
                                              out_chunk)
            delta = sched.solve_delta() - delta_slack
            in_tot, out_tot = spec.h_in * spec.w_in * ci, ci
            ot = _avoid(pt - delta, out_tot, pos, 0,
                        cur=(it, ia, in_tot))
            oa = (ot if not aligned else
                  _avoid(_floor_mult(pa - delta, out_chunk), out_tot, pos,
                         1, round_to=out_chunk, cur=(it, ia, in_tot)))
            kind, d_out = "pool_avg", spec.c
            extra = dict(h_in=spec.h_in, w_in=spec.w_in, h_out=1, w_out=1,
                         rows_in=rows, rows_out=1)
            aligns.append(math.lcm(in_chunk, out_chunk))
            new_state = (1, spec.c, (1, 1))
        else:
            raise TypeError(f"unknown layer spec {spec!r}")

        if not aligned:
            ia, oa = it, ot
        op = PoolOp(kind=kind, in_ptr=ia, out_ptr=oa, delta=delta,
                    in_segments=in_tot, out_segments=out_tot,
                    segment_bytes=seg_width * elem_bytes,
                    d_in=states[src_j][1] if src_j is not None else cur,
                    d_out=d_out, hold_input=_hold_input(pos), **extra)
        if src_j is not None:
            tens.append(chain_rec)   # the chained tensor, not the held one
        else:
            tens.append((it, ia, in_tot))
        # Live span at this op: In, Out and every held residual interval.
        lo_t, hi_t = min(it, ot), max(it + in_tot, ot + out_tot)
        lo_a, hi_a = min(ia, oa), max(ia + in_tot, oa + out_tot)
        for j in hold_at[pos]:
            lo_t = min(lo_t, tens[j][0])
            hi_t = max(hi_t, tens[j][0] + tens[j][2])
            lo_a = min(lo_a, tens[j][1])
            hi_a = max(hi_a, tens[j][1] + tens[j][2])
        spans_t.append(hi_t - lo_t)
        spans_a.append(hi_a - lo_a)
        ops.append(op)
        pt, pa = ot, oa
        rows, cur, img = new_state

    pool_segments = max(spans_t)

    if aligned:
        align = math.lcm(*aligns)
        n_segments = ceil_div(max(spans_a), align) * align
        base = min(min(op.in_ptr, op.out_ptr) for op in ops)
        shift = -_floor_mult(base, align) if base < 0 else 0
    else:
        n_segments = pool_segments
        base = min(min(op.in_ptr, op.out_ptr) for op in ops)
        shift = -base
    if shift:
        ops = [dataclasses.replace(
                   op, in_ptr=op.in_ptr + shift, out_ptr=op.out_ptr + shift,
                   aux_ptr=op.aux_ptr + shift if op.aux_op >= 0 else 0)
               for op in ops]

    if state_needs:
        # Persistent state pins the ring's origin across invocations, so
        # the frame program must be WRAP-FREE — the infinite-horizon form
        # of the Eq.-(2) avoid constraint: a held interval avoided by
        # every op of every future step degenerates to "past the linear
        # extent of all frame traffic".  The modulus grows to the linear
        # extent and states are carved out above it; frame accesses then
        # never reduce into a state interval, by construction (the
        # static verifier re-proves this, VMCU211/213).
        ext = n_segments
        for op in ops:
            ext = max(ext, op.in_ptr + op.in_segments,
                      op.out_ptr + op.out_segments)
            if op.aux_op >= 0:
                ext = max(ext, op.aux_ptr + op.in_segments)
        repl: dict[int, tuple[int, int]] = {}
        for op_i, segs_n, chunk in state_needs:
            if aligned and ext % chunk:
                ext = ceil_div(ext, chunk) * chunk
            repl[op_i] = (ext, segs_n)
            ext += segs_n
        pool_segments = ext
        n_segments = (ceil_div(ext, math.lcm(*aligns)) * math.lcm(*aligns)
                      if aligned else ext)
        ops = [dataclasses.replace(op, state_ptr=repl[i][0],
                                   state_segments=repl[i][1])
               if i in repl else op
               for i, op in enumerate(ops)]

    return PoolProgram(m_rows=m_rows, seg_width=seg_width,
                       block_rows=block_rows, n_segments=n_segments,
                       pool_segments=pool_segments, elem_bytes=elem_bytes,
                       dtype=dtype, ops=tuple(ops))


def _conv_state_pool(spec, rows, dim, img, pos):
    if img is None:
        if rows != spec.h_in * spec.w_in:
            raise ValueError(f"layer {pos}: pool expects {spec.h_in}x"
                             f"{spec.w_in} pixel rows, program has {rows}")
    elif img != (spec.h_in, spec.w_in):
        raise ValueError(f"layer {pos}: pool image mismatch")
    if dim != spec.c:
        raise ValueError(f"layer {pos}: pool c={spec.c} != dim={dim}")


# ---------------------------------------------------------------------------
# Byte-granular plan-only programs (Eq. 2 analytic plans).
# ---------------------------------------------------------------------------

def _plan_analytic(m_rows: int, d_in: int, spec) -> PoolProgram:
    from .graph_planner import plan_fc_chain, plan_inverted_bottleneck
    if isinstance(spec, FusedChainSpec):
        dims = [d_in, *spec.dims]
        fp = plan_fc_chain(m_rows, dims, elem_bytes=spec.elem_bytes,
                           rows_per_step=spec.rows_per_step)
        op = PoolOp(kind="fused_chain", in_ptr=fp.delta_bytes, out_ptr=0,
                    delta=fp.delta_bytes, in_segments=fp.input_bytes,
                    out_segments=fp.output_bytes, segment_bytes=1,
                    d_in=d_in, d_out=dims[-1],
                    workspace_bytes=fp.workspace_bytes)
    else:
        fp = plan_inverted_bottleneck(spec.cfg, spec.workspace)
        op = PoolOp(kind="inverted_bottleneck", in_ptr=fp.delta_bytes,
                    out_ptr=0, delta=fp.delta_bytes,
                    in_segments=fp.input_bytes,
                    out_segments=fp.output_bytes, segment_bytes=1,
                    d_in=spec.cfg.c_in, d_out=spec.cfg.c_out,
                    workspace_bytes=fp.workspace_bytes)
    pool_bytes = (max(op.in_segments + op.delta, op.out_segments)
                  + op.workspace_bytes)
    return PoolProgram(m_rows=m_rows, seg_width=1, block_rows=None,
                       n_segments=pool_bytes, pool_segments=pool_bytes,
                       elem_bytes=1, dtype="byte", ops=(op,))


def plan_module_program(cfg, workspace: str = "paper_11seg") -> PoolProgram:
    """One-op program for a fused inverted-bottleneck module (Fig. 6).

    ``pool_bytes`` equals ``plan_inverted_bottleneck(cfg).pool_bytes``."""
    return plan_program(cfg.hw * cfg.hw, cfg.c_in,
                        [InvertedBottleneckSpec(cfg, workspace)])


def plan_stream_chain_program(m_rows: int, dims: Sequence[int], *,
                              rows_per_step: int = 1,
                              elem_bytes: int = 2) -> PoolProgram:
    """One-op program for a whole-chain streaming fusion (Eq. 2).

    ``pool_bytes`` equals ``plan_fc_chain(m_rows, dims, ...).pool_bytes``."""
    return plan_program(m_rows, dims[0],
                        [FusedChainSpec(tuple(dims[1:]),
                                        rows_per_step=rows_per_step,
                                        elem_bytes=elem_bytes)])


# ---------------------------------------------------------------------------
# Multi-program composition.
# ---------------------------------------------------------------------------

def concat_programs(programs: Sequence[PoolProgram]) -> PoolProgram:
    """Chain programs over ONE pool: program ``i+1``'s input is placed
    exactly where program ``i``'s output landed, so consecutive programs
    overlap in the ring instead of each resetting the pool.

    The merged pool length is the *largest* single-program live span, not
    the sum — the whole point of cross-boundary Eq.-(1)/(2) chaining.
    Aligned programs concatenate only when the required shift lands on
    every op's DMA block (plan the whole net in one :func:`plan_program`
    call otherwise — this hook is for composing independently planned
    stages).
    """
    programs = list(programs)
    if not programs:
        raise ValueError("need at least one program")
    base = programs[0]
    if any(p.seg_width != base.seg_width or p.elem_bytes != base.elem_bytes
           or p.dtype != base.dtype for p in programs):
        raise ValueError("programs must share seg_width, elem_bytes and "
                         "dtype")
    aligned = base.aligned
    if any(p.aligned != aligned for p in programs):
        raise ValueError("cannot mix aligned and tight programs")
    if not all(p.executable for p in programs):
        raise ValueError("plan-only programs cannot be concatenated")

    align_all = 1
    if aligned:
        for p in programs:
            for op in p.ops:
                align_all = math.lcm(align_all, math.lcm(*p.op_blocks(op)))

    merged: list[PoolOp] = []
    cursor = None  # previous program's (shifted) output pointer
    prev_p = None
    for p in programs:
        if cursor is None:
            shift = 0
        else:
            if prev_p.out_rows != p.in_rows or prev_p.out_dim != p.in_dim:
                raise ValueError(
                    f"program boundary mismatch: {prev_p.out_rows} rows x "
                    f"{prev_p.out_dim} -> {p.in_rows} rows x {p.in_dim}")
            shift = cursor - p.input_ptr
            if aligned and shift % align_all:
                raise ValueError(
                    f"aligned concat needs a shift multiple of {align_all} "
                    f"(got {shift}); plan the chain in one plan_program "
                    "call instead")
        idx0 = len(merged)
        for op in p.ops:
            merged.append(dataclasses.replace(
                op, in_ptr=op.in_ptr + shift, out_ptr=op.out_ptr + shift,
                aux_ptr=op.aux_ptr + shift if op.aux_op >= 0 else 0,
                aux_op=op.aux_op + idx0 if op.aux_op >= 0 else -1))
        cursor = merged[-1].out_ptr
        prev_p = p

    pool_segments = max(p.pool_segments for p in programs)
    if aligned:
        n_segments = ceil_div(max(p.n_segments for p in programs),
                              align_all) * align_all
    else:
        n_segments = pool_segments
    lo = min(min(op.in_ptr, op.out_ptr) for op in merged)
    shift = (-_floor_mult(lo, align_all) if aligned and lo < 0
             else (-lo if lo < 0 else 0))
    if shift:
        merged = [dataclasses.replace(
            op, in_ptr=op.in_ptr + shift, out_ptr=op.out_ptr + shift,
            aux_ptr=op.aux_ptr + shift if op.aux_op >= 0 else 0)
            for op in merged]
    return PoolProgram(m_rows=base.m_rows, seg_width=base.seg_width,
                       block_rows=base.block_rows, n_segments=n_segments,
                       pool_segments=pool_segments,
                       elem_bytes=base.elem_bytes, dtype=base.dtype,
                       ops=tuple(merged))
