"""Multi-layer (fused) segment planning — vMCU Eq. (2).

For a producer/consumer chain executed as ONE streaming kernel, the pool
holds the chain *input* and the chain *output* (overlapped at a solved
offset) plus a small constant workspace for the intermediate tensors — the
paper's inverted-bottleneck kernel (Fig. 6, 11-segment workspace).

The generic solver below reduces Eq. (2) to the same scan as Eq. (1): walk
the fused iteration domain (output pixels in row-major order), track

  * ``w_end(t)``   — running max of output *byte* write-end addresses,
  * ``r_min(>=t)`` — min over current-and-future iterations of the lowest
                     input byte still needed (reverse minimum accumulate),

and the minimal input/output offset is ``delta = max_t [w_end(<=t) −
r_min(>t)]`` (writes at t happen after reads at t).  This generalizes the
single-layer scan to arbitrary read frontiers (conv halos, residual reads).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

import numpy as np

WorkspacePolicy = Literal["paper_11seg", "row_cache"]


def solve_stream_offset(write_end: np.ndarray, read_start: np.ndarray) -> int:
    """Minimal byte offset ``b_In − b_Out`` for a streaming schedule.

    ``write_end[t]``  — one past the last output byte written at step t.
    ``read_start[t]`` — lowest input byte address step t still needs.
    Both relative to their tensor's base (b_Out / b_In).
    """
    if len(write_end) != len(read_start):
        raise ValueError("schedules must have equal length")
    w_run = np.maximum.accumulate(write_end)
    # lowest input byte needed at any step >= t
    r_future = np.minimum.accumulate(read_start[::-1])[::-1]
    # writes at step t land after reads at step t: compare against r_future
    # shifted by one (reads strictly after t). The final step has no future
    # readers — its write only needs to stay inside the pool.
    r_next = np.empty_like(r_future)
    r_next[:-1] = r_future[1:]
    r_next[-1] = np.iinfo(np.int64).max // 4
    return int(max(0, np.max(w_run - r_next)))


@dataclasses.dataclass(frozen=True)
class ModuleConfig:
    """An inverted-bottleneck module (paper Table 2 row)."""

    name: str
    hw: int          # input image height == width
    c_in: int
    c_mid: int
    c_out: int
    rs: int          # depthwise kernel size (R == S)
    strides: tuple[int, int, int]  # (pw1, dw, pw2)
    elem_bytes: int = 1  # int8 quantized

    @property
    def has_residual(self) -> bool:
        return (self.c_in == self.c_out
                and all(s == 1 for s in self.strides))

    def spatial(self) -> tuple[int, int, int]:
        """(input hw, post-pw1 hw, output hw) with 'same' padding for DW."""
        h0 = self.hw
        h1 = -(-h0 // self.strides[0])
        h2 = -(-h1 // self.strides[1])
        h3 = -(-h2 // self.strides[2])
        return h0, h1, h3

    @property
    def input_bytes(self) -> int:
        return self.hw * self.hw * self.c_in * self.elem_bytes

    @property
    def output_bytes(self) -> int:
        _, _, h_out = self.spatial()
        return h_out * h_out * self.c_out * self.elem_bytes


@dataclasses.dataclass(frozen=True)
class FusedPlan:
    delta_bytes: int
    workspace_bytes: int
    input_bytes: int
    output_bytes: int

    @property
    def pool_bytes(self) -> int:
        return (max(self.input_bytes + self.delta_bytes, self.output_bytes)
                + self.workspace_bytes)


def plan_inverted_bottleneck(cfg: ModuleConfig,
                             workspace: WorkspacePolicy = "paper_11seg",
                             ) -> FusedPlan:
    """Plan the fused PW→DW→PW(→add) kernel of paper Fig. 6.

    Iterates output pixels of E in row-major order; per pixel the kernel
    needs a DW halo of B pixels, which pull an A halo through PW1's stride.
    """
    h0, h1, h2 = cfg.spatial()
    s1, s2, s3 = cfg.strides
    pad = (cfg.rs - 1) // 2
    eb = cfg.elem_bytes

    p = np.arange(h2 * h2, dtype=np.int64)
    ep, eq = p // h2, p % h2
    # E pixel (ep, eq) <- D (stride s3) <- C pixel (s3*ep, s3*eq)
    cp, cq = ep * s3, eq * s3
    # C pixel <- DW window over B rows s2*cp - pad .. s2*cp - pad + rs - 1
    bp_lo = np.maximum(cp * s2 - pad, 0)
    bq_lo = np.maximum(cq * s2 - pad, 0)
    # B pixel <- PW1 (stride s1) <- A pixel (s1*bp, s1*bq)
    ap_lo, aq_lo = bp_lo * s1, bq_lo * s1
    read_start = (ap_lo * cfg.hw + aq_lo) * cfg.c_in * eb
    if cfg.has_residual:  # residual reads A[ep, eq] — never below the halo
        res_start = (ep * cfg.hw + eq) * cfg.c_in * eb
        read_start = np.minimum(read_start, res_start)
    write_end = (p + 1) * cfg.c_out * eb

    delta = solve_stream_offset(write_end, read_start)

    if workspace == "paper_11seg":
        # RS x RS segments of B + 1 of C + 1 of D (Fig. 6): segment = one
        # channel vector of the respective tensor.
        ws = (cfg.rs * cfg.rs * cfg.c_mid + cfg.c_mid + cfg.c_out) * eb
    else:  # row_cache: RS rows of B cached to avoid PW1 recompute
        ws = (cfg.rs * h1 * cfg.c_mid + cfg.c_mid + cfg.c_out) * eb

    return FusedPlan(delta_bytes=delta, workspace_bytes=ws,
                     input_bytes=cfg.input_bytes,
                     output_bytes=cfg.output_bytes)


def plan_fc_chain(M: int, dims: list[int], *, elem_bytes: int = 2,
                  rows_per_step: int = 1) -> FusedPlan:
    """Plan a fused chain of fully-connected layers
    ``X[M,d0] -> H1[M,d1] -> ... -> Y[M,dL]`` streamed ``rows_per_step`` rows
    at a time (the transformer-MLP analogue of the inverted bottleneck: the
    intermediates live in a workspace of one row-block each and are never
    materialized).
    """
    if len(dims) < 2:
        raise ValueError("need at least input and output dims")
    d_in, d_out = dims[0], dims[-1]
    steps = -(-M // rows_per_step)
    t = np.arange(steps, dtype=np.int64)
    rows_done = np.minimum((t + 1) * rows_per_step, M)
    read_start = t * rows_per_step * d_in * elem_bytes
    write_end = rows_done * d_out * elem_bytes
    delta = solve_stream_offset(write_end, read_start)
    ws = sum(dims[1:-1]) * rows_per_step * elem_bytes
    return FusedPlan(delta_bytes=delta, workspace_bytes=ws,
                     input_bytes=M * d_in * elem_bytes,
                     output_bytes=M * d_out * elem_bytes)


def plan_module_fallback(cfg: ModuleConfig) -> int:
    """Per-layer (unfused) vMCU plan: single-layer segment overlap applied
    to each conv, residual source held live.  The paper itself falls back
    to this when fusion is unsuitable (e.g. its B18: 7x7 kernel on a 6x6
    image); with tiny spatial extents the R·S workspace of the fused kernel
    can exceed the fusion win."""
    from .planner import plan_pointwise_conv
    h0, h1, h2 = cfg.spatial()
    eb = cfg.elem_bytes
    sa = h0 * h0 * cfg.c_in * eb
    sb = h1 * h1 * cfg.c_mid * eb
    h_dw = -(-h1 // cfg.strides[1])
    sc = h_dw * h_dw * cfg.c_mid * eb
    sd = h2 * h2 * cfg.c_out * eb
    res = sa if cfg.has_residual else 0
    # PW1: input A must stay live when it feeds the residual — no overlap.
    if cfg.has_residual:
        pw1 = sa + sb
    else:
        pw1 = plan_pointwise_conv(h0, h0, cfg.c_in, cfg.c_mid,
                                  stride=cfg.strides[0],
                                  elem_bytes=eb).pool_bytes
    dw = res + sb                        # depthwise in-place (+ held A)
    pw2 = res + plan_pointwise_conv(h_dw, h_dw, cfg.c_mid, cfg.c_out,
                                    stride=cfg.strides[2],
                                    elem_bytes=eb).pool_bytes
    add = res + sd                       # in-place add
    return max(pw1, dw, pw2, add)


def vmcu_module_bytes(cfg: ModuleConfig,
                      workspace: WorkspacePolicy = "paper_11seg") -> int:
    """vMCU's choice per module: fused streaming kernel where it wins,
    per-layer segment planning otherwise (paper §7.3 exclusion rule)."""
    return min(plan_inverted_bottleneck(cfg, workspace).pool_bytes,
               plan_module_fallback(cfg))


# ---------------------------------------------------------------------------
# Tensor-level baselines (paper §7 comparisons) at module granularity.
# ---------------------------------------------------------------------------

def tinyengine_module_bytes(cfg: ModuleConfig) -> int:
    """TinyEngine-style: per-layer buffers, in-place DW, residual add fused
    into PW2's epilogue (A stays live through the module when residual)."""
    h0, h1, h2 = cfg.spatial()
    eb = cfg.elem_bytes
    sa = h0 * h0 * cfg.c_in * eb
    sb = h1 * h1 * cfg.c_mid * eb
    h_dw = -(-h1 // cfg.strides[1])
    sc = h_dw * h_dw * cfg.c_mid * eb
    sd = h2 * h2 * cfg.c_out * eb
    res = sa if cfg.has_residual else 0
    phases = [
        sa + sb,            # PW1: A, B live
        sb + res,           # DW in-place inside B's buffer
        sc + sd + res,      # PW2: C, D live (+A held for residual)
    ]
    if cfg.has_residual:
        phases.append(sd + sa)  # add: D += A (in-place into D)
    return max(phases)


def hmcos_module_bytes(cfg: ModuleConfig) -> int:
    """HMCOS-style: scheduling only, no in-place — every layer's input and
    output coexist (linear chains give scheduling nothing to reorder)."""
    h0, h1, h2 = cfg.spatial()
    eb = cfg.elem_bytes
    sa = h0 * h0 * cfg.c_in * eb
    sb = h1 * h1 * cfg.c_mid * eb
    h_dw = -(-h1 // cfg.strides[1])
    sc = h_dw * h_dw * cfg.c_mid * eb
    sd = h2 * h2 * cfg.c_out * eb
    res = sa if cfg.has_residual else 0
    phases = [sa + sb, sb + sc + res, sc + sd + res]
    if cfg.has_residual:
        phases.append(sd + sa + cfg.output_bytes)  # add out-of-place
    return max(phases)


# Paper Table 2 module configs ------------------------------------------------

MCUNET_5FPS_VWW = [
    ModuleConfig("S1", 20, 16, 48, 16, 3, (1, 1, 1)),
    ModuleConfig("S2", 20, 16, 48, 16, 3, (1, 1, 1)),
    ModuleConfig("S3", 10, 24, 144, 16, 3, (1, 1, 1)),
    ModuleConfig("S4", 10, 24, 120, 24, 3, (1, 1, 1)),
    ModuleConfig("S5", 5, 40, 240, 40, 3, (1, 1, 1)),
    ModuleConfig("S6", 5, 48, 192, 48, 3, (1, 1, 1)),
    ModuleConfig("S7", 3, 96, 480, 96, 3, (1, 1, 1)),
    ModuleConfig("S8", 3, 96, 384, 96, 3, (1, 1, 1)),
]

MCUNET_320KB_IMAGENET = [
    ModuleConfig("B1", 176, 3, 16, 8, 3, (2, 1, 1)),
    ModuleConfig("B2", 88, 8, 24, 16, 7, (1, 2, 1)),
    ModuleConfig("B3", 44, 16, 80, 16, 3, (1, 1, 1)),
    ModuleConfig("B4", 44, 16, 80, 16, 7, (1, 1, 1)),
    ModuleConfig("B5", 44, 16, 64, 24, 5, (1, 1, 1)),
    ModuleConfig("B6", 44, 16, 80, 24, 5, (1, 2, 1)),
    ModuleConfig("B7", 22, 24, 120, 24, 5, (1, 1, 1)),
    ModuleConfig("B8", 22, 24, 120, 24, 5, (1, 1, 1)),
    ModuleConfig("B9", 22, 24, 120, 40, 3, (1, 2, 1)),
    ModuleConfig("B10", 11, 40, 240, 40, 7, (1, 1, 1)),
    ModuleConfig("B11", 11, 40, 160, 40, 5, (1, 1, 1)),
    ModuleConfig("B12", 11, 40, 200, 48, 7, (1, 2, 1)),
    ModuleConfig("B13", 11, 48, 240, 48, 7, (1, 1, 1)),
    ModuleConfig("B14", 11, 48, 240, 48, 3, (1, 1, 1)),
    ModuleConfig("B15", 11, 48, 288, 96, 3, (1, 2, 1)),
    ModuleConfig("B16", 6, 96, 480, 96, 7, (1, 1, 1)),
    ModuleConfig("B17", 6, 96, 384, 96, 3, (1, 1, 1)),
]
