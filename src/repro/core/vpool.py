"""VirtualPool — the ONE virtualized segment pool every kernel partitions.

vMCU's central object (paper §4) is a single circular pool
``Pool[MemCap/Seg]`` that all tensors of a kernel chain live inside at
planner-solved offsets.  This module is the repo's single source of truth
for that object:

  * ``ceil_div`` / ``segments_for`` — THE ceil-div segment helper (was
    triplicated across ``ring_buffer._segs``, ``segment_matmul._segs`` and
    inline ``-(-d // SEG_WIDTH)`` in ``ops.py``).
  * ``stage_rows`` / ``fetch_rows`` — THE host-side ring staging/readback
    (modular segment indexing = the paper's ``addr % (MemCap/Seg)`` bounds
    check).  ``ring_buffer.write_rows/read_rows`` and the old
    ``segment_matmul.stage_rows/fetch_rows`` are thin aliases of these.
  * ``PoolSpec`` — the pool geometry record (n_segments, seg_width, dtype).
  * ``VirtualPool`` — an immutable handle pairing a spec with the donated
    backing array; kernels and executors thread it functionally.

Plans over a VirtualPool are :class:`repro.core.program.PoolProgram`
objects; executors (``repro.core.executors``) run the same program on the
``sim`` / ``jnp`` / ``pallas`` backends.  See DESIGN.md §3.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

# TPU lane width — the canonical segment width; one pool segment row holds
# SEG_WIDTH elements so MXU tiles stay aligned (DESIGN.md §5).
SEG_WIDTH = 128
LANE = SEG_WIDTH  # historical alias (ring_buffer)


def ceil_div(a: int, b: int) -> int:
    """Ceiling division for non-negative ``a`` and positive ``b``."""
    return -(-a // b)


def segments_for(dim: int, seg_width: int = SEG_WIDTH) -> int:
    """Number of ``seg_width``-wide segments covering a ``dim``-wide row."""
    return ceil_div(dim, seg_width)


@dataclasses.dataclass(frozen=True)
class PoolSpec:
    """Geometry of a virtual pool: ``n_segments`` rows of ``seg_width``
    elements of ``dtype``.  Hashable so it can ride in static jit args."""

    n_segments: int
    seg_width: int = SEG_WIDTH
    dtype: Any = jnp.float32

    def __post_init__(self):
        if self.n_segments <= 0 or self.seg_width <= 0:
            raise ValueError(f"bad pool geometry {self!r}")

    @property
    def shape(self) -> tuple[int, int]:
        return (self.n_segments, self.seg_width)

    @property
    def segment_bytes(self) -> int:
        return self.seg_width * np.dtype(self.dtype).itemsize

    @property
    def nbytes(self) -> int:
        return self.n_segments * self.segment_bytes


def fetch_segments(pool: jax.Array, ptr: int, count: int,
                   n_segments: int | None = None) -> jax.Array:
    """Read ``count`` consecutive ring segments starting at ``ptr``.

    Pointers are static Python ints, so the modular index resolves at
    trace time: a run that stays inside the pool is ONE contiguous XLA
    slice, a wrapping run is two (tail + head) — never a gather.  The
    selected segments are identical to ``pool[(ptr + arange(count)) % n]``.
    """
    n = pool.shape[0] if n_segments is None else n_segments
    start = int(ptr) % n
    if start + count <= n:
        return jax.lax.slice_in_dim(pool, start, start + count, axis=0)
    head = n - start
    return jnp.concatenate(
        [jax.lax.slice_in_dim(pool, start, n, axis=0),
         jax.lax.slice_in_dim(pool, 0, count - head, axis=0)], axis=0)


def stage_segments(pool: jax.Array, segs: jax.Array, ptr: int,
                   n_segments: int | None = None) -> jax.Array:
    """Write ``segs [count, seg_width]`` at ring segment ``ptr`` — the
    in-place dual of :func:`fetch_segments` (one update slice, or two on
    a wrap; with a donated pool XLA updates the buffer in place)."""
    n = pool.shape[0] if n_segments is None else n_segments
    start = int(ptr) % n
    count = segs.shape[0]
    segs = segs.astype(pool.dtype)
    if start + count <= n:
        return jax.lax.dynamic_update_slice_in_dim(pool, segs, start,
                                                   axis=0)
    head = n - start
    pool = jax.lax.dynamic_update_slice_in_dim(pool, segs[:head], start,
                                               axis=0)
    return jax.lax.dynamic_update_slice_in_dim(pool, segs[head:], 0,
                                               axis=0)


def stage_rows(pool: jax.Array, rows: jax.Array, ptr: int,
               n_segments: int | None = None) -> jax.Array:
    """Place ``rows [M, d]`` into the ring starting at segment ``ptr``.

    Rows are padded to whole segments and stored with modular addressing —
    the paper's circular-buffer bounds check, lowered to contiguous
    slices (:func:`stage_segments`).
    """
    m, d = rows.shape
    seg_w = pool.shape[1]
    segs = segments_for(d, seg_w)
    padded = jnp.pad(rows, ((0, 0), (0, segs * seg_w - d)))
    return stage_segments(pool, padded.reshape(m * segs, seg_w), ptr,
                          n_segments)


def fetch_rows(pool: jax.Array, ptr: int, m: int, d: int,
               n_segments: int | None = None) -> jax.Array:
    """Gather ``[m, d]`` rows resident at segment ``ptr`` out of the ring."""
    seg_w = pool.shape[1]
    segs = segments_for(d, seg_w)
    return fetch_segments(pool, ptr, m * segs,
                          n_segments).reshape(m, segs * seg_w)[:, :d]


@dataclasses.dataclass(frozen=True)
class VirtualPool:
    """Immutable handle on the one pool array all kernels partition.

    Functional style: every mutation returns a new handle wrapping the
    updated array (under jit with donation the buffer itself is reused —
    the MCU's raw-pointer discipline recovered at the XLA level).
    """

    array: jax.Array

    @classmethod
    def alloc(cls, spec: PoolSpec) -> "VirtualPool":
        return cls(jnp.zeros(spec.shape, spec.dtype))

    @property
    def n_segments(self) -> int:
        return self.array.shape[0]

    @property
    def seg_width(self) -> int:
        return self.array.shape[1]

    @property
    def dtype(self):
        return self.array.dtype

    @property
    def spec(self) -> PoolSpec:
        return PoolSpec(self.n_segments, self.seg_width, self.array.dtype)

    @property
    def nbytes(self) -> int:
        return self.spec.nbytes

    def stage_rows(self, rows: jax.Array, ptr: int) -> "VirtualPool":
        return VirtualPool(stage_rows(self.array, rows, ptr))

    def fetch_rows(self, ptr: int, m: int, d: int) -> jax.Array:
        return fetch_rows(self.array, ptr, m, d)
