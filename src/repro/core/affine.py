"""Affine formulation of segment-level memory planning (vMCU §4).

The paper models a kernel as:

  * an iteration domain  ``{S[i] : H·i + B < 0}`` — here restricted to the box
    domains every vMCU kernel actually uses (GEMM / conv / fused chains),
  * per-tensor *access functions* ``S[i] -> T[u], u = A_u·i + V_u``,
  * a row-major *mapping vector* ``L`` flattening segment indices ``u`` to a
    linear pool address ``addr = L·u + b_off``.

All quantities are in units of SEGMENTS, not bytes; byte accounting happens in
:mod:`repro.core.planner` / :mod:`repro.core.pool`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class IterDomain:
    """A box iteration domain ``0 <= i_d < extents[d]``, iterated in
    lexicographic (row-major) order — the order vMCU kernels execute in."""

    extents: tuple[int, ...]

    def __post_init__(self):
        if any(e <= 0 for e in self.extents):
            raise ValueError(f"empty iteration domain {self.extents}")

    @property
    def size(self) -> int:
        return math.prod(self.extents)

    def points_lex(self) -> np.ndarray:
        """All iteration points as an ``(size, ndim)`` int64 array, in
        lexicographic order (last axis fastest)."""
        grids = np.indices(self.extents).reshape(len(self.extents), -1)
        return grids.T.astype(np.int64)


@dataclasses.dataclass(frozen=True)
class AccessFn:
    """Affine segment access ``u = A·i + V`` followed by row-major flattening
    with mapping vector ``L`` (strides of the accessed tensor, in segments)."""

    A: tuple[tuple[int, ...], ...]  # (tensor_rank, iter_rank)
    V: tuple[int, ...]              # (tensor_rank,)
    shape: tuple[int, ...]          # tensor shape in segments (defines L)

    def __post_init__(self):
        rank = len(self.shape)
        if len(self.A) != rank or len(self.V) != rank:
            raise ValueError("A/V rank must match tensor shape rank")

    @property
    def L(self) -> tuple[int, ...]:
        """Row-major strides of the tensor in segments."""
        strides = []
        acc = 1
        for extent in reversed(self.shape):
            strides.append(acc)
            acc *= extent
        return tuple(reversed(strides))

    @property
    def size(self) -> int:
        return math.prod(self.shape)

    def linear_coeffs(self) -> tuple[np.ndarray, int]:
        """Collapse ``L·(A·i + V)`` into ``(c, c0)`` with addr = c·i + c0."""
        A = np.asarray(self.A, dtype=np.int64)
        V = np.asarray(self.V, dtype=np.int64)
        L = np.asarray(self.L, dtype=np.int64)
        return L @ A, int(L @ V)

    def addresses(self, points: np.ndarray) -> np.ndarray:
        c, c0 = self.linear_coeffs()
        return points @ c + c0


def gemm_domain(M: int, N: int, K: int) -> IterDomain:
    """Iteration domain of the vMCU fully-connected kernel (Fig. 4), one
    point per (row, out-col-segment, in-col-segment)."""
    return IterDomain((M, N, K))


def gemm_read_access(M: int, K: int) -> AccessFn:
    """Reads ``In[m, k]`` at iteration (m, n, k)."""
    return AccessFn(A=((1, 0, 0), (0, 0, 1)), V=(0, 0), shape=(M, K))


def gemm_write_access(M: int, N: int) -> AccessFn:
    """Writes ``Out[m, n]`` at iteration (m, n, k) (stored when k completes;
    using the per-k address is conservative and matches the paper's Eq. 1)."""
    return AccessFn(A=((1, 0, 0), (0, 1, 0)), V=(0, 0), shape=(M, N))


def conv2d_pointwise_domain(P: int, Q: int, K: int, C: int) -> IterDomain:
    """1x1 conv == GEMM over (P*Q, K, C); kept spatial for clarity."""
    return IterDomain((P, Q, K, C))


def conv2d_read_access(H: int, W: int, C: int, *, stride: int = 1,
                       r: int = 0, s: int = 0) -> AccessFn:
    """Reads ``In[p*stride + r, q*stride + s, c]`` at iteration (p, q, k, c)
    for a fixed filter tap (r, s). Tap offsets enter through ``V``."""
    return AccessFn(
        A=((stride, 0, 0, 0), (0, stride, 0, 0), (0, 0, 0, 1)),
        V=(r, s, 0),
        shape=(H, W, C),
    )


def conv2d_write_access(P: int, Q: int, K: int) -> AccessFn:
    """Writes ``Out[p, q, k]`` at iteration (p, q, k, c)."""
    return AccessFn(
        A=((1, 0, 0, 0), (0, 1, 0, 0), (0, 0, 1, 0)),
        V=(0, 0, 0),
        shape=(P, Q, K),
    )
