"""JAX HBM ring pool — legacy chain API, now thin adapters over the
VirtualPool / PoolProgram abstraction.

``ChainPlan``/``plan_chain`` remain for callers of the original API, but
planning is delegated to :func:`repro.core.program.plan_program`
(``block_rows=None`` — the exact, unaligned Eq.-(1) geometry) and the
layer scan to :func:`repro.core.executors.gemm_ring_scan` (the single jnp
ring-GEMM implementation, shared with the ``jnp`` executor backend).
``write_rows``/``read_rows`` are aliases of the one stage/fetch in
:mod:`repro.core.vpool`.  New code should use ``plan_program`` +
``execute`` directly (see DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from .executors import gemm_ring_scan
from .program import GemmSpec, plan_program
from .vpool import LANE, fetch_rows as _fetch_rows
from .vpool import segments_for
from .vpool import stage_rows as _stage_rows


def _segs(dim: int, seg_width: int) -> int:
    return segments_for(dim, seg_width)


@dataclasses.dataclass(frozen=True)
class ChainPlan:
    """Static plan for an FC chain ``d0 -> d1 -> ... -> dL`` over M rows.

    Legacy adapter: equivalent to
    ``plan_program(m_rows, dims[0], [GemmSpec(d) for d in dims[1:]],
    seg_width=seg_width, block_rows=None)``.
    """

    m_rows: int
    dims: tuple[int, ...]
    seg_width: int
    n_segments: int
    # per layer: (in_ptr, out_ptr) segment offsets (virtual, pre-modulo)
    layer_ptrs: tuple[tuple[int, int], ...]

    @property
    def pool_bytes(self) -> int:  # fp32 demo pool
        return self.n_segments * self.seg_width * 4

    @property
    def naive_bytes(self) -> int:
        """Tensor-level chain: worst adjacent in+out pair lives at once."""
        per = [self.m_rows * _segs(d, self.seg_width) for d in self.dims]
        worst = max(per[i] + per[i + 1] for i in range(len(per) - 1))
        return worst * self.seg_width * 4


def plan_chain(m_rows: int, dims: list[int], seg_width: int = LANE) -> ChainPlan:
    """Solve Eq. (1) per layer and chain the pointers (adapter over
    :func:`plan_program`): layer i's output pointer sits ``delta_i``
    segments below its input pointer; the next layer consumes it in place."""
    prog = plan_program(m_rows, dims[0], [GemmSpec(d) for d in dims[1:]],
                        seg_width=seg_width, block_rows=None)
    shift = prog.ops[0].in_ptr  # program pointers are shifted >= 0
    ptrs = tuple((op.in_ptr - shift, op.out_ptr - shift) for op in prog.ops)
    return ChainPlan(m_rows=m_rows, dims=tuple(dims), seg_width=seg_width,
                     n_segments=prog.n_segments, layer_ptrs=ptrs)


def write_rows(pool: jax.Array, rows: jax.Array, ptr: int,
               n_segments: int) -> jax.Array:
    """Alias of :func:`repro.core.vpool.stage_rows` (the one impl)."""
    return _stage_rows(pool, rows, ptr, n_segments)


def read_rows(pool: jax.Array, ptr: int, m: int, d: int,
              n_segments: int) -> jax.Array:
    """Alias of :func:`repro.core.vpool.fetch_rows` (the one impl)."""
    return _fetch_rows(pool, ptr, m, d, n_segments)


def init_chain_params(key: jax.Array, dims: list[int],
                      dtype=jnp.float32) -> list[tuple[jax.Array, jax.Array]]:
    params = []
    for d_in, d_out in zip(dims[:-1], dims[1:]):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (d_in, d_out), dtype) / math.sqrt(d_in)
        params.append((w, jnp.zeros((d_out,), dtype)))
    return params


@partial(jax.jit, static_argnames=("plan", "block_rows"), donate_argnums=(0,))
def ring_chain_apply(pool: jax.Array, params, plan: ChainPlan,
                     block_rows: int = 1) -> jax.Array:
    """Run the whole planned chain inside the donated pool buffer."""
    base = plan.layer_ptrs[-1][1]  # most negative pointer; shift all >= 0
    n_layers = len(params)
    for i, ((w, b), (in_ptr, out_ptr)) in enumerate(
            zip(params, plan.layer_ptrs)):
        act = None if i == n_layers - 1 else "gelu"
        pool = gemm_ring_scan(pool, w, b,
                              in_ptr=in_ptr - base, out_ptr=out_ptr - base,
                              m_rows=plan.m_rows,
                              n_segments=plan.n_segments,
                              block_rows=block_rows, activation=act)
    return pool


def naive_chain_apply(x: jax.Array, params) -> jax.Array:
    """Tensor-level reference: every intermediate fully materialized."""
    for i, (w, b) in enumerate(params):
        x = x @ w.astype(x.dtype) + b.astype(x.dtype)
        if i != len(params) - 1:
            x = jax.nn.gelu(x)
    return x


def run_chain_via_ring(x: jax.Array, params, plan: ChainPlan,
                       block_rows: int = 1) -> jax.Array:
    """Convenience wrapper: stage input into a fresh pool, run, read out."""
    base = plan.layer_ptrs[-1][1]
    pool = jnp.zeros((plan.n_segments, plan.seg_width), x.dtype)
    pool = write_rows(pool, x, plan.layer_ptrs[0][0] - base, plan.n_segments)
    pool = ring_chain_apply(pool, params, plan, block_rows)
    return read_rows(pool, plan.layer_ptrs[-1][1] - base, plan.m_rows,
                     plan.dims[-1], plan.n_segments)
