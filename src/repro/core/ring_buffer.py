"""JAX HBM ring pool — vMCU's circular segment buffer as a jit-able module.

On MCU the kernel owns raw pointers; under XLA we recover the same effect
with (a) ONE pool array ``[n_segments, seg_width]`` threaded through the
layer chain and donated at the jit boundary, and (b) modular segment
indexing (``jnp.take`` / scatter with ``% n_segments`` indices) — the
paper's `addr % (MemCap/Seg)` bounds check, verbatim.

``memory_analysis()`` of the compiled chain shows the activation footprint
collapsing to the pool size (benchmarks/pool_footprint.py); numerics are
bit-identical to the naive chain (tests/test_ring_buffer.py).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .planner import gemm_offset_closed_form

# TPU lane width; segments are padded to it so MXU tiles stay aligned.
LANE = 128


def _segs(dim: int, seg_width: int) -> int:
    return -(-dim // seg_width)


@dataclasses.dataclass(frozen=True)
class ChainPlan:
    """Static plan for an FC chain ``d0 -> d1 -> ... -> dL`` over M rows."""

    m_rows: int
    dims: tuple[int, ...]
    seg_width: int
    n_segments: int
    # per layer: (in_ptr, out_ptr) segment offsets (virtual, pre-modulo)
    layer_ptrs: tuple[tuple[int, int], ...]

    @property
    def pool_bytes(self) -> int:  # fp32 demo pool
        return self.n_segments * self.seg_width * 4

    @property
    def naive_bytes(self) -> int:
        """Tensor-level chain: worst adjacent in+out pair lives at once."""
        per = [self.m_rows * _segs(d, self.seg_width) for d in self.dims]
        worst = max(per[i] + per[i + 1] for i in range(len(per) - 1))
        return worst * self.seg_width * 4


def plan_chain(m_rows: int, dims: list[int], seg_width: int = LANE) -> ChainPlan:
    """Solve Eq. (1) per layer and chain the pointers: layer i's output
    pointer is shifted ``delta_i`` segments below its input pointer; the
    next layer consumes it in place."""
    ptrs = []
    in_ptr = 0
    max_span = 0
    for d_in, d_out in zip(dims[:-1], dims[1:]):
        k_segs = _segs(d_in, seg_width)
        n_segs = _segs(d_out, seg_width)
        delta = gemm_offset_closed_form(m_rows, n_segs, k_segs)
        out_ptr = in_ptr - delta
        # Track the widest live span (in segments) this layer needs.
        span = (max(in_ptr + m_rows * k_segs, out_ptr + m_rows * n_segs)
                - min(in_ptr, out_ptr))
        max_span = max(max_span, span)
        ptrs.append((in_ptr, out_ptr))
        in_ptr = out_ptr
    return ChainPlan(m_rows=m_rows, dims=tuple(dims), seg_width=seg_width,
                     n_segments=max_span, layer_ptrs=tuple(ptrs))


def write_rows(pool: jax.Array, rows: jax.Array, ptr: int,
               n_segments: int) -> jax.Array:
    """Store ``rows [M, d]`` into the ring starting at segment ``ptr``."""
    m, d = rows.shape
    seg_w = pool.shape[1]
    segs = _segs(d, seg_w)
    padded = jnp.pad(rows, ((0, 0), (0, segs * seg_w - d)))
    flat = padded.reshape(m * segs, seg_w)
    idx = (ptr + jnp.arange(m * segs)) % n_segments
    return pool.at[idx].set(flat.astype(pool.dtype))


def read_rows(pool: jax.Array, ptr: int, m: int, d: int,
              n_segments: int) -> jax.Array:
    seg_w = pool.shape[1]
    segs = _segs(d, seg_w)
    idx = (ptr + jnp.arange(m * segs)) % n_segments
    flat = jnp.take(pool, idx, axis=0)
    return flat.reshape(m, segs * seg_w)[:, :d]


def _layer_scan(pool: jax.Array, w: jax.Array, b: jax.Array, *,
                in_ptr: int, out_ptr: int, m_rows: int, n_segments: int,
                block_rows: int, activation) -> jax.Array:
    """One FC layer streamed through the ring, ``block_rows`` rows per step.

    Mirrors the paper's Fig.-4 kernel: RAMLoad a row-block of input
    segments, Dot against the (un-pooled, "Flash") weight, RAMStore the
    output row-block at the solved offset; the modulo on every index is the
    circular-buffer bounds check.
    """
    d_in, d_out = w.shape
    seg_w = pool.shape[1]
    k_segs, n_segs = _segs(d_in, seg_w), _segs(d_out, seg_w)
    n_blocks = m_rows // block_rows
    if n_blocks * block_rows != m_rows:
        raise ValueError("block_rows must divide m_rows")

    def step(p, blk):
        row0 = blk * block_rows
        ridx = (in_ptr + row0 * k_segs
                + jnp.arange(block_rows * k_segs)) % n_segments
        x = jnp.take(p, ridx, axis=0).reshape(block_rows, k_segs * seg_w)
        x = x[:, :d_in]
        y = activation(x @ w.astype(x.dtype) + b.astype(x.dtype))
        pad = jnp.pad(y, ((0, 0), (0, n_segs * seg_w - d_out)))
        widx = (out_ptr + row0 * n_segs
                + jnp.arange(block_rows * n_segs)) % n_segments
        return p.at[widx].set(pad.reshape(block_rows * n_segs, seg_w)), None

    pool, _ = jax.lax.scan(step, pool, jnp.arange(n_blocks))
    return pool


def init_chain_params(key: jax.Array, dims: list[int],
                      dtype=jnp.float32) -> list[tuple[jax.Array, jax.Array]]:
    params = []
    for d_in, d_out in zip(dims[:-1], dims[1:]):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (d_in, d_out), dtype) / math.sqrt(d_in)
        params.append((w, jnp.zeros((d_out,), dtype)))
    return params


@partial(jax.jit, static_argnames=("plan", "block_rows"), donate_argnums=(0,))
def ring_chain_apply(pool: jax.Array, params, plan: ChainPlan,
                     block_rows: int = 1) -> jax.Array:
    """Run the whole planned chain inside the donated pool buffer."""
    base = plan.layer_ptrs[-1][1]  # most negative pointer; shift all >= 0
    for (w, b), (in_ptr, out_ptr), is_last in zip(
            params, plan.layer_ptrs,
            [i == len(params) - 1 for i in range(len(params))]):
        act = (lambda x: x) if is_last else jax.nn.gelu
        pool = _layer_scan(pool, w, b,
                           in_ptr=in_ptr - base, out_ptr=out_ptr - base,
                           m_rows=plan.m_rows, n_segments=plan.n_segments,
                           block_rows=block_rows, activation=act)
    return pool


def naive_chain_apply(x: jax.Array, params) -> jax.Array:
    """Tensor-level reference: every intermediate fully materialized."""
    for i, (w, b) in enumerate(params):
        x = x @ w.astype(x.dtype) + b.astype(x.dtype)
        if i != len(params) - 1:
            x = jax.nn.gelu(x)
    return x


def run_chain_via_ring(x: jax.Array, params, plan: ChainPlan,
                       block_rows: int = 1) -> jax.Array:
    """Convenience wrapper: stage input into a fresh pool, run, read out."""
    base = plan.layer_ptrs[-1][1]
    pool = jnp.zeros((plan.n_segments, plan.seg_width), x.dtype)
    pool = write_rows(pool, x, plan.layer_ptrs[0][0] - base, plan.n_segments)
    pool = ring_chain_apply(pool, params, plan, block_rows)
    return read_rows(pool, plan.layer_ptrs[-1][1] - base, plan.m_rows,
                     plan.dims[-1], plan.n_segments)
