"""Circular segment-pool simulator — the correctness oracle for plans.

Simulates vMCU's ``Pool[MemCap/Seg]`` byte-for-byte: every address is taken
modulo the pool length, a write to a still-live segment that does not belong
to the writing tensor raises (this is the "silent error" the paper warns
about when too few empty segments are allocated).  Tests drive kernel
schedules through this simulator with the planner's delta (must succeed) and
with delta − 1 (must clobber), proving the plans are tight.
"""
from __future__ import annotations

import dataclasses
from typing import Hashable

import numpy as np


class PoolClobberError(RuntimeError):
    """A write overwrote a live segment of another tensor."""


@dataclasses.dataclass
class _Segment:
    owner: Hashable
    payload: object = None


class SegmentPool:
    """A circular buffer of ``n_segments`` slots with liveness tracking."""

    def __init__(self, n_segments: int, segment_bytes: int = 1):
        if n_segments <= 0:
            raise ValueError("pool must have at least one segment")
        self.n = n_segments
        self.segment_bytes = segment_bytes
        self._slots: dict[int, _Segment] = {}
        self.peak_live = 0
        self.reads = 0
        self.writes = 0
        self.frees = 0

    # -- addressing ---------------------------------------------------------
    def _wrap(self, addr: int) -> int:
        return addr % self.n  # the paper's modulo bounds check

    # -- operations ---------------------------------------------------------
    def write(self, addr: int, owner: Hashable, payload: object = None) -> None:
        slot = self._wrap(addr)
        prev = self._slots.get(slot)
        if prev is not None and prev.owner != owner:
            raise PoolClobberError(
                f"write by {owner!r} at pool[{slot}] clobbers live segment "
                f"of {prev.owner!r}")
        self._slots[slot] = _Segment(owner, payload)
        self.writes += 1
        self.peak_live = max(self.peak_live, len(self._slots))

    def read(self, addr: int, owner: Hashable) -> object:
        slot = self._wrap(addr)
        seg = self._slots.get(slot)
        if seg is None:
            raise PoolClobberError(f"read of dead segment pool[{slot}] by {owner!r}")
        if seg.owner != owner:
            raise PoolClobberError(
                f"read by {owner!r} at pool[{slot}] sees segment of "
                f"{seg.owner!r} — input was overwritten too early")
        self.reads += 1
        return seg.payload

    def free(self, addr: int, owner: Hashable) -> None:
        slot = self._wrap(addr)
        seg = self._slots.get(slot)
        if seg is None:
            return  # double-free is benign in the paper's kernels
        if seg.owner != owner:
            raise PoolClobberError(
                f"free by {owner!r} at pool[{slot}] of segment owned by "
                f"{seg.owner!r}")
        del self._slots[slot]
        self.frees += 1

    @property
    def live(self) -> int:
        return len(self._slots)

    @property
    def peak_bytes(self) -> int:
        return self.peak_live * self.segment_bytes


def run_gemm_schedule(pool: SegmentPool, M: int, N: int, K: int,
                      b_out: int, b_in: int,
                      in_payload: np.ndarray | None = None) -> dict[int, object]:
    """Execute the paper's FC kernel schedule (Fig. 4) against the pool.

    Input segments In[m,k] start resident at ``b_in + m*K + k``; output
    segments are stored to ``b_out + m*N + n``.  Eq. (1)'s ``∀ j ⪯ i``
    semantics means an input segment is *dead after its last read* — the
    explicit RAMFree loop in Fig. 4 is bookkeeping that trails the real
    lifetime — so the simulator frees each input segment immediately after
    the final ``n`` iteration reads it.  Returns {linear_out_idx: payload}
    so callers can check numerics survived the ring.
    """
    for m in range(M):
        for k in range(K):
            payload = None if in_payload is None else in_payload[m, k]
            pool.write(b_in + m * K + k, owner=("in", m, k), payload=payload)
    out: dict[int, object] = {}
    for m in range(M):
        for n in range(N):
            acc = []
            for k in range(K):
                acc.append(pool.read(b_in + m * K + k, owner=("in", m, k)))
                if n == N - 1:  # last read of In[m, k] — segment is dead
                    pool.free(b_in + m * K + k, owner=("in", m, k))
            pool.write(b_out + m * N + n, owner="out",
                       payload=(m, n, tuple(acc)))
            out[m * N + n] = (m, n)
    # outputs must all be intact at the end
    for m in range(M):
        for n in range(N):
            pool.read(b_out + m * N + n, owner="out")
    return out
