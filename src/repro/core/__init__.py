"""vMCU core: segment-level memory management (paper §4–§5), TPU-adapted.

Public surface (the unified pool/plan API — DESIGN.md §3):
  * vpool     — VirtualPool / PoolSpec, THE stage/fetch + ceil-div helpers
  * program   — PoolProgram IR + plan_program() single planning front-end
  * executors — execute(program, pool, params, backend=sim|jnp|pallas)

Solvers and legacy adapters:
  * planner       — Eq. (1) offset solver (exact scan + closed forms)
  * graph_planner — Eq. (2) fused multi-layer plans (inverted bottleneck,
                    FC chains) + TinyEngine/HMCOS module baselines
  * pool          — circular segment-pool simulator (correctness oracle)
  * baselines     — single-layer tensor-level baselines
  * ring_buffer   — legacy ChainPlan adapters over plan_program
"""
from .affine import AccessFn, IterDomain
from .planner import (SegmentPlan, gemm_min_footprint_segments,
                      gemm_offset_closed_form, motivational_example,
                      plan_affine, plan_gemm, plan_pointwise_conv,
                      solve_offset_bruteforce, solve_offset_scan)
from .graph_planner import (FusedPlan, MCUNET_5FPS_VWW,
                            MCUNET_320KB_IMAGENET, ModuleConfig,
                            hmcos_module_bytes, plan_fc_chain,
                            plan_inverted_bottleneck, solve_stream_offset,
                            tinyengine_module_bytes)
from .pool import PoolClobberError, SegmentPool, run_gemm_schedule
from .baselines import (FIG7_CASES, LayerShape, hmcos_bytes,
                        pointwise_conv_layer, tinyengine_bytes)
from .vpool import (LANE, SEG_WIDTH, PoolSpec, VirtualPool, ceil_div,
                    fetch_rows, segments_for, stage_rows)
from .program import (ACTIVATIONS, AvgPoolSpec, ConvDWSpec, ConvK2DSpec,
                      ConvPWSpec, ElementwiseSpec, FusedChainSpec,
                      FusedMLPSpec, GemmSpec, IBModuleSpec,
                      InvertedBottleneckSpec, PoolOp, PoolProgram,
                      ResidualAddSpec, concat_programs,
                      plan_module_program, plan_program,
                      plan_stream_chain_program, resolve_activation)
from .executors import (execute, executor_names, register_executor,
                        run_program, run_program_jnp, run_program_pallas,
                        run_program_sim)
from .ring_buffer import (ChainPlan, init_chain_params, naive_chain_apply,
                          plan_chain, ring_chain_apply, run_chain_via_ring)

__all__ = [
    # unified API
    "PoolSpec", "VirtualPool", "SEG_WIDTH", "LANE", "ceil_div",
    "segments_for", "stage_rows", "fetch_rows",
    "PoolOp", "PoolProgram", "plan_program", "plan_module_program",
    "plan_stream_chain_program", "concat_programs", "GemmSpec",
    "FusedMLPSpec", "ElementwiseSpec", "FusedChainSpec",
    "InvertedBottleneckSpec", "ConvPWSpec", "ConvDWSpec", "ConvK2DSpec",
    "IBModuleSpec", "ResidualAddSpec", "AvgPoolSpec",
    "ACTIVATIONS", "resolve_activation",
    "execute", "executor_names", "register_executor", "run_program",
    "run_program_sim", "run_program_jnp", "run_program_pallas",
    # solvers + legacy adapters
    "AccessFn", "IterDomain", "SegmentPlan", "FusedPlan", "ModuleConfig",
    "SegmentPool", "PoolClobberError", "ChainPlan", "LayerShape",
    "FIG7_CASES", "MCUNET_5FPS_VWW", "MCUNET_320KB_IMAGENET",
    "gemm_min_footprint_segments", "gemm_offset_closed_form",
    "motivational_example", "plan_affine", "plan_gemm",
    "plan_pointwise_conv", "solve_offset_bruteforce", "solve_offset_scan",
    "solve_stream_offset", "plan_inverted_bottleneck", "plan_fc_chain",
    "tinyengine_module_bytes", "hmcos_module_bytes", "run_gemm_schedule",
    "hmcos_bytes", "tinyengine_bytes", "pointwise_conv_layer",
    "plan_chain", "ring_chain_apply", "naive_chain_apply",
    "run_chain_via_ring", "init_chain_params",
]
