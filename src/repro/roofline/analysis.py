"""Three-term roofline from a compiled dry-run artifact.

  compute    = FLOPs_per_chip / peak_FLOPs           (197 TFLOP/s bf16, v5e)
  memory     = HBM_bytes_per_chip / HBM_bw           (819 GB/s)
  collective = Σ algo_factor·bytes_per_chip / ICI_bw (~50 GB/s/link)

``cost_analysis()`` of the SPMD-partitioned executable reports per-device
FLOPs/bytes.  Collectives are parsed from the post-optimization HLO text
(they do not exist pre-partitioning); output shapes there are per-device.
Ring-algorithm factors: all-reduce moves ≈2× its payload per chip,
all-gather / reduce-scatter / all-to-all / permute ≈1×.  This is a
structural model — no wall clock exists on this CPU container.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")

_ALGO_FACTOR = {
    "all-reduce": 2.0,       # reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shapes_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> dict[str, dict[str, float]]:
    """Per-collective-kind {count, bytes} from post-optimization HLO."""
    out: dict[str, dict[str, float]] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shapes, kind = m.group(1), m.group(2)
        b = _shape_bytes(shapes)
        rec = out.setdefault(kind, {"count": 0, "bytes": 0.0})
        rec["count"] += 1
        rec["bytes"] += b
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    hbm_bytes_per_chip: float
    collective_bytes_per_chip: float
    collectives: dict
    # while-loop (scan) trip counts are already folded into cost_analysis

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return sum(_ALGO_FACTOR[k] * v["bytes"]
                   for k, v in self.collectives.items()) / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def fraction_of_roofline(self, useful_flops_per_chip: float) -> float:
        """useful-FLOPs-time / achievable step time (perfect overlap)."""
        if self.bound_time == 0:
            return 0.0
        return (useful_flops_per_chip / PEAK_FLOPS) / self.bound_time

    def summary(self) -> dict:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "collectives": self.collectives,
        }


def analyze(compiled, hlo_text: str | None = None) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):   # older API returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    colls = parse_collectives(text)
    cbytes = sum(v["bytes"] for v in colls.values())
    return Roofline(flops_per_chip=flops, hbm_bytes_per_chip=byts,
                    collective_bytes_per_chip=cbytes, collectives=colls)


def model_flops(param_count: int, active_param_count: int, tokens: int,
                kind: str) -> float:
    """MODEL_FLOPS: 6·N·D train, 2·N·D forward-only (N = active params)."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * active_param_count * tokens


# ---------------------------------------------------------------------------
# MCU ring roofline — fed by measured TraceArtifacts, not cost models.
# ---------------------------------------------------------------------------

MCU_PEAK_MACS = 80e6      # Cortex-M4 @ 80 MHz, ~1 MAC/cycle sustained
MCU_SRAM_BW = 320e6       # bytes/s: one 32-bit SRAM access per cycle


def ring_traffic_summary(trace, *, peak_macs_per_s: float = MCU_PEAK_MACS,
                         sram_bw_bytes_per_s: float = MCU_SRAM_BW) -> dict:
    """Per-op-kind roofline terms from one ring trace's MEASURED traffic.

    ``trace`` is a :class:`repro.obs.TraceArtifact` (or its payload
    dict) — the byte counters in it come from the executed/verified
    schedule, so this replaces the closed-form traffic models the
    energy-proxy figures previously trusted.  Each kind gets its summed
    ``bytes_moved`` / ``macs``, arithmetic intensity, the two roofline
    times at the given machine balance, and the binding term.
    """
    payload = trace if isinstance(trace, dict) else trace.to_dict()
    kinds: dict[str, dict] = {}
    for e in payload["events"]:
        k = e.get("kind")
        if k is None:
            continue
        rec = kinds.setdefault(k, {"n_ops": 0, "bytes_loaded": 0,
                                   "bytes_stored": 0, "macs": 0})
        rec["n_ops"] += 1
        rec["bytes_loaded"] += e.get("bytes_loaded", 0)
        rec["bytes_stored"] += e.get("bytes_stored", 0)
        rec["macs"] += e.get("macs", 0)
    for rec in kinds.values():
        moved = rec["bytes_loaded"] + rec["bytes_stored"]
        rec["bytes_moved"] = moved
        rec["arithmetic_intensity"] = rec["macs"] / moved if moved else 0.0
        rec["t_compute_s"] = rec["macs"] / peak_macs_per_s
        rec["t_memory_s"] = moved / sram_bw_bytes_per_s
        rec["bound"] = ("compute" if rec["t_compute_s"] >= rec["t_memory_s"]
                        else "memory")
    totals = payload["totals"]
    moved = totals["bytes_loaded"] + totals["bytes_stored"]
    ridge = peak_macs_per_s / sram_bw_bytes_per_s  # machine balance
    intensity = totals["macs"] / moved if moved else 0.0
    return {
        "net": payload.get("net"),
        "backend": payload.get("backend"),
        "kinds": kinds,
        "bytes_moved": moved,
        "macs": totals["macs"],
        "arithmetic_intensity": intensity,
        "ridge_intensity": ridge,
        "bound": "compute" if intensity >= ridge else "memory",
        "t_compute_s": totals["macs"] / peak_macs_per_s,
        "t_memory_s": moved / sram_bw_bytes_per_s,
        "watermark_bytes": totals.get("watermark_bytes"),
    }
