"""Fill EXPERIMENTS.md §Dry-run and §Roofline tables from dry-run JSONs.

Usage: PYTHONPATH=src python -m repro.roofline.report [results/dryrun]
"""
from __future__ import annotations

import glob
import json
import os
import sys

ARCH_ORDER = ["gemma2-2b", "gemma3-1b", "gemma2-27b", "granite-8b",
              "granite-moe-1b-a400m", "deepseek-moe-16b",
              "llama-3.2-vision-90b", "recurrentgemma-2b", "whisper-tiny",
              "mamba2-780m"]
CELL_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(results_dir: str) -> list[dict]:
    recs = [json.load(open(p))
            for p in glob.glob(os.path.join(results_dir, "*.json"))]

    def key(r):
        return (ARCH_ORDER.index(r["arch"]) if r["arch"] in ARCH_ORDER
                else 99, CELL_ORDER.index(r["cell"]) if r["cell"]
                in CELL_ORDER else 9, r.get("mesh", ""))
    return sorted(recs, key=key)


def dryrun_table(recs: list[dict]) -> str:
    lines = ["| arch | cell | mesh | status | compile | peak GB/chip | "
             "fits 16G | dominant collectives |",
             "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['cell']} | {r['mesh']} | "
                         f"ERROR: {r.get('error', '?')[:60]} | | | | |")
            continue
        m = r["memory"]
        colls = r["roofline"]["collectives"]
        top = sorted(colls.items(), key=lambda kv: -kv[1]["bytes"])[:2]
        cstr = "; ".join(f"{k}×{int(v['count'])} "
                         f"({v['bytes']/1e9:.2f}GB)" for k, v in top)
        lines.append(
            f"| {r['arch']} | {r['cell']} | {r['mesh']} | ok | "
            f"{r['compile_s']:.0f}s | {m['peak_bytes']/1e9:.2f} | "
            f"{'✓' if m['fits_16g'] else '✗'} | {cstr} |")
    return "\n".join(lines)


def roofline_table(recs: list[dict]) -> str:
    lines = ["| arch | cell | t_comp s | t_mem s | t_coll s | dominant | "
             "MODEL/HLO | fraction | one-line lever |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("mesh") != "16x16" or r["status"] != "ok":
            continue
        rf = r["roofline"]
        lever = _lever(r)
        lines.append(
            f"| {r['arch']} | {r['cell']} | {rf['t_compute_s']:.3f} | "
            f"{rf['t_memory_s']:.3f} | {rf['t_collective_s']:.3f} | "
            f"{rf['dominant']} | {rf['model_flops_ratio']:.3f} | "
            f"{rf['roofline_fraction']:.4f} | {lever} |")
    return "\n".join(lines)


def _lever(r: dict) -> str:
    rf = r["roofline"]
    dom = rf["dominant"]
    if r["cell"].startswith("decode") or r["cell"].startswith("long"):
        return ("decode is latency-bound: batch more requests per step or "
                "quantize the KV cache to halve the %s term" % dom)
    if dom == "collective":
        return ("bf16 param gathers + reduce-scatter grads cut wire bytes "
                "~3x (§Perf it.1/2)")
    if dom == "memory":
        if rf["model_flops_ratio"] < 0.05:
            return "dispatch overhead dominates — see §Perf MoE iterations"
        return ("cut HBM round-trips: bf16 gathers, fused-MLP streaming, "
                "smaller remat window")
    if rf["model_flops_ratio"] < 0.1:
        return "HLO FLOPs are overhead, not model math — fix dispatch/scan"
    return "MXU-bound: increase per-chip batch or reduce remat recompute"


def fill(md_path: str, results_dir: str) -> None:
    recs = load(results_dir)
    text = open(md_path).read()
    text = text.replace("<!-- DRYRUN_TABLE -->", dryrun_table(recs))
    text = text.replace("<!-- ROOFLINE_TABLE -->", roofline_table(recs))
    open(md_path, "w").write(text)
    ok = sum(1 for r in recs if r["status"] == "ok")
    print(f"filled {md_path}: {ok}/{len(recs)} cells ok")


if __name__ == "__main__":
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    fill("EXPERIMENTS.md", d)
