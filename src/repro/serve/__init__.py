from .engine import ServingEngine, make_serve_fns
