"""Batched serving engine: continuous-batching decode over ring KV caches.

The request loop is deliberately simple (this container is CPU-only) but the
step functions are the exact ones the dry-run lowers at production shapes:
``prefill`` materializes caches (full layers → [B,S,KV,D]; sliding-window
layers → vMCU ring of ``window`` slots), ``decode_step`` advances every
active slot one token, writing ring slots modulo the window.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..models.transformer import Model
from ..obs.spans import active, span
from ..parallel.sharding import AxisRules, no_sharding


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new: int = 16
    generated: list[int] = dataclasses.field(default_factory=list)


def make_serve_fns(model: Model, rules: AxisRules | None = None, *,
                   cache_len: int):
    rules = rules or no_sharding()

    @jax.jit
    def prefill(params, tokens, memory=None):
        return model.prefill(params, tokens, rules, memory=memory,
                             cache_len=cache_len)

    @jax.jit
    def decode_step(params, caches, token, cur_len):
        return model.decode_step(params, caches, token, cur_len, rules)

    return prefill, decode_step


class ServingEngine:
    """Greedy batched generation; one prefill per batch, then lockstep
    decode.  Real deployments interleave admission — the step functions
    support it (per-slot cur_len would become a vector; kept scalar here
    because all assigned decode cells are lockstep)."""

    def __init__(self, model: Model, params: Any,
                 rules: AxisRules | None = None, cache_len: int = 256):
        self.model = model
        self.params = params
        self.cache_len = cache_len
        self.prefill, self.decode = make_serve_fns(model, rules,
                                                   cache_len=cache_len)

    def generate(self, prompts: list[list[int]], max_new: int = 16,
                 memory: jax.Array | None = None) -> list[list[int]]:
        B = len(prompts)
        L = max(len(p) for p in prompts)
        toks = jnp.asarray([[0] * (L - len(p)) + p for p in prompts],
                           jnp.int32)  # left-pad
        with span("serve.prefill", batch=B, prompt_len=L):
            logits, caches, cur = self.prefill(self.params, toks, memory)
            if active():  # sync only when actually timing
                jax.block_until_ready(logits)
        out = [[] for _ in range(B)]
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        with span("serve.decode", batch=B, steps=max_new):
            for _ in range(max_new):
                for i in range(B):
                    out[i].append(int(tok[i]))
                logits, caches, cur = self.decode(self.params, caches,
                                                  tok, cur)
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return out
