"""Fused inverted-bottleneck kernel — the paper's Fig. 6, on TPU.

PW-expand → DW 3x3 → PW-project → (+residual), streamed row-by-row through
the ring pool: tensor B (the C_mid-wide expansion) exists only as a
(RS+ ) row workspace in VMEM — never in HBM — and output rows of E
overwrite consumed rows of A at the Eq.-2 offset.

Layout: NHWC with N folded into rows; one grid step produces one output
row (W × C_out).  The workspace holds RS rows of B (the DW halo) — the
row-cache variant of the paper's 11-segment workspace (DESIGN.md §1).
Stride-1, 'same' padding (MCUNet's dominant configuration; the planner in
:mod:`repro.core.graph_planner` handles the general case analytically).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(pool_ref, w1_ref, wd_ref, w2_ref, out_ref,
            b_rows, y_row, sem_in, sem_out, *,
            in_ptr: int, out_ptr: int, n_seg: int, H: int, W: int,
            C_in: int, C_mid: int, C_out: int, RS: int, residual: bool):
    """Grid step p computes output row p (W x C_out segments)."""
    p = pl.program_id(0)
    pad = (RS - 1) // 2

    # --- load the A rows this output row needs (halo) and expand to B ----
    # b_rows: VMEM [RS, W, C_mid] ring of expanded rows; row r of the halo
    # lives at slot (p + r) % RS — a second, inner vMCU ring.
    def expand_row(h_idx, slot):
        """PW1: A[h_idx] (W x C_in) -> B slot (W x C_mid)."""
        a_row = y_row  # reuse scratch? no — separate load target
        off = jax.lax.rem(in_ptr + h_idx * W, n_seg)
        cp = pltpu.make_async_copy(pool_ref.at[pl.ds(off, W)],
                                   a_row.at[pl.ds(0, W)], sem_in)
        cp.start()
        cp.wait()
        a = a_row[pl.ds(0, W), pl.ds(0, C_in)].astype(jnp.float32)
        b = jnp.dot(a, w1_ref[...].astype(jnp.float32),
                    preferred_element_type=jnp.float32)
        b_rows[slot] = jnp.maximum(b, 0.0).astype(b_rows.dtype)  # ReLU

    # Invariant: A-row h (expanded to B) lives at halo slot h % RS.
    # First output row primes rows 0..pad; each later row expands exactly
    # one new row (p + pad).  Writes past H land in slots whose reads are
    # always masked (src_h >= H), so the invariant holds for live rows.
    @pl.when(p == 0)
    def _prime():
        for r in range(pad + 1):
            expand_row(min(r, H - 1), r % RS)

    @pl.when(p > 0)
    def _advance():
        h = jnp.clip(p + pad, 0, H - 1)
        expand_row(h, jax.lax.rem(p + pad, RS))

    # --- DW RSxRS over the halo + PW2, one output row ---------------------
    acc = jnp.zeros((W, C_mid), jnp.float32)
    for r in range(RS):
        src_h = p + r - pad
        slot = jax.lax.rem(jnp.clip(src_h, 0, H - 1), RS)
        row = b_rows[slot].astype(jnp.float32)          # [W, C_mid]
        for s in range(RS):
            shift = s - pad
            shifted = jnp.roll(row, -shift, axis=0)
            # zero the wrapped columns ('same' padding)
            col = jax.lax.broadcasted_iota(jnp.int32, (W, 1), 0)
            ok = ((col + shift >= 0) & (col + shift < W)
                  & (src_h >= 0) & (src_h < H))
            acc += jnp.where(ok, shifted, 0.0) \
                * wd_ref[r, s].astype(jnp.float32)[None, :]
    c_row = jnp.maximum(acc, 0.0)                       # [W, C_mid]
    d_row = jnp.dot(c_row, w2_ref[...].astype(jnp.float32),
                    preferred_element_type=jnp.float32)  # [W, C_out]

    if residual:
        off = jax.lax.rem(in_ptr + p * W, n_seg)
        cp = pltpu.make_async_copy(pool_ref.at[pl.ds(off, W)],
                                   y_row.at[pl.ds(0, W)], sem_in)
        cp.start()
        cp.wait()
        d_row = d_row + y_row[pl.ds(0, W), pl.ds(0, C_out)] \
            .astype(jnp.float32)

    pad_c = y_row.shape[1] - C_out
    e = d_row.astype(y_row.dtype)
    if pad_c:
        e = jnp.pad(e, ((0, 0), (0, pad_c)))
    y_row[pl.ds(0, W)] = e
    off = jax.lax.rem(out_ptr + p * W, n_seg)
    st = pltpu.make_async_copy(y_row.at[pl.ds(0, W)],
                               out_ref.at[pl.ds(off, W)], sem_out)
    st.start()
    st.wait()


@functools.partial(
    jax.jit,
    static_argnames=("H", "W", "C_in", "C_mid", "C_out", "RS", "in_ptr",
                     "out_ptr", "residual", "interpret"),
    donate_argnums=(0,))
def ring_inverted_bottleneck(pool: jax.Array, w1: jax.Array, wd: jax.Array,
                             w2: jax.Array, *, H: int, W: int, C_in: int,
                             C_mid: int, C_out: int, RS: int = 3,
                             in_ptr: int = 0, out_ptr: int = 0,
                             residual: bool = True,
                             interpret: bool = False) -> jax.Array:
    """pool: [n_segments, seg_width] with A resident at ``in_ptr`` (one
    segment per pixel, row-major).  w1: [C_in, C_mid]; wd: [RS, RS, C_mid]
    depthwise; w2: [C_mid, C_out].  Returns the pool with E at ``out_ptr``.
    """
    n_seg, seg_w = pool.shape
    if max(C_in, C_out) > seg_w or C_mid > 8 * seg_w:
        raise ValueError("channel widths exceed segment geometry")
    kernel = functools.partial(
        _kernel, in_ptr=in_ptr, out_ptr=out_ptr, n_seg=n_seg, H=H, W=W,
        C_in=C_in, C_mid=C_mid, C_out=C_out, RS=RS, residual=residual)
    return pl.pallas_call(
        kernel,
        grid=(H,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ARBITRARY),
            pl.BlockSpec((C_in, C_mid), lambda p: (0, 0)),
            pl.BlockSpec((RS, RS, C_mid), lambda p: (0, 0, 0)),
            pl.BlockSpec((C_mid, C_out), lambda p: (0, 0)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ARBITRARY),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        scratch_shapes=[
            pltpu.VMEM((RS, W, C_mid), pool.dtype),   # B halo ring
            pltpu.VMEM((W, seg_w), pool.dtype),       # row I/O staging
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(pool, w1, wd, w2)


def inverted_bottleneck_ref(a: jax.Array, w1: jax.Array, wd: jax.Array,
                            w2: jax.Array, *, residual: bool = True
                            ) -> jax.Array:
    """Oracle: A [H,W,C_in] -> E [H,W,C_out], stride 1, 'same' padding,
    ReLU after PW1 and DW (matching the kernel)."""
    H, W, C_in = a.shape
    RS = wd.shape[0]
    pad = (RS - 1) // 2
    b = jnp.maximum(jnp.einsum("hwc,cm->hwm", a.astype(jnp.float32),
                               w1.astype(jnp.float32)), 0.0)
    bp = jnp.pad(b, ((pad, pad), (pad, pad), (0, 0)))
    c = sum(bp[r:r + H, s:s + W] * wd[r, s].astype(jnp.float32)[None, None]
            for r in range(RS) for s in range(RS))
    c = jnp.maximum(c, 0.0)
    e = jnp.einsum("hwm,mo->hwo", c, w2.astype(jnp.float32))
    if residual:
        e = e + a.astype(jnp.float32)
    return e.astype(a.dtype)
