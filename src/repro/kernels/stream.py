"""Streaming ring kernels — persistent temporal state on the segment ring.

Per-frame ops for ``repro.stream`` (DESIGN.md §14), following the
``conv2d``/``quantized`` skeleton (pool in HBM/ARBITRARY, async copies,
input/output aliasing):

  * ``ring_conv_stream``   — sliding-window temporal conv.  Grid step 0
                             assembles the shifted window in a VMEM
                             scratch (DMA the kept state rows + the new
                             frame rows) and DMAs it back to the state
                             region; every grid step then computes one
                             output image row from the VMEM-resident
                             window (the scratch persists across the
                             sequential grid, like the avgpool
                             accumulator).
  * ``ring_gru_cell``      — gated recurrence: the hidden row at
                             ``state_ptr`` is read, updated with the
                             shared hard-gate math
                             (``repro.quant.requant.gru_update``), and
                             stored to BOTH the state region and the
                             chained output.
  * ``*_q`` twins          — the int8 deployment forms (int32
                             accumulate, CMSIS-NN requantize; the GRU
                             runs the fully-integer Q12 pipeline, so jnp
                             and Pallas agree bitwise).

The state region never wraps — the planner places it above the frame
program's linear extent (``core.program``, wrap-free placement) — so the
state offsets here are static Python ints; only the per-row output
offset needs the ``% n_segments`` bounds check.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.program import resolve_activation
from ..quant.requant import act_i32 as _q_act
from ..quant.requant import (gru_update, gru_update_q12, requantize,
                             requantize_i32)
from .segment_matmul import SEG_WIDTH, _segs


# ---------------------------------------------------------------------------
# Sliding-window temporal conv.
# ---------------------------------------------------------------------------

def _shift_window_p0(p, pool_ref, out_ref, w_vmem, sem_in, sem_out, *,
                     in_ptr: int, state_ptr: int, wc: int, h_win: int,
                     hop: int):
    """Grid step 0: build the shifted window in VMEM and write it back."""
    keep = (h_win - hop) * wc

    @pl.when(p == 0)
    def _():
        cp1 = pltpu.make_async_copy(
            pool_ref.at[pl.ds(state_ptr + hop * wc, keep)],
            w_vmem.at[pl.ds(0, keep)], sem_in)
        cp1.start()
        cp1.wait()
        cp2 = pltpu.make_async_copy(pool_ref.at[pl.ds(in_ptr, hop * wc)],
                                    w_vmem.at[pl.ds(keep, hop * wc)],
                                    sem_in)
        cp2.start()
        cp2.wait()
        st = pltpu.make_async_copy(w_vmem,
                                   out_ref.at[pl.ds(state_ptr,
                                                    h_win * wc)], sem_out)
        st.start()
        st.wait()


def _stream_kernel(pool_ref, w_ref, b_ref, out_ref, w_vmem, y_vmem, sem_in,
                   sem_out, *, in_ptr: int, out_ptr: int, state_ptr: int,
                   n_seg: int, h_win: int, w_in: int, h_out: int,
                   w_out: int, c_in: int, c_out: int, k: int, stride: int,
                   hop: int, pad_v: int, pad_h: int,
                   activation: str | None):
    p = pl.program_id(0)
    ksegs, nsegs = _segs(c_in), _segs(c_out)
    wc = w_in * ksegs
    _shift_window_p0(p, pool_ref, out_ref, w_vmem, sem_in, sem_out,
                     in_ptr=in_ptr, state_ptr=state_ptr, wc=wc,
                     h_win=h_win, hop=hop)
    acc = jnp.zeros((w_out, c_out), jnp.float32)
    qs = jax.lax.broadcasted_iota(jnp.int32, (w_out, 1), 0)[:, 0]
    for r in range(k):
        src = p * stride - pad_v + r
        valid_r = (src >= 0) & (src < h_win)
        srcc = jnp.clip(src, 0, h_win - 1)
        row = w_vmem[pl.ds(srcc * wc, wc)] \
            .reshape(w_in, ksegs * SEG_WIDTH)[:, :c_in] \
            .astype(jnp.float32)
        for s in range(k):
            cols = qs * stride - pad_h + s
            valid_c = (cols >= 0) & (cols < w_in)
            tap = jnp.take(row, jnp.clip(cols, 0, w_in - 1), axis=0)
            ok = valid_r & valid_c[:, None]
            acc = acc + jnp.dot(jnp.where(ok, tap, 0.0),
                                w_ref[r, s].astype(jnp.float32),
                                preferred_element_type=jnp.float32)
    y = resolve_activation(activation)(acc + b_ref[...].astype(jnp.float32))
    y = y.astype(y_vmem.dtype)
    padw = nsegs * SEG_WIDTH - c_out
    if padw:
        y = jnp.pad(y, ((0, 0), (0, padw)))
    y_vmem[...] = y.reshape(w_out * nsegs, SEG_WIDTH)
    ooff = jax.lax.rem(out_ptr + p * (w_out * nsegs), n_seg)
    store = pltpu.make_async_copy(y_vmem,
                                  out_ref.at[pl.ds(ooff, w_out * nsegs)],
                                  sem_out)
    store.start()
    store.wait()


def _stream_geometry(pool, *, w_in, w_out, c_in, c_out, h_win, hop,
                     in_ptr, out_ptr, state_ptr):
    n_seg = pool.shape[0]
    ksegs, nsegs = _segs(c_in), _segs(c_out)
    wc = w_in * ksegs
    if h_win % hop:
        raise ValueError("hop must divide h_win")
    if n_seg % wc or n_seg % (w_out * nsegs) or in_ptr % wc \
            or out_ptr % (w_out * nsegs) or state_ptr % wc:
        raise ValueError("pool/pointers not image-row aligned")
    if state_ptr + h_win * wc > n_seg or in_ptr + hop * wc > n_seg:
        raise ValueError("state/frame region wraps — streaming programs "
                         "must be planned wrap-free (core.program)")
    return n_seg, ksegs, nsegs, wc


@functools.partial(
    jax.jit,
    static_argnames=("h_win", "w_in", "h_out", "w_out", "c_in", "c_out",
                     "k", "stride", "padding", "hop", "in_ptr", "out_ptr",
                     "state_ptr", "activation", "interpret"),
    donate_argnums=(0,))
def ring_conv_stream(pool: jax.Array, w: jax.Array, b: jax.Array, *,
                     h_win: int, w_in: int, h_out: int, w_out: int,
                     c_in: int, c_out: int, k: int = 3, stride: int = 1,
                     padding: str = "same", hop: int = 1, in_ptr: int = 0,
                     out_ptr: int = 0, state_ptr: int = 0,
                     activation: str | None = None,
                     interpret: bool = False) -> jax.Array:
    """One streaming step: shift the ring-resident ``[h_win, w_in, c_in]``
    window by ``hop`` image rows, append the staged frame, write the
    window back at ``state_ptr``, and emit the full k x k conv output
    ``[h_out, w_out, c_out]`` at ``out_ptr`` (``w``: [k, k, c_in,
    c_out])."""
    from ..core.rowsched import conv_k2d_pad, conv_k2d_pad_w

    n_seg, ksegs, nsegs, wc = _stream_geometry(
        pool, w_in=w_in, w_out=w_out, c_in=c_in, c_out=c_out, h_win=h_win,
        hop=hop, in_ptr=in_ptr, out_ptr=out_ptr, state_ptr=state_ptr)
    kernel = functools.partial(
        _stream_kernel, in_ptr=in_ptr, out_ptr=out_ptr,
        state_ptr=state_ptr, n_seg=n_seg, h_win=h_win, w_in=w_in,
        h_out=h_out, w_out=w_out, c_in=c_in, c_out=c_out, k=k,
        stride=stride, hop=hop, pad_v=conv_k2d_pad(k, padding),
        pad_h=conv_k2d_pad_w(k, padding), activation=activation)
    return pl.pallas_call(
        kernel,
        grid=(h_out,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ARBITRARY),
            pl.BlockSpec((k, k, c_in, c_out), lambda p: (0, 0, 0, 0)),
            pl.BlockSpec((c_out,), lambda p: (0,)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ARBITRARY),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        scratch_shapes=[
            pltpu.VMEM((h_win * wc, SEG_WIDTH), pool.dtype),
            pltpu.VMEM((w_out * nsegs, SEG_WIDTH), pool.dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(pool, w, b)


def _stream_q_kernel(pool_ref, w_ref, b_ref, m_ref, s_ref, out_ref, w_vmem,
                     y_vmem, sem_in, sem_out, *, in_ptr: int, out_ptr: int,
                     state_ptr: int, n_seg: int, h_win: int, w_in: int,
                     h_out: int, w_out: int, c_in: int, c_out: int, k: int,
                     stride: int, hop: int, pad_v: int, pad_h: int,
                     activation: str | None):
    p = pl.program_id(0)
    ksegs, nsegs = _segs(c_in), _segs(c_out)
    wc = w_in * ksegs
    _shift_window_p0(p, pool_ref, out_ref, w_vmem, sem_in, sem_out,
                     in_ptr=in_ptr, state_ptr=state_ptr, wc=wc,
                     h_win=h_win, hop=hop)
    acc = jnp.zeros((w_out, c_out), jnp.int32)
    qs = jax.lax.broadcasted_iota(jnp.int32, (w_out, 1), 0)[:, 0]
    for r in range(k):
        src = p * stride - pad_v + r
        valid_r = (src >= 0) & (src < h_win)
        srcc = jnp.clip(src, 0, h_win - 1)
        row = w_vmem[pl.ds(srcc * wc, wc)] \
            .reshape(w_in, ksegs * SEG_WIDTH)[:, :c_in] \
            .astype(jnp.int32)
        for s in range(k):
            cols = qs * stride - pad_h + s
            valid_c = (cols >= 0) & (cols < w_in)
            tap = jnp.take(row, jnp.clip(cols, 0, w_in - 1), axis=0)
            ok = valid_r & valid_c[:, None]
            acc = acc + jnp.dot(jnp.where(ok, tap, 0),
                                w_ref[r, s].astype(jnp.int32),
                                preferred_element_type=jnp.int32)
    acc = _q_act(acc + b_ref[...].astype(jnp.int32), activation)
    y = requantize(acc, m_ref[...][None, :], s_ref[...][None, :])
    padw = nsegs * SEG_WIDTH - c_out
    if padw:
        y = jnp.pad(y, ((0, 0), (0, padw)))
    y_vmem[...] = y.reshape(w_out * nsegs, SEG_WIDTH)
    ooff = jax.lax.rem(out_ptr + p * (w_out * nsegs), n_seg)
    store = pltpu.make_async_copy(y_vmem,
                                  out_ref.at[pl.ds(ooff, w_out * nsegs)],
                                  sem_out)
    store.start()
    store.wait()


@functools.partial(
    jax.jit,
    static_argnames=("h_win", "w_in", "h_out", "w_out", "c_in", "c_out",
                     "k", "stride", "padding", "hop", "in_ptr", "out_ptr",
                     "state_ptr", "activation", "interpret"),
    donate_argnums=(0,))
def ring_conv_stream_q(pool: jax.Array, w: jax.Array, b: jax.Array,
                       mult: jax.Array, shift: jax.Array, *, h_win: int,
                       w_in: int, h_out: int, w_out: int, c_in: int,
                       c_out: int, k: int = 3, stride: int = 1,
                       padding: str = "same", hop: int = 1,
                       in_ptr: int = 0, out_ptr: int = 0,
                       state_ptr: int = 0, activation: str | None = None,
                       interpret: bool = False) -> jax.Array:
    """Int8 streaming conv: the window shift/writeback is an exact int8
    copy; the conv is the conv_k2d int32-accumulate + per-channel
    requantize pipeline."""
    from ..core.rowsched import conv_k2d_pad, conv_k2d_pad_w

    n_seg, ksegs, nsegs, wc = _stream_geometry(
        pool, w_in=w_in, w_out=w_out, c_in=c_in, c_out=c_out, h_win=h_win,
        hop=hop, in_ptr=in_ptr, out_ptr=out_ptr, state_ptr=state_ptr)
    kernel = functools.partial(
        _stream_q_kernel, in_ptr=in_ptr, out_ptr=out_ptr,
        state_ptr=state_ptr, n_seg=n_seg, h_win=h_win, w_in=w_in,
        h_out=h_out, w_out=w_out, c_in=c_in, c_out=c_out, k=k,
        stride=stride, hop=hop, pad_v=conv_k2d_pad(k, padding),
        pad_h=conv_k2d_pad_w(k, padding), activation=activation)
    return pl.pallas_call(
        kernel,
        grid=(h_out,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ARBITRARY),
            pl.BlockSpec((k, k, c_in, c_out), lambda p: (0, 0, 0, 0)),
            pl.BlockSpec((c_out,), lambda p: (0,)),
            pl.BlockSpec((c_out,), lambda p: (0,)),
            pl.BlockSpec((c_out,), lambda p: (0,)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ARBITRARY),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        scratch_shapes=[
            pltpu.VMEM((h_win * wc, SEG_WIDTH), pool.dtype),
            pltpu.VMEM((w_out * nsegs, SEG_WIDTH), pool.dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(pool, w, b, mult, shift)


# ---------------------------------------------------------------------------
# GRU cell.
# ---------------------------------------------------------------------------

def _gru_geometry(pool, *, d_in, d_h, in_ptr, out_ptr, state_ptr):
    n_seg = pool.shape[0]
    ci, co = _segs(d_in), _segs(d_h)
    if n_seg % ci or n_seg % co or in_ptr % ci or out_ptr % co \
            or state_ptr % co:
        raise ValueError("pool/pointers not row aligned")
    if state_ptr + co > n_seg or in_ptr + ci > n_seg:
        raise ValueError("state/frame region wraps — streaming programs "
                         "must be planned wrap-free (core.program)")
    return n_seg, ci, co


def _gru_loads(pool_ref, x_vmem, h_vmem, sem_in, *, in_ptr, state_ptr,
               ci, co):
    cp1 = pltpu.make_async_copy(pool_ref.at[pl.ds(in_ptr, ci)], x_vmem,
                                sem_in)
    cp1.start()
    cp1.wait()
    cp2 = pltpu.make_async_copy(pool_ref.at[pl.ds(state_ptr, co)], h_vmem,
                                sem_in)
    cp2.start()
    cp2.wait()


def _gru_stores(out_ref, h_vmem, sem_out, *, out_ptr, state_ptr, co,
                n_seg):
    st1 = pltpu.make_async_copy(h_vmem, out_ref.at[pl.ds(state_ptr, co)],
                                sem_out)
    st1.start()
    st1.wait()
    st2 = pltpu.make_async_copy(h_vmem,
                                out_ref.at[pl.ds(out_ptr % n_seg, co)],
                                sem_out)
    st2.start()
    st2.wait()


def _gru_kernel(pool_ref, w_ref, u_ref, b_ref, out_ref, x_vmem, h_vmem,
                sem_in, sem_out, *, in_ptr: int, out_ptr: int,
                state_ptr: int, n_seg: int, d_in: int, d_h: int):
    ci, co = _segs(d_in), _segs(d_h)
    _gru_loads(pool_ref, x_vmem, h_vmem, sem_in, in_ptr=in_ptr,
               state_ptr=state_ptr, ci=ci, co=co)
    x = x_vmem[...].reshape(1, ci * SEG_WIDTH)[:, :d_in] \
        .astype(jnp.float32)
    h = h_vmem[...].reshape(1, co * SEG_WIDTH)[:, :d_h] \
        .astype(jnp.float32)
    gx = jnp.dot(x, w_ref[...].astype(jnp.float32),
                 preferred_element_type=jnp.float32) \
        + b_ref[...].astype(jnp.float32)
    gh = jnp.dot(h, u_ref[...].astype(jnp.float32),
                 preferred_element_type=jnp.float32)
    hp = gru_update(gx, gh, h, d_h).astype(h_vmem.dtype)
    pad = co * SEG_WIDTH - d_h
    if pad:
        hp = jnp.pad(hp, ((0, 0), (0, pad)))
    h_vmem[...] = hp.reshape(co, SEG_WIDTH)
    _gru_stores(out_ref, h_vmem, sem_out, out_ptr=out_ptr,
                state_ptr=state_ptr, co=co, n_seg=n_seg)


@functools.partial(
    jax.jit,
    static_argnames=("d_in", "d_h", "in_ptr", "out_ptr", "state_ptr",
                     "interpret"),
    donate_argnums=(0,))
def ring_gru_cell(pool: jax.Array, w: jax.Array, u: jax.Array,
                  b: jax.Array, *, d_in: int, d_h: int, in_ptr: int = 0,
                  out_ptr: int = 0, state_ptr: int = 0,
                  interpret: bool = False) -> jax.Array:
    """One GRU step in the ring: ``h' = gru_update(x@w + b, h@u, h)``
    with ``h`` the pool-resident row at ``state_ptr``; ``h'`` is written
    back there AND chained at ``out_ptr``."""
    n_seg, ci, co = _gru_geometry(pool, d_in=d_in, d_h=d_h, in_ptr=in_ptr,
                                  out_ptr=out_ptr, state_ptr=state_ptr)
    kernel = functools.partial(_gru_kernel, in_ptr=in_ptr, out_ptr=out_ptr,
                               state_ptr=state_ptr, n_seg=n_seg,
                               d_in=d_in, d_h=d_h)
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ARBITRARY),
            pl.BlockSpec((d_in, 3 * d_h), lambda p: (0, 0)),
            pl.BlockSpec((d_h, 3 * d_h), lambda p: (0, 0)),
            pl.BlockSpec((3 * d_h,), lambda p: (0,)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ARBITRARY),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        scratch_shapes=[
            pltpu.VMEM((ci, SEG_WIDTH), pool.dtype),
            pltpu.VMEM((co, SEG_WIDTH), pool.dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(pool, w, u, b)


def _gru_q_kernel(pool_ref, w_ref, u_ref, b_ref, mx_ref, sx_ref, mu_ref,
                  su_ref, out_ref, x_vmem, h_vmem, sem_in, sem_out, *,
                  in_ptr: int, out_ptr: int, state_ptr: int, n_seg: int,
                  d_in: int, d_h: int):
    ci, co = _segs(d_in), _segs(d_h)
    _gru_loads(pool_ref, x_vmem, h_vmem, sem_in, in_ptr=in_ptr,
               state_ptr=state_ptr, ci=ci, co=co)
    x = x_vmem[...].reshape(1, ci * SEG_WIDTH)[:, :d_in] \
        .astype(jnp.int32)
    h = h_vmem[...].reshape(1, co * SEG_WIDTH)[:, :d_h]
    gx = requantize_i32(
        jnp.dot(x, w_ref[...].astype(jnp.int32),
                preferred_element_type=jnp.int32),
        mx_ref[...][None, :], sx_ref[...][None, :])
    gx = gx + b_ref[...].astype(jnp.int32)
    gh = requantize_i32(
        jnp.dot(h.astype(jnp.int32), u_ref[...].astype(jnp.int32),
                preferred_element_type=jnp.int32),
        mu_ref[...][None, :], su_ref[...][None, :])
    hp = gru_update_q12(gx, gh, h, d_h)
    pad = co * SEG_WIDTH - d_h
    if pad:
        hp = jnp.pad(hp, ((0, 0), (0, pad)))
    h_vmem[...] = hp.reshape(co, SEG_WIDTH)
    _gru_stores(out_ref, h_vmem, sem_out, out_ptr=out_ptr,
                state_ptr=state_ptr, co=co, n_seg=n_seg)


@functools.partial(
    jax.jit,
    static_argnames=("d_in", "d_h", "in_ptr", "out_ptr", "state_ptr",
                     "interpret"),
    donate_argnums=(0,))
def ring_gru_cell_q(pool: jax.Array, w: jax.Array, u: jax.Array,
                    b: jax.Array, mult_x: jax.Array, shift_x: jax.Array,
                    mult_u: jax.Array, shift_u: jax.Array, *, d_in: int,
                    d_h: int, in_ptr: int = 0, out_ptr: int = 0,
                    state_ptr: int = 0,
                    interpret: bool = False) -> jax.Array:
    """Int8 GRU step: both int32 accumulators requantize to the Q12 gate
    domain, the update is the shared fully-integer pipeline
    (``gru_update_q12``) and the hidden state stays at the fixed Q7
    scale — bitwise-equal to the jnp executor."""
    n_seg, ci, co = _gru_geometry(pool, d_in=d_in, d_h=d_h, in_ptr=in_ptr,
                                  out_ptr=out_ptr, state_ptr=state_ptr)
    kernel = functools.partial(_gru_q_kernel, in_ptr=in_ptr,
                               out_ptr=out_ptr, state_ptr=state_ptr,
                               n_seg=n_seg, d_in=d_in, d_h=d_h)
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ARBITRARY),
            pl.BlockSpec((d_in, 3 * d_h), lambda p: (0, 0)),
            pl.BlockSpec((d_h, 3 * d_h), lambda p: (0, 0)),
            pl.BlockSpec((3 * d_h,), lambda p: (0,)),
            pl.BlockSpec((3 * d_h,), lambda p: (0,)),
            pl.BlockSpec((3 * d_h,), lambda p: (0,)),
            pl.BlockSpec((3 * d_h,), lambda p: (0,)),
            pl.BlockSpec((3 * d_h,), lambda p: (0,)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ARBITRARY),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        scratch_shapes=[
            pltpu.VMEM((ci, SEG_WIDTH), pool.dtype),
            pltpu.VMEM((co, SEG_WIDTH), pool.dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(pool, w, u, b, mult_x, shift_x, mult_u, shift_u)
