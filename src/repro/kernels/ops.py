"""Jit'd public wrappers around the Pallas kernels.

Each wrapper is now a one-call demonstration of the unified API: plan a
:class:`PoolProgram`, alloc a :class:`VirtualPool`, ``execute`` on the
``pallas`` backend, fetch the result.  Production code keeps the pool
alive across a longer program (see examples/quickstart.py).

On CPU (this container) every kernel runs in ``interpret=True`` mode — the
kernel body executes in Python, validating ring logic and numerics; on a
TPU backend the same call sites compile through Mosaic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .segment_matmul import (SEG_WIDTH, aligned_pool_geometry, fetch_rows,
                             ring_gemm, stage_rows)
from .fused_mlp import ring_fused_mlp
from .ring_decode import ring_cache_update, ring_decode_attention
from ..core.executors import execute
from ..core.program import FusedMLPSpec, GemmSpec, plan_program
from ..core.vpool import VirtualPool, segments_for


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def segment_gemm(x: jax.Array, w: jax.Array, b: jax.Array | None = None, *,
                 block_rows: int = 8) -> tuple[jax.Array, dict]:
    """Plan + stage + run the ring GEMM; returns (result, plan_info).

    This is the one-call demonstration path; production code keeps the pool
    alive across layers (see examples/quickstart.py).
    """
    m, d_in = x.shape
    d_out = w.shape[1]
    program = plan_program(m, d_in, [GemmSpec(d_out)], seg_width=SEG_WIDTH,
                           block_rows=block_rows,
                           elem_bytes=jnp.dtype(x.dtype).itemsize)
    pool = VirtualPool.alloc(program.spec(x.dtype))
    pool = pool.stage_rows(x, program.input_ptr)
    pool = execute(program, pool, [(w, b)], backend="pallas",
                   interpret=_interpret())
    y = pool.fetch_rows(program.output_ptr, m, d_out)
    op = program.ops[0]
    info = dict(n_segments=program.n_segments, in_ptr=op.in_ptr,
                out_ptr=op.out_ptr, delta=op.delta,
                pool_bytes=program.physical_pool_bytes,
                naive_bytes=program.naive_bytes)
    return y, info


def fused_mlp(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
              w_down: jax.Array, *, block_rows: int = 8, ff_tile: int = 512,
              gated: bool = True, residual: bool = True,
              activation: str = "gelu") -> jax.Array:
    """In-place fused MLP through a fresh ring pool (delta == 0)."""
    m, d = x.shape
    program = plan_program(
        m, d,
        [FusedMLPSpec(d_ff=w_up.shape[1], gated=gated, residual=residual,
                      activation=activation, ff_tile=ff_tile)],
        seg_width=SEG_WIDTH, block_rows=block_rows,
        elem_bytes=jnp.dtype(x.dtype).itemsize)
    pool = VirtualPool.alloc(program.spec(x.dtype))
    pool = pool.stage_rows(x, program.input_ptr)
    pool = execute(program, pool, [(w_gate, w_up, w_down)],
                   backend="pallas", interpret=_interpret())
    return pool.fetch_rows(program.output_ptr, m, d)


def decode_attention(q: jax.Array, k_ring: jax.Array, v_ring: jax.Array,
                     seq_len: jax.Array, *, window: int, block: int = 128,
                     softcap: float | None = None) -> jax.Array:
    return ring_decode_attention(q, k_ring, v_ring,
                                 jnp.asarray(seq_len, jnp.int32),
                                 window=window, block=block, softcap=softcap,
                                 interpret=_interpret())


__all__ = [
    "segment_gemm", "fused_mlp", "decode_attention", "ring_cache_update",
    "ring_gemm", "ring_fused_mlp", "ring_decode_attention",
    "aligned_pool_geometry", "stage_rows", "fetch_rows", "SEG_WIDTH", "ref",
    "segments_for",
]
