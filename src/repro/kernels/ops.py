"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) every kernel runs in ``interpret=True`` mode — the
kernel body executes in Python, validating ring logic and numerics; on a TPU
backend the same call sites compile through Mosaic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .segment_matmul import (SEG_WIDTH, aligned_pool_geometry, fetch_rows,
                             ring_gemm, stage_rows)
from .fused_mlp import ring_fused_mlp
from .ring_decode import ring_cache_update, ring_decode_attention
from ..core.planner import gemm_offset_closed_form


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def segment_gemm(x: jax.Array, w: jax.Array, b: jax.Array | None = None, *,
                 block_rows: int = 8) -> tuple[jax.Array, dict]:
    """Plan + stage + run the ring GEMM; returns (result, plan_info).

    This is the one-call demonstration path; production code keeps the pool
    alive across layers (see examples/quickstart.py).
    """
    m, d_in = x.shape
    d_out = w.shape[1]
    if b is None:
        b = jnp.zeros((d_out,), w.dtype)
    k_segs = -(-d_in // SEG_WIDTH)
    n_segs = -(-d_out // SEG_WIDTH)
    delta = gemm_offset_closed_form(m, n_segs, k_segs)
    n_seg, in_ptr, out_ptr = aligned_pool_geometry(
        m, d_in, d_out, delta, block_rows)
    pool = jnp.zeros((n_seg, SEG_WIDTH), x.dtype)
    pool = stage_rows(pool, x, in_ptr)
    pool = ring_gemm(pool, w, b, m_rows=m, d_in=d_in, d_out=d_out,
                     in_ptr=in_ptr, out_ptr=out_ptr, block_rows=block_rows,
                     interpret=_interpret())
    y = fetch_rows(pool, out_ptr, m, d_out)
    info = dict(n_segments=n_seg, in_ptr=in_ptr, out_ptr=out_ptr,
                delta=delta,
                pool_bytes=n_seg * SEG_WIDTH * x.dtype.itemsize,
                naive_bytes=(m * k_segs + m * n_segs) * SEG_WIDTH
                * x.dtype.itemsize)
    return y, info


def fused_mlp(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
              w_down: jax.Array, *, block_rows: int = 8, ff_tile: int = 512,
              gated: bool = True, residual: bool = True,
              activation: str = "gelu") -> jax.Array:
    """In-place fused MLP through a fresh ring pool (delta == 0)."""
    m, d = x.shape
    d_segs = -(-d // SEG_WIDTH)
    bd = block_rows * d_segs
    n_seg = -(-(m * d_segs) // bd) * bd
    pool = jnp.zeros((n_seg, SEG_WIDTH), x.dtype)
    pool = stage_rows(pool, x, 0)
    pool = ring_fused_mlp(pool, w_gate, w_up, w_down, m_rows=m, d_model=d,
                          ptr=0, block_rows=block_rows, ff_tile=ff_tile,
                          gated=gated, residual=residual,
                          activation=activation, interpret=_interpret())
    return fetch_rows(pool, 0, m, d)


def decode_attention(q: jax.Array, k_ring: jax.Array, v_ring: jax.Array,
                     seq_len: jax.Array, *, window: int, block: int = 128,
                     softcap: float | None = None) -> jax.Array:
    return ring_decode_attention(q, k_ring, v_ring,
                                 jnp.asarray(seq_len, jnp.int32),
                                 window=window, block=block, softcap=softcap,
                                 interpret=_interpret())


__all__ = [
    "segment_gemm", "fused_mlp", "decode_attention", "ring_cache_update",
    "ring_gemm", "ring_fused_mlp", "ring_decode_attention",
    "aligned_pool_geometry", "stage_rows", "fetch_rows", "SEG_WIDTH", "ref",
]
