"""Ring KV-cache decode attention — vMCU's circular pool applied to
sliding-window KV caches (gemma2/3, recurrentgemma local layers).

A sliding-window cache IS a vMCU segment pool: slot ``t % window`` holds
token ``t``'s K/V segment, the write pointer advances modulo the window, and
"RAMFree" is the overwrite of the evicted token.  The decode kernel is a
flash-decoding pass over the ring with an *online softmax* accumulated in
VMEM scratch; slot validity (``t < seq_len`` before the ring fills) plays the
role of the paper's boundary check.

Layout: k_ring/v_ring ``[window, kv_heads, head_dim]``; q ``[q_heads,
head_dim]`` (one decode step). GQA: q_heads = kv_heads * group.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(seq_len_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, window: int, block: int,
            softcap: float | None):
    b = pl.program_id(0)
    nb = pl.num_programs(0)

    @pl.when(b == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    seq_len = seq_len_ref[0]
    q = q_ref[...].astype(jnp.float32)            # [q_heads, d]
    k = k_ref[...].astype(jnp.float32)            # [block, kv_heads, d]
    v = v_ref[...].astype(jnp.float32)
    q_heads, d = q.shape
    kv_heads = k.shape[1]
    group = q_heads // kv_heads

    # scores[s, h] for ring slots s in this block
    qg = q.reshape(kv_heads, group, d)
    s = jnp.einsum("khd,bkd->bkh", qg * (d ** -0.5), k)   # [block, kv, group]
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    # Ring validity: slot id < seq_len OR the ring has fully wrapped.
    slot = b * block + jax.lax.broadcasted_iota(jnp.int32, (block, 1, 1), 0)
    valid = (slot < seq_len) | (seq_len >= window)
    s = jnp.where(valid, s, NEG_INF)
    s = s.reshape(block, q_heads)

    m_prev, l_prev = m_scr[...], l_scr[...]       # [q_heads]
    m_cur = jnp.max(s, axis=0)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[None, :])               # [block, q_heads]
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=0)
    vg = jnp.repeat(v, group, axis=1)             # [block, q_heads, d]
    pv = jnp.einsum("bh,bhd->hd", p, vg)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(b == nb - 1)
    def _done():
        o_ref[...] = (acc_scr[...] / l_scr[...][:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "block", "softcap", "interpret"))
def ring_decode_attention(q: jax.Array, k_ring: jax.Array, v_ring: jax.Array,
                          seq_len: jax.Array, *, window: int,
                          block: int = 128, softcap: float | None = None,
                          interpret: bool = False) -> jax.Array:
    """One decode step of attention over a ring KV cache.

    q: [q_heads, head_dim]; k_ring/v_ring: [window, kv_heads, head_dim];
    seq_len: int32 scalar array — tokens written so far (cache already
    contains the current token).  Returns [q_heads, head_dim].
    """
    q_heads, d = q.shape
    kv_heads = k_ring.shape[1]
    if window % block:
        raise ValueError("block must divide window")
    grid = (window // block,)
    kernel = functools.partial(_kernel, window=window, block=block,
                               softcap=softcap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((q_heads, d), lambda b, *_: (0, 0)),
            pl.BlockSpec((block, kv_heads, d), lambda b, *_: (b, 0, 0)),
            pl.BlockSpec((block, kv_heads, d), lambda b, *_: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((q_heads, d), lambda b, *_: (0, 0)),
        scratch_shapes=[
            pltpu.VMEM((q_heads,), jnp.float32),
            pltpu.VMEM((q_heads,), jnp.float32),
            pltpu.VMEM((q_heads, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((q_heads, d), q.dtype),
        interpret=interpret,
    )(seq_len.reshape(1), q, k_ring, v_ring)


def ring_cache_update(k_ring: jax.Array, v_ring: jax.Array, k_new: jax.Array,
                      v_new: jax.Array, seq_len: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
    """Write one token's K/V into ring slot ``seq_len % window`` — the
    paper's RAMStore-with-modulo, verbatim."""
    window = k_ring.shape[0]
    slot = jnp.asarray(seq_len, jnp.int32) % window
    k_ring = jax.lax.dynamic_update_slice(
        k_ring, k_new[None].astype(k_ring.dtype), (slot, 0, 0))
    v_ring = jax.lax.dynamic_update_slice(
        v_ring, v_new[None].astype(v_ring.dtype), (slot, 0, 0))
    return k_ring, v_ring
