"""Int8 ring kernels — the quantized whole-network PoolOps on TPU.

Every kernel follows the fp32 skeletons (``segment_matmul`` /
``conv2d``): the int8 pool stays in HBM/ARBITRARY, async copies perform
the ``addr % n_segments`` circular-buffer bounds check, and input/output
aliasing updates the pool in place.  What changes is the element math —
the MCU deployment form:

  * loads are int8 segments (one pool segment is now ``SEG_WIDTH`` bytes,
    so the executed ring is byte-comparable to the paper's
    ``mcu_bottleneck_bytes``),
  * the Dot accumulates in int32 (MXU int8 path;
    ``preferred_element_type=jnp.int32`` — the SMLAD/``VMLADAVA.S8``
    analogue),
  * the store epilogue is the CMSIS-NN fixed-point requantization
    (:func:`repro.quant.requant.requantize`: multiplier+shift,
    round-to-nearest-even, saturating int8) with per-output-channel
    constants streamed from "Flash" like the weights.

Scalar requant pairs (residual add, avgpool) are static kernel
parameters; per-channel pairs ride as int32 operands next to the bias.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..quant.requant import act_i32 as _q_act
from ..quant.requant import requantize, requantize_i32
from .segment_matmul import SEG_WIDTH, _segs


# ---------------------------------------------------------------------------
# GEMM.
# ---------------------------------------------------------------------------

def _gemm_kernel(pool_ref, w_ref, b_ref, m_ref, s_ref, out_ref, x_vmem,
                 y_vmem, sem_in, sem_out, *, in_ptr: int, out_ptr: int,
                 n_seg: int, block_rows: int, d_in: int, d_out: int,
                 activation: str | None):
    i = pl.program_id(0)
    k_segs, n_segs = _segs(d_in), _segs(d_out)
    bk, bn = block_rows * k_segs, block_rows * n_segs
    in_off = jax.lax.rem(in_ptr + i * bk, n_seg)
    load = pltpu.make_async_copy(pool_ref.at[pl.ds(in_off, bk)], x_vmem,
                                 sem_in)
    load.start()
    load.wait()
    x = x_vmem[...].reshape(block_rows, k_segs * SEG_WIDTH)[:, :d_in]
    acc = jnp.dot(x.astype(jnp.int32), w_ref[...].astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    acc = _q_act(acc + b_ref[...].astype(jnp.int32), activation)
    y = requantize(acc, m_ref[...][None, :], s_ref[...][None, :])
    pad = n_segs * SEG_WIDTH - d_out
    if pad:
        y = jnp.pad(y, ((0, 0), (0, pad)))
    y_vmem[...] = y.reshape(bn, SEG_WIDTH)
    out_off = jax.lax.rem(out_ptr + i * bn, n_seg)
    store = pltpu.make_async_copy(y_vmem, out_ref.at[pl.ds(out_off, bn)],
                                  sem_out)
    store.start()
    store.wait()


@functools.partial(
    jax.jit,
    static_argnames=("m_rows", "d_in", "d_out", "in_ptr", "out_ptr",
                     "block_rows", "activation", "interpret"),
    donate_argnums=(0,))
def ring_gemm_q(pool: jax.Array, w: jax.Array, b: jax.Array,
                mult: jax.Array, shift: jax.Array, *, m_rows: int,
                d_in: int, d_out: int, in_ptr: int, out_ptr: int,
                block_rows: int = 8, activation: str | None = None,
                interpret: bool = False) -> jax.Array:
    """Int8 Fig.-4 FC kernel: int8 In @ int8 W -> int32 acc -> requantize
    per output channel on store."""
    n_seg = pool.shape[0]
    k_segs, n_segs = _segs(d_in), _segs(d_out)
    bk, bn = block_rows * k_segs, block_rows * n_segs
    if m_rows % block_rows:
        raise ValueError("block_rows must divide m_rows")
    if n_seg % math.lcm(bk, bn) or in_ptr % bk or out_ptr % bn:
        raise ValueError("pool/pointers not block-aligned")
    kernel = functools.partial(
        _gemm_kernel, in_ptr=in_ptr, out_ptr=out_ptr, n_seg=n_seg,
        block_rows=block_rows, d_in=d_in, d_out=d_out,
        activation=activation)
    return pl.pallas_call(
        kernel,
        grid=(m_rows // block_rows,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ARBITRARY),
            pl.BlockSpec((d_in, d_out), lambda i: (0, 0)),
            pl.BlockSpec((d_out,), lambda i: (0,)),
            pl.BlockSpec((d_out,), lambda i: (0,)),
            pl.BlockSpec((d_out,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ARBITRARY),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        scratch_shapes=[
            pltpu.VMEM((bk, SEG_WIDTH), pool.dtype),
            pltpu.VMEM((bn, SEG_WIDTH), pool.dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(pool, w, b, mult, shift)


# ---------------------------------------------------------------------------
# Pointwise conv.
# ---------------------------------------------------------------------------

def _pw_kernel(pool_ref, w_ref, b_ref, m_ref, s_ref, out_ref, x_vmem,
               y_vmem, sem_in, sem_out, *, in_ptr: int, out_ptr: int,
               n_seg: int, h_in: int, w_in: int, h_out: int, w_out: int,
               c_in: int, c_out: int, stride: int, resample: bool,
               activation: str | None):
    p = pl.program_id(0)
    ksegs, nsegs = _segs(c_in), _segs(c_out)
    if resample:
        src = jax.lax.div(p * h_in, h_out)
    else:
        src = p * stride
    off = jax.lax.rem(in_ptr + src * (w_in * ksegs), n_seg)
    load = pltpu.make_async_copy(pool_ref.at[pl.ds(off, w_in * ksegs)],
                                 x_vmem, sem_in)
    load.start()
    load.wait()
    x = x_vmem[...].reshape(w_in, ksegs * SEG_WIDTH)[:, :c_in]
    q = jax.lax.broadcasted_iota(jnp.int32, (w_out, 1), 0)[:, 0]
    cols = (q * w_in) // w_out if resample else q * stride
    xs = jnp.take(x, cols, axis=0).astype(jnp.int32)
    acc = jnp.dot(xs, w_ref[...].astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    acc = _q_act(acc + b_ref[...].astype(jnp.int32), activation)
    y = requantize(acc, m_ref[...][None, :], s_ref[...][None, :])
    pad = nsegs * SEG_WIDTH - c_out
    if pad:
        y = jnp.pad(y, ((0, 0), (0, pad)))
    y_vmem[...] = y.reshape(w_out * nsegs, SEG_WIDTH)
    ooff = jax.lax.rem(out_ptr + p * (w_out * nsegs), n_seg)
    store = pltpu.make_async_copy(y_vmem,
                                  out_ref.at[pl.ds(ooff, w_out * nsegs)],
                                  sem_out)
    store.start()
    store.wait()


@functools.partial(
    jax.jit,
    static_argnames=("h_in", "w_in", "h_out", "w_out", "c_in", "c_out",
                     "stride", "resample", "in_ptr", "out_ptr",
                     "activation", "interpret"),
    donate_argnums=(0,))
def ring_conv_pw_q(pool: jax.Array, w: jax.Array, b: jax.Array,
                   mult: jax.Array, shift: jax.Array, *, h_in: int,
                   w_in: int, h_out: int, w_out: int, c_in: int,
                   c_out: int, stride: int = 1, resample: bool = False,
                   in_ptr: int = 0, out_ptr: int = 0,
                   activation: str | None = None,
                   interpret: bool = False) -> jax.Array:
    """Int8 pointwise conv in the ring, one output image row per step."""
    n_seg = pool.shape[0]
    ksegs, nsegs = _segs(c_in), _segs(c_out)
    if n_seg % (w_in * ksegs) or n_seg % (w_out * nsegs) \
            or in_ptr % (w_in * ksegs) or out_ptr % (w_out * nsegs):
        raise ValueError("pool/pointers not image-row aligned")
    kernel = functools.partial(
        _pw_kernel, in_ptr=in_ptr, out_ptr=out_ptr, n_seg=n_seg,
        h_in=h_in, w_in=w_in, h_out=h_out, w_out=w_out, c_in=c_in,
        c_out=c_out, stride=stride, resample=resample,
        activation=activation)
    return pl.pallas_call(
        kernel,
        grid=(h_out,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ARBITRARY),
            pl.BlockSpec((c_in, c_out), lambda p: (0, 0)),
            pl.BlockSpec((c_out,), lambda p: (0,)),
            pl.BlockSpec((c_out,), lambda p: (0,)),
            pl.BlockSpec((c_out,), lambda p: (0,)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ARBITRARY),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        scratch_shapes=[
            pltpu.VMEM((w_in * ksegs, SEG_WIDTH), pool.dtype),
            pltpu.VMEM((w_out * nsegs, SEG_WIDTH), pool.dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(pool, w, b, mult, shift)


# ---------------------------------------------------------------------------
# Depthwise conv.
# ---------------------------------------------------------------------------

def _dw_kernel(pool_ref, w_ref, b_ref, m_ref, s_ref, out_ref, x_vmem,
               y_vmem, sem_in, sem_out, *, in_ptr: int, out_ptr: int,
               n_seg: int, h_in: int, w_in: int, h_out: int, w_out: int,
               c: int, rs: int, stride: int, pad_v: int, pad_h: int,
               activation: str | None):
    p = pl.program_id(0)
    segs = _segs(c)
    acc = jnp.zeros((w_out, c), jnp.int32)
    qs = jax.lax.broadcasted_iota(jnp.int32, (w_out, 1), 0)[:, 0]
    for r in range(rs):
        src = p * stride - pad_v + r
        valid_r = (src >= 0) & (src < h_in)
        srcc = jnp.clip(src, 0, h_in - 1)
        off = jax.lax.rem(in_ptr + srcc * (w_in * segs), n_seg)
        load = pltpu.make_async_copy(pool_ref.at[pl.ds(off, w_in * segs)],
                                     x_vmem, sem_in)
        load.start()
        load.wait()
        row = x_vmem[...].reshape(w_in, segs * SEG_WIDTH)[:, :c] \
            .astype(jnp.int32)
        for s in range(rs):
            cols = qs * stride - pad_h + s
            valid_c = (cols >= 0) & (cols < w_in)
            tap = jnp.take(row, jnp.clip(cols, 0, w_in - 1), axis=0)
            ok = valid_r & valid_c[:, None]
            acc = acc + jnp.where(ok, tap, 0) \
                * w_ref[r, s].astype(jnp.int32)[None, :]
    acc = _q_act(acc + b_ref[...].astype(jnp.int32), activation)
    y = requantize(acc, m_ref[...][None, :], s_ref[...][None, :])
    padw = segs * SEG_WIDTH - c
    if padw:
        y = jnp.pad(y, ((0, 0), (0, padw)))
    y_vmem[...] = y.reshape(w_out * segs, SEG_WIDTH)
    ooff = jax.lax.rem(out_ptr + p * (w_out * segs), n_seg)
    store = pltpu.make_async_copy(y_vmem,
                                  out_ref.at[pl.ds(ooff, w_out * segs)],
                                  sem_out)
    store.start()
    store.wait()


@functools.partial(
    jax.jit,
    static_argnames=("h_in", "w_in", "h_out", "w_out", "c", "rs", "stride",
                     "padding", "in_ptr", "out_ptr", "activation",
                     "interpret"),
    donate_argnums=(0,))
def ring_conv_dw_q(pool: jax.Array, w: jax.Array, b: jax.Array,
                   mult: jax.Array, shift: jax.Array, *, h_in: int,
                   w_in: int, h_out: int, w_out: int, c: int, rs: int = 3,
                   stride: int = 1, padding: str = "same", in_ptr: int = 0,
                   out_ptr: int = 0, activation: str | None = None,
                   interpret: bool = False) -> jax.Array:
    """Int8 depthwise RSxRS conv inside the ring."""
    from ..core.rowsched import conv_k2d_pad, conv_k2d_pad_w

    n_seg = pool.shape[0]
    segs = _segs(c)
    if n_seg % (w_in * segs) or n_seg % (w_out * segs) \
            or in_ptr % (w_in * segs) or out_ptr % (w_out * segs):
        raise ValueError("pool/pointers not image-row aligned")
    kernel = functools.partial(
        _dw_kernel, in_ptr=in_ptr, out_ptr=out_ptr, n_seg=n_seg, h_in=h_in,
        w_in=w_in, h_out=h_out, w_out=w_out, c=c, rs=rs, stride=stride,
        pad_v=conv_k2d_pad(rs, padding), pad_h=conv_k2d_pad_w(rs, padding),
        activation=activation)
    return pl.pallas_call(
        kernel,
        grid=(h_out,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ARBITRARY),
            pl.BlockSpec((rs, rs, c), lambda p: (0, 0, 0)),
            pl.BlockSpec((c,), lambda p: (0,)),
            pl.BlockSpec((c,), lambda p: (0,)),
            pl.BlockSpec((c,), lambda p: (0,)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ARBITRARY),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        scratch_shapes=[
            pltpu.VMEM((w_in * segs, SEG_WIDTH), pool.dtype),
            pltpu.VMEM((w_out * segs, SEG_WIDTH), pool.dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(pool, w, b, mult, shift)


# ---------------------------------------------------------------------------
# General k x k spatial conv.
# ---------------------------------------------------------------------------

def _k2d_kernel(pool_ref, w_ref, b_ref, m_ref, s_ref, out_ref, x_vmem,
                y_vmem, sem_in, sem_out, *, in_ptr: int, out_ptr: int,
                n_seg: int, h_in: int, w_in: int, h_out: int, w_out: int,
                c_in: int, c_out: int, k: int, stride: int, pad_v: int,
                pad_h: int, activation: str | None):
    p = pl.program_id(0)
    ksegs, nsegs = _segs(c_in), _segs(c_out)
    acc = jnp.zeros((w_out, c_out), jnp.int32)
    qs = jax.lax.broadcasted_iota(jnp.int32, (w_out, 1), 0)[:, 0]
    for r in range(k):
        src = p * stride - pad_v + r
        valid_r = (src >= 0) & (src < h_in)
        srcc = jnp.clip(src, 0, h_in - 1)
        off = jax.lax.rem(in_ptr + srcc * (w_in * ksegs), n_seg)
        load = pltpu.make_async_copy(pool_ref.at[pl.ds(off, w_in * ksegs)],
                                     x_vmem, sem_in)
        load.start()
        load.wait()
        row = x_vmem[...].reshape(w_in, ksegs * SEG_WIDTH)[:, :c_in] \
            .astype(jnp.int32)
        for s in range(k):
            cols = qs * stride - pad_h + s
            valid_c = (cols >= 0) & (cols < w_in)
            tap = jnp.take(row, jnp.clip(cols, 0, w_in - 1), axis=0)
            ok = valid_r & valid_c[:, None]
            acc = acc + jnp.dot(jnp.where(ok, tap, 0),
                                w_ref[r, s].astype(jnp.int32),
                                preferred_element_type=jnp.int32)
    acc = _q_act(acc + b_ref[...].astype(jnp.int32), activation)
    y = requantize(acc, m_ref[...][None, :], s_ref[...][None, :])
    padw = nsegs * SEG_WIDTH - c_out
    if padw:
        y = jnp.pad(y, ((0, 0), (0, padw)))
    y_vmem[...] = y.reshape(w_out * nsegs, SEG_WIDTH)
    ooff = jax.lax.rem(out_ptr + p * (w_out * nsegs), n_seg)
    store = pltpu.make_async_copy(y_vmem,
                                  out_ref.at[pl.ds(ooff, w_out * nsegs)],
                                  sem_out)
    store.start()
    store.wait()


@functools.partial(
    jax.jit,
    static_argnames=("h_in", "w_in", "h_out", "w_out", "c_in", "c_out",
                     "k", "stride", "padding", "in_ptr", "out_ptr",
                     "activation", "interpret"),
    donate_argnums=(0,))
def ring_conv_k2d_q(pool: jax.Array, w: jax.Array, b: jax.Array,
                    mult: jax.Array, shift: jax.Array, *, h_in: int,
                    w_in: int, h_out: int, w_out: int, c_in: int,
                    c_out: int, k: int = 3, stride: int = 1,
                    padding: str = "same", in_ptr: int = 0,
                    out_ptr: int = 0, activation: str | None = None,
                    interpret: bool = False) -> jax.Array:
    """Int8 k x k conv inside the ring: int8 halo rows -> int32 dot per
    tap -> per-output-channel requantize on store (symmetric zero point
    keeps the padding exact)."""
    from ..core.rowsched import conv_k2d_pad, conv_k2d_pad_w

    n_seg = pool.shape[0]
    ksegs, nsegs = _segs(c_in), _segs(c_out)
    if n_seg % (w_in * ksegs) or n_seg % (w_out * nsegs) \
            or in_ptr % (w_in * ksegs) or out_ptr % (w_out * nsegs):
        raise ValueError("pool/pointers not image-row aligned")
    kernel = functools.partial(
        _k2d_kernel, in_ptr=in_ptr, out_ptr=out_ptr, n_seg=n_seg,
        h_in=h_in, w_in=w_in, h_out=h_out, w_out=w_out, c_in=c_in,
        c_out=c_out, k=k, stride=stride, pad_v=conv_k2d_pad(k, padding),
        pad_h=conv_k2d_pad_w(k, padding), activation=activation)
    return pl.pallas_call(
        kernel,
        grid=(h_out,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ARBITRARY),
            pl.BlockSpec((k, k, c_in, c_out), lambda p: (0, 0, 0, 0)),
            pl.BlockSpec((c_out,), lambda p: (0,)),
            pl.BlockSpec((c_out,), lambda p: (0,)),
            pl.BlockSpec((c_out,), lambda p: (0,)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ARBITRARY),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        scratch_shapes=[
            pltpu.VMEM((w_in * ksegs, SEG_WIDTH), pool.dtype),
            pltpu.VMEM((w_out * nsegs, SEG_WIDTH), pool.dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(pool, w, b, mult, shift)


# ---------------------------------------------------------------------------
# Residual add.
# ---------------------------------------------------------------------------

def _add_kernel(pool_ref, out_ref, x_vmem, r_vmem, sem_in, sem_out, *,
                in_ptr: int, aux_ptr: int, out_ptr: int, n_seg: int,
                chunk: int, mult_in: int, shift_in: int, mult_aux: int,
                shift_aux: int, activation: str | None):
    t = pl.program_id(0)
    off_x = jax.lax.rem(in_ptr + t * chunk, n_seg)
    off_r = jax.lax.rem(aux_ptr + t * chunk, n_seg)
    cp1 = pltpu.make_async_copy(pool_ref.at[pl.ds(off_x, chunk)], x_vmem,
                                sem_in)
    cp1.start()
    cp1.wait()
    cp2 = pltpu.make_async_copy(pool_ref.at[pl.ds(off_r, chunk)], r_vmem,
                                sem_in)
    cp2.start()
    cp2.wait()
    ya = requantize_i32(x_vmem[...].astype(jnp.int32), mult_in, shift_in)
    yb = requantize_i32(r_vmem[...].astype(jnp.int32), mult_aux, shift_aux)
    acc = _q_act(ya + yb, activation)   # post-add relu (int32 domain)
    x_vmem[...] = jnp.clip(acc, -128, 127).astype(x_vmem.dtype)
    off_o = jax.lax.rem(out_ptr + t * chunk, n_seg)
    st = pltpu.make_async_copy(x_vmem, out_ref.at[pl.ds(off_o, chunk)],
                               sem_out)
    st.start()
    st.wait()


@functools.partial(
    jax.jit,
    static_argnames=("rows", "d", "in_ptr", "aux_ptr", "out_ptr",
                     "mult_in", "shift_in", "mult_aux", "shift_aux",
                     "activation", "interpret"),
    donate_argnums=(0,))
def ring_add_q(pool: jax.Array, *, rows: int, d: int, in_ptr: int,
               aux_ptr: int, out_ptr: int, mult_in: int, shift_in: int,
               mult_aux: int, shift_aux: int,
               activation: str | None = None,
               interpret: bool = False) -> jax.Array:
    """Int8 residual add: both operands requantized to the output scale,
    summed (optional int32-domain relu), saturated — streamed one pixel
    row at a time."""
    n_seg = pool.shape[0]
    chunk = _segs(d)
    if n_seg % chunk or in_ptr % chunk or aux_ptr % chunk \
            or out_ptr % chunk:
        raise ValueError("pool/pointers not row aligned")
    kernel = functools.partial(_add_kernel, in_ptr=in_ptr, aux_ptr=aux_ptr,
                               out_ptr=out_ptr, n_seg=n_seg, chunk=chunk,
                               mult_in=mult_in, shift_in=shift_in,
                               mult_aux=mult_aux, shift_aux=shift_aux,
                               activation=activation)
    return pl.pallas_call(
        kernel,
        grid=(rows,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ARBITRARY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.ARBITRARY),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        scratch_shapes=[
            pltpu.VMEM((chunk, SEG_WIDTH), pool.dtype),
            pltpu.VMEM((chunk, SEG_WIDTH), pool.dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(pool)


# ---------------------------------------------------------------------------
# Global average pool.
# ---------------------------------------------------------------------------

def _avgpool_kernel(pool_ref, out_ref, x_vmem, y_vmem, acc_vmem, sem_in,
                    sem_out, *, in_ptr: int, out_ptr: int, n_seg: int,
                    h: int, w: int, c: int, mult: int, shift: int):
    p = pl.program_id(0)
    segs = _segs(c)
    off = jax.lax.rem(in_ptr + p * (w * segs), n_seg)
    load = pltpu.make_async_copy(pool_ref.at[pl.ds(off, w * segs)], x_vmem,
                                 sem_in)
    load.start()
    load.wait()
    row = x_vmem[...].reshape(w, segs * SEG_WIDTH).astype(jnp.int32)
    rowsum = jnp.sum(row, axis=0, keepdims=True)

    @pl.when(p == 0)
    def _init():
        acc_vmem[...] = jnp.zeros_like(acc_vmem)

    acc_vmem[0:1, :] = acc_vmem[0:1, :] + rowsum

    @pl.when(p == h - 1)
    def _emit():
        # the 1/(h*w) mean normalization is folded into the multiplier
        y = requantize(acc_vmem[0:1, :], mult, shift)
        y_vmem[...] = y.reshape(segs, SEG_WIDTH)
        ooff = jax.lax.rem(out_ptr, n_seg)
        st = pltpu.make_async_copy(y_vmem, out_ref.at[pl.ds(ooff, segs)],
                                   sem_out)
        st.start()
        st.wait()


@functools.partial(
    jax.jit,
    static_argnames=("h", "w", "c", "in_ptr", "out_ptr", "mult", "shift",
                     "interpret"),
    donate_argnums=(0,))
def ring_avgpool_q(pool: jax.Array, *, h: int, w: int, c: int, in_ptr: int,
                   out_ptr: int, mult: int, shift: int,
                   interpret: bool = False) -> jax.Array:
    """Int8 global average pool: int32 row sums accumulated in VMEM, one
    requantized output row stored at the last step."""
    n_seg = pool.shape[0]
    segs = _segs(c)
    if n_seg % (w * segs) or in_ptr % (w * segs) or out_ptr % segs:
        raise ValueError("pool/pointers not aligned")
    kernel = functools.partial(_avgpool_kernel, in_ptr=in_ptr,
                               out_ptr=out_ptr, n_seg=n_seg, h=h, w=w,
                               c=c, mult=mult, shift=shift)
    return pl.pallas_call(
        kernel,
        grid=(h,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ARBITRARY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.ARBITRARY),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        scratch_shapes=[
            pltpu.VMEM((w * segs, SEG_WIDTH), pool.dtype),
            pltpu.VMEM((segs, SEG_WIDTH), pool.dtype),
            pltpu.VMEM((8, segs * SEG_WIDTH), jnp.int32),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(pool)
