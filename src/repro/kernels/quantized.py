"""Int8 ring kernels — the quantized whole-network PoolOps on TPU.

Every kernel follows the fp32 skeletons (``segment_matmul`` /
``conv2d``): the int8 pool stays in HBM/ARBITRARY, async copies perform
the ``addr % n_segments`` circular-buffer bounds check, and input/output
aliasing updates the pool in place.  What changes is the element math —
the MCU deployment form:

  * loads are int8 segments (one pool segment is now ``SEG_WIDTH`` bytes,
    so the executed ring is byte-comparable to the paper's
    ``mcu_bottleneck_bytes``),
  * the Dot accumulates in int32 (MXU int8 path;
    ``preferred_element_type=jnp.int32`` — the SMLAD/``VMLADAVA.S8``
    analogue),
  * the store epilogue is the CMSIS-NN fixed-point requantization
    (:func:`repro.quant.requant.requantize`: multiplier+shift,
    round-to-nearest-even, saturating int8) with per-output-channel
    constants streamed from "Flash" like the weights.

Scalar requant pairs (residual add, avgpool) are static kernel
parameters; per-channel pairs ride as int32 operands next to the bias.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..quant.requant import act_i32 as _q_act
from ..quant.requant import requantize, requantize_i32
from .segment_matmul import SEG_WIDTH, _segs


# ---------------------------------------------------------------------------
# GEMM.
# ---------------------------------------------------------------------------

def _gemm_kernel(pool_ref, w_ref, b_ref, m_ref, s_ref, out_ref, x_vmem,
                 y_vmem, sem_in, sem_out, *, in_ptr: int, out_ptr: int,
                 n_seg: int, block_rows: int, d_in: int, d_out: int,
                 num_blocks: int, activation: str | None):
    i = pl.program_id(0)
    k_segs, n_segs = _segs(d_in), _segs(d_out)
    bk, bn = block_rows * k_segs, block_rows * n_segs
    slot = jax.lax.rem(i, 2)

    def ram_load(block, into):
        off = jax.lax.rem(in_ptr + block * bk, n_seg)
        return pltpu.make_async_copy(pool_ref.at[pl.ds(off, bk)],
                                     x_vmem.at[into], sem_in.at[into])

    # Double-buffered RAMLoad (see segment_matmul._kernel / DESIGN.md §15)
    @pl.when(i == 0)
    def _prime():
        ram_load(0, 0).start()

    @pl.when(i + 1 < num_blocks)
    def _prefetch():
        ram_load(i + 1, 1 - slot).start()

    ram_load(i, slot).wait()
    x = x_vmem[slot].reshape(block_rows, k_segs * SEG_WIDTH)[:, :d_in]
    acc = jnp.dot(x.astype(jnp.int32), w_ref[...].astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    acc = _q_act(acc + b_ref[...].astype(jnp.int32), activation)
    y = requantize(acc, m_ref[...][None, :], s_ref[...][None, :])
    pad = n_segs * SEG_WIDTH - d_out
    if pad:
        y = jnp.pad(y, ((0, 0), (0, pad)))
    y_vmem[...] = y.reshape(bn, SEG_WIDTH)
    out_off = jax.lax.rem(out_ptr + i * bn, n_seg)
    store = pltpu.make_async_copy(y_vmem, out_ref.at[pl.ds(out_off, bn)],
                                  sem_out)
    store.start()
    store.wait()


@functools.partial(
    jax.jit,
    static_argnames=("m_rows", "d_in", "d_out", "in_ptr", "out_ptr",
                     "block_rows", "activation", "interpret"),
    donate_argnums=(0,))
def ring_gemm_q(pool: jax.Array, w: jax.Array, b: jax.Array,
                mult: jax.Array, shift: jax.Array, *, m_rows: int,
                d_in: int, d_out: int, in_ptr: int, out_ptr: int,
                block_rows: int = 8, activation: str | None = None,
                interpret: bool = False) -> jax.Array:
    """Int8 Fig.-4 FC kernel: int8 In @ int8 W -> int32 acc -> requantize
    per output channel on store."""
    n_seg = pool.shape[0]
    k_segs, n_segs = _segs(d_in), _segs(d_out)
    bk, bn = block_rows * k_segs, block_rows * n_segs
    if m_rows % block_rows:
        raise ValueError("block_rows must divide m_rows")
    if n_seg % math.lcm(bk, bn) or in_ptr % bk or out_ptr % bn:
        raise ValueError("pool/pointers not block-aligned")
    kernel = functools.partial(
        _gemm_kernel, in_ptr=in_ptr, out_ptr=out_ptr, n_seg=n_seg,
        block_rows=block_rows, d_in=d_in, d_out=d_out,
        num_blocks=m_rows // block_rows, activation=activation)
    return pl.pallas_call(
        kernel,
        grid=(m_rows // block_rows,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ARBITRARY),
            pl.BlockSpec((d_in, d_out), lambda i: (0, 0)),
            pl.BlockSpec((d_out,), lambda i: (0,)),
            pl.BlockSpec((d_out,), lambda i: (0,)),
            pl.BlockSpec((d_out,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ARBITRARY),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, bk, SEG_WIDTH), pool.dtype),   # double buffer
            pltpu.VMEM((bn, SEG_WIDTH), pool.dtype),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA,
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(pool, w, b, mult, shift)


# ---------------------------------------------------------------------------
# Pointwise conv.
# ---------------------------------------------------------------------------

def _pw_kernel(pool_ref, w_ref, b_ref, m_ref, s_ref, out_ref, x_vmem,
               y_vmem, sem_in, sem_out, *, in_ptr: int, out_ptr: int,
               n_seg: int, h_in: int, w_in: int, h_out: int, w_out: int,
               c_in: int, c_out: int, stride: int, resample: bool,
               row_block: int, num_blocks: int, activation: str | None):
    p = pl.program_id(0)
    ksegs, nsegs = _segs(c_in), _segs(c_out)
    in_chunk = row_block * w_in * ksegs
    out_chunk = row_block * w_out * nsegs
    slot = jax.lax.rem(p, 2)

    def ram_load(block, into):
        # row_block > 1 only when stride == 1 and not resample (the
        # driver's blocking rule), so a block's source rows are the
        # contiguous run starting at its first source row
        if resample:
            # traced mirror of core.rowsched.resample_src
            src = jax.lax.div(block * h_in, h_out)
        else:
            src = block * row_block * stride
        off = jax.lax.rem(in_ptr + src * (w_in * ksegs), n_seg)
        return pltpu.make_async_copy(pool_ref.at[pl.ds(off, in_chunk)],
                                     x_vmem.at[into], sem_in.at[into])

    # Double-buffered RAMLoad: stage block p+1 while block p computes
    # (safe pre-store: block p+1's input is still live — DESIGN.md §15).
    @pl.when(p == 0)
    def _prime():
        ram_load(0, 0).start()

    @pl.when(p + 1 < num_blocks)
    def _prefetch():
        ram_load(p + 1, 1 - slot).start()

    ram_load(p, slot).wait()
    x = x_vmem[slot].reshape(row_block * w_in, ksegs * SEG_WIDTH)[:, :c_in]
    if row_block == 1 and (stride != 1 or resample):
        q = jax.lax.broadcasted_iota(jnp.int32, (w_out, 1), 0)[:, 0]
        # traced mirror of core.rowsched.resample_src
        cols = (q * w_in) // w_out if resample else q * stride
        x = jnp.take(x, cols, axis=0)
    xs = x.astype(jnp.int32)                    # [row_block*w_out, c_in]
    acc = jnp.dot(xs, w_ref[...].astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    acc = _q_act(acc + b_ref[...].astype(jnp.int32), activation)
    y = requantize(acc, m_ref[...][None, :], s_ref[...][None, :])
    pad = nsegs * SEG_WIDTH - c_out
    if pad:
        y = jnp.pad(y, ((0, 0), (0, pad)))
    y_vmem[...] = y.reshape(out_chunk, SEG_WIDTH)
    ooff = jax.lax.rem(out_ptr + p * out_chunk, n_seg)
    store = pltpu.make_async_copy(y_vmem,
                                  out_ref.at[pl.ds(ooff, out_chunk)],
                                  sem_out)
    store.start()
    store.wait()


@functools.partial(
    jax.jit,
    static_argnames=("h_in", "w_in", "h_out", "w_out", "c_in", "c_out",
                     "stride", "resample", "in_ptr", "out_ptr",
                     "activation", "row_block", "interpret"),
    donate_argnums=(0,))
def ring_conv_pw_q(pool: jax.Array, w: jax.Array, b: jax.Array,
                   mult: jax.Array, shift: jax.Array, *, h_in: int,
                   w_in: int, h_out: int, w_out: int, c_in: int,
                   c_out: int, stride: int = 1, resample: bool = False,
                   in_ptr: int = 0, out_ptr: int = 0,
                   activation: str | None = None, row_block: int = 1,
                   interpret: bool = False) -> jax.Array:
    """Int8 pointwise conv in the ring, ``row_block`` output image rows
    per step (blocking requires the identity pixel map — see
    :func:`repro.kernels.conv2d.ring_conv_pw`)."""
    n_seg = pool.shape[0]
    ksegs, nsegs = _segs(c_in), _segs(c_out)
    if n_seg % (w_in * ksegs) or n_seg % (w_out * nsegs) \
            or in_ptr % (w_in * ksegs) or out_ptr % (w_out * nsegs):
        raise ValueError("pool/pointers not image-row aligned")
    if row_block != 1 and (stride != 1 or resample or h_out % row_block):
        raise ValueError("row_block needs stride==1, no resample, and "
                         "row_block | h_out")
    num_blocks = h_out // row_block
    kernel = functools.partial(
        _pw_kernel, in_ptr=in_ptr, out_ptr=out_ptr, n_seg=n_seg,
        h_in=h_in, w_in=w_in, h_out=h_out, w_out=w_out, c_in=c_in,
        c_out=c_out, stride=stride, resample=resample,
        row_block=row_block, num_blocks=num_blocks, activation=activation)
    return pl.pallas_call(
        kernel,
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ARBITRARY),
            pl.BlockSpec((c_in, c_out), lambda p: (0, 0)),
            pl.BlockSpec((c_out,), lambda p: (0,)),
            pl.BlockSpec((c_out,), lambda p: (0,)),
            pl.BlockSpec((c_out,), lambda p: (0,)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ARBITRARY),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, row_block * w_in * ksegs, SEG_WIDTH),
                       pool.dtype),                       # double buffer
            pltpu.VMEM((row_block * w_out * nsegs, SEG_WIDTH), pool.dtype),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA,
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(pool, w, b, mult, shift)


# ---------------------------------------------------------------------------
# Depthwise conv.
# ---------------------------------------------------------------------------

def _dw_kernel(pool_ref, w_ref, b_ref, m_ref, s_ref, out_ref, x_vmem,
               y_vmem, sem_in, sem_out, *, in_ptr: int, out_ptr: int,
               n_seg: int, h_in: int, w_in: int, h_out: int, w_out: int,
               c: int, rs: int, stride: int, pad_v: int, pad_h: int,
               activation: str | None):
    p = pl.program_id(0)
    segs = _segs(c)

    def tap_load(row_p, r, into):
        srcc = jnp.clip(row_p * stride - pad_v + r, 0, h_in - 1)
        off = jax.lax.rem(in_ptr + srcc * (w_in * segs), n_seg)
        return pltpu.make_async_copy(pool_ref.at[pl.ds(off, w_in * segs)],
                                     x_vmem.at[into], sem_in.at[into])

    # Pipelined halo loads (see conv2d._dw_kernel / DESIGN.md §15).
    @pl.when(p == 0)
    def _prime():
        tap_load(0, 0, 0).start()

    acc = jnp.zeros((w_out, c), jnp.int32)
    qs = jax.lax.broadcasted_iota(jnp.int32, (w_out, 1), 0)[:, 0]
    for r in range(rs):
        slot = jax.lax.rem(p * rs + r, 2)
        spare = 1 - slot
        if r + 1 < rs:
            tap_load(p, r + 1, spare).start()
        else:
            @pl.when(p + 1 < h_out)
            def _prefetch():
                tap_load(p + 1, 0, spare).start()
        tap_load(p, r, slot).wait()
        src = p * stride - pad_v + r
        valid_r = (src >= 0) & (src < h_in)
        row = x_vmem[slot].reshape(w_in, segs * SEG_WIDTH)[:, :c] \
            .astype(jnp.int32)
        for s in range(rs):
            cols = qs * stride - pad_h + s
            valid_c = (cols >= 0) & (cols < w_in)
            tap = jnp.take(row, jnp.clip(cols, 0, w_in - 1), axis=0)
            ok = valid_r & valid_c[:, None]
            acc = acc + jnp.where(ok, tap, 0) \
                * w_ref[r, s].astype(jnp.int32)[None, :]
    acc = _q_act(acc + b_ref[...].astype(jnp.int32), activation)
    y = requantize(acc, m_ref[...][None, :], s_ref[...][None, :])
    padw = segs * SEG_WIDTH - c
    if padw:
        y = jnp.pad(y, ((0, 0), (0, padw)))
    y_vmem[...] = y.reshape(w_out * segs, SEG_WIDTH)
    ooff = jax.lax.rem(out_ptr + p * (w_out * segs), n_seg)
    store = pltpu.make_async_copy(y_vmem,
                                  out_ref.at[pl.ds(ooff, w_out * segs)],
                                  sem_out)
    store.start()
    store.wait()


@functools.partial(
    jax.jit,
    static_argnames=("h_in", "w_in", "h_out", "w_out", "c", "rs", "stride",
                     "padding", "in_ptr", "out_ptr", "activation",
                     "interpret"),
    donate_argnums=(0,))
def ring_conv_dw_q(pool: jax.Array, w: jax.Array, b: jax.Array,
                   mult: jax.Array, shift: jax.Array, *, h_in: int,
                   w_in: int, h_out: int, w_out: int, c: int, rs: int = 3,
                   stride: int = 1, padding: str = "same", in_ptr: int = 0,
                   out_ptr: int = 0, activation: str | None = None,
                   interpret: bool = False) -> jax.Array:
    """Int8 depthwise RSxRS conv inside the ring."""
    from ..core.rowsched import conv_k2d_pad, conv_k2d_pad_w

    n_seg = pool.shape[0]
    segs = _segs(c)
    if n_seg % (w_in * segs) or n_seg % (w_out * segs) \
            or in_ptr % (w_in * segs) or out_ptr % (w_out * segs):
        raise ValueError("pool/pointers not image-row aligned")
    kernel = functools.partial(
        _dw_kernel, in_ptr=in_ptr, out_ptr=out_ptr, n_seg=n_seg, h_in=h_in,
        w_in=w_in, h_out=h_out, w_out=w_out, c=c, rs=rs, stride=stride,
        pad_v=conv_k2d_pad(rs, padding), pad_h=conv_k2d_pad_w(rs, padding),
        activation=activation)
    return pl.pallas_call(
        kernel,
        grid=(h_out,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ARBITRARY),
            pl.BlockSpec((rs, rs, c), lambda p: (0, 0, 0)),
            pl.BlockSpec((c,), lambda p: (0,)),
            pl.BlockSpec((c,), lambda p: (0,)),
            pl.BlockSpec((c,), lambda p: (0,)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ARBITRARY),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, w_in * segs, SEG_WIDTH), pool.dtype),   # 2-slot
            pltpu.VMEM((w_out * segs, SEG_WIDTH), pool.dtype),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA,
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(pool, w, b, mult, shift)


# ---------------------------------------------------------------------------
# General k x k spatial conv.
# ---------------------------------------------------------------------------

def _k2d_kernel(pool_ref, w_ref, b_ref, m_ref, s_ref, out_ref, x_vmem,
                y_vmem, sem_in, sem_out, *, in_ptr: int, out_ptr: int,
                n_seg: int, h_in: int, w_in: int, h_out: int, w_out: int,
                c_in: int, c_out: int, k: int, stride: int, pad_v: int,
                pad_h: int, activation: str | None):
    p = pl.program_id(0)
    ksegs, nsegs = _segs(c_in), _segs(c_out)

    def tap_load(row_p, r, into):
        srcc = jnp.clip(row_p * stride - pad_v + r, 0, h_in - 1)
        off = jax.lax.rem(in_ptr + srcc * (w_in * ksegs), n_seg)
        return pltpu.make_async_copy(pool_ref.at[pl.ds(off, w_in * ksegs)],
                                     x_vmem.at[into], sem_in.at[into])

    # Pipelined halo loads (see conv2d._k2d_kernel / DESIGN.md §15).
    @pl.when(p == 0)
    def _prime():
        tap_load(0, 0, 0).start()

    acc = jnp.zeros((w_out, c_out), jnp.int32)
    qs = jax.lax.broadcasted_iota(jnp.int32, (w_out, 1), 0)[:, 0]
    for r in range(k):
        slot = jax.lax.rem(p * k + r, 2)
        spare = 1 - slot
        if r + 1 < k:
            tap_load(p, r + 1, spare).start()
        else:
            @pl.when(p + 1 < h_out)
            def _prefetch():
                tap_load(p + 1, 0, spare).start()
        tap_load(p, r, slot).wait()
        src = p * stride - pad_v + r
        valid_r = (src >= 0) & (src < h_in)
        row = x_vmem[slot].reshape(w_in, ksegs * SEG_WIDTH)[:, :c_in] \
            .astype(jnp.int32)
        for s in range(k):
            cols = qs * stride - pad_h + s
            valid_c = (cols >= 0) & (cols < w_in)
            tap = jnp.take(row, jnp.clip(cols, 0, w_in - 1), axis=0)
            ok = valid_r & valid_c[:, None]
            acc = acc + jnp.dot(jnp.where(ok, tap, 0),
                                w_ref[r, s].astype(jnp.int32),
                                preferred_element_type=jnp.int32)
    acc = _q_act(acc + b_ref[...].astype(jnp.int32), activation)
    y = requantize(acc, m_ref[...][None, :], s_ref[...][None, :])
    padw = nsegs * SEG_WIDTH - c_out
    if padw:
        y = jnp.pad(y, ((0, 0), (0, padw)))
    y_vmem[...] = y.reshape(w_out * nsegs, SEG_WIDTH)
    ooff = jax.lax.rem(out_ptr + p * (w_out * nsegs), n_seg)
    store = pltpu.make_async_copy(y_vmem,
                                  out_ref.at[pl.ds(ooff, w_out * nsegs)],
                                  sem_out)
    store.start()
    store.wait()


@functools.partial(
    jax.jit,
    static_argnames=("h_in", "w_in", "h_out", "w_out", "c_in", "c_out",
                     "k", "stride", "padding", "in_ptr", "out_ptr",
                     "activation", "interpret"),
    donate_argnums=(0,))
def ring_conv_k2d_q(pool: jax.Array, w: jax.Array, b: jax.Array,
                    mult: jax.Array, shift: jax.Array, *, h_in: int,
                    w_in: int, h_out: int, w_out: int, c_in: int,
                    c_out: int, k: int = 3, stride: int = 1,
                    padding: str = "same", in_ptr: int = 0,
                    out_ptr: int = 0, activation: str | None = None,
                    interpret: bool = False) -> jax.Array:
    """Int8 k x k conv inside the ring: int8 halo rows -> int32 dot per
    tap -> per-output-channel requantize on store (symmetric zero point
    keeps the padding exact)."""
    from ..core.rowsched import conv_k2d_pad, conv_k2d_pad_w

    n_seg = pool.shape[0]
    ksegs, nsegs = _segs(c_in), _segs(c_out)
    if n_seg % (w_in * ksegs) or n_seg % (w_out * nsegs) \
            or in_ptr % (w_in * ksegs) or out_ptr % (w_out * nsegs):
        raise ValueError("pool/pointers not image-row aligned")
    kernel = functools.partial(
        _k2d_kernel, in_ptr=in_ptr, out_ptr=out_ptr, n_seg=n_seg,
        h_in=h_in, w_in=w_in, h_out=h_out, w_out=w_out, c_in=c_in,
        c_out=c_out, k=k, stride=stride, pad_v=conv_k2d_pad(k, padding),
        pad_h=conv_k2d_pad_w(k, padding), activation=activation)
    return pl.pallas_call(
        kernel,
        grid=(h_out,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ARBITRARY),
            pl.BlockSpec((k, k, c_in, c_out), lambda p: (0, 0, 0, 0)),
            pl.BlockSpec((c_out,), lambda p: (0,)),
            pl.BlockSpec((c_out,), lambda p: (0,)),
            pl.BlockSpec((c_out,), lambda p: (0,)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ARBITRARY),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, w_in * ksegs, SEG_WIDTH), pool.dtype),  # 2-slot
            pltpu.VMEM((w_out * nsegs, SEG_WIDTH), pool.dtype),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA,
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(pool, w, b, mult, shift)


# ---------------------------------------------------------------------------
# Residual add.
# ---------------------------------------------------------------------------

def _add_kernel(pool_ref, out_ref, x_vmem, r_vmem, sem_in, sem_out, *,
                in_ptr: int, aux_ptr: int, out_ptr: int, n_seg: int,
                chunk: int, rows: int, mult_in: int, shift_in: int,
                mult_aux: int, shift_aux: int, activation: str | None):
    t = pl.program_id(0)
    slot = jax.lax.rem(t, 2)

    def ram_load(row, into):
        off_x = jax.lax.rem(in_ptr + row * chunk, n_seg)
        off_r = jax.lax.rem(aux_ptr + row * chunk, n_seg)
        cp1 = pltpu.make_async_copy(pool_ref.at[pl.ds(off_x, chunk)],
                                    x_vmem.at[into], sem_in.at[into, 0])
        cp2 = pltpu.make_async_copy(pool_ref.at[pl.ds(off_r, chunk)],
                                    r_vmem.at[into], sem_in.at[into, 1])
        return cp1, cp2

    # Both operand rows double-buffer (see conv2d._add_kernel).
    @pl.when(t == 0)
    def _prime():
        for cp in ram_load(0, 0):
            cp.start()

    @pl.when(t + 1 < rows)
    def _prefetch():
        for cp in ram_load(t + 1, 1 - slot):
            cp.start()

    for cp in ram_load(t, slot):
        cp.wait()
    ya = requantize_i32(x_vmem[slot].astype(jnp.int32), mult_in, shift_in)
    yb = requantize_i32(r_vmem[slot].astype(jnp.int32), mult_aux,
                        shift_aux)
    acc = _q_act(ya + yb, activation)   # post-add relu (int32 domain)
    x_vmem[slot] = jnp.clip(acc, -128, 127).astype(x_vmem.dtype)
    off_o = jax.lax.rem(out_ptr + t * chunk, n_seg)
    st = pltpu.make_async_copy(x_vmem.at[slot],
                               out_ref.at[pl.ds(off_o, chunk)], sem_out)
    st.start()
    st.wait()


@functools.partial(
    jax.jit,
    static_argnames=("rows", "d", "in_ptr", "aux_ptr", "out_ptr",
                     "mult_in", "shift_in", "mult_aux", "shift_aux",
                     "activation", "interpret"),
    donate_argnums=(0,))
def ring_add_q(pool: jax.Array, *, rows: int, d: int, in_ptr: int,
               aux_ptr: int, out_ptr: int, mult_in: int, shift_in: int,
               mult_aux: int, shift_aux: int,
               activation: str | None = None,
               interpret: bool = False) -> jax.Array:
    """Int8 residual add: both operands requantized to the output scale,
    summed (optional int32-domain relu), saturated — streamed one pixel
    row at a time."""
    n_seg = pool.shape[0]
    chunk = _segs(d)
    if n_seg % chunk or in_ptr % chunk or aux_ptr % chunk \
            or out_ptr % chunk:
        raise ValueError("pool/pointers not row aligned")
    kernel = functools.partial(_add_kernel, in_ptr=in_ptr, aux_ptr=aux_ptr,
                               out_ptr=out_ptr, n_seg=n_seg, chunk=chunk,
                               rows=rows, mult_in=mult_in,
                               shift_in=shift_in, mult_aux=mult_aux,
                               shift_aux=shift_aux, activation=activation)
    return pl.pallas_call(
        kernel,
        grid=(rows,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ARBITRARY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.ARBITRARY),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, chunk, SEG_WIDTH), pool.dtype),   # 2-slot x
            pltpu.VMEM((2, chunk, SEG_WIDTH), pool.dtype),   # 2-slot res
            pltpu.SemaphoreType.DMA((2, 2)),
            pltpu.SemaphoreType.DMA,
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(pool)


# ---------------------------------------------------------------------------
# Global average pool.
# ---------------------------------------------------------------------------

def _avgpool_kernel(pool_ref, out_ref, x_vmem, y_vmem, acc_vmem, sem_in,
                    sem_out, *, in_ptr: int, out_ptr: int, n_seg: int,
                    h: int, w: int, c: int, mult: int, shift: int):
    p = pl.program_id(0)
    segs = _segs(c)
    slot = jax.lax.rem(p, 2)

    def ram_load(row, into):
        off = jax.lax.rem(in_ptr + row * (w * segs), n_seg)
        return pltpu.make_async_copy(pool_ref.at[pl.ds(off, w * segs)],
                                     x_vmem.at[into], sem_in.at[into])

    # Double-buffered row loads; nothing stores until the last step, so
    # the prefetch trivially precedes every write.
    @pl.when(p == 0)
    def _prime():
        ram_load(0, 0).start()

    @pl.when(p + 1 < h)
    def _prefetch():
        ram_load(p + 1, 1 - slot).start()

    ram_load(p, slot).wait()
    row = x_vmem[slot].reshape(w, segs * SEG_WIDTH).astype(jnp.int32)
    rowsum = jnp.sum(row, axis=0, keepdims=True)

    @pl.when(p == 0)
    def _init():
        acc_vmem[...] = jnp.zeros_like(acc_vmem)

    acc_vmem[0:1, :] = acc_vmem[0:1, :] + rowsum

    @pl.when(p == h - 1)
    def _emit():
        # the 1/(h*w) mean normalization is folded into the multiplier
        y = requantize(acc_vmem[0:1, :], mult, shift)
        y_vmem[...] = y.reshape(segs, SEG_WIDTH)
        ooff = jax.lax.rem(out_ptr, n_seg)
        st = pltpu.make_async_copy(y_vmem, out_ref.at[pl.ds(ooff, segs)],
                                   sem_out)
        st.start()
        st.wait()


@functools.partial(
    jax.jit,
    static_argnames=("h", "w", "c", "in_ptr", "out_ptr", "mult", "shift",
                     "interpret"),
    donate_argnums=(0,))
def ring_avgpool_q(pool: jax.Array, *, h: int, w: int, c: int, in_ptr: int,
                   out_ptr: int, mult: int, shift: int,
                   interpret: bool = False) -> jax.Array:
    """Int8 global average pool: int32 row sums accumulated in VMEM, one
    requantized output row stored at the last step."""
    n_seg = pool.shape[0]
    segs = _segs(c)
    if n_seg % (w * segs) or in_ptr % (w * segs) or out_ptr % segs:
        raise ValueError("pool/pointers not aligned")
    kernel = functools.partial(_avgpool_kernel, in_ptr=in_ptr,
                               out_ptr=out_ptr, n_seg=n_seg, h=h, w=w,
                               c=c, mult=mult, shift=shift)
    return pl.pallas_call(
        kernel,
        grid=(h,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ARBITRARY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.ARBITRARY),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, w * segs, SEG_WIDTH), pool.dtype),   # 2-slot
            pltpu.VMEM((segs, SEG_WIDTH), pool.dtype),
            pltpu.VMEM((8, segs * SEG_WIDTH), jnp.int32),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA,
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(pool)
