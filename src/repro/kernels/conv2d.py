"""Ring conv / residual / pool kernels — whole-network PoolOps on TPU.

The remaining executable op kinds a full DNN needs beyond the Fig.-4 GEMM
and the Fig.-6 fused module:

  * ``ring_conv_pw``  — (strided / resampling) pointwise conv, one output
                        image row per grid step.  The whole source image
                        row is RAMLoaded (contiguous segments) and the
                        strided columns are selected in VMEM.
  * ``ring_conv_dw``  — depthwise RSxRS conv, 'same' padding: the RS halo
                        rows are RAMLoaded per output row (clamped at the
                        image edge, contributions masked), one output row
                        RAMStored at the solved offset.
  * ``ring_add``      — residual add: stream one pixel row from the
                        chained operand and one from the *held* residual
                        source, store the sum (in place over the operand).
  * ``ring_avgpool``  — global average pool: accumulate one image row per
                        grid step in a VMEM scratch, store the single
                        output row at the last step.

All follow the segment_matmul skeleton: pool stays in HBM/ARBITRARY,
async copies with the ``addr % n_segments`` bounds check, input/output
aliasing so the pool buffer is updated in place.  Layout is one image row
per DMA block (``W * segs(C)`` segments), the alignment unit the planner
guarantees (``PoolProgram.op_blocks``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.program import resolve_activation
from .segment_matmul import SEG_WIDTH, _segs


# ---------------------------------------------------------------------------
# Pointwise conv.
# ---------------------------------------------------------------------------

def _pw_kernel(pool_ref, w_ref, b_ref, out_ref, x_vmem, y_vmem, sem_in,
               sem_out, *, in_ptr: int, out_ptr: int, n_seg: int,
               h_in: int, w_in: int, h_out: int, w_out: int, c_in: int,
               c_out: int, stride: int, resample: bool, row_block: int,
               num_blocks: int, activation: str | None):
    p = pl.program_id(0)
    ksegs, nsegs = _segs(c_in), _segs(c_out)
    in_chunk = row_block * w_in * ksegs
    out_chunk = row_block * w_out * nsegs
    slot = jax.lax.rem(p, 2)

    def ram_load(block, into):
        # row_block > 1 only when stride == 1 and not resample (the
        # driver's blocking rule), so a block's source rows are the
        # contiguous run starting at its first source row
        if resample:
            # traced mirror of core.rowsched.resample_src
            src = jax.lax.div(block * h_in, h_out)
        else:
            src = block * row_block * stride
        off = jax.lax.rem(in_ptr + src * (w_in * ksegs), n_seg)
        return pltpu.make_async_copy(pool_ref.at[pl.ds(off, in_chunk)],
                                     x_vmem.at[into], sem_in.at[into])

    # Double-buffered RAMLoad: stage block p+1 while block p computes
    # (safe pre-store: block p+1's input is still live — DESIGN.md §15).
    @pl.when(p == 0)
    def _prime():
        ram_load(0, 0).start()

    @pl.when(p + 1 < num_blocks)
    def _prefetch():
        ram_load(p + 1, 1 - slot).start()

    ram_load(p, slot).wait()
    x = x_vmem[slot].reshape(row_block * w_in, ksegs * SEG_WIDTH)[:, :c_in]
    if row_block == 1 and (stride != 1 or resample):
        q = jax.lax.broadcasted_iota(jnp.int32, (w_out, 1), 0)[:, 0]
        # traced mirror of core.rowsched.resample_src
        cols = (q * w_in) // w_out if resample else q * stride
        x = jnp.take(x, cols, axis=0)
    xs = x.astype(jnp.float32)                  # [row_block*w_out, c_in]
    y = jnp.dot(xs, w_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    y = resolve_activation(activation)(y + b_ref[...].astype(jnp.float32))
    y = y.astype(x_vmem.dtype)
    pad = nsegs * SEG_WIDTH - c_out
    if pad:
        y = jnp.pad(y, ((0, 0), (0, pad)))
    y_vmem[...] = y.reshape(out_chunk, SEG_WIDTH)
    ooff = jax.lax.rem(out_ptr + p * out_chunk, n_seg)
    store = pltpu.make_async_copy(y_vmem,
                                  out_ref.at[pl.ds(ooff, out_chunk)],
                                  sem_out)
    store.start()
    store.wait()


@functools.partial(
    jax.jit,
    static_argnames=("h_in", "w_in", "h_out", "w_out", "c_in", "c_out",
                     "stride", "resample", "in_ptr", "out_ptr",
                     "activation", "row_block", "interpret"),
    donate_argnums=(0,))
def ring_conv_pw(pool: jax.Array, w: jax.Array, b: jax.Array, *, h_in: int,
                 w_in: int, h_out: int, w_out: int, c_in: int, c_out: int,
                 stride: int = 1, resample: bool = False, in_ptr: int = 0,
                 out_ptr: int = 0, activation: str | None = None,
                 row_block: int = 1, interpret: bool = False) -> jax.Array:
    """Pointwise conv ``[h_in, w_in, c_in] -> [h_out, w_out, c_out]`` in
    the ring; rows live one pixel per ``segs(c)`` segments, row-major.

    ``row_block`` image rows are fused per grid step (blocking requires
    the identity pixel map: ``stride == 1`` and no resampling); the next
    block's rows stage into the spare VMEM slot while the current block
    computes (DESIGN.md §15)."""
    n_seg = pool.shape[0]
    ksegs, nsegs = _segs(c_in), _segs(c_out)
    if n_seg % (w_in * ksegs) or n_seg % (w_out * nsegs) \
            or in_ptr % (w_in * ksegs) or out_ptr % (w_out * nsegs):
        raise ValueError("pool/pointers not image-row aligned")
    if row_block != 1 and (stride != 1 or resample or h_out % row_block):
        raise ValueError("row_block needs stride==1, no resample, and "
                         "row_block | h_out")
    num_blocks = h_out // row_block
    kernel = functools.partial(
        _pw_kernel, in_ptr=in_ptr, out_ptr=out_ptr, n_seg=n_seg,
        h_in=h_in, w_in=w_in, h_out=h_out, w_out=w_out, c_in=c_in,
        c_out=c_out, stride=stride, resample=resample,
        row_block=row_block, num_blocks=num_blocks, activation=activation)
    return pl.pallas_call(
        kernel,
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ARBITRARY),
            pl.BlockSpec((c_in, c_out), lambda p: (0, 0)),
            pl.BlockSpec((c_out,), lambda p: (0,)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ARBITRARY),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, row_block * w_in * ksegs, SEG_WIDTH),
                       pool.dtype),                       # double buffer
            pltpu.VMEM((row_block * w_out * nsegs, SEG_WIDTH), pool.dtype),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA,
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(pool, w, b)


# ---------------------------------------------------------------------------
# Depthwise conv.
# ---------------------------------------------------------------------------

def _dw_kernel(pool_ref, w_ref, b_ref, out_ref, x_vmem, y_vmem, sem_in,
               sem_out, *, in_ptr: int, out_ptr: int, n_seg: int,
               h_in: int, w_in: int, h_out: int, w_out: int, c: int,
               rs: int, stride: int, pad_v: int, pad_h: int,
               activation: str | None):
    p = pl.program_id(0)
    segs = _segs(c)

    def tap_load(row_p, r, into):
        srcc = jnp.clip(row_p * stride - pad_v + r, 0, h_in - 1)
        off = jax.lax.rem(in_ptr + srcc * (w_in * segs), n_seg)
        return pltpu.make_async_copy(pool_ref.at[pl.ds(off, w_in * segs)],
                                     x_vmem.at[into], sem_in.at[into])

    # Pipelined halo loads: the (p, r) tap sequence is double-buffered —
    # tap r+1 (or the next output row's first tap) stages while tap r
    # accumulates.  The cross-row prefetch precedes row p's RAMStore,
    # which is safe because row p+1's halo is still live (DESIGN.md §15).
    @pl.when(p == 0)
    def _prime():
        tap_load(0, 0, 0).start()

    acc = jnp.zeros((w_out, c), jnp.float32)
    qs = jax.lax.broadcasted_iota(jnp.int32, (w_out, 1), 0)[:, 0]
    for r in range(rs):
        slot = jax.lax.rem(p * rs + r, 2)
        spare = 1 - slot
        if r + 1 < rs:
            tap_load(p, r + 1, spare).start()
        else:
            @pl.when(p + 1 < h_out)
            def _prefetch():
                tap_load(p + 1, 0, spare).start()
        tap_load(p, r, slot).wait()
        src = p * stride - pad_v + r
        valid_r = (src >= 0) & (src < h_in)
        row = x_vmem[slot].reshape(w_in, segs * SEG_WIDTH)[:, :c] \
            .astype(jnp.float32)
        for s in range(rs):
            cols = qs * stride - pad_h + s
            valid_c = (cols >= 0) & (cols < w_in)
            tap = jnp.take(row, jnp.clip(cols, 0, w_in - 1), axis=0)
            ok = valid_r & valid_c[:, None]
            acc = acc + jnp.where(ok, tap, 0.0) \
                * w_ref[r, s].astype(jnp.float32)[None, :]
    y = resolve_activation(activation)(acc + b_ref[...].astype(jnp.float32))
    y = y.astype(x_vmem.dtype)
    padw = segs * SEG_WIDTH - c
    if padw:
        y = jnp.pad(y, ((0, 0), (0, padw)))
    y_vmem[...] = y.reshape(w_out * segs, SEG_WIDTH)
    ooff = jax.lax.rem(out_ptr + p * (w_out * segs), n_seg)
    store = pltpu.make_async_copy(y_vmem,
                                  out_ref.at[pl.ds(ooff, w_out * segs)],
                                  sem_out)
    store.start()
    store.wait()


@functools.partial(
    jax.jit,
    static_argnames=("h_in", "w_in", "h_out", "w_out", "c", "rs", "stride",
                     "padding", "in_ptr", "out_ptr", "activation",
                     "interpret"),
    donate_argnums=(0,))
def ring_conv_dw(pool: jax.Array, w: jax.Array, b: jax.Array, *, h_in: int,
                 w_in: int, h_out: int, w_out: int, c: int, rs: int = 3,
                 stride: int = 1, padding: str = "same", in_ptr: int = 0,
                 out_ptr: int = 0, activation: str | None = None,
                 interpret: bool = False) -> jax.Array:
    """Depthwise RSxRS conv inside the ring.

    ``w``: [rs, rs, c]; output row ``p`` reads the clamped input halo
    rows ``p*stride - pad .. + rs - 1`` (masked at the edges).  The
    slice-padding modes (``same_top``/``same_mid``) drop the vertical
    top pad while keeping the horizontal one."""
    from ..core.rowsched import conv_k2d_pad, conv_k2d_pad_w

    n_seg = pool.shape[0]
    segs = _segs(c)
    if n_seg % (w_in * segs) or n_seg % (w_out * segs) \
            or in_ptr % (w_in * segs) or out_ptr % (w_out * segs):
        raise ValueError("pool/pointers not image-row aligned")
    kernel = functools.partial(
        _dw_kernel, in_ptr=in_ptr, out_ptr=out_ptr, n_seg=n_seg, h_in=h_in,
        w_in=w_in, h_out=h_out, w_out=w_out, c=c, rs=rs, stride=stride,
        pad_v=conv_k2d_pad(rs, padding), pad_h=conv_k2d_pad_w(rs, padding),
        activation=activation)
    return pl.pallas_call(
        kernel,
        grid=(h_out,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ARBITRARY),
            pl.BlockSpec((rs, rs, c), lambda p: (0, 0, 0)),
            pl.BlockSpec((c,), lambda p: (0,)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ARBITRARY),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, w_in * segs, SEG_WIDTH), pool.dtype),  # 2 slots
            pltpu.VMEM((w_out * segs, SEG_WIDTH), pool.dtype),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA,
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(pool, w, b)


# ---------------------------------------------------------------------------
# General k x k spatial conv.
# ---------------------------------------------------------------------------

def _k2d_kernel(pool_ref, w_ref, b_ref, out_ref, x_vmem, y_vmem, sem_in,
                sem_out, *, in_ptr: int, out_ptr: int, n_seg: int,
                h_in: int, w_in: int, h_out: int, w_out: int, c_in: int,
                c_out: int, k: int, stride: int, pad_v: int, pad_h: int,
                activation: str | None):
    p = pl.program_id(0)
    ksegs, nsegs = _segs(c_in), _segs(c_out)

    def tap_load(row_p, r, into):
        srcc = jnp.clip(row_p * stride - pad_v + r, 0, h_in - 1)
        off = jax.lax.rem(in_ptr + srcc * (w_in * ksegs), n_seg)
        return pltpu.make_async_copy(pool_ref.at[pl.ds(off, w_in * ksegs)],
                                     x_vmem.at[into], sem_in.at[into])

    # Pipelined halo loads — see _dw_kernel.
    @pl.when(p == 0)
    def _prime():
        tap_load(0, 0, 0).start()

    acc = jnp.zeros((w_out, c_out), jnp.float32)
    qs = jax.lax.broadcasted_iota(jnp.int32, (w_out, 1), 0)[:, 0]
    for r in range(k):
        slot = jax.lax.rem(p * k + r, 2)
        spare = 1 - slot
        if r + 1 < k:
            tap_load(p, r + 1, spare).start()
        else:
            @pl.when(p + 1 < h_out)
            def _prefetch():
                tap_load(p + 1, 0, spare).start()
        tap_load(p, r, slot).wait()
        src = p * stride - pad_v + r
        valid_r = (src >= 0) & (src < h_in)
        row = x_vmem[slot].reshape(w_in, ksegs * SEG_WIDTH)[:, :c_in] \
            .astype(jnp.float32)
        for s in range(k):
            cols = qs * stride - pad_h + s
            valid_c = (cols >= 0) & (cols < w_in)
            tap = jnp.take(row, jnp.clip(cols, 0, w_in - 1), axis=0)
            ok = valid_r & valid_c[:, None]
            acc = acc + jnp.dot(jnp.where(ok, tap, 0.0),
                                w_ref[r, s].astype(jnp.float32),
                                preferred_element_type=jnp.float32)
    y = resolve_activation(activation)(acc + b_ref[...].astype(jnp.float32))
    y = y.astype(x_vmem.dtype)
    padw = nsegs * SEG_WIDTH - c_out
    if padw:
        y = jnp.pad(y, ((0, 0), (0, padw)))
    y_vmem[...] = y.reshape(w_out * nsegs, SEG_WIDTH)
    ooff = jax.lax.rem(out_ptr + p * (w_out * nsegs), n_seg)
    store = pltpu.make_async_copy(y_vmem,
                                  out_ref.at[pl.ds(ooff, w_out * nsegs)],
                                  sem_out)
    store.start()
    store.wait()


@functools.partial(
    jax.jit,
    static_argnames=("h_in", "w_in", "h_out", "w_out", "c_in", "c_out",
                     "k", "stride", "padding", "in_ptr", "out_ptr",
                     "activation", "interpret"),
    donate_argnums=(0,))
def ring_conv_k2d(pool: jax.Array, w: jax.Array, b: jax.Array, *,
                  h_in: int, w_in: int, h_out: int, w_out: int, c_in: int,
                  c_out: int, k: int = 3, stride: int = 1,
                  padding: str = "same", in_ptr: int = 0, out_ptr: int = 0,
                  activation: str | None = None,
                  interpret: bool = False) -> jax.Array:
    """General k x k conv ``[h_in, w_in, c_in] -> [h_out, w_out, c_out]``
    inside the ring.

    ``w``: [k, k, c_in, c_out]; output row ``p`` RAMLoads the k input
    halo rows ``p*stride - pad .. + k - 1`` (rows/cols outside the image
    masked to the zero padding), dots each tap against the Flash weight
    slice and RAMStores one output image row at the solved offset."""
    from ..core.rowsched import conv_k2d_pad, conv_k2d_pad_w

    n_seg = pool.shape[0]
    ksegs, nsegs = _segs(c_in), _segs(c_out)
    if n_seg % (w_in * ksegs) or n_seg % (w_out * nsegs) \
            or in_ptr % (w_in * ksegs) or out_ptr % (w_out * nsegs):
        raise ValueError("pool/pointers not image-row aligned")
    kernel = functools.partial(
        _k2d_kernel, in_ptr=in_ptr, out_ptr=out_ptr, n_seg=n_seg,
        h_in=h_in, w_in=w_in, h_out=h_out, w_out=w_out, c_in=c_in,
        c_out=c_out, k=k, stride=stride, pad_v=conv_k2d_pad(k, padding),
        pad_h=conv_k2d_pad_w(k, padding), activation=activation)
    return pl.pallas_call(
        kernel,
        grid=(h_out,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ARBITRARY),
            pl.BlockSpec((k, k, c_in, c_out), lambda p: (0, 0, 0, 0)),
            pl.BlockSpec((c_out,), lambda p: (0,)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ARBITRARY),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, w_in * ksegs, SEG_WIDTH), pool.dtype),  # 2 slots
            pltpu.VMEM((w_out * nsegs, SEG_WIDTH), pool.dtype),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA,
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(pool, w, b)


# ---------------------------------------------------------------------------
# Residual add.
# ---------------------------------------------------------------------------

def _add_kernel(pool_ref, out_ref, x_vmem, r_vmem, sem_in, sem_out, *,
                in_ptr: int, aux_ptr: int, out_ptr: int, n_seg: int,
                chunk: int, rows: int, activation: str | None):
    t = pl.program_id(0)
    slot = jax.lax.rem(t, 2)

    def ram_load(row, into):
        off_x = jax.lax.rem(in_ptr + row * chunk, n_seg)
        off_r = jax.lax.rem(aux_ptr + row * chunk, n_seg)
        cp1 = pltpu.make_async_copy(pool_ref.at[pl.ds(off_x, chunk)],
                                    x_vmem.at[into], sem_in.at[into, 0])
        cp2 = pltpu.make_async_copy(pool_ref.at[pl.ds(off_r, chunk)],
                                    r_vmem.at[into], sem_in.at[into, 1])
        return cp1, cp2

    # Both operand rows double-buffer: row t+1 (operand + held residual)
    # stages while row t sums — the prefetch precedes row t's in-place
    # store, safe because row t+1's sources are still live.
    @pl.when(t == 0)
    def _prime():
        for cp in ram_load(0, 0):
            cp.start()

    @pl.when(t + 1 < rows)
    def _prefetch():
        for cp in ram_load(t + 1, 1 - slot):
            cp.start()

    for cp in ram_load(t, slot):
        cp.wait()
    y = resolve_activation(activation)(
        x_vmem[slot].astype(jnp.float32)
        + r_vmem[slot].astype(jnp.float32)).astype(x_vmem.dtype)
    x_vmem[slot] = y
    off_o = jax.lax.rem(out_ptr + t * chunk, n_seg)
    st = pltpu.make_async_copy(x_vmem.at[slot],
                               out_ref.at[pl.ds(off_o, chunk)], sem_out)
    st.start()
    st.wait()


@functools.partial(
    jax.jit,
    static_argnames=("rows", "d", "in_ptr", "aux_ptr", "out_ptr",
                     "activation", "interpret"),
    donate_argnums=(0,))
def ring_add(pool: jax.Array, *, rows: int, d: int, in_ptr: int,
             aux_ptr: int, out_ptr: int, activation: str | None = None,
             interpret: bool = False) -> jax.Array:
    """``Out[t] = act(In[t] + Res[t])`` streamed one pixel row at a time;
    the residual source rows die exactly as they are read (the planner
    held them live until here)."""
    n_seg = pool.shape[0]
    chunk = _segs(d)
    if n_seg % chunk or in_ptr % chunk or aux_ptr % chunk \
            or out_ptr % chunk:
        raise ValueError("pool/pointers not row aligned")
    kernel = functools.partial(_add_kernel, in_ptr=in_ptr, aux_ptr=aux_ptr,
                               out_ptr=out_ptr, n_seg=n_seg, chunk=chunk,
                               rows=rows, activation=activation)
    return pl.pallas_call(
        kernel,
        grid=(rows,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ARBITRARY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.ARBITRARY),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, chunk, SEG_WIDTH), pool.dtype),    # 2 slots
            pltpu.VMEM((2, chunk, SEG_WIDTH), pool.dtype),
            pltpu.SemaphoreType.DMA((2, 2)),
            pltpu.SemaphoreType.DMA,
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(pool)


# ---------------------------------------------------------------------------
# Global average pool.
# ---------------------------------------------------------------------------

def _avgpool_kernel(pool_ref, out_ref, x_vmem, acc_vmem, sem_in, sem_out, *,
                    in_ptr: int, out_ptr: int, n_seg: int, h: int, w: int,
                    c: int):
    p = pl.program_id(0)
    segs = _segs(c)
    slot = jax.lax.rem(p, 2)

    def ram_load(row, into):
        off = jax.lax.rem(in_ptr + row * (w * segs), n_seg)
        return pltpu.make_async_copy(pool_ref.at[pl.ds(off, w * segs)],
                                     x_vmem.at[into], sem_in.at[into])

    # Double-buffered row loads; nothing stores until the last step, so
    # the prefetch trivially precedes every write.
    @pl.when(p == 0)
    def _prime():
        ram_load(0, 0).start()

    @pl.when(p + 1 < h)
    def _prefetch():
        ram_load(p + 1, 1 - slot).start()

    ram_load(p, slot).wait()
    row = x_vmem[slot].reshape(w, segs * SEG_WIDTH).astype(jnp.float32)
    rowsum = jnp.sum(row, axis=0, keepdims=True)     # [1, segs*SEG]

    @pl.when(p == 0)
    def _init():
        acc_vmem[...] = jnp.zeros_like(acc_vmem)

    acc_vmem[0:1, :] = acc_vmem[0:1, :] + rowsum

    @pl.when(p == h - 1)
    def _emit():
        y = (acc_vmem[0:1, :] / (h * w)).astype(x_vmem.dtype)
        x_vmem[slot, pl.ds(0, segs)] = y.reshape(segs, SEG_WIDTH)
        ooff = jax.lax.rem(out_ptr, n_seg)
        st = pltpu.make_async_copy(x_vmem.at[slot].at[pl.ds(0, segs)],
                                   out_ref.at[pl.ds(ooff, segs)], sem_out)
        st.start()
        st.wait()


@functools.partial(
    jax.jit,
    static_argnames=("h", "w", "c", "in_ptr", "out_ptr", "interpret"),
    donate_argnums=(0,))
def ring_avgpool(pool: jax.Array, *, h: int, w: int, c: int, in_ptr: int,
                 out_ptr: int, interpret: bool = False) -> jax.Array:
    """Global average pool ``[h, w, c] -> [1, 1, c]`` in the ring: one
    image row accumulated per grid step, single output row at the end."""
    n_seg = pool.shape[0]
    segs = _segs(c)
    if n_seg % (w * segs) or in_ptr % (w * segs) or out_ptr % segs:
        raise ValueError("pool/pointers not aligned")
    kernel = functools.partial(_avgpool_kernel, in_ptr=in_ptr,
                               out_ptr=out_ptr, n_seg=n_seg, h=h, w=w, c=c)
    return pl.pallas_call(
        kernel,
        grid=(h,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ARBITRARY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.ARBITRARY),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, w * segs, SEG_WIDTH), pool.dtype),  # 2 slots
            pltpu.VMEM((8, segs * SEG_WIDTH), jnp.float32),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA,
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(pool)
