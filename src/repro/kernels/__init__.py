"""Pallas TPU kernels for vMCU's compute hot-spots.

  segment_matmul — ring-buffer GEMM (paper Fig. 4 FC kernel)
  fused_mlp      — in-place streaming MLP (paper Fig. 6 inverted bottleneck)
  elementwise    — in-place ring elementwise (delta == 0 pool ops)
  ring_decode    — decode attention over a ring KV cache (sliding window)

All are reachable through the unified API: ``repro.core.execute(program,
pool, params, backend="pallas")``.  Validated in interpret mode against
:mod:`repro.kernels.ref` oracles and the jnp executor backend.
"""
from .elementwise import ring_elementwise
from .ops import (SEG_WIDTH, decode_attention, fused_mlp, ring_cache_update,
                  segment_gemm)
