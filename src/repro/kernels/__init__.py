"""Pallas TPU kernels for vMCU's compute hot-spots.

  segment_matmul      — ring-buffer GEMM (paper Fig. 4 FC kernel)
  fused_mlp           — in-place streaming MLP (transformer Fig.-6 analogue)
  inverted_bottleneck — fused PW→DW→PW(→add) module (paper Fig. 6)
  conv2d              — ring pointwise/depthwise/general-k2d conv,
                        residual add, global avgpool (whole-network
                        ops, DESIGN.md §7/§10)
  quantized           — the int8 forms of gemm/conv_pw/conv_dw/conv_k2d/
                        add/avgpool: int32 accumulate + fixed-point
                        requantize on store (DESIGN.md §8)
  elementwise         — in-place ring elementwise (delta == 0 pool ops)
  ring_decode         — decode attention over a ring KV cache

All are reachable through the unified API: ``repro.core.execute(program,
pool, params, backend="pallas")``.  Validated in interpret mode against
:mod:`repro.kernels.ref` oracles and the jnp executor backend.
"""
from .conv2d import (ring_add, ring_avgpool, ring_conv_dw, ring_conv_k2d,
                     ring_conv_pw)
from .elementwise import ring_elementwise
from .ops import (SEG_WIDTH, decode_attention, fused_mlp, ring_cache_update,
                  segment_gemm)
from .quantized import (ring_add_q, ring_avgpool_q, ring_conv_dw_q,
                        ring_conv_k2d_q, ring_conv_pw_q, ring_gemm_q)
