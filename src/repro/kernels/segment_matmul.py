"""Ring-buffer GEMM — the vMCU fully-connected kernel (paper Fig. 4), TPU-native.

MCU mapping (paper)            → TPU mapping (here)
  RAM segment pool             → HBM pool array [n_segments, SEG_WIDTH]
                                 (memory_space=ARBITRARY, aliased in/out)
  RAMLoad  (+ modulo check)    → async_copy pool→VMEM scratch at
                                 (in_ptr + block·k_segs) % n_segments
  FlashLoad (weights in Flash) → BlockSpec-streamed HBM→VMEM weight tiles
  Dot (2x2x16 SADD16/SMLAD)    → MXU jnp.dot on the (block_rows, d_in) tile,
                                 fp32 accumulation
  RAMStore (+ modulo check)    → async_copy VMEM→pool at
                                 (out_ptr + block·n_segs) % n_segments
  RAMFree                      → implicit: the ring pointer advance IS the
                                 free (dead segments are overwritten)

Two-level tiling exactly as §5.1: the outer level walks `block_rows` rows of
segments through the ring; the inner level is the MXU tile (the hardware
"instruction lane").

Alignment adaptation (DESIGN.md): DMA needs contiguous ranges, so the pool
length is rounded to a multiple of both the input and output block segment
counts and pointers are block-aligned — mid-block wrap never occurs.  The
planner's delta is rounded up accordingly (never down: safety is preserved).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.program import resolve_activation
from ..core.vpool import SEG_WIDTH, segments_for
from ..core.vpool import fetch_rows as _pool_fetch_rows
from ..core.vpool import stage_rows as _pool_stage_rows


def _segs(d: int) -> int:
    return segments_for(d, SEG_WIDTH)


def _kernel(pool_ref, w_ref, b_ref, out_ref, x_vmem, y_vmem, sem_in, sem_out,
            *, in_ptr: int, out_ptr: int, n_seg: int, block_rows: int,
            d_in: int, d_out: int, num_blocks: int,
            activation: str | None):
    i = pl.program_id(0)
    k_segs, n_segs = _segs(d_in), _segs(d_out)
    bk, bn = block_rows * k_segs, block_rows * n_segs
    slot = jax.lax.rem(i, 2)

    def ram_load(block, into):
        off = jax.lax.rem(in_ptr + block * bk, n_seg)
        return pltpu.make_async_copy(pool_ref.at[pl.ds(off, bk)],
                                     x_vmem.at[into], sem_in.at[into])

    # --- RAMLoad, double-buffered: block 0 primes the pipeline; every
    # step then stages block i+1 into the spare slot while block i
    # computes.  Prefetching before block i's RAMStore is safe: block
    # i+1's input is still live, so the certified schedule proves the
    # store cannot touch it (DESIGN.md §15).
    @pl.when(i == 0)
    def _prime():
        ram_load(0, 0).start()

    @pl.when(i + 1 < num_blocks)
    def _prefetch():
        ram_load(i + 1, 1 - slot).start()

    ram_load(i, slot).wait()

    # --- Dot: MXU on the segment block --------------------------------------
    x = x_vmem[slot].reshape(block_rows, k_segs * SEG_WIDTH)[:, :d_in]
    y = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    y = resolve_activation(activation)(y + b_ref[...].astype(jnp.float32))
    y = y.astype(x_vmem.dtype)
    pad = n_segs * SEG_WIDTH - d_out
    if pad:
        y = jnp.pad(y, ((0, 0), (0, pad)))
    y_vmem[...] = y.reshape(bn, SEG_WIDTH)

    # --- RAMStore: VMEM → ring (overwrites freed input segments) ------------
    out_off = jax.lax.rem(out_ptr + i * bn, n_seg)
    store = pltpu.make_async_copy(y_vmem, out_ref.at[pl.ds(out_off, bn)],
                                  sem_out)
    store.start()
    store.wait()


def aligned_pool_geometry(m_rows: int, d_in: int, d_out: int,
                          delta_segments: int, block_rows: int
                          ) -> tuple[int, int, int]:
    """Round the planner's geometry to DMA-safe alignment.

    Returns (n_segments, in_ptr, out_ptr) with in_ptr % bk == 0,
    out_ptr % bn == 0, n_segments % lcm(bk, bn) == 0 and
    in_ptr - out_ptr >= delta_segments.
    """
    k_segs, n_segs = _segs(d_in), _segs(d_out)
    bk, bn = block_rows * k_segs, block_rows * n_segs
    out_ptr = 0
    # smallest bk-multiple >= delta (shifting In UP is always safe)
    in_ptr = -(-delta_segments // bk) * bk
    span = max(in_ptr + m_rows * k_segs, m_rows * n_segs)
    align = math.lcm(bk, bn)
    n_segments = -(-span // align) * align
    return n_segments, in_ptr, out_ptr


@functools.partial(
    jax.jit,
    static_argnames=("m_rows", "d_in", "d_out", "in_ptr", "out_ptr",
                     "block_rows", "activation", "interpret"),
    donate_argnums=(0,))
def ring_gemm(pool: jax.Array, w: jax.Array, b: jax.Array, *, m_rows: int,
              d_in: int, d_out: int, in_ptr: int, out_ptr: int,
              block_rows: int = 8, activation: str | None = None,
              interpret: bool = False) -> jax.Array:
    """Run ``Out[m_rows, d_out] = In[m_rows, d_in] @ w + b`` inside the ring.

    ``pool``: [n_segments, SEG_WIDTH]; input rows resident at ``in_ptr``;
    output lands at ``out_ptr`` (planner-solved, block-aligned).  Returns the
    updated pool (same buffer — donated & aliased).
    """
    n_seg = pool.shape[0]
    k_segs, n_segs = _segs(d_in), _segs(d_out)
    bk, bn = block_rows * k_segs, block_rows * n_segs
    if m_rows % block_rows:
        raise ValueError("block_rows must divide m_rows")
    if n_seg % math.lcm(bk, bn) or in_ptr % bk or out_ptr % bn:
        raise ValueError("pool/pointers not block-aligned; use "
                         "aligned_pool_geometry()")
    grid = (m_rows // block_rows,)
    kernel = functools.partial(
        _kernel, in_ptr=in_ptr, out_ptr=out_ptr, n_seg=n_seg,
        block_rows=block_rows, d_in=d_in, d_out=d_out,
        num_blocks=m_rows // block_rows, activation=activation)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ARBITRARY),      # pool stays HBM
            pl.BlockSpec((d_in, d_out), lambda i: (0, 0)),   # FlashLoad
            pl.BlockSpec((d_out,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ARBITRARY),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, bk, SEG_WIDTH), pool.dtype),   # double buffer
            pltpu.VMEM((bn, SEG_WIDTH), pool.dtype),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA,
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(pool, w, b)


def stage_rows(pool: jax.Array, rows: jax.Array, ptr: int) -> jax.Array:
    """Alias of :func:`repro.core.vpool.stage_rows` (the one impl)."""
    return _pool_stage_rows(pool, rows, ptr)


def fetch_rows(pool: jax.Array, ptr: int, m: int, d: int) -> jax.Array:
    """Alias of :func:`repro.core.vpool.fetch_rows` (the one impl)."""
    return _pool_fetch_rows(pool, ptr, m, d)
