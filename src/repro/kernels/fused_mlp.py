"""Fused streaming MLP — the inverted-bottleneck kernel (paper Fig. 6) for
transformers.

The paper fuses PW-expand → DW → PW-project → add with an 11-segment
workspace so intermediate tensors never exist in RAM.  The transformer
analogue fuses up-projection → activation (optionally gated) → down-
projection → residual-add: the ``[rows, d_ff]`` intermediate — the widest
tensor in the network — never exists in HBM.  Per vMCU Eq. (2) this chain's
input/output offset is ZERO (each output row depends only on its own input
row), so the kernel runs fully **in place** in the ring pool: the output row
block overwrites its own input row block, beating the single-layer 50% bound
exactly as §5.2 promises.

Grid = (row_blocks, ff_blocks); ff is the inner (fastest) axis so the
``d_ff`` reduction accumulates in an fp32 VMEM scratch while weight tiles
stream from HBM ("Flash").  The row block is the vMCU outer tile; the MXU
tile is the inner tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .segment_matmul import SEG_WIDTH, _segs


def _kernel(pool_ref, wg_ref, wu_ref, wd_ref, out_ref,
            x_vmem, acc_vmem, sem_in, sem_out,
            *, ptr: int, n_seg: int, block_rows: int, d_model: int,
            gated: bool, residual: bool, activation: str):
    m, f = pl.program_id(0), pl.program_id(1)
    nf = pl.num_programs(1)
    d_segs = _segs(d_model)
    bd = block_rows * d_segs

    # Load the input row-block once per row (first ff step).
    @pl.when(f == 0)
    def _load():
        off = jax.lax.rem(ptr + m * bd, n_seg)
        cp = pltpu.make_async_copy(pool_ref.at[pl.ds(off, bd)], x_vmem,
                                   sem_in)
        cp.start()
        cp.wait()

    x = x_vmem[...].reshape(block_rows, d_segs * SEG_WIDTH)[:, :d_model]
    x = x.astype(jnp.float32)

    # Workspace: one [block_rows, ff_tile] slice of the intermediate —
    # the "11 segments" of Fig. 6; d_ff is never materialized.
    up = jnp.dot(x, wu_ref[...].astype(jnp.float32),
                 preferred_element_type=jnp.float32)
    if gated:
        gate = jnp.dot(x, wg_ref[...].astype(jnp.float32),
                       preferred_element_type=jnp.float32)
        if activation == "gelu":
            h = jax.nn.gelu(gate) * up
        else:
            h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up) if activation == "gelu" else jax.nn.silu(up)
    part = jnp.dot(h, wd_ref[...].astype(jnp.float32),
                   preferred_element_type=jnp.float32)

    @pl.when(f == 0)
    def _init():
        acc_vmem[...] = jnp.zeros_like(acc_vmem)

    acc_vmem[...] += part

    # Final ff step: residual add and in-place RAMStore (delta == 0).
    @pl.when(f == nf - 1)
    def _store():
        y = acc_vmem[...]
        if residual:
            y = y + x
        y = y.astype(x_vmem.dtype)
        pad = d_segs * SEG_WIDTH - d_model
        if pad:
            y = jnp.pad(y, ((0, 0), (0, pad)))
        x_vmem[...] = y.reshape(bd, SEG_WIDTH)
        off = jax.lax.rem(ptr + m * bd, n_seg)
        cp = pltpu.make_async_copy(x_vmem, out_ref.at[pl.ds(off, bd)],
                                   sem_out)
        cp.start()
        cp.wait()


@functools.partial(
    jax.jit,
    static_argnames=("m_rows", "d_model", "ptr", "block_rows", "ff_tile",
                     "gated", "residual", "activation", "interpret"),
    donate_argnums=(0,))
def ring_fused_mlp(pool: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                   w_down: jax.Array, *, m_rows: int, d_model: int, ptr: int,
                   block_rows: int = 8, ff_tile: int = 512,
                   gated: bool = True, residual: bool = True,
                   activation: str = "gelu",
                   interpret: bool = False) -> jax.Array:
    """In-place fused MLP over rows resident at ``ptr`` in the ring pool.

    w_gate/w_up: [d_model, d_ff]; w_down: [d_ff, d_model].  The d_ff axis is
    tiled by ``ff_tile``; each tile's weights stream HBM→VMEM via BlockSpec.
    """
    n_seg = pool.shape[0]
    d_ff = w_up.shape[1]
    d_segs = _segs(d_model)
    bd = block_rows * d_segs
    if m_rows % block_rows or d_ff % ff_tile:
        raise ValueError("block_rows | m_rows and ff_tile | d_ff required")
    if n_seg % bd or ptr % bd:
        raise ValueError("pool/ptr must be row-block aligned")
    grid = (m_rows // block_rows, d_ff // ff_tile)
    kernel = functools.partial(
        _kernel, ptr=ptr, n_seg=n_seg, block_rows=block_rows,
        d_model=d_model, gated=gated, residual=residual,
        activation=activation)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ARBITRARY),          # ring pool
            pl.BlockSpec((d_model, ff_tile), lambda m, f: (0, f)),
            pl.BlockSpec((d_model, ff_tile), lambda m, f: (0, f)),
            pl.BlockSpec((ff_tile, d_model), lambda m, f: (f, 0)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ARBITRARY),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        scratch_shapes=[
            pltpu.VMEM((bd, SEG_WIDTH), pool.dtype),
            pltpu.VMEM((block_rows, d_model), jnp.float32),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(pool, w_gate, w_up, w_down)
