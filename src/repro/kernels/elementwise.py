"""In-place ring elementwise kernel — delta == 0 pool ops on TPU.

The simplest PoolProgram op: map a registered element-wise fn over rows
resident in the ring pool, writing each row-block back over itself (the
paper's in-place epilogue case — RAMStore at the input pointer).  Same
RAMLoad / compute / RAMStore skeleton as the ring GEMM (Fig. 4), with the
modulo bounds check on every block offset.

The fn is applied to the whole padded ``[bd, SEG_WIDTH]`` tile; every fn
in :data:`repro.core.program.ACTIVATIONS` maps 0 -> 0, so segment padding
columns stay zero through the ring.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.program import resolve_activation
from .segment_matmul import SEG_WIDTH, _segs


def _kernel(pool_ref, out_ref, x_vmem, sem_in, sem_out, *,
            ptr: int, n_seg: int, bd: int, num_blocks: int, fn: str):
    i = pl.program_id(0)
    slot = jax.lax.rem(i, 2)

    def ram_load(block, into):
        off = jax.lax.rem(ptr + block * bd, n_seg)
        return pltpu.make_async_copy(pool_ref.at[pl.ds(off, bd)],
                                     x_vmem.at[into], sem_in.at[into])

    # Double-buffered RAMLoad: block i+1 stages while block i computes.
    # Block i's in-place store covers block i's rows only, never block
    # i+1's still-live rows, so the prefetch is clobber-free.
    @pl.when(i == 0)
    def _prime():
        ram_load(0, 0).start()

    @pl.when(i + 1 < num_blocks)
    def _prefetch():
        ram_load(i + 1, 1 - slot).start()

    ram_load(i, slot).wait()
    y = resolve_activation(fn)(x_vmem[slot].astype(jnp.float32))
    x_vmem[slot] = y.astype(x_vmem.dtype)
    off = jax.lax.rem(ptr + i * bd, n_seg)
    store = pltpu.make_async_copy(x_vmem.at[slot],
                                  out_ref.at[pl.ds(off, bd)], sem_out)
    store.start()
    store.wait()


@functools.partial(
    jax.jit,
    static_argnames=("m_rows", "d", "ptr", "fn", "block_rows", "interpret"),
    donate_argnums=(0,))
def ring_elementwise(pool: jax.Array, *, m_rows: int, d: int, ptr: int,
                     fn: str = "gelu", block_rows: int = 1,
                     interpret: bool = False) -> jax.Array:
    """Apply ``fn`` in place to ``[m_rows, d]`` rows resident at ``ptr``."""
    n_seg = pool.shape[0]
    d_segs = _segs(d)
    bd = block_rows * d_segs
    if m_rows % block_rows:
        raise ValueError("block_rows must divide m_rows")
    if n_seg % bd or ptr % bd:
        raise ValueError("pool/ptr must be row-block aligned")
    kernel = functools.partial(_kernel, ptr=ptr, n_seg=n_seg, bd=bd,
                               num_blocks=m_rows // block_rows, fn=fn)
    return pl.pallas_call(
        kernel,
        grid=(m_rows // block_rows,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ARBITRARY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.ARBITRARY),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, bd, SEG_WIDTH), pool.dtype),   # double buffer
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA,
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(pool)
