"""Pure-jnp oracles for every executable op kind (the ``ref.py`` contract).

Each oracle computes the mathematical result with no ring/pool mechanics;
tests stage inputs into a ring, run the op on a backend, fetch outputs,
and compare against these.  This file is THE reference the conformance
matrix (``tests/test_conformance_matrix.py``) pins every (op kind,
backend, dtype) cell against:

  * fp32 oracles — ``assert_allclose``; the conv oracles go through
    ``lax.conv_general_dilated`` so a shared gather/tap indexing bug in
    the executors cannot cancel out,
  * int8 oracles (``*_q_ref``) — BITWISE equality; integer accumulation
    is order-independent, so these simple formulations pin the ring
    kernels exactly (they share only the one
    :func:`repro.quant.requant.requantize` definition with them).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.program import resolve_activation
from ..core.rowsched import conv_k2d_pad


def gemm_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    return (x.astype(jnp.float32) @ w.astype(jnp.float32)
            + b.astype(jnp.float32)).astype(x.dtype)


def fused_mlp_ref(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                  w_down: jax.Array, *, gated: bool = True,
                  residual: bool = True,
                  activation: str = "gelu") -> jax.Array:
    xf = x.astype(jnp.float32)
    up = xf @ w_up.astype(jnp.float32)
    if gated:
        g = xf @ w_gate.astype(jnp.float32)
        act = jax.nn.gelu(g) if activation == "gelu" else jax.nn.silu(g)
        h = act * up
    else:
        h = jax.nn.gelu(up) if activation == "gelu" else jax.nn.silu(up)
    y = h @ w_down.astype(jnp.float32)
    if residual:
        y = y + xf
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# fp32 whole-network op oracles.
# ---------------------------------------------------------------------------

def _act(y, activation):
    return resolve_activation(activation)(y)


def _conv2d(img, w, *, stride: int, pad_lo: int, h_out: int, w_out: int,
            groups: int = 1) -> jax.Array:
    """``lax.conv_general_dilated`` with the repo's halo convention: low
    padding fixed, high padding whatever makes the output shape exact."""
    h_in, w_in, _ = img.shape
    rs = w.shape[0]
    ph = (h_out - 1) * stride + rs - pad_lo - h_in
    pw = (w_out - 1) * stride + rs - pad_lo - w_in
    out = jax.lax.conv_general_dilated(
        img[None].astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=((pad_lo, ph), (pad_lo, pw)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)
    return out[0]


def conv_pw_ref(img: jax.Array, w: jax.Array, b: jax.Array, *,
                stride: int = 1, activation: str | None = None
                ) -> jax.Array:
    """Pointwise conv ``[h, w, c_in] -> [ceil(h/s), ceil(w/s), c_out]``."""
    h_out = -(-img.shape[0] // stride)
    w_out = -(-img.shape[1] // stride)
    c_in, c_out = w.shape
    y = _conv2d(img, w.reshape(1, 1, c_in, c_out), stride=stride,
                pad_lo=0, h_out=h_out, w_out=w_out)
    return _act(y + b.astype(jnp.float32), activation).astype(img.dtype)


def conv_dw_ref(img: jax.Array, w: jax.Array, b: jax.Array, *,
                stride: int = 1, activation: str | None = None
                ) -> jax.Array:
    """Depthwise RSxRS conv, 'same' padding; ``w``: [rs, rs, c]."""
    rs, _, c = w.shape
    h_out = -(-img.shape[0] // stride)
    w_out = -(-img.shape[1] // stride)
    y = _conv2d(img, w.reshape(rs, rs, 1, c), stride=stride,
                pad_lo=(rs - 1) // 2, h_out=h_out, w_out=w_out, groups=c)
    return _act(y + b.astype(jnp.float32), activation).astype(img.dtype)


def conv_k2d_ref(img: jax.Array, w: jax.Array, b: jax.Array, *,
                 stride: int = 1, padding: str = "same",
                 activation: str | None = None) -> jax.Array:
    """General k x k conv; ``w``: [k, k, c_in, c_out]."""
    from ..core.rowsched import conv_k2d_out

    k = w.shape[0]
    h_out = conv_k2d_out(img.shape[0], k, stride, padding)
    w_out = conv_k2d_out(img.shape[1], k, stride, padding)
    y = _conv2d(img, w, stride=stride, pad_lo=conv_k2d_pad(k, padding),
                h_out=h_out, w_out=w_out)
    return _act(y + b.astype(jnp.float32), activation).astype(img.dtype)


def conv_stream_ref(state: jax.Array, frame: jax.Array, w: jax.Array,
                    b: jax.Array, *, stride: int = 1,
                    padding: str = "same",
                    activation: str | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """Oracle for ONE conv_stream step: drop the oldest ``hop`` image
    rows of the ``[h_win, w_in, c_in]`` window, append the ``[hop, w_in,
    c_in]`` frame, then run the ``lax``-backed k x k conv oracle over the
    shifted window.  Returns ``(y, new_state)``."""
    win = jnp.concatenate([state[frame.shape[0]:], frame], axis=0)
    return conv_k2d_ref(win, w, b, stride=stride, padding=padding,
                        activation=activation), win


def gru_cell_ref(x: jax.Array, h: jax.Array, w: jax.Array, u: jax.Array,
                 b: jax.Array) -> jax.Array:
    """Hard-gate GRU step oracle — ``h' = gru_update(x@w + b, h@u, h)``
    (the one shared gate definition in ``repro.quant.requant``)."""
    from ..quant.requant import gru_update

    xf, hf = x.astype(jnp.float32), h.astype(jnp.float32)
    gx = xf @ w.astype(jnp.float32) + b.astype(jnp.float32)
    gh = hf @ u.astype(jnp.float32)
    return gru_update(gx, gh, hf, w.shape[1] // 3).astype(x.dtype)


def add_ref(x: jax.Array, res: jax.Array, *,
            activation: str | None = None) -> jax.Array:
    return _act(x.astype(jnp.float32) + res.astype(jnp.float32),
                activation).astype(x.dtype)


def avgpool_ref(img: jax.Array) -> jax.Array:
    """Global average pool ``[h, w, c] -> [1, c]``."""
    return jnp.mean(img.astype(jnp.float32), axis=(0, 1),
                    keepdims=False)[None, :].astype(img.dtype)


def elementwise_ref(x: jax.Array, fn: str) -> jax.Array:
    return _act(x.astype(jnp.float32), fn).astype(x.dtype)


def ib_fused_ref(a: jax.Array, w1: jax.Array, wd: jax.Array,
                 w2: jax.Array, *, residual: bool = True) -> jax.Array:
    """Fused inverted bottleneck (Fig. 6) oracle — re-exported so every
    executable op kind has its reference here."""
    from .inverted_bottleneck import inverted_bottleneck_ref

    return inverted_bottleneck_ref(a, w1, wd, w2, residual=residual)


# ---------------------------------------------------------------------------
# int8 op oracles: int8 operands -> int32 accumulate -> the ONE shared
# requantize definition.  Bitwise contracts for the quantized kernels.
# ---------------------------------------------------------------------------

def _q_act(acc, activation):
    from ..quant.requant import act_i32

    return act_i32(acc, activation)


def gemm_q_ref(x_q, w_q, b_q, mult, shift, *, activation=None):
    from ..quant.requant import requantize

    acc = jnp.dot(x_q.astype(jnp.int32), w_q.astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    acc = _q_act(acc + b_q.astype(jnp.int32), activation)
    return requantize(acc, mult[None, :], shift[None, :])


def conv_pw_q_ref(img_q, w_q, b_q, mult, shift, *, stride=1,
                  activation=None):
    sub = img_q[::stride, ::stride].astype(jnp.int32)
    acc = jnp.einsum("hwc,cd->hwd", sub, w_q.astype(jnp.int32))
    return _requant_img(acc, b_q, mult, shift, activation)


def conv_dw_q_ref(img_q, w_q, b_q, mult, shift, *, stride=1,
                  activation=None):
    rs, _, c = w_q.shape
    acc = _tap_acc(img_q, w_q.reshape(rs, rs, 1, c), stride,
                   (rs - 1) // 2, "same", depthwise=True)
    return _requant_img(acc, b_q, mult, shift, activation)


def conv_k2d_q_ref(img_q, w_q, b_q, mult, shift, *, stride=1,
                   padding="same", activation=None):
    k = w_q.shape[0]
    acc = _tap_acc(img_q, w_q, stride, conv_k2d_pad(k, padding), padding)
    return _requant_img(acc, b_q, mult, shift, activation)


def _tap_acc(img_q, w_q, stride, pad_lo, padding, *, depthwise=False):
    """Int32 tap-sum conv (exact — integer addition is associative)."""
    k = w_q.shape[0]
    h_in, w_in, _ = img_q.shape
    if padding == "same":
        h_out, w_out = -(-h_in // stride), -(-w_in // stride)
    else:
        h_out = (h_in - k) // stride + 1
        w_out = (w_in - k) // stride + 1
    pad_hi = pad_lo + stride if padding == "same" else 0
    padded = jnp.pad(img_q.astype(jnp.int32),
                     ((pad_lo, pad_hi), (pad_lo, pad_hi), (0, 0)))
    c_out = w_q.shape[2] if depthwise else w_q.shape[3]
    acc = jnp.zeros((h_out, w_out, c_out), jnp.int32)
    for r in range(k):
        for c in range(k):
            tap = padded[r:r + stride * (h_out - 1) + 1:stride,
                         c:c + stride * (w_out - 1) + 1:stride]
            if depthwise:
                acc = acc + tap * w_q[r, c, 0].astype(jnp.int32)[None,
                                                                 None]
            else:
                acc = acc + jnp.einsum("hwc,cd->hwd", tap,
                                       w_q[r, c].astype(jnp.int32))
    return acc


def _requant_img(acc, b_q, mult, shift, activation):
    from ..quant.requant import requantize

    acc = _q_act(acc + b_q.astype(jnp.int32), activation)
    return requantize(acc, mult[None, None, :], shift[None, None, :])


def add_q_ref(x_q, res_q, mult_in, shift_in, mult_aux, shift_aux, *,
              activation=None):
    from ..quant.requant import requantize_i32

    ya = requantize_i32(x_q.astype(jnp.int32), mult_in, shift_in)
    yb = requantize_i32(res_q.astype(jnp.int32), mult_aux, shift_aux)
    return jnp.clip(_q_act(ya + yb, activation), -128, 127) \
        .astype(jnp.int8)


def conv_stream_q_ref(state_q, frame_q, w_q, b_q, mult, shift, *,
                      stride=1, padding="same", activation=None):
    """Int8 conv_stream step: the shift/append is an exact int8 copy,
    the conv is the bitwise conv_k2d pipeline.  Returns
    ``(y_q, new_state_q)``."""
    win = jnp.concatenate([state_q[frame_q.shape[0]:], frame_q], axis=0)
    return conv_k2d_q_ref(win, w_q, b_q, mult, shift, stride=stride,
                          padding=padding, activation=activation), win


def gru_cell_q_ref(x_q, h_q7, w_q, u_q, b_q12, mult_x, shift_x, mult_u,
                   shift_u):
    """Int8 GRU step: both accumulators requantized to Q12, then the
    shared fixed-point update (bitwise contract for the ring kernels)."""
    from ..quant.requant import gru_update_q12, requantize_i32

    gx = requantize_i32(
        jnp.dot(x_q.astype(jnp.int32), w_q.astype(jnp.int32),
                preferred_element_type=jnp.int32), mult_x, shift_x)
    gx = gx + b_q12.astype(jnp.int32)
    gh = requantize_i32(
        jnp.dot(h_q7.astype(jnp.int32), u_q.astype(jnp.int32),
                preferred_element_type=jnp.int32), mult_u, shift_u)
    return gru_update_q12(gx, gh, h_q7, w_q.shape[1] // 3)


def avgpool_q_ref(img_q, mult, shift):
    from ..quant.requant import requantize

    acc = jnp.sum(img_q.astype(jnp.int32), axis=(0, 1))[None, :]
    return requantize(acc, mult, shift)


def ring_decode_ref(q: jax.Array, k_ring: jax.Array, v_ring: jax.Array,
                    seq_len: int, *, window: int,
                    softcap: float | None = None) -> jax.Array:
    """Oracle decode attention over the *logical* (unrolled) window."""
    q_heads, d = q.shape
    kv_heads = k_ring.shape[1]
    group = q_heads // kv_heads
    qf = q.astype(jnp.float32) * (d ** -0.5)
    kf = k_ring.astype(jnp.float32)
    vf = v_ring.astype(jnp.float32)
    s = jnp.einsum("hd,skd->sh",
                   qf.reshape(kv_heads, group, d).reshape(q_heads, d),
                   jnp.repeat(kf, group, axis=1)
                   .reshape(window, q_heads, d)[:, :, :]
                   ).reshape(window, q_heads) if False else jnp.einsum(
        "khd,skd->skh", qf.reshape(kv_heads, group, d), kf
    ).reshape(window, q_heads)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    slot = jnp.arange(window)[:, None]
    valid = (slot < seq_len) | (seq_len >= window)
    s = jnp.where(valid, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=0)
    vg = jnp.repeat(vf, group, axis=1)
    return jnp.einsum("sh,shd->hd", p, vg).astype(q.dtype)
