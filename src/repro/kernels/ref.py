"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each oracle computes the mathematical result with no ring/pool mechanics;
tests stage inputs into a ring, run the kernel, fetch outputs, and
``assert_allclose`` against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gemm_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    return (x.astype(jnp.float32) @ w.astype(jnp.float32)
            + b.astype(jnp.float32)).astype(x.dtype)


def fused_mlp_ref(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                  w_down: jax.Array, *, gated: bool = True,
                  residual: bool = True,
                  activation: str = "gelu") -> jax.Array:
    xf = x.astype(jnp.float32)
    up = xf @ w_up.astype(jnp.float32)
    if gated:
        g = xf @ w_gate.astype(jnp.float32)
        act = jax.nn.gelu(g) if activation == "gelu" else jax.nn.silu(g)
        h = act * up
    else:
        h = jax.nn.gelu(up) if activation == "gelu" else jax.nn.silu(up)
    y = h @ w_down.astype(jnp.float32)
    if residual:
        y = y + xf
    return y.astype(x.dtype)


def ring_decode_ref(q: jax.Array, k_ring: jax.Array, v_ring: jax.Array,
                    seq_len: int, *, window: int,
                    softcap: float | None = None) -> jax.Array:
    """Oracle decode attention over the *logical* (unrolled) window."""
    q_heads, d = q.shape
    kv_heads = k_ring.shape[1]
    group = q_heads // kv_heads
    qf = q.astype(jnp.float32) * (d ** -0.5)
    kf = k_ring.astype(jnp.float32)
    vf = v_ring.astype(jnp.float32)
    s = jnp.einsum("hd,skd->sh",
                   qf.reshape(kv_heads, group, d).reshape(q_heads, d),
                   jnp.repeat(kf, group, axis=1)
                   .reshape(window, q_heads, d)[:, :, :]
                   ).reshape(window, q_heads) if False else jnp.einsum(
        "khd,skd->skh", qf.reshape(kv_heads, group, d), kf
    ).reshape(window, q_heads)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    slot = jnp.arange(window)[:, None]
    valid = (slot < seq_len) | (seq_len >= window)
    s = jnp.where(valid, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=0)
    vg = jnp.repeat(vf, group, axis=1)
    return jnp.einsum("sh,shd->hd", p, vg).astype(q.dtype)
