from .sharding import AxisRules, no_sharding
