"""Logical-axis sharding rules for both mesh modes.

Two modes (DESIGN.md §6):

* ``tp``      — Megatron TP on the ``model`` axis (heads / d_ff / experts /
                vocab) + ZeRO-3 FSDP on ``data`` + DP batch on (pod, data).
                Used when ``n_heads % model_size == 0``.
* ``fsdp_sp`` — small-head archs: params replicated on ``model`` (FSDP on
                ``data``), activations *sequence*-sharded on ``model``
                (context parallelism); vocab still TP on ``model``.

Models never name physical axes — they call ``rules.act(x, "batch", "seq",
None)`` and ``rules.param_spec(path, shape)``; on a plain CPU (no mesh) every
call is a no-op so the same code runs in smoke tests.
"""
from __future__ import annotations

import dataclasses
import re

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class AxisRules:
    mesh: Mesh | None
    mode: str = "tp"           # "tp" | "fsdp_sp"
    multi_pod: bool = False
    decode: bool = False       # decode steps: S==1, never shard "seq"
    long_context: bool = False  # long_500k: batch==1, shard cache seq
    kv_shardable: bool = True  # n_kv_heads % model_size == 0
    sp_residual: bool = False  # tp mode: Megatron-SP — shard the residual
                               # stream (and saved activations) on "model"

    # -- logical -> physical ---------------------------------------------------
    def _phys(self, logical: str | None):
        if logical is None:
            return None
        if logical == "batch":
            if self.long_context:
                return None    # batch == 1
            return ("pod", "data") if self.multi_pod else "data"
        if logical == "fsdp":
            return "data"
        if logical == "seq":
            if self.decode:
                return None    # decode: query length 1
            return "model" if self.mode == "fsdp_sp" else None
        if logical == "res_seq":   # residual stream between blocks
            if self.decode:
                return None
            if self.mode == "fsdp_sp" or self.sp_residual:
                return "model"
            return None
        if logical == "kv_seq":      # KV-cache sequence dim
            if self.long_context:
                # batch==1: spread the cache over everything available
                return "data" if self.kv_shardable else ("data", "model")
            if self.decode and not self.kv_shardable:
                return "model"  # heads can't shard — shard cache seq instead
            return None
        if logical == "kv_heads":
            return ("model" if self.mode == "tp" and self.kv_shardable
                    else None)
        if logical in ("heads", "ff", "experts", "tp"):
            return "model" if self.mode == "tp" else None
        if logical == "vocab":
            return "model"
        raise ValueError(f"unknown logical axis {logical!r}")

    def spec(self, *logical: str | None) -> P:
        return P(*(self._phys(ax) for ax in logical))

    def act(self, x: jax.Array, *logical: str | None) -> jax.Array:
        """Constrain an activation; no-op without a mesh."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(*logical)))

    def sharding(self, *logical: str | None) -> NamedSharding | None:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(*logical))

    # -- parameter placement ---------------------------------------------------
    # Path-pattern rules, first match wins. Trailing dims are matched right-
    # aligned so stacked [n_groups, ...] params get None on the lead axis.
    _PARAM_RULES: tuple[tuple[str, tuple[str | None, ...]], ...] = (
        (r"embed|unembed", ("vocab", "fsdp")),
        (r"\bw_(q|k|v)\b", ("fsdp", "heads")),
        (r"\bw_o\b", ("heads", "fsdp")),
        (r"\bw_(gate|up)\b$", ("fsdp", "ff")),
        (r"\bw_down\b", ("ff", "fsdp")),
        (r"moe_(gate|up)", ("experts", "fsdp", None)),
        (r"moe_down", ("experts", None, "fsdp")),
        (r"shared_(gate|up)", ("fsdp", "ff")),
        (r"shared_down", ("ff", "fsdp")),
        (r"router", ("fsdp", None)),
        (r"ssm_w_(z|x)|ssm_conv_x", ("fsdp", "heads")),  # d_inner cols
        (r"ssm_w_(b|c|dt)", ("fsdp", None)),
        (r"ssm_out", ("heads", "fsdp")),
        (r"ssm_(a_log|d|dt_bias|norm)", (None,)),
        (r"lru_w_(x|y)", ("fsdp", "tp")),
        (r"lru_out", ("tp", "fsdp")),
        (r"lru_", (None,)),
        (r"conv", (None, None)),
        (r"ln|norm|scale|bias", (None,)),
    )

    def param_spec(self, path: str, ndim: int) -> P:
        for pat, dims in self._PARAM_RULES:
            if re.search(pat, path):
                dims = tuple(d for d in dims)
                if len(dims) > ndim:
                    dims = dims[-ndim:]
                lead = (None,) * (ndim - len(dims))
                return P(*(self._phys(d) for d in (lead + dims)))
        return P(*([None] * ndim))

    def params_shardings(self, params) -> object:
        """Map a param pytree to NamedShardings (None mesh → None tree)."""
        if self.mesh is None:
            return jax.tree.map(lambda _: None, params)

        def leaf(path, x):
            name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in path)
            return NamedSharding(self.mesh,
                                 self.param_spec(name, x.ndim))
        return jax.tree_util.tree_map_with_path(leaf, params)

    def constrain_tree(self, params):
        """Pin every param (works on tracers) to its rule sharding.

        Used inside the loss so the *cotangent* of each parameter is
        resharded right here — XLA then forms reduce-scatters for the
        gradient reduction instead of all-reduce + keep-replicated
        (§Perf iteration 2)."""
        if self.mesh is None:
            return params

        def leaf(path, x):
            name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in path)
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(self.mesh, self.param_spec(name, x.ndim)))
        return jax.tree_util.tree_map_with_path(leaf, params)


def no_sharding() -> AxisRules:
    return AxisRules(mesh=None)
