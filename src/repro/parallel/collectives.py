"""Distributed-optimization collectives.

* ``compressed_psum``   — int8-quantized gradient all-reduce (error-feedback
  compatible): quantize per-bucket to int8 with an fp32 scale, psum the int32
  accumulation, dequantize.  8x wire-bytes reduction on the DP/pod axis —
  usable under ``shard_map`` where the collective is explicit.
* ``bucketed_psum``     — chunk a pytree into fixed-byte buckets so the
  all-reduce overlaps with backprop compute (latency hiding at the scheduler
  level; bucket size is a hillclimb lever).
* ``quantize_int8 / dequantize_int8`` — the codec, reused by checkpointing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8 all-reduce: each participant sends 1 byte/elem + one fp32 scale.

    The shared max-scale is agreed with a tiny scalar all-reduce first so
    the int32 sum dequantizes consistently.
    """
    scale = jax.lax.pmax(jnp.max(jnp.abs(x.astype(jnp.float32))),
                         axis_name) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                 -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale


def bucketed_psum(tree, axis_name: str, bucket_bytes: int = 4 << 20,
                  compressed: bool = False):
    """All-reduce a pytree in fixed-size flat buckets."""
    leaves, treedef = jax.tree.flatten(tree)
    flat = jnp.concatenate([x.reshape(-1).astype(jnp.float32)
                            for x in leaves])
    n = flat.shape[0]
    per = max(1, bucket_bytes // 4)
    pads = (-n) % per
    flat = jnp.pad(flat, (0, pads)).reshape(-1, per)
    op = compressed_psum if compressed else jax.lax.psum
    # sequential buckets — the scheduler overlaps each with ongoing compute
    flat = jax.lax.map(lambda b: op(b, axis_name), flat)
    flat = flat.reshape(-1)[:n]
    out, off = [], 0
    for x in leaves:
        sz = x.size
        out.append(flat[off:off + sz].reshape(x.shape).astype(x.dtype))
        off += sz
    return jax.tree.unflatten(treedef, out)
