"""AdamW + schedules, pure JAX (no optax dependency).

Master weights fp32; model code casts to bf16 at use sites, so the state
layout is (params, mu, nu) fp32 — 12 bytes/param, matching the dry-run
memory analysis assumptions in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class TrainState(NamedTuple):
    step: jax.Array
    params: Any          # fp32 masters (sharded)
    mu: Any
    nu: Any
    # Optional bf16 working copy (DeepSpeed-style two-copy scheme): the
    # forward/backward consume THIS tree, so every FSDP all-gather moves
    # bf16 by construction — the fp32 masters never cross the network.
    # None when the scheme is off (§Perf iteration B1).
    cast: Any = None


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = cfg.peak_lr * (step + 1) / cfg.warmup_steps
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.peak_lr * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def cast_tree(params: Any) -> Any:
    return jax.tree.map(
        lambda p: p.astype(jnp.bfloat16) if p.dtype == jnp.float32 else p,
        params)


def init_state(params: Any, *, two_copy: bool = False) -> TrainState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      mu=zeros, nu=jax.tree.map(jnp.copy, zeros),
                      cast=cast_tree(params) if two_copy else None)


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(state: TrainState, grads: Any, cfg: AdamWConfig
                 ) -> tuple[TrainState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    lr = lr_at(cfg, state.step)
    t = (state.step + 1).astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    class _Upd(NamedTuple):     # sentinel leaf (params contain plain tuples)
        p: jax.Array
        m: jax.Array
        v: jax.Array

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat, vhat = m / bc1, v / bc2
        p = p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                      + cfg.weight_decay * p)
        return _Upd(p, m, v)

    out = jax.tree.map(upd, state.params, grads, state.mu, state.nu)
    is_upd = lambda x: isinstance(x, _Upd)  # noqa: E731
    params = jax.tree.map(lambda o: o.p, out, is_leaf=is_upd)
    mu = jax.tree.map(lambda o: o.m, out, is_leaf=is_upd)
    nu = jax.tree.map(lambda o: o.v, out, is_leaf=is_upd)
    new_cast = cast_tree(params) if state.cast is not None else None
    return (TrainState(state.step + 1, params, mu, nu, new_cast),
            {"grad_norm": gnorm, "lr": lr})
