"""Synthetic, deterministic, restart-safe data pipeline.

Batches are a pure function of (arch, step) so a restarted job regenerates
exactly the stream it would have seen — the data-side half of
checkpoint/restart fault tolerance.  On a real cluster each host
materializes only its addressable shard (``make_array_from_callback``).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeCell


def batch_spec(cfg: ModelConfig, cell: ShapeCell) -> dict[str, jax.ShapeDtypeStruct]:
    B, S = cell.global_batch, cell.seq_len
    spec = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if cfg.family == "vlm":
        spec["memory"] = jax.ShapeDtypeStruct(
            (B, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        spec["memory"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return spec


def synthetic_batch(cfg: ModelConfig, batch: int, seq: int, step: int,
                    seed: int = 0) -> dict[str, jax.Array]:
    """Host-side deterministic batch (used by examples / CPU training)."""
    rng = np.random.default_rng(np.uint64(seed) * 1_000_003 + np.uint64(step))
    toks = rng.integers(0, cfg.vocab, size=(batch, seq + 1), dtype=np.int64)
    out = {
        "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
        "labels": jnp.asarray(toks[:, 1:], jnp.int32),
    }
    if cfg.family == "vlm":
        out["memory"] = jnp.asarray(
            rng.standard_normal((batch, cfg.n_image_tokens, cfg.d_model)),
            jnp.bfloat16)
    if cfg.family == "audio":
        out["memory"] = jnp.asarray(
            rng.standard_normal((batch, cfg.encoder_seq, cfg.d_model)),
            jnp.bfloat16)
    return out


def sharded_batch(cfg: ModelConfig, batch: int, seq: int, step: int,
                  shardings: dict, seed: int = 0) -> dict[str, jax.Array]:
    """Materialize only the local shards (multi-host path)."""
    full = synthetic_batch(cfg, batch, seq, step, seed)

    def place(name, x):
        sh = shardings.get(name)
        if sh is None:
            return x
        return jax.make_array_from_callback(
            x.shape, sh, lambda idx: np.asarray(x[idx]))
    return {k: place(k, v) for k, v in full.items()}
