"""Train-step factory: microbatched grad accumulation, mixed precision,
remat policy — the function the dry-run lowers for every train cell.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..models.transformer import Model
from ..parallel.sharding import AxisRules, no_sharding
from .optimizer import AdamWConfig, TrainState, adamw_update, init_state


def make_train_step(model: Model, rules: AxisRules | None = None, *,
                    opt: AdamWConfig | None = None, microbatches: int = 1,
                    remat_policy: str | None = None,
                    cast_params_bf16: bool = False,
                    constrain_grads: bool = False,
                    two_copy: bool = False):
    """Returns ``train_step(state, batch) -> (state, metrics)``.

    Hillclimb levers (§Perf; all off in the baseline):
      two_copy         — forward/backward consume a bf16 copy carried in
        the TrainState (state.cast): FSDP gathers move bf16 by
        construction; masters stay fp32 and local.  Gradients arrive in
        bf16 and are upcast in the optimizer.
      cast_params_bf16 — in-graph shard-local bf16 cast (refuted on this
        XLA build: the partitioner re-hoists gathers to the fp32 point);
      constrain_grads  — pin params inside the loss so gradient cotangents
        reshard there (refuted: XLA CPU lowers it as the same
        all-reduce + dynamic-slice it already emits).
    """
    rules = rules or no_sharding()
    opt = opt or AdamWConfig()

    def loss_fn(params, batch):
        if constrain_grads:
            params = rules.constrain_tree(params)
        if cast_params_bf16:
            params = jax.tree.map(
                lambda p: (p.astype(jnp.bfloat16)
                           if p.dtype == jnp.float32 else p), params)
            if constrain_grads:   # keep the bf16 copies sharded too
                params = rules.constrain_tree(params)
        loss, metrics = model.loss(params, batch, rules,
                                   remat_policy=remat_policy)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: dict[str, jax.Array]):
        fwd_params = state.cast if (two_copy and state.cast is not None) \
            else state.params
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(fwd_params, batch)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc_fn(carry, mbatch):
                gacc, lacc = carry
                (loss, _), grads = grad_fn(fwd_params, mbatch)
                gacc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                    gacc, grads)
                return (gacc, lacc + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, loss), _ = jax.lax.scan(
                acc_fn, (zeros, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            metrics = {}
        new_state, opt_metrics = adamw_update(state, grads, opt)
        return new_state, {"loss": loss, **opt_metrics}

    return train_step


def init_train_state(model: Model, key: jax.Array,
                     two_copy: bool = False) -> TrainState:
    return init_state(model.init(key), two_copy=two_copy)


def eval_state_shapes(model: Model) -> Any:
    """ShapeDtypeStruct tree of the train state (no allocation)."""
    return jax.eval_shape(
        lambda: init_train_state(model, jax.random.PRNGKey(0)))
