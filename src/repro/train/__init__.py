from .optimizer import AdamWConfig, TrainState, adamw_update, init_state
from .train_step import make_train_step, init_train_state
