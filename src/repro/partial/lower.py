"""Lowering — rewrite sliced groups into per-slice PoolOp runs inside
ONE merged :class:`PoolProgram` (DESIGN.md §13).

The surgery replaces a group's conv chain ``ops[g_lo:hi)`` with
``n_slices`` copies of the chain, one per output row band:

  * the group input ``X`` stays exactly where the plan put it; every
    slice reads a halo window of it in place (``in_row0``/``h_src``
    windowed reads, ``hold_input`` + ``in_op`` record sharing) and the
    LAST slice frees it (``free_src``) — unless the group ends in a
    residual ``add`` that still needs ``X``, which then frees it as its
    held aux source exactly as in the unsliced plan;
  * interior tensors live in per-chain-position scratch BANDS stacked
    directly below ``X`` — each band is sized for the worst slice and
    reused by every slice, with ordinary produce/consume semantics;
  * output bands land at their final resting offsets ``y0 + oa*yrow``
    and merge into ONE output record via ``out_op``/``out_row0``
    deferred-write ownership, so the consumer op reads the assembled
    tensor exactly as before;
  * every op after the group shifts down by the (block-aligned) ring
    savings, and the program's ring length is re-derived from the live
    spans of the rewritten schedule (:func:`recompute_spans` — the same
    max-live-span accounting ``plan_program`` uses).

Pointers stay multiples of their DMA blocks and ``n_segments`` a
multiple of every block, so ``check_alignment`` and the static
verifier's decidable fragment still cover the result.
"""
from __future__ import annotations

import dataclasses
import math

from ..core.program import (EXECUTABLE_KINDS, PoolOp, PoolProgram,
                            _floor_mult)
from ..core.vpool import ceil_div, segments_for
from .slicer import chain_chunks, chain_range, chain_steps, slice_layout


class PartialLowerError(ValueError):
    """The requested slicing cannot be lowered onto the ring."""


def _blocks(op: PoolOp, seg_width: int, block_rows: int | None
            ) -> tuple[int, int]:
    """(in, out) DMA block sizes in segments (PoolProgram.op_blocks)."""
    br = block_rows or 1
    ci = segments_for(op.d_in, seg_width)
    co = segments_for(op.d_out, seg_width)
    if op.kind in ("conv_pw", "conv_dw", "conv_k2d", "ib_fused"):
        return op.w_in * ci, op.w_out * co
    if op.kind == "pool_avg":
        return op.w_in * ci, co
    if op.kind == "add":
        return ci, co
    return br * ci, br * co


def live_spans(ops: tuple[PoolOp, ...]) -> list[int]:
    """Per-op instantaneous live span (segments) of an op schedule.

    Mirrors ``plan_program``'s ring accounting on the FINAL pointers:
    tracks every live tensor record (program input, chained tensors,
    held branch/residual sources, partially-assembled ``out_op``
    outputs) and reports the lo..hi extent each op observes.
    """
    live: dict[int, tuple[int, int]] = {}

    def _union(key: int, lo: int, hi: int) -> None:
        cur = live.get(key)
        live[key] = ((min(cur[0], lo), max(cur[1], hi)) if cur
                     else (lo, hi))

    first = ops[0]
    _union(0, first.in_ptr, first.in_ptr + first.in_segments)
    spans = []
    for i, op in enumerate(ops):
        ikey = op.in_op if op.in_op >= 0 else i
        okey = op.out_op if op.out_op >= 0 else i + 1
        _union(ikey, op.in_ptr, op.in_ptr + op.in_segments)
        _union(okey, op.out_ptr, op.out_ptr + op.out_segments)
        if op.aux_op >= 0:
            _union(op.aux_op, op.aux_ptr, op.aux_ptr + op.in_segments)
        lo = min(v[0] for v in live.values())
        hi = max(v[1] for v in live.values())
        spans.append(hi - lo)
        if not op.hold_input or op.free_src:
            live.pop(ikey, None)
        if op.aux_op >= 0:
            live.pop(op.aux_op, None)
    return spans


def recompute_spans(ops: tuple[PoolOp, ...]) -> int:
    """Max instantaneous live span (segments) — the merged ring length."""
    return max(live_spans(ops))


def slice_group_ops(program: PoolProgram, op_lo: int, op_hi: int,
                    n_slices: int) -> tuple[list[PoolOp], list[int]]:
    """Replace group ``[op_lo, op_hi)``'s conv chain with per-slice runs.

    Returns ``(ops, parents)`` where ``parents[i]`` is the index of the
    op in ``program`` that new op ``i`` descends from (slices map to
    their chain op — the parameter/qparam sharing map).  The returned
    list is NOT finalized: run :func:`finalize` (or let
    :func:`apply_partial` do it) to re-derive the ring length.
    """
    rng = chain_range(program, op_lo, op_hi)
    if isinstance(rng, str):
        raise PartialLowerError(
            f"group ops[{op_lo}:{op_hi}) is not sliceable: {rng}")
    g_lo, hi = rng
    ops = list(program.ops)
    chain = tuple(ops[g_lo:hi])
    L = len(chain)
    steps = chain_steps(chain)
    layout = slice_layout(steps, n_slices)
    if layout is None:
        raise PartialLowerError(
            f"no feasible {n_slices}-slice split of group "
            f"ops[{g_lo}:{hi}) (h_out={steps[-1].h_out}, halos clash "
            "with interior padding)")
    chunks = chain_chunks(program, chain)
    aligned = program.block_rows is not None

    # -- scratch bands stacked below X (addresses descend) ----------------
    x0 = chain[0].in_ptr
    base = x0
    band_base = [0] * L                       # [0] unused (X in place)
    for j in range(1, L):
        size = layout.band_rows[j] * chunks[j][0]
        b = base - size
        if aligned:
            b = _floor_mult(b, chunks[j][0])
        band_base[j] = b
        base = b

    # -- the assembled output record, shifted down with everything after --
    yrow = chunks[-1][1]
    y_tot = steps[-1].h_out * yrow
    y0_orig = chain[-1].out_ptr
    y0_raw = base - y_tot
    if aligned:
        down_align = math.lcm(yrow, *(
            math.lcm(*_blocks(op, program.seg_width, program.block_rows))
            for op in ops[hi:] if op.kind in EXECUTABLE_KINDS))
    else:
        down_align = 1
    dshift = _floor_mult(y0_raw - y0_orig, down_align)
    y0 = y0_orig + dshift

    # X survives the chain for a trailing residual add (the unsliced op
    # held it too); otherwise the last slice frees the whole record.
    free_x = not chain[0].hold_input
    shiftn = n_slices * L - L
    consumer_new = hi + shiftn

    sliced: list[PoolOp] = []
    parents_mid: list[int] = []
    for i, wins in enumerate(layout.windows):
        for j in range(L):
            op, w = chain[j], wins[j]
            in_chunk, out_chunk = chunks[j]
            last = j == L - 1
            in_ptr = x0 if j == 0 else band_base[j]
            out_ptr = (y0 + w.out_lo * yrow) if last else band_base[j + 1]
            sliced.append(dataclasses.replace(
                op,
                in_ptr=in_ptr, out_ptr=out_ptr, delta=in_ptr - out_ptr,
                in_segments=(op.in_segments if j == 0
                             else w.h_in * in_chunk),
                out_segments=w.h_out * out_chunk,
                rows_in=w.h_in * op.w_in, rows_out=w.h_out * op.w_out,
                h_in=w.h_in, h_out=w.h_out, padding=w.padding,
                in_op=(g_lo if (j == 0 and i > 0) else -1),
                hold_input=(j == 0),
                in_row0=(w.in_lo if j == 0 else 0),
                h_src=(op.h_in if j == 0 else 0),
                out_op=(consumer_new if last else -1),
                out_row0=(w.out_lo if last else 0),
                free_src=(j == 0 and i == n_slices - 1 and free_x)))
            parents_mid.append(g_lo + j)

    # -- every op after the chain shifts by the ring savings --------------
    tail: list[PoolOp] = []
    for op in ops[hi:]:
        kw: dict = {"out_ptr": op.out_ptr + dshift}
        if op.in_op == -1 or op.in_op >= hi:
            kw["in_ptr"] = op.in_ptr + dshift
        if op.in_op >= hi:
            kw["in_op"] = op.in_op + shiftn
        if op.aux_op >= hi:
            kw["aux_op"] = op.aux_op + shiftn
            kw["aux_ptr"] = op.aux_ptr + dshift
        if op.out_op >= hi:
            kw["out_op"] = op.out_op + shiftn
        tail.append(dataclasses.replace(op, **kw))

    new_ops = ops[:g_lo] + sliced + tail
    parents = (list(range(g_lo)) + parents_mid
               + list(range(hi, len(ops))))
    return new_ops, parents


def finalize(program: PoolProgram,
             ops: list[PoolOp]) -> PoolProgram:
    """Re-derive the ring from a rewritten op list.

    Shifts every pointer non-negative (preserving block alignment) and
    recomputes ``pool_segments``/``n_segments`` from the live spans —
    ``n_segments`` stays a multiple of every op's DMA blocks so
    ``check_alignment`` holds on the merged program.
    """
    aligned = program.block_rows is not None
    execs = [op for op in ops if op.kind in EXECUTABLE_KINDS]
    align = (math.lcm(*(math.lcm(*_blocks(op, program.seg_width,
                                          program.block_rows))
                        for op in execs)) if aligned and execs else 1)
    base = min(min(op.in_ptr, op.out_ptr) if op.aux_op < 0
               else min(op.in_ptr, op.out_ptr, op.aux_ptr)
               for op in ops)
    if base < 0:
        shift = -_floor_mult(base, align)
        ops = [dataclasses.replace(
            op, in_ptr=op.in_ptr + shift, out_ptr=op.out_ptr + shift,
            aux_ptr=op.aux_ptr + shift if op.aux_op >= 0 else op.aux_ptr)
            for op in ops]
    span = recompute_spans(tuple(ops))
    n = ceil_div(span, align) * align if aligned else span
    out = dataclasses.replace(program, ops=tuple(ops),
                              pool_segments=span, n_segments=n)
    if aligned:
        out.check_alignment()
    return out


def apply_partial(program: PoolProgram,
                  choices: dict[tuple[int, int], int]
                  ) -> tuple[PoolProgram, tuple[int, ...]]:
    """Slice every group in ``choices`` (``{(op_lo, op_hi): n_slices}``,
    ranges over the UNSLICED program) and finalize the merged ring.

    Returns ``(program, parents)`` — ``parents[i]`` maps op ``i`` of the
    sliced program back to its originating op, for parameter/qparam
    sharing and trace attribution.
    """
    parents = list(range(len(program.ops)))
    ops = list(program.ops)
    cur = program
    # descending op order: each surgery only renumbers ops AFTER its
    # group, so earlier (lower) group ranges stay valid throughout
    for (op_lo, op_hi), n in sorted(choices.items(), reverse=True):
        cur = dataclasses.replace(cur, ops=tuple(ops))
        ops, step_parents = slice_group_ops(cur, op_lo, op_hi, n)
        parents = [parents[p] for p in step_parents]
    return finalize(program, ops), tuple(parents)
