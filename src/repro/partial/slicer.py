"""Slice selection — the partial-execution cost model (DESIGN.md §13).

A fusion group whose interior tensors pin the ring can trade latency
for memory Pex/MCUNetV2-style: split the group's OUTPUT spatially into
``n`` row bands and run the producing conv chain once per band.  Each
run reads a halo-extended window of the group input (held in place on
the ring), stages the interior tensors in small per-position scratch
bands, and lands its output band at its final ring offset — boundary
rows of the interior tensors are recomputed by adjacent slices, which
is exactly the extra-MACs-for-bytes trade this module prices.

The module is pure geometry/arithmetic: :func:`chain_steps` extracts a
group's conv-chain geometry, :func:`slice_layout` back-propagates
halo-aware row windows through the chain (the same ``core.rowsched``
k x k frontier conventions the executors run), and :func:`pareto`
enumerates the feasible slice counts as a latency/memory frontier.
The actual ``PoolOp`` surgery lives in :mod:`repro.partial.lower`.
"""
from __future__ import annotations

import dataclasses

from ..core.program import PoolOp, PoolProgram
from ..core.rowsched import conv_k2d_pad
from ..core.vpool import ceil_div, segments_for

#: Conv kinds a slice chain may contain (linear, spatially local ops).
CHAIN_KINDS = ("conv_pw", "conv_dw", "conv_k2d")


@dataclasses.dataclass(frozen=True)
class ChainStep:
    """Vertical geometry of one chain position (one conv op)."""

    kind: str
    k: int                    # kernel extent (1 for pointwise)
    stride: int
    pad: int                  # top halo of the ORIGINAL padding mode
    padding: str              # the original mode ("same"/"valid"/...)
    h_in: int
    h_out: int
    w_in: int
    w_out: int
    d_in: int
    d_out: int

    def in_window(self, oa: int, ob: int) -> tuple[int, int]:
        """Input rows needed for output rows ``[oa, ob)`` (clamped)."""
        lo = max(0, oa * self.stride - self.pad)
        hi = min(self.h_in, (ob - 1) * self.stride - self.pad + self.k)
        return lo, hi

    def local_padding(self, oa: int) -> str | None:
        """Padding mode of a slice starting at output row ``oa``.

        ``None`` marks an infeasible boundary: an interior slice whose
        window would need a PARTIAL top halo (0 < oa*s < pad) — no
        padding mode expresses that, so the slice count is discarded.
        """
        if oa == 0:
            return self.padding
        if oa * self.stride < self.pad:
            return None
        return "valid" if self.padding == "valid" else "same_mid"

    def row_macs(self) -> int:
        """MACs per output row (the recompute-overhead unit)."""
        taps = self.k * self.k
        if self.kind == "conv_pw":
            return self.w_out * self.d_in * self.d_out
        if self.kind == "conv_dw":
            return self.w_out * taps * self.d_out
        return self.w_out * taps * self.d_in * self.d_out


@dataclasses.dataclass(frozen=True)
class SliceWindows:
    """Row windows of ONE slice at ONE chain position."""

    in_lo: int                # input window [in_lo, in_hi) — rows of the
    in_hi: int                # position's input tensor
    out_lo: int               # output band [out_lo, out_hi)
    out_hi: int
    padding: str              # local padding mode of the sliced op

    @property
    def h_in(self) -> int:
        return self.in_hi - self.in_lo

    @property
    def h_out(self) -> int:
        return self.out_hi - self.out_lo


@dataclasses.dataclass(frozen=True)
class SliceLayout:
    """A feasible slicing of one group chain into ``n_slices`` bands.

    ``windows[i][j]`` are slice ``i``'s row windows at chain position
    ``j``; ``band_rows[j]`` (``j >= 1``) is the scratch-band height for
    the interior tensor entering position ``j`` — the max over slices,
    since every slice reuses the same band.
    """

    steps: tuple[ChainStep, ...]
    n_slices: int
    windows: tuple[tuple[SliceWindows, ...], ...]
    band_rows: tuple[int, ...]       # len == len(steps); [0] unused (X)

    @property
    def extra_macs(self) -> int:
        """Recomputed MACs vs the unsliced chain (halo overlap cost)."""
        total = 0
        for j, st in enumerate(self.steps):
            rows = sum(w[j].h_out for w in self.windows)
            total += (rows - st.h_out) * st.row_macs()
        return total

    @property
    def chain_macs(self) -> int:
        return sum(st.h_out * st.row_macs() for st in self.steps)

    @property
    def extra_in_rows(self) -> tuple[int, ...]:
        """Per-position extra INPUT rows read (halo re-reads)."""
        return tuple(sum(w[j].h_in for w in self.windows) - st.h_in
                     for j, st in enumerate(self.steps))


def chain_steps(ops: tuple[PoolOp, ...]) -> tuple[ChainStep, ...]:
    """The vertical geometry of a conv chain (one group, add excluded)."""
    steps = []
    for op in ops:
        k = op.rs if op.kind in ("conv_dw", "conv_k2d") else 1
        pad = conv_k2d_pad(k, op.padding) if k > 1 else 0
        steps.append(ChainStep(
            kind=op.kind, k=k, stride=op.stride, pad=pad,
            padding=op.padding, h_in=op.h_in, h_out=op.h_out,
            w_in=op.w_in, w_out=op.w_out, d_in=op.d_in, d_out=op.d_out))
    return tuple(steps)


def even_bounds(h: int, n: int) -> tuple[int, ...]:
    """``n+1`` monotone band boundaries splitting ``h`` output rows."""
    return tuple(round(i * h / n) for i in range(n + 1))


def slice_layout(steps: tuple[ChainStep, ...],
                 n_slices: int) -> SliceLayout | None:
    """Back-propagate ``n_slices`` even output bands through the chain.

    Returns ``None`` when the split is infeasible: degenerate bands, or
    an interior boundary that would need a partial top halo at some
    position (``0 < oa*s < pad`` — no local padding mode covers it).
    """
    L = len(steps)
    h_last = steps[-1].h_out
    if not 2 <= n_slices <= h_last:
        return None
    bounds = even_bounds(h_last, n_slices)
    if any(bounds[i] >= bounds[i + 1] for i in range(n_slices)):
        return None
    slices = []
    for i in range(n_slices):
        oa, ob = bounds[i], bounds[i + 1]
        wins: list[SliceWindows] = []
        # walk the chain backward: position j's output band is position
        # j+1's input window
        for j in range(L - 1, -1, -1):
            st = steps[j]
            pad_mode = st.local_padding(oa)
            if pad_mode is None:
                return None
            ia, ib = st.in_window(oa, ob)
            wins.append(SliceWindows(ia, ib, oa, ob, pad_mode))
            oa, ob = ia, ib          # becomes position j-1's output band
        slices.append(tuple(reversed(wins)))
    band_rows = tuple(
        0 if j == 0 else max(w[j].h_in for w in slices)
        for j in range(L))
    return SliceLayout(steps=steps, n_slices=n_slices,
                       windows=tuple(slices), band_rows=band_rows)


# ---------------------------------------------------------------------------
# Sliceability + cost over a planned program.
# ---------------------------------------------------------------------------

def chain_range(program: PoolProgram, op_lo: int,
                op_hi: int) -> tuple[int, int] | str:
    """The sliceable conv chain ``[op_lo, hi)`` of group ``[op_lo,
    op_hi)``, or a reason string when the group cannot be sliced.

    A trailing residual ``add`` stays OUTSIDE the chain: it consumes
    the chain output plus the group input (which the slices then hold
    instead of freeing).  First/last groups are excluded — the program
    input is staged (not a ring record the slices could hold), and the
    network output is fetched whole.
    """
    ops = program.ops
    hi = op_hi
    if ops and ops[hi - 1].kind == "add" and hi - 1 > op_lo:
        hi -= 1
    if op_lo == 0:
        return "first group (program input is staged, not held)"
    if op_hi >= len(ops):
        return "last group (network output is fetched whole)"
    if hi - op_lo < 1:
        return "empty chain"
    for i in range(op_lo, hi):
        op = ops[i]
        if op.kind not in CHAIN_KINDS:
            return f"op {i} kind {op.kind!r} is not spatially local"
        if op.resample:
            return f"op {i} resamples (non-local row map)"
        if op.aux_op >= 0:
            return f"op {i} reads a residual source"
        if i > op_lo and (op.in_op >= 0 or op.hold_input):
            return f"op {i} branches off the linear chain"
    if ops[op_lo].in_op >= 0:
        return "group input is a held branch record"
    nxt = ops[hi]
    if nxt.in_op >= 0:
        return f"consumer op {hi} does not read the chain output"
    for i in range(hi, len(ops)):
        op = ops[i]
        for ref in (op.in_op, op.aux_op):
            if op_lo < ref < hi:
                return (f"op {i} holds interior tensor of op {ref} "
                        "across the group")
    return (op_lo, hi)


def chain_chunks(program: PoolProgram,
                 ops: tuple[PoolOp, ...]) -> tuple[tuple[int, int], ...]:
    """Per-position (in, out) row chunks in segments (one image row)."""
    sw = program.seg_width
    return tuple((op.w_in * segments_for(op.d_in, sw),
                  op.w_out * segments_for(op.d_out, sw)) for op in ops)


@dataclasses.dataclass(frozen=True)
class SliceCandidate:
    """One point of a group's latency/memory Pareto frontier."""

    op_lo: int
    op_hi: int                # chain end (residual add excluded)
    n_slices: int
    region_segments: int      # X + scratch bands + Y (tight estimate)
    extra_macs: int
    extra_read_segments: int

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def candidate(program: PoolProgram, op_lo: int, op_hi: int,
              n_slices: int) -> SliceCandidate | None:
    """Cost one (group, n_slices) point; ``None`` if infeasible.

    ``(op_lo, op_hi)`` may be the full GROUP range — the sliceable
    chain (trailing residual ``add`` excluded) is resolved here."""
    rng = chain_range(program, op_lo, op_hi)
    if isinstance(rng, str):
        return None
    op_lo, op_hi = rng
    ops = program.ops[op_lo:op_hi]
    steps = chain_steps(ops)
    layout = slice_layout(steps, n_slices)
    if layout is None:
        return None
    chunks = chain_chunks(program, ops)
    x_tot = steps[0].h_in * chunks[0][0]
    y_tot = steps[-1].h_out * chunks[-1][1]
    scratch = sum(layout.band_rows[j] * chunks[j][0]
                  for j in range(1, len(ops)))
    extra_reads = sum(r * chunks[j][0]
                      for j, r in enumerate(layout.extra_in_rows))
    return SliceCandidate(
        op_lo=op_lo, op_hi=op_hi, n_slices=n_slices,
        region_segments=x_tot + scratch + y_tot,
        extra_macs=layout.extra_macs,
        extra_read_segments=extra_reads)


def pareto(program: PoolProgram, op_lo: int, op_hi: int, *,
           max_slices: int | None = None) -> list[SliceCandidate]:
    """The group's feasible latency/memory frontier, by slice count.

    Dominated points (more slices AND no memory gain) are dropped —
    what remains is monotone: region shrinks as recompute grows.
    Accepts group or chain ranges (see :func:`candidate`).
    """
    rng = chain_range(program, op_lo, op_hi)
    if isinstance(rng, str):
        return []
    op_lo, op_hi = rng
    ops = program.ops[op_lo:op_hi]
    h_last = ops[-1].h_out
    cap = min(max_slices or h_last, h_last)
    frontier: list[SliceCandidate] = []
    best = None
    for n in range(2, cap + 1):
        c = candidate(program, op_lo, op_hi, n)
        if c is None:
            continue
        if best is None or c.region_segments < best:
            frontier.append(c)
            best = c.region_segments
    return frontier


def op_macs(op: PoolOp) -> int:
    """Whole-op MAC count (conv vocabulary; 0 for add/pool/plan-only)."""
    if op.kind in CHAIN_KINDS:
        k = op.rs if op.kind in ("conv_dw", "conv_k2d") else 1
        taps = k * k
        per_row = {"conv_pw": op.w_out * op.d_in * op.d_out,
                   "conv_dw": op.w_out * taps * op.d_out,
                   "conv_k2d": op.w_out * taps * op.d_in * op.d_out}
        return op.h_out * per_row[op.kind]
    if op.kind == "gemm":
        return (op.rows_in or 1) * op.d_in * op.d_out
    return 0


def program_macs(program: PoolProgram) -> int:
    return sum(op_macs(op) for op in program.ops)


def estimate_slices(program: PoolProgram, groups, sram_segments: int,
                    *, max_slices: int | None = None) -> int | None:
    """Cheapest total slice estimate that could bring every over-budget
    group region under ``sram_segments`` — the VMCU303 advisory number.

    ``groups`` is an iterable of ``(op_lo, op_hi)`` group ranges.
    Returns ``None`` when some pinning group cannot be sliced under the
    budget (partial execution cannot resolve the overflow).
    """
    total = 0
    for op_lo, op_hi in groups:
        span = max(op.span_segments
                   for op in program.ops[op_lo:op_hi])
        if span <= sram_segments:
            continue
        rng = chain_range(program, op_lo, op_hi)
        if isinstance(rng, str):
            return None
        lo, hi = rng
        fit = [c for c in pareto(program, lo, hi, max_slices=max_slices)
               if c.region_segments <= sram_segments]
        if not fit:
            return None
        total += min(fit, key=lambda c: c.n_slices).n_slices
    return total or None
