"""Partial execution on the segment ring (DESIGN.md §13).

When a fusion group's footprint overflows the target SRAM, this
subsystem turns the hard :class:`repro.SRAMBudgetError` into a
scheduled latency/memory trade: split the group's output spatially and
re-run the producing conv chain once per slice, recomputing the halo
rows adjacent slices share (Pex / MCUNetV2 patch-based inference).

  * :mod:`repro.partial.slicer` — halo-aware window propagation and
    the recompute-MACs-vs-bytes-saved cost model (Pareto frontier),
  * :mod:`repro.partial.lower` — the ``PoolOp`` surgery producing ONE
    merged, verifier-coverable program,
  * :func:`plan_partial` — the driver-facing policy: greedily slice
    whichever group pins the ring, walking each group's frontier until
    the whole net fits (``partial="auto"``) or a fixed slice count is
    forced on the pinning group (``partial=N``).
"""
from __future__ import annotations

import dataclasses

from ..core.program import PoolProgram
from .lower import (PartialLowerError, apply_partial, finalize,
                    live_spans, recompute_spans, slice_group_ops)
from .slicer import (SliceCandidate, candidate, chain_range, chain_steps,
                     estimate_slices, op_macs, pareto, program_macs,
                     slice_layout)


class PartialPlanError(PartialLowerError):
    """No slicing of the sliceable groups brings the net under budget."""


@dataclasses.dataclass(frozen=True)
class PartialPlan:
    """A chosen slicing: the sliced program + its cost accounting."""

    program: PoolProgram              # sliced + finalized
    parents: tuple[int, ...]          # sliced op -> unsliced op index
    choices: dict                     # {(op_lo, op_hi): n_slices}
    groups: tuple[dict, ...]          # per-group cost rows
    ring_bytes_before: int
    ring_bytes_after: int
    net_macs: int

    @property
    def extra_macs(self) -> int:
        return sum(g["extra_macs"] for g in self.groups)

    @property
    def extra_read_segments(self) -> int:
        return sum(g["extra_read_segments"] for g in self.groups)

    @property
    def mac_overhead(self) -> float:
        return self.extra_macs / self.net_macs if self.net_macs else 0.0

    def summary(self) -> dict:
        """JSON-safe accounting for reports/artifacts/benchmarks."""
        return {
            "n_sliced_groups": len(self.groups),
            "total_slices": sum(g["n_slices"] for g in self.groups),
            "ring_bytes_before": self.ring_bytes_before,
            "ring_bytes_after": self.ring_bytes_after,
            "extra_macs": self.extra_macs,
            "mac_overhead": self.mac_overhead,
            "extra_read_segments": self.extra_read_segments,
            "groups": list(self.groups),
        }


def _pinning_range(spans, parents, ranges):
    """The group range containing the op that pins the current ring."""
    i = max(range(len(spans)), key=spans.__getitem__)
    parent = parents[i]
    for lo, hi in ranges:
        if lo <= parent < hi:
            return (lo, hi)
    return None


def plan_partial(program: PoolProgram, group_ranges, sram_bytes: int, *,
                 force: int | None = None,
                 max_slices: int | None = None) -> PartialPlan | None:
    """Choose and lower a slicing that fits ``program`` in ``sram_bytes``.

    ``group_ranges`` are ``(op_lo, op_hi)`` fusion-group spans of the
    unsliced program (``NetPlan.groups``).  Auto mode (``force=None``):
    repeatedly find the op pinning the ring, walk its group one step
    further along the slice-count Pareto frontier, stop when the ring
    fits; returns ``None`` when the program already fits and raises
    :class:`PartialPlanError` when no slicing can fit.  ``force=N``
    slices the pinning group with exactly ``N`` slices, fit or not.
    """
    seg_bytes = program.seg_width * program.elem_bytes
    ranges = [tuple(r) for r in group_ranges]
    choices: dict[tuple[int, int], int] = {}

    if force is not None:
        # most-pinning SLICEABLE group first (the op pinning the ring
        # may sit in the unsliceable first/last group)
        spans = live_spans(program.ops)
        by_span = sorted(ranges, key=lambda r: -max(spans[r[0]:r[1]]))
        c = rng = None
        for rng in by_span:
            c = candidate(program, rng[0], rng[1], force)
            if c is not None:
                break
        if c is None:
            chk = chain_range(program, by_span[0][0], by_span[0][1])
            why = chk if isinstance(chk, str) else "halo-infeasible split"
            raise PartialPlanError(
                f"cannot slice any group into {force} slices; pinning "
                f"group ops[{by_span[0][0]}:{by_span[0][1]}): {why}")
        choices[rng] = force
    else:
        if program.pool_bytes <= sram_bytes:
            return None
        frontiers: dict[tuple[int, int], list[SliceCandidate]] = {}
        while True:
            sliced_prog, parents = apply_partial(program, choices)
            if sliced_prog.pool_bytes <= sram_bytes:
                break
            spans = live_spans(sliced_prog.ops)
            rng = _pinning_range(spans, parents, ranges)
            ring = sliced_prog.pool_bytes
            if rng is None:
                raise PartialPlanError(
                    f"ring {ring} B > {sram_bytes} B SRAM is pinned "
                    "outside every fusion group")
            if rng not in frontiers:
                chk = chain_range(program, rng[0], rng[1])
                frontiers[rng] = ([] if isinstance(chk, str) else
                                  pareto(program, rng[0], rng[1],
                                         max_slices=max_slices))
            cur_n = choices.get(rng, 1)
            nxt = next((c for c in frontiers[rng] if c.n_slices > cur_n),
                       None)
            if nxt is None:
                chk = chain_range(program, rng[0], rng[1])
                why = (chk if isinstance(chk, str)
                       else "its slice frontier is exhausted")
                raise PartialPlanError(
                    f"ring {ring} B > {sram_bytes} B SRAM: pinned by "
                    f"group ops[{rng[0]}:{rng[1]}) and {why}")
            choices[rng] = nxt.n_slices

    sliced_prog, parents = apply_partial(program, choices)
    rows = []
    for (lo, hi), n in sorted(choices.items()):
        c = candidate(program, lo, hi, n)
        rows.append({"op_lo": lo, "op_hi": hi, "n_slices": n,
                     "region_segments": c.region_segments,
                     "region_bytes": c.region_segments * seg_bytes,
                     "extra_macs": c.extra_macs,
                     "extra_read_segments": c.extra_read_segments})
    return PartialPlan(
        program=sliced_prog, parents=parents, choices=dict(choices),
        groups=tuple(rows),
        ring_bytes_before=program.pool_bytes,
        ring_bytes_after=sliced_prog.pool_bytes,
        net_macs=program_macs(program))


__all__ = ["PartialLowerError", "PartialPlan", "PartialPlanError",
           "SliceCandidate", "apply_partial", "candidate", "chain_range",
           "chain_steps", "estimate_slices", "finalize", "live_spans",
           "op_macs", "pareto", "plan_partial", "program_macs",
           "recompute_spans", "slice_group_ops", "slice_layout"]
