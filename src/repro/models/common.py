"""Shared layers: norms, RoPE, attention (full / sliding / cross / decode),
dense MLPs.  Pure JAX, mesh-agnostic (sharding via AxisRules callbacks).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.sharding import AxisRules

NEG_INF = -2.3819763e38  # large negative for masking (bf16-safe)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


def init_norm(cfg: ModelConfig, d: int | None = None) -> dict:
    d = d or cfg.d_model
    p = {"scale": jnp.zeros((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: [..., S] (broadcastable)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # [..., S, half]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention (training / prefill): chunked-query softmax attention.
# --------------------------------------------------------------------------

def _softcap(s: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return s
    return jnp.tanh(s / cap) * cap


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """GQA: repeat KV heads to the full head count.  Keeping the head axis
    FLAT (no [KV, group] reshape) lets GSPMD carry the head sharding through
    every einsum — with kv-heads sharded the repeat stays shard-local, with
    kv replicated only the q heads shard (Megatron GQA)."""
    group = n_heads // k.shape[2]
    return jnp.repeat(k, group, axis=2) if group > 1 else k


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool, window: int | None,
              softcap: float | None, q_offset: int = 0,
              chunk: int = 2048, bf16_einsum: bool = False) -> jax.Array:
    """q: [B,Sq,H,D]; k/v: [B,Skv,KV,D] (GQA).  Query-chunked so the score
    matrix never exceeds [B,H,chunk,Skv] — XLA keeps one chunk live.

    ``bf16_einsum`` (§Perf): feed the MXU bf16 operands with fp32
    accumulation (preferred_element_type) instead of materializing fp32
    copies of K/V — XLA otherwise places the seq all-gather AFTER the
    upcast, doubling collective and HBM bytes.
    """
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    qs = q * (D ** -0.5)

    def chunk_attn(qc: jax.Array, cstart) -> jax.Array:
        if bf16_einsum:
            # bf16 score pipeline with fp32 reductions: the [B,H,chunk,S]
            # score matrix — the largest recurring HBM tensor in training —
            # stays bf16 end-to-end; max/sum accumulate fp32.  Halves the
            # dominant memory-roofline term (§Perf A3).
            s = jnp.einsum("bqhd,bshd->bhqs", qc, k,
                           preferred_element_type=jnp.float32
                           ).astype(q.dtype)
        else:
            s = jnp.einsum("bqhd,bshd->bhqs", qc.astype(jnp.float32),
                           k.astype(jnp.float32))
        s = _softcap(s, softcap)
        qpos = (cstart + q_offset
                + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2))
        kpos = jax.lax.broadcasted_iota(jnp.int32, s.shape, 3)
        mask = jnp.ones_like(s, dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF if s.dtype == jnp.float32
                      else jnp.finfo(s.dtype).min)
        if bf16_einsum:
            m = jnp.max(s.astype(jnp.float32), axis=-1, keepdims=True)
            p = jnp.exp(s - m.astype(s.dtype))          # bf16, max-shifted
            l = jnp.sum(p, axis=-1, keepdims=True,
                        dtype=jnp.float32)              # fp32 accumulation
            p = (p / l.astype(s.dtype))
            return jnp.einsum("bhqs,bshd->bqhd", p, v,
                              preferred_element_type=jnp.float32
                              ).astype(q.dtype)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqs,bshd->bqhd", p,
                          v.astype(jnp.float32)).astype(q.dtype)

    if Sq <= chunk:
        return chunk_attn(qs, 0)
    n = -(-Sq // chunk)
    pad = n * chunk - Sq
    qp = jnp.pad(qs, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qp = qp.reshape(B, n, chunk, H, D).transpose(1, 0, 2, 3, 4)
    outs = jax.lax.map(
        lambda args: chunk_attn(args[0], args[1] * chunk),
        (qp, jnp.arange(n)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, n * chunk, H, D)
    return out[:, :Sq]


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     cur_len: jax.Array, *, softcap: float | None,
                     ring: bool = False, window: int = 0) -> jax.Array:
    """Single-step decode.  q: [B,1,H,D]; k/v: [B,S,KV,D] (S = max seq or
    ring window).  ``cur_len``: tokens so far *including* the current one.
    For ``ring`` caches, slot validity is the vMCU boundary check.

    GROUPED einsums, no KV expansion: decode caches are sharded on the
    sequence (or kv-head) axis, which the grouped contraction preserves;
    expanding KV to H heads would multiply cache-sized temporaries by the
    GQA group (8x for llama-90b — §Perf global improvement)."""
    B, _, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    qf = (q.astype(jnp.float32) * D ** -0.5).reshape(B, KV, G, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k.astype(jnp.float32))
    s = _softcap(s, softcap)
    slot = jax.lax.broadcasted_iota(jnp.int32, s.shape, 3)
    if ring:
        valid = (slot < cur_len) | (cur_len >= window)
    else:
        valid = slot < cur_len
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, 1, H, D).astype(q.dtype)


# --------------------------------------------------------------------------
# Attention block params + forward
# --------------------------------------------------------------------------

def init_attn(key: jax.Array, cfg: ModelConfig, *, cross: bool = False
              ) -> dict:
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "ln": init_norm(cfg),
        "w_q": jax.random.normal(k1, (d, qd), jnp.float32) * s,
        "w_k": jax.random.normal(k2, (d, kvd), jnp.float32) * s,
        "w_v": jax.random.normal(k3, (d, kvd), jnp.float32) * s,
        "w_o": jax.random.normal(k4, (qd, d), jnp.float32) * s,
    }
    if cfg.post_norms:
        p["post_ln"] = init_norm(cfg)
    return p


class KVCache(NamedTuple):
    k: jax.Array   # [B, S_or_window, KV, D]
    v: jax.Array


def project_qkv(p: dict, x: jax.Array, cfg: ModelConfig, rules: AxisRules,
                positions: jax.Array, *, rope_q: bool = True,
                rope_k: bool = True):
    B, S, _ = x.shape
    dt = x.dtype
    q = (x @ p["w_q"].astype(dt)).reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = (x @ p["w_k"].astype(dt)).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ p["w_v"].astype(dt)).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    if rope_q:
        q = rope(q, positions, cfg.rope_theta)
    if rope_k:
        k = rope(k, positions, cfg.rope_theta)
    q = rules.act(q, "batch", "seq", "heads", None)
    # K/V replicated over seq shards (explicit all-gather point in fsdp_sp)
    k = rules.act(k, "batch", None, "kv_heads", None)
    v = rules.act(v, "batch", None, "kv_heads", None)
    return q, k, v


# --------------------------------------------------------------------------
# Dense MLPs
# --------------------------------------------------------------------------

def init_mlp(key: jax.Array, cfg: ModelConfig, d_ff: int | None = None
             ) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {
        "ln": init_norm(cfg),
        "w_up": jax.random.normal(k2, (d, f), jnp.float32) * s_in,
        "w_down": jax.random.normal(k3, (f, d), jnp.float32) * s_out,
    }
    if cfg.mlp in ("geglu", "swiglu"):
        p["w_gate"] = jax.random.normal(k1, (d, f), jnp.float32) * s_in
    if cfg.post_norms:
        p["post_ln"] = init_norm(cfg)
    return p


def mlp_forward(p: dict, x: jax.Array, cfg: ModelConfig, rules: AxisRules
                ) -> jax.Array:
    dt = x.dtype
    h = apply_norm(p["ln"], x, cfg)
    up = h @ p["w_up"].astype(dt)
    up = rules.act(up, "batch", "seq", "ff")
    if cfg.mlp == "geglu":
        g = h @ p["w_gate"].astype(dt)
        up = jax.nn.gelu(g) * up
    elif cfg.mlp == "swiglu":
        g = h @ p["w_gate"].astype(dt)
        up = jax.nn.silu(g) * up
    else:
        up = jax.nn.gelu(up)
    out = up @ p["w_down"].astype(dt)
    out = rules.act(out, "batch", "res_seq", None)
    if cfg.post_norms:
        out = apply_norm(p["post_ln"], out, cfg)
    return out
