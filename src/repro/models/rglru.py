"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Recurrence: ``h_t = a_t ⊙ h_{t-1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x_t)`` with
``a_t = exp(−c · softplus(Λ) · σ(r_t))``.  Full-sequence forward uses an
associative scan (log-depth — the parallel form used for training); decode
is the O(1)-state step.  Gates are diagonal (per-channel), a documented
simplification of Griffin's block-diagonal gate matrices (DESIGN.md).

Like the Mamba state, the LRU hidden state is a one-segment vMCU ring.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.sharding import AxisRules
from .common import apply_norm, init_norm

_C = 8.0  # Griffin's fixed temperature


class LRUCache(NamedTuple):
    h: jax.Array       # [B, W]
    conv: jax.Array    # [B, K-1, W]


def init_rec(key: jax.Array, cfg: ModelConfig) -> dict:
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    return {
        "ln": init_norm(cfg),
        "lru_w_y": jax.random.normal(ks[0], (d, w), jnp.float32) * s,  # gate
        "lru_w_x": jax.random.normal(ks[1], (d, w), jnp.float32) * s,  # main
        "lru_conv": jax.random.normal(ks[2], (cfg.ssm_conv, w),
                                      jnp.float32) * 0.1,
        "lru_lambda": jax.random.uniform(ks[3], (w,), jnp.float32,
                                         0.9, 0.999),
        "lru_gate_a": jax.random.normal(ks[4], (w,), jnp.float32) * 0.1,
        "lru_gate_i": jax.random.normal(ks[5], (w,), jnp.float32) * 0.1,
        "lru_out": jax.random.normal(jax.random.fold_in(key, 7), (w, d),
                                     jnp.float32) / math.sqrt(w),
    }


def _gates(p: dict, x: jax.Array):
    """a_t, gated input — x: [..., W] fp32."""
    log_lam = jax.nn.softplus(8.0 * p["lru_lambda"])
    r = jax.nn.sigmoid(x * p["lru_gate_a"])
    i = jax.nn.sigmoid(x * p["lru_gate_i"])
    log_a = -_C * log_lam * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    return a, beta * i * x


def _assoc_scan(a: jax.Array, bx: jax.Array, h0: jax.Array | None):
    """h_t = a_t h_{t-1} + bx_t via associative scan over axis 1."""
    if h0 is not None:
        bx = bx.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h


def rec_forward(p: dict, x: jax.Array, cfg: ModelConfig, rules: AxisRules,
                cache: LRUCache | None = None, *,
                return_cache: bool = False):
    """x: [B,S,d] → mixed output (pre-residual)."""
    B, S, d = x.shape
    dt = x.dtype
    h = apply_norm(p["ln"], x, cfg)
    y_gate = jax.nn.gelu(h @ p["lru_w_y"].astype(dt))
    xs = h @ p["lru_w_x"].astype(dt)
    # causal depthwise conv1d
    K = p["lru_conv"].shape[0]
    pad = (jnp.zeros_like(xs[:, : K - 1]) if cache is None
           else cache.conv.astype(dt))
    full = jnp.concatenate([pad, xs], axis=1)
    xs = sum(full[:, i:i + S] * p["lru_conv"][i].astype(dt) for i in range(K))
    xs = rules.act(xs, "batch", "seq", "tp")

    a, bx = _gates(p, xs.astype(jnp.float32))
    h0 = None if cache is None else cache.h
    hseq = _assoc_scan(a, bx, h0)
    out = (hseq.astype(dt) * y_gate) @ p["lru_out"].astype(dt)
    out = rules.act(out, "batch", "res_seq", None)
    if not return_cache:
        return out, None
    return out, LRUCache(h=hseq[:, -1], conv=full[:, -(K - 1):])


def rec_step(p: dict, x: jax.Array, cfg: ModelConfig, rules: AxisRules,
             cache: LRUCache):
    B, _, d = x.shape
    dt = x.dtype
    h = apply_norm(p["ln"], x, cfg)[:, 0]
    y_gate = jax.nn.gelu(h @ p["lru_w_y"].astype(dt))
    xs = h @ p["lru_w_x"].astype(dt)
    K = p["lru_conv"].shape[0]
    full = jnp.concatenate([cache.conv.astype(dt), xs[:, None]], axis=1)
    xs = jnp.einsum("bkw,kw->bw", full, p["lru_conv"].astype(dt))
    a, bx = _gates(p, xs.astype(jnp.float32))
    h_new = a * cache.h + bx
    out = ((h_new.astype(dt) * y_gate) @ p["lru_out"].astype(dt))[:, None]
    return out, LRUCache(h=h_new, conv=full[:, 1:])


def init_rec_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16
                   ) -> LRUCache:
    w = cfg.lru_width or cfg.d_model
    return LRUCache(h=jnp.zeros((batch, w), jnp.float32),
                    conv=jnp.zeros((batch, cfg.ssm_conv - 1, w), dtype))
