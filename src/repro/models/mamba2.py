"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060).

Chunked SSD: sequence is split into chunks; within a chunk the quadratic
(attention-like) form runs on the MXU, across chunks a small state
[H, P, N] recurrence is scanned — the asymptotically-linear part.  The
recurrent state is the ultimate vMCU ring: O(1) segments regardless of
context length (why mamba2 runs the long_500k cell).

Single-token ``step`` drives decode.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.sharding import AxisRules
from .common import apply_norm, init_norm, rmsnorm


class SSMCache(NamedTuple):
    state: jax.Array       # [B, H, P, N]
    conv: jax.Array        # [B, K-1, conv_dim]


def init_ssm(key: jax.Array, cfg: ModelConfig) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    G, N, H = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 8)
    s = 1.0 / math.sqrt(d)
    conv_dim = di + 2 * G * N
    return {
        "ln": init_norm(cfg),
        "ssm_w_z": jax.random.normal(ks[0], (d, di), jnp.float32) * s,
        "ssm_w_x": jax.random.normal(ks[1], (d, di), jnp.float32) * s,
        "ssm_w_b": jax.random.normal(ks[2], (d, G * N), jnp.float32) * s,
        "ssm_w_c": jax.random.normal(ks[3], (d, G * N), jnp.float32) * s,
        "ssm_w_dt": jax.random.normal(ks[4], (d, H), jnp.float32) * s,
        "ssm_conv": jax.random.normal(ks[5], (cfg.ssm_conv, conv_dim),
                                      jnp.float32) * 0.1,
        "ssm_a_log": jnp.zeros((H,), jnp.float32),
        "ssm_dt_bias": jnp.zeros((H,), jnp.float32),
        "ssm_d": jnp.ones((H,), jnp.float32),
        "ssm_norm": jnp.zeros((di,), jnp.float32),
        "ssm_out": jax.random.normal(ks[6], (di, d), jnp.float32)
        / math.sqrt(di),
    }


def _causal_conv(seq: jax.Array, w: jax.Array, state: jax.Array | None):
    """Depthwise causal conv1d.  seq: [B,S,C]; w: [K,C]; state: [B,K-1,C]."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros_like(seq[:, : K - 1])
    else:
        pad = state.astype(seq.dtype)
    full = jnp.concatenate([pad, seq], axis=1)
    out = sum(full[:, i:i + seq.shape[1]] * w[i].astype(seq.dtype)
              for i in range(K))
    return jax.nn.silu(out), full[:, -(K - 1):]


def _ssd_chunked(x, dt, A, B_, C, chunk: int):
    """Chunked SSD scan.  x: [B,S,H,P]; dt: [B,S,H]; A: [H];
    B_/C: [B,S,G,N].  Returns y [B,S,H,P]."""
    Bb, S, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    nc = S // chunk
    rep = H // G
    xc = x.reshape(Bb, nc, chunk, H, P)
    dtc = dt.reshape(Bb, nc, chunk, H)
    Bc = jnp.repeat(B_.reshape(Bb, nc, chunk, G, N), rep, axis=3)
    Cc = jnp.repeat(C.reshape(Bb, nc, chunk, G, N), rep, axis=3)

    dA = dtc * (-jnp.exp(A))[None, None, None, :]          # [B,nc,c,H] (<0)
    seg = jnp.cumsum(dA, axis=2)                           # within-chunk sums
    total = seg[:, :, -1]                                  # [B,nc,H]

    # --- intra-chunk (quadratic within chunk) ---------------------------------
    li = seg[:, :, :, None, :] - seg[:, :, None, :, :]     # [B,nc,ci,cj,H]
    mask = jax.lax.broadcasted_iota(jnp.int32, li.shape, 2) >= \
        jax.lax.broadcasted_iota(jnp.int32, li.shape, 3)
    decay = jnp.where(mask, jnp.exp(li), 0.0)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Cc, Bc) * decay
    y_intra = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", scores, dtc, xc)

    # --- chunk states + inter-chunk recurrence --------------------------------
    decay_in = jnp.exp(total[:, :, None, :] - seg)         # [B,nc,c,H]
    chunk_state = jnp.einsum("bcjhn,bcjh,bcjh,bcjhp->bchpn",
                             Bc, decay_in, dtc, xc)

    def scan_fn(carry, inp):
        st_prev = carry
        tot, cs = inp
        st = st_prev * jnp.exp(tot)[..., None, None] + cs
        return st, st_prev

    init = jnp.zeros((Bb, H, P, N), x.dtype)
    _, prev_states = jax.lax.scan(
        scan_fn, init,
        (total.transpose(1, 0, 2), chunk_state.transpose(1, 0, 2, 3, 4)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)     # [B,nc,H,P,N]

    decay_out = jnp.exp(seg)                               # [B,nc,c,H]
    y_inter = jnp.einsum("bcihn,bcih,bchpn->bcihp",
                         Cc, decay_out, prev_states)
    y = (y_intra + y_inter).reshape(Bb, S, H, P)
    return y


def ssm_forward(p: dict, x: jax.Array, cfg: ModelConfig, rules: AxisRules,
                cache: SSMCache | None = None, *, return_cache: bool = False):
    """Full-sequence forward (train / prefill)."""
    B, S, d = x.shape
    dt_ = x.dtype
    di, G, N, H, P = (cfg.d_inner, cfg.ssm_groups, cfg.ssm_state,
                      cfg.ssm_heads, cfg.ssm_head_dim)
    h = apply_norm(p["ln"], x, cfg)
    z = h @ p["ssm_w_z"].astype(dt_)
    xs = h @ p["ssm_w_x"].astype(dt_)
    Bp = h @ p["ssm_w_b"].astype(dt_)
    Cp = h @ p["ssm_w_c"].astype(dt_)
    dt = jax.nn.softplus((h @ p["ssm_w_dt"].astype(dt_)).astype(jnp.float32)
                         + p["ssm_dt_bias"])
    conv_in = jnp.concatenate([xs, Bp, Cp], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, p["ssm_conv"], None)
    xs, Bp, Cp = jnp.split(conv_out, [di, di + G * N], axis=-1)
    xs = rules.act(xs.reshape(B, S, H, P), "batch", "seq", "heads", None)
    Bp = Bp.reshape(B, S, G, N).astype(jnp.float32)
    Cp = Cp.reshape(B, S, G, N).astype(jnp.float32)

    chunk = min(cfg.ssm_chunk, S)
    pad = (-S) % chunk
    if pad:  # zero-dt padding is a no-op on the state recurrence
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bp = jnp.pad(Bp, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cp = jnp.pad(Cp, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    y = _ssd_chunked(xs.astype(jnp.float32), dt, p["ssm_a_log"], Bp, Cp,
                     chunk)
    if pad:
        y, xs, Bp, dt = (a[:, :S] for a in (y, xs, Bp, dt))
    y = y + xs.astype(jnp.float32) * p["ssm_d"][None, None, :, None]
    y = y.reshape(B, S, di).astype(dt_)
    y = rmsnorm(y * jax.nn.silu(z), p["ssm_norm"])
    out = y @ p["ssm_out"].astype(dt_)
    out = rules.act(out, "batch", "res_seq", None)
    if not return_cache:
        return out, None
    # final state for decode handoff
    dA = dt * (-jnp.exp(p["ssm_a_log"]))[None, None]
    seg = jnp.cumsum(dA, axis=1)
    decay_in = jnp.exp(seg[:, -1:, :] - seg)
    state = jnp.einsum("bshn,bsh,bsh,bshp->bhpn",
                       jnp.repeat(Bp, H // G, axis=2), decay_in, dt,
                       xs.astype(jnp.float32))
    return out, SSMCache(state=state.astype(jnp.float32),
                         conv=conv_state.astype(dt_))


def ssm_step(p: dict, x: jax.Array, cfg: ModelConfig, rules: AxisRules,
             cache: SSMCache):
    """One decode token.  x: [B,1,d]."""
    B, _, d = x.shape
    dt_ = x.dtype
    di, G, N, H, P = (cfg.d_inner, cfg.ssm_groups, cfg.ssm_state,
                      cfg.ssm_heads, cfg.ssm_head_dim)
    h = apply_norm(p["ln"], x, cfg)[:, 0]
    z = h @ p["ssm_w_z"].astype(dt_)
    xs = h @ p["ssm_w_x"].astype(dt_)
    Bp = h @ p["ssm_w_b"].astype(dt_)
    Cp = h @ p["ssm_w_c"].astype(dt_)
    dt = jax.nn.softplus((h @ p["ssm_w_dt"].astype(dt_)).astype(jnp.float32)
                         + p["ssm_dt_bias"])                     # [B,H]
    conv_in = jnp.concatenate([xs, Bp, Cp], axis=-1)             # [B,C]
    K = cfg.ssm_conv
    full = jnp.concatenate([cache.conv.astype(dt_), conv_in[:, None]], 1)
    w = p["ssm_conv"].astype(dt_)
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", full, w))
    new_conv = full[:, 1:]
    xs, Bp, Cp = jnp.split(conv_out, [di, di + G * N], axis=-1)
    xs = xs.reshape(B, H, P).astype(jnp.float32)
    Bp = jnp.repeat(Bp.reshape(B, G, N), H // G, axis=1).astype(jnp.float32)
    Cp = jnp.repeat(Cp.reshape(B, G, N), H // G, axis=1).astype(jnp.float32)
    dA = jnp.exp(dt * (-jnp.exp(p["ssm_a_log"]))[None])          # [B,H]
    state = (cache.state * dA[..., None, None]
             + jnp.einsum("bhn,bh,bhp->bhpn", Bp, dt, xs))
    y = jnp.einsum("bhn,bhpn->bhp", Cp, state)
    y = y + xs * p["ssm_d"][None, :, None]
    y = y.reshape(B, di).astype(dt_)
    y = rmsnorm(y * jax.nn.silu(z), p["ssm_norm"])
    out = (y @ p["ssm_out"].astype(dt_))[:, None]
    return out, SSMCache(state=state, conv=new_conv)


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16
                   ) -> SSMCache:
    conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return SSMCache(
        state=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                         cfg.ssm_state), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    )
