"""Model zoo: one scan-over-groups engine (transformer.py) + family blocks."""
from .transformer import Model, init_block, block_forward, block_step
