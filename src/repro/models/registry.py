"""Config name → Model facade."""
from ..configs import get_config
from .transformer import Model


def build_model(name_or_cfg) -> Model:
    cfg = (name_or_cfg if not isinstance(name_or_cfg, str)
           else get_config(name_or_cfg))
    return Model(cfg)
