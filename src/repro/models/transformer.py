"""Unified model: decoder LM / MoE / hybrid / SSM / enc-dec / VLM.

One scan-over-layer-groups engine serves all ten assigned architectures:
``cfg.pattern`` names the repeating block kinds; full groups run under
``jax.lax.scan`` (keeps HLO size depth-independent — critical for the
100-layer × 512-device dry-run) and remainder layers run unrolled.

Modes:
  * ``forward``      full-sequence (training / encoder)
  * ``prefill``      full-sequence + materialize KV/state caches
  * ``decode_step``  one token against the caches

Cache kinds: full attention → [B,S,KV,D] KV; sliding window → vMCU ring
KV (window slots, modular write pointer); rec/ssm → O(1) state (the
degenerate one-segment ring).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.sharding import AxisRules, no_sharding
from .common import (KVCache, apply_norm, attention, decode_attention,
                     init_attn, init_mlp, init_norm, mlp_forward,
                     project_qkv, rope, _softcap)
from .mamba2 import (SSMCache, init_ssm, init_ssm_cache, ssm_forward,
                     ssm_step)
from .moe import init_moe, moe_forward
from .rglru import (LRUCache, init_rec, init_rec_cache, rec_forward,
                    rec_step)

ATTN_KINDS = ("full", "local", "global", "cross")


class CrossCache(NamedTuple):
    self_kv: KVCache
    mem_k: jax.Array    # [B, S_mem, KV, D]
    mem_v: jax.Array


# --------------------------------------------------------------------------
# Block init
# --------------------------------------------------------------------------

def _ffn_init(key: jax.Array, cfg: ModelConfig, *, dense_ff: int | None = None
              ) -> dict | None:
    if cfg.d_ff == 0:
        return None
    if cfg.n_experts and dense_ff is None:
        return init_moe(key, cfg)
    return init_mlp(key, cfg, d_ff=dense_ff)


def init_block(key: jax.Array, cfg: ModelConfig, kind: str, *,
               dense_ff: int | None = None) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("full", "local", "global"):
        p = {"attn": init_attn(k1, cfg)}
    elif kind == "cross":
        p = {"attn": init_attn(k1, cfg),
             "xattn": init_attn(k3, cfg)}
    elif kind == "rec":
        p = {"rec": init_rec(k1, cfg)}
    elif kind == "ssm":
        p = {"ssm": init_ssm(k1, cfg)}
    else:
        raise ValueError(kind)
    ffn = _ffn_init(k2, cfg, dense_ff=dense_ff)
    if ffn is not None:
        p["ffn"] = ffn
    return p


# --------------------------------------------------------------------------
# Block forward (full sequence) and step (decode)
# --------------------------------------------------------------------------

def _attn_sub(p: dict, x: jax.Array, cfg: ModelConfig, rules: AxisRules,
              kind: str, positions: jax.Array, *, memory=None,
              make_cache: bool = False, cache_len: int = 0):
    """Self (or cross) attention sub-layer, full sequence."""
    B, S, _ = x.shape
    h = apply_norm(p["ln"], x, cfg)
    q, k, v = project_qkv(p, h, cfg, rules, positions)
    window = cfg.window if kind == "local" else None
    o = attention(q, k, v, causal=True, window=window,
                  softcap=cfg.attn_softcap, bf16_einsum=cfg.bf16_einsum)
    o = o.reshape(B, S, cfg.q_dim) @ p["w_o"].astype(x.dtype)
    o = rules.act(o, "batch", "res_seq", None)
    if cfg.post_norms:
        o = apply_norm(p["post_ln"], o, cfg)
    cache = None
    if make_cache:
        if kind == "local":
            w = cfg.window
            if S >= w:
                ring_k = jnp.roll(k[:, S - w:], S % w, axis=1)
                ring_v = jnp.roll(v[:, S - w:], S % w, axis=1)
            else:
                ring_k = jnp.pad(k, ((0, 0), (0, w - S), (0, 0), (0, 0)))
                ring_v = jnp.pad(v, ((0, 0), (0, w - S), (0, 0), (0, 0)))
            cache = KVCache(
                rules.act(ring_k, "batch", None, "kv_heads", None),
                rules.act(ring_v, "batch", None, "kv_heads", None))
        else:
            L = max(cache_len, S)
            k = jnp.pad(k, ((0, 0), (0, L - S), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, L - S), (0, 0), (0, 0)))
            cache = KVCache(
                rules.act(k, "batch", "kv_seq", "kv_heads", None),
                rules.act(v, "batch", "kv_seq", "kv_heads", None))
    return o, cache


def _xattn_sub(p: dict, x: jax.Array, cfg: ModelConfig, rules: AxisRules,
               memory: jax.Array, *, make_cache: bool = False):
    """Cross-attention to encoder/image memory (no causal mask, no rope on
    memory)."""
    B, S, _ = x.shape
    dt = x.dtype
    h = apply_norm(p["ln"], x, cfg)
    q = (h @ p["w_q"].astype(dt)).reshape(B, S, cfg.n_heads, cfg.head_dim)
    mk = (memory @ p["w_k"].astype(dt)).reshape(
        B, -1, cfg.n_kv_heads, cfg.head_dim)
    mv = (memory @ p["w_v"].astype(dt)).reshape(
        B, -1, cfg.n_kv_heads, cfg.head_dim)
    q = rules.act(q, "batch", "seq", "heads", None)
    mk = rules.act(mk, "batch", None, "kv_heads", None)
    mv = rules.act(mv, "batch", None, "kv_heads", None)
    o = attention(q, mk, mv, causal=False, window=None, softcap=None,
                  bf16_einsum=cfg.bf16_einsum)
    o = o.reshape(B, S, cfg.q_dim) @ p["w_o"].astype(dt)
    o = rules.act(o, "batch", "res_seq", None)
    return o, (mk, mv) if make_cache else None


def _ffn_sub(p: dict, x: jax.Array, cfg: ModelConfig, rules: AxisRules,
             *, dense: bool = False):
    if "ffn" not in p:
        return jnp.zeros_like(x), 0.0
    if cfg.n_experts and not dense and "router" in p["ffn"]:
        return moe_forward(p["ffn"], x, cfg, rules)
    return mlp_forward(p["ffn"], x, cfg, rules), 0.0


def block_forward(p: dict, x: jax.Array, cfg: ModelConfig, rules: AxisRules,
                  kind: str, positions: jax.Array, *, memory=None,
                  make_cache: bool = False, cache_len: int = 0):
    """Residual block, full sequence → (x, cache, aux)."""
    cache = None
    if kind in ("full", "local", "global"):
        o, cache = _attn_sub(p["attn"], x, cfg, rules, kind, positions,
                             make_cache=make_cache, cache_len=cache_len)
        x = x + o
    elif kind == "cross":
        o, sc = _attn_sub(p["attn"], x, cfg, rules, "full", positions,
                          make_cache=make_cache, cache_len=cache_len)
        x = x + o
        xo, mkv = _xattn_sub(p["xattn"], x, cfg, rules, memory,
                             make_cache=make_cache)
        x = x + xo
        if make_cache:
            cache = CrossCache(self_kv=sc, mem_k=mkv[0], mem_v=mkv[1])
    elif kind == "rec":
        o, cache = rec_forward(p["rec"], x, cfg, rules,
                               return_cache=make_cache)
        x = x + o
    elif kind == "ssm":
        o, cache = ssm_forward(p["ssm"], x, cfg, rules,
                               return_cache=make_cache)
        x = x + o
    else:
        raise ValueError(kind)
    o, aux = _ffn_sub(p, x, cfg, rules)
    return x + o, cache, aux


def block_step(p: dict, x: jax.Array, cfg: ModelConfig, rules: AxisRules,
               kind: str, cache, cur_len: jax.Array):
    """One-token decode step → (x, new_cache)."""
    B = x.shape[0]
    dt = x.dtype
    pos = (cur_len - 1)[None] if cur_len.ndim == 0 else cur_len - 1

    def self_attn(ap, kv: KVCache, ring: bool):
        h = apply_norm(ap["ln"], x, cfg)
        q = (h @ ap["w_q"].astype(dt)).reshape(B, 1, cfg.n_heads,
                                               cfg.head_dim)
        kn = (h @ ap["w_k"].astype(dt)).reshape(B, 1, cfg.n_kv_heads,
                                                cfg.head_dim)
        vn = (h @ ap["w_v"].astype(dt)).reshape(B, 1, cfg.n_kv_heads,
                                                cfg.head_dim)
        q = rope(q, pos[None, :], cfg.rope_theta)
        kn = rope(kn, pos[None, :], cfg.rope_theta)
        slot = jnp.where(ring, pos[0] % cfg.window, pos[0])
        # Token write via one-hot masked add, NOT dynamic_update_slice: a
        # DUS with a traced index on a sequence-sharded cache makes GSPMD
        # replicate the whole cache every step ("involuntary full
        # rematerialization"); the masked add is elementwise → shard-local
        # (§Perf global improvement; the vMCU RAMStore, GSPMD-safe).
        S = kv.k.shape[1]
        cache_dt = kv.k.dtype
        # arithmetic in bf16 (fp8 caches have no full ALU support); the
        # stored cache — the HBM-resident tensor — stays in cache_dt.
        mdt = cache_dt if cache_dt in (jnp.bfloat16, jnp.float32) \
            else jnp.bfloat16
        hot = (jax.lax.broadcasted_iota(jnp.int32, (1, S, 1, 1), 1)
               == slot).astype(mdt)
        k = (kv.k.astype(mdt) * (1 - hot)
             + kn.astype(mdt) * hot).astype(cache_dt)
        v = (kv.v.astype(mdt) * (1 - hot)
             + vn.astype(mdt) * hot).astype(cache_dt)
        o = decode_attention(q, k, v, cur_len, softcap=cfg.attn_softcap,
                             ring=bool(ring), window=cfg.window)
        o = o.reshape(B, 1, cfg.q_dim) @ ap["w_o"].astype(dt)
        if cfg.post_norms:
            o = apply_norm(ap["post_ln"], o, cfg)
        return o, KVCache(k, v)

    aux_cache = cache
    if kind in ("full", "global"):
        o, aux_cache = self_attn(p["attn"], cache, ring=False)
        x_new = x + o
    elif kind == "local":
        o, aux_cache = self_attn(p["attn"], cache, ring=True)
        x_new = x + o
    elif kind == "cross":
        o, skv = self_attn(p["attn"], cache.self_kv, ring=False)
        x_new = x + o
        h = apply_norm(p["xattn"]["ln"], x_new, cfg)
        q = (h @ p["xattn"]["w_q"].astype(dt)).reshape(
            B, 1, cfg.n_heads, cfg.head_dim)
        o = decode_attention(q, cache.mem_k, cache.mem_v,
                             jnp.asarray(cache.mem_k.shape[1]),
                             softcap=None)
        o = o.reshape(B, 1, cfg.q_dim) @ p["xattn"]["w_o"].astype(dt)
        x_new = x_new + o
        aux_cache = CrossCache(skv, cache.mem_k, cache.mem_v)
    elif kind == "rec":
        o, aux_cache = rec_step(p["rec"], x, cfg, rules, cache)
        x_new = x + o
    elif kind == "ssm":
        o, aux_cache = ssm_step(p["ssm"], x, cfg, rules, cache)
        x_new = x + o
    else:
        raise ValueError(kind)
    o, _ = _ffn_sub(p, x_new, cfg, rules)
    return x_new + o, aux_cache


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, cache_len: int,
                     dtype=jnp.bfloat16):
    if kind in ("full", "global"):
        shape = (batch, cache_len, cfg.n_kv_heads, cfg.head_dim)
        return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
    if kind == "local":
        shape = (batch, cfg.window, cfg.n_kv_heads, cfg.head_dim)
        return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
    if kind == "cross":
        shape = (batch, cache_len, cfg.n_kv_heads, cfg.head_dim)
        mshape = (batch, cfg.memory_len(), cfg.n_kv_heads, cfg.head_dim)
        return CrossCache(
            KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)),
            jnp.zeros(mshape, dtype), jnp.zeros(mshape, dtype))
    if kind == "rec":
        return init_rec_cache(cfg, batch, dtype)
    if kind == "ssm":
        return init_ssm_cache(cfg, batch, dtype)
    raise ValueError(kind)


# --------------------------------------------------------------------------
# Whole-model
# --------------------------------------------------------------------------

def _memory_len(cfg: ModelConfig) -> int:
    if cfg.family == "audio":
        return cfg.encoder_seq
    if cfg.family == "vlm":
        return cfg.n_image_tokens
    return 0


# attach as method for cache init
ModelConfig.memory_len = _memory_len  # type: ignore[attr-defined]


@dataclasses.dataclass(frozen=True)
class Model:
    """Pure-function model facade built from a ModelConfig."""

    cfg: ModelConfig

    # ---- init ---------------------------------------------------------------
    def init(self, key: jax.Array) -> dict:
        cfg = self.cfg
        g, rem = cfg.n_groups()
        lead = cfg.first_dense_layers
        if lead:  # deepseek: leading dense layers come out of the scan depth
            g, rem = (cfg.n_layers - lead) // len(cfg.pattern), \
                (cfg.n_layers - lead) % len(cfg.pattern)
        keys = jax.random.split(key, 8)
        params: dict[str, Any] = {
            "embed": jax.random.normal(keys[0], (cfg.vocab, cfg.d_model),
                                       jnp.float32) * 0.02,
            "final_ln": init_norm(cfg),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = jax.random.normal(
                keys[1], (cfg.vocab, cfg.d_model), jnp.float32) * 0.02
        if lead:
            dense_ff = cfg.d_ff * (cfg.top_k + cfg.n_shared_experts)
            params["lead"] = tuple(
                init_block(jax.random.fold_in(keys[2], i), cfg,
                           cfg.pattern[0] if cfg.pattern else "full",
                           dense_ff=dense_ff)
                for i in range(lead))
        # scan groups: tuple over pattern positions of stacked params
        def stack_init(kind: str, base: jax.Array):
            ks = jax.random.split(base, g)
            return jax.vmap(lambda kk: init_block(kk, cfg, kind))(ks)
        params["groups"] = tuple(
            stack_init(kind, jax.random.fold_in(keys[3], i))
            for i, kind in enumerate(cfg.pattern))
        params["rem"] = tuple(
            init_block(jax.random.fold_in(keys[4], i), cfg, cfg.pattern[i])
            for i in range(rem))
        if cfg.encoder_layers:
            eks = jax.random.split(keys[5], cfg.encoder_layers)
            params["encoder"] = {
                "blocks": jax.vmap(
                    lambda kk: init_block(kk, cfg, "full"))(eks),
                "final_ln": init_norm(cfg),
            }
        return params

    # ---- helpers --------------------------------------------------------------
    def _embed(self, params, tokens, rules: AxisRules):
        x = jnp.take(params["embed"], tokens, axis=0)
        x = (x * math.sqrt(self.cfg.d_model)).astype(jnp.bfloat16)
        return rules.act(x, "batch", "res_seq", None)

    def _unembed(self, params, x, rules: AxisRules):
        w = params.get("unembed", params["embed"])
        if self.cfg.bf16_einsum:
            # bf16 operands, fp32 accumulation: the seq all-gather of x and
            # the vocab matmul move bf16, not fp32 copies (§Perf).
            logits = jnp.einsum("bsd,vd->bsv", x, w.astype(x.dtype),
                                preferred_element_type=jnp.float32)
        else:
            logits = x.astype(jnp.float32) @ w.astype(jnp.float32).T
        logits = _softcap(logits, self.cfg.logit_softcap)
        return rules.act(logits, "batch", None, "vocab")

    def _encode(self, params, frames, rules: AxisRules):
        """Whisper encoder over precomputed conv-frontend frames (stub)."""
        cfg = self.cfg
        x = frames.astype(jnp.bfloat16)
        pos = jnp.arange(x.shape[1])

        def enc_block(x, bp):
            h = apply_norm(bp["attn"]["ln"], x, cfg)
            q, k, v = project_qkv(bp["attn"], h, cfg, rules, pos)
            o = attention(q, k, v, causal=False, window=None, softcap=None,
                          bf16_einsum=cfg.bf16_einsum)
            o = o.reshape(*x.shape[:2], cfg.q_dim) \
                @ bp["attn"]["w_o"].astype(x.dtype)
            x = x + rules.act(o, "batch", "res_seq", None)
            return x + mlp_forward(bp["ffn"], x, cfg, rules), None

        if cfg.scan_layers:
            x, _ = jax.lax.scan(enc_block, x, params["encoder"]["blocks"])
        else:
            n_e = jax.tree.leaves(params["encoder"]["blocks"])[0].shape[0]
            for ei in range(n_e):
                bp = jax.tree.map(lambda a: a[ei],
                                  params["encoder"]["blocks"])
                x, _ = enc_block(x, bp)
        return apply_norm(params["encoder"]["final_ln"], x, cfg)

    def _scan_blocks(self, params, x, rules, positions, memory,
                     remat_policy: str):
        """Training/plain forward through lead + scan groups + remainder."""
        cfg = self.cfg

        def apply_pattern(carry, gparams):
            x, aux = carry
            for i, kind in enumerate(cfg.pattern):
                x, _, a = block_forward(gparams[i], x, cfg, rules, kind,
                                        positions, memory=memory)
                aux = aux + a
            return (x, aux), None

        if remat_policy != "none":
            policy = {
                "nothing": jax.checkpoint_policies.nothing_saveable,
                "dots": jax.checkpoint_policies
                .dots_with_no_batch_dims_saveable,
            }.get(remat_policy, jax.checkpoint_policies.nothing_saveable)
            apply_pattern = jax.checkpoint(apply_pattern, policy=policy)

        aux = jnp.zeros((), jnp.float32)
        for bp in params.get("lead", ()):
            x, _, a = block_forward(bp, x, cfg, rules, cfg.pattern[0],
                                    positions, memory=memory)
            aux = aux + a
        if cfg.scan_layers:
            (x, aux), _ = jax.lax.scan(apply_pattern, (x, aux),
                                       params["groups"])
        else:  # unrolled: exact trip-count FLOPs in cost_analysis
            n_g = jax.tree.leaves(params["groups"])[0].shape[0]
            for gi in range(n_g):
                gp = jax.tree.map(lambda a: a[gi], params["groups"])
                (x, aux), _ = apply_pattern((x, aux), gp)
        for i, bp in enumerate(params.get("rem", ())):
            x, _, a = block_forward(bp, x, cfg, rules, cfg.pattern[i],
                                    positions, memory=memory)
            aux = aux + a
        return x, aux

    # ---- public: full-sequence forward ---------------------------------------
    def forward(self, params, tokens, rules: AxisRules | None = None,
                memory: jax.Array | None = None,
                remat_policy: str | None = None):
        rules = rules or no_sharding()
        cfg = self.cfg
        if cfg.encoder_layers and memory is not None:
            memory = self._encode(params, memory, rules)
        x = self._embed(params, tokens, rules)
        positions = jnp.arange(tokens.shape[1])
        x, aux = self._scan_blocks(params, x, rules, positions, memory,
                                   remat_policy or cfg.remat_policy)
        x = apply_norm(params["final_ln"], x, cfg)
        return self._unembed(params, x, rules), aux

    # ---- public: loss ----------------------------------------------------------
    def loss(self, params, batch: dict, rules: AxisRules | None = None,
             remat_policy: str | None = None):
        logits, aux = self.forward(params, batch["tokens"], rules,
                                   memory=batch.get("memory"),
                                   remat_policy=remat_policy)
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits, axis=-1)
        # label log-prob via masked reduction, NOT take_along_axis: a gather
        # over the vocab-sharded axis would all-gather the full [B,S,V]
        # logits; the where+sum reduces shard-locally then psums a scalar.
        vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logp.shape,
                                              logp.ndim - 1)
        ll = jnp.sum(jnp.where(vocab_iota == labels[..., None], logp, 0.0),
                     axis=-1)
        loss = -jnp.mean(ll)
        if self.cfg.n_experts:
            loss = loss + 0.01 * aux
        return loss, {"ce": -jnp.mean(ll), "aux": aux}

    # ---- public: serving --------------------------------------------------------
    def _layer_seq(self):
        cfg = self.cfg
        g, rem = cfg.n_groups()
        lead = cfg.first_dense_layers
        if lead:
            g = (cfg.n_layers - lead) // len(cfg.pattern)
            rem = (cfg.n_layers - lead) % len(cfg.pattern)
        return lead, g, rem

    def init_caches(self, batch: int, cache_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        lead, g, rem = self._layer_seq()
        mk = lambda kind: init_block_cache(cfg, kind, batch, cache_len, dtype)
        stack = lambda kind: jax.tree.map(
            lambda x: jnp.broadcast_to(x, (g,) + x.shape), mk(kind))
        return {
            "lead": tuple(mk(cfg.pattern[0]) for _ in range(lead)),
            "groups": tuple(stack(kind) for kind in cfg.pattern),
            "rem": tuple(mk(cfg.pattern[i]) for i in range(rem)),
        }

    def prefill(self, params, tokens, rules: AxisRules | None = None,
                memory: jax.Array | None = None, cache_len: int = 0):
        """Full-sequence pass materializing caches; returns (logits_last,
        caches, cur_len)."""
        rules = rules or no_sharding()
        cfg = self.cfg
        if cfg.encoder_layers and memory is not None:
            memory = self._encode(params, memory, rules)
        S = tokens.shape[1]
        cache_len = max(cache_len, S)
        x = self._embed(params, tokens, rules)
        positions = jnp.arange(S)
        caches = {"lead": [], "groups": [], "rem": []}

        def run(bp, x, kind):
            return block_forward(bp, x, cfg, rules, kind, positions,
                                 memory=memory, make_cache=True,
                                 cache_len=cache_len)

        for bp in params.get("lead", ()):
            x, c, _ = run(bp, x, cfg.pattern[0])
            caches["lead"].append(c)

        def scan_fn(x, gparams):
            cs = []
            for i, kind in enumerate(cfg.pattern):
                x, c, _ = run(gparams[i], x, kind)
                cs.append(c)
            return x, tuple(cs)

        if cfg.scan_layers:
            x, gcaches = jax.lax.scan(scan_fn, x, params["groups"])
        else:
            n_g = jax.tree.leaves(params["groups"])[0].shape[0]
            outs = []
            for gi in range(n_g):
                gp = jax.tree.map(lambda a: a[gi], params["groups"])
                x, cs = scan_fn(x, gp)
                outs.append(cs)
            gcaches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        caches["groups"] = gcaches
        for i, bp in enumerate(params.get("rem", ())):
            x, c, _ = run(bp, x, cfg.pattern[i])
            caches["rem"].append(c)
        x = apply_norm(params["final_ln"], x, cfg)
        logits = self._unembed(params, x[:, -1:], rules)
        caches = {k: tuple(v) if isinstance(v, list) else v
                  for k, v in caches.items()}
        return logits[:, 0], caches, jnp.asarray(S, jnp.int32)

    def decode_step(self, params, caches, token, cur_len,
                    rules: AxisRules | None = None):
        """token: [B] int32 → (logits [B,V], new caches, cur_len+1)."""
        rules = rules or no_sharding()
        cfg = self.cfg
        x = self._embed(params, token[:, None], rules)
        cur = cur_len + 1  # length including this token

        new = {"lead": [], "rem": []}
        for bp, c in zip(params.get("lead", ()), caches["lead"]):
            x, nc = block_step(bp, x, cfg, rules, cfg.pattern[0], c, cur)
            new["lead"].append(nc)

        def scan_fn(x, inp):
            gparams, gcaches = inp
            ncs = []
            for i, kind in enumerate(cfg.pattern):
                x, nc = block_step(gparams[i], x, cfg, rules, kind,
                                   gcaches[i], cur)
                ncs.append(nc)
            return x, tuple(ncs)

        if cfg.scan_layers:
            x, gcaches = jax.lax.scan(scan_fn, x,
                                      (params["groups"], caches["groups"]))
        else:
            n_g = jax.tree.leaves(params["groups"])[0].shape[0]
            outs = []
            for gi in range(n_g):
                gp = jax.tree.map(lambda a: a[gi], params["groups"])
                gc = jax.tree.map(lambda a: a[gi], caches["groups"])
                x, cs = scan_fn(x, (gp, gc))
                outs.append(cs)
            gcaches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        for i, (bp, c) in enumerate(zip(params.get("rem", ()),
                                        caches["rem"])):
            x, nc = block_step(bp, x, cfg, rules, cfg.pattern[i], c, cur)
            new["rem"].append(nc)
        x = apply_norm(params["final_ln"], x, cfg)
        logits = self._unembed(params, x, rules)
        caches = {"lead": tuple(new["lead"]), "groups": gcaches,
                  "rem": tuple(new["rem"])}
        return logits[:, 0], caches, cur
