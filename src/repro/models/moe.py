"""Mixture-of-Experts FFN: top-k routing with sort-free capacity dispatch.

Dispatch is scatter-based (one-hot rank within expert → static-capacity
slots), NOT dense-einsum-over-all-experts, so compiled FLOPs reflect only
the *active* expert compute — required for an honest roofline (§Roofline
counts MODEL_FLOPS = 6·N_active·D for MoE).

Supports granite-moe (32e top-8) and deepseek-moe (2 shared + 64 routed
top-6, fine-grained).  Experts are sharded on the ``model`` axis; the
scatter/gather around the expert GEMMs is where XLA SPMD places the
all-to-all — visible in the dry-run HLO.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.sharding import AxisRules
from .common import apply_norm, init_norm


def init_moe(key: jax.Array, cfg: ModelConfig) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 8)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {
        "ln": init_norm(cfg),
        "router": jax.random.normal(ks[0], (d, E), jnp.float32) * s_in,
        "moe_gate": jax.random.normal(ks[1], (E, d, f), jnp.float32) * s_in,
        "moe_up": jax.random.normal(ks[2], (E, d, f), jnp.float32) * s_in,
        "moe_down": jax.random.normal(ks[3], (E, f, d), jnp.float32) * s_out,
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        p["shared_gate"] = jax.random.normal(ks[4], (d, fs), jnp.float32) * s_in
        p["shared_up"] = jax.random.normal(ks[5], (d, fs), jnp.float32) * s_in
        p["shared_down"] = jax.random.normal(ks[6], (fs, d), jnp.float32) * s_out
    return p


def _act(cfg: ModelConfig, g: jax.Array, u: jax.Array) -> jax.Array:
    return (jax.nn.silu(g) if cfg.mlp == "swiglu" else jax.nn.gelu(g)) * u


def moe_forward(p: dict, x: jax.Array, cfg: ModelConfig, rules: AxisRules
                ) -> tuple[jax.Array, jax.Array]:
    """x: [B,S,d] → (out [B,S,d], aux_loss scalar)."""
    B, S, d = x.shape
    dt = x.dtype
    h = apply_norm(p["ln"], x, cfg)
    T = B * S
    ht = h.reshape(T, d)
    E, k = cfg.n_experts, cfg.top_k
    # capacity: cf-scaled, but never dropping when T is tiny (decode steps —
    # a token occupies at most one slot per expert, so cap >= T is lossless)
    cap = max(1, int(cfg.capacity_factor * T * k / E), min(T, 16))

    logits = (ht @ p["router"].astype(dt)).astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, -1)
    gate, eidx = jax.lax.top_k(probs, k)                        # [T, k]
    gate = gate / jnp.sum(gate, -1, keepdims=True)

    # load-balance aux loss (Switch-style) + router z-loss
    density = jnp.mean(jax.nn.one_hot(eidx[:, 0], E), axis=0)
    router_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(density * router_prob)
    aux = aux + 1e-3 * jnp.mean(jnp.square(jax.nn.logsumexp(logits, -1)))

    # rank within expert → capacity slot (scatter dispatch)
    flat_e = eidx.reshape(-1)                                   # [T*k]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    if cfg.moe_dispatch == "scan":
        # log-depth prefix sum: jnp.cumsum lowers to reduce-window, which
        # HLO costs (and TPU executes) as O(n·w) — quadratic in tokens.
        # associative_scan is O(n log n) adds (§Perf hillclimb C, it. 1).
        csum = jax.lax.associative_scan(jnp.add, onehot, axis=0)
    else:  # "cumsum" — the baseline recorded in §Roofline
        csum = jnp.cumsum(onehot, axis=0)
    rank = (csum * onehot).sum(-1) - 1                          # [T*k]
    keep = rank < cap
    xk = jnp.repeat(ht, k, axis=0)
    if cfg.moe_dispatch == "scan":
        # expert-major scatter target, constrained to the expert (model)
        # axis BEFORE the scatter so the dispatch exchange is an
        # all-to-all-sized reshard, not an all-reduce of the whole buffer
        # (§Perf hillclimb C, it. 2).
        rank_c = jnp.clip(rank, 0, cap - 1)
        buf = jnp.zeros((E, cap, d), dt)
        buf = rules.act(buf, "heads", None, None)
        xe = buf.at[flat_e, rank_c].add(jnp.where(keep[:, None], xk, 0))
    else:
        slot = flat_e * cap + jnp.clip(rank, 0, cap - 1)
        buf = jnp.zeros((E * cap, d), dt).at[slot].add(
            jnp.where(keep[:, None], xk, 0))
        xe = buf.reshape(E, cap, d)
    xe = rules.act(xe, "heads", None, None)   # experts on model axis

    g = jnp.einsum("ecd,edf->ecf", xe, p["moe_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xe, p["moe_up"].astype(dt))
    y = jnp.einsum("ecf,efd->ecd", _act(cfg, g, u),
                   p["moe_down"].astype(dt))
    y = rules.act(y, "heads", None, None)

    if cfg.moe_dispatch == "scan":
        out = y[flat_e, jnp.clip(rank, 0, cap - 1)] * keep[:, None]
    else:
        out = y.reshape(E * cap, d)[slot] * keep[:, None]
    out = (out.reshape(T, k, d)
           * gate[..., None].astype(dt)).sum(axis=1)

    if cfg.n_shared_experts:
        sg = ht @ p["shared_gate"].astype(dt)
        su = ht @ p["shared_up"].astype(dt)
        out = out + _act(cfg, sg, su) @ p["shared_down"].astype(dt)

    out = rules.act(out.reshape(B, S, d), "batch", "res_seq", None)
    return out, aux
