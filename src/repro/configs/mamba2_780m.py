"""Mamba-2 780M [arXiv:2405.21060]: 48L SSD blocks, d=1536 (attn-free,
d_ff=0), d_inner=3072, 48 SSD heads (head_dim 64), state N=128, vocab
50280.  48 heads % 16 == 0 ⇒ TP over SSD heads; O(1) state ⇒ long_500k."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=1, n_kv_heads=1, head_dim=64,
    d_ff=0, vocab=50_280,
    pattern=("ssm",),
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4, ssm_groups=1,
    ssm_chunk=256,
    mlp="gelu", tie_embeddings=True,
    shard_mode="tp", sub_quadratic=True,
))
