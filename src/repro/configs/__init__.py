"""Assigned-architecture configs.  Import this package to populate
ARCH_REGISTRY; ``get_config(name)`` fetches one."""
from .base import (ALL_SHAPES, ARCH_REGISTRY, DECODE_32K, LONG_500K,
                   ModelConfig, PREFILL_32K, ShapeCell, TRAIN_4K, cells_for,
                   get_config)
from . import (gemma2_2b, gemma3_1b, gemma2_27b, granite_8b, granite_moe_1b,
               deepseek_moe_16b, llama32_vision_90b, recurrentgemma_2b,
               whisper_tiny, mamba2_780m)

ALL_ARCHS = tuple(ARCH_REGISTRY)
