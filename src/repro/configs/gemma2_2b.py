"""Gemma-2 2B [arXiv:2408.00118]: 26L, d=2304, 8H GQA(kv=4), head_dim 256,
d_ff=9216 GeGLU, vocab 256000, 1:1 local:global (window 4096), attn/logit
softcaps, post-norms.  8 heads < 16 ⇒ fsdp_sp sharding; predominantly-
sliding hybrid ⇒ eligible for long_500k (ring KV on local layers)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma2-2b", family="lm",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=9216, vocab=256_000,
    pattern=("local", "global"), window=4096,
    attn_softcap=50.0, logit_softcap=30.0,
    mlp="geglu", post_norms=True, tie_embeddings=True,
    shard_mode="fsdp_sp", sub_quadratic=True,
    remat_policy="nothing",
))
