"""Llama-3.2 Vision 90B [hf:meta-llama/Llama-3.2-11B-Vision, scaled]:
100L backbone, d=8192, 64H GQA(kv=8), d_ff=28672 SwiGLU, vocab 128256;
cross-attention to image-patch embeddings every 5th layer.  Vision frontend
is a STUB — input_specs() supplies precomputed patch embeddings."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28_672, vocab=128_256,
    pattern=("full", "full", "full", "full", "cross"),
    n_image_tokens=1024,
    mlp="swiglu", tie_embeddings=False, rope_theta=500_000.0,
    shard_mode="tp", sub_quadratic=False,
))
