"""Granite-3.0 1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base]: 24L,
d=1024, 16H GQA(kv=8), MoE 32 experts top-8, expert d_ff=512, vocab 49155."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab=49_155,
    pattern=("full",),
    n_experts=32, top_k=8,
    mlp="swiglu", tie_embeddings=True,
    shard_mode="tp", sub_quadratic=False,
))
