"""Whisper-tiny [arXiv:2212.04356]: enc-dec, 4+4L, d=384, 6H, d_ff=1536
GELU, LayerNorm, vocab 51865.  Conv frontend is a STUB — input_specs()
supplies 1500 precomputed frame embeddings.  Decoder natively caps at 448
positions; the assigned decode_32k cell lowers with an extended position
range (RoPE adaptation, noted in DESIGN.md).  long_500k skipped."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, head_dim=64,
    d_ff=1536, vocab=51_865,
    pattern=("cross",),
    encoder_layers=4, encoder_seq=1500,
    mlp="gelu", norm="layernorm", tie_embeddings=True,
    shard_mode="fsdp_sp", sub_quadratic=False,
))
