"""Config system: one dataclass drives model build, sharding and dry-run.

Every assigned architecture is a ``ModelConfig`` in its own module
(``repro/configs/<id>.py``, exact literature values) and registers itself in
``ARCH_REGISTRY``.  ``reduced()`` derives the CPU smoke-test variant.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

Family = Literal["lm", "moe", "vlm", "hybrid", "audio", "ssm"]
ShardMode = Literal["tp", "fsdp_sp"]

# Block kinds usable in a layer pattern.
#   full   — causal full attention
#   local  — sliding-window causal attention
#   global — full attention (gemma naming; softcap per config)
#   cross  — cross-attention to encoder/image memory (+ self full)
#   rec    — RG-LRU recurrent block (recurrentgemma)
#   ssm    — Mamba-2 SSD block
BlockKind = Literal["full", "local", "global", "cross", "rec", "ssm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                  # 0 → d_model // n_heads
    pattern: tuple[BlockKind, ...] = ("full",)
    window: int = 4096                 # sliding-window size for "local"
    rope_theta: float = 10_000.0
    # gemma-style softcaps (None → off)
    attn_softcap: float | None = None
    logit_softcap: float | None = None
    mlp: Literal["geglu", "swiglu", "gelu"] = "swiglu"
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    post_norms: bool = False           # gemma2 post-attn/post-ffn norms
    tie_embeddings: bool = True
    # --- MoE -------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    first_dense_layers: int = 0        # deepseek: leading dense FFN layers
    moe_dispatch: str = "cumsum"       # cumsum (baseline) | scan (§Perf)
    # --- SSM (mamba2 SSD) --------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_groups: int = 1
    ssm_chunk: int = 256
    # --- RG-LRU (recurrentgemma) -------------------------------------------
    lru_width: int = 0
    # --- enc-dec / multimodal stubs ----------------------------------------
    encoder_layers: int = 0            # whisper encoder depth
    encoder_seq: int = 0               # frames after conv stub (whisper 1500)
    n_image_tokens: int = 0            # vlm patch-embedding stub length
    max_decode_len: int = 0            # 0 → unlimited (position table size)
    # --- distribution --------------------------------------------------------
    shard_mode: ShardMode = "tp"
    sub_quadratic: bool = False        # eligible for long_500k
    remat_policy: str = "nothing"      # nothing|dots|full — hillclimb lever
    bf16_einsum: bool = False          # §Perf: bf16 inputs + f32 accum in
                                       # attention/unembed einsums (kills
                                       # f32 activation gathers)
    scan_layers: bool = True           # False → unroll (exact cost_analysis)
    notes: str = ""

    # -- derived -------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        # Pad the vocab to a multiple of 256 so the embedding table shards
        # evenly on the 16-way model axis (standard production practice —
        # MaxText/Megatron do the same; padded rows never receive tokens).
        if self.vocab % 256:
            object.__setattr__(self, "vocab_unpadded", self.vocab)
            object.__setattr__(self, "vocab",
                               -(-self.vocab // 256) * 256)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:          # mamba2
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    def n_groups(self) -> tuple[int, int]:
        """(full scan groups, remainder layers)."""
        p = len(self.pattern)
        return self.n_layers // p, self.n_layers % p

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), for roofline
        MODEL_FLOPS = 6·N·D."""
        d, v = self.d_model, self.vocab
        total = v * d                           # embedding (tied)
        if not self.tie_embeddings:
            total += v * d
        per_layer: dict[BlockKind, int] = {}
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        dense_ffn = (3 if self.mlp in ("geglu", "swiglu") else 2) * d * self.d_ff
        moe_ffn = (self.n_experts + self.n_shared_experts) * 3 * d * self.d_ff \
            + d * self.n_experts if self.n_experts else 0
        ffn = moe_ffn if self.n_experts else dense_ffn
        for kind in set(self.pattern):
            if kind in ("full", "local", "global"):
                per_layer[kind] = attn + ffn
            elif kind == "cross":
                per_layer[kind] = 2 * attn + ffn   # self + cross attention
            elif kind == "rec":
                w = self.lru_width or d
                per_layer[kind] = (2 * d * w + w * d      # in/out projections
                                   + 2 * w                 # a-gate, i-gate
                                   + self.ssm_conv * w     # conv1d
                                   + dense_ffn)
            elif kind == "ssm":
                di, ns = self.d_inner, self.ssm_state
                per_layer[kind] = (d * (2 * di + 2 * self.ssm_groups * ns
                                        + self.ssm_heads)
                                   + self.ssm_conv * (di + 2 * self.ssm_groups * ns)
                                   + 2 * self.ssm_heads + di * d + di)
        g, rem = self.n_groups()
        count = 0
        for i, kind in enumerate(self.pattern):
            count += per_layer[kind] * (g + (1 if i < rem else 0))
        total += count
        if self.encoder_layers:
            total += self.encoder_layers * (attn + dense_ffn)
        return total

    def active_param_count(self) -> int:
        """MoE: only routed-active experts count toward useful FLOPs."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        all_experts = self.n_experts * 3 * d * self.d_ff
        active = (self.top_k + self.n_shared_experts) * 3 * d * self.d_ff
        return self.param_count() - self._moe_layers() * (all_experts -
                                                          active + 0)

    def _moe_layers(self) -> int:
        return self.n_layers - self.first_dense_layers if self.n_experts else 0

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family/pattern, tiny dims."""
        p = len(self.pattern)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=max(p, 2 if p == 1 else p),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) or 1,
            head_dim=16,
            d_ff=128 if not self.n_experts else 32,
            vocab=256,
            window=32,
            n_experts=min(self.n_experts, 4),
            n_shared_experts=min(self.n_shared_experts, 1),
            top_k=min(self.top_k, 2),
            # no-drop capacity: capacity-based MoE is batch-dependent by
            # design; smoke tests need decode == forward exactly.
            capacity_factor=float(max(self.n_experts, 1)),
            first_dense_layers=min(self.first_dense_layers, 1),
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=16 if self.ssm_state else self.ssm_head_dim,
            ssm_chunk=8,
            lru_width=64 if self.lru_width else 0,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 24) if self.encoder_seq else 0,
            n_image_tokens=min(self.n_image_tokens, 8),
            rope_theta=10_000.0,
        )


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned input-shape cell."""

    name: str
    kind: Literal["train", "prefill", "decode"]
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeCell("train_4k", "train", 4_096, 256)
PREFILL_32K = ShapeCell("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeCell("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeCell("long_500k", "decode", 524_288, 1)

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)

ARCH_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    ARCH_REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    from . import ALL_ARCHS  # noqa: F401  (populate registry)
    return ARCH_REGISTRY[name]


def cells_for(cfg: ModelConfig) -> list[ShapeCell]:
    """The shape cells this arch runs (long_500k only if sub-quadratic)."""
    cells = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.sub_quadratic:
        cells.append(LONG_500K)
    return cells
