"""Granite-8B code [arXiv:2405.04324]: llama-arch, 36L, d=4096, 32H
GQA(kv=8), d_ff=14336 SwiGLU, vocab 49152.  Pure full attention ⇒
long_500k skipped (DESIGN.md §Arch-applicability)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-8b", family="lm",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14_336, vocab=49_152,
    pattern=("full",),
    mlp="swiglu", tie_embeddings=True,
    shard_mode="tp", sub_quadratic=False,
))
