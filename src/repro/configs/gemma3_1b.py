"""Gemma-3 1B [hf:google/gemma-3-1b-pt]: 26L, d=1152, 4H GQA(kv=1),
head_dim 256, d_ff=6912 GeGLU, vocab 262144, 5:1 local:global (window 512),
128k context.  No softcaps (gemma3 uses qk-norm; modeled without)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-1b", family="lm",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab=262_144,
    pattern=("local", "local", "local", "local", "local", "global"),
    window=512, rope_theta=1_000_000.0,
    mlp="geglu", post_norms=True, tie_embeddings=True,
    shard_mode="fsdp_sp", sub_quadratic=True,
))
