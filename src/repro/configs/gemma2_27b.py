"""Gemma-2 27B [arXiv:2408.00118]: 46L, d=4608, 32H GQA(kv=16),
head_dim 128, d_ff=36864 GeGLU, vocab 256000, 1:1 local:global, softcaps.
32 heads ⇒ Megatron TP on the model axis."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma2-27b", family="lm",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36_864, vocab=256_000,
    pattern=("local", "global"), window=4096,
    attn_softcap=50.0, logit_softcap=30.0,
    mlp="geglu", post_norms=True, tie_embeddings=True,
    shard_mode="tp", sub_quadratic=False,
))
