"""DeepSeek-MoE 16B [arXiv:2401.06066]: 28L, d=2048, 16H (kv=16),
fine-grained MoE: 64 routed top-6 + 2 shared experts, expert d_ff=1408,
first layer dense (d_ff = 8*1408 ≈ paper's 10944 — noted), vocab 102400."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab=102_400,
    pattern=("full",),
    n_experts=64, n_shared_experts=2, top_k=6, first_dense_layers=1,
    mlp="swiglu", tie_embeddings=True,
    shard_mode="tp", sub_quadratic=False,
))
