"""RecurrentGemma-2B / Griffin [arXiv:2402.19427]: 26L, d=2560,
10H GQA(kv=1), head_dim 256, d_ff=7680 GeGLU, lru_width=2560,
pattern (rec, rec, local-attn) — 1 attention per 2 recurrent blocks,
window 2048.  Hybrid ⇒ long_500k eligible (O(1) recurrent state +
ring KV)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab=256_000,
    pattern=("rec", "rec", "local"), window=2048,
    lru_width=2560,
    mlp="geglu", tie_embeddings=True,
    shard_mode="fsdp_sp", sub_quadratic=True,
))
