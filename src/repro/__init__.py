"""vMCU reproduction, grown into a jax/Pallas system.

The deployment front door is one call (DESIGN.md §9):

    import repro
    cn = repro.compile("mcunet-5fps-vww", target="cortex-m4")
    y = cn.run(x)            # any executor backend
    cn.emit_c("out/")        # intrinsic-C units, requant tables baked in
    cn.report()              # footprint vs the target's SRAM budget
    cn.save("vww.plan.json") # solved plan artifact; load() never
                             # re-runs the scheduler

Subsystem packages stay importable directly: ``repro.core`` (pool +
planner + executors), ``repro.graph`` (whole-network compiler),
``repro.quant`` (int8), ``repro.kernels`` (Pallas ring kernels),
``repro.analysis`` (static ring-safety verifier + ``vmcu-lint``;
``repro.compile(..., certify="static")`` proves plans instead of
replaying them).

Note: ``repro.compile`` is the *function*; the package it lives in is
reachable as ``repro.compile.targets`` etc. via normal ``from`` imports.
"""
from .compile import (CompiledNet, CompileError, PASS_NAMES, PassRecord,
                      REQUANT_IDIOMS, SRAMBudgetError, Target,
                      available_nets, compile, get_target, list_targets,
                      load, register_target)

__all__ = [
    "CompiledNet", "CompileError", "PASS_NAMES", "PassRecord",
    "REQUANT_IDIOMS", "SRAMBudgetError", "Target", "available_nets",
    "compile", "get_target", "list_targets", "load", "register_target",
]
