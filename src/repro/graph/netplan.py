"""The global network planner: all groups in ONE VirtualPool ring.

``plan_net`` turns a :class:`graph.ir.Graph` into a :class:`NetPlan`:

  1. schedule the DAG (``graph.schedule.reorder``),
  2. select fusion groups by the paper's exclusion rule,
  3. lower every group to ``plan_program()`` layer specs and solve the
     WHOLE net as one :class:`PoolProgram` — the Eq.-(1)/(2) offsets
     chain *across* group boundaries, so group ``i+1`` overwrites group
     ``i``'s consumed input instead of resetting the pool,
  4. chain the byte-granular (int8, MCU) footprints of the groups the
     same way and report the whole-network bottleneck against the
     TinyEngine / HMCOS tensor-level baselines.

Two footprints, two granularities, by design: ``program.pool_bytes`` is
the *executed* segment-granular ring (fp32 on the TPU backends, certified
by the ``sim`` oracle), ``mcu_bottleneck_bytes`` is the paper's byte-
granular int8 number (the Fig. 9/10 metric the 61.5% reduction is
measured on).  The byte formulas of ``core.graph_planner`` cross-check
the per-group values.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from ..core.graph_planner import ModuleConfig
from ..core.program import (AvgPoolSpec, ConvDWSpec, ConvK2DSpec,
                            ConvPWSpec, ConvStreamSpec, GemmSpec,
                            FusedMLPSpec, GRUCellSpec, IBModuleSpec,
                            LayerSpec, PoolProgram, ResidualAddSpec,
                            plan_program)
from ..core.vpool import SEG_WIDTH, ceil_div
from .ir import Graph
from .schedule import FusionGroup, reorder, select_groups


@dataclasses.dataclass(frozen=True)
class GroupPlan:
    """One fusion group's slot in the NetPlan."""

    group: FusionGroup
    op_lo: int                # slice of NetPlan.program.ops
    op_hi: int
    mcu_in_off: int           # byte-chain offsets (Eq. 2 across groups)
    mcu_out_off: int

    @property
    def name(self) -> str:
        return self.group.name


@dataclasses.dataclass
class NetPlan:
    """A fully planned network over one ring."""

    name: str
    graph: Graph
    order: tuple[str, ...]
    groups: tuple[GroupPlan, ...]
    program: PoolProgram
    mcu_pool_bytes: int       # byte-granular whole-net ring (max span)

    # -- whole-network MCU numbers (paper Fig. 9/10 metric) ---------------
    @property
    def mcu_bottleneck_bytes(self) -> int:
        return max(g.group.mcu_bytes for g in self.groups)

    @property
    def tinyengine_bottleneck_bytes(self) -> int:
        return max(g.group.te_bytes for g in self.groups)

    @property
    def hmcos_bottleneck_bytes(self) -> int:
        return max(g.group.hmcos_bytes for g in self.groups)

    @property
    def reduction_vs_tinyengine(self) -> float:
        return 1.0 - (self.mcu_bottleneck_bytes
                      / self.tinyengine_bottleneck_bytes)

    @property
    def reduction_vs_hmcos(self) -> float:
        return 1.0 - (self.mcu_bottleneck_bytes
                      / self.hmcos_bottleneck_bytes)

    # -- executed (segment-granular) footprint ----------------------------
    @property
    def pool_bytes(self) -> int:
        return self.program.pool_bytes

    @property
    def physical_pool_bytes(self) -> int:
        return self.program.physical_pool_bytes

    def bottleneck_group(self) -> GroupPlan:
        return max(self.groups, key=lambda g: g.group.mcu_bytes)

    def deployable(self, ram_bytes: int) -> bool:
        return self.mcu_bottleneck_bytes <= ram_bytes


# ---------------------------------------------------------------------------
# Group -> layer-spec lowering.
# ---------------------------------------------------------------------------

def _module_specs(graph: Graph, group: FusionGroup,
                  cfg: ModuleConfig) -> list[LayerSpec]:
    if group.fused_exec:
        return [IBModuleSpec(cfg)]
    s1, s2, s3 = cfg.strides
    h0 = cfg.hw
    h1 = ceil_div(h0, s1)
    h2 = ceil_div(h1, s2)
    specs: list[LayerSpec] = [
        ConvPWSpec(h0, h0, cfg.c_in, cfg.c_mid, stride=s1,
                   activation="relu"),
        ConvDWSpec(h1, h1, cfg.c_mid, rs=cfg.rs, stride=s2,
                   activation="relu"),
        ConvPWSpec(h2, h2, cfg.c_mid, cfg.c_out, stride=s3),
    ]
    if cfg.has_residual:
        specs.append(ResidualAddSpec(3))
    return specs


def _node_spec(graph: Graph, nid: str,
               input_from: int = 0) -> list[LayerSpec]:
    n = graph.nodes[nid]
    tin = graph.in_tensor(nid)
    if input_from and n.kind not in ("conv_pw", "conv_k2d"):
        raise ValueError(f"{nid}: only conv_pw/conv_k2d nodes can read a "
                         "held branch tensor")
    if n.kind == "conv_pw":
        return [ConvPWSpec(tin.h, tin.w, tin.d, n.out.d, stride=n.stride,
                           resample_to=((n.out.h, n.out.w) if n.resample
                                        else None),
                           activation=n.activation,
                           input_from=input_from)]
    if n.kind == "conv_dw":
        return [ConvDWSpec(tin.h, tin.w, tin.d, rs=n.rs, stride=n.stride,
                           activation=n.activation)]
    if n.kind == "conv_k2d":
        return [ConvK2DSpec(tin.h, tin.w, tin.d, n.out.d, k=n.rs,
                            stride=n.stride, padding=n.padding,
                            activation=n.activation,
                            input_from=input_from)]
    if n.kind == "conv_stream":
        return [ConvStreamSpec(n.h_win, tin.w, tin.d, n.out.d, k=n.rs,
                               stride=n.stride, padding=n.padding,
                               hop=n.hop, activation=n.activation)]
    if n.kind == "gru_cell":
        return [GRUCellSpec(n.out.d)]
    if n.kind == "avgpool":
        return [AvgPoolSpec(tin.h, tin.w, tin.d)]
    if n.kind == "fc":
        return [GemmSpec(n.out.d, activation=n.activation)]
    if n.kind == "mlp":
        from .ir import _ff_tile
        return [FusedMLPSpec(n.d_ff, gated=n.gated, residual=True,
                             activation=n.activation or "gelu",
                             ff_tile=_ff_tile(n.d_ff))]
    if n.kind == "elementwise":
        from ..core.program import ElementwiseSpec
        return [ElementwiseSpec(n.activation or "gelu")]
    raise ValueError(f"cannot lower node kind {n.kind!r}")


def resblock_specs(graph: Graph, ids: Sequence[str]) -> list[LayerSpec]:
    """Lower a ``block``-tagged residual run (in scheduled order) to
    plan_program specs.

    The run is a linear chain plus at most one branch per node: a node
    whose graph input is not the chained tensor becomes a branch conv
    (``input_from`` — it reads the *held* input of the op whose chained
    tensor it needs, e.g. the ResNet shortcut projection reading the
    block input), and the closing ``add``'s residual operand resolves to
    whichever op's chained input produced it (``ResidualAddSpec.src``).
    """
    nodes = [graph.nodes[i] for i in ids]
    if len(nodes) < 2 or nodes[-1].kind != "add":
        raise ValueError(f"res block {ids}: must end in an add node")
    # chained tensor entering op j: the previous node's output (op 0
    # chains from the block input)
    chain_in = [nodes[0].inputs[0]] + [n.id for n in nodes[:-1]]
    specs: list[LayerSpec] = []
    for j, n in enumerate(nodes[:-1]):
        src_id = n.inputs[0]
        input_from = 0
        if src_id != chain_in[j]:
            k = chain_in.index(src_id)
            if k >= j:
                raise ValueError(f"{n.id}: branch source {src_id!r} not "
                                 "available earlier in the block")
            input_from = j - k
        specs.extend(_node_spec(graph, n.id, input_from=input_from))
    add = nodes[-1]
    main, aux = add.inputs
    if main != nodes[-2].id:
        main, aux = aux, main
    if main != nodes[-2].id:
        raise ValueError(f"{add.id}: neither add operand chains from the "
                         f"preceding node {nodes[-2].id!r}")
    if aux not in chain_in:
        raise ValueError(f"{add.id}: residual operand {aux!r} is not a "
                         "tensor the block holds")
    src = (len(nodes) - 1) - chain_in.index(aux)
    specs.append(ResidualAddSpec(src, activation=add.activation))
    return specs


def group_specs(graph: Graph, group: FusionGroup) -> list[LayerSpec]:
    """Lower one fusion group to ``plan_program`` layer specs."""
    if group.kind == "module":
        return _module_specs(graph, group, graph.modules[group.name])
    if group.kind == "resblock":
        return resblock_specs(graph, group.node_ids)
    specs: list[LayerSpec] = []
    for nid in group.node_ids:
        specs.extend(_node_spec(graph, nid))
    return specs


# ---------------------------------------------------------------------------
# plan_net.
# ---------------------------------------------------------------------------

def _plan_net(graph: Graph, *, seg_width: int = SEG_WIDTH,
              block_rows: int | None = 1, elem_bytes: int | None = None,
              dtype: str = "float32", delta_slack: int = 0,
              fused_exec: bool = True,
              order: Sequence[str] | None = None) -> NetPlan:
    """Plan a whole network into one ring.

    ``block_rows=1`` (default) produces the DMA-aligned geometry all
    three backends execute; ``block_rows=None`` the tight Eq.-(1)/(2)
    geometry (``sim``/``jnp`` only).

    ``dtype`` sets the executed pool element type (``"int8"`` makes
    ``program.pool_bytes`` byte-comparable to ``mcu_bottleneck_bytes``).
    ``fused_exec=False`` forces every module to lower to its unfused
    pw → dw → pw (→ add) op run — the form the int8 executor requantizes
    between ops (the byte-granular *reported* footprints still follow
    the paper's exclusion rule either way).
    """
    graph.validate()
    if order is None:
        order, _ = reorder(graph)
    order = list(order)
    groups = select_groups(graph, order, seg_width=seg_width)
    if not fused_exec:
        groups = [dataclasses.replace(g, fused_exec=False) for g in groups]

    specs: list[LayerSpec] = []
    ranges: list[tuple[int, int]] = []
    for g in groups:
        lo = len(specs)
        specs.extend(group_specs(graph, g))
        ranges.append((lo, len(specs)))

    tin = graph.nodes[graph.input_id()].out
    program = plan_program(tin.rows, tin.d, specs, seg_width=seg_width,
                           block_rows=block_rows, elem_bytes=elem_bytes,
                           dtype=dtype, delta_slack=delta_slack)

    # Chain the byte-granular group plans across boundaries (Eq. 2): the
    # next group's input IS this group's output, delta_bytes below it.
    gplans: list[GroupPlan] = []
    off = 0
    for g, (lo, hi) in zip(groups, ranges):
        out_off = off - g.delta_bytes
        gplans.append(GroupPlan(group=g, op_lo=lo, op_hi=hi,
                                mcu_in_off=off, mcu_out_off=out_off))
        off = out_off
    mcu_pool = max(g.mcu_bytes for g in groups)

    return NetPlan(name=graph.name, graph=graph, order=tuple(order),
                   groups=tuple(gplans), program=program,
                   mcu_pool_bytes=mcu_pool)


def plan_net(graph: Graph, **kwargs) -> NetPlan:
    """Deprecated direct entry — use :func:`repro.compile`.

    ``plan_net`` is now the ``plan`` pass of the compile driver
    (``repro.compile(net, target=...)``), which sources seg-width /
    alignment / dtype knobs from the :class:`repro.compile.targets.
    Target` registry instead of per-call-site wiring.  The shim keeps
    the exact legacy behavior (same defaults, same NetPlan)."""
    import warnings

    warnings.warn(
        "direct plan_net() entry is deprecated; use "
        "repro.compile(net, target=...) — the driver runs plan_net as "
        "its 'plan' pass with knobs from the Target registry",
        DeprecationWarning, stacklevel=2)
    return _plan_net(graph, **kwargs)
