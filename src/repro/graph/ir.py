"""Whole-network DAG IR + builders.

A :class:`Graph` is an ordered DAG of :class:`Node` ops over quantized
:class:`Tensor` values (per-tensor byte sizes drive the lifetime
analysis in ``graph.schedule``).  Node kinds:

  ``input`` ``conv_pw`` ``conv_dw`` ``add`` ``avgpool`` ``flatten``
  ``fc`` ``mlp`` ``elementwise``

Builders lower the paper's MCUNet module tables
(:data:`repro.core.graph_planner.MCUNET_5FPS_VWW` /
:data:`MCUNET_320KB_IMAGENET`) and every registered ``configs/`` model
into the IR.  Modules expand to their *unfused* pw → dw → pw (→ add)
node sequence tagged with the module name — fusing them back into one
Fig.-6 kernel is the scheduler's decision (``graph.schedule``), made by
the paper's own exclusion rule, not the builder's.

Where consecutive table modules do not chain (channel or resolution
mismatch — the tables list benchmark modules, not a closed network), the
builder inserts a pointwise *adapter* conv: strided when the resolution
divides down exactly, nearest-grid resampling otherwise.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

from ..core.graph_planner import ModuleConfig
from ..core.vpool import ceil_div


@dataclasses.dataclass(frozen=True)
class Tensor:
    """A value in the graph: ``rows`` x ``d`` elements (``h``/``w`` carry
    the image geometry for conv tensors; ``rows == h * w`` then)."""

    rows: int
    d: int
    h: int = 0
    w: int = 0
    elem_bytes: int = 1

    @property
    def nbytes(self) -> int:
        return self.rows * self.d * self.elem_bytes


@dataclasses.dataclass(frozen=True)
class Node:
    """One IR op.  ``inputs`` are producer node ids (the second input of
    ``add`` is the residual source); ``out`` is the produced tensor."""

    id: str
    kind: str
    inputs: tuple[str, ...]
    out: Tensor
    stride: int = 1
    rs: int = 0
    resample: bool = False
    activation: str | None = None
    d_ff: int = 0
    gated: bool = False
    module: str = ""          # module tag for fusion-group selection


class Graph:
    """An ordered DAG; insertion order is a valid topological order."""

    def __init__(self, name: str, elem_bytes: int = 1):
        self.name = name
        self.elem_bytes = elem_bytes
        self.nodes: dict[str, Node] = {}
        self.modules: dict[str, ModuleConfig] = {}

    # -- construction ------------------------------------------------------
    def add(self, id: str, kind: str, inputs: Sequence[str], out: Tensor,
            **attrs) -> str:
        if id in self.nodes:
            raise ValueError(f"duplicate node id {id!r}")
        for src in inputs:
            if src not in self.nodes:
                raise ValueError(f"node {id!r} references unknown input "
                                 f"{src!r}")
        self.nodes[id] = Node(id=id, kind=kind, inputs=tuple(inputs),
                              out=out, **attrs)
        return id

    # -- structure ---------------------------------------------------------
    def node(self, id: str) -> Node:
        return self.nodes[id]

    def in_tensor(self, id: str) -> Tensor:
        """The (first) input tensor of a node."""
        n = self.nodes[id]
        if not n.inputs:
            raise ValueError(f"node {id!r} has no inputs")
        return self.nodes[n.inputs[0]].out

    def consumers(self, id: str) -> list[str]:
        return [n.id for n in self.nodes.values() if id in n.inputs]

    def input_id(self) -> str:
        for n in self.nodes.values():
            if n.kind == "input":
                return n.id
        raise ValueError("graph has no input node")

    def output_id(self) -> str:
        sinks = [n.id for n in self.nodes.values()
                 if not self.consumers(n.id)]
        if len(sinks) != 1:
            raise ValueError(f"graph has {len(sinks)} sinks: {sinks}")
        return sinks[0]

    def topo_order(self) -> list[str]:
        """Kahn topological order (ties broken by insertion order)."""
        indeg = {i: len(n.inputs) for i, n in self.nodes.items()}
        ready = [i for i, d in indeg.items() if d == 0]
        order: list[str] = []
        while ready:
            i = ready.pop(0)
            order.append(i)
            for c in self.consumers(i):
                indeg[c] -= 1
                if indeg[c] == 0:
                    ready.append(c)
        if len(order) != len(self.nodes):
            raise ValueError("graph has a cycle")
        return order

    def validate(self) -> None:
        self.topo_order()
        for n in self.nodes.values():
            if n.kind == "input":
                if n.inputs:
                    raise ValueError("input node cannot have inputs")
                continue
            t = self.in_tensor(n.id)
            if n.kind in ("conv_pw", "conv_dw") and t.h * t.w != t.rows:
                raise ValueError(f"{n.id}: conv over non-image tensor")
            if n.kind == "add":
                if len(n.inputs) != 2:
                    raise ValueError(f"{n.id}: add needs two inputs")
                a, b = (self.nodes[s].out for s in n.inputs)
                if (a.rows, a.d) != (b.rows, b.d):
                    raise ValueError(f"{n.id}: add shape mismatch")
            if n.kind == "flatten" and t.rows != 1:
                raise ValueError(
                    f"{n.id}: only 1x1 tensors flatten losslessly in "
                    "row-major pool layout (use avgpool first)")


# ---------------------------------------------------------------------------
# Builders.
# ---------------------------------------------------------------------------

def _adapter(g: Graph, src: str, cur: Tensor, h: int, c: int,
             elem_bytes: int, idx: int) -> tuple[str, Tensor]:
    """Insert a pointwise adapter conv from ``cur`` to an ``h x h x c``
    tensor: strided when the resolution divides down, resampling
    otherwise."""
    stride, resample = 1, False
    if cur.h != h:
        s = max(1, round(cur.h / h))
        if ceil_div(cur.h, s) == h:
            stride = s
        else:
            resample = True
    out = Tensor(rows=h * h, d=c, h=h, w=h, elem_bytes=elem_bytes)
    nid = g.add(f"T{idx}", "conv_pw", [src], out, stride=stride,
                resample=resample, activation=None)
    return nid, out


def build_mcunet(modules: Iterable[ModuleConfig], name: str, *,
                 num_classes: int = 2, elem_bytes: int = 1,
                 include_head: bool = True) -> Graph:
    """Lower a MCUNet module table into the IR.

    Each table row becomes its unfused pw1 -> dw -> pw2 (-> residual add)
    node run tagged ``module=<row name>``; adapters connect rows whose
    shapes do not chain; an avgpool/flatten/fc head closes the net.
    """
    modules = list(modules)
    g = Graph(name, elem_bytes=elem_bytes)
    cfg0 = modules[0]
    cur = Tensor(rows=cfg0.hw * cfg0.hw, d=cfg0.c_in, h=cfg0.hw, w=cfg0.hw,
                 elem_bytes=elem_bytes)
    src = g.add("in", "input", [], cur)
    for t, cfg in enumerate(modules):
        if (cur.h, cur.d) != (cfg.hw, cfg.c_in):
            src, cur = _adapter(g, src, cur, cfg.hw, cfg.c_in, elem_bytes,
                                t)
        g.modules[cfg.name] = cfg
        s1, s2, s3 = cfg.strides
        h0 = cfg.hw
        h1 = ceil_div(h0, s1)
        h2 = ceil_div(h1, s2)
        h3 = ceil_div(h2, s3)
        mod_in = src
        b = Tensor(h1 * h1, cfg.c_mid, h1, h1, elem_bytes)
        src = g.add(f"{cfg.name}.pw1", "conv_pw", [src], b, stride=s1,
                    activation="relu", module=cfg.name)
        c = Tensor(h2 * h2, cfg.c_mid, h2, h2, elem_bytes)
        src = g.add(f"{cfg.name}.dw", "conv_dw", [src], c, stride=s2,
                    rs=cfg.rs, activation="relu", module=cfg.name)
        d = Tensor(h3 * h3, cfg.c_out, h3, h3, elem_bytes)
        src = g.add(f"{cfg.name}.pw2", "conv_pw", [src], d, stride=s3,
                    module=cfg.name)
        if cfg.has_residual:
            src = g.add(f"{cfg.name}.add", "add", [src, mod_in], d,
                        module=cfg.name)
        cur = d
    if include_head:
        pooled = Tensor(1, cur.d, 1, 1, elem_bytes)
        src = g.add("head.pool", "avgpool", [src], pooled)
        src = g.add("head.flatten", "flatten", [src], pooled)
        logits = Tensor(1, num_classes, 1, 1, elem_bytes)
        src = g.add("head.fc", "fc", [src], logits)
    g.validate()
    return g


def _ff_tile(d_ff: int, cap: int = 512) -> int:
    """Largest divisor of d_ff not exceeding ``cap``."""
    for t in range(min(cap, d_ff), 0, -1):
        if d_ff % t == 0:
            return t
    return d_ff


def build_mlp_tower(cfg, *, m_rows: int = 8, n_layers: int | None = None,
                    elem_bytes: int = 2) -> Graph:
    """Lower a ``configs/`` :class:`ModelConfig`'s FFN stack into the IR
    (the pool-resident part of an LM block; attention state does not
    stream through the ring — DESIGN.md §Arch-applicability)."""
    n_layers = cfg.n_layers if n_layers is None else n_layers
    gated = cfg.mlp in ("geglu", "swiglu")
    act = "silu" if cfg.mlp == "swiglu" else "gelu"
    d_ff = cfg.d_ff
    if d_ff == 0:           # pure-SSM configs: the in-projection
        d_ff = cfg.d_inner  # expansion is the never-materialized tensor
        gated, act = True, "silu"
    g = Graph(f"{cfg.name}-mlp-tower", elem_bytes=elem_bytes)
    cur = Tensor(rows=m_rows, d=cfg.d_model, elem_bytes=elem_bytes)
    src = g.add("in", "input", [], cur)
    for i in range(n_layers):
        src = g.add(f"L{i}.mlp", "mlp", [src], cur, d_ff=d_ff,
                    gated=gated, activation=act)
    g.validate()
    return g
