"""Whole-network DAG IR + builders.

A :class:`Graph` is an ordered DAG of :class:`Node` ops over quantized
:class:`Tensor` values (per-tensor byte sizes drive the lifetime
analysis in ``graph.schedule``).  Node kinds:

  ``input`` ``conv_pw`` ``conv_dw`` ``conv_k2d`` ``add`` ``avgpool``
  ``flatten`` ``fc`` ``mlp`` ``elementwise``

Builders lower the paper's MCUNet module tables
(:data:`repro.core.graph_planner.MCUNET_5FPS_VWW` /
:data:`MCUNET_320KB_IMAGENET`) and every registered ``configs/`` model
into the IR.  Modules expand to their *unfused* pw → dw → pw (→ add)
node sequence tagged with the module name — fusing them back into one
Fig.-6 kernel is the scheduler's decision (``graph.schedule``), made by
the paper's own exclusion rule, not the builder's.

Where consecutive table modules do not chain (channel or resolution
mismatch — the tables list benchmark modules, not a closed network), the
builder inserts a pointwise *adapter* conv: strided when the resolution
divides down exactly, nearest-grid resampling otherwise.

The MLPerf-Tiny-class model zoo (``build_ds_cnn`` / ``build_resnet8`` /
``build_mobilenet_v1``) builds on the general ``conv_k2d`` node: real
k x k spatial convs with halo frontiers, incl. ResNet residual blocks
whose shortcut projection reads the *held* block input (``block``-tagged
node runs — lowered by ``graph.schedule.select_groups`` as one planning
unit).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

from ..core.graph_planner import ModuleConfig
from ..core.rowsched import conv_k2d_out
from ..core.vpool import ceil_div


@dataclasses.dataclass(frozen=True)
class Tensor:
    """A value in the graph: ``rows`` x ``d`` elements (``h``/``w`` carry
    the image geometry for conv tensors; ``rows == h * w`` then)."""

    rows: int
    d: int
    h: int = 0
    w: int = 0
    elem_bytes: int = 1

    @property
    def nbytes(self) -> int:
        return self.rows * self.d * self.elem_bytes


@dataclasses.dataclass(frozen=True)
class Node:
    """One IR op.  ``inputs`` are producer node ids (the second input of
    ``add`` is the residual source); ``out`` is the produced tensor."""

    id: str
    kind: str
    inputs: tuple[str, ...]
    out: Tensor
    stride: int = 1
    rs: int = 0
    padding: str = "same"     # conv_k2d halo convention (same/valid)
    resample: bool = False
    activation: str | None = None
    d_ff: int = 0
    gated: bool = False
    module: str = ""          # module tag for fusion-group selection
    block: str = ""           # residual-block tag (ResNet-style groups)
    h_win: int = 0            # conv_stream: sliding-window height
    hop: int = 0              # conv_stream: frame rows appended per step


class Graph:
    """An ordered DAG; insertion order is a valid topological order."""

    def __init__(self, name: str, elem_bytes: int = 1):
        self.name = name
        self.elem_bytes = elem_bytes
        self.nodes: dict[str, Node] = {}
        self.modules: dict[str, ModuleConfig] = {}

    # -- construction ------------------------------------------------------
    def add(self, id: str, kind: str, inputs: Sequence[str], out: Tensor,
            **attrs) -> str:
        if id in self.nodes:
            raise ValueError(f"duplicate node id {id!r}")
        for src in inputs:
            if src not in self.nodes:
                raise ValueError(f"node {id!r} references unknown input "
                                 f"{src!r}")
        self.nodes[id] = Node(id=id, kind=kind, inputs=tuple(inputs),
                              out=out, **attrs)
        return id

    # -- structure ---------------------------------------------------------
    def node(self, id: str) -> Node:
        return self.nodes[id]

    def in_tensor(self, id: str) -> Tensor:
        """The (first) input tensor of a node."""
        n = self.nodes[id]
        if not n.inputs:
            raise ValueError(f"node {id!r} has no inputs")
        return self.nodes[n.inputs[0]].out

    def consumers(self, id: str) -> list[str]:
        return [n.id for n in self.nodes.values() if id in n.inputs]

    def input_id(self) -> str:
        for n in self.nodes.values():
            if n.kind == "input":
                return n.id
        raise ValueError("graph has no input node")

    def output_id(self) -> str:
        sinks = [n.id for n in self.nodes.values()
                 if not self.consumers(n.id)]
        if len(sinks) != 1:
            raise ValueError(f"graph has {len(sinks)} sinks: {sinks}")
        return sinks[0]

    def topo_order(self) -> list[str]:
        """Kahn topological order (ties broken by insertion order)."""
        indeg = {i: len(n.inputs) for i, n in self.nodes.items()}
        ready = [i for i, d in indeg.items() if d == 0]
        order: list[str] = []
        while ready:
            i = ready.pop(0)
            order.append(i)
            for c in self.consumers(i):
                indeg[c] -= 1
                if indeg[c] == 0:
                    ready.append(c)
        if len(order) != len(self.nodes):
            raise ValueError("graph has a cycle")
        return order

    def validate(self) -> None:
        self.topo_order()
        for n in self.nodes.values():
            if n.kind == "input":
                if n.inputs:
                    raise ValueError("input node cannot have inputs")
                continue
            t = self.in_tensor(n.id)
            if n.kind in ("conv_pw", "conv_dw", "conv_k2d", "conv_stream") \
                    and t.h * t.w != t.rows:
                raise ValueError(f"{n.id}: conv over non-image tensor")
            if n.kind == "conv_stream" and (t.h, t.w) != (n.hop, t.w):
                raise ValueError(f"{n.id}: conv_stream frame height "
                                 f"{t.h} != hop {n.hop}")
            if n.kind == "add":
                if len(n.inputs) != 2:
                    raise ValueError(f"{n.id}: add needs two inputs")
                a, b = (self.nodes[s].out for s in n.inputs)
                if (a.rows, a.d) != (b.rows, b.d):
                    raise ValueError(f"{n.id}: add shape mismatch")
            if n.kind == "flatten" and t.rows != 1:
                raise ValueError(
                    f"{n.id}: only 1x1 tensors flatten losslessly in "
                    "row-major pool layout (use avgpool first)")


# ---------------------------------------------------------------------------
# Builders.
# ---------------------------------------------------------------------------

def _adapter(g: Graph, src: str, cur: Tensor, h: int, c: int,
             elem_bytes: int, idx: int) -> tuple[str, Tensor]:
    """Insert a pointwise adapter conv from ``cur`` to an ``h x h x c``
    tensor: strided when the resolution divides down, resampling
    otherwise."""
    stride, resample = 1, False
    if cur.h != h:
        s = max(1, round(cur.h / h))
        if ceil_div(cur.h, s) == h:
            stride = s
        else:
            resample = True
    out = Tensor(rows=h * h, d=c, h=h, w=h, elem_bytes=elem_bytes)
    nid = g.add(f"T{idx}", "conv_pw", [src], out, stride=stride,
                resample=resample, activation=None)
    return nid, out


def build_mcunet(modules: Iterable[ModuleConfig], name: str, *,
                 num_classes: int = 2, elem_bytes: int = 1,
                 include_head: bool = True) -> Graph:
    """Lower a MCUNet module table into the IR.

    Each table row becomes its unfused pw1 -> dw -> pw2 (-> residual add)
    node run tagged ``module=<row name>``; adapters connect rows whose
    shapes do not chain; an avgpool/flatten/fc head closes the net.
    """
    modules = list(modules)
    g = Graph(name, elem_bytes=elem_bytes)
    cfg0 = modules[0]
    cur = Tensor(rows=cfg0.hw * cfg0.hw, d=cfg0.c_in, h=cfg0.hw, w=cfg0.hw,
                 elem_bytes=elem_bytes)
    src = g.add("in", "input", [], cur)
    for t, cfg in enumerate(modules):
        if (cur.h, cur.d) != (cfg.hw, cfg.c_in):
            src, cur = _adapter(g, src, cur, cfg.hw, cfg.c_in, elem_bytes,
                                t)
        g.modules[cfg.name] = cfg
        s1, s2, s3 = cfg.strides
        h0 = cfg.hw
        h1 = ceil_div(h0, s1)
        h2 = ceil_div(h1, s2)
        h3 = ceil_div(h2, s3)
        mod_in = src
        b = Tensor(h1 * h1, cfg.c_mid, h1, h1, elem_bytes)
        src = g.add(f"{cfg.name}.pw1", "conv_pw", [src], b, stride=s1,
                    activation="relu", module=cfg.name)
        c = Tensor(h2 * h2, cfg.c_mid, h2, h2, elem_bytes)
        src = g.add(f"{cfg.name}.dw", "conv_dw", [src], c, stride=s2,
                    rs=cfg.rs, activation="relu", module=cfg.name)
        d = Tensor(h3 * h3, cfg.c_out, h3, h3, elem_bytes)
        src = g.add(f"{cfg.name}.pw2", "conv_pw", [src], d, stride=s3,
                    module=cfg.name)
        if cfg.has_residual:
            src = g.add(f"{cfg.name}.add", "add", [src, mod_in], d,
                        module=cfg.name)
        cur = d
    if include_head:
        pooled = Tensor(1, cur.d, 1, 1, elem_bytes)
        src = g.add("head.pool", "avgpool", [src], pooled)
        src = g.add("head.flatten", "flatten", [src], pooled)
        logits = Tensor(1, num_classes, 1, 1, elem_bytes)
        src = g.add("head.fc", "fc", [src], logits)
    g.validate()
    return g


# ---------------------------------------------------------------------------
# MLPerf-Tiny-class model zoo (conv_k2d workloads).
# ---------------------------------------------------------------------------

def _k2d(g: Graph, id: str, src: str, cur: Tensor, c_out: int, *, k: int,
         stride: int = 1, padding: str = "same",
         activation: str | None = "relu", block: str = "",
         elem_bytes: int = 1) -> tuple[str, Tensor]:
    h = conv_k2d_out(cur.h, k, stride, padding)
    w = conv_k2d_out(cur.w, k, stride, padding)
    out = Tensor(rows=h * w, d=c_out, h=h, w=w, elem_bytes=elem_bytes)
    nid = g.add(id, "conv_k2d", [src], out, stride=stride, rs=k,
                padding=padding, activation=activation, block=block)
    return nid, out


def _head(g: Graph, src: str, cur: Tensor, num_classes: int,
          elem_bytes: int) -> None:
    pooled = Tensor(1, cur.d, 1, 1, elem_bytes)
    src = g.add("head.pool", "avgpool", [src], pooled)
    src = g.add("head.flatten", "flatten", [src], pooled)
    logits = Tensor(1, num_classes, 1, 1, elem_bytes)
    g.add("head.fc", "fc", [src], logits)


def build_ds_cnn(*, num_classes: int = 12, c: int = 64,
                 elem_bytes: int = 1) -> Graph:
    """DS-CNN keyword spotting (MLPerf Tiny): 49x10x1 MFCC input, a
    strided k x k stem conv, four depthwise-separable blocks, avgpool +
    fc head.

    The reference stem is a (10, 4)-shaped stride-2 filter; the segment
    ring's conv vocabulary is square k in {3, 5}, so the stem is the
    closest square member: 5x5 stride 2 (same channel count and output
    grid)."""
    g = Graph("ds-cnn", elem_bytes=elem_bytes)
    cur = Tensor(rows=49 * 10, d=1, h=49, w=10, elem_bytes=elem_bytes)
    src = g.add("in", "input", [], cur)
    src, cur = _k2d(g, "stem", src, cur, c, k=5, stride=2,
                    elem_bytes=elem_bytes)
    for i in range(4):
        out = Tensor(cur.rows, c, cur.h, cur.w, elem_bytes)
        src = g.add(f"B{i}.dw", "conv_dw", [src], out, rs=3,
                    activation="relu")
        src = g.add(f"B{i}.pw", "conv_pw", [src], out, activation="relu")
        cur = out
    _head(g, src, cur, num_classes, elem_bytes)
    g.validate()
    return g


def build_resnet8(*, num_classes: int = 10, elem_bytes: int = 1) -> Graph:
    """ResNet-8 (MLPerf Tiny image classification): 32x32x3 input, a
    3x3 stem and three residual stacks (16/32/64 channels; stacks 2 and
    3 downsample with stride 2 and a 1x1 stride-2 shortcut projection),
    avgpool + fc head.

    Each stack is a ``block``-tagged node run so the scheduler lowers it
    as one planning unit: the main-path convs run while the planner
    holds the block input, the shortcut projection reads that held
    tensor (``input_from``), and the post-add relu rides on the ``add``
    op."""
    g = Graph("resnet-8", elem_bytes=elem_bytes)
    cur = Tensor(rows=32 * 32, d=3, h=32, w=32, elem_bytes=elem_bytes)
    src = g.add("in", "input", [], cur)
    src, cur = _k2d(g, "stem", src, cur, 16, k=3, elem_bytes=elem_bytes)
    for i, (c, stride) in enumerate(((16, 1), (32, 2), (64, 2))):
        tag = f"R{i}"
        block_in, tin = src, cur
        src, cur = _k2d(g, f"{tag}.c1", src, cur, c, k=3, stride=stride,
                        block=tag, elem_bytes=elem_bytes)
        src, cur = _k2d(g, f"{tag}.c2", src, cur, c, k=3, stride=1,
                        activation=None, block=tag,
                        elem_bytes=elem_bytes)
        res = block_in
        if stride != 1 or tin.d != c:
            res = g.add(f"{tag}.sc", "conv_pw", [block_in], cur,
                        stride=stride, activation=None, block=tag)
        src = g.add(f"{tag}.add", "add", [src, res], cur,
                    activation="relu", block=tag)
    _head(g, src, cur, num_classes, elem_bytes)
    g.validate()
    return g


def build_mobilenet_v1(*, hw: int = 96, num_classes: int = 2,
                       width_mult: float = 0.25,
                       elem_bytes: int = 1) -> Graph:
    """MobileNetV1 (width multiplier 0.25, 96x96 input by default — the
    MLPerf Tiny visual-wake-words configuration): a real 3x3 stride-2
    stem conv (the op MCUNet-style tables never exercise) followed by
    13 depthwise-separable blocks and the avgpool/fc head."""
    def ch(c: int) -> int:
        return max(8, int(c * width_mult + 0.5) // 8 * 8)

    g = Graph(f"mobilenetv1-{width_mult}", elem_bytes=elem_bytes)
    cur = Tensor(rows=hw * hw, d=3, h=hw, w=hw, elem_bytes=elem_bytes)
    src = g.add("in", "input", [], cur)
    src, cur = _k2d(g, "stem", src, cur, ch(32), k=3, stride=2,
                    elem_bytes=elem_bytes)
    blocks = ((64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2),
              (512, 1), (512, 1), (512, 1), (512, 1), (512, 1),
              (1024, 2), (1024, 1))
    for i, (c, stride) in enumerate(blocks):
        h = ceil_div(cur.h, stride)
        w = ceil_div(cur.w, stride)
        dwt = Tensor(h * w, cur.d, h, w, elem_bytes)
        src = g.add(f"B{i}.dw", "conv_dw", [src], dwt, rs=3,
                    stride=stride, activation="relu")
        out = Tensor(h * w, ch(c), h, w, elem_bytes)
        src = g.add(f"B{i}.pw", "conv_pw", [src], out, activation="relu")
        cur = out
    _head(g, src, cur, num_classes, elem_bytes)
    g.validate()
    return g


def build_ad_autoencoder(*, d_in: int = 640, d_hidden: int = 128,
                         d_latent: int = 8, elem_bytes: int = 1) -> Graph:
    """MLPerf-Tiny anomaly detection (ToyADMOS): a fully-connected
    autoencoder over 640-dim (5-frame stacked) log-mel windows — four
    128-wide encoder layers, an 8-dim bottleneck, four 128-wide decoder
    layers and the 640-dim reconstruction head (the anomaly score is
    the reconstruction error, computed outside the net)."""
    g = Graph("ad-toyadmos", elem_bytes=elem_bytes)
    cur = Tensor(rows=1, d=d_in, elem_bytes=elem_bytes)
    src = g.add("in", "input", [], cur)
    dims = (d_hidden,) * 4 + (d_latent,) + (d_hidden,) * 4 + (d_in,)
    for i, d in enumerate(dims):
        out = Tensor(rows=1, d=d, elem_bytes=elem_bytes)
        act = "relu" if i < len(dims) - 1 else None
        src = g.add(f"fc{i}", "fc", [src], out, activation=act)
    g.validate()
    return g


def _ff_tile(d_ff: int, cap: int = 512) -> int:
    """Largest divisor of d_ff not exceeding ``cap``."""
    for t in range(min(cap, d_ff), 0, -1):
        if d_ff % t == 0:
            return t
    return d_ff


def build_mlp_tower(cfg, *, m_rows: int = 8, n_layers: int | None = None,
                    elem_bytes: int = 2) -> Graph:
    """Lower a ``configs/`` :class:`ModelConfig`'s FFN stack into the IR
    (the pool-resident part of an LM block; attention state does not
    stream through the ring — DESIGN.md §Arch-applicability)."""
    n_layers = cfg.n_layers if n_layers is None else n_layers
    gated = cfg.mlp in ("geglu", "swiglu")
    act = "silu" if cfg.mlp == "swiglu" else "gelu"
    d_ff = cfg.d_ff
    if d_ff == 0:           # pure-SSM configs: the in-projection
        d_ff = cfg.d_inner  # expansion is the never-materialized tensor
        gated, act = True, "silu"
    g = Graph(f"{cfg.name}-mlp-tower", elem_bytes=elem_bytes)
    cur = Tensor(rows=m_rows, d=cfg.d_model, elem_bytes=elem_bytes)
    src = g.add("in", "input", [], cur)
    for i in range(n_layers):
        src = g.add(f"L{i}.mlp", "mlp", [src], cur, d_ff=d_ff,
                    gated=gated, activation=act)
    g.validate()
    return g
