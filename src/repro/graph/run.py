"""Executor bridge: run a planned network end-to-end on any backend.

``run_net`` stages the input image into the ring, executes the NetPlan's
merged :class:`PoolProgram` on ``sim``/``jnp``/``pallas`` and fetches the
output; ``certify_net`` drives the sim oracle (raises
:class:`PoolClobberError` iff any cross-layer offset is unsafe);
``reference_forward`` computes the same network as a plain-XLA forward
pass with no pool mechanics — the float-tolerance ground truth for the
ring backends.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..core.executors import execute, run_program
from ..core.program import PoolProgram, resolve_activation
from .netplan import NetPlan


def _prog(plan) -> PoolProgram:
    return plan.program if isinstance(plan, NetPlan) else plan


def init_net_params(plan, key=None, dtype=jnp.float32) -> list:
    """Random, magnitude-controlled parameters for every op of the plan
    (weights scaled ~1/sqrt(fan_in) so deep nets stay in float range)."""
    program = _prog(plan)
    if key is None:
        key = jax.random.PRNGKey(0)
    gain = 2.0 ** 0.5  # He init: ReLU halves the variance
    params = []
    for op in program.ops:
        if op.kind in ("gemm", "conv_pw"):
            key, k1 = jax.random.split(key)
            w = jax.random.normal(k1, (op.d_in, op.d_out), dtype)
            params.append((w * gain / (op.d_in ** 0.5), None))
        elif op.kind == "conv_dw":
            key, k1 = jax.random.split(key)
            w = jax.random.normal(k1, (op.rs, op.rs, op.d_in), dtype)
            params.append((w / op.rs, None))
        elif op.kind in ("conv_k2d", "conv_stream"):
            key, k1 = jax.random.split(key)
            w = jax.random.normal(k1, (op.rs, op.rs, op.d_in, op.d_out),
                                  dtype)
            params.append((w * gain / ((op.rs * op.rs * op.d_in) ** 0.5),
                           None))
        elif op.kind == "gru_cell":
            key, k1, k2 = jax.random.split(key, 3)
            w = jax.random.normal(k1, (op.d_in, 3 * op.d_out), dtype) \
                / (op.d_in ** 0.5)
            u = jax.random.normal(k2, (op.d_out, 3 * op.d_out), dtype) \
                / (op.d_out ** 0.5)
            params.append((w, u, None))
        elif op.kind == "ib_fused":
            key, k1, k2, k3 = jax.random.split(key, 4)
            w1 = jax.random.normal(k1, (op.d_in, op.d_mid), dtype) \
                / (op.d_in ** 0.5)
            wd = jax.random.normal(k2, (op.rs, op.rs, op.d_mid), dtype) \
                / op.rs
            w2 = jax.random.normal(k3, (op.d_mid, op.d_out), dtype) \
                / (op.d_mid ** 0.5)
            params.append((w1, wd, w2))
        elif op.kind == "fused_mlp":
            key, k1, k2, k3 = jax.random.split(key, 4)
            wg = jax.random.normal(k1, (op.d_in, op.d_ff), dtype) \
                / (op.d_in ** 0.5)
            wu = jax.random.normal(k2, (op.d_in, op.d_ff), dtype) \
                / (op.d_in ** 0.5)
            wd = jax.random.normal(k3, (op.d_ff, op.d_in), dtype) \
                / op.d_ff
            params.append((wg, wu, wd))
        else:
            params.append(None)
    return params


def _conv_ref(img, w, *, stride: int, pad_lo: int, h_out: int, w_out: int,
              groups: int = 1) -> jax.Array:
    """Independent conv oracle via ``lax.conv_general_dilated`` (NOT the
    executors' tap/gather formulation, so a shared indexing bug cannot
    cancel out).  High padding is chosen so the output is exactly
    ``ceil(h/stride)`` — the planner's 'same' convention."""
    h_in, w_in, _ = img.shape
    rs = w.shape[0]
    ph = (h_out - 1) * stride + rs - pad_lo - h_in
    pw = (w_out - 1) * stride + rs - pad_lo - w_in
    out = jax.lax.conv_general_dilated(
        img[None], w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=((pad_lo, ph), (pad_lo, pw)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)
    return out[0]


def reference_forward(plan, x: jax.Array, params, *,
                      intermediates: list | None = None) -> jax.Array:
    """Plain-XLA forward pass of the planned network (no pool).

    ``x`` is ``[rows, d]`` — the flattened input image.  Residual ``add``
    ops read the saved input of their source op, exactly as the ring
    executors read the held interval.

    ``intermediates`` (if a list) collects the float input tensor of
    every op followed by the network output — the taps int8 calibration
    (:func:`quantize_net`) derives its activation scales from.
    """
    from ..core.rowsched import conv_k2d_pad, resample_src

    program = _prog(plan)
    saved: dict[int, jax.Array] = {}
    cur = x.astype(jnp.float32)
    for i, (op, p) in enumerate(zip(program.ops, params)):
        saved[i] = cur
        if intermediates is not None:
            intermediates.append(cur)
        # branch convs (ResNet shortcut projections) read the held input
        # of op ``in_op``, not the chained tensor
        src = saved[op.in_op] if op.in_op >= 0 else cur
        act = resolve_activation(op.activation)
        if op.kind in ("gemm", "conv_pw"):
            w, b = p if p[1] is not None else (p[0], jnp.zeros(op.d_out))
            wf = w.astype(jnp.float32)
            if op.kind == "conv_pw" and op.resample:
                # the nearest-grid adapter is gather-by-definition
                img = src.reshape(op.h_in, op.w_in, op.d_in)
                ridx = [resample_src(r, op.h_in, op.h_out)
                        for r in range(op.h_out)]
                cidx = [resample_src(c, op.w_in, op.w_out)
                        for c in range(op.w_out)]
                sub = img[jnp.array(ridx)][:, jnp.array(cidx)]
                y = jnp.einsum("hwc,cd->hwd", sub, wf)
                cur = act(y + b).reshape(op.rows_out, op.d_out)
            elif op.kind == "conv_pw":
                img = src.reshape(op.h_in, op.w_in, op.d_in)
                y = _conv_ref(img, wf.reshape(1, 1, op.d_in, op.d_out),
                              stride=op.stride, pad_lo=0,
                              h_out=op.h_out, w_out=op.w_out)
                cur = act(y + b).reshape(op.rows_out, op.d_out)
            else:
                cur = act(src @ wf + b)
        elif op.kind == "conv_dw":
            w, b = p if p[1] is not None else (p[0], jnp.zeros(op.d_out))
            img = src.reshape(op.h_in, op.w_in, op.d_in)
            y = _conv_ref(img,
                          w.astype(jnp.float32).reshape(op.rs, op.rs, 1,
                                                        op.d_in),
                          stride=op.stride, pad_lo=(op.rs - 1) // 2,
                          h_out=op.h_out, w_out=op.w_out,
                          groups=op.d_in)
            cur = act(y + b).reshape(op.rows_out, op.d_out)
        elif op.kind == "conv_k2d":
            w, b = p if p[1] is not None else (p[0], jnp.zeros(op.d_out))
            img = src.reshape(op.h_in, op.w_in, op.d_in)
            y = _conv_ref(img, w.astype(jnp.float32),
                          stride=op.stride,
                          pad_lo=conv_k2d_pad(op.rs, op.padding),
                          h_out=op.h_out, w_out=op.w_out)
            cur = act(y + b).reshape(op.rows_out, op.d_out)
        elif op.kind == "conv_stream":
            # one streaming step from reset: the window is the zero
            # state (== zero padding, exactly what VirtualPool.alloc
            # leaves in the state region) with the frame appended
            w, b = p if p[1] is not None else (p[0], jnp.zeros(op.d_out))
            frame = src.reshape(op.hop, op.w_in, op.d_in)
            state = jnp.zeros((op.h_in - op.hop, op.w_in, op.d_in),
                              jnp.float32)
            win = jnp.concatenate([state, frame], axis=0)
            y = _conv_ref(win, w.astype(jnp.float32),
                          stride=op.stride,
                          pad_lo=conv_k2d_pad(op.rs, op.padding),
                          h_out=op.h_out, w_out=op.w_out)
            cur = act(y + b).reshape(op.rows_out, op.d_out)
        elif op.kind == "gru_cell":
            from ..quant.requant import gru_update
            w, u, b = p if p[2] is not None else \
                (p[0], p[1], jnp.zeros(3 * op.d_out))
            h = jnp.zeros((1, op.d_out), jnp.float32)
            gx = src @ w.astype(jnp.float32) + b.astype(jnp.float32)
            gh = h @ u.astype(jnp.float32)
            cur = gru_update(gx, gh, h, op.d_out)
        elif op.kind == "ib_fused":
            from ..kernels.inverted_bottleneck import \
                inverted_bottleneck_ref
            w1, wd, w2 = p
            a = src.reshape(op.h_in, op.w_in, op.d_in)
            cur = inverted_bottleneck_ref(a, w1, wd, w2,
                                          residual=op.residual) \
                .astype(jnp.float32).reshape(op.rows_out, op.d_out)
        elif op.kind == "add":
            cur = act(cur + saved[op.aux_op])
        elif op.kind == "pool_avg":
            img = cur.reshape(op.h_in, op.w_in, op.d_in)
            cur = jnp.mean(img, axis=(0, 1))[None, :]
        elif op.kind == "fused_mlp":
            from ..kernels.ref import fused_mlp_ref
            wg, wu, wd = p
            cur = fused_mlp_ref(cur, wg, wu, wd, gated=op.gated,
                                residual=op.residual,
                                activation=op.activation) \
                .astype(jnp.float32)
        elif op.kind == "elementwise":
            cur = act(cur)
        else:
            raise NotImplementedError(op.kind)
    if intermediates is not None:
        intermediates.append(cur)
    return cur


def run_net(plan, x: jax.Array, params, *, backend: str = "jnp",
            **kwargs) -> jax.Array:
    """Stage ``x`` at the plan's input pointer, execute every group
    through the one ring, fetch the network output."""
    program = _prog(plan)
    y, _pool = run_program(program, x, params, backend=backend, **kwargs)
    return y


def certify_net(plan):
    """Run the whole NetProgram through the SegmentPool clobber oracle.

    Returns the oracle (peak_live, reads/writes stats); raises
    :class:`repro.core.pool.PoolClobberError` iff any op's write lands on
    a segment some later op still needs — i.e. the cross-layer chaining
    is provably safe when this returns.
    """
    return execute(_prog(plan), backend="sim")


# ---------------------------------------------------------------------------
# Int8 quantized execution (DESIGN.md §8).
# ---------------------------------------------------------------------------

_Q_KINDS = ("gemm", "conv_pw", "conv_dw", "conv_k2d", "add", "pool_avg",
            "conv_stream", "gru_cell")
_Q_ACTIVATIONS = (None, "identity", "relu")


@dataclasses.dataclass
class QuantizedNet:
    """A calibrated int8 deployment of one planned network.

    ``program`` is the SAME solved plan re-typed int8
    (``with_dtype("int8")`` — segment geometry, and therefore the sim
    certificate, is shared with the float plan); ``qparams`` are the
    per-op executor entries (int8 weights, int32 biases, requant
    multiplier/shift constants); ``act_scales[i]`` is the symmetric
    scale of tensor ``i`` (0 = network input, ``i`` = output of op
    ``i-1``)."""

    plan: object                       # the float NetPlan / PoolProgram
    program: "PoolProgram"             # int8-typed program
    params: list                       # float params (reference forward)
    qparams: list                      # int8 executor entries
    act_scales: tuple[float, ...]

    @property
    def in_scale(self) -> float:
        return self.act_scales[0]

    @property
    def out_scale(self) -> float:
        return self.act_scales[-1]

    @property
    def pool_bytes(self) -> int:
        """The executed int8 ring footprint — byte-comparable to the
        byte-granular ``mcu_bottleneck_bytes`` now."""
        return self.program.pool_bytes


def _check_quantizable(program: PoolProgram) -> None:
    for op in program.ops:
        if op.kind not in _Q_KINDS:
            raise ValueError(
                f"op kind {op.kind!r} has no int8 execution path — plan "
                "the net with plan_net(..., fused_exec=False) so modules "
                "lower to their unfused pw/dw/pw(/add) runs")
        if op.activation not in _Q_ACTIVATIONS:
            raise ValueError(f"activation {op.activation!r} has no int8 "
                             "form (relu/None only)")


def _quantize_net(plan, params, *, calib: jax.Array | None = None,
                  n_calib: int = 2, key=None) -> QuantizedNet:
    """Calibrate an int8 deployment from the float reference forward.

    ``plan`` must lower to the unfused op vocabulary (``plan_net(...,
    fused_exec=False)``); ``calib`` is ``[n, rows, d]`` float calibration
    inputs (random normal when omitted).  Per-tensor symmetric activation
    scales come from the amax over the captured reference intermediates;
    weights are per-output-channel; every op gets CMSIS-NN-style
    ``(multiplier, shift)`` requant constants relating
    ``s_in * s_w[c] / s_out``.
    """
    from ..obs.spans import span
    from ..quant import (calibrate, quantize, quantize_bias, requant_pair,
                         requant_scalar)

    program = _prog(plan)
    _check_quantizable(program)
    if calib is None:
        if key is None:
            key = jax.random.PRNGKey(0)
        calib = jax.random.normal(
            key, (n_calib, program.in_rows, program.in_dim))

    # 1. activation scales from the captured reference intermediates
    n_ops = len(program.ops)
    amax = [0.0] * (n_ops + 1)
    with span("calibrate", batches=len(calib), taps=n_ops + 1):
        for x in calib:
            taps: list = []
            reference_forward(program, x, params, intermediates=taps)
            for i, t in enumerate(taps):
                amax[i] = max(amax[i], float(jnp.abs(t).max()))
    with span("act_scales"):
        act_qps = [calibrate(jnp.array([a])) for a in amax]
        act_scales = tuple(float(qp.scale) for qp in act_qps)
    if any(op.kind == "gru_cell" for op in program.ops):
        # the GRU hidden state IS the op output and lives in the pool at
        # the FIXED Q7 scale 1/128 across invocations — pin it before
        # any downstream requant constant is derived from it
        scales = list(act_scales)
        for i, op in enumerate(program.ops):
            if op.kind == "gru_cell":
                scales[i + 1] = 1.0 / 128.0
        act_scales = tuple(scales)

    # 2. per-op weight quantization + requant constants
    qparams: list = []
    with span("quantize_ops", ops=n_ops):
        for i, (op, p) in enumerate(zip(program.ops, params)):
            # branch convs read the held input of op ``in_op`` — their
            # input scale is that tensor's, not the chained tensor's
            s_in = act_scales[op.in_op if op.in_op >= 0 else i]
            s_out = act_scales[i + 1]
            if op.kind in ("gemm", "conv_pw", "conv_dw", "conv_k2d",
                           "conv_stream"):
                w, b = p if p[1] is not None else (p[0], None)
                axis = {"conv_dw": 2, "conv_k2d": 3,
                        "conv_stream": 3}.get(op.kind, 1)
                w_qp = calibrate(w, axis=axis)
                w_q = quantize(w, w_qp)
                b_q = (quantize_bias(b, s_in, w_qp) if b is not None
                       else jnp.zeros((op.d_out,), jnp.int32))
                mult, shift = requant_pair(s_in, w_qp, s_out)
                qparams.append((w_q, b_q, mult, shift))
            elif op.kind == "add":
                s_aux = act_scales[op.aux_op]   # the held source is op
                #                                 aux_op's INPUT tensor
                m_i, s_i = requant_scalar(s_in / s_out)
                m_a, s_a = requant_scalar(s_aux / s_out)
                qparams.append((m_i, s_i, m_a, s_a))
            elif op.kind == "pool_avg":
                m, s = requant_scalar(s_in / (op.h_in * op.w_in * s_out))
                qparams.append((m, s))
            elif op.kind == "gru_cell":
                # Q12 gate domain (scale 1/4096): both accumulators are
                # requantized into it, the bias is folded there, and the
                # recurrent input is the fixed Q7 hidden state
                w, u, b = p
                w_qp = calibrate(w, axis=1)
                u_qp = calibrate(u, axis=1)
                w_q, u_q = quantize(w, w_qp), quantize(u, u_qp)
                b_q12 = (jnp.asarray(
                    jnp.round(jnp.asarray(b, jnp.float32) * 4096.0),
                    jnp.int32) if b is not None
                    else jnp.zeros((3 * op.d_out,), jnp.int32))
                mx, sx = requant_pair(s_in, w_qp, 1.0 / 4096.0)
                mu, su = requant_pair(1.0 / 128.0, u_qp, 1.0 / 4096.0)
                qparams.append((w_q, u_q, b_q12, mx, sx, mu, su))
    return QuantizedNet(plan=plan, program=program.with_dtype("int8"),
                        params=list(params), qparams=qparams,
                        act_scales=act_scales)


def quantize_net(plan, params, **kwargs) -> QuantizedNet:
    """Deprecated direct entry — use ``repro.compile(net, target=...,
    dtype="int8")``, whose ``quantize`` pass runs this calibration with
    the target's dtype/idiom defaults.  The shim keeps the exact legacy
    behavior (same defaults, same QuantizedNet)."""
    import warnings

    warnings.warn(
        "direct quantize_net() entry is deprecated; use "
        "repro.compile(net, target=..., dtype='int8') — the driver runs "
        "quantize_net as its 'quantize' pass",
        DeprecationWarning, stacklevel=2)
    return _quantize_net(plan, params, **kwargs)


def run_net_quantized(qnet: QuantizedNet, x: jax.Array, *,
                      backend: str = "jnp", **kwargs) -> jax.Array:
    """Quantize ``x``, execute the int8 program on the ring, dequantize.

    The pool is an int8 array — ``n_segments * seg_width`` BYTES of
    state, the deployable footprint — and every op accumulates in int32
    and requantizes on store (sim certifies the identical schedule)."""
    from ..quant import QParams, dequantize, quantize

    x_q = quantize(x, QParams(scale=qnet.in_scale))
    y_q, _pool = run_program(qnet.program, x_q, qnet.qparams,
                             backend=backend, **kwargs)
    return dequantize(y_q, QParams(scale=qnet.out_scale))


def quantized_agreement(qnet: QuantizedNet, *, n: int = 8, key=None,
                        backend: str = "jnp") -> dict:
    """Top-line int8-vs-float agreement over random inputs.

    Returns ``cosine`` (mean cosine similarity of the flattened
    outputs), ``argmax_agreement`` (fraction of inputs whose top-1
    output index matches) and ``n``."""
    import numpy as np

    if key is None:
        key = jax.random.PRNGKey(42)
    program = qnet.program
    xs = jax.random.normal(key, (n, program.in_rows, program.in_dim))
    cos, agree = [], []
    for x in xs:
        ref = np.asarray(reference_forward(program, x, qnet.params))
        got = np.asarray(run_net_quantized(qnet, x, backend=backend))
        a, b = ref.ravel(), got.ravel()
        denom = (np.linalg.norm(a) * np.linalg.norm(b)) or 1.0
        cos.append(float(a @ b / denom))
        agree.append(int(np.argmax(a) == np.argmax(b)))
    return {"cosine": float(np.mean(cos)),
            "argmax_agreement": float(np.mean(agree)), "n": n}
